//! E1 / §1 motivation — fraction of PUD-executable operations under
//! each allocator, across allocation sizes.
//!
//! Paper's reported numbers: malloc and posix_memalign are 0% at every
//! size; huge-page-backed allocation reaches up to ~60% only at large
//! sizes; (PUMA, by design, is ~100%). Raw series: out/motivation.csv.
//!
//! Run: `cargo bench --bench bench_motivation`

use puma::alloc::puma::FitPolicy;
use puma::report;
use puma::workloads::microbench::AllocatorKind;
use puma::workloads::sweep::{self, SweepConfig};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("PUMA_BENCH_FAST").is_ok();
    let mut cfg = SweepConfig::default();
    if fast {
        cfg.sizes = vec![250, 4 << 10, 64 << 10, 768 << 10];
        cfg.huge_pages = 64;
        cfg.churn_rounds = 5_000;
    }
    let kinds = [
        AllocatorKind::Malloc,
        AllocatorKind::Memalign,
        AllocatorKind::HugePages,
        AllocatorKind::Puma(FitPolicy::WorstFit),
    ];

    println!("# bench_motivation — reproduces the §1 allocator study");
    let t0 = std::time::Instant::now();
    let rows = sweep::run_motivation(&cfg, &kinds)?;
    println!("{} cells in {:.2?} wall\n", rows.len(), t0.elapsed());
    println!("{}", report::motivation(&rows, Some(std::path::Path::new("out")))?);

    // Paper-shape assertions.
    let frac = |kind: AllocatorKind, pred: &dyn Fn(u64) -> bool| -> Vec<f64> {
        rows.iter()
            .filter(|(k, s, _)| *k == kind && pred(*s))
            .map(|(_, _, f)| *f)
            .collect()
    };
    let all = |_: u64| true;
    for k in [AllocatorKind::Malloc, AllocatorKind::Memalign] {
        let worst = frac(k, &all).into_iter().fold(0.0, f64::max);
        assert!(
            worst < 0.02,
            "{}: expected ~0% PUD-executable, got {worst:.2}",
            k.name()
        );
    }
    // huge pages: partial success only — some sizes work (when the
    // bump offsets happen to be row+bank congruent), most do not.
    // The paper reports "up to 60%" at large sizes; our deterministic
    // bump model is binary per size, so the per-size values are 0% or
    // 100% and the *mean* lands in the paper's partial band. See
    // EXPERIMENTS.md E1 for the discussion.
    let huge_all = frac(AllocatorKind::HugePages, &all);
    let huge_mean = huge_all.iter().sum::<f64>() / huge_all.len() as f64;
    let huge_small = frac(AllocatorKind::HugePages, &|s| s < 8 << 10)
        .into_iter()
        .fold(0.0, f64::max);
    let puma_min = frac(AllocatorKind::Puma(FitPolicy::WorstFit), &|s| s >= 4 << 10)
        .into_iter()
        .fold(1.0, f64::min);
    assert!(
        huge_mean > 0.02 && huge_mean < 0.9,
        "hugepages should be partial overall (mean {huge_mean:.2})"
    );
    assert!(
        huge_small < 0.05,
        "hugepages should fail at sub-row sizes (got {huge_small:.2})"
    );
    assert!(
        puma_min > 0.95,
        "puma should be ~100% at row-sized allocations (got {puma_min:.2})"
    );
    println!(
        "motivation shape checks passed (malloc/memalign ~0%; hugepages partial \
         [mean {:.0}%]; puma ~100%)",
        huge_mean * 100.0
    );
    Ok(())
}
