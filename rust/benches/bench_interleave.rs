//! E4 ablation — sensitivity to the DRAM interleaving scheme.
//!
//! PUMA consumes the interleaving from the device tree; this bench
//! shows that (a) PUMA keeps ~100% PUD eligibility under every scheme
//! (it adapts via the subarray-ID computation), while (b) the
//! huge-page baseline's luck changes drastically with the scheme —
//! the reason the paper needs the device-tree information at all.
//!
//! Run: `cargo bench --bench bench_interleave`

use puma::alloc::puma::FitPolicy;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::util::csvio::Csv;
use puma::util::table::Table;
use puma::workloads::microbench::{self, AllocatorKind, Micro};

fn eligibility(
    scheme: InterleaveScheme,
    kind: AllocatorKind,
    size: u64,
) -> anyhow::Result<f64> {
    let mut sys = System::boot(SystemConfig {
        scheme,
        huge_pages: 64,
        churn_rounds: 5_000,
        seed: 0x1417,
        artifacts: None,
        ..Default::default()
    })?;
    let r = microbench::run(&mut sys, kind, Micro::Aand, size, 1, 32, false, 11)?;
    Ok(r.pud_fraction())
}

fn main() -> anyhow::Result<()> {
    println!("# bench_interleave — interleaving-scheme sensitivity (E4)");
    let g = DramGeometry::default();
    let schemes: Vec<(&str, InterleaveScheme)> = vec![
        ("row_major", InterleaveScheme::row_major(g.clone())),
        ("bank_xor", InterleaveScheme::bank_xor(g.clone())),
        ("subarray_low", InterleaveScheme::subarray_low(g.clone())),
    ];
    let kinds = [
        AllocatorKind::Malloc,
        AllocatorKind::HugePages,
        AllocatorKind::Puma(FitPolicy::WorstFit),
    ];
    let size = 384 << 10; // a size where hugepages can get lucky

    let mut table =
        Table::new(vec!["allocator", "row_major", "bank_xor", "subarray_low"]).left(0);
    let mut csv = Csv::new(vec!["allocator", "scheme", "pud_fraction"]);
    let mut puma_min = 1.0f64;
    let mut huge_spread = Vec::new();
    for kind in kinds {
        let mut row = vec![kind.name().to_string()];
        for (sname, scheme) in &schemes {
            let f = eligibility(scheme.clone(), kind, size)?;
            row.push(format!("{:.0}%", f * 100.0));
            csv.row(vec![
                kind.name().to_string(),
                sname.to_string(),
                format!("{f:.4}"),
            ]);
            if matches!(kind, AllocatorKind::Puma(_)) {
                puma_min = puma_min.min(f);
            }
            if kind == AllocatorKind::HugePages {
                huge_spread.push(f);
            }
        }
        table.row(row);
    }
    println!("{}", table.render());
    csv.write("out/interleave.csv")?;
    println!("(raw: out/interleave.csv)");

    assert!(
        puma_min > 0.95,
        "PUMA must adapt to every scheme (min {puma_min:.2})"
    );
    let spread = huge_spread.iter().copied().fold(f64::NEG_INFINITY, f64::max)
        - huge_spread.iter().copied().fold(f64::INFINITY, f64::min);
    println!(
        "interleave check passed (PUMA scheme-proof; hugepages spread {:.0} points)",
        spread * 100.0
    );
    Ok(())
}
