//! E6 — request-path throughput: serial submission vs the batched
//! plan/schedule/execute pipeline, plus (when artifacts exist) the raw
//! XLA/PJRT fallback kernels.
//!
//! The core section needs no compiled artifacts: it drives the full
//! System with the scalar fallback over a mixed workload (PUMA-placed
//! ops that run in-DRAM + malloc-placed ops that fall back), once via
//! N serial `submit` calls and once via one `submit_batch`. It writes
//! `BENCH_runtime.json` with machine-readable ops/s, pud_row_fraction,
//! and dispatch counts so the perf trajectory is tracked across PRs.
//!
//! `xla_dispatches` in the JSON counts fallback *dispatch units* (one
//! per coalesced dispatch group); when the XLA runtime is loaded these
//! are exactly the `run_op` calls issued (reported separately as
//! `xla_run_op_calls`, which stays 0 without artifacts). Throughput is
//! reported in
//! simulated time (the paper's metric): the batched path's elapsed
//! time lets independent banks overlap, the serial path pays the
//! per-op sum.
//!
//! Run: `cargo bench --bench bench_runtime`

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::analysis::VerifyLevel;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::pud::arith;
use puma::pud::isa::{BulkRequest, PudOp};
use puma::util::bench::{bench, black_box, BenchOpts};
use puma::util::csvio::Csv;
use puma::util::rng::Pcg64;
use puma::workloads::analytics::{
    self, AnalyticsConfig, AnalyticsResult, ShardedConfig, ShardedResult,
};
use puma::workloads::churn::{self, ChurnConfig, ChurnResult};
use puma::workloads::filter::{self, FilterConfig, FilterResult};
use puma::workloads::microbench::AllocatorKind;
use puma::workloads::queries::{self, QueriesConfig, QueryResult};
use puma::workloads::serve::{ServeConfig, ServeResult};

fn small_scheme() -> InterleaveScheme {
    InterleaveScheme::row_major(DramGeometry::small()) // 64 MiB
}

fn boot() -> System {
    System::boot(SystemConfig {
        scheme: small_scheme(),
        huge_pages: 16,
        churn_rounds: 3_000,
        seed: 0xE6,
        artifacts: None,
        ..Default::default()
    })
    .expect("boot")
}

/// Build the mixed workload on `sys`: `groups` independent operand
/// triples — 3 of every 4 PUMA-placed (in-DRAM path), the rest
/// malloc-placed (fallback path) — with one partial-tail op. Returns
/// the owning pid and the requests in submission order.
fn build_workload(
    sys: &mut System,
    groups: usize,
) -> anyhow::Result<(puma::os::process::Pid, Vec<BulkRequest>)> {
    let pid = sys.spawn();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let mut puma_alloc = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma_alloc.pim_preallocate(&mut sys.os, 8)?;
    let mut malloc = MallocSim::new();
    let ops = [PudOp::And, PudOp::Or, PudOp::Xor, PudOp::Copy];
    let mut rng = Pcg64::new(0xBEEF);
    let mut reqs = Vec::with_capacity(groups);
    for i in 0..groups {
        // one partial tail row in the mix, the rest row-multiples
        let len = if i == groups / 2 { 3 * row + 1000 } else { 4 * row };
        let op = ops[i % ops.len()];
        let on_pud = i % 4 != 3;
        let (a, b, dst) = if on_pud {
            let a = sys.alloc(&mut puma_alloc, pid, len)?;
            (
                a,
                sys.alloc_align(&mut puma_alloc, pid, len, a)?,
                sys.alloc_align(&mut puma_alloc, pid, len, a)?,
            )
        } else {
            let a = sys.alloc(&mut malloc, pid, len)?;
            (
                a,
                sys.alloc(&mut malloc, pid, len)?,
                sys.alloc(&mut malloc, pid, len)?,
            )
        };
        let mut data = vec![0u8; len as usize];
        rng.fill_bytes(&mut data);
        sys.write_virt(pid, a, &data)?;
        rng.fill_bytes(&mut data);
        sys.write_virt(pid, b, &data)?;
        let srcs = match op.arity() {
            1 => vec![a],
            _ => vec![a, b],
        };
        reqs.push(BulkRequest::new(op, dst, srcs, len));
    }
    Ok((pid, reqs))
}

struct PathMetrics {
    sim_ns: f64,
    elapsed_sim_ns: f64,
    ops_per_sim_s: f64,
    pud_row_fraction: f64,
    fallback_dispatches: u64,
    xla_dispatches: u64,
    waves: u64,
    wall_ns_per_pass: f64,
}

fn measure(serial: bool, groups: usize, opts: &BenchOpts) -> anyhow::Result<PathMetrics> {
    // stats pass: one traversal on a fresh system
    let mut sys = boot();
    let (pid, reqs) = build_workload(&mut sys, groups)?;
    let mut sim_ns = 0.0;
    let mut elapsed_sim_ns = 0.0;
    if serial {
        for r in &reqs {
            let ns = sys.submit(pid, r)?;
            sim_ns += ns;
            elapsed_sim_ns += ns;
        }
    } else {
        let report = sys.submit_batch(pid, &reqs)?;
        sim_ns = report.total_ns;
        elapsed_sim_ns = report.elapsed_ns;
    }
    let stats = sys.coord.stats.clone();
    let pipeline = sys.coord.pipeline.clone();

    // timing pass: repeated traversals on the same (idempotent) system
    let name = if serial { "coordinator-serial" } else { "coordinator-batched" };
    let label = format!("{name} ({groups} mixed ops)");
    let res = bench(&label, opts, |_| {
        if serial {
            for r in &reqs {
                black_box(sys.submit(pid, r).expect("submit"));
            }
        } else {
            black_box(sys.submit_batch(pid, &reqs).expect("submit_batch"));
        }
    });

    Ok(PathMetrics {
        sim_ns,
        elapsed_sim_ns,
        ops_per_sim_s: reqs.len() as f64 / (elapsed_sim_ns * 1e-9),
        pud_row_fraction: stats.pud_row_fraction(),
        fallback_dispatches: pipeline.fallback_dispatches,
        xla_dispatches: stats.xla_dispatches,
        waves: pipeline.waves,
        wall_ns_per_pass: res.wall_ns.mean,
    })
}

fn churn_json(r: &ChurnResult) -> String {
    let curve = |f: &dyn Fn(&puma::workloads::churn::EpochSample) -> f64| {
        r.samples
            .iter()
            .map(|s| format!("{:.4}", f(s)))
            .collect::<Vec<_>>()
            .join(", ")
    };
    format!(
        "{{\"steady_pud_fraction\": {:.6}, \"pages_returned\": {}, \
         \"regions_migrated\": {}, \"final_occupancy\": {:.6}, \
         \"final_pool_available\": {}, \"pud_curve\": [{}], \
         \"occupancy_curve\": [{}]}}",
        r.steady_state_pud_fraction,
        r.pages_returned,
        r.alloc.regions_migrated,
        r.final_occupancy,
        r.final_pool_available,
        curve(&|s| s.op_pud_fraction),
        curve(&|s| s.pool_occupancy),
    )
}

fn filter_json(r: &FilterResult) -> String {
    format!(
        "{{\"pud_row_fraction\": {:.6}, \"hand_pud_row_fraction\": {:.6}, \
         \"ops\": {}, \"scratch_slots\": {}, \"cse_hits\": {}, \"waves\": {}, \
         \"elapsed_sim_ns\": {:.1}, \"hand_sim_ns\": {:.1}, \
         \"speedup_vs_hand\": {:.3}, \"matches\": {}}}",
        r.compiled_pud_fraction,
        r.hand_pud_fraction,
        r.compile.ops,
        r.compile.scratch_slots,
        r.compile.cse_hits,
        r.waves,
        r.elapsed_ns,
        r.hand_ns,
        r.speedup(),
        r.matches
    )
}

fn analytics_json(r: &AnalyticsResult) -> String {
    format!(
        "{{\"allocator\": \"{}\", \"width\": {}, \"pud_row_fraction\": {:.6}, \
         \"elapsed_sim_ns\": {:.1}, \"ops\": {}, \"aaps_per_elem\": {:.4}, \
         \"host_ns_per_elem\": {:.4}, \"col_hits\": {}, \"col_misses\": {}, \
         \"pool_leases\": {}, \"matches\": {}, \"sum\": {}}}",
        r.allocator,
        r.width,
        r.pud_row_fraction(),
        r.elapsed_ns,
        r.compile.ops,
        r.aaps_per_elem,
        r.host_ns_per_elem,
        r.col_hits,
        r.col_misses,
        r.pool_leases,
        r.matches,
        r.sum
    )
}

fn sharded_json(r: &ShardedResult) -> String {
    format!(
        "{{\"allocator\": \"{}\", \"width\": {}, \"shards\": {}, \
         \"pud_row_fraction\": {:.6}, \"elapsed_sim_ns\": {:.1}, \
         \"waves\": {}, \"host_ns_per_elem\": {:.4}, \"col_hits\": {}, \
         \"col_misses\": {}, \"matches\": {}, \"sum\": {}}}",
        r.allocator,
        r.width,
        r.shard_count,
        r.pud_row_fraction(),
        r.elapsed_ns,
        r.waves,
        r.host_ns_per_elem,
        r.col_hits,
        r.col_misses,
        r.matches,
        r.sum
    )
}

fn query_json(r: &QueryResult) -> String {
    format!(
        "{{\"allocator\": \"{}\", \"shape\": \"{}\", \"shards\": {}, \
         \"param\": {}, \"batches\": {}, \"waves\": {}, \"rounds\": {}, \
         \"compiles\": {}, \"pud_row_fraction\": {:.6}, \
         \"elapsed_sim_ns\": {:.1}, \"ns_per_elem\": {:.4}, \
         \"host_ns_per_elem\": {:.4}, \"col_hits\": {}, \"col_misses\": {}, \
         \"matches\": {}, \"agg\": {}}}",
        r.allocator,
        r.shape,
        r.shards,
        r.param,
        r.batches,
        r.waves,
        r.rounds,
        r.compiles,
        r.pud_row_fraction(),
        r.elapsed_ns,
        r.elapsed_ns / r.rows.max(1) as f64,
        r.host_ns_per_elem,
        r.col_hits,
        r.col_misses,
        r.matches,
        r.agg
    )
}

/// Per-shape summary over the flat PUMA cell — the fields the CI
/// bench job asserts on (`pud_row_fraction` + `ns_per_elem`).
fn query_shape_json(cells: &[QueryResult], shape: &str) -> String {
    let p = cells
        .iter()
        .find(|r| r.allocator == "puma" && r.shape == shape && r.shards == 0)
        .expect("puma flat query cell");
    format!(
        "{{\"pud_row_fraction\": {:.6}, \"ns_per_elem\": {:.4}, \
         \"host_ns_per_elem\": {:.4}, \"matches\": {}}}",
        p.pud_row_fraction(),
        p.elapsed_ns / p.rows.max(1) as f64,
        p.host_ns_per_elem,
        p.matches
    )
}

fn serve_json(r: &ServeResult) -> String {
    format!(
        "{{\"allocator\": \"{}\", \"drr_rounds\": {}, \
         \"drr_p50_ns\": {:.1}, \"drr_p99_ns\": {:.1}, \
         \"b2b_p50_ns\": {:.1}, \"b2b_p99_ns\": {:.1}, \
         \"drr_makespan_ns\": {:.1}, \"b2b_makespan_ns\": {:.1}, \
         \"p99_speedup\": {:.4}, \"identical\": {}, \
         \"pud_row_fraction\": {:.6}, \"accepted\": {}, \"queued\": {}, \
         \"rejected\": {}}}",
        r.allocator,
        r.drr_rounds,
        r.drr_p50_ns,
        r.drr_p99_ns,
        r.b2b_p50_ns,
        r.b2b_p99_ns,
        r.drr_makespan_ns,
        r.b2b_makespan_ns,
        r.p99_speedup(),
        r.identical,
        r.pud_row_fraction(),
        r.admission.accepted,
        r.admission.queued,
        r.admission.rejected
    )
}

/// Mean host-boundary ns/elem across the PUMA cells — the gated
/// host-time metric (lower is better).
fn mean_host_ns<'a, I: Iterator<Item = &'a f64>>(vals: I) -> f64 {
    let v: Vec<f64> = vals.copied().collect();
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// Measure the blocked bit-matrix transpose against the bit-at-a-time
/// oracle on a 1 Mi x 16-bit column (both directions), asserting the
/// word-level kernel actually pays for itself. Returns
/// `(naive_ns, blocked_ns, speedup)` per full transpose+untranspose.
fn measure_transpose(opts: &BenchOpts) -> (f64, f64, f64) {
    const ELEMS: usize = 1 << 20;
    const WIDTH: u32 = 16;
    let mut rng = Pcg64::new(0x7125);
    let values: Vec<u64> = (0..ELEMS)
        .map(|_| rng.next_u64() & arith::width_mask(WIDTH))
        .collect();

    let naive = bench("transpose-naive (1Mi x 16b)", opts, |_| {
        let planes = arith::transpose_naive(black_box(&values), WIDTH);
        let back = arith::untranspose_naive(black_box(&planes), ELEMS);
        black_box(back);
    });
    let blocked = bench("transpose-blocked (1Mi x 16b)", opts, |_| {
        let planes = arith::transpose(black_box(&values), WIDTH);
        let back =
            arith::untranspose(black_box(&planes), ELEMS).expect("full planes");
        black_box(back);
    });

    // sanity besides speed: identical output on the measured input
    assert_eq!(
        arith::transpose(&values, WIDTH),
        arith::transpose_naive(&values, WIDTH),
        "blocked transpose must be byte-identical to the oracle"
    );

    let speedup = naive.wall_ns.mean / blocked.wall_ns.mean.max(1e-9);
    (naive.wall_ns.mean, blocked.wall_ns.mean, speedup)
}

fn json_path(m: &PathMetrics, groups: usize) -> String {
    // "xla_dispatches" is the tracked metric: fallback dispatch units
    // (counted in every mode; == run_op calls once artifacts load).
    // "xla_run_op_calls" is what the loaded runtime actually executed
    // (0 in the artifact-less CI run).
    format!(
        "{{\"ops\": {}, \"sim_ns\": {:.1}, \"elapsed_sim_ns\": {:.1}, \
         \"ops_per_s\": {:.1}, \"pud_row_fraction\": {:.6}, \
         \"xla_dispatches\": {}, \"xla_run_op_calls\": {}, \
         \"waves\": {}, \"wall_ns_per_pass\": {:.0}}}",
        groups,
        m.sim_ns,
        m.elapsed_sim_ns,
        m.ops_per_sim_s,
        m.pud_row_fraction,
        m.fallback_dispatches,
        m.xla_dispatches,
        m.waves,
        m.wall_ns_per_pass
    )
}

fn main() -> anyhow::Result<()> {
    println!("# bench_runtime — request-path throughput (E6 / §Perf)");
    let opts = BenchOpts::from_env();
    let groups = 32usize;

    let serial = measure(true, groups, &opts)?;
    let batched = measure(false, groups, &opts)?;

    println!(
        "\nserial : {:>10.0} ops/s(sim)  pud_frac {:.3}  dispatch units {}",
        serial.ops_per_sim_s, serial.pud_row_fraction, serial.fallback_dispatches
    );
    println!(
        "batched: {:>10.0} ops/s(sim)  pud_frac {:.3}  dispatch units {}  waves {}",
        batched.ops_per_sim_s,
        batched.pud_row_fraction,
        batched.fallback_dispatches,
        batched.waves
    );
    assert!(
        (serial.pud_row_fraction - batched.pud_row_fraction).abs() < 1e-12,
        "batching must not change placement results"
    );
    assert!(
        batched.fallback_dispatches <= serial.fallback_dispatches,
        "coalescing must not increase dispatches"
    );

    // ---- allocation lifecycle: churn, compaction off vs on ----------
    println!("\n# churn — allocation lifecycle (compaction off vs on)");
    let cc = ChurnConfig::default();
    let churn_off = churn::run(small_scheme(), &cc)?;
    let churn_on = churn::run(
        small_scheme(),
        &ChurnConfig {
            compact: true,
            ..cc
        },
    )?;
    println!(
        "off: steady pud_frac {:.3}, {} page(s) returned, final occ {:.2}",
        churn_off.steady_state_pud_fraction,
        churn_off.pages_returned,
        churn_off.final_occupancy
    );
    println!(
        "on : steady pud_frac {:.3}, {} page(s) returned, {} region(s) \
         migrated, final occ {:.2}",
        churn_on.steady_state_pud_fraction,
        churn_on.pages_returned,
        churn_on.alloc.regions_migrated,
        churn_on.final_occupancy
    );
    assert!(
        churn_on.steady_state_pud_fraction >= churn_off.steady_state_pud_fraction,
        "compaction must not lose in-DRAM coverage"
    );
    assert!(
        churn_on.pages_returned >= 1,
        "compaction must return huge pages to the boot pool"
    );

    // ---- filter: compiled expression batches vs hand-issued ops -----
    println!("\n# filter — compiled predicate batches vs hand-issued ops");
    let fc = FilterConfig::default();
    let filter_puma = filter::run(
        small_scheme(),
        &fc,
        AllocatorKind::Puma(FitPolicy::WorstFit),
    )?;
    let filter_malloc = filter::run(small_scheme(), &fc, AllocatorKind::Malloc)?;
    println!(
        "puma  : compiled pud_frac {:.3} vs hand {:.3}, {} op(s) in {} wave(s), \
         {:.1}x vs hand",
        filter_puma.compiled_pud_fraction,
        filter_puma.hand_pud_fraction,
        filter_puma.compile.ops,
        filter_puma.waves,
        filter_puma.speedup()
    );
    println!(
        "malloc: compiled pud_frac {:.3} vs hand {:.3} (fallback both ways)",
        filter_malloc.compiled_pud_fraction, filter_malloc.hand_pud_fraction
    );
    assert!(
        filter_puma.compiled_pud_fraction > filter_puma.hand_pud_fraction,
        "the compiler's co-located scratch must beat ad-hoc temp placement"
    );
    assert!(
        filter_puma.compile.cse_hits >= 1,
        "the canonical predicate contains a shared NOT for CSE"
    );

    // ---- transpose: blocked bit-matrix kernel vs bit-at-a-time -----
    println!("\n# transpose — blocked 64x64 word kernel vs naive oracle");
    let (naive_ns, blocked_ns, transpose_speedup) = measure_transpose(&opts);
    println!(
        "1Mi x 16b round-trip: naive {:.2} ms -> blocked {:.2} ms ({:.1}x)",
        naive_ns / 1e6,
        blocked_ns / 1e6,
        transpose_speedup
    );
    assert!(
        transpose_speedup >= 20.0,
        "the blocked transpose must beat the bit-at-a-time oracle by >= 20x \
         at 1Mi x 16b (got {transpose_speedup:.1}x)"
    );

    // ---- host boundary: warm cells must be allocator-quiet ---------
    // one system, one pool, same width twice: the second cell must hit
    // the resident column both times and lease nothing from the pool
    println!("\n# host boundary — resident columns + size-classed scratch");
    let warm_cfg = AnalyticsConfig::default();
    let mut wsys = boot();
    let wpid = wsys.spawn();
    let wrow = wsys.os.scheme.geometry.row_bytes as u64;
    let mut walloc = PumaAlloc::new(wrow, FitPolicy::WorstFit);
    walloc.pim_preallocate(&mut wsys.os, warm_cfg.puma_pages)?;
    let mut wpool = arith::ShardedScratch::new();
    let cold = analytics::run_cell(
        &mut wsys, &mut walloc, wpid, "puma", &warm_cfg, 16, &mut wpool,
    )?;
    let warm = analytics::run_cell(
        &mut wsys, &mut walloc, wpid, "puma", &warm_cfg, 16, &mut wpool,
    )?;
    println!(
        "cold: {} col miss(es), {} pool lease(s); warm: {} miss(es), \
         {} lease(s), {} col hit(s)",
        cold.col_misses, cold.pool_leases, warm.col_misses, warm.pool_leases,
        warm.col_hits
    );
    assert!(cold.pool_leases > 0, "the cold cell must lease scratch");
    assert_eq!(
        warm.pool_leases, 0,
        "a warm same-width repeat must do zero allocator round-trips"
    );
    assert_eq!(warm.col_misses, 0, "a warm repeat must not rebuild the column");
    assert!(
        warm.col_hits >= 2,
        "both kernels of a warm cell must hit the resident column"
    );
    assert_eq!(warm.sum, cold.sum, "warm repeats stay value-identical");
    wsys.trim_pools(&mut walloc, wpid, &mut wpool, 0)?;
    wsys.flush_columns(&mut walloc, wpid)?;

    // ---- analytics: vertical arithmetic, PUMA vs every baseline ----
    println!("\n# analytics — filter-then-sum over vertical columns");
    let acfg = AnalyticsConfig::default();
    let kinds = [
        AllocatorKind::Malloc,
        AllocatorKind::Memalign,
        AllocatorKind::HugePages,
        AllocatorKind::Puma(FitPolicy::WorstFit),
    ];
    let cells = analytics::sweep(&small_scheme(), &acfg, &kinds)?;
    let mut min_margin = f64::INFINITY;
    for &w in &acfg.widths {
        let puma_cell = cells
            .iter()
            .find(|r| r.allocator == "puma" && r.width == w)
            .expect("puma cell");
        println!(
            "width {w:>2}: puma pud_frac {:.3}, {} op(s), {:.1} aaps/elem",
            puma_cell.pud_row_fraction(),
            puma_cell.compile.ops,
            puma_cell.aaps_per_elem
        );
        for r in cells.iter().filter(|r| r.width == w && r.allocator != "puma") {
            assert!(
                puma_cell.pud_row_fraction() > r.pud_row_fraction(),
                "width {w}: puma ({}) must beat {} ({})",
                puma_cell.pud_row_fraction(),
                r.allocator,
                r.pud_row_fraction()
            );
            min_margin = min_margin
                .min(puma_cell.pud_row_fraction() - r.pud_row_fraction());
        }
    }
    assert!(
        cells.iter().all(|r| r.col_hits >= 1),
        "every cell's sum kernel must hit the resident column cache"
    );
    let analytics_host_ns = mean_host_ns(
        cells
            .iter()
            .filter(|r| r.allocator == "puma")
            .map(|r| &r.host_ns_per_elem),
    );

    // ---- analytics_sharded: MIMDRAM-style bank-parallel SIMD -------
    println!("\n# analytics_sharded — bank-sharded vertical arithmetic");
    let scfg = ShardedConfig {
        widths: vec![8],
        shards: vec![1, 8],
        ..Default::default()
    };
    // the default 16-bank geometry: S = 8 shards land on 8 disjoint
    // banks, S = 1 is the fully co-located single-subarray layout
    let sharded_scheme = InterleaveScheme::row_major(DramGeometry::default());
    let scells = analytics::sweep_sharded(
        &sharded_scheme,
        &scfg,
        &[
            AllocatorKind::Puma(FitPolicy::WorstFit),
            AllocatorKind::Malloc,
        ],
    )?;
    let s1 = scells
        .iter()
        .find(|r| r.allocator == "puma" && r.shards == 1)
        .expect("puma S=1 cell");
    let s8 = scells
        .iter()
        .find(|r| r.allocator == "puma" && r.shards == 8)
        .expect("puma S=8 cell");
    let sharded_speedup = s1.elapsed_ns / s8.elapsed_ns.max(1e-9);
    println!(
        "puma  : S=1 elapsed {:.0} ns -> S=8 elapsed {:.0} ns ({:.2}x), \
         pud_frac {:.3}",
        s1.elapsed_ns,
        s8.elapsed_ns,
        sharded_speedup,
        s8.pud_row_fraction()
    );
    assert!(
        s8.elapsed_ns < s1.elapsed_ns,
        "bank sharding must strictly shrink the batch makespan under PUMA \
         (S=8 {} vs S=1 {})",
        s8.elapsed_ns,
        s1.elapsed_ns
    );
    assert_eq!(s8.sum, s1.sum, "sharded results must be bit-identical");
    assert_eq!(s8.matches, s1.matches);
    let sharded_min_pud = scells
        .iter()
        .filter(|r| r.allocator == "puma")
        .map(|r| r.pud_row_fraction())
        .fold(f64::INFINITY, f64::min);
    assert!(
        scells.iter().all(|r| r.col_hits >= 1),
        "sharded cells must reuse the flat cell's host image and the \
         resident shards"
    );
    let sharded_host_ns = mean_host_ns(
        scells
            .iter()
            .filter(|r| r.allocator == "puma")
            .map(|r| &r.host_ns_per_elem),
    );

    // ---- queries: semi-join / group-by / top-k over the engine ----
    println!("\n# queries — semi-join / group-by / top-k (PUD engine)");
    let qcfg = QueriesConfig {
        rows: 8 * 1024,
        k: 512,
        churn_rounds: 500,
        ..Default::default()
    };
    let qcells = queries::sweep(&small_scheme(), &qcfg, &kinds)?;
    let shapes = ["semi_join", "group_by", "top_k"];
    for shape in shapes {
        // every placement variant the sweep produced for this shape:
        // flat (shards == 0) and bank-sharded (shards == qcfg.shards)
        for shards in [0usize, qcfg.shards] {
            let puma_cell = qcells
                .iter()
                .find(|r| {
                    r.allocator == "puma" && r.shape == shape && r.shards == shards
                })
                .expect("puma query cell");
            if shards == 0 {
                println!(
                    "{shape:>9}: puma pud_frac {:.3}, {} batch(es), \
                     {} wave(s), {} matching row(s)",
                    puma_cell.pud_row_fraction(),
                    puma_cell.batches,
                    puma_cell.waves,
                    puma_cell.matches
                );
            }
            for r in qcells.iter().filter(|r| {
                r.shape == shape && r.shards == shards && r.allocator != "puma"
            }) {
                assert!(
                    puma_cell.pud_row_fraction() > r.pud_row_fraction(),
                    "{shape} (S={shards}): puma ({}) must beat {} ({})",
                    puma_cell.pud_row_fraction(),
                    r.allocator,
                    r.pud_row_fraction()
                );
                assert_eq!(
                    (r.matches, r.agg),
                    (puma_cell.matches, puma_cell.agg),
                    "{shape} (S={shards}): {} result diverged from puma",
                    r.allocator
                );
            }
        }
    }
    let queries_min_pud = qcells
        .iter()
        .filter(|r| r.allocator == "puma")
        .map(|r| r.pud_row_fraction())
        .fold(f64::INFINITY, f64::min);
    let queries_host_ns = mean_host_ns(
        qcells
            .iter()
            .filter(|r| r.allocator == "puma")
            .map(|r| &r.host_ns_per_elem),
    );

    // ---- serve: multi-tenant DRR fairness vs back-to-back ----------
    // the default 16-bank geometry: 8 spread-anchored tenants land on
    // disjoint banks, so the merged DRR rounds overlap their waves;
    // back-to-back pays each tenant's makespan serially
    println!("\n# serve — multi-tenant fairness (DRR vs back-to-back)");
    let svcfg = ServeConfig {
        tenants: 8,
        ops_per_tenant: 12,
        backpressure: 6,
        churn_rounds: 1_000,
        ..Default::default()
    };
    let serve_scheme = InterleaveScheme::row_major(DramGeometry::default());
    let serve_puma = puma::workloads::serve::run(
        serve_scheme.clone(),
        &svcfg,
        AllocatorKind::Puma(FitPolicy::WorstFit),
    )?;
    let serve_malloc = puma::workloads::serve::run(
        serve_scheme,
        &svcfg,
        AllocatorKind::Malloc,
    )?;
    println!(
        "puma  : DRR p99 {:.0} ns vs b2b p99 {:.0} ns ({:.2}x), \
         {} round(s), pud_frac {:.3}",
        serve_puma.drr_p99_ns,
        serve_puma.b2b_p99_ns,
        serve_puma.p99_speedup(),
        serve_puma.drr_rounds,
        serve_puma.pud_row_fraction()
    );
    println!(
        "malloc: DRR p99 {:.0} ns vs b2b p99 {:.0} ns ({:.2}x)",
        serve_malloc.drr_p99_ns,
        serve_malloc.b2b_p99_ns,
        serve_malloc.p99_speedup()
    );
    assert!(
        serve_puma.identical && serve_malloc.identical,
        "DRR and back-to-back must produce byte-identical tenant buffers"
    );
    assert!(
        serve_puma.drr_p99_ns < serve_puma.b2b_p99_ns,
        "DRR p99 tenant completion must strictly beat back-to-back under \
         PUMA placement (drr {:.0} vs b2b {:.0})",
        serve_puma.drr_p99_ns,
        serve_puma.b2b_p99_ns
    );
    assert!(
        serve_puma.pud_row_fraction() > 0.5,
        "spread anchors + align chaining must keep serve traffic in DRAM \
         (got {:.3})",
        serve_puma.pud_row_fraction()
    );
    assert_eq!(serve_puma.admission.rejected, 0);
    assert!(
        serve_puma.admission.queued > 0,
        "backpressure threshold below ops_per_tenant must trip Queued"
    );

    // ---- observability: tracer overhead must stay in budget --------
    // the same batched pass with the wave tracer off vs on, min-of-N
    // wall clock on a warm system (min absorbs scheduler noise; the
    // work itself is deterministic). DESIGN.md §14's <5% budget is
    // asserted here and `obs_trace_overhead_frac` is gated in CI.
    println!("\n# observability — tracer overhead + latency percentiles");
    let measure_obs = |traced: bool| -> anyhow::Result<(f64, System)> {
        let mut sys = boot();
        let (pid, reqs) = build_workload(&mut sys, groups)?;
        sys.coord.obs.tracer.set_enabled(traced);
        black_box(sys.submit_batch(pid, &reqs)?); // warmup
        let mut best = f64::INFINITY;
        for _ in 0..9 {
            let t0 = std::time::Instant::now();
            black_box(sys.submit_batch(pid, &reqs)?);
            best = best.min(t0.elapsed().as_nanos() as f64);
        }
        Ok((best, sys))
    };
    let (wall_off, _sys_off) = measure_obs(false)?;
    let (wall_on, sys_on) = measure_obs(true)?;
    let obs_overhead_frac = (wall_on - wall_off).max(0.0) / wall_off.max(1.0);
    let tracer = &sys_on.coord.obs.tracer;
    let mut bank_busy: std::collections::BTreeMap<u32, f64> =
        std::collections::BTreeMap::new();
    for ev in tracer.events() {
        for lane in &ev.lanes {
            *bank_busy.entry(lane.bank).or_insert(0.0) += lane.busy_ns;
        }
    }
    let busiest = bank_busy.values().copied().fold(0.0f64, f64::max);
    let idlest = bank_busy
        .values()
        .copied()
        .fold(f64::INFINITY, f64::min);
    let bank_util_spread = if bank_busy.is_empty() {
        1.0
    } else {
        busiest / idlest.max(1e-9)
    };
    let op_sim_ns_p99 = sys_on
        .coord
        .obs
        .registry
        .hist_by_name("coord/op_sim_ns")
        .expect("coordinator registers coord/op_sim_ns at boot")
        .p99();
    println!(
        "tracer off {:.0} ns -> on {:.0} ns per pass ({:.2}% overhead); \
         op p99 {} sim-ns, bank spread {:.2}x, {} wave(s) traced, {} dropped",
        wall_off,
        wall_on,
        obs_overhead_frac * 100.0,
        op_sim_ns_p99,
        bank_util_spread,
        tracer.len(),
        tracer.dropped
    );
    assert!(
        obs_overhead_frac < 0.05,
        "wave tracing must cost <5% of the batched pass \
         (got {:.2}%: off {wall_off:.0} ns, on {wall_on:.0} ns)",
        obs_overhead_frac * 100.0
    );
    assert!(
        tracer.len() as u64 + tracer.dropped == tracer.total_waves,
        "ring accounting must cover every wave"
    );

    // ---- analysis: verifier overhead must stay in budget -----------
    // the analytics sweep with the static verifier Off vs Full (every
    // emitted stream dataflow-checked + translation-validated),
    // min-of-N wall clock on a warm system. ISSUE 10's <10% budget is
    // asserted here and `verify_overhead_frac` is gated in CI.
    println!("\n# analysis — static verifier overhead (Full vs Off)");
    let vcfg = AnalyticsConfig {
        elems: 64 * 1024,
        widths: vec![4, 8],
        churn_rounds: 500,
        ..Default::default()
    };
    let measure_verify = |level: VerifyLevel| -> anyhow::Result<f64> {
        let mut sys = System::boot(SystemConfig {
            scheme: small_scheme(),
            huge_pages: vcfg.huge_pages,
            churn_rounds: vcfg.churn_rounds,
            seed: vcfg.seed,
            artifacts: None,
            verify: level,
            ..Default::default()
        })?;
        let pid = sys.spawn();
        let mut alloc = AllocatorKind::Puma(FitPolicy::WorstFit)
            .build(&mut sys, vcfg.puma_pages)?;
        let mut pools = puma::pud::arith::ShardedScratch::new();
        let mut sweep = |sys: &mut System,
                         alloc: &mut dyn puma::alloc::traits::Allocator,
                         pools: &mut puma::pud::arith::ShardedScratch|
         -> anyhow::Result<()> {
            for &w in &vcfg.widths {
                black_box(analytics::run_cell(
                    sys, alloc, pid, "verify", &vcfg, w, pools,
                )?);
            }
            Ok(())
        };
        sweep(&mut sys, alloc.as_mut(), &mut pools)?; // warmup
        let mut best = f64::INFINITY;
        for _ in 0..9 {
            let t0 = std::time::Instant::now();
            sweep(&mut sys, alloc.as_mut(), &mut pools)?;
            best = best.min(t0.elapsed().as_nanos() as f64);
            sys.take_diagnostics(); // drain between passes
        }
        Ok(best)
    };
    let wall_verify_off = measure_verify(VerifyLevel::Off)?;
    let wall_verify_full = measure_verify(VerifyLevel::Full)?;
    let verify_overhead_frac =
        (wall_verify_full - wall_verify_off).max(0.0) / wall_verify_off.max(1.0);
    println!(
        "verifier off {:.0} ns -> full {:.0} ns per sweep ({:.2}% overhead)",
        wall_verify_off,
        wall_verify_full,
        verify_overhead_frac * 100.0
    );
    assert!(
        verify_overhead_frac < 0.10,
        "full verification must cost <10% of the analytics sweep \
         (got {:.2}%: off {wall_verify_off:.0} ns, full \
         {wall_verify_full:.0} ns)",
        verify_overhead_frac * 100.0
    );

    let json = format!(
        "{{\n  \"bench\": \"bench_runtime\",\n  \"workload\": \
         {{\"groups\": {groups}, \"mix\": \"3:1 puma:malloc, \
         and|or|xor|copy, one partial tail\"}},\n  \"dispatch_metric\": \
         \"fallback dispatch units (== XLA run_op calls when artifacts \
         are loaded)\",\n  \"serial\": {},\n  \"batched\": {},\n  \
         \"speedup_sim\": {:.3},\n  \"dispatch_reduction\": {:.3},\n  \
         \"churn\": {{\"epochs\": {}, \"off\": {}, \"on\": {}, \
         \"steady_pud_gain\": {:.6}}},\n  \
         \"filter\": {{\"clauses\": {}, \"columns\": {}, \"rows\": {}, \
         \"puma\": {}, \"malloc\": {}, \"pud_gain_vs_hand\": {:.6}}},\n  \
         \"transpose\": {{\"elems\": 1048576, \"width\": 16, \
         \"naive_wall_ns\": {:.0}, \"blocked_wall_ns\": {:.0}, \
         \"speedup\": {:.2}}},\n  \
         \"analytics\": {{\"elems\": {}, \"widths\": [{}], \
         \"threshold_frac\": {:.2}, \"min_puma_margin\": {:.6}, \
         \"host_ns_per_elem\": {:.4}, \
         \"cells\": [\n    {}\n  ]}},\n  \
         \"analytics_sharded\": {{\"elems\": {}, \"width\": {}, \
         \"speedup_s8\": {:.4}, \"puma_pud_row_fraction\": {:.6}, \
         \"host_ns_per_elem\": {:.4}, \
         \"cells\": [\n    {}\n  ]}},\n  \
         \"queries\": {{\"rows\": {}, \"width\": {}, \"shards\": {}, \
         \"semi_join\": {}, \"group_by\": {}, \"top_k\": {}, \
         \"min_puma_pud_row_fraction\": {:.6}, \
         \"host_ns_per_elem\": {:.4}, \
         \"cells\": [\n    {}\n  ]}},\n  \
         \"serve\": {{\"tenants\": {}, \"ops_per_tenant\": {}, \
         \"quantum\": {}, \"serve_p99_makespan\": {:.1}, \
         \"serve_puma_pud_row_fraction\": {:.6}, \"p99_speedup\": {:.4}, \
         \"puma\": {}, \"malloc\": {}}},\n  \
         \"observability\": {{\"obs_trace_overhead_frac\": {:.4}, \
         \"wall_off_ns\": {:.0}, \"wall_on_ns\": {:.0}, \
         \"op_sim_ns_p99\": {}, \"bank_util_spread\": {:.4}, \
         \"waves_traced\": {}, \"waves_dropped\": {}}},\n  \
         \"analysis\": {{\"verify_overhead_frac\": {:.4}, \
         \"wall_verify_off_ns\": {:.0}, \
         \"wall_verify_full_ns\": {:.0}}}\n}}\n",
        json_path(&serial, groups),
        json_path(&batched, groups),
        serial.elapsed_sim_ns / batched.elapsed_sim_ns.max(1e-9),
        serial.fallback_dispatches as f64
            / (batched.fallback_dispatches.max(1)) as f64,
        cc.epochs,
        churn_json(&churn_off),
        churn_json(&churn_on),
        churn_on.steady_state_pud_fraction - churn_off.steady_state_pud_fraction,
        filter_puma.clauses,
        filter_puma.columns,
        filter_puma.rows,
        filter_json(&filter_puma),
        filter_json(&filter_malloc),
        filter_puma.compiled_pud_fraction - filter_puma.hand_pud_fraction,
        naive_ns,
        blocked_ns,
        transpose_speedup,
        acfg.elems,
        acfg.widths
            .iter()
            .map(|w| w.to_string())
            .collect::<Vec<_>>()
            .join(", "),
        acfg.threshold_frac,
        min_margin,
        analytics_host_ns,
        cells
            .iter()
            .map(analytics_json)
            .collect::<Vec<_>>()
            .join(",\n    "),
        scfg.elems,
        scfg.widths[0],
        sharded_speedup,
        sharded_min_pud,
        sharded_host_ns,
        scells
            .iter()
            .map(sharded_json)
            .collect::<Vec<_>>()
            .join(",\n    "),
        qcfg.rows,
        qcfg.width,
        qcfg.shards,
        query_shape_json(&qcells, "semi_join"),
        query_shape_json(&qcells, "group_by"),
        query_shape_json(&qcells, "top_k"),
        queries_min_pud,
        queries_host_ns,
        qcells
            .iter()
            .map(query_json)
            .collect::<Vec<_>>()
            .join(",\n    "),
        svcfg.tenants,
        svcfg.ops_per_tenant,
        svcfg.quantum,
        serve_puma.drr_p99_ns,
        serve_puma.pud_row_fraction(),
        serve_puma.p99_speedup(),
        serve_json(&serve_puma),
        serve_json(&serve_malloc),
        obs_overhead_frac,
        wall_off,
        wall_on,
        op_sim_ns_p99,
        bank_util_spread,
        tracer.len(),
        tracer.dropped,
        verify_overhead_frac,
        wall_verify_off,
        wall_verify_full,
    );
    std::fs::write("BENCH_runtime.json", &json)?;
    println!("\nwrote BENCH_runtime.json");

    // ---- optional: raw XLA kernel throughput (needs `make artifacts`)
    let Some(dir) = puma::config::default_artifacts() else {
        println!("artifacts/ missing — skipping raw XLA kernel section");
        return Ok(());
    };
    use puma::runtime::{XlaRuntime, ROW_BYTES};
    let t0 = std::time::Instant::now();
    let mut rt = XlaRuntime::load(&dir)?;
    println!(
        "\nloaded + compiled {} ops in {:.2?}\n",
        rt.ops().len(),
        t0.elapsed()
    );
    let mut rng = Pcg64::new(0xBE);
    let mut csv = Csv::new(vec!["op", "rows", "mean_ns", "gib_per_s"]);
    for op in ["and", "copy", "zero", "xor"] {
        for rows in [1u32, 8, 64, 256] {
            let n = rows as usize * ROW_BYTES;
            let mut a = vec![0u8; n];
            let mut b = vec![0u8; n];
            rng.fill_bytes(&mut a);
            rng.fill_bytes(&mut b);
            let srcs: Vec<&[u8]> = match op {
                "and" | "xor" => vec![&a, &b],
                "copy" => vec![&a],
                _ => vec![],
            };
            let res = bench(&format!("{op}@{rows}rows"), &opts, |_| {
                let out = rt.run_op(op, rows, &srcs).expect("run_op");
                black_box(out);
            });
            let gibps = n as f64 / res.wall_ns.mean / 1.073_741_824;
            csv.row(vec![
                op.to_string(),
                rows.to_string(),
                format!("{:.0}", res.wall_ns.mean),
                format!("{gibps:.2}"),
            ]);
        }
    }
    csv.write("out/runtime.csv")?;
    println!("\n(raw: out/runtime.csv; dispatches so far: {})", rt.dispatches);
    Ok(())
}
