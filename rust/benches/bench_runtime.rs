//! E6 — XLA/PJRT fallback runtime throughput (wall-clock).
//!
//! Measures the CPU-fallback hot path in isolation: bulk ops through
//! the AOT-compiled kernels, across shape buckets, plus the effect of
//! greedy bucketing on odd row counts. This is the §Perf measurement
//! harness for L3's fallback dispatch and the L1 kernels' CPU
//! execution. Requires `make artifacts`; skips cleanly without it.
//!
//! Run: `cargo bench --bench bench_runtime`

use puma::runtime::{XlaRuntime, ROW_BYTES};
use puma::util::bench::{bench, black_box, BenchOpts};
use puma::util::csvio::Csv;
use puma::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    println!("# bench_runtime — XLA fallback throughput (E6 / §Perf)");
    let Some(dir) = puma::config::default_artifacts() else {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return Ok(());
    };
    let t0 = std::time::Instant::now();
    let mut rt = XlaRuntime::load(&dir)?;
    println!("loaded + compiled {} ops in {:.2?}\n", rt.ops().len(), t0.elapsed());

    let opts = BenchOpts::from_env();
    let mut rng = Pcg64::new(0xBE);
    let mut csv = Csv::new(vec!["op", "rows", "mean_ns", "gib_per_s"]);

    for op in ["and", "copy", "zero", "xor"] {
        for rows in [1u32, 8, 64, 256] {
            let n = rows as usize * ROW_BYTES;
            let mut a = vec![0u8; n];
            let mut b = vec![0u8; n];
            rng.fill_bytes(&mut a);
            rng.fill_bytes(&mut b);
            let srcs: Vec<&[u8]> = match op {
                "and" | "xor" => vec![&a, &b],
                "copy" => vec![&a],
                _ => vec![],
            };
            let res = bench(&format!("{op}@{rows}rows"), &opts, |_| {
                let out = rt.run_op(op, rows, &srcs).expect("run_op");
                black_box(out);
            });
            let gibps = n as f64 / res.wall_ns.mean / 1.073_741_824;
            csv.row(vec![
                op.to_string(),
                rows.to_string(),
                format!("{:.0}", res.wall_ns.mean),
                format!("{gibps:.2}"),
            ]);
        }
    }

    // bucketing overhead: 257 rows = 256+1 vs two native dispatches
    let rows = 257u32;
    let n = rows as usize * ROW_BYTES;
    let mut a = vec![0u8; n];
    rng.fill_bytes(&mut a);
    let srcs: Vec<&[u8]> = vec![&a];
    bench("copy@257rows (bucketed 256+1)", &opts, |_| {
        let out = rt.run_op("copy", rows, &srcs).expect("run_op");
        black_box(out);
    });

    csv.write("out/runtime.csv")?;
    println!("\n(raw: out/runtime.csv; dispatches so far: {})", rt.dispatches);
    Ok(())
}
