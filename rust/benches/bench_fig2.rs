//! E2 / Figure 2 — PUMA speedup over malloc for the three
//! micro-benchmarks across the paper's allocation-size sweep.
//!
//! The primary output is the *simulated-time* speedup series (the
//! paper's y-axis); the harness also reports wall-clock per sweep cell
//! for §Perf. Raw series land in out/figure2.csv.
//!
//! Run: `cargo bench --bench bench_fig2`
//! Fast: `PUMA_BENCH_FAST=1 cargo bench --bench bench_fig2`
//! With the XLA runtime on the fallback path: `PUMA_BENCH_XLA=1 ...`

use puma::alloc::puma::FitPolicy;
use puma::report;
use puma::workloads::microbench::{AllocatorKind, Micro};
use puma::workloads::sweep::{self, SweepConfig};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("PUMA_BENCH_FAST").is_ok();
    let use_xla = std::env::var("PUMA_BENCH_XLA").is_ok();
    let mut cfg = SweepConfig::default();
    if use_xla {
        cfg.artifacts = puma::config::default_artifacts();
        if cfg.artifacts.is_none() {
            eprintln!("PUMA_BENCH_XLA set but artifacts/ missing; scalar fallback");
        }
    }
    if fast {
        cfg.sizes = vec![250, 64 << 10, 768 << 10];
        cfg.huge_pages = 64;
        cfg.churn_rounds = 5_000;
    }

    println!("# bench_fig2 — reproduces paper Figure 2");
    let mut series = Vec::new();
    for micro in Micro::ALL {
        let t0 = std::time::Instant::now();
        let cells = sweep::run_micro_sweep(
            &cfg,
            AllocatorKind::Puma(FitPolicy::WorstFit),
            micro,
        )?;
        println!(
            "{:<6} sweep: {} cells in {:.2?} wall",
            micro.name(),
            cells.len(),
            t0.elapsed()
        );
        series.push((micro, cells));
    }
    println!();
    println!("{}", report::figure2(&series, Some(std::path::Path::new("out")))?);

    // Paper-shape assertions: PUMA wins at the large end, and the
    // speedup at the top size exceeds the smallest size's.
    for (micro, cells) in &series {
        let first = cells.first().unwrap().speedup();
        let last = cells.last().unwrap().speedup();
        assert!(last > 1.0, "{}: top-size speedup {last:.2}x <= 1", micro.name());
        assert!(
            last > first,
            "{}: speedup must grow with size ({first:.2}x -> {last:.2}x)",
            micro.name()
        );
    }
    println!("fig2 shape checks passed (PUMA wins; speedup grows with size)");
    Ok(())
}
