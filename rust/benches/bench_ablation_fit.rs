//! E3 ablation — PUMA's fit policy: worst-fit (the paper's choice) vs
//! best-fit vs first-fit, under memory pressure.
//!
//! The paper argues worst-fit maximizes the space remaining in each
//! subarray after an allocation, which keeps subarrays open so
//! hint-aligned operands can still co-locate. This bench replays a
//! multi-group allocation trace with a deliberately small region pool
//! and compares hint co-location and PUD fractions across policies.
//!
//! Run: `cargo bench --bench bench_ablation_fit`

use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::traits::Allocator;
use puma::coordinator::system::{System, SystemConfig};
use puma::util::csvio::Csv;
use puma::util::table::Table;
use puma::workloads::trace::Trace;

fn run_policy(policy: FitPolicy, pages: usize, seed: u64) -> anyhow::Result<(f64, f64, f64)> {
    let mut sys = System::boot(SystemConfig {
        huge_pages: pages + 4,
        churn_rounds: 5_000,
        seed,
        artifacts: None,
        ..Default::default()
    })?;
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let mut puma = PumaAlloc::new(row, policy);
    puma.pim_preallocate(&mut sys.os, pages)?;
    let pid = sys.spawn();
    // heavy trace: many groups, sizeable operands, churn
    let trace = Trace::generate(seed, 24, 48 * row, 3);
    let ns = trace.replay(&mut sys, &mut puma, pid)?;
    let st = puma.stats();
    let coloc = st.hint_colocated as f64
        / (st.hint_colocated + st.hint_missed).max(1) as f64;
    Ok((sys.coord.stats.pud_row_fraction(), coloc, ns))
}

fn main() -> anyhow::Result<()> {
    println!("# bench_ablation_fit — worst-fit vs best-fit vs first-fit (E3)");
    let mut table = Table::new(vec![
        "policy",
        "pud-rows%",
        "hint-coloc%",
        "sim-time(us)",
    ])
    .left(0);
    let mut csv = Csv::new(vec!["policy", "pud_fraction", "hint_colocation", "sim_ns"]);
    let mut results = Vec::new();
    for (policy, name) in [
        (FitPolicy::WorstFit, "worst-fit (paper)"),
        (FitPolicy::BestFit, "best-fit"),
        (FitPolicy::FirstFit, "first-fit"),
    ] {
        // average over seeds to avoid one lucky layout
        let mut pud = 0.0;
        let mut coloc = 0.0;
        let mut ns = 0.0;
        const SEEDS: u64 = 3;
        for s in 0..SEEDS {
            let (p, c, n) = run_policy(policy, 24, 0xAB1E + s)?;
            pud += p;
            coloc += c;
            ns += n;
        }
        pud /= SEEDS as f64;
        coloc /= SEEDS as f64;
        ns /= SEEDS as f64;
        table.row(vec![
            name.to_string(),
            format!("{:.1}%", pud * 100.0),
            format!("{:.1}%", coloc * 100.0),
            format!("{:.1}", ns / 1000.0),
        ]);
        csv.row(vec![
            name.to_string(),
            format!("{pud:.4}"),
            format!("{coloc:.4}"),
            format!("{ns:.0}"),
        ]);
        results.push((policy, pud, coloc));
    }
    println!("{}", table.render());
    csv.write("out/ablation_fit.csv")?;
    println!("(raw: out/ablation_fit.csv)");

    // Worst-fit should co-locate at least as well as the alternatives.
    let worst = results
        .iter()
        .find(|(p, _, _)| *p == FitPolicy::WorstFit)
        .unwrap();
    for (p, pud, _) in &results {
        if *p != FitPolicy::WorstFit {
            assert!(
                worst.1 >= pud - 0.05,
                "worst-fit PUD fraction {:.2} should not lose to {:?} {:.2}",
                worst.1,
                p,
                pud
            );
        }
    }
    println!("ablation check passed (worst-fit co-locates best or ties)");
    Ok(())
}
