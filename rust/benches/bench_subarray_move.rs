//! E5 — the cost of operands landing in *different* subarrays: the
//! penalty PUMA exists to avoid.
//!
//! Compares, for a row-granular copy of increasing size:
//!   * FPM        — same-subarray RowClone (PUMA placement),
//!   * PSM        — inter-subarray in-DRAM move (LISA-class),
//!   * CPU        — over-the-channel fallback (malloc placement).
//!
//! The paper cites LISA for the "extra latency due to inter-subarray
//! data movement"; this bench regenerates that latency gap from our
//! timing model and the functional engine.
//!
//! Run: `cargo bench --bench bench_subarray_move`

use puma::dram::address::InterleaveScheme;
use puma::dram::device::DramDevice;
use puma::dram::geometry::{DramGeometry, SubarrayId};
use puma::dram::timing::TimingParams;
use puma::pud::rowclone;
use puma::util::csvio::Csv;
use puma::util::table::{fnum, Table};
use puma::util::units::fmt_bytes;

fn main() -> anyhow::Result<()> {
    println!("# bench_subarray_move — FPM vs PSM vs CPU copy latency (E5)");
    let scheme = InterleaveScheme::row_major(DramGeometry::default());
    let timing = TimingParams::default();
    let row_bytes = scheme.geometry.row_bytes;
    let mut dev = DramDevice::new(scheme.clone());

    let mut table = Table::new(vec![
        "size",
        "rows",
        "FPM(us)",
        "PSM(us)",
        "CPU(us)",
        "PSM/FPM",
        "CPU/FPM",
    ])
    .left(0);
    let mut csv = Csv::new(vec!["bytes", "rows", "fpm_ns", "psm_ns", "cpu_ns"]);

    for rows in [1u64, 8, 32, 128, 512] {
        let bytes = rows * row_bytes as u64;
        // functional check on a couple of rows: PSM really moves data
        if rows <= 8 {
            for r in 0..rows as u32 {
                let src = dev
                    .scheme
                    .decode(dev.scheme.row_start_addr(SubarrayId(0), r));
                let dst = dev
                    .scheme
                    .decode(dev.scheme.row_start_addr(SubarrayId(1), r));
                let data = vec![(r + 1) as u8; row_bytes as usize];
                dev.write_row(&src, &data);
                rowclone::psm_copy(&mut dev, &timing, &src, &dst)?;
                assert_eq!(dev.read_row(&dst), data);
            }
        }
        let fpm = timing.rowclone_fpm_ns(rows);
        let psm = timing.rowclone_psm_ns(rows, row_bytes);
        let cpu = timing.cpu_bulk_ns(bytes, bytes);
        table.row(vec![
            fmt_bytes(bytes),
            rows.to_string(),
            fnum(fpm / 1000.0),
            fnum(psm / 1000.0),
            fnum(cpu / 1000.0),
            format!("{}x", fnum(psm / fpm)),
            format!("{}x", fnum(cpu / fpm)),
        ]);
        csv.row(vec![
            bytes.to_string(),
            rows.to_string(),
            format!("{fpm:.0}"),
            format!("{psm:.0}"),
            format!("{cpu:.0}"),
        ]);
    }
    println!("{}", table.render());
    csv.write("out/subarray_move.csv")?;
    println!("(raw: out/subarray_move.csv)");

    // ordering invariants at realistic row counts
    let fpm = timing.rowclone_fpm_ns(128);
    let psm = timing.rowclone_psm_ns(128, row_bytes);
    let cpu = timing.cpu_bulk_ns(128 * row_bytes as u64, 128 * row_bytes as u64);
    assert!(fpm < psm && psm < cpu, "FPM < PSM < CPU must hold");
    assert!(cpu / fpm > 10.0, "channel copy should be >10x FPM");
    println!(
        "subarray-move check passed (PSM {:.1}x FPM, CPU {:.1}x FPM at 1 MiB)",
        psm / fpm,
        cpu / fpm
    );
    Ok(())
}
