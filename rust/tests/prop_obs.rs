//! Properties of the observability layer (DESIGN.md §14):
//!
//! * histogram merging is associative and commutative, and percentile
//!   estimates bracket the true nearest-rank value within the log2
//!   factor-of-2 guarantee (exact for 0 and for single-sample hists);
//! * the tracer ring drops excess waves and accounts for every one of
//!   them;
//! * a live trace capture agrees with `PipelineStats` wave counts,
//!   names only real banks, and its DDR stream replays back to the
//!   coordinator's exact totals.

use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::assert_prop;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::obs::export;
use puma::obs::metrics::Hist;
use puma::obs::trace::{Tracer, WaveEvent};
use puma::proptest::{self, Gen};
use puma::pud::isa::{BulkRequest, PudOp};

fn gen_samples(g: &mut Gen) -> Vec<u64> {
    let n = g.usize(1..64);
    (0..n)
        .map(|_| {
            // mix magnitudes so several buckets populate
            let shift = g.usize(0..40);
            g.u64(0..1024) << shift
        })
        .collect()
}

fn hist_of(samples: &[u64]) -> Hist {
    let mut h = Hist::new();
    for &v in samples {
        h.record(v);
    }
    h
}

#[test]
fn hist_merge_is_associative_and_commutative() {
    proptest::check_cases("hist merge algebra", 64, |g| {
        let (a, b, c) = (gen_samples(g), gen_samples(g), gen_samples(g));
        let (ha, hb, hc) = (hist_of(&a), hist_of(&b), hist_of(&c));

        let mut ab_c = ha.clone();
        ab_c.merge(&hb);
        ab_c.merge(&hc);
        let mut bc = hb.clone();
        bc.merge(&hc);
        let mut a_bc = ha.clone();
        a_bc.merge(&bc);
        assert_prop!(ab_c == a_bc, "merge must be associative");

        let mut ab = ha.clone();
        ab.merge(&hb);
        let mut ba = hb.clone();
        ba.merge(&ha);
        assert_prop!(ab == ba, "merge must be commutative");

        // merged hist == hist of concatenated samples
        let mut all = a.clone();
        all.extend_from_slice(&b);
        assert_prop!(ab == hist_of(&all));
    });
}

#[test]
fn hist_percentiles_bracket_the_sorted_reference() {
    proptest::check_cases("hist percentile bounds", 64, |g| {
        let samples = gen_samples(g);
        let h = hist_of(&samples);
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for p in [50.0, 90.0, 99.0] {
            let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
            let truth = sorted[rank.clamp(1, sorted.len()) - 1];
            let est = h.percentile(p);
            assert_prop!(
                est >= truth,
                "p{p}: estimate {est} under true value {truth}"
            );
            if truth == 0 {
                assert_prop!(est == 0, "p{p}: zero must be exact");
            } else {
                assert_prop!(
                    est < 2 * truth.max(1),
                    "p{p}: estimate {est} outside [v, 2v) for v={truth}"
                );
            }
        }
    });
}

#[test]
fn hist_bucket_boundaries_and_singletons_are_exact() {
    // log2 bucket edges: 2^(k-1) and 2^k - 1 land in bucket k
    for k in 1..63u32 {
        let lo = 1u64 << (k - 1);
        let hi = (1u64 << k) - 1;
        assert_eq!(Hist::bucket_index(lo), k as usize, "lower edge of {k}");
        assert_eq!(Hist::bucket_index(hi), k as usize, "upper edge of {k}");
    }
    // a single-sample hist reports that sample exactly at every
    // percentile (the min/max clamp collapses the bucket range)
    proptest::check_cases("singleton hists are exact", 64, |g| {
        let v = g.u64(0..u64::MAX);
        let h = hist_of(&[v]);
        for p in [1.0, 50.0, 99.0, 100.0] {
            assert_prop!(h.percentile(p) == v, "v={v} p={p}");
        }
    });
}

#[test]
fn tracer_ring_accounts_for_every_wave() {
    proptest::check_cases("ring overflow accounting", 64, |g| {
        let capacity = g.usize(1..16);
        let n = g.usize(0..48);
        let mut t = Tracer::new(capacity);
        for _ in 0..n {
            t.record(WaveEvent {
                batch: 0,
                wave: 0,
                start_ns: 0.0,
                pud_ns: g.u64(1..1_000) as f64,
                fallback_ns: 0.0,
                lanes: vec![],
                ops: vec![],
            });
        }
        assert_prop!(t.len() == n.min(capacity), "kept = min(n, capacity)");
        assert_prop!(
            t.dropped == n.saturating_sub(capacity) as u64,
            "dropped = overflow (n={n} cap={capacity} dropped={})",
            t.dropped
        );
        assert_prop!(t.total_waves == n as u64);
        assert_prop!(t.len() as u64 + t.dropped == t.total_waves);
        // the ring keeps the oldest waves, ids assigned in order
        for (i, ev) in t.events().iter().enumerate() {
            assert_prop!(ev.wave == i as u64);
        }
        // the sim-time cursor advanced over every wave, kept or not,
        // so it can never run behind the kept events
        let kept_ns: f64 =
            t.events().iter().map(WaveEvent::elapsed_ns).sum();
        assert_prop!(t.now_ns >= kept_ns);
    });
}

fn boot() -> System {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    System::boot(SystemConfig {
        scheme,
        huge_pages: 12,
        churn_rounds: 500,
        seed: 0x0B5E55ED,
        artifacts: None,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn live_capture_matches_pipeline_and_replays() {
    proptest::check_cases("trace capture vs pipeline", 8, |g| {
        let mut sys = boot();
        sys.coord.obs.tracer.set_enabled(true);
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 8).unwrap();

        let nbufs = g.usize(3..6);
        let mut vas = Vec::with_capacity(nbufs);
        let mut lens = Vec::with_capacity(nbufs);
        let mut hint = None;
        for i in 0..nbufs {
            // some ragged lengths so fallback rows appear too
            let len = g.u64(1..5) * row
                + if g.bool() { g.u64(1..row) } else { 0 };
            let va = match hint {
                Some(h) => sys.alloc_align(&mut puma, pid, len, h).unwrap(),
                None => sys.alloc(&mut puma, pid, len).unwrap(),
            };
            hint.get_or_insert(va);
            let data: Vec<u8> =
                (0..len).map(|j| ((i as u64 * 197 + j) % 253) as u8).collect();
            sys.write_virt(pid, va, &data).unwrap();
            vas.push(va);
            lens.push(len);
        }

        let nops = g.usize(2..8);
        for _ in 0..nops {
            let op = *g.choose(&PudOp::ALL);
            let dst = g.usize(0..nbufs);
            let srcs: Vec<usize> =
                (0..op.arity()).map(|_| g.usize(0..nbufs)).collect();
            let max_len = srcs
                .iter()
                .chain(std::iter::once(&dst))
                .map(|&i| lens[i])
                .min()
                .unwrap();
            let len = if g.bool() { max_len } else { g.u64(1..max_len + 1) };
            sys.enqueue(
                pid,
                BulkRequest::new(op, vas[dst], srcs.iter().map(|&i| vas[i]).collect(), len),
            );
        }
        sys.flush(pid).unwrap();

        let tracer = &sys.coord.obs.tracer;
        let p = &sys.coord.pipeline;
        assert_prop!(
            tracer.len() as u64 + tracer.dropped == p.waves,
            "every pipeline wave is traced or counted as dropped"
        );
        assert_prop!(tracer.total_waves == p.waves);
        let banks = sys.os.scheme.geometry.total_banks();
        let mut slot_ops = 0u64;
        for (i, ev) in tracer.events().iter().enumerate() {
            assert_prop!(ev.wave == i as u64, "waves serialize in order");
            assert_prop!(!ev.ops.is_empty(), "no empty waves");
            slot_ops += ev.ops.len() as u64;
            for lane in &ev.lanes {
                assert_prop!(
                    lane.bank < banks,
                    "lane bank {} out of range {banks}",
                    lane.bank
                );
                assert_prop!(lane.rows > 0 && lane.busy_ns > 0.0);
            }
        }
        assert_prop!(slot_ops == sys.coord.stats.ops, "one slot per op");

        // the DDR stream replays to the coordinator's exact totals
        let stream = export::ddr_stream(tracer.events());
        export::verify_replay(&stream, &sys.coord.stats).unwrap();

        // the Chrome trace is well-formed enough for Perfetto: a
        // traceEvents array naming only real banks
        let json = export::chrome_trace(tracer.events());
        assert_prop!(json.contains("\"traceEvents\""));
        for b in banks..banks + 4 {
            assert_prop!(
                !json.contains(&format!("\"bank {b}\"")),
                "phantom bank {b} lane"
            );
        }
    });
}
