//! Integration: the four allocators against one shared machine —
//! placement properties and PUD-eligibility end to end.

use puma::alloc::hugealloc::HugeAlloc;
use puma::alloc::mallocsim::MallocSim;
use puma::alloc::memalign::MemalignSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::traits::{Allocator, OsCtx};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::os::process::{Pid, Process};
use puma::pud::legality::{check_rowwise, pud_fraction};

fn boot() -> OsCtx {
    OsCtx::boot(
        InterleaveScheme::row_major(DramGeometry::default()),
        128,
        10_000,
        0xA11C,
    )
    .unwrap()
}

fn eligibility(
    ctx: &mut OsCtx,
    alloc: &mut dyn Allocator,
    use_hint: bool,
    len: u64,
) -> f64 {
    let mut proc = Process::new(Pid(9));
    let a = alloc.alloc(ctx, &mut proc, len).unwrap();
    let (b, c) = if use_hint {
        (
            alloc.alloc_align(ctx, &mut proc, len, a).unwrap(),
            alloc.alloc_align(ctx, &mut proc, len, a).unwrap(),
        )
    } else {
        (
            alloc.alloc(ctx, &mut proc, len).unwrap(),
            alloc.alloc(ctx, &mut proc, len).unwrap(),
        )
    };
    let ea = proc.phys_extents(a, len).unwrap();
    let eb = proc.phys_extents(b, len).unwrap();
    let ec = proc.phys_extents(c, len).unwrap();
    let plan = check_rowwise(&ctx.scheme, &[&ec, &ea, &eb], len);
    pud_fraction(&plan)
}

#[test]
fn allocator_eligibility_ladder() {
    // the paper's §1 comparison, end to end on one machine
    let len = 256 << 10;
    let mut ctx = boot();

    let mut malloc = MallocSim::new();
    let f_malloc = eligibility(&mut ctx, &mut malloc, false, len);
    assert!(f_malloc < 0.02, "malloc {f_malloc}");

    let mut memalign = MemalignSim::new(8192);
    let f_memalign = eligibility(&mut ctx, &mut memalign, false, len);
    assert!(f_memalign < 0.02, "posix_memalign {f_memalign}");

    let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut ctx, 32).unwrap();
    let f_puma = eligibility(&mut ctx, &mut puma, true, len);
    assert!(f_puma > 0.98, "puma {f_puma}");
}

#[test]
fn hugepages_partial_across_sizes() {
    // hugepages: 0% at sub-row sizes, sometimes high at row-congruent
    // large sizes — partial overall (the paper's "up to 60%")
    let mut fractions = Vec::new();
    for len in [250u64, 4 << 10, 64 << 10, 384 << 10, 768 << 10] {
        let mut ctx = boot();
        let mut huge = HugeAlloc::new(8192);
        fractions.push(eligibility(&mut ctx, &mut huge, false, len));
    }
    assert!(fractions[0] < 0.05, "sub-row must fail: {fractions:?}");
    let mean: f64 = fractions.iter().sum::<f64>() / fractions.len() as f64;
    assert!(
        mean > 0.05 && mean < 0.95,
        "hugepages should be partial overall: {fractions:?}"
    );
}

#[test]
fn puma_pool_exhaustion_and_recovery() {
    let mut ctx = boot();
    let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut ctx, 2).unwrap();
    let mut proc = Process::new(Pid(3));
    let total = puma.free_regions() as u64 * 8192;
    // exhaust the pool
    let a = puma.alloc(&mut ctx, &mut proc, total).unwrap();
    assert_eq!(puma.free_regions(), 0);
    assert!(puma.alloc(&mut ctx, &mut proc, 8192).is_err());
    // free -> full recovery, allocations succeed again
    puma.free(&mut ctx, &mut proc, a).unwrap();
    let b = puma.alloc(&mut ctx, &mut proc, 8192).unwrap();
    assert!(puma.lookup(Pid(3), b).is_some());
}

#[test]
fn allocators_share_one_machine_without_interference() {
    // different allocators in different processes on the same OS ctx
    let mut ctx = boot();
    let mut p1 = Process::new(Pid(1));
    let mut p2 = Process::new(Pid(2));
    let mut malloc = MallocSim::new();
    let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut ctx, 8).unwrap();
    let m = malloc.alloc(&mut ctx, &mut p1, 64 << 10).unwrap();
    let q = puma.alloc(&mut ctx, &mut p2, 64 << 10).unwrap();
    // physical extents must be disjoint
    let em = p1.phys_extents(m, 64 << 10).unwrap();
    let eq = p2.phys_extents(q, 64 << 10).unwrap();
    for a in &em {
        for b in &eq {
            let a_end = a.paddr + a.len;
            let b_end = b.paddr + b.len;
            assert!(a_end <= b.paddr || b_end <= a.paddr, "overlap!");
        }
    }
}

#[test]
fn stats_track_hint_outcomes() {
    let mut ctx = boot();
    let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut ctx, 8).unwrap();
    let mut proc = Process::new(Pid(5));
    let a = puma.alloc(&mut ctx, &mut proc, 16 * 8192).unwrap();
    puma.alloc_align(&mut ctx, &mut proc, 16 * 8192, a).unwrap();
    let st = puma.stats();
    assert_eq!(st.allocs, 2);
    assert_eq!(st.hint_colocated, 16);
    assert_eq!(st.hint_missed, 0);
}
