//! Property: compiled PUD execution of a random expression DAG is
//! byte-identical to the IR's scalar reference evaluator — under
//! co-located (PUMA) placement, under deliberately misaligned (malloc)
//! placement that exercises the fallback path, and with the optimizer
//! in the loop (CSE/folds/De Morgan never change results).

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::scratch::ScratchPool;
use puma::alloc::traits::Allocator;
use puma::assert_prop;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::proptest::{self, Gen};
use puma::pud::compiler::{self, Expr, ExprBuilder, ExprId};
use puma::util::rng::Pcg64;

fn boot() -> System {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    System::boot(SystemConfig {
        scheme,
        huge_pages: 12,
        churn_rounds: 800,
        seed: 0xC0117,
        artifacts: None,
        ..Default::default()
    })
    .unwrap()
}

/// A random DAG: <= 6 leaves, <= 24 nodes. Children are drawn from
/// all earlier nodes, so real sharing (diamonds) occurs routinely.
fn gen_expr(g: &mut Gen) -> Expr {
    let n_leaves = g.usize(1..7);
    let mut b = ExprBuilder::new();
    let mut ids: Vec<ExprId> = (0..n_leaves).map(|i| b.leaf(i)).collect();
    let interior = g.usize(1..19); // leaves + interior <= 24
    for _ in 0..interior {
        let pick = |g: &mut Gen, ids: &[ExprId]| ids[g.usize(0..ids.len())];
        let id = match g.usize(0..12) {
            0 | 1 => {
                let a = pick(g, &ids);
                b.not(a)
            }
            2 | 3 | 4 => {
                let (x, y) = (pick(g, &ids), pick(g, &ids));
                b.and(x, y)
            }
            5 | 6 | 7 => {
                let (x, y) = (pick(g, &ids), pick(g, &ids));
                b.or(x, y)
            }
            8 | 9 => {
                let (x, y) = (pick(g, &ids), pick(g, &ids));
                b.xor(x, y)
            }
            10 => {
                let (x, y) = (pick(g, &ids), pick(g, &ids));
                b.and_not(x, y)
            }
            _ => b.constant(g.bool()),
        };
        ids.push(id);
    }
    let root = *ids.last().unwrap();
    b.build(root)
}

/// Allocate operand buffers + dst with `alloc` (hint-aligned when
/// `hinted`), seed deterministic contents, run the compiled
/// expression, and return (device result, oracle result, PUD row
/// fraction of the expression's batch).
fn run_one(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    expr: &Expr,
    len: u64,
    hinted: bool,
    seed: u64,
) -> (Vec<u8>, Vec<u8>, f64) {
    let pid = sys.spawn();
    let n = expr.n_leaves().max(1);
    let first = sys.alloc(alloc, pid, len).unwrap();
    let mut operands = vec![first];
    for _ in 1..n {
        let va = if hinted {
            sys.alloc_align(alloc, pid, len, first).unwrap()
        } else {
            sys.alloc(alloc, pid, len).unwrap()
        };
        operands.push(va);
    }
    let dst = if hinted {
        sys.alloc_align(alloc, pid, len, first).unwrap()
    } else {
        sys.alloc(alloc, pid, len).unwrap()
    };
    let mut rng = Pcg64::new(seed);
    let mut data: Vec<Vec<u8>> = Vec::with_capacity(n);
    for &va in &operands {
        let mut v = vec![0u8; len as usize];
        rng.fill_bytes(&mut v);
        sys.write_virt(pid, va, &v).unwrap();
        data.push(v);
    }
    let mut pool = ScratchPool::new();
    let rep = sys
        .run_expr(alloc, pid, expr, &operands, dst, len, &mut pool)
        .unwrap();
    let got = sys.read_virt(pid, dst, len).unwrap();
    let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let want = expr.eval_bytes(&refs, len as usize).unwrap();
    (got, want, rep.pud_row_fraction())
}

#[test]
fn compiled_execution_matches_reference_property() {
    proptest::check_cases("compiled == scalar reference", 12, |g| {
        let expr = gen_expr(g);
        let row = 8192u64;
        let tail = if g.bool() { g.u64(1..row) } else { 0 };
        let len = g.u64(1..3) * row + tail;
        let seed = g.u64(1..u64::MAX);

        // CSE / folds / De Morgan never change results: the optimized
        // DAG evaluates identically on random bytes
        let opt = compiler::compile(&expr);
        let n = expr.n_leaves().max(1);
        let mut rng = Pcg64::new(seed ^ 0x5E5E);
        let bufs: Vec<Vec<u8>> = (0..n)
            .map(|_| {
                let mut v = vec![0u8; 64];
                rng.fill_bytes(&mut v);
                v
            })
            .collect();
        let refs: Vec<&[u8]> = bufs.iter().map(|v| v.as_slice()).collect();
        assert_prop!(
            expr.eval_bytes(&refs, 64).unwrap()
                == opt.expr().eval_bytes(&refs, 64).unwrap(),
            "optimizer changed semantics of {expr}"
        );

        // co-located placement: executes in-DRAM, byte-identical
        let mut sys = boot();
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 8).unwrap();
        let (got, want, pud) = run_one(&mut sys, &mut puma, &expr, len, true, seed);
        assert_prop!(got == want, "PUMA-placed result diverged for {expr}");
        assert_prop!(
            pud > 0.9,
            "co-located operands should run in-DRAM ({pud}) for {expr}"
        );

        // deliberately misaligned placement: fallback path, still
        // byte-identical
        let mut sys2 = boot();
        let mut malloc = MallocSim::new();
        let (got2, want2, pud2) =
            run_one(&mut sys2, &mut malloc, &expr, len, false, seed);
        assert_prop!(got2 == want2, "malloc-placed result diverged for {expr}");
        // The fallback-fraction claim is statistical: an individual
        // row can pass legality by luck when a malloc frame happens to
        // sit row-aligned (dst-only Zero after const-folding, or a
        // low-arity op). Programs with a couple of ops make that noise
        // negligible; byte-identity above is checked unconditionally.
        if opt.expr().n_leaves() > 0 && opt.stats.ops >= 2 {
            assert_prop!(
                pud2 < 0.75 && pud2 < pud,
                "malloc placement should mostly fall back \
                 (pud2={pud2}, co-located={pud}) for {expr}"
            );
        }
        assert_prop!(want == want2, "oracle must not depend on placement");
    });
}

#[test]
fn spilling_expressions_stay_correct() {
    // 8 simultaneously-live ANDs exceed the default 4-slot pool
    let mut b = ExprBuilder::new();
    let ands: Vec<ExprId> = (0..8)
        .map(|i| {
            let x = b.leaf(i % 6);
            let y = b.leaf((i + 1) % 6);
            let xy = b.and(x, y);
            let z = b.leaf((i + 2) % 6);
            b.xor(xy, z)
        })
        .collect();
    // pairwise fold at the end keeps all eight live at once
    let p: Vec<ExprId> = ands.chunks(2).map(|c| b.or(c[0], c[1])).collect();
    let q: Vec<ExprId> = p.chunks(2).map(|c| b.and(c[0], c[1])).collect();
    let root = b.xor(q[0], q[1]);
    let expr = b.build(root);

    let compiled = compiler::compile(&expr);
    assert!(
        compiled.stats.spills > 0,
        "this expression must exceed the default scratch pool \
         (needs {} slots)",
        compiled.stats.scratch_slots
    );

    let row = 8192u64;
    let mut sys = boot();
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 8).unwrap();
    let (got, want, pud) = run_one(&mut sys, &mut puma, &expr, 2 * row, true, 77);
    assert_eq!(got, want, "spilled execution diverged");
    assert!(pud > 0.9, "spill rows are hint-co-located too, got {pud}");
}
