//! Property-based tests on DRAM model invariants (mini-proptest).

use puma::dram::address::InterleaveScheme;
use puma::dram::device::DramDevice;
use puma::dram::geometry::DramGeometry;
use puma::proptest::{self, assert_prop};
use puma::pud::legality::{check_rowwise, RowPlan};
use puma::os::process::PhysExtent;

fn random_geometry(g: &mut puma::proptest::Gen) -> DramGeometry {
    DramGeometry {
        channels: 1 << g.u64(0..2),
        ranks_per_channel: 1 << g.u64(0..2),
        banks_per_rank: 1 << g.u64(1..3),
        subarrays_per_bank: 1 << g.u64(1..4),
        rows_per_subarray: 1 << g.u64(4..7),
        row_bytes: 1 << g.u64(6..10),
    }
}

#[test]
fn decode_encode_roundtrip_random_geometries() {
    proptest::check_cases("addr roundtrip", 32, |g| {
        let geom = random_geometry(g);
        let scheme = match g.u64(0..3) {
            0 => InterleaveScheme::row_major(geom),
            1 => InterleaveScheme::bank_xor(geom),
            _ => InterleaveScheme::subarray_low(geom),
        };
        for _ in 0..32 {
            let addr = g.u64(0..scheme.geometry.capacity_bytes());
            let loc = scheme.decode(addr);
            assert_prop!(scheme.geometry.contains(&loc), "loc outside geometry");
            assert_prop!(scheme.encode(&loc) == addr, "roundtrip failed at {addr:#x}");
        }
    });
}

#[test]
fn device_write_read_arbitrary_spans() {
    proptest::check_cases("device rw spans", 24, |g| {
        let geom = random_geometry(g);
        let cap = geom.capacity_bytes();
        let mut dev = DramDevice::new(InterleaveScheme::row_major(geom));
        let len = g.u64(1..4096.min(cap));
        let addr = g.u64(0..cap - len);
        let data: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
        dev.write(addr, &data);
        let mut back = vec![0u8; len as usize];
        dev.read(addr, &mut back);
        assert_prop!(back == data, "readback mismatch");
        // a disjoint span is still zero
        if addr > len + 1 {
            let mut before = vec![0xFFu8; 1];
            dev.read(0, &mut before);
            // address 0 may coincide with the span only if addr == 0
            assert_prop!(before[0] == 0 || addr == 0);
        }
    });
}

#[test]
fn legality_plan_covers_exactly_the_request() {
    proptest::check_cases("plan coverage", 24, |g| {
        let geom = DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 4,
            rows_per_subarray: 64,
            row_bytes: 512,
        };
        let scheme = InterleaveScheme::row_major(geom);
        // random (possibly scattered) operand extents covering len
        let len = g.u64(1..6000);
        let mk = |g: &mut puma::proptest::Gen| -> Vec<PhysExtent> {
            let mut left = len;
            let mut out = Vec::new();
            while left > 0 {
                let piece = g.u64(1..left + 1);
                let paddr =
                    g.u64(0..scheme.geometry.capacity_bytes() - piece);
                out.push(PhysExtent { paddr, len: piece });
                left -= piece;
            }
            out
        };
        let dst = mk(g);
        let src = mk(g);
        let plan = check_rowwise(&scheme, &[&dst, &src], len);
        let covered: u64 = plan.iter().map(|p| p.bytes() as u64).sum();
        assert_prop!(covered == len, "plan covers {covered}, want {len}");
        // every fallback entry's extents cover its bytes
        for p in &plan {
            if let RowPlan::Fallback {
                dst, srcs, bytes, ..
            } = p
            {
                let d: u64 = dst.iter().map(|e| e.len).sum();
                assert_prop!(d == *bytes as u64, "dst extents {d} != {bytes}");
                for s in srcs {
                    let sv: u64 = s.iter().map(|e| e.len).sum();
                    assert_prop!(sv == *bytes as u64);
                }
            }
        }
    });
}

#[test]
fn bank_hit_rate_bounded() {
    proptest::check_cases("bank hit rate", 16, |g| {
        use puma::dram::bank::BankState;
        use puma::dram::timing::TimingParams;
        let geom = DramGeometry::default();
        let t = TimingParams::default();
        let mut bank = BankState::new();
        for _ in 0..g.usize(1..200) {
            let loc = puma::dram::geometry::Loc {
                channel: 0,
                rank: 0,
                bank: g.u64(0..16) as u32,
                subarray: g.u64(0..64) as u32,
                row: g.u64(0..1024) as u32,
                column: 0,
            };
            let ns = bank.access(&geom, &t, &loc);
            assert_prop!(ns == t.row_hit_ns() || ns == t.row_miss_ns());
        }
        let hr = bank.hit_rate();
        assert_prop!((0.0..=1.0).contains(&hr));
    });
}
