//! End-to-end integration: the complete stack — PUMA allocation, PUD
//! execution, XLA fallback, reports — on small but real workloads.

use puma::alloc::puma::FitPolicy;
use puma::report;
use puma::workloads::microbench::{AllocatorKind, Micro};
use puma::workloads::sweep::{self, SweepConfig};

fn fast_cfg(artifacts: bool) -> SweepConfig {
    SweepConfig {
        sizes: vec![250, 64 << 10, 384 << 10],
        reps: 4,
        huge_pages: 48,
        puma_pages: 24,
        churn_rounds: 4_000,
        seed: 0xE2E,
        artifacts: if artifacts {
            let dir =
                std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            dir.join("manifest.tsv").exists().then_some(dir)
        } else {
            None
        },
        ..Default::default()
    }
}

#[test]
fn figure2_shape_holds_scalar() {
    let cfg = fast_cfg(false);
    let mut series = Vec::new();
    for micro in Micro::ALL {
        let cells = sweep::run_micro_sweep(
            &cfg,
            AllocatorKind::Puma(FitPolicy::WorstFit),
            micro,
        )
        .unwrap();
        // PUMA wins at the top size, and grows from the bottom
        let first = cells.first().unwrap().speedup();
        let last = cells.last().unwrap().speedup();
        assert!(last > 1.0, "{}: {last:.2}", micro.name());
        assert!(last > first, "{}: {first:.2} -> {last:.2}", micro.name());
        series.push((micro, cells));
    }
    // the report renders without touching the fs
    let text = report::figure2(&series, None).unwrap();
    assert!(text.contains("zero-speedup"));
}

#[test]
fn figure2_cell_through_xla_runtime() {
    // one sweep cell with the real XLA fallback: the malloc baseline
    // routes every row through the AOT artifacts
    let cfg = fast_cfg(true);
    if cfg.artifacts.is_none() {
        return; // artifacts not built
    }
    let cells =
        sweep::run_micro_sweep(&cfg, AllocatorKind::Puma(FitPolicy::WorstFit), Micro::Aand)
            .unwrap();
    assert!(cells.last().unwrap().speedup() > 1.0);
}

#[test]
fn motivation_shape_holds() {
    let cfg = fast_cfg(false);
    let rows = sweep::run_motivation(
        &cfg,
        &[
            AllocatorKind::Malloc,
            AllocatorKind::Memalign,
            AllocatorKind::Puma(FitPolicy::WorstFit),
        ],
    )
    .unwrap();
    for (k, s, f) in &rows {
        match k {
            AllocatorKind::Malloc | AllocatorKind::Memalign => {
                assert!(*f < 0.02, "{} at {s}: {f}", k.name())
            }
            AllocatorKind::Puma(_) => assert!(*f > 0.95, "puma at {s}: {f}"),
            _ => {}
        }
    }
}

#[test]
fn shipped_config_files_load_and_match_the_builtin_machine() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    // the devicetree file describes the default scheme exactly
    let text = std::fs::read_to_string(root.join("configs/dram-8gib.dts")).unwrap();
    let scheme = puma::dram::devicetree::parse(&text).unwrap();
    assert_eq!(
        scheme,
        puma::dram::address::InterleaveScheme::row_major(Default::default())
    );
    // the run configs parse and carry the paper's sweep
    let cfg = puma::config::Config::load_file(
        root.join("configs/default.conf").to_str().unwrap(),
    )
    .unwrap();
    assert_eq!(cfg.huge_pages, 256);
    assert_eq!(cfg.reps, 16);
    assert_eq!(cfg.sizes.first(), Some(&250));
    assert_eq!(cfg.sizes.last(), Some(&(6 * (1 << 20) / 8)));
    let smoke = puma::config::Config::load_file(
        root.join("configs/smoke.conf").to_str().unwrap(),
    )
    .unwrap();
    assert_eq!(smoke.sizes.len(), 3);
}
