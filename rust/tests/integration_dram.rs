//! Integration: DRAM model — address mapping x device x devicetree
//! round-trips on full-size (8 GiB) machines.

use puma::dram::address::{Field, InterleaveScheme};
use puma::dram::device::DramDevice;
use puma::dram::devicetree;
use puma::dram::geometry::{DramGeometry, SubarrayId};
use puma::util::rng::Pcg64;

#[test]
fn full_size_roundtrip_all_schemes() {
    let g = DramGeometry::default();
    for scheme in [
        InterleaveScheme::row_major(g.clone()),
        InterleaveScheme::bank_xor(g.clone()),
        InterleaveScheme::subarray_low(g.clone()),
    ] {
        let mut rng = Pcg64::new(0xD12A);
        for _ in 0..5_000 {
            let addr = rng.below(scheme.geometry.capacity_bytes());
            let loc = scheme.decode(addr);
            assert!(scheme.geometry.contains(&loc));
            assert_eq!(scheme.encode(&loc), addr);
        }
    }
}

#[test]
fn devicetree_file_to_device_pipeline() {
    // render -> parse -> build a device -> write/read across rows
    let scheme = InterleaveScheme::row_major(DramGeometry::default());
    let text = devicetree::render(&scheme);
    let parsed = devicetree::parse(&text).unwrap();
    assert_eq!(parsed, scheme);
    let mut dev = DramDevice::new(parsed);
    let mut rng = Pcg64::new(77);
    let mut data = vec![0u8; 100_000];
    rng.fill_bytes(&mut data);
    let addr = 123_456_789;
    dev.write(addr, &data);
    let mut back = vec![0u8; data.len()];
    dev.read(addr, &mut back);
    assert_eq!(back, data);
    // ~13 rows materialized for ~100 KB (8 KiB rows)
    assert!(dev.resident_rows() >= 12 && dev.resident_rows() <= 14);
}

#[test]
fn subarray_row_addresses_cover_distinct_rows() {
    let scheme = InterleaveScheme::row_major(DramGeometry::default());
    let mut seen = std::collections::HashSet::new();
    for sid in (0..scheme.geometry.total_subarrays()).step_by(37) {
        for row in (0..scheme.geometry.rows_per_subarray).step_by(101) {
            let addr = scheme.row_start_addr(SubarrayId(sid), row);
            assert!(scheme.row_aligned(addr));
            assert!(seen.insert(addr), "duplicate row address {addr:#x}");
        }
    }
}

#[test]
fn every_field_mapped_once_in_builtin_schemes() {
    let g = DramGeometry::default();
    for scheme in [
        InterleaveScheme::row_major(g.clone()),
        InterleaveScheme::bank_xor(g.clone()),
        InterleaveScheme::subarray_low(g),
    ] {
        scheme.validate().unwrap();
        for f in Field::ALL {
            assert!(
                scheme.bits.iter().any(|(g, _)| *g == f),
                "missing field {f:?}"
            );
        }
    }
}
