//! Fault-injection properties of `pud::analysis`: the verifier accepts
//! every stream the compiler emits for the random-DAG corpus (and the
//! translation validation over exhaustive truth-table lanes *proves*
//! stream == source DAG), while each class of systematic corruption —
//! swapped ops, operand-clobbering aliases, leaked scratch leases,
//! reordered hazards, reserved-row placements, truncated streams — is
//! rejected with the matching [`VerifyErrorKind`].

use std::cell::Cell;

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::scratch::ScratchPool;
use puma::analysis::lint::Lint;
use puma::analysis::verify::{
    verify_compiled, verify_compiled_multi, VerifyErrorKind,
};
use puma::analysis::{Severity, VerifyLevel};
use puma::assert_prop;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::proptest::{self, Gen};
use puma::pud::compiler::{self, Expr, ExprBuilder, ExprId};
use puma::pud::isa::PudOp;
use puma::util::rng::Pcg64;

/// A random DAG: <= 6 leaves, <= 24 nodes, real sharing via children
/// drawn from all earlier nodes (same shape as the prop_compiler
/// corpus). With <= 6 leaves every translation validation in this file
/// runs on exhaustive truth-table lanes — acceptance is a proof.
fn gen_expr(g: &mut Gen) -> Expr {
    let n_leaves = g.usize(1..7);
    let mut b = ExprBuilder::new();
    let mut ids: Vec<ExprId> = (0..n_leaves).map(|i| b.leaf(i)).collect();
    let interior = g.usize(1..19);
    for _ in 0..interior {
        let pick = |g: &mut Gen, ids: &[ExprId]| ids[g.usize(0..ids.len())];
        let id = match g.usize(0..12) {
            0 | 1 => {
                let a = pick(g, &ids);
                b.not(a)
            }
            2 | 3 | 4 => {
                let (x, y) = (pick(g, &ids), pick(g, &ids));
                b.and(x, y)
            }
            5 | 6 | 7 => {
                let (x, y) = (pick(g, &ids), pick(g, &ids));
                b.or(x, y)
            }
            8 | 9 => {
                let (x, y) = (pick(g, &ids), pick(g, &ids));
                b.xor(x, y)
            }
            10 => {
                let (x, y) = (pick(g, &ids), pick(g, &ids));
                b.and_not(x, y)
            }
            _ => b.constant(g.bool()),
        };
        ids.push(id);
    }
    let root = *ids.last().unwrap();
    b.build(root)
}

fn addrs(n: usize, base: u64) -> Vec<u64> {
    (0..n as u64).map(|i| base + i * 0x1000).collect()
}

/// Same-arity replacement candidates for an op swap that survives the
/// arity and hazard checks and must therefore be caught by translation
/// validation.
fn swap_candidates(op: PudOp) -> Vec<PudOp> {
    [PudOp::And, PudOp::Or, PudOp::Xor, PudOp::Copy, PudOp::Not]
        .into_iter()
        .filter(|c| *c != op && c.arity() == op.arity())
        .collect()
}

#[test]
fn verifier_accepts_corpus_and_rejects_every_mutation_class() {
    // detections are counted across the whole corpus: a single case
    // can lack a mutation site (one-request streams, scratch-free
    // programs), but each class must fire somewhere in the run
    let hit_swap = Cell::new(0u32);
    let hit_alias = Cell::new(0u32);
    let hit_leak = Cell::new(0u32);
    let hit_reorder = Cell::new(0u32);
    let hit_reserved = Cell::new(0u32);
    let hit_truncated = Cell::new(0u32);

    proptest::check_cases("verify accepts corpus, rejects faults", 24, |g| {
        let expr = gen_expr(g);
        let c = compiler::compile(&expr);
        let n = expr.n_leaves().max(1);
        let operands = addrs(n, 0x10_0000);
        let scratch = addrs(c.scratch_needed().max(1), 0x20_0000);
        let dst = 0x30_0000u64;
        let len = g.u64(1..8192);
        let reqs = c.emit(&operands, dst, len, &scratch).unwrap();

        // 0. acceptance: the pristine stream verifies, and with <= 6
        //    leaves the lanes enumerate every assignment (a proof)
        let ok = verify_compiled(&c, &operands, dst, len, &scratch, &reqs, None)
            .unwrap_or_else(|e| panic!("pristine stream rejected: {e} ({expr})"));
        assert_prop!(ok.ops == reqs.len(), "every request checked");
        assert_prop!(ok.exhaustive, "<= 6 leaves must verify exhaustively");
        assert_prop!(ok.waves >= 1, "a non-empty stream has waves");

        // 1. swapped op on the dst-defining (last) request: same
        //    (dst, srcs, len) tuple, different function -> translation
        //    validation must name it. A candidate can escape only when
        //    both source images are identically zero, which the
        //    optimizer folds away in practice — counted globally.
        let last = reqs.len() - 1;
        for cand in swap_candidates(reqs[last].op) {
            let mut m = reqs.clone();
            m[last].op = cand;
            if let Err(e) =
                verify_compiled(&c, &operands, dst, len, &scratch, &m, None)
            {
                assert_prop!(
                    e.kind == VerifyErrorKind::TranslationMismatch,
                    "op swap {} -> {cand:?} flagged as {}, want \
                     translation_mismatch",
                    reqs[last].op,
                    e.kind
                );
                hit_swap.set(hit_swap.get() + 1);
            }
        }

        // 2. alias a request's dst onto an operand buffer that a later
        //    request still reads -> the in-place-dst legality rule
        if let Some((p, va)) = (1..reqs.len()).rev().find_map(|p| {
            reqs[p]
                .srcs
                .iter()
                .find(|s| operands.contains(*s))
                .map(|s| (p, *s))
        }) {
            let mut m = reqs.clone();
            m[p - 1].dst = va;
            let e = verify_compiled(&c, &operands, dst, len, &scratch, &m, None)
                .expect_err("operand clobber must be rejected");
            assert_prop!(
                e.kind == VerifyErrorKind::IllegalAlias,
                "operand clobber flagged as {}, want illegal_alias",
                e.kind
            );
            hit_alias.set(hit_alias.get() + 1);
        }

        // 3. phantom scratch lease: a slot the binding claims the
        //    program needs but the stream never touches
        if c.scratch_needed() > 0 {
            let mut leased = vec![0x40_0000u64];
            leased.extend_from_slice(&scratch);
            let e =
                verify_compiled(&c, &operands, dst, len, &leased, &reqs, None)
                    .expect_err("phantom lease must be rejected");
            assert_prop!(
                e.kind == VerifyErrorKind::ScratchLeak,
                "phantom lease flagged as {}, want scratch_leak",
                e.kind
            );
            hit_leak.set(hit_leak.get() + 1);
        }

        // 4. reorder an adjacent pair (picked so dataflow still
        //    passes) -> the greedy hazard-wave partition diverges
        if let Some(i) = (0..reqs.len().saturating_sub(1)).find(|&i| {
            let (a, b) = (&reqs[i], &reqs[i + 1]);
            let differ = a.dst != b.dst || a.srcs != b.srcs || a.len != b.len;
            differ
                && !b.srcs.contains(&a.dst)
                && !(operands.contains(&b.dst) && a.srcs.contains(&b.dst))
        }) {
            let mut m = reqs.clone();
            m.swap(i, i + 1);
            let e = verify_compiled(&c, &operands, dst, len, &scratch, &m, None)
                .expect_err("reordered stream must be rejected");
            assert_prop!(
                e.kind == VerifyErrorKind::HazardWaveMismatch,
                "reorder flagged as {}, want hazard_wave_mismatch",
                e.kind
            );
            hit_reorder.set(hit_reorder.get() + 1);
        }

        // 5. reserved-row poisoning: the probe marks the output
        //    buffer's row as an Ambit control/temp row
        {
            let probe = move |va: u64| va == dst;
            let e = verify_compiled(
                &c,
                &operands,
                dst,
                len,
                &scratch,
                &reqs,
                Some(&probe),
            )
            .expect_err("reserved placement must be rejected");
            assert_prop!(
                e.kind == VerifyErrorKind::ReservedRow,
                "reserved placement flagged as {}, want reserved_row",
                e.kind
            );
            hit_reserved.set(hit_reserved.get() + 1);
        }

        // 6. truncated stream: drop the final request. When that was
        //    the only write to dst the diagnosis is precise; when dst
        //    doubled as an in-place temp the stream is still rejected
        //    (as a leak or wave divergence)
        {
            let mut m = reqs.clone();
            let popped = m.pop().unwrap();
            let dst_writes = reqs.iter().filter(|r| r.dst == dst).count();
            match verify_compiled(&c, &operands, dst, len, &scratch, &m, None) {
                Err(e) if dst_writes == 1 && popped.dst == dst => {
                    assert_prop!(
                        e.kind == VerifyErrorKind::TruncatedStream,
                        "truncation flagged as {}, want truncated_stream",
                        e.kind
                    );
                    hit_truncated.set(hit_truncated.get() + 1);
                }
                Err(e) => {
                    assert_prop!(
                        matches!(
                            e.kind,
                            VerifyErrorKind::TruncatedStream
                                | VerifyErrorKind::ScratchLeak
                                | VerifyErrorKind::HazardWaveMismatch
                        ),
                        "truncation flagged as unexpected kind {}",
                        e.kind
                    );
                    hit_truncated.set(hit_truncated.get() + 1);
                }
                Ok(_) => panic!("truncated stream accepted for {expr}"),
            }
        }
    });

    for (name, hits) in [
        ("op swap", &hit_swap),
        ("operand alias", &hit_alias),
        ("scratch leak", &hit_leak),
        ("hazard reorder", &hit_reorder),
        ("reserved row", &hit_reserved),
        ("truncated stream", &hit_truncated),
    ] {
        assert!(
            hits.get() > 0,
            "mutation class `{name}` never fired across the corpus"
        );
    }
}

#[test]
fn verifier_accepts_multi_output_corpus() {
    proptest::check_cases("multi-output corpus verifies", 12, |g| {
        let n_leaves = g.usize(1..7);
        let mut b = ExprBuilder::new();
        let mut ids: Vec<ExprId> =
            (0..n_leaves).map(|i| b.leaf(i)).collect();
        for _ in 0..g.usize(1..12) {
            let x = ids[g.usize(0..ids.len())];
            let y = ids[g.usize(0..ids.len())];
            let id = match g.usize(0..3) {
                0 => b.and(x, y),
                1 => b.or(x, y),
                _ => b.xor(x, y),
            };
            ids.push(id);
        }
        // duplicate roots are legal and must collapse consistently
        let n_roots = g.usize(1..4);
        let roots: Vec<ExprId> =
            (0..n_roots).map(|_| ids[g.usize(0..ids.len())]).collect();
        let m = b.build_multi(roots);
        let c = compiler::compile_multi(&m);

        let operands = addrs(n_leaves.max(1), 0x10_0000);
        let dsts = addrs(n_roots, 0x30_0000);
        let scratch = addrs(c.scratch_needed().max(1), 0x20_0000);
        let len = g.u64(1..4096);
        let reqs = c.emit(&operands, &dsts, len, &scratch).unwrap();
        let ok = verify_compiled_multi(
            &c, &operands, &dsts, len, &scratch, &reqs, None,
        )
        .unwrap_or_else(|e| panic!("multi stream rejected: {e}"));
        assert_prop!(ok.exhaustive, "<= 6 leaves must verify exhaustively");
    });
}

/// End-to-end PudSan: with `VerifyLevel::Full` the `System` verifies
/// every emitted stream against the page table, and the linter
/// attributes fallback rows. PUMA placement must come back clean;
/// deliberately misaligned placement must be attributed, never
/// escalated to an error.
#[test]
fn full_verification_is_clean_under_puma_and_attributed_under_malloc() {
    let mut b = ExprBuilder::new();
    let (x, y, z) = (b.leaf(0), b.leaf(1), b.leaf(2));
    let xy = b.and(x, y);
    let root = b.xor(xy, z);
    let expr = b.build(root);

    let run = |puma_placed: bool| -> Vec<puma::analysis::Diagnostic> {
        let scheme = InterleaveScheme::row_major(DramGeometry::small());
        let row = scheme.geometry.row_bytes as u64;
        let mut sys = System::boot(SystemConfig {
            scheme,
            huge_pages: 12,
            churn_rounds: 400,
            seed: 0xA11A,
            artifacts: None,
            verify: VerifyLevel::Full,
            ..Default::default()
        })
        .unwrap();
        let pid = sys.spawn();
        let len = 2 * row;
        let mut puma_alloc = PumaAlloc::new(row, FitPolicy::WorstFit);
        let mut malloc = MallocSim::new();
        let (alloc, hinted): (&mut dyn puma::alloc::traits::Allocator, bool) =
            if puma_placed {
                puma_alloc.pim_preallocate(&mut sys.os, 8).unwrap();
                (&mut puma_alloc, true)
            } else {
                (&mut malloc, false)
            };
        let first = sys.alloc(alloc, pid, len).unwrap();
        let mut operands = vec![first];
        for _ in 1..3 {
            let va = if hinted {
                sys.alloc_align(alloc, pid, len, first).unwrap()
            } else {
                sys.alloc(alloc, pid, len).unwrap()
            };
            operands.push(va);
        }
        let dst = if hinted {
            sys.alloc_align(alloc, pid, len, first).unwrap()
        } else {
            sys.alloc(alloc, pid, len).unwrap()
        };
        let mut rng = Pcg64::new(7);
        for &va in &operands {
            let mut v = vec![0u8; len as usize];
            rng.fill_bytes(&mut v);
            sys.write_virt(pid, va, &v).unwrap();
        }
        let mut pool = ScratchPool::new();
        sys.run_expr(alloc, pid, &expr, &operands, dst, len, &mut pool)
            .unwrap();
        sys.take_diagnostics()
    };

    let clean = run(true);
    assert!(
        clean.iter().all(|d| d.severity < Severity::Error),
        "PUMA-placed run must verify without errors: {clean:?}"
    );

    let attributed = run(false);
    assert!(
        attributed.iter().all(|d| d.severity < Severity::Error),
        "misalignment is a performance fault, not a verify error: \
         {attributed:?}"
    );
    assert!(
        attributed
            .iter()
            .any(|d| matches!(d.lint, Lint::FallbackRow(_))),
        "malloc placement must produce attributed fallback rows: \
         {attributed:?}"
    );
}
