//! Property-based tests on allocator invariants (mini-proptest; see
//! DESIGN.md §7).

use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::traits::{Allocator, OsCtx};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::os::buddy::BuddyAllocator;
use puma::os::process::{Pid, Process};
use puma::proptest::{self, assert_prop};

fn small_ctx(seed: u64) -> OsCtx {
    OsCtx::boot(
        InterleaveScheme::row_major(DramGeometry::small()),
        16,
        2_000,
        seed,
    )
    .unwrap()
}

#[test]
fn buddy_never_double_allocates() {
    proptest::check_cases("buddy disjoint blocks", 24, |g| {
        let mut buddy = BuddyAllocator::new(4096).unwrap();
        let mut live: Vec<(u64, u8)> = Vec::new();
        let mut frames = std::collections::HashSet::new();
        for _ in 0..g.usize(1..80) {
            if live.is_empty() || g.bool() {
                let order = g.u64(0..5) as u8;
                if let Ok(pfn) = buddy.alloc(order) {
                    for f in pfn..pfn + (1 << order) {
                        assert_prop!(frames.insert(f), "frame {f} double-allocated");
                    }
                    live.push((pfn, order));
                }
            } else {
                let idx = g.usize(0..live.len());
                let (pfn, order) = live.swap_remove(idx);
                for f in pfn..pfn + (1 << order) {
                    frames.remove(&f);
                }
                buddy.free(pfn, order);
            }
        }
        buddy.check_invariants().unwrap();
        // cleanup frees everything back
        for (pfn, order) in live {
            buddy.free(pfn, order);
        }
        assert_prop!(buddy.free_frames() == 4096);
    });
}

#[test]
fn puma_regions_unique_and_recycled() {
    proptest::check_cases("puma region uniqueness", 12, |g| {
        let seed = g.u64(0..1 << 32);
        let mut ctx = small_ctx(seed);
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 6).unwrap();
        let start_regions = puma.free_regions();
        let mut proc = Process::new(Pid(1));
        let mut live: Vec<u64> = Vec::new();
        let mut held_regions = std::collections::HashSet::new();
        for _ in 0..g.usize(1..30) {
            if live.is_empty() || g.ratio(2, 3) {
                let rows = g.u64(1..20);
                let hint = if !live.is_empty() && g.bool() {
                    Some(live[g.usize(0..live.len())])
                } else {
                    None
                };
                let res = match hint {
                    Some(h) => puma.alloc_align(&mut ctx, &mut proc, rows * 8192, h),
                    None => puma.alloc(&mut ctx, &mut proc, rows * 8192),
                };
                if let Ok(va) = res {
                    // regions backing this allocation are not in use
                    for r in &puma.lookup(Pid(1), va).unwrap().regions {
                        assert_prop!(
                            held_regions.insert(r.paddr),
                            "region {:#x} double-handed", r.paddr
                        );
                    }
                    live.push(va);
                }
            } else {
                let idx = g.usize(0..live.len());
                let va = live.swap_remove(idx);
                for r in puma.lookup(Pid(1), va).unwrap().regions.clone() {
                    held_regions.remove(&r.paddr);
                }
                puma.free(&mut ctx, &mut proc, va).unwrap();
            }
        }
        for va in live {
            puma.free(&mut ctx, &mut proc, va).unwrap();
        }
        assert_prop!(puma.free_regions() == start_regions, "regions leaked");
    });
}

#[test]
fn puma_allocations_always_row_aligned_regions() {
    proptest::check_cases("puma row alignment", 12, |g| {
        let mut ctx = small_ctx(g.u64(0..1 << 32));
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 4).unwrap();
        let mut proc = Process::new(Pid(2));
        let len = g.u64(1..400_000);
        if let Ok(va) = puma.alloc(&mut ctx, &mut proc, len) {
            let alloc = puma.lookup(Pid(2), va).unwrap();
            for r in &alloc.regions {
                assert_prop!(r.paddr % 8192 == 0, "region misaligned");
                assert_prop!(ctx.scheme.subarray_id(r.paddr) == r.sid);
            }
            // virtual range is fully mapped
            assert_prop!(proc
                .phys_extents(va, alloc.regions.len() as u64 * 8192)
                .is_ok());
        }
    });
}

#[test]
fn hint_colocation_is_total_when_pool_is_fresh() {
    proptest::check_cases("fresh-pool colocation", 10, |g| {
        let mut ctx = small_ctx(g.u64(0..1 << 32));
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 8).unwrap();
        let mut proc = Process::new(Pid(3));
        let rows = g.u64(1..24);
        let a = puma.alloc(&mut ctx, &mut proc, rows * 8192).unwrap();
        let b = puma
            .alloc_align(&mut ctx, &mut proc, rows * 8192, a)
            .unwrap();
        let ra = &puma.lookup(Pid(3), a).unwrap().regions;
        let rb = &puma.lookup(Pid(3), b).unwrap().regions;
        for (x, y) in ra.iter().zip(rb) {
            assert_prop!(x.sid == y.sid, "row not co-located");
        }
    });
}
