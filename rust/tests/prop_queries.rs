//! Differential query fuzzing: every `pud::query` shape (bitmap
//! semi-join, batched group-by, top-k threshold bisection) over random
//! multi-column tables — ragged lengths, duplicate/missing/
//! out-of-domain keys, widths 4/8/16 — verified bit-for-bit against
//! the scalar host oracles in `pud::query::reference` under all three
//! placement regimes: co-located (PUMA, hint-aligned, in-DRAM),
//! deliberately misaligned (malloc, CPU fallback), and bank-sharded.
//! A fixed-seed regression corpus pins the edge cases (empty build
//! side, all-rows-match, `k = 0`, `k ≥ N`, single group, all-equal
//! column, single-row probe), and satellite tests cover column-cache
//! LRU eviction under budget pressure and the zero-fresh-compiles
//! warm-sweep guarantee.

// Several properties pin the deprecated flat/sharded shims on purpose:
// they must keep producing bit-identical results until removal
// (tests/prop_serve.rs checks shim == unified-API equivalence).
#![allow(deprecated)]

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::scratch::ScratchPool;
use puma::alloc::traits::Allocator;
use puma::assert_prop;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::os::process::Pid;
use puma::proptest::{self, Gen};
use puma::pud::arith::{
    self, ArithOp, ShardedLayout, ShardedScratch, VerticalLayout,
};
use puma::pud::query::{self, reference};
use puma::util::rng::Pcg64;
use puma::workloads::microbench::AllocatorKind;
use puma::workloads::queries::{self, QueriesConfig};

/// Fuzz boots one system per case, so the pre-aging churn is kept
/// short — placement legality, not fragmentation realism, is under
/// test here.
fn boot() -> System {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    System::boot(SystemConfig {
        scheme,
        huge_pages: 12,
        churn_rounds: 60,
        seed: 0xA217,
        artifacts: None,
        ..Default::default()
    })
    .unwrap()
}

fn boot_puma() -> (System, PumaAlloc) {
    let mut sys = boot();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 8).unwrap();
    (sys, puma)
}

/// One random query-fuzz table: three columns plus per-shape inputs.
#[derive(Debug, Clone)]
struct Table {
    width: u32,
    cust: Vec<u64>,
    grp: Vec<u64>,
    qty: Vec<u64>,
    build: Vec<u64>,
    groups: Vec<u64>,
    k: u64,
    /// Residual semi-join predicate `quantity < thr`; `None` drops the
    /// predicate leg entirely.
    thr: Option<u64>,
}

fn gen_table(g: &mut Gen) -> Table {
    let width = *g.choose(&[4u32, 8, 16]);
    let domain = 1u64 << width;
    // ragged lengths: sub-octet tables hit the padded final byte,
    // larger ones span partial rows
    let elems = if g.ratio(1, 5) {
        g.usize(1..9)
    } else {
        g.usize(9..400)
    };
    // probe keys cluster in a sub-range so build keys both hit and miss
    let key_span = g.u64(1..domain + 1);
    let seed = g.u64(1..u64::MAX);
    let mut rng = Pcg64::new(seed);
    let cust: Vec<u64> = (0..elems).map(|_| rng.below(key_span)).collect();
    let grp_span = g.u64(1..domain.min(16) + 1);
    let grp: Vec<u64> = (0..elems).map(|_| rng.below(grp_span)).collect();
    let mask = arith::width_mask(width);
    let qty: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
    // build side: possibly empty, duplicates legal, occasionally an
    // out-of-domain straggler the engine must drop
    let mut build = g.vec(0..12, |g| g.u64(0..key_span + 2));
    if g.ratio(1, 8) {
        build.push(domain);
    }
    // requested groups may duplicate or name keys absent from the data
    let groups = g.vec(0..6, |g| g.u64(0..domain));
    let k = g.u64(0..elems as u64 + 3);
    let thr = if g.bool() { Some(g.u64(0..domain)) } else { None };
    Table {
        width,
        cust,
        grp,
        qty,
        build,
        groups,
        k,
        thr,
    }
}

/// Allocate a `w`-bit layout, hint-aligned to `hint` when `hinted`
/// (the PUMA co-location protocol); baselines allocate plainly.
fn vert(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    hinted: bool,
    w: u32,
    elems: usize,
    hint: u64,
) -> VerticalLayout {
    if hinted {
        VerticalLayout::alloc_with_hint(sys, alloc, pid, w, elems, hint)
            .unwrap()
    } else {
        VerticalLayout::alloc(sys, alloc, pid, w, elems).unwrap()
    }
}

/// Run all three shapes flat over `t` with `alloc` and verify each
/// against the scalar reference. `hinted` co-locates every plane with
/// the first column (the PUMA protocol); baselines allocate plainly.
fn check_flat(sys: &mut System, alloc: &mut dyn Allocator, hinted: bool, t: &Table) {
    let pid = sys.spawn();
    let elems = t.cust.len();
    let cust =
        VerticalLayout::alloc(sys, alloc, pid, t.width, elems).unwrap();
    let hint = cust.hint();
    let grp = vert(sys, alloc, pid, hinted, t.width, elems, hint);
    let qty = vert(sys, alloc, pid, hinted, t.width, elems, hint);
    cust.store(sys, pid, &t.cust).unwrap();
    grp.store(sys, pid, &t.grp).unwrap();
    qty.store(sys, pid, &t.qty).unwrap();
    let mut pool = ScratchPool::new();

    // --- semi-join -----------------------------------------------------
    let pred = t.thr.map(|thr| {
        let m = vert(sys, alloc, pid, hinted, 1, elems, hint);
        sys.run_arith_const(alloc, pid, ArithOp::CmpLt, thr, &qty, &m, &mut pool)
            .unwrap();
        m
    });
    let dst = vert(sys, alloc, pid, hinted, 1, elems, hint);
    query::semi_join_mask(
        sys,
        alloc,
        pid,
        &cust,
        &t.build,
        pred.as_ref().map(|m| m.planes()[0]),
        &dst,
        &mut pool,
    )
    .unwrap();
    let got = dst.load(sys, pid).unwrap();
    let pred_ref: Option<Vec<bool>> =
        t.thr.map(|thr| t.qty.iter().map(|&v| v < thr).collect());
    let want = reference::semi_join(&t.cust, &t.build, pred_ref.as_deref());
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert_prop!(
            (g == 1) == w,
            "semi-join bit {i} diverged (hinted {hinted}, width {}, \
             elems {elems}, build {:?}, thr {:?})",
            t.width,
            t.build,
            t.thr
        );
    }

    // --- group-by ------------------------------------------------------
    let (aggs, _) =
        query::group_by_sum(sys, alloc, pid, &grp, &qty, &t.groups, &mut pool)
            .unwrap();
    let want = reference::group_by(&t.grp, &t.qty, &t.groups);
    assert_prop!(aggs.len() == want.len(), "one aggregate per group");
    for (i, (a, (wc, ws))) in aggs.iter().zip(&want).enumerate() {
        assert_prop!(
            a.group == t.groups[i] && a.count == *wc && a.sum == *ws,
            "group {} diverged: count {} vs {wc}, sum {} vs {ws} \
             (hinted {hinted}, width {}, elems {elems})",
            t.groups[i],
            a.count,
            a.sum,
            t.width
        );
    }

    // --- top-k ---------------------------------------------------------
    let tdst = vert(sys, alloc, pid, hinted, 1, elems, hint);
    let (tk, _) =
        query::top_k(sys, alloc, pid, &qty, t.k, &tdst, &mut pool).unwrap();
    let (want_t, want_sel) = reference::top_k(&t.qty, t.k, t.width);
    assert_prop!(
        tk.threshold == want_t,
        "top-k threshold {} != reference {want_t} (k {}, elems {elems}, \
         width {}, hinted {hinted})",
        tk.threshold,
        t.k,
        t.width
    );
    let got = tdst.load(sys, pid).unwrap();
    let mut selected = 0u64;
    for (i, (&g, &w)) in got.iter().zip(&want_sel).enumerate() {
        assert_prop!(
            (g == 1) == w,
            "top-k bit {i} diverged (k {}, threshold {})",
            t.k,
            tk.threshold
        );
        selected += g;
    }
    assert_prop!(
        tk.selected == selected,
        "reported selection count {} != mask popcount {selected}",
        tk.selected
    );

    for l in [Some(cust), Some(grp), Some(qty), pred, Some(dst), Some(tdst)]
        .into_iter()
        .flatten()
    {
        l.free(sys, alloc, pid).unwrap();
    }
    sys.release_scratch(alloc, pid, &mut pool).unwrap();
}

/// Sharded twin of [`check_flat`]: the same shapes over bank-sharded
/// layouts, verified against the same scalar references.
fn check_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    shards: usize,
    t: &Table,
) {
    let pid = sys.spawn();
    let elems = t.cust.len();
    let cust =
        ShardedLayout::alloc(sys, alloc, pid, t.width, elems, shards).unwrap();
    let grp = ShardedLayout::alloc_like(sys, alloc, pid, t.width, &cust).unwrap();
    let qty = ShardedLayout::alloc_like(sys, alloc, pid, t.width, &cust).unwrap();
    cust.store(sys, pid, &t.cust).unwrap();
    grp.store(sys, pid, &t.grp).unwrap();
    qty.store(sys, pid, &t.qty).unwrap();
    let mut pools = ShardedScratch::new();

    let pred = t.thr.map(|thr| {
        let m = ShardedLayout::alloc_like(sys, alloc, pid, 1, &qty).unwrap();
        sys.run_arith_const_sharded(
            alloc,
            pid,
            ArithOp::CmpLt,
            thr,
            &qty,
            &m,
            &mut pools,
        )
        .unwrap();
        m
    });
    let dst = ShardedLayout::alloc_like(sys, alloc, pid, 1, &cust).unwrap();
    query::semi_join_mask_sharded(
        sys,
        alloc,
        pid,
        &cust,
        &t.build,
        pred.as_ref(),
        &dst,
        &mut pools,
    )
    .unwrap();
    let got = dst.load(sys, pid).unwrap();
    let pred_ref: Option<Vec<bool>> =
        t.thr.map(|thr| t.qty.iter().map(|&v| v < thr).collect());
    let want = reference::semi_join(&t.cust, &t.build, pred_ref.as_deref());
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert_prop!(
            (g == 1) == w,
            "S={shards}: semi-join bit {i} diverged (width {}, elems {elems})",
            t.width
        );
    }

    let (aggs, _) = query::group_by_sum_sharded(
        sys, alloc, pid, &grp, &qty, &t.groups, &mut pools,
    )
    .unwrap();
    let want = reference::group_by(&t.grp, &t.qty, &t.groups);
    for (a, (wc, ws)) in aggs.iter().zip(&want) {
        assert_prop!(
            a.count == *wc && a.sum == *ws,
            "S={shards}: group {} diverged (count {} vs {wc}, sum {} vs {ws})",
            a.group,
            a.count,
            a.sum
        );
    }

    let tdst = ShardedLayout::alloc_like(sys, alloc, pid, 1, &qty).unwrap();
    let (tk, _) =
        query::top_k_sharded(sys, alloc, pid, &qty, t.k, &tdst, &mut pools)
            .unwrap();
    let (want_t, want_sel) = reference::top_k(&t.qty, t.k, t.width);
    assert_prop!(
        tk.threshold == want_t,
        "S={shards}: top-k threshold {} != reference {want_t} (k {})",
        tk.threshold,
        t.k
    );
    let got = tdst.load(sys, pid).unwrap();
    for (i, (&g, &w)) in got.iter().zip(&want_sel).enumerate() {
        assert_prop!((g == 1) == w, "S={shards}: top-k bit {i} diverged");
    }

    for l in [Some(cust), Some(grp), Some(qty), pred, Some(dst), Some(tdst)]
        .into_iter()
        .flatten()
    {
        l.free(sys, alloc, pid).unwrap();
    }
    sys.trim_scratch_sharded(alloc, pid, &mut pools, 0).unwrap();
}

#[test]
fn queries_match_reference_co_located() {
    proptest::check_cases("co-located queries == scalar reference", 64, |g| {
        let t = gen_table(g);
        let (mut sys, mut puma) = boot_puma();
        check_flat(&mut sys, &mut puma, true, &t);
    });
}

#[test]
fn queries_match_reference_misaligned() {
    proptest::check_cases("misaligned queries == scalar reference", 64, |g| {
        let t = gen_table(g);
        let mut sys = boot();
        let mut malloc = MallocSim::new();
        check_flat(&mut sys, &mut malloc, false, &t);
    });
}

#[test]
fn queries_match_reference_sharded() {
    proptest::check_cases("sharded queries == scalar reference", 64, |g| {
        let t = gen_table(g);
        // S may exceed elems: degenerate shard counts collapse
        let shards = g.usize(1..7);
        let (mut sys, mut puma) = boot_puma();
        check_sharded(&mut sys, &mut puma, shards, &t);
    });
}

/// Fixed regression corpus: the edge shapes the fuzzer only sometimes
/// draws, pinned so they run on every commit under every placement.
fn corpus() -> Vec<Table> {
    let base = |elems: usize, width: u32, seed: u64| -> (Vec<u64>, Vec<u64>, Vec<u64>) {
        let mut rng = Pcg64::new(seed);
        let domain = 1u64 << width;
        let mask = arith::width_mask(width);
        let cust = (0..elems).map(|_| rng.below(domain)).collect();
        let grp = (0..elems).map(|_| rng.below(domain.min(8))).collect();
        let qty = (0..elems).map(|_| rng.next_u64() & mask).collect();
        (cust, grp, qty)
    };
    let mut out = Vec::new();
    // empty build side: the semi-join mask must be all-false
    let (cust, grp, qty) = base(37, 8, 1);
    out.push(Table {
        width: 8,
        cust,
        grp,
        qty,
        build: vec![],
        groups: vec![0, 3],
        k: 5,
        thr: None,
    });
    // out-of-domain build keys only: dropped, all-false again
    let (cust, grp, qty) = base(21, 4, 2);
    out.push(Table {
        width: 4,
        cust,
        grp,
        qty,
        build: vec![16, 17, 99],
        groups: vec![7],
        k: 0, // k = 0: empty selection, threshold 2^w
        thr: Some(8),
    });
    // all rows match: the build side spans the whole 4-bit domain
    let (cust, grp, qty) = base(50, 4, 3);
    out.push(Table {
        width: 4,
        cust,
        grp,
        qty,
        build: (0..16).collect(),
        groups: (0..8).collect(),
        k: 50, // k = N: threshold 0, everything selected
        thr: None,
    });
    // k > N and a requested group absent from the data (count 0)
    let (cust, grp, qty) = base(11, 8, 4);
    out.push(Table {
        width: 8,
        cust,
        grp,
        qty,
        build: vec![0, 0, 1, 1, 2], // duplicate keys dedup
        groups: vec![200],
        k: 300,
        thr: Some(0), // thr = 0: the predicate rejects every row
    });
    // all-equal column: top-k ties select every row; one group
    // covers the whole table
    out.push(Table {
        width: 8,
        cust: vec![5; 30],
        grp: vec![2; 30],
        qty: vec![7; 30],
        build: vec![5],
        groups: vec![2],
        k: 4,
        thr: None,
    });
    // single-row probe: layouts reject zero elements, so one row is
    // the smallest probe side
    out.push(Table {
        width: 16,
        cust: vec![40_000],
        grp: vec![0],
        qty: vec![65_535],
        build: vec![40_000, 9],
        groups: vec![0, 1],
        k: 1,
        thr: Some(1),
    });
    out
}

#[test]
fn regression_corpus_co_located_flat_and_sharded() {
    for t in corpus() {
        let (mut sys, mut puma) = boot_puma();
        check_flat(&mut sys, &mut puma, true, &t);
        check_sharded(&mut sys, &mut puma, 3, &t);
    }
}

#[test]
fn regression_corpus_misaligned() {
    for t in corpus() {
        let mut sys = boot();
        let mut malloc = MallocSim::new();
        check_flat(&mut sys, &mut malloc, false, &t);
    }
}

#[test]
fn column_cache_evicts_under_budget_pressure_and_rebuilds_fresh() {
    let (mut sys, mut puma) = boot_puma();
    let pid = sys.spawn();
    sys.set_column_budget(1);
    let a: Vec<u64> = (0..100u64).map(|i| i & 0xFF).collect();
    let b: Vec<u64> = (0..100u64).map(|i| (i * 3) & 0xFF).collect();
    let ca = sys.cached_column(&mut puma, pid, 1, 7, 8, &a).unwrap();
    assert_eq!(ca.load(&mut sys, pid).unwrap(), a);
    // a second column under budget 1 evicts the first
    let cb = sys.cached_column(&mut puma, pid, 2, 7, 8, &b).unwrap();
    assert_eq!(cb.load(&mut sys, pid).unwrap(), b);
    let s = sys.column_cache_stats();
    assert!(s.evictions >= 1, "budget 1 must evict: {s:?}");
    // refetching the evicted column is a miss + rebuild, never a
    // stale-plane hit
    let miss0 = sys.column_cache_stats().resident_misses;
    let ca2 = sys.cached_column(&mut puma, pid, 1, 7, 8, &a).unwrap();
    assert_eq!(
        sys.column_cache_stats().resident_misses,
        miss0 + 1,
        "evicted column must rebuild, not hit"
    );
    assert_eq!(ca2.load(&mut sys, pid).unwrap(), a);
    sys.flush_columns(&mut puma, pid).unwrap();
}

#[test]
fn query_cells_stay_correct_with_budget_below_working_set() {
    let cfg = QueriesConfig {
        rows: 2048,
        k: 128,
        shards: 0,
        churn_rounds: 60,
        ..Default::default()
    };

    // budget 1: the semi-join cell touches two columns but uses each
    // immediately after its own fetch, so even a single-slot cache
    // (every fetch evicts and frees the previous column) stays correct
    let (mut sys, mut puma) = boot_puma();
    let pid = sys.spawn();
    sys.set_column_budget(1);
    let mut pool = ShardedScratch::new();
    let r = queries::run_cell_semi_join(
        &mut sys, &mut puma, pid, "puma", &cfg, &mut pool,
    )
    .unwrap();
    assert!(r.matches > 0);
    assert!(r.col_misses >= 2, "budget 1 cannot hold both columns");
    let s = sys.column_cache_stats();
    assert!(s.evictions >= 1, "working set 2 under budget 1 must evict: {s:?}");
    // a repeat still verifies — every fetch is a rebuild, none stale
    let r2 = queries::run_cell_semi_join(
        &mut sys, &mut puma, pid, "puma", &cfg, &mut pool,
    )
    .unwrap();
    assert_eq!(r2.matches, r.matches);
    assert_eq!(r2.agg, r.agg);
    assert!(r2.col_misses >= 1, "budget 1 cannot serve a warm repeat");
    sys.trim_pools(&mut puma, pid, &mut pool, 0).unwrap();
    sys.flush_columns(&mut puma, pid).unwrap();

    // budget 2: the full three-shape sweep needs three distinct
    // columns, so evictions churn between cells while each cell's own
    // <= 2-column working set still fits — every inline oracle passes
    let (mut sys, mut puma) = boot_puma();
    let pid = sys.spawn();
    sys.set_column_budget(2);
    let mut pool = ShardedScratch::new();
    let a = queries::run_cell_semi_join(
        &mut sys, &mut puma, pid, "puma", &cfg, &mut pool,
    )
    .unwrap();
    let b = queries::run_cell_group_by(
        &mut sys, &mut puma, pid, "puma", &cfg, &mut pool,
    )
    .unwrap();
    let c = queries::run_cell_top_k(
        &mut sys, &mut puma, pid, "puma", &cfg, &mut pool,
    )
    .unwrap();
    assert!(a.matches > 0 && b.matches > 0 && c.matches > 0);
    let s = sys.column_cache_stats();
    assert!(s.evictions >= 1, "3 columns under budget 2 must evict: {s:?}");
    sys.trim_pools(&mut puma, pid, &mut pool, 0).unwrap();
    sys.flush_columns(&mut puma, pid).unwrap();
}

#[test]
fn warm_query_sweep_compiles_nothing() {
    // satellite: after one cold sweep, a full re-sweep must be served
    // entirely from the program cache — zero fresh kernel compiles,
    // observed both per-cell and via System::program_cache_stats()
    let (mut sys, mut puma) = boot_puma();
    let pid = sys.spawn();
    let cfg = QueriesConfig {
        rows: 4096,
        k: 256,
        shards: 0,
        churn_rounds: 60,
        ..Default::default()
    };
    let mut pool = ShardedScratch::new();
    let cold = [
        queries::run_cell_semi_join(&mut sys, &mut puma, pid, "puma", &cfg, &mut pool)
            .unwrap(),
        queries::run_cell_group_by(&mut sys, &mut puma, pid, "puma", &cfg, &mut pool)
            .unwrap(),
        queries::run_cell_top_k(&mut sys, &mut puma, pid, "puma", &cfg, &mut pool)
            .unwrap(),
    ];
    assert!(
        cold.iter().map(|r| r.compiles).sum::<usize>() >= 1,
        "the cold sweep must compile something"
    );
    let warm0 = sys.program_cache_stats();
    let warm = [
        queries::run_cell_semi_join(&mut sys, &mut puma, pid, "puma", &cfg, &mut pool)
            .unwrap(),
        queries::run_cell_group_by(&mut sys, &mut puma, pid, "puma", &cfg, &mut pool)
            .unwrap(),
        queries::run_cell_top_k(&mut sys, &mut puma, pid, "puma", &cfg, &mut pool)
            .unwrap(),
    ];
    for (r, c) in warm.iter().zip(&cold) {
        assert_eq!(r.compiles, 0, "{}: warm cell compiled", r.shape);
        assert_eq!(r.agg, c.agg, "{}: warm result diverged", r.shape);
        assert_eq!(r.matches, c.matches);
    }
    let warm1 = sys.program_cache_stats();
    assert_eq!(
        warm1.misses, warm0.misses,
        "a warm sweep must not insert fresh programs"
    );
    assert!(warm1.hits > warm0.hits, "warm kernels must be cache hits");
    sys.trim_pools(&mut puma, pid, &mut pool, 0).unwrap();
    sys.flush_columns(&mut puma, pid).unwrap();
}
