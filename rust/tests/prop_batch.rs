//! Property: `submit_batch` over N mixed (PUD + fallback) requests is
//! equivalent to N serial `submit` calls — byte-identical DRAM
//! contents, identical per-op simulated times, identical `CoordStats`
//! totals — including partial-tail rows, operand aliasing, and
//! dependent chains. Also: the extent-translation cache must never
//! serve a mapping that an allocator has torn down.

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::assert_prop;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::os::process::Pid;
use puma::proptest::{self, Gen};
use puma::pud::isa::{BulkRequest, PudOp};

fn boot() -> System {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    System::boot(SystemConfig {
        scheme,
        huge_pages: 12,
        churn_rounds: 800,
        seed: 0xBA7C4,
        artifacts: None,
        ..Default::default()
    })
    .unwrap()
}

/// Pure description of one generated scenario, applied identically to
/// two freshly booted systems.
#[derive(Debug, Clone)]
struct BufSpec {
    rows: u64,
    tail: u64,
    on_pud: bool,
    hinted: bool,
}

#[derive(Debug, Clone)]
struct OpSpec {
    op: PudOp,
    dst: usize,
    srcs: Vec<usize>,
    len: u64,
}

fn gen_scenario(g: &mut Gen) -> (Vec<BufSpec>, Vec<OpSpec>) {
    let nbufs = g.usize(2..6);
    let bufs: Vec<BufSpec> = (0..nbufs)
        .map(|_| BufSpec {
            rows: g.u64(1..5),
            tail: if g.bool() { g.u64(1..8192) } else { 0 },
            on_pud: g.bool(),
            hinted: g.bool(),
        })
        .collect();
    let buf_len = |b: &BufSpec| b.rows * 8192 + b.tail;
    let nops = g.usize(1..7);
    let ops = (0..nops)
        .map(|_| {
            let op = *g.choose(&PudOp::ALL);
            let dst = g.usize(0..nbufs);
            let srcs: Vec<usize> =
                (0..op.arity()).map(|_| g.usize(0..nbufs)).collect();
            let max_len = srcs
                .iter()
                .chain(std::iter::once(&dst))
                .map(|&i| buf_len(&bufs[i]))
                .min()
                .unwrap();
            // sometimes the full common length (exercising partial
            // tails from `tail`), sometimes an arbitrary prefix
            let len = if g.bool() { max_len } else { g.u64(1..max_len + 1) };
            OpSpec { op, dst, srcs, len }
        })
        .collect();
    (bufs, ops)
}

/// Materialize the scenario on `sys`: allocate + seed buffers, build
/// requests. Fully deterministic, so two identically booted systems
/// end up with identical layouts and contents.
fn materialize(
    sys: &mut System,
    bufs: &[BufSpec],
    ops: &[OpSpec],
) -> (Pid, Vec<(u64, u64)>, Vec<BulkRequest>) {
    let pid = sys.spawn();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 8).unwrap();
    let mut malloc = MallocSim::new();
    let mut vas: Vec<(u64, u64)> = Vec::with_capacity(bufs.len());
    let mut first_pud: Option<u64> = None;
    for (i, b) in bufs.iter().enumerate() {
        let len = b.rows * row + b.tail;
        let va = if b.on_pud {
            let va = match first_pud {
                Some(hint) if b.hinted => {
                    sys.alloc_align(&mut puma, pid, len, hint).unwrap()
                }
                _ => sys.alloc(&mut puma, pid, len).unwrap(),
            };
            first_pud.get_or_insert(va);
            va
        } else {
            sys.alloc(&mut malloc, pid, len).unwrap()
        };
        let data: Vec<u8> =
            (0..len).map(|j| ((i as u64 * 131 + j) % 251) as u8).collect();
        sys.write_virt(pid, va, &data).unwrap();
        vas.push((va, len));
    }
    let reqs = ops
        .iter()
        .map(|o| {
            BulkRequest::new(
                o.op,
                vas[o.dst].0,
                o.srcs.iter().map(|&i| vas[i].0).collect(),
                o.len,
            )
        })
        .collect();
    (pid, vas, reqs)
}

#[test]
fn batch_equals_serial_property() {
    proptest::check_cases("submit_batch == N x submit", 16, |g| {
        let (bufs, ops) = gen_scenario(g);

        let mut s1 = boot();
        let (pid1, vas1, reqs1) = materialize(&mut s1, &bufs, &ops);
        let mut serial_ns = Vec::with_capacity(reqs1.len());
        for r in &reqs1 {
            serial_ns.push(s1.submit(pid1, r).unwrap());
        }

        let mut s2 = boot();
        let (pid2, vas2, reqs2) = materialize(&mut s2, &bufs, &ops);
        assert_prop!(vas1 == vas2, "layouts diverged: {vas1:?} vs {vas2:?}");
        let report = s2.submit_batch(pid2, &reqs2).unwrap();

        // identical per-op simulated times
        assert_prop!(
            report.per_op_ns == serial_ns,
            "per-op ns diverged: {:?} vs {serial_ns:?}",
            report.per_op_ns
        );
        // identical stats totals
        assert_prop!(
            s1.coord.stats == s2.coord.stats,
            "stats diverged:\n{:?}\nvs\n{:?}",
            s1.coord.stats,
            s2.coord.stats
        );
        // byte-identical memory images across every buffer
        for (i, &(va, len)) in vas1.iter().enumerate() {
            let m1 = s1.read_virt(pid1, va, len).unwrap();
            let m2 = s2.read_virt(pid2, va, len).unwrap();
            assert_prop!(m1 == m2, "buffer {i} image diverged");
        }
        // elapsed may only shrink relative to the serial sum
        let total: f64 = serial_ns.iter().sum();
        assert_prop!(
            report.elapsed_ns <= total + 1e-6,
            "elapsed {} > serial {total}",
            report.elapsed_ns
        );
    });
}

#[test]
fn batched_partial_tail_matches_serial() {
    // deterministic regression for the partial-tail case: len is not
    // a row multiple, so the final row of every operand is short
    let mut s1 = boot();
    let mut s2 = boot();
    let row = s1.os.scheme.geometry.row_bytes as u64;
    let len = 3 * row + 1000;
    let setup = |sys: &mut System| {
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 6).unwrap();
        let a = sys.alloc(&mut puma, pid, len).unwrap();
        let b = sys.alloc_align(&mut puma, pid, len, a).unwrap();
        let c = sys.alloc_align(&mut puma, pid, len, a).unwrap();
        let data: Vec<u8> = (0..len).map(|i| (i % 241) as u8).collect();
        sys.write_virt(pid, a, &data).unwrap();
        sys.write_virt(pid, b, &data).unwrap();
        (pid, a, b, c)
    };
    let (p1, a1, b1, c1) = setup(&mut s1);
    let (p2, a2, b2, c2) = setup(&mut s2);
    assert_eq!((a1, b1, c1), (a2, b2, c2));
    let reqs = vec![
        BulkRequest::new(PudOp::Xor, c1, vec![a1, b1], len),
        BulkRequest::new(PudOp::Not, b1, vec![a1], len),
    ];
    for r in &reqs {
        s1.submit(p1, r).unwrap();
    }
    s2.submit_batch(p2, &reqs).unwrap();
    assert_eq!(s1.coord.stats, s2.coord.stats);
    assert_eq!(
        s1.read_virt(p1, c1, len).unwrap(),
        s2.read_virt(p2, c2, len).unwrap()
    );
    assert_eq!(
        s1.read_virt(p1, b1, len).unwrap(),
        s2.read_virt(p2, b2, len).unwrap()
    );
    // xor of identical inputs is zero; not(a) flips the pattern
    assert_eq!(s1.read_virt(p1, c1, len).unwrap(), vec![0u8; len as usize]);
}

#[test]
fn extent_cache_never_serves_freed_mappings() {
    let mut sys = boot();
    let pid = sys.spawn();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let len = 2 * row;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 6).unwrap();
    let a = sys.alloc(&mut puma, pid, len).unwrap();
    let b = sys.alloc_align(&mut puma, pid, len, a).unwrap();
    sys.write_virt(pid, a, &vec![0x5Au8; len as usize]).unwrap();
    let req = BulkRequest::new(PudOp::Copy, b, vec![a], len);
    sys.submit(pid, &req).unwrap(); // warms the cache for a and b
    sys.submit(pid, &req).unwrap(); // served from cache
    assert!(sys.coord.pipeline.extent_cache.hits >= 2);
    // tear down the source: a stale cache would happily keep copying
    sys.free(&mut puma, pid, a).unwrap();
    assert!(
        sys.submit(pid, &req).is_err(),
        "freed operand must fail, not be served from the extent cache"
    );
    // remap and resubmit: fresh translation, correct data
    let a2 = sys.alloc(&mut puma, pid, len).unwrap();
    sys.write_virt(pid, a2, &vec![0xC3u8; len as usize]).unwrap();
    let req2 = BulkRequest::new(PudOp::Copy, b, vec![a2], len);
    sys.submit(pid, &req2).unwrap();
    assert_eq!(
        sys.read_virt(pid, b, len).unwrap(),
        vec![0xC3u8; len as usize]
    );
}
