//! Properties of the vertical-arithmetic layer: the transpose path
//! round-trips, and compiled bit-serial kernels are value-identical to
//! scalar reference arithmetic — under co-located (PUMA) placement
//! that runs in-DRAM and under deliberately misaligned (malloc)
//! placement that exercises the CPU fallback.

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::scratch::ScratchPool;
use puma::alloc::traits::Allocator;
use puma::assert_prop;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::proptest;
use puma::pud::arith::{self, ArithOp, VerticalLayout};
use puma::util::rng::Pcg64;

fn boot() -> System {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    System::boot(SystemConfig {
        scheme,
        huge_pages: 12,
        churn_rounds: 800,
        seed: 0xA217,
        artifacts: None,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn transpose_roundtrip_property() {
    proptest::check_cases("vertical transpose roundtrips", 64, |g| {
        let elems = g.usize(1..2000);
        let width = g.usize(1..17) as u32;
        let seed = g.u64(1..u64::MAX);
        let mut rng = Pcg64::new(seed);
        let mask = arith::width_mask(width);
        let values: Vec<u64> =
            (0..elems).map(|_| rng.next_u64() & mask).collect();
        let planes = arith::transpose(&values, width);
        assert_prop!(planes.len() == width as usize, "one plane per bit");
        for p in &planes {
            assert_prop!(
                p.len() == elems.div_ceil(8),
                "plane length is ceil(elems/8)"
            );
        }
        let back = arith::untranspose(&planes, elems);
        assert_prop!(back == values, "transpose/untranspose must round-trip");
    });
}

/// Run every kernel over one operand pair with `alloc`, verifying the
/// loaded results element-by-element against `arith::reference`.
/// Returns the worst (lowest) PUD-row fraction seen across kernels.
fn run_kernels(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    hinted: bool,
    width: u32,
    elems: usize,
    seed: u64,
) -> f64 {
    let pid = sys.spawn();
    let mask = arith::width_mask(width);
    let mut rng = Pcg64::new(seed);
    let va: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
    let vb: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
    let a = VerticalLayout::alloc(sys, alloc, pid, width, elems).unwrap();
    let b = if hinted {
        VerticalLayout::alloc_with_hint(sys, alloc, pid, width, elems, a.hint())
            .unwrap()
    } else {
        VerticalLayout::alloc(sys, alloc, pid, width, elems).unwrap()
    };
    a.store(sys, pid, &va).unwrap();
    b.store(sys, pid, &vb).unwrap();
    let mut pool = ScratchPool::new();
    let mut worst = 1.0f64;
    for op in ArithOp::ALL {
        let out_w = op.out_width(width);
        let dst = if hinted {
            VerticalLayout::alloc_with_hint(sys, alloc, pid, out_w, elems, a.hint())
                .unwrap()
        } else {
            VerticalLayout::alloc(sys, alloc, pid, out_w, elems).unwrap()
        };
        let rhs = if op.is_binary() { Some(&b) } else { None };
        let rep = sys.run_arith(alloc, pid, op, &a, rhs, &dst, &mut pool).unwrap();
        worst = worst.min(rep.pud_row_fraction());
        let got = dst.load(sys, pid).unwrap();
        for i in 0..elems {
            let want = arith::reference(op, width, va[i], vb[i]);
            assert_prop!(
                got[i] == want,
                "{}({:#x}, {:#x}) = {:#x}, want {:#x} (width {width}, \
                 hinted {hinted})",
                op.name(),
                va[i],
                vb[i],
                got[i],
                want
            );
        }
        dst.free(sys, alloc, pid).unwrap();
    }
    // filter-then-sum: mask = (a < b), sum of a under the mask
    let mask_l = if hinted {
        VerticalLayout::alloc_with_hint(sys, alloc, pid, 1, elems, a.hint())
            .unwrap()
    } else {
        VerticalLayout::alloc(sys, alloc, pid, 1, elems).unwrap()
    };
    sys.run_arith(alloc, pid, ArithOp::CmpLt, &a, Some(&b), &mask_l, &mut pool)
        .unwrap();
    let (sum, rep) = sys
        .arith_sum(alloc, pid, &a, Some(mask_l.planes()[0]), &mut pool)
        .unwrap();
    let want: u128 = va
        .iter()
        .zip(&vb)
        .filter(|(x, y)| x < y)
        .map(|(x, _)| *x as u128)
        .sum();
    assert_prop!(
        sum == want,
        "masked sum {sum} != reference {want} (width {width}, hinted {hinted})"
    );
    worst = worst.min(rep.expect("masked sum batches").pud_row_fraction());
    worst
}

#[test]
fn compiled_kernels_match_reference_property() {
    proptest::check_cases("arith kernels == scalar reference", 3, |g| {
        let width = *g.choose(&[4u32, 8, 16]);
        let seed = g.u64(1..u64::MAX);
        // one full DRAM row per plane keeps the co-located run measurable
        let elems = 64 * 1024;

        let mut sys = boot();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 8).unwrap();
        let pud = run_kernels(&mut sys, &mut puma, true, width, elems, seed);
        assert_prop!(
            pud > 0.9,
            "hint-aligned planes must run in-DRAM (worst {pud}, width {width})"
        );

        let mut sys2 = boot();
        let mut malloc = MallocSim::new();
        let pud2 = run_kernels(&mut sys2, &mut malloc, false, width, elems, seed);
        assert_prop!(
            pud2 < 0.5 && pud2 < pud,
            "malloc planes should mostly fall back (worst {pud2})"
        );
    });
}

#[test]
fn ragged_columns_stay_correct() {
    // elems not a multiple of 8 -> padded final byte; not a multiple of
    // a row -> partial-row requests. Correctness must survive both.
    let mut sys = boot();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 8).unwrap();
    run_kernels(&mut sys, &mut puma, true, 5, 1003, 0x7A66);
}
