//! Properties of the vertical-arithmetic layer: the transpose path
//! round-trips, and compiled bit-serial kernels are value-identical to
//! scalar reference arithmetic — under co-located (PUMA) placement
//! that runs in-DRAM and under deliberately misaligned (malloc)
//! placement that exercises the CPU fallback.

// These properties pin the deprecated flat/sharded shims on purpose:
// they must keep producing bit-identical results until removal
// (tests/prop_serve.rs checks shim == unified-API equivalence).
#![allow(deprecated)]

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::scratch::ScratchPool;
use puma::alloc::traits::Allocator;
use puma::assert_prop;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::proptest;
use puma::pud::arith::{
    self, ArithOp, ShardedLayout, ShardedScratch, VerticalLayout,
};
use puma::util::rng::Pcg64;

fn boot() -> System {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    System::boot(SystemConfig {
        scheme,
        huge_pages: 12,
        churn_rounds: 800,
        seed: 0xA217,
        artifacts: None,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn transpose_roundtrip_property() {
    proptest::check_cases("vertical transpose roundtrips", 64, |g| {
        let elems = g.usize(1..2000);
        let width = g.usize(1..17) as u32;
        let seed = g.u64(1..u64::MAX);
        let mut rng = Pcg64::new(seed);
        let mask = arith::width_mask(width);
        let values: Vec<u64> =
            (0..elems).map(|_| rng.next_u64() & mask).collect();
        let planes = arith::transpose(&values, width);
        assert_prop!(planes.len() == width as usize, "one plane per bit");
        for p in &planes {
            assert_prop!(
                p.len() == elems.div_ceil(8),
                "plane length is ceil(elems/8)"
            );
        }
        let back = arith::untranspose(&planes, elems).unwrap();
        assert_prop!(back == values, "transpose/untranspose must round-trip");
    });
}

#[test]
fn blocked_transpose_matches_naive_oracle_property() {
    // the word-level blocked transpose must be byte-identical to the
    // bit-at-a-time oracle across every width the layout layer admits
    // (1..=64, past the kernel cap) and ragged lengths: elems % 64 != 0
    // exercises partial octets, elems < 8 a single padded byte
    proptest::check_cases("blocked transpose == naive oracle", 128, |g| {
        let elems = if g.ratio(1, 4) {
            g.usize(1..8)
        } else {
            g.usize(1..3000)
        };
        let width = g.usize(1..65) as u32;
        let seed = g.u64(1..u64::MAX);
        let mut rng = Pcg64::new(seed);
        let mask = arith::width_mask(width);
        let values: Vec<u64> =
            (0..elems).map(|_| rng.next_u64() & mask).collect();

        let blocked = arith::transpose(&values, width);
        let naive = arith::transpose_naive(&values, width);
        assert_prop!(
            blocked == naive,
            "blocked transpose diverged (width {width}, elems {elems})"
        );

        let back = arith::untranspose(&blocked, elems).unwrap();
        let back_naive = arith::untranspose_naive(&blocked, elems);
        assert_prop!(
            back == back_naive,
            "blocked untranspose diverged (width {width}, elems {elems})"
        );
        assert_prop!(back == values, "blocked round-trip must be lossless");
    });
}

#[test]
fn untranspose_rejects_short_planes_property() {
    // satellite regression: a plane shorter than ceil(elems/8) used to
    // panic out-of-bounds; it must be a clean error at any position
    proptest::check_cases("short planes are a clean error", 64, |g| {
        let elems = g.usize(9..2000);
        let width = g.usize(1..33) as u32;
        let seed = g.u64(1..u64::MAX);
        let mut rng = Pcg64::new(seed);
        let mask = arith::width_mask(width);
        let values: Vec<u64> =
            (0..elems).map(|_| rng.next_u64() & mask).collect();
        let mut planes = arith::transpose(&values, width);
        let victim = g.usize(0..planes.len());
        let cut = g.usize(0..planes[victim].len());
        planes[victim].truncate(cut);
        assert_prop!(
            arith::untranspose(&planes, elems).is_err(),
            "a truncated plane (plane {victim} cut to {cut}) must error"
        );
    });
}

/// Run every kernel over one operand pair with `alloc`, verifying the
/// loaded results element-by-element against `arith::reference`.
/// Returns the worst (lowest) PUD-row fraction seen across kernels.
fn run_kernels(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    hinted: bool,
    width: u32,
    elems: usize,
    seed: u64,
) -> f64 {
    let pid = sys.spawn();
    let mask = arith::width_mask(width);
    let mut rng = Pcg64::new(seed);
    let va: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
    let vb: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
    let a = VerticalLayout::alloc(sys, alloc, pid, width, elems).unwrap();
    let b = if hinted {
        VerticalLayout::alloc_with_hint(sys, alloc, pid, width, elems, a.hint())
            .unwrap()
    } else {
        VerticalLayout::alloc(sys, alloc, pid, width, elems).unwrap()
    };
    a.store(sys, pid, &va).unwrap();
    b.store(sys, pid, &vb).unwrap();
    let mut pool = ScratchPool::new();
    let mut worst = 1.0f64;
    for op in ArithOp::ALL {
        let out_w = op.out_width(width);
        let dst = if hinted {
            VerticalLayout::alloc_with_hint(sys, alloc, pid, out_w, elems, a.hint())
                .unwrap()
        } else {
            VerticalLayout::alloc(sys, alloc, pid, out_w, elems).unwrap()
        };
        let rhs = if op.is_binary() { Some(&b) } else { None };
        let rep = sys.run_arith(alloc, pid, op, &a, rhs, &dst, &mut pool).unwrap();
        worst = worst.min(rep.pud_row_fraction());
        let got = dst.load(sys, pid).unwrap();
        for i in 0..elems {
            let want = arith::reference(op, width, va[i], vb[i]);
            assert_prop!(
                got[i] == want,
                "{}({:#x}, {:#x}) = {:#x}, want {:#x} (width {width}, \
                 hinted {hinted})",
                op.name(),
                va[i],
                vb[i],
                got[i],
                want
            );
        }
        dst.free(sys, alloc, pid).unwrap();
    }
    // filter-then-sum: mask = (a < b), sum of a under the mask
    let mask_l = if hinted {
        VerticalLayout::alloc_with_hint(sys, alloc, pid, 1, elems, a.hint())
            .unwrap()
    } else {
        VerticalLayout::alloc(sys, alloc, pid, 1, elems).unwrap()
    };
    sys.run_arith(alloc, pid, ArithOp::CmpLt, &a, Some(&b), &mask_l, &mut pool)
        .unwrap();
    let (sum, rep) = sys
        .arith_sum(alloc, pid, &a, Some(mask_l.planes()[0]), &mut pool)
        .unwrap();
    let want: u128 = va
        .iter()
        .zip(&vb)
        .filter(|(x, y)| x < y)
        .map(|(x, _)| *x as u128)
        .sum();
    assert_prop!(
        sum == want,
        "masked sum {sum} != reference {want} (width {width}, hinted {hinted})"
    );
    worst = worst.min(rep.expect("masked sum batches").pud_row_fraction());
    worst
}

#[test]
fn compiled_kernels_match_reference_property() {
    proptest::check_cases("arith kernels == scalar reference", 3, |g| {
        let width = *g.choose(&[4u32, 8, 16]);
        let seed = g.u64(1..u64::MAX);
        // one full DRAM row per plane keeps the co-located run measurable
        let elems = 64 * 1024;

        let mut sys = boot();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 8).unwrap();
        let pud = run_kernels(&mut sys, &mut puma, true, width, elems, seed);
        assert_prop!(
            pud > 0.9,
            "hint-aligned planes must run in-DRAM (worst {pud}, width {width})"
        );

        let mut sys2 = boot();
        let mut malloc = MallocSim::new();
        let pud2 = run_kernels(&mut sys2, &mut malloc, false, width, elems, seed);
        assert_prop!(
            pud2 < 0.5 && pud2 < pud,
            "malloc planes should mostly fall back (worst {pud2})"
        );
    });
}

/// Run `op` over `(va, vb)` both unsharded and sharded with `alloc`,
/// asserting the sharded result is byte-identical to the unsharded
/// one and to the scalar reference, and that the sharded masked sum
/// matches the unsharded masked sum. Returns the sharded kernel's
/// PUD-row fraction.
#[allow(clippy::too_many_arguments)]
fn check_sharded_matches_unsharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    hinted: bool,
    op: ArithOp,
    width: u32,
    shards: usize,
    va: &[u64],
    vb: &[u64],
) -> f64 {
    let pid = sys.spawn();
    let elems = va.len();
    let out_w = op.out_width(width);

    // unsharded reference execution
    let a = VerticalLayout::alloc(sys, alloc, pid, width, elems).unwrap();
    let b = if hinted {
        VerticalLayout::alloc_with_hint(sys, alloc, pid, width, elems, a.hint())
            .unwrap()
    } else {
        VerticalLayout::alloc(sys, alloc, pid, width, elems).unwrap()
    };
    let dst = VerticalLayout::alloc(sys, alloc, pid, out_w, elems).unwrap();
    a.store(sys, pid, va).unwrap();
    b.store(sys, pid, vb).unwrap();
    let mut pool = ScratchPool::new();
    sys.run_arith(alloc, pid, op, &a, Some(&b), &dst, &mut pool).unwrap();
    let want = dst.load(sys, pid).unwrap();
    let mask_u =
        VerticalLayout::alloc(sys, alloc, pid, 1, elems).unwrap();
    sys.run_arith(alloc, pid, ArithOp::CmpLt, &a, Some(&b), &mask_u, &mut pool)
        .unwrap();
    let (sum_u, _) = sys
        .arith_sum(alloc, pid, &a, Some(mask_u.planes()[0]), &mut pool)
        .unwrap();

    // sharded execution of the same kernels over the same data
    let sa = ShardedLayout::alloc(sys, alloc, pid, width, elems, shards).unwrap();
    let sb = ShardedLayout::alloc_like(sys, alloc, pid, width, &sa).unwrap();
    let sdst = ShardedLayout::alloc_like(sys, alloc, pid, out_w, &sa).unwrap();
    sa.store(sys, pid, va).unwrap();
    sb.store(sys, pid, vb).unwrap();
    let mut pools = ShardedScratch::new();
    let rep = sys
        .run_arith_sharded(alloc, pid, op, &sa, Some(&sb), &sdst, &mut pools)
        .unwrap();
    let got = sdst.load(sys, pid).unwrap();
    assert_prop!(
        got == want,
        "{}: sharded (S={shards}, {} actual) diverged from unsharded \
         (width {width}, elems {elems}, hinted {hinted})",
        op.name(),
        sa.n_shards()
    );
    for (i, &g) in got.iter().enumerate() {
        let r = arith::reference(op, width, va[i], vb[i]);
        assert_prop!(
            g == r,
            "{}({:#x}, {:#x}) = {g:#x}, reference {r:#x}",
            op.name(),
            va[i],
            vb[i]
        );
    }
    let mask_s = ShardedLayout::alloc_like(sys, alloc, pid, 1, &sa).unwrap();
    sys.run_arith_sharded(
        alloc,
        pid,
        ArithOp::CmpLt,
        &sa,
        Some(&sb),
        &mask_s,
        &mut pools,
    )
    .unwrap();
    let (sum_s, _) = sys
        .arith_sum_sharded(alloc, pid, &sa, Some(&mask_s), &mut pools)
        .unwrap();
    assert_prop!(
        sum_s == sum_u,
        "masked sum diverged: sharded {sum_s} vs unsharded {sum_u} \
         (S={shards}, width {width}, elems {elems}, hinted {hinted})"
    );
    rep.pud_row_fraction()
}

#[test]
fn sharded_execution_matches_unsharded_property() {
    proptest::check_cases("sharded == unsharded (byte-identical)", 4, |g| {
        let width = *g.choose(&[4u32, 8, 16]);
        // occasionally degenerate columns so S > elems is exercised;
        // non-multiple sizes give a ragged last shard
        let elems = if g.ratio(1, 4) {
            g.usize(1..8)
        } else {
            g.usize(50..5000)
        };
        let shards = g.usize(1..10);
        let op = *g.choose(&[
            ArithOp::Add,
            ArithOp::Sub,
            ArithOp::Min,
            ArithOp::CmpEq,
        ]);
        let seed = g.u64(1..u64::MAX);
        let mask = arith::width_mask(width);
        let mut rng = Pcg64::new(seed);
        let va: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
        let vb: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();

        // co-located (PUMA placement-spread) shards run in-DRAM
        let mut sys = boot();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 8).unwrap();
        let pud = check_sharded_matches_unsharded(
            &mut sys, &mut puma, true, op, width, shards, &va, &vb,
        );
        assert_prop!(
            pud > 0.9,
            "spread shards must stay in-DRAM (got {pud}, S={shards})"
        );

        // deliberately misaligned placement stays value-identical
        let mut sys2 = boot();
        let mut malloc = MallocSim::new();
        check_sharded_matches_unsharded(
            &mut sys2, &mut malloc, false, op, width, shards, &va, &vb,
        );
    });
}

#[test]
fn ragged_columns_stay_correct() {
    // elems not a multiple of 8 -> padded final byte; not a multiple of
    // a row -> partial-row requests. Correctness must survive both.
    let mut sys = boot();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 8).unwrap();
    run_kernels(&mut sys, &mut puma, true, 5, 1003, 0x7A66);
}
