//! Integration: OS substrate — buddy + page tables + hugetlb pool
//! working together at realistic scale.

use puma::os::buddy::BuddyAllocator;
use puma::os::hugepage::HugePagePool;
use puma::os::page_table::{PageKind, PageTable};
use puma::os::process::{Pid, Process};
use puma::os::vma::VmaKind;
use puma::os::{HUGE_PAGE_SIZE, PAGE_SIZE};
use puma::util::rng::Pcg64;

#[test]
fn boot_8gib_machine_reserve_pool_and_churn() {
    let mut buddy = BuddyAllocator::with_capacity_bytes(8 << 30).unwrap();
    let pool = HugePagePool::reserve(&mut buddy, 256).unwrap();
    assert_eq!(pool.available(), 256);
    let mut rng = Pcg64::new(1);
    buddy.churn(&mut rng, 20_000);
    buddy.check_invariants().unwrap();
    // the machine still has most of its memory
    assert!(buddy.free_frames() > (6u64 << 30) / PAGE_SIZE);
}

#[test]
fn process_with_mixed_page_sizes() {
    let mut buddy = BuddyAllocator::with_capacity_bytes(64 << 20).unwrap();
    let mut proc = Process::new(Pid(1));
    // a base-page VMA
    let va1 = proc.mmap(16 * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon).unwrap();
    proc.populate_base(va1, 16, || buddy.alloc(0)).unwrap();
    // a huge-page VMA
    let va2 = proc
        .mmap(2 * HUGE_PAGE_SIZE, HUGE_PAGE_SIZE, VmaKind::Huge)
        .unwrap();
    for i in 0..2 {
        let pfn = buddy.alloc(puma::os::HUGE_PAGE_ORDER).unwrap();
        proc.map_huge(va2 + i * HUGE_PAGE_SIZE, pfn * PAGE_SIZE)
            .unwrap();
    }
    // extents resolve across both mapping kinds
    assert_eq!(
        proc.phys_extents(va1, 16 * PAGE_SIZE)
            .unwrap()
            .iter()
            .map(|e| e.len)
            .sum::<u64>(),
        16 * PAGE_SIZE
    );
    let he = proc.phys_extents(va2, 2 * HUGE_PAGE_SIZE).unwrap();
    assert!(he.len() <= 2);
    // unmap the base pages; frames return to the buddy
    let before = buddy.free_frames();
    for i in 0..16 {
        let t = proc.page_table.unmap(va1 + i * PAGE_SIZE).unwrap();
        buddy.free(t.paddr / PAGE_SIZE, 0);
    }
    assert_eq!(buddy.free_frames(), before + 16);
    buddy.check_invariants().unwrap();
}

#[test]
fn page_table_dense_random_mappings() {
    let mut pt = PageTable::new();
    let mut rng = Pcg64::new(3);
    let mut mapped = std::collections::HashMap::new();
    for _ in 0..2_000 {
        let vpn = rng.below(1 << 22); // within Sv39, base pages
        let va = vpn * PAGE_SIZE;
        let pa = rng.below(1 << 20) * PAGE_SIZE;
        if mapped.contains_key(&va) {
            continue;
        }
        pt.map(va, pa, PageKind::Base).unwrap();
        mapped.insert(va, pa);
    }
    for (va, pa) in &mapped {
        let t = pt.translate(*va + 17).unwrap();
        assert_eq!(t.paddr, *pa + 17);
    }
    assert_eq!(pt.mapped_base_pages as usize, mapped.len());
}

#[test]
fn hugetlb_reservation_under_fragmentation_can_fail() {
    // after enough churn-pinned fragmentation, reserving many huge
    // pages becomes impossible — the reason Linux (and PUMA's
    // pre-allocation) reserve at boot
    let mut buddy = BuddyAllocator::with_capacity_bytes(16 << 20).unwrap();
    let mut rng = Pcg64::new(4);
    buddy.churn(&mut rng, 10_000);
    let want = (buddy.nframes() / 512) as usize; // all-of-memory worth
    assert!(HugePagePool::reserve(&mut buddy, want).is_err());
}
