//! Integration: coordinator dispatch over the assembled System —
//! full allocator -> legality -> execute -> verify loops.

use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::coordinator::system::{System, SystemConfig};
use puma::pud::isa::{BulkRequest, PudOp};
use puma::util::rng::Pcg64;

fn boot() -> System {
    System::boot(SystemConfig {
        huge_pages: 64,
        churn_rounds: 8_000,
        seed: 0xC0,
        artifacts: None,
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn op_chain_through_coordinator() {
    // d = (a AND b) XOR (NOT b): a chain of dependent bulk ops, all
    // in-DRAM under PUMA placement, verified against the host oracle.
    let mut sys = boot();
    let pid = sys.spawn();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let len = 32 * row;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 16).unwrap();
    let a = sys.alloc(&mut puma, pid, len).unwrap();
    let b = sys.alloc_align(&mut puma, pid, len, a).unwrap();
    let t = sys.alloc_align(&mut puma, pid, len, a).unwrap();
    let u = sys.alloc_align(&mut puma, pid, len, a).unwrap();
    let d = sys.alloc_align(&mut puma, pid, len, a).unwrap();
    let mut rng = Pcg64::new(0xAB);
    let mut va = vec![0u8; len as usize];
    let mut vb = vec![0u8; len as usize];
    rng.fill_bytes(&mut va);
    rng.fill_bytes(&mut vb);
    sys.write_virt(pid, a, &va).unwrap();
    sys.write_virt(pid, b, &vb).unwrap();

    sys.submit(pid, &BulkRequest::new(PudOp::And, t, vec![a, b], len))
        .unwrap();
    sys.submit(pid, &BulkRequest::new(PudOp::Not, u, vec![b], len))
        .unwrap();
    sys.submit(pid, &BulkRequest::new(PudOp::Xor, d, vec![t, u], len))
        .unwrap();

    let want: Vec<u8> = va
        .iter()
        .zip(&vb)
        .map(|(x, y)| (x & y) ^ !y)
        .collect();
    assert_eq!(sys.read_virt(pid, d, len).unwrap(), want);
    assert!(sys.coord.stats.pud_row_fraction() > 0.99);
    assert_eq!(sys.coord.stats.ops, 3);
}

#[test]
fn mixed_allocators_mixed_paths_one_system() {
    let mut sys = boot();
    let pid = sys.spawn();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let len = 16 * row;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 8).unwrap();
    let mut malloc = MallocSim::new();

    // PUMA-placed op
    let a = sys.alloc(&mut puma, pid, len).unwrap();
    let b = sys.alloc_align(&mut puma, pid, len, a).unwrap();
    sys.write_virt(pid, a, &vec![0x55u8; len as usize]).unwrap();
    sys.submit(pid, &BulkRequest::new(PudOp::Copy, b, vec![a], len))
        .unwrap();
    let pud_after_first = sys.coord.stats.pud_rows;
    assert_eq!(pud_after_first, 16);

    // malloc-placed op on the same system falls back
    let c = sys.alloc(&mut malloc, pid, len).unwrap();
    let d = sys.alloc(&mut malloc, pid, len).unwrap();
    sys.write_virt(pid, c, &vec![0x77u8; len as usize]).unwrap();
    sys.submit(pid, &BulkRequest::new(PudOp::Copy, d, vec![c], len))
        .unwrap();
    assert_eq!(sys.coord.stats.pud_rows, pud_after_first, "no new PUD rows");
    assert!(sys.coord.stats.fallback_rows >= 16);
    assert_eq!(
        sys.read_virt(pid, d, len).unwrap(),
        vec![0x77u8; len as usize]
    );
}

#[test]
fn stats_fully_pud_tracks_per_op() {
    let mut sys = boot();
    let pid = sys.spawn();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 8).unwrap();
    let mut malloc = MallocSim::new();
    let a = sys.alloc(&mut puma, pid, row).unwrap();
    let b = sys.alloc_align(&mut puma, pid, row, a).unwrap();
    sys.submit(pid, &BulkRequest::new(PudOp::Copy, b, vec![a], row))
        .unwrap();
    let m1 = sys.alloc(&mut malloc, pid, row).unwrap();
    let m2 = sys.alloc(&mut malloc, pid, row).unwrap();
    sys.submit(pid, &BulkRequest::new(PudOp::Copy, m2, vec![m1], row))
        .unwrap();
    assert_eq!(sys.coord.stats.ops_fully_pud.hits, 1);
    assert_eq!(sys.coord.stats.ops_fully_pud.total, 2);
}

#[test]
fn partial_tail_sizes_handled() {
    // operation length not a row multiple: the tail row is partial
    let mut sys = boot();
    let pid = sys.spawn();
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let len = 3 * row + 1000;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, 8).unwrap();
    let a = sys.alloc(&mut puma, pid, len).unwrap();
    let b = sys.alloc_align(&mut puma, pid, len, a).unwrap();
    let data: Vec<u8> = (0..len).map(|i| (i % 241) as u8).collect();
    sys.write_virt(pid, a, &data).unwrap();
    sys.submit(pid, &BulkRequest::new(PudOp::Copy, b, vec![a], len))
        .unwrap();
    assert_eq!(sys.read_virt(pid, b, len).unwrap(), data);
    assert_eq!(sys.coord.stats.pud_rows, 4); // 3 full + 1 partial row
}
