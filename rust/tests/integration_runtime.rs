//! Integration: XLA/PJRT runtime — every artifact compiles, executes,
//! and agrees with the scalar oracle. Skips cleanly when artifacts
//! have not been built.

use puma::pud::isa::PudOp;
use puma::runtime::{manifest, XlaRuntime, ROW_BYTES};
use puma::util::rng::Pcg64;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.tsv").exists().then_some(dir)
}

#[test]
fn manifest_covers_every_pud_op_and_all_buckets() {
    let Some(dir) = artifacts() else { return };
    let entries = manifest::load(&dir).unwrap();
    for op in PudOp::ALL {
        let buckets: Vec<u32> = entries
            .iter()
            .filter(|e| e.op == op.kernel_name())
            .map(|e| e.rows)
            .collect();
        assert_eq!(buckets.len(), 4, "{op}: want 4 buckets, got {buckets:?}");
        for b in [1u32, 8, 64, 256] {
            assert!(buckets.contains(&b), "{op}: missing bucket {b}");
        }
    }
}

#[test]
fn all_ops_all_buckets_match_oracle() {
    let Some(dir) = artifacts() else { return };
    let mut rt = XlaRuntime::load(&dir).unwrap();
    let mut rng = Pcg64::new(0xE2E);
    for op in PudOp::ALL {
        for rows in [1u32, 8] {
            let n = rows as usize * ROW_BYTES;
            let mut a = vec![0u8; n];
            let mut b = vec![0u8; n];
            rng.fill_bytes(&mut a);
            rng.fill_bytes(&mut b);
            let srcs: Vec<&[u8]> = match op.arity() {
                0 => vec![],
                1 => vec![&a],
                _ => vec![&a, &b],
            };
            let got = rt.run_op(op.kernel_name(), rows, &srcs).unwrap();
            let mut want = vec![0u8; n];
            op.apply_bytes(&srcs, &mut want);
            assert_eq!(got, want, "{op}@{rows} rows");
        }
    }
}

#[test]
fn odd_row_counts_cover_via_buckets() {
    let Some(dir) = artifacts() else { return };
    let mut rt = XlaRuntime::load(&dir).unwrap();
    let mut rng = Pcg64::new(0x0DD);
    for rows in [3u32, 13, 73, 300] {
        let n = rows as usize * ROW_BYTES;
        let mut a = vec![0u8; n];
        rng.fill_bytes(&mut a);
        let got = rt.run_op("not", rows, &[&a]).unwrap();
        let want: Vec<u8> = a.iter().map(|x| !x).collect();
        assert_eq!(got, want, "not@{rows} rows");
    }
}

#[test]
fn dispatch_counts_follow_bucket_plan() {
    let Some(dir) = artifacts() else { return };
    let mut rt = XlaRuntime::load(&dir).unwrap();
    let base = rt.dispatches;
    let n = 9 * ROW_BYTES;
    let a = vec![0u8; n];
    rt.run_op("copy", 9, &[&a]).unwrap(); // 8 + 1
    assert_eq!(rt.dispatches - base, 2);
}
