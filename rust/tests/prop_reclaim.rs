//! Property tests for the allocation lifecycle (DESIGN.md §8):
//! region accounting under arbitrary alloc/free interleavings,
//! huge-page reassembly restoring the boot pool, and content-
//! preserving, leak-free compaction.

use puma::alloc::puma::{FitPolicy, PumaAlloc};
use puma::alloc::traits::{Allocator, OsCtx};
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::dram::timing::TimingParams;
use puma::os::process::{Pid, Process};
use puma::proptest::{self, assert_prop};

const ROW: u64 = 8192;

fn small_scheme() -> InterleaveScheme {
    InterleaveScheme::row_major(DramGeometry::small()) // 64 MiB
}

fn small_ctx(seed: u64) -> OsCtx {
    OsCtx::boot(small_scheme(), 16, 1_500, seed).unwrap()
}

/// carved == free + live must hold after every mutation — no region is
/// ever lost or double-tracked, across allocs, frees, reclaims, and
/// re-preallocation.
#[test]
fn interleavings_leak_no_rows() {
    proptest::check_cases("lifecycle region conservation", 10, |g| {
        let mut ctx = small_ctx(g.u64(0..1 << 32));
        let boot_pool = ctx.pool.available();
        let mut puma = PumaAlloc::new(ROW, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 3).unwrap();
        let mut proc = Process::new(Pid(1));
        let mut live: Vec<u64> = Vec::new();
        let check = |puma: &PumaAlloc| {
            assert_prop!(
                puma.carved_regions()
                    == puma.free_regions() + puma.live_regions(),
                "carved {} != free {} + live {}",
                puma.carved_regions(),
                puma.free_regions(),
                puma.live_regions()
            );
        };
        for _ in 0..g.usize(5..60) {
            match g.usize(0..10) {
                0..=4 => {
                    let rows = g.u64(1..12);
                    let hint = (!live.is_empty() && g.bool())
                        .then(|| live[g.usize(0..live.len())]);
                    let res = match hint {
                        Some(h) => {
                            puma.alloc_align(&mut ctx, &mut proc, rows * ROW, h)
                        }
                        None => puma.alloc(&mut ctx, &mut proc, rows * ROW),
                    };
                    if let Ok(va) = res {
                        live.push(va);
                    }
                }
                5..=7 => {
                    if !live.is_empty() {
                        let va = live.swap_remove(g.usize(0..live.len()));
                        puma.free(&mut ctx, &mut proc, va).unwrap();
                    }
                }
                8 => {
                    puma.reclaim(&mut ctx).unwrap();
                }
                _ => {
                    if ctx.pool.available() > 0 && puma.preallocated() < 4 {
                        puma.pim_preallocate(&mut ctx, 1).unwrap();
                    }
                }
            }
            check(&puma);
            // every boot-pool page is either with the pool or with PUMA
            assert_prop!(
                ctx.pool.available() + puma.preallocated() == boot_pool,
                "huge page leaked: pool {} + puma {} != {}",
                ctx.pool.available(),
                puma.preallocated(),
                boot_pool
            );
        }
        // drain: everything freed -> every page reassembles -> the
        // boot pool is restored to its baseline
        for va in live {
            puma.free(&mut ctx, &mut proc, va).unwrap();
        }
        puma.reclaim(&mut ctx).unwrap();
        check(&puma);
        assert_prop!(puma.carved_regions() == 0, "pages left behind");
        assert_prop!(
            ctx.pool.available() == boot_pool,
            "pool not restored: {} != {}",
            ctx.pool.available(),
            boot_pool
        );
    });
}

/// Full free + reclaim returns exactly the preallocated pages, no
/// matter how the pool was carved up in between.
#[test]
fn reassembly_restores_pool_to_baseline() {
    proptest::check_cases("huge-page reassembly", 10, |g| {
        let mut ctx = small_ctx(g.u64(0..1 << 32));
        let boot_pool = ctx.pool.available();
        let pages = g.usize(1..5);
        let mut puma = PumaAlloc::new(ROW, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, pages).unwrap();
        let mut proc = Process::new(Pid(7));
        let mut live = Vec::new();
        for _ in 0..g.usize(1..25) {
            let rows = g.u64(1..10);
            if let Ok(va) = puma.alloc(&mut ctx, &mut proc, rows * ROW) {
                live.push(va);
            }
        }
        for va in live {
            puma.free(&mut ctx, &mut proc, va).unwrap();
        }
        let reclaimed = puma.reclaim(&mut ctx).unwrap();
        assert_prop!(reclaimed == pages, "reclaimed {reclaimed} of {pages}");
        assert_prop!(puma.stats().pages_reclaimed == pages as u64);
        assert_prop!(ctx.pool.available() == boot_pool);
        assert_prop!(puma.free_regions() == 0 && puma.carved_regions() == 0);
    });
}

/// `compact()` must preserve the bytes of every live allocation —
/// reachable through the (possibly re-pointed) virtual addresses — and
/// keep the region/page accounting exact.
#[test]
fn compaction_preserves_contents_and_accounting() {
    proptest::check_cases("compaction content preservation", 6, |g| {
        let mut sys = System::boot(SystemConfig {
            scheme: small_scheme(),
            timing: TimingParams::default(),
            huge_pages: 8,
            churn_rounds: 500,
            seed: g.u64(0..1 << 32),
            artifacts: None,
        })
        .unwrap();
        let boot_pool = sys.os.pool.available();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(ROW, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 2).unwrap();

        // build aligned groups under pressure until the pool runs dry
        let mut contents: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut groups: Vec<(u64, u64)> = Vec::new();
        loop {
            let rows = g.u64(1..8);
            if puma.free_regions() < 2 * rows as usize {
                break;
            }
            let len = rows * ROW;
            let Ok(a) = sys.alloc(&mut puma, pid, len) else { break };
            let Ok(b) = sys.alloc_align(&mut puma, pid, len, a) else {
                sys.free(&mut puma, pid, a).unwrap();
                break;
            };
            for va in [a, b] {
                // one random tag per operand (not per byte) keeps the
                // shrink log small while the contents stay distinctive
                let tag = g.u64(0..256) as u8;
                let data: Vec<u8> = (0..len)
                    .map(|i| tag ^ (i % 251) as u8)
                    .collect();
                sys.write_virt(pid, va, &data).unwrap();
                contents.push((va, data));
            }
            groups.push((a, b));
        }
        assert_prop!(!groups.is_empty(), "pool too small for the workload");

        // free a random subset of whole groups
        let mut i = 0;
        while i < groups.len() {
            if g.ratio(1, 2) {
                let (a, b) = groups.swap_remove(i);
                sys.free(&mut puma, pid, b).unwrap();
                sys.free(&mut puma, pid, a).unwrap();
                contents.retain(|(va, _)| *va != a && *va != b);
            } else {
                i += 1;
            }
        }

        let live_before = puma.live_regions();
        sys.compact(&mut puma, pid).unwrap();

        assert_prop!(
            puma.live_regions() == live_before,
            "compaction changed the live-region count"
        );
        assert_prop!(
            puma.carved_regions()
                == puma.free_regions() + puma.live_regions(),
            "carved {} != free {} + live {}",
            puma.carved_regions(),
            puma.free_regions(),
            puma.live_regions()
        );
        assert_prop!(
            sys.os.pool.available() + puma.preallocated() == boot_pool,
            "huge page lost across compaction"
        );
        for (va, want) in &contents {
            let got = sys.read_virt(pid, *va, want.len() as u64).unwrap();
            assert_prop!(
                got == *want,
                "contents of {va:#x} changed across compaction"
            );
        }
    });
}
