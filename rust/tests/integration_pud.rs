//! Integration: PUD substrate — functional equivalence of every op
//! against the scalar oracle, over full-size rows and multi-row plans.

use puma::dram::address::InterleaveScheme;
use puma::dram::device::DramDevice;
use puma::dram::geometry::{DramGeometry, SubarrayId};
use puma::dram::timing::TimingParams;
use puma::os::process::PhysExtent;
use puma::pud::exec::PudEngine;
use puma::pud::isa::PudOp;
use puma::pud::legality::check_rowwise;
use puma::util::rng::Pcg64;

fn engine() -> PudEngine {
    PudEngine::new(
        DramDevice::new(InterleaveScheme::row_major(DramGeometry::default())),
        TimingParams::default(),
    )
}

fn rows_ext(e: &PudEngine, sid: u32, first: u32, n: u32) -> Vec<PhysExtent> {
    let rb = e.device.geometry().row_bytes as u64;
    (0..n)
        .map(|i| PhysExtent {
            paddr: e.device.scheme.row_start_addr(SubarrayId(sid), first + i),
            len: rb,
        })
        .collect()
}

#[test]
fn every_op_matches_oracle_over_8_rows() {
    let rb = 8192usize;
    let n = 8usize;
    let mut rng = Pcg64::new(0x9D);
    for op in PudOp::ALL {
        let mut e = engine();
        let dst = rows_ext(&e, 5, 0, n as u32);
        let s1 = rows_ext(&e, 5, 100, n as u32);
        let s2 = rows_ext(&e, 5, 200, n as u32);
        let mut a = vec![0u8; rb * n];
        let mut b = vec![0u8; rb * n];
        rng.fill_bytes(&mut a);
        rng.fill_bytes(&mut b);
        for (i, ext) in s1.iter().enumerate() {
            e.device.write(ext.paddr, &a[i * rb..(i + 1) * rb]);
        }
        for (i, ext) in s2.iter().enumerate() {
            e.device.write(ext.paddr, &b[i * rb..(i + 1) * rb]);
        }
        let operands: Vec<&[PhysExtent]> = match op.arity() {
            0 => vec![&dst],
            1 => vec![&dst, &s1],
            _ => vec![&dst, &s1, &s2],
        };
        let plan = check_rowwise(&e.device.scheme, &operands, (rb * n) as u64);
        assert!(plan.iter().all(|p| p.is_pud()), "{op}: plan not all PUD");
        let st = e.execute(op, &plan, true).unwrap();
        assert_eq!(st.pud_rows, n as u64);
        // oracle
        let mut want = vec![0u8; rb * n];
        let srcs: Vec<&[u8]> = match op.arity() {
            0 => vec![],
            1 => vec![&a],
            _ => vec![&a, &b],
        };
        op.apply_bytes(&srcs, &mut want);
        let mut got = vec![0u8; rb * n];
        for (i, ext) in dst.iter().enumerate() {
            e.device.read(ext.paddr, &mut got[i * rb..(i + 1) * rb]);
        }
        assert_eq!(got, want, "{op} mismatch");
    }
}

#[test]
fn command_counters_scale_with_rows() {
    let mut e = engine();
    let n = 16u32;
    let dst = rows_ext(&e, 2, 0, n);
    let s1 = rows_ext(&e, 2, 100, n);
    let s2 = rows_ext(&e, 2, 200, n);
    let plan = check_rowwise(
        &e.device.scheme,
        &[&dst, &s1, &s2],
        n as u64 * 8192,
    );
    e.execute(PudOp::And, &plan, false).unwrap();
    assert_eq!(e.device.counters.aaps, 4 * n as u64);
    assert_eq!(e.device.counters.tras, n as u64);
    let energy = puma::dram::energy::EnergyParams::default();
    assert!(energy.total_nj(&e.device.counters) > 0.0);
}

#[test]
fn mixed_subarray_plan_splits_correctly() {
    let mut e = engine();
    // dst rows alternate between two subarrays; src stays in one ->
    // alternating PUD/fallback plan
    let rb = e.device.geometry().row_bytes as u64;
    let mut dst = Vec::new();
    for i in 0..8u32 {
        let sid = if i % 2 == 0 { 3 } else { 4 };
        dst.push(PhysExtent {
            paddr: e.device.scheme.row_start_addr(SubarrayId(sid), i),
            len: rb,
        });
    }
    let src = rows_ext(&e, 3, 100, 8);
    let plan = check_rowwise(&e.device.scheme, &[&dst, &src], 8 * rb);
    let pud = plan.iter().filter(|p| p.is_pud()).count();
    assert_eq!(pud, 4, "half the rows co-locate");
    let st = e.execute(PudOp::Copy, &plan, true).unwrap();
    assert_eq!(st.pud_rows, 4);
    assert_eq!(st.fallback_rows, 4);
    // every row still gets the right data
    let mut buf = vec![0u8; rb as usize];
    for (i, d) in dst.iter().enumerate() {
        let mut want = vec![0u8; rb as usize];
        e.device.read(src[i].paddr, &mut want);
        e.device.read(d.paddr, &mut buf);
        assert_eq!(buf, want, "row {i}");
    }
}

#[test]
fn timing_hierarchy_holds_at_scale() {
    let t = TimingParams::default();
    let rows = 768; // 6 Mb of rows
    let bytes = rows * 8192u64;
    let zero = t.rowclone_zero_ns(rows);
    let copy = t.rowclone_fpm_ns(rows);
    let and = t.ambit_and_or_ns(rows);
    let xor = t.ambit_xor_ns(rows);
    let cpu = t.cpu_bulk_ns(2 * bytes, bytes);
    assert!(zero <= copy && copy < and && and < xor);
    assert!(xor < cpu, "even XOR (7 AAPs/row) beats the channel");
}
