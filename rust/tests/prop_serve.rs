//! Properties of the multi-tenant serving boundary (DESIGN.md §15):
//! DRR interleaving preserves every tenant's FIFO program order, the
//! fair schedule is byte-identical to the back-to-back baseline,
//! scratch-quota admission rejects typed and leases nothing (and the
//! tenant recovers with `Session::trim`), and the deprecated
//! flat/sharded `System` shims stay bit-identical to the unified
//! `Column` API they delegate to.

// Property 4 pins the deprecated shims on purpose: they must keep
// producing bit-identical results until removal.
#![allow(deprecated)]

use anyhow::Result;
use puma::alloc::mallocsim::MallocSim;
use puma::alloc::puma::FitPolicy;
use puma::alloc::request::AllocRequest;
use puma::alloc::scratch::ScratchPool;
use puma::assert_prop;
use puma::coordinator::system::{System, SystemConfig};
use puma::dram::address::InterleaveScheme;
use puma::dram::geometry::DramGeometry;
use puma::proptest;
use puma::pud::arith::{
    self, ArithOp, Column, LayoutSpec, ShardedLayout, ShardedScratch,
    VerticalLayout,
};
use puma::pud::isa::{BulkRequest, PudOp};
use puma::serve::{
    Gateway, GatewayConfig, RejectReason, ServeError, Session, SessionConfig,
    SessionId,
};
use puma::util::rng::Pcg64;
use puma::workloads::microbench::AllocatorKind;

fn boot(seed: u64) -> System {
    let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
    System::boot(SystemConfig {
        scheme,
        huge_pages: 12,
        churn_rounds: 200,
        seed,
        artifacts: None,
        ..Default::default()
    })
    .unwrap()
}

fn row_bytes() -> u64 {
    DramGeometry::small().row_bytes as u64
}

/// One randomly drawn request over a tenant's four buffers: the
/// destination and sources are always distinct indices, so host-model
/// semantics are unambiguous.
fn draw_step(g: &mut proptest::Gen) -> (PudOp, usize, usize, usize) {
    let op = *g.choose(&PudOp::ALL);
    let dst = g.usize(0..4);
    let s1 = (dst + 1 + g.usize(0..3)) % 4;
    let rest: Vec<usize> =
        (0..4).filter(|&k| k != dst && k != s1).collect();
    let s2 = rest[g.usize(0..rest.len())];
    (op, dst, s1, s2)
}

/// Scalar reference semantics of one bulk op over the mirrored host
/// buffers, applied in the tenant's submission order.
fn apply_step(
    model: &mut [Vec<u8>],
    op: PudOp,
    dst: usize,
    s1: usize,
    s2: usize,
) {
    for i in 0..model[dst].len() {
        let a = model[s1][i];
        let b = model[s2][i];
        model[dst][i] = match op {
            PudOp::Zero => 0,
            PudOp::Copy => a,
            PudOp::Not => !a,
            PudOp::And => a & b,
            PudOp::Or => a | b,
            PudOp::Xor => a ^ b,
        };
    }
}

fn request_for(
    op: PudOp,
    vas: &[u64; 4],
    dst: usize,
    s1: usize,
    s2: usize,
    len: u64,
) -> BulkRequest {
    let srcs = match op.arity() {
        0 => vec![],
        1 => vec![vas[s1]],
        _ => vec![vas[s1], vas[s2]],
    };
    BulkRequest::new(op, vas[dst], srcs, len)
}

/// Open `tenants` sessions, allocate four buffers each, seed them with
/// random bytes, and return the handles plus a host mirror of every
/// buffer's contents.
#[allow(clippy::type_complexity)]
fn open_tenants(
    gw: &mut Gateway,
    tenants: usize,
    len: u64,
    rng: &mut Pcg64,
) -> Vec<(SessionId, [u64; 4], Vec<Vec<u8>>)> {
    (0..tenants)
        .map(|t| {
            let id = gw.open(SessionConfig::named(format!("t{t}")));
            let (vas, model) = gw
                .with_session(id, |sess, sys, alloc| {
                    let mut vas = [0u64; 4];
                    let mut model = Vec::with_capacity(4);
                    for (k, slot) in vas.iter_mut().enumerate() {
                        let va = sess.alloc(
                            sys,
                            alloc,
                            AllocRequest::bytes(len),
                        )?;
                        let mut data = vec![0u8; len as usize];
                        // tenant-and-buffer-specific deterministic fill
                        let mut r = Pcg64::new(
                            rng.next_u64() ^ ((t as u64) << 8) ^ k as u64,
                        );
                        r.fill_bytes(&mut data);
                        sess.write(sys, va, &data)?;
                        *slot = va;
                        model.push(data);
                    }
                    Ok((vas, model))
                })
                .unwrap();
            (id, vas, model)
        })
        .collect()
}

/// Property 1: the DRR scheduler may interleave tenants however it
/// likes, but each tenant's own requests execute in submission order.
/// Every tenant's requests form a dependent chain over its four
/// buffers, so any within-tenant reorder diverges from the host model.
/// The quantum is drawn strictly below every request's row cost, so a
/// round releases at most one request per tenant and a full drain is
/// forced through many interleaved rounds.
#[test]
fn per_tenant_fifo_survives_drr_interleaving_property() {
    proptest::check_cases("per-tenant FIFO under DRR", 8, |g| {
        let tenants = g.usize(2..5);
        let ops = g.usize(3..8);
        let quantum = g.u64(1..3);
        let len = (quantum + g.u64(1..3)) * row_bytes();
        let seed = g.u64(1..u64::MAX);

        let mut gw = Gateway::new(
            boot(0x5EED),
            Box::new(MallocSim::new()),
            GatewayConfig { quantum },
        );
        let mut rng = Pcg64::new(seed);
        let mut lanes = open_tenants(&mut gw, tenants, len, &mut rng);
        for _ in 0..ops {
            for (id, vas, model) in lanes.iter_mut() {
                let (op, dst, s1, s2) = draw_step(g);
                let outcome = gw
                    .submit(*id, request_for(op, vas, dst, s1, s2, len))
                    .unwrap();
                assert_prop!(outcome.is_admitted(), "traffic under the cap");
                apply_step(model, op, dst, s1, s2);
            }
        }
        let rounds = gw.drain().unwrap();
        assert_prop!(
            rounds >= ops as u64,
            "quantum below request cost must force >= one round per \
             request ({rounds} rounds for {ops} ops)"
        );
        for (id, vas, model) in &lanes {
            for (k, want) in model.iter().enumerate() {
                let got = gw
                    .with_session(*id, |sess, sys, _| {
                        sess.read(sys, vas[k], len)
                    })
                    .unwrap();
                assert_prop!(
                    &got == want,
                    "tenant {id:?} buffer {k} diverged from FIFO order"
                );
            }
        }
    });
}

/// Property 2: DRR interleaving and the back-to-back baseline are
/// byte-identical schedules of the same traffic — on malloc placement
/// and on PUMA placement alike.
#[test]
fn drr_matches_back_to_back_byte_for_byte_property() {
    proptest::check_cases("DRR == back-to-back", 6, |g| {
        let tenants = g.usize(2..5);
        let ops = g.usize(2..7);
        let len = g.u64(1..3) * row_bytes();
        let puma = g.bool();
        let seed = g.u64(1..u64::MAX);
        let plan: Vec<Vec<(PudOp, usize, usize, usize)>> = (0..tenants)
            .map(|_| (0..ops).map(|_| draw_step(g)).collect())
            .collect();

        let build = || -> Gateway {
            let mut sys = boot(0x7EA);
            let kind = if puma {
                AllocatorKind::Puma(FitPolicy::WorstFit)
            } else {
                AllocatorKind::Malloc
            };
            let alloc = kind.build(&mut sys, 8).unwrap();
            Gateway::new(sys, alloc, GatewayConfig { quantum: 2 })
        };
        let mut fair = build();
        let mut base = build();
        let lanes_f =
            open_tenants(&mut fair, tenants, len, &mut Pcg64::new(seed));
        let lanes_b =
            open_tenants(&mut base, tenants, len, &mut Pcg64::new(seed));
        for (t, steps) in plan.iter().enumerate() {
            for &(op, dst, s1, s2) in steps {
                let (idf, vf, _) = &lanes_f[t];
                fair.submit(*idf, request_for(op, vf, dst, s1, s2, len))
                    .unwrap();
                let (idb, vb, _) = &lanes_b[t];
                base.submit(*idb, request_for(op, vb, dst, s1, s2, len))
                    .unwrap();
            }
        }
        fair.drain().unwrap();
        base.drain_back_to_back().unwrap();
        for t in 0..tenants {
            let (idf, vf, _) = &lanes_f[t];
            let fair_bufs: Vec<Vec<u8>> = (0..4)
                .map(|k| {
                    let va = vf[k];
                    fair.with_session(*idf, |sess, sys, _| {
                        sess.read(sys, va, len)
                    })
                    .unwrap()
                })
                .collect();
            let (idb, vb, _) = &lanes_b[t];
            for (k, fair_buf) in fair_bufs.iter().enumerate() {
                let va = vb[k];
                let base_buf = base
                    .with_session(*idb, |sess, sys, _| {
                        sess.read(sys, va, len)
                    })
                    .unwrap();
                assert_prop!(
                    fair_buf == &base_buf,
                    "tenant {t} buffer {k}: DRR and back-to-back diverged"
                );
            }
        }
        for (_, done) in
            fair.completions().iter().chain(base.completions().iter())
        {
            assert_prop!(*done > 0.0, "every tenant completed on the clock");
        }
    });
}

/// Property 3: a kernel whose scratch lease would exceed the session
/// quota is refused with a typed `ScratchExhausted` *before* anything
/// is leased, and the tenant recovers by trimming its pools. The quota
/// is calibrated from a probe session running the same kernel, so the
/// property holds for whatever footprint the compiler assigns.
#[test]
fn scratch_quota_rejects_typed_and_recovers_after_trim_property() {
    proptest::check_cases("scratch quota + trim recovery", 6, |g| {
        let width = g.usize(4..9) as u32;
        let elems = g.usize(512..2048);
        let seed = g.u64(1..u64::MAX);
        let mask = arith::width_mask(width);
        let mut rng = Pcg64::new(seed);
        let va: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
        let vb: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
        // 16x the elements => 16x the plane bytes => a different
        // scratch size class, so the big kernel cannot reuse the small
        // kernel's resident buffers.
        let big: usize = elems * 16;

        let mut sys = boot(0xB16);
        let mut malloc = MallocSim::new();
        let run = |sess: &mut Session,
                   sys: &mut System,
                   alloc: &mut MallocSim,
                   n: usize|
         -> Result<(Column, Column, Column)> {
            let a = sess.alloc_column(sys, alloc, width, n, LayoutSpec::Flat)?;
            let b = sess.alloc_column_like(sys, alloc, width, &a)?;
            let dst = sess.alloc_column_like(sys, alloc, width, &a)?;
            sess.store_column(sys, &a, &va[..n.min(elems)])?;
            sess.store_column(sys, &b, &vb[..n.min(elems)])?;
            Ok((a, b, dst))
        };

        // probe: what does one Add actually keep resident?
        let mut probe = Session::open(&mut sys, SessionConfig::named("probe"));
        let (pa, pb, pdst) = run(&mut probe, &mut sys, &mut malloc, elems)
            .unwrap();
        probe
            .arith(&mut sys, &mut malloc, ArithOp::Add, &pa, Some(&pb), &pdst)
            .unwrap();
        let footprint = probe.scratch_resident();
        assert_prop!(footprint > 0, "the Add kernel must lease scratch");
        probe.release(&mut sys, &mut malloc).unwrap();

        let mut sess = Session::open(
            &mut sys,
            SessionConfig {
                scratch_quota: footprint,
                ..SessionConfig::named("metered")
            },
        );
        // the small kernel fits the quota exactly
        let (a, b, dst) = run(&mut sess, &mut sys, &mut malloc, elems).unwrap();
        sess.arith(&mut sys, &mut malloc, ArithOp::Add, &a, Some(&b), &dst)
            .unwrap();
        assert_prop!(sess.scratch_resident() == footprint);

        // the big kernel would double the footprint: typed rejection,
        // nothing leased
        let mut rbig = Pcg64::new(seed ^ 1);
        let wa: Vec<u64> = (0..big).map(|_| rbig.next_u64() & mask).collect();
        let wb: Vec<u64> = (0..big).map(|_| rbig.next_u64() & mask).collect();
        let ba = sess
            .alloc_column(&mut sys, &mut malloc, width, big, LayoutSpec::Flat)
            .unwrap();
        let bb = sess
            .alloc_column_like(&mut sys, &mut malloc, width, &ba)
            .unwrap();
        let bdst = sess
            .alloc_column_like(&mut sys, &mut malloc, width, &ba)
            .unwrap();
        sess.store_column(&mut sys, &ba, &wa).unwrap();
        sess.store_column(&mut sys, &bb, &wb).unwrap();
        let err = sess
            .arith(&mut sys, &mut malloc, ArithOp::Add, &ba, Some(&bb), &bdst)
            .unwrap_err();
        match ServeError::from_anyhow(&err) {
            Some(ServeError::Rejected(RejectReason::ScratchExhausted {
                projected,
                quota,
            })) => {
                assert_prop!(*quota == footprint);
                assert_prop!(
                    *projected > *quota,
                    "projected {projected} must exceed quota {quota}"
                );
            }
            other => panic!("expected ScratchExhausted, got {other:?}: {err}"),
        }
        assert_prop!(
            sess.scratch_resident() == footprint,
            "a rejected kernel must lease nothing"
        );

        // recovery: trim the pools, rerun, verify the arithmetic
        sess.trim(&mut sys, &mut malloc, 0).unwrap();
        assert_prop!(sess.scratch_resident() == 0, "trim(0) empties the pools");
        sess.arith(&mut sys, &mut malloc, ArithOp::Add, &ba, Some(&bb), &bdst)
            .unwrap();
        let got = sess.load_column(&mut sys, &bdst).unwrap();
        for (i, &v) in got.iter().enumerate() {
            assert_prop!(
                v == arith::reference(ArithOp::Add, width, wa[i], wb[i]),
                "post-recovery Add diverged at element {i}"
            );
        }
    });
}

/// Property 4: the deprecated flat/sharded `System` entry points are
/// bit-identical to the unified layout-polymorphic API they now
/// delegate to — kernels, constant kernels, and sums, checked against
/// the scalar reference oracle on separate but identically-booted
/// machines.
#[test]
fn deprecated_shims_match_the_unified_api_property() {
    proptest::check_cases("shims == unified API", 6, |g| {
        let width = g.usize(2..9) as u32;
        let elems = g.usize(64..1500);
        let shards = g.usize(2..5);
        let op = *g.choose(&[
            ArithOp::Add,
            ArithOp::Sub,
            ArithOp::CmpLt,
            ArithOp::CmpEq,
            ArithOp::Min,
            ArithOp::Max,
        ]);
        let seed = g.u64(1..u64::MAX);
        let mask = arith::width_mask(width);
        let rhs = g.u64(0..mask + 1);
        let mut rng = Pcg64::new(seed);
        let va: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();
        let vb: Vec<u64> = (0..elems).map(|_| rng.next_u64() & mask).collect();

        // --- the deprecated surface ---------------------------------
        let mut so = boot(0x01D);
        let mut ao = MallocSim::new();
        let po = so.spawn();
        let la = so.cached_column(&mut ao, po, 1, 0, width, &va).unwrap();
        let lb = so.cached_column(&mut ao, po, 2, 0, width, &vb).unwrap();
        let ld =
            VerticalLayout::alloc(&mut so, &mut ao, po, op.out_width(width), elems)
                .unwrap();
        let lc = VerticalLayout::alloc(&mut so, &mut ao, po, width, elems)
            .unwrap();
        let mut pool = ScratchPool::new();
        so.run_arith(&mut ao, po, op, &la, Some(&lb), &ld, &mut pool)
            .unwrap();
        so.run_arith_const(&mut ao, po, ArithOp::Add, rhs, &la, &lc, &mut pool)
            .unwrap();
        let out_old = ld.load(&mut so, po).unwrap();
        let out_old_const = lc.load(&mut so, po).unwrap();
        let (sum_old, _) =
            so.arith_sum(&mut ao, po, &la, None, &mut pool).unwrap();
        let sa = so
            .cached_column_sharded(&mut ao, po, 3, 0, width, &va, shards)
            .unwrap();
        let sb = so
            .cached_column_sharded(&mut ao, po, 4, 0, width, &vb, shards)
            .unwrap();
        let sd = ShardedLayout::alloc(
            &mut so,
            &mut ao,
            po,
            op.out_width(width),
            elems,
            shards,
        )
        .unwrap();
        let mut pools_old = ShardedScratch::new();
        so.run_arith_sharded(&mut ao, po, op, &sa, Some(&sb), &sd, &mut pools_old)
            .unwrap();
        let out_old_sh = sd.load(&mut so, po).unwrap();

        // --- the unified surface ------------------------------------
        let mut sn = boot(0x01D);
        let mut an = MallocSim::new();
        let pn = sn.spawn();
        let ca = sn
            .column(&mut an, pn, 1, 0, width, &va, LayoutSpec::Flat)
            .unwrap();
        let cb = sn
            .column(&mut an, pn, 2, 0, width, &vb, LayoutSpec::Flat)
            .unwrap();
        let cd = Column::Flat(
            VerticalLayout::alloc(&mut sn, &mut an, pn, op.out_width(width), elems)
                .unwrap(),
        );
        let cc = Column::Flat(
            VerticalLayout::alloc(&mut sn, &mut an, pn, width, elems).unwrap(),
        );
        let mut pools = ShardedScratch::new();
        sn.arith(&mut an, pn, op, &ca, Some(&cb), &cd, &mut pools)
            .unwrap();
        sn.arith_const(&mut an, pn, ArithOp::Add, rhs, &ca, &cc, &mut pools)
            .unwrap();
        let load = |sys: &mut System, col: &Column| match col {
            Column::Flat(l) => l.load(sys, pn).unwrap(),
            Column::Sharded(s) => s.load(sys, pn).unwrap(),
        };
        let out_new = load(&mut sn, &cd);
        let out_new_const = load(&mut sn, &cc);
        let (sum_new, _) =
            sn.column_sum(&mut an, pn, &ca, None, &mut pools).unwrap();
        let csa = sn
            .column(&mut an, pn, 3, 0, width, &va, LayoutSpec::Sharded(shards))
            .unwrap();
        let csb = sn
            .column(&mut an, pn, 4, 0, width, &vb, LayoutSpec::Sharded(shards))
            .unwrap();
        let csd = Column::Sharded(
            ShardedLayout::alloc(
                &mut sn,
                &mut an,
                pn,
                op.out_width(width),
                elems,
                shards,
            )
            .unwrap(),
        );
        sn.arith(&mut an, pn, op, &csa, Some(&csb), &csd, &mut pools)
            .unwrap();
        let out_new_sh = load(&mut sn, &csd);

        // --- equivalence, and both against the oracle ---------------
        assert_prop!(out_old == out_new, "flat {op:?} shim diverged");
        assert_prop!(out_old_sh == out_new_sh, "sharded {op:?} shim diverged");
        assert_prop!(
            out_old_const == out_new_const,
            "const-add shim diverged"
        );
        assert_prop!(sum_old == sum_new, "sum shim diverged");
        for i in 0..elems {
            let want = arith::reference(op, width, va[i], vb[i]);
            assert_prop!(out_new[i] == want, "unified {op:?} off oracle at {i}");
            assert_prop!(out_old[i] == want, "shim {op:?} off oracle at {i}");
            assert_prop!(out_new_sh[i] == want, "sharded off oracle at {i}");
        }
        let want_sum: u128 = va.iter().map(|&x| x as u128).sum();
        assert_prop!(sum_new == want_sum, "column_sum off oracle");
    });
}
