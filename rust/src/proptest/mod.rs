//! Minimal property-based testing framework (the `proptest` crate is
//! not in the offline vendor set — DESIGN.md §7).
//!
//! Features: seeded deterministic generation, configurable case count,
//! and greedy shrinking of failing inputs. The failing seed and the
//! shrunk input's `Debug` rendering are included in the panic message
//! so failures reproduce with `PUMA_PROP_SEED=<seed>`.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the libxla rpath in this image)
//! use puma::{assert_prop, proptest};
//! proptest::check("sum commutes", |g| {
//!     let a = g.u64(0..1000);
//!     let b = g.u64(0..1000);
//!     assert_prop!(a + b == b + a, "a={a} b={b}");
//! });
//! ```

use crate::util::rng::Pcg64;

/// Per-case value source handed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Trace of raw draws, kept so shrinking can replay a prefix.
    log: Vec<u64>,
    /// When replaying under shrink, values to force for each draw.
    forced: Option<Vec<u64>>,
    draw_idx: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self {
            rng: Pcg64::new(seed),
            log: Vec::new(),
            forced: None,
            draw_idx: 0,
        }
    }

    fn replay(seed: u64, forced: Vec<u64>) -> Self {
        Self {
            rng: Pcg64::new(seed),
            log: Vec::new(),
            forced: Some(forced),
            draw_idx: 0,
        }
    }

    /// Raw bounded draw; everything else routes through this so that
    /// shrinking (which rewrites these raw values) covers all types.
    fn draw(&mut self, bound: u64) -> u64 {
        let fresh = self.rng.below(bound.max(1));
        let v = match &self.forced {
            Some(forced) if self.draw_idx < forced.len() => {
                forced[self.draw_idx].min(bound.saturating_sub(1))
            }
            _ => fresh,
        };
        self.draw_idx += 1;
        self.log.push(v);
        v
    }

    /// Uniform u64 in `[range.start, range.end)`.
    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        assert!(range.start < range.end, "empty range");
        range.start + self.draw(range.end - range.start)
    }

    /// Uniform usize in `[range.start, range.end)`.
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.draw(2) == 1
    }

    /// Biased boolean, true with probability `num/denom`.
    pub fn ratio(&mut self, num: u64, denom: u64) -> bool {
        self.draw(denom) < num
    }

    /// Pick one item from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.draw(xs.len() as u64) as usize]
    }

    /// A vector of `len in len_range` elements built by `f`.
    pub fn vec<T>(
        &mut self,
        len_range: std::ops::Range<usize>,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let len = self.usize(len_range);
        (0..len).map(|_| f(self)).collect()
    }
}

/// Property outcome, captured via panic unwinding.
type CaseResult = Result<(), String>;

fn run_case(seed: u64, forced: Option<Vec<u64>>, prop: &dyn Fn(&mut Gen)) -> (CaseResult, Vec<u64>) {
    let mut g = match forced {
        Some(f) => Gen::replay(seed, f),
        None => Gen::new(seed),
    };
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        prop(&mut g);
    }));
    let log = std::mem::take(&mut g.log);
    match result {
        Ok(()) => (Ok(()), log),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            (Err(msg), log)
        }
    }
}

/// Number of cases per property; override with `PUMA_PROP_CASES`.
pub fn default_cases() -> u32 {
    std::env::var("PUMA_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` for `default_cases()` random cases. On failure, shrink
/// the raw draw trace (component-wise halving / zeroing) and panic
/// with the seed + shrunk trace.
pub fn check(name: &str, prop: impl Fn(&mut Gen)) {
    check_cases(name, default_cases(), prop)
}

/// As [`check`] with an explicit case count.
pub fn check_cases(name: &str, cases: u32, prop: impl Fn(&mut Gen)) {
    let base_seed = std::env::var("PUMA_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x9E3779B97F4A7C15u64);
    // Silence the default panic hook while we intentionally catch
    // panics; restore it afterwards.
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let outcome = (|| {
        for case in 0..cases {
            let seed = base_seed ^ (case as u64).wrapping_mul(0xA24B_AED4_963E_E407);
            let (res, log) = run_case(seed, None, &prop);
            if let Err(msg) = res {
                let (slog, smsg) = shrink(seed, log, msg, &prop);
                return Err(format!(
                    "property {name:?} failed (seed={seed}, case {case}/{cases})\n\
                     shrunk raw trace: {slog:?}\nfailure: {smsg}"
                ));
            }
        }
        Ok(())
    })();
    std::panic::set_hook(hook);
    if let Err(msg) = outcome {
        panic!("{msg}");
    }
}

/// Greedy shrink over the raw draw trace: try zeroing, halving, and
/// decrementing each position while the property still fails.
fn shrink(
    seed: u64,
    mut log: Vec<u64>,
    mut msg: String,
    prop: &dyn Fn(&mut Gen),
) -> (Vec<u64>, String) {
    let mut improved = true;
    let mut budget = 2000u32;
    while improved && budget > 0 {
        improved = false;
        for i in 0..log.len() {
            if log[i] == 0 {
                continue;
            }
            for candidate in [0, log[i] / 2, log[i] - 1] {
                if candidate >= log[i] {
                    continue;
                }
                budget = budget.saturating_sub(1);
                if budget == 0 {
                    break;
                }
                let mut trial = log.clone();
                trial[i] = candidate;
                let (res, _) = run_case(seed, Some(trial.clone()), prop);
                if let Err(m) = res {
                    log = trial;
                    msg = m;
                    improved = true;
                    break;
                }
            }
        }
    }
    (log, msg)
}

/// Assertion macro that formats a helpful message.
#[macro_export]
macro_rules! assert_prop {
    ($cond:expr) => {
        if !$cond {
            panic!("assertion failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            panic!("assertion failed: {} — {}", stringify!($cond), format!($($fmt)+));
        }
    };
}
pub use crate::assert_prop;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("xor involutive", |g| {
            let a = g.u64(0..u64::MAX);
            let b = g.u64(0..u64::MAX);
            assert_prop!((a ^ b) ^ b == a);
        });
    }

    #[test]
    fn vec_gen_respects_len() {
        check("vec len", |g| {
            let v = g.vec(0..17, |g| g.bool());
            assert_prop!(v.len() < 17);
        });
    }

    #[test]
    fn failing_property_reports_seed_and_shrinks() {
        let res = std::panic::catch_unwind(|| {
            check_cases("always fails above 10", 16, |g| {
                let v = g.u64(0..1000);
                assert_prop!(v <= 10, "v={v}");
            });
        });
        let msg = match res {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed="), "missing seed in: {msg}");
        // the shrinker should reach the boundary value 11
        assert!(msg.contains("[11]"), "not shrunk to minimum: {msg}");
    }

    #[test]
    fn choose_and_ratio_draw() {
        check("choose in slice", |g| {
            let xs = [1, 2, 3];
            let c = *g.choose(&xs);
            assert_prop!(xs.contains(&c));
            let _ = g.ratio(1, 3);
        });
    }
}
