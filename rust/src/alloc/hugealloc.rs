//! Huge-page-backed allocation (the strongest baseline).
//!
//! Allocations are served from 2 MiB huge pages: physically contiguous
//! within each page and row-aligned when the request is at least a row
//! long (mmap-like natural alignment). What this baseline *lacks* is
//! subarray awareness: operands of one PUD operation are bump-placed
//! wherever the arena cursor happens to be, across huge pages drawn
//! from the general (THP-style) allocator — so whether two operands'
//! rows co-locate in a subarray is luck, improving with allocation
//! size but never guaranteed. That is the paper's observed "up to 60%
//! at large sizes" behaviour.
//!
//! Pages come from the buddy allocator at order 9 (transparent-huge-
//! page style) rather than from PUMA's reserved pool, which models the
//! paper's baseline (ordinary hugetlb/THP usage, no PUD pool).

use anyhow::{bail, Result};
use rustc_hash::FxHashMap;

use crate::os::process::Process;
use crate::os::vma::VmaKind;
use crate::os::{align_up, HUGE_PAGE_ORDER, HUGE_PAGE_SIZE, PAGE_SIZE};

use super::traits::{AllocStats, Allocator, OsCtx};

struct ArenaPage {
    va: u64,
    pfn: u64,
    used: u64,
}

#[derive(Debug, Clone, Copy)]
struct Live {
    /// Huge pages exclusively owned by this allocation (large path).
    owned_va: u64,
    owned_pages: u64,
    /// Requested size (free-side byte accounting).
    len: u64,
}

/// Huge-page arena allocator.
pub struct HugeAlloc {
    row_bytes: u64,
    arena: Option<ArenaPage>,
    /// arena pages kept alive for the allocator's lifetime
    arena_pages: Vec<(u64, u64)>, // (va, pfn)
    live: FxHashMap<u64, Live>,
    stats: AllocStats,
}

impl HugeAlloc {
    pub fn new(row_bytes: u64) -> Self {
        Self {
            row_bytes,
            arena: None,
            arena_pages: Vec::new(),
            live: FxHashMap::default(),
            stats: AllocStats::default(),
        }
    }

    fn new_huge_page(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
    ) -> Result<ArenaPage> {
        let pfn = ctx.buddy.alloc(HUGE_PAGE_ORDER)?;
        let va = proc.mmap(HUGE_PAGE_SIZE, HUGE_PAGE_SIZE, VmaKind::Huge)?;
        proc.map_huge(va, pfn * PAGE_SIZE)?;
        self.stats.alloc_ns += ctx.timing.syscall_ns + ctx.timing.huge_fault_ns;
        self.stats.pages_mapped += HUGE_PAGE_SIZE / PAGE_SIZE;
        self.arena_pages.push((va, pfn));
        Ok(ArenaPage { va, pfn, used: 0 })
    }
}

impl Allocator for HugeAlloc {
    fn name(&self) -> &'static str {
        "hugepages"
    }

    fn alloc(&mut self, ctx: &mut OsCtx, proc: &mut Process, len: u64) -> Result<u64> {
        if len == 0 {
            bail!("hugealloc(0)");
        }
        self.stats.allocs += 1;
        self.stats.bytes_requested += len;

        if len > HUGE_PAGE_SIZE {
            // multi-page path: dedicated consecutive huge pages; VA is
            // contiguous, physical pages are whatever order-9 blocks
            // the buddy returns (not necessarily adjacent).
            let npages = align_up(len, HUGE_PAGE_SIZE) / HUGE_PAGE_SIZE;
            let va = proc.mmap(npages * HUGE_PAGE_SIZE, HUGE_PAGE_SIZE, VmaKind::Huge)?;
            self.stats.alloc_ns += ctx.timing.syscall_ns;
            for i in 0..npages {
                let pfn = ctx.buddy.alloc(HUGE_PAGE_ORDER)?;
                proc.map_huge(va + i * HUGE_PAGE_SIZE, pfn * PAGE_SIZE)?;
                self.stats.alloc_ns += ctx.timing.huge_fault_ns;
                self.stats.pages_mapped += HUGE_PAGE_SIZE / PAGE_SIZE;
            }
            self.live.insert(
                va,
                Live {
                    owned_va: va,
                    owned_pages: npages,
                    len,
                },
            );
            return Ok(va);
        }

        // arena path: bump inside the current huge page, row-aligning
        // requests of at least one row (glibc aligns big chunks too)
        let align = if len >= self.row_bytes {
            self.row_bytes
        } else {
            16
        };
        let need_from = |used: u64| -> u64 { align_up(used, align) };
        let mut arena = match self.arena.take() {
            Some(a) if need_from(a.used) + len <= HUGE_PAGE_SIZE => a,
            _ => self.new_huge_page(ctx, proc)?,
        };
        let off = need_from(arena.used);
        let va = arena.va + off;
        arena.used = off + len;
        self.arena = Some(arena);
        self.live.insert(
            va,
            Live {
                owned_va: 0,
                owned_pages: 0,
                len,
            },
        );
        Ok(va)
    }

    fn free(&mut self, ctx: &mut OsCtx, proc: &mut Process, va: u64) -> Result<()> {
        let live = match self.live.remove(&va) {
            Some(l) => l,
            None => bail!("free of unknown pointer {va:#x}"),
        };
        self.stats.frees += 1;
        self.stats.bytes_freed += live.len;
        if live.owned_pages > 0 {
            for i in 0..live.owned_pages {
                let t = proc.unmap_page(live.owned_va + i * HUGE_PAGE_SIZE)?;
                ctx.buddy.free(t.paddr / PAGE_SIZE, HUGE_PAGE_ORDER);
            }
            proc.unmap_vma(live.owned_va)?;
            self.stats.pages_unmapped +=
                live.owned_pages * (HUGE_PAGE_SIZE / PAGE_SIZE);
            self.stats.alloc_ns += ctx.timing.syscall_ns;
        }
        // arena chunks are recycled with the arena (glibc-like): bytes
        // count as freed, the arena's mapped pages stay resident
        Ok(())
    }

    fn stats(&self) -> AllocStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::os::process::Pid;

    fn ctx() -> OsCtx {
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        OsCtx::boot(scheme, 8, 0, 0).unwrap()
    }

    #[test]
    fn arena_allocs_physically_contiguous() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut h = HugeAlloc::new(8192);
        let va = h.alloc(&mut ctx, &mut proc, 64 * 1024).unwrap();
        let ext = proc.phys_extents(va, 64 * 1024).unwrap();
        assert_eq!(ext.len(), 1, "inside one huge page");
    }

    #[test]
    fn row_sized_allocs_are_row_aligned() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut h = HugeAlloc::new(8192);
        let small = h.alloc(&mut ctx, &mut proc, 100).unwrap();
        let big = h.alloc(&mut ctx, &mut proc, 16 * 1024).unwrap();
        let _ = small;
        let ext = proc.phys_extents(big, 16 * 1024).unwrap();
        assert_eq!(ext[0].paddr % 8192, 0, "row-aligned physical start");
    }

    #[test]
    fn multi_page_path_owns_pages() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut h = HugeAlloc::new(8192);
        let before = ctx.buddy.free_frames();
        let va = h.alloc(&mut ctx, &mut proc, 5 * 1024 * 1024).unwrap();
        assert_eq!(va % HUGE_PAGE_SIZE, 0);
        assert!(proc.phys_extents(va, 5 * 1024 * 1024).is_ok());
        h.free(&mut ctx, &mut proc, va).unwrap();
        assert_eq!(ctx.buddy.free_frames(), before);
        let s = h.stats();
        assert_eq!(s.bytes_freed, 5 * 1024 * 1024);
        assert_eq!(s.pages_unmapped, 3 * (HUGE_PAGE_SIZE / 4096));
    }

    #[test]
    fn arena_rolls_to_next_page_when_full() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut h = HugeAlloc::new(8192);
        let a = h.alloc(&mut ctx, &mut proc, HUGE_PAGE_SIZE - 4096).unwrap();
        let b = h.alloc(&mut ctx, &mut proc, 8192).unwrap();
        let ea = proc.phys_extents(a, 1024).unwrap();
        let eb = proc.phys_extents(b, 1024).unwrap();
        // b lives in a different huge page
        assert_ne!(
            ea[0].paddr / HUGE_PAGE_SIZE,
            eb[0].paddr / HUGE_PAGE_SIZE
        );
    }
}
