//! glibc-style `malloc` simulation.
//!
//! Models the two glibc paths that matter for physical placement:
//!
//! * **small** (< `MMAP_THRESHOLD`): bump allocation inside an arena,
//!   16-byte aligned after a 16-byte chunk header — so returned
//!   pointers are virtually *unaligned* to rows/pages; arena pages
//!   fault in one 4 KiB frame at a time.
//! * **large** (>= threshold): a fresh anonymous mmap — page-aligned
//!   VA, but still demand-paged frame-by-frame.
//!
//! Physical frames come from the churned buddy allocator, so
//! consecutive virtual pages land on scattered physical frames: row
//! alignment and subarray co-location essentially never happen, which
//! is why the paper measures 0% PUD-executable operations here.

use anyhow::{bail, Result};
use rustc_hash::FxHashMap;

use crate::os::process::Process;
use crate::os::vma::VmaKind;
use crate::os::{align_up, PAGE_SIZE};

use super::traits::{AllocStats, Allocator, OsCtx};

/// glibc's default M_MMAP_THRESHOLD.
pub const MMAP_THRESHOLD: u64 = 128 * 1024;
/// Chunk header + alignment, as in glibc (16 bytes on 64-bit).
const CHUNK_HEADER: u64 = 16;
const ARENA_CHUNK: u64 = 1 << 20; // arena grows 1 MiB at a time

#[derive(Debug, Clone, Copy)]
enum AllocKind {
    Small { len: u64 },
    Large { start: u64, pages: u64, len: u64 },
}

/// The malloc simulator (one instance per process under test).
#[derive(Default)]
pub struct MallocSim {
    /// current arena bump region: (next_free_va, end_va)
    arena: Option<(u64, u64)>,
    /// VA actually faulted in so far within the arena (page-granular).
    arena_mapped_to: u64,
    live: FxHashMap<u64, AllocKind>,
    stats: AllocStats,
}

impl MallocSim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fault in frames for `[from, to)` of the arena.
    fn fault_arena(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        to: u64,
    ) -> Result<()> {
        while self.arena_mapped_to < to {
            let va = self.arena_mapped_to;
            let pfn = ctx.buddy.alloc(0)?;
            proc.populate_base(va, 1, || Ok(pfn))?;
            self.stats.pages_mapped += 1;
            self.stats.alloc_ns += ctx.timing.minor_fault_ns;
            self.arena_mapped_to = va + PAGE_SIZE;
        }
        Ok(())
    }
}

impl Allocator for MallocSim {
    fn name(&self) -> &'static str {
        "malloc"
    }

    fn alloc(&mut self, ctx: &mut OsCtx, proc: &mut Process, len: u64) -> Result<u64> {
        if len == 0 {
            bail!("malloc(0)");
        }
        self.stats.allocs += 1;
        self.stats.bytes_requested += len;
        let va = if len >= MMAP_THRESHOLD {
            // large path: fresh mmap, demand-paged scattered frames
            let pages = align_up(len, PAGE_SIZE) / PAGE_SIZE;
            let start = proc.mmap(pages * PAGE_SIZE, PAGE_SIZE, VmaKind::Anon)?;
            self.stats.alloc_ns += ctx.timing.syscall_ns;
            for i in 0..pages {
                let pfn = ctx.buddy.alloc(0)?;
                proc.populate_base(start + i * PAGE_SIZE, 1, || Ok(pfn))?;
                self.stats.pages_mapped += 1;
                self.stats.alloc_ns += ctx.timing.minor_fault_ns;
            }
            self.live
                .insert(start, AllocKind::Large { start, pages, len });
            start
        } else {
            // small path: arena bump with chunk header
            let need = align_up(len + CHUNK_HEADER, 16);
            let (mut next, mut end) = match self.arena {
                Some(a) => a,
                None => (0, 0),
            };
            if next == 0 || next + need > end {
                let grow = align_up(need.max(ARENA_CHUNK), PAGE_SIZE);
                let start = proc.mmap(grow, PAGE_SIZE, VmaKind::Anon)?;
                self.stats.alloc_ns += ctx.timing.syscall_ns;
                next = start;
                end = start + grow;
                self.arena_mapped_to = start;
            }
            let user_va = next + CHUNK_HEADER;
            let new_next = next + need;
            self.arena = Some((new_next, end));
            self.fault_arena(ctx, proc, align_up(new_next, PAGE_SIZE))?;
            self.live.insert(user_va, AllocKind::Small { len });
            user_va
        };
        Ok(va)
    }

    fn free(&mut self, ctx: &mut OsCtx, proc: &mut Process, va: u64) -> Result<()> {
        let kind = match self.live.remove(&va) {
            Some(k) => k,
            None => bail!("free of unknown pointer {va:#x}"),
        };
        self.stats.frees += 1;
        match kind {
            AllocKind::Small { len } => {
                // glibc keeps small chunks in free lists; frames stay
                // with the arena. Nothing unmaps, so `pages_unmapped`
                // intentionally lags `pages_mapped` by the arena size —
                // but the user-visible bytes are released either way.
                self.stats.bytes_freed += len;
            }
            AllocKind::Large { start, pages, len } => {
                for i in 0..pages {
                    let t = proc.unmap_page(start + i * PAGE_SIZE)?;
                    ctx.buddy.free(t.paddr / PAGE_SIZE, 0);
                }
                proc.unmap_vma(start)?;
                self.stats.bytes_freed += len;
                self.stats.pages_unmapped += pages;
                self.stats.alloc_ns += ctx.timing.syscall_ns;
            }
        }
        Ok(())
    }

    fn stats(&self) -> AllocStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::os::process::Pid;

    fn ctx() -> OsCtx {
        let scheme = InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 4,
            subarrays_per_bank: 8,
            rows_per_subarray: 256,
            row_bytes: 4096,
        }); // 32 MiB
        OsCtx::boot(scheme, 4, 2_000, 11).unwrap()
    }

    #[test]
    fn small_allocs_are_unaligned_and_live_in_arena() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut m = MallocSim::new();
        let a = m.alloc(&mut ctx, &mut proc, 100).unwrap();
        let b = m.alloc(&mut ctx, &mut proc, 100).unwrap();
        // chunk headers break page/row alignment
        assert_ne!(a % PAGE_SIZE, 0);
        assert_eq!(a % 16, 0);
        assert!(b > a);
        assert!(b - a < PAGE_SIZE, "same arena");
        assert!(proc.phys_extents(a, 100).is_ok());
    }

    #[test]
    fn large_allocs_get_scattered_frames() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut m = MallocSim::new();
        let va = m.alloc(&mut ctx, &mut proc, 256 * 1024).unwrap();
        assert_eq!(va % PAGE_SIZE, 0);
        let ext = proc.phys_extents(va, 256 * 1024).unwrap();
        // churned buddy => many discontiguous extents
        assert!(
            ext.len() > 8,
            "expected scattered frames, got {} extents",
            ext.len()
        );
    }

    #[test]
    fn free_returns_large_frames() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut m = MallocSim::new();
        let before = ctx.buddy.free_frames();
        let va = m.alloc(&mut ctx, &mut proc, 256 * 1024).unwrap();
        assert!(ctx.buddy.free_frames() < before);
        m.free(&mut ctx, &mut proc, va).unwrap();
        assert_eq!(ctx.buddy.free_frames(), before);
        assert!(m.free(&mut ctx, &mut proc, va).is_err());
        // free-side accounting mirrors the alloc side on the mmap path
        let s = m.stats();
        assert_eq!(s.bytes_freed, 256 * 1024);
        assert_eq!(s.pages_unmapped, s.pages_mapped);
    }

    #[test]
    fn small_free_releases_bytes_but_keeps_arena_pages() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut m = MallocSim::new();
        let va = m.alloc(&mut ctx, &mut proc, 500).unwrap();
        m.free(&mut ctx, &mut proc, va).unwrap();
        let s = m.stats();
        assert_eq!(s.bytes_freed, 500);
        assert_eq!(s.pages_unmapped, 0, "arena frames stay resident");
        assert!(s.pages_mapped > 0);
    }

    #[test]
    fn stats_accumulate() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut m = MallocSim::new();
        m.alloc(&mut ctx, &mut proc, 100).unwrap();
        m.alloc(&mut ctx, &mut proc, 200 * 1024).unwrap();
        let s = m.stats();
        assert_eq!(s.allocs, 2);
        assert_eq!(s.bytes_requested, 100 + 200 * 1024);
        assert!(s.alloc_ns > 0.0);
        assert!(s.pages_mapped >= 50);
    }

    #[test]
    fn zero_len_rejected() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut m = MallocSim::new();
        assert!(m.alloc(&mut ctx, &mut proc, 0).is_err());
    }
}
