//! `posix_memalign` simulation.
//!
//! Returns *virtually* aligned pointers (we align to the DRAM row
//! size, the most favorable choice for PUD), but the physical backing
//! is the same demand-paged, churned-buddy story as `malloc` — so the
//! operands still land in scattered frames and PUD legality fails.
//! The paper notes posix_memalign performs identically to malloc; the
//! motivation bench (E1) confirms the same here.

use anyhow::{bail, Result};
use rustc_hash::FxHashMap;

use crate::os::process::Process;
use crate::os::vma::VmaKind;
use crate::os::{align_up, PAGE_SIZE};

use super::traits::{AllocStats, Allocator, OsCtx};

/// posix_memalign-style allocator with a fixed alignment.
pub struct MemalignSim {
    pub alignment: u64,
    live: FxHashMap<u64, (u64, u64)>, // va -> (pages, requested len)
    stats: AllocStats,
}

impl MemalignSim {
    /// Align to the DRAM row size of `row_bytes` (typical PUD-hopeful
    /// usage: the strongest virtual alignment the API can express).
    pub fn new(alignment: u64) -> Self {
        assert!(alignment.is_power_of_two());
        Self {
            alignment,
            live: FxHashMap::default(),
            stats: AllocStats::default(),
        }
    }
}

impl Allocator for MemalignSim {
    fn name(&self) -> &'static str {
        "posix_memalign"
    }

    fn alloc(&mut self, ctx: &mut OsCtx, proc: &mut Process, len: u64) -> Result<u64> {
        if len == 0 {
            bail!("posix_memalign(0)");
        }
        self.stats.allocs += 1;
        self.stats.bytes_requested += len;
        let pages = align_up(len, PAGE_SIZE) / PAGE_SIZE;
        let va = proc.mmap(
            pages * PAGE_SIZE,
            self.alignment.max(PAGE_SIZE),
            VmaKind::Anon,
        )?;
        self.stats.alloc_ns += ctx.timing.syscall_ns;
        for i in 0..pages {
            let pfn = ctx.buddy.alloc(0)?;
            proc.populate_base(va + i * PAGE_SIZE, 1, || Ok(pfn))?;
            self.stats.pages_mapped += 1;
            self.stats.alloc_ns += ctx.timing.minor_fault_ns;
        }
        self.live.insert(va, (pages, len));
        Ok(va)
    }

    fn free(&mut self, ctx: &mut OsCtx, proc: &mut Process, va: u64) -> Result<()> {
        let (pages, len) = match self.live.remove(&va) {
            Some(p) => p,
            None => bail!("free of unknown pointer {va:#x}"),
        };
        self.stats.frees += 1;
        for i in 0..pages {
            let t = proc.unmap_page(va + i * PAGE_SIZE)?;
            ctx.buddy.free(t.paddr / PAGE_SIZE, 0);
        }
        proc.unmap_vma(va)?;
        self.stats.bytes_freed += len;
        self.stats.pages_unmapped += pages;
        self.stats.alloc_ns += ctx.timing.syscall_ns;
        Ok(())
    }

    fn stats(&self) -> AllocStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::os::process::Pid;

    fn ctx() -> OsCtx {
        let scheme = InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 4,
            subarrays_per_bank: 8,
            rows_per_subarray: 256,
            row_bytes: 4096,
        }); // 32 MiB
        OsCtx::boot(scheme, 4, 2_000, 13).unwrap()
    }

    #[test]
    fn virtually_aligned_physically_scattered() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut m = MemalignSim::new(8192);
        let va = m.alloc(&mut ctx, &mut proc, 64 * 1024).unwrap();
        assert_eq!(va % 8192, 0, "virtual alignment honored");
        let ext = proc.phys_extents(va, 64 * 1024).unwrap();
        assert!(ext.len() > 2, "physical backing still scattered");
    }

    #[test]
    fn free_roundtrip() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut m = MemalignSim::new(4096);
        let before = ctx.buddy.free_frames();
        let va = m.alloc(&mut ctx, &mut proc, 10 * 4096).unwrap();
        m.free(&mut ctx, &mut proc, va).unwrap();
        assert_eq!(ctx.buddy.free_frames(), before);
        let s = m.stats();
        assert_eq!(s.bytes_freed, s.bytes_requested);
        assert_eq!(s.pages_unmapped, s.pages_mapped);
    }

    #[test]
    #[should_panic(expected = "power_of_two")]
    fn non_pow2_alignment_panics() {
        let _ = MemalignSim::new(3000);
    }
}
