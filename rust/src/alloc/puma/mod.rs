//! PUMA — the paper's allocator.
//!
//! Three user-facing APIs (paper §2):
//!
//! * [`PumaAlloc::pim_preallocate`] — move huge pages from the boot
//!   pool into PUMA's region store (the user decides how many, since
//!   huge pages are scarce).
//! * `pim_alloc` (via [`Allocator::alloc`]) — first-operand
//!   allocation: worst-fit over the subarray-indexed ordered array,
//!   maximizing leftover space per subarray so future operands can
//!   co-locate.
//! * `pim_alloc_align` (via [`Allocator::alloc_align`]) — subsequent
//!   operands: look the hint up in the allocation hashmap, then place
//!   each region in the *same subarray* as the corresponding hint
//!   region, falling back to worst-fit only when that subarray is
//!   full. Scattered regions are re-mmapped into contiguous VA.
//!
//! Regions are row-granular (see [`region`]): allocations are rounded
//! up to whole DRAM rows, which is what makes every PUMA operand
//! row-aligned by construction.

pub mod ordered;
pub mod region;

use anyhow::{bail, Context, Result};
use rustc_hash::FxHashMap;

use crate::os::process::Process;
use crate::os::vma::VmaKind;
use crate::os::PAGE_SIZE;

use super::traits::{AllocStats, Allocator, OsCtx};
use ordered::OrderedArray;
use region::{split_huge_page, Region};

/// Region placement policy (the paper uses worst-fit; the others are
/// for the E3 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitPolicy {
    WorstFit,
    BestFit,
    FirstFit,
}

/// A live PUMA allocation: the ordered list of regions backing a
/// contiguous VA range.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub va: u64,
    pub len: u64,
    pub regions: Vec<Region>,
}

/// The PUMA allocator state (kernel-module equivalent).
pub struct PumaAlloc {
    free: OrderedArray,
    /// The allocation hashmap, "indexed by the allocation's virtual
    /// address" (paper §2).
    allocations: FxHashMap<u64, Allocation>,
    pub policy: FitPolicy,
    row_bytes: u64,
    preallocated_pages: usize,
    stats: AllocStats,
}

impl PumaAlloc {
    pub fn new(row_bytes: u64, policy: FitPolicy) -> Self {
        assert!(row_bytes % PAGE_SIZE == 0 || PAGE_SIZE % row_bytes == 0,
            "row size and page size must nest");
        Self {
            free: OrderedArray::new(),
            allocations: FxHashMap::default(),
            policy,
            row_bytes,
            preallocated_pages: 0,
            stats: AllocStats::default(),
        }
    }

    /// `pim_preallocate`: dedicate `n` huge pages from the boot pool
    /// to PUD allocations, splitting them into subarray-indexed
    /// regions.
    pub fn pim_preallocate(&mut self, ctx: &mut OsCtx, n: usize) -> Result<()> {
        for i in 0..n {
            let page = ctx
                .pool
                .alloc()
                .with_context(|| format!("pim_preallocate page {i}/{n}"))?;
            for r in split_huge_page(&ctx.scheme, &page) {
                self.free.insert(r);
            }
            self.preallocated_pages += 1;
            self.stats.alloc_ns += ctx.timing.huge_fault_ns;
        }
        Ok(())
    }

    /// Free regions currently available.
    pub fn free_regions(&self) -> usize {
        self.free.total_free()
    }

    /// Look up a live allocation (used by the coordinator to reach
    /// region metadata without a page-table walk).
    pub fn lookup(&self, va: u64) -> Option<&Allocation> {
        self.allocations.get(&va)
    }

    fn regions_needed(&self, len: u64) -> usize {
        (len.div_ceil(self.row_bytes)) as usize
    }

    fn take_policy(&mut self) -> Option<Region> {
        match self.policy {
            FitPolicy::WorstFit => self.free.take_worst_fit(),
            FitPolicy::BestFit => self.free.take_best_fit(),
            FitPolicy::FirstFit => self.free.take_first_fit(),
        }
    }

    /// Map `regions` into fresh contiguous VA in `proc` and record the
    /// allocation. This is the re-mmap step: regions may come from
    /// different huge pages, yet the user sees one contiguous object.
    fn map_regions(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        regions: Vec<Region>,
        len: u64,
    ) -> Result<u64> {
        let total = regions.len() as u64 * self.row_bytes;
        let va = proc.mmap(total, self.row_bytes.max(PAGE_SIZE), VmaKind::Pud)?;
        self.stats.alloc_ns += ctx.timing.syscall_ns;
        let pages_per_region = self.row_bytes / PAGE_SIZE;
        for (i, r) in regions.iter().enumerate() {
            let base_va = va + i as u64 * self.row_bytes;
            for p in 0..pages_per_region {
                proc.page_table.map(
                    base_va + p * PAGE_SIZE,
                    r.paddr + p * PAGE_SIZE,
                    crate::os::page_table::PageKind::Base,
                )?;
            }
            self.stats.alloc_ns += ctx.timing.remap_region_ns;
            self.stats.pages_mapped += pages_per_region;
        }
        self.allocations.insert(
            va,
            Allocation {
                va,
                len,
                regions,
            },
        );
        Ok(va)
    }
}

impl Allocator for PumaAlloc {
    fn name(&self) -> &'static str {
        "puma"
    }

    /// `pim_alloc`: worst-fit first allocation.
    fn alloc(&mut self, ctx: &mut OsCtx, proc: &mut Process, len: u64) -> Result<u64> {
        if len == 0 {
            bail!("pim_alloc(0)");
        }
        self.stats.allocs += 1;
        self.stats.bytes_requested += len;
        let need = self.regions_needed(len);
        if need > self.free.total_free() {
            bail!(
                "PUD region pool exhausted: need {need}, have {} \
                 (pim_preallocate more huge pages)",
                self.free.total_free()
            );
        }
        let mut regions = Vec::with_capacity(need);
        for _ in 0..need {
            let r = self.take_policy().expect("checked total above");
            self.stats.alloc_ns += ctx.timing.puma_region_ns;
            regions.push(r);
        }
        self.map_regions(ctx, proc, regions, len)
    }

    /// `pim_alloc_align`: co-locate with the hint allocation.
    fn alloc_align(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        len: u64,
        hint: u64,
    ) -> Result<u64> {
        if len == 0 {
            bail!("pim_alloc_align(0)");
        }
        // 1. hashmap lookup; a miss is an error (paper §2 step 1)
        let hint_regions: Vec<Region> = match self.allocations.get(&hint) {
            Some(a) => a.regions.clone(),
            None => bail!("pim_alloc_align: hint {hint:#x} is not a PUMA allocation"),
        };
        self.stats.allocs += 1;
        self.stats.bytes_requested += len;
        let need = self.regions_needed(len);
        if need > self.free.total_free() {
            bail!(
                "PUD region pool exhausted: need {need}, have {}",
                self.free.total_free()
            );
        }
        // 2-4. walk the hint's regions; try same-subarray first, then
        // policy fallback
        let mut regions = Vec::with_capacity(need);
        for i in 0..need {
            let preferred = hint_regions.get(i % hint_regions.len().max(1));
            let r = match preferred.and_then(|p| self.free.take_from(p.sid)) {
                Some(r) => {
                    self.stats.hint_colocated += 1;
                    r
                }
                None => {
                    self.stats.hint_missed += 1;
                    self.take_policy().expect("checked total above")
                }
            };
            self.stats.alloc_ns += ctx.timing.puma_region_ns;
            regions.push(r);
        }
        // 5. re-mmap into contiguous VA
        self.map_regions(ctx, proc, regions, len)
    }

    fn free(&mut self, ctx: &mut OsCtx, proc: &mut Process, va: u64) -> Result<()> {
        let alloc = match self.allocations.remove(&va) {
            Some(a) => a,
            None => bail!("pim_free of unknown pointer {va:#x}"),
        };
        self.stats.frees += 1;
        let pages_per_region = self.row_bytes / PAGE_SIZE;
        for (i, r) in alloc.regions.iter().enumerate() {
            let base_va = va + i as u64 * self.row_bytes;
            for p in 0..pages_per_region {
                proc.unmap_page(base_va + p * PAGE_SIZE)?;
            }
            self.free.insert(*r);
        }
        proc.unmap_vma(va)?;
        self.stats.alloc_ns += ctx.timing.syscall_ns;
        Ok(())
    }

    fn stats(&self) -> AllocStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::os::process::Pid;

    fn ctx() -> OsCtx {
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        OsCtx::boot(scheme, 32, 1_000, 3).unwrap()
    }

    fn puma(ctx: &mut OsCtx, pages: usize) -> PumaAlloc {
        let mut p = PumaAlloc::new(
            ctx.scheme.geometry.row_bytes as u64,
            FitPolicy::WorstFit,
        );
        p.pim_preallocate(ctx, pages).unwrap();
        p
    }

    #[test]
    fn preallocate_splits_pages_into_regions() {
        let mut ctx = ctx();
        let p = puma(&mut ctx, 4);
        // 4 pages x 256 rows, minus reserved overlaps
        assert!(p.free_regions() > 900 && p.free_regions() <= 1024);
    }

    #[test]
    fn alloc_returns_row_aligned_contiguous_va() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 4);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let va = p.alloc(&mut ctx, &mut proc, 5 * row + 10).unwrap();
        assert_eq!(va % row, 0);
        // 6 regions mapped contiguously in VA
        let ext = proc.phys_extents(va, 6 * row).unwrap();
        let total: u64 = ext.iter().map(|e| e.len).sum();
        assert_eq!(total, 6 * row);
        // every region row-aligned physically
        let alloc = p.lookup(va).unwrap();
        assert_eq!(alloc.regions.len(), 6);
        for r in &alloc.regions {
            assert_eq!(r.paddr % row, 0);
        }
    }

    #[test]
    fn worst_fit_draws_from_fullest_subarrays() {
        // pim_alloc takes each region from the currently-fullest
        // subarray (paper §2). With a fresh pool all subarrays are
        // equally full, so an 8-region allocation spreads over the 8
        // lowest sids — and crucially leaves every touched subarray
        // with maximal remaining space for the hint-aligned operands.
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 8);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let max_before = p.free.occupancy()[0].1;
        let va = p.alloc(&mut ctx, &mut proc, 8 * row).unwrap();
        let alloc = p.lookup(va).unwrap();
        for r in &alloc.regions {
            // every drawn subarray still has plenty of room for the
            // aligned second/third operands
            assert!(p.free.free_in(r.sid) >= max_before - 2);
        }
    }

    #[test]
    fn alloc_align_colocates_with_hint() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 8);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let a = p.alloc(&mut ctx, &mut proc, 4 * row).unwrap();
        let b = p.alloc_align(&mut ctx, &mut proc, 4 * row, a).unwrap();
        let c = p.alloc_align(&mut ctx, &mut proc, 4 * row, a).unwrap();
        let ra = p.lookup(a).unwrap().regions.clone();
        let rb = p.lookup(b).unwrap().regions.clone();
        let rc = p.lookup(c).unwrap().regions.clone();
        let colocated = ra
            .iter()
            .zip(&rb)
            .zip(&rc)
            .filter(|((x, y), z)| x.sid == y.sid && y.sid == z.sid)
            .count();
        assert_eq!(colocated, 4, "all rows of A/B/C share subarrays");
        assert!(p.stats().hint_colocated >= 8);
    }

    #[test]
    fn alloc_align_requires_valid_hint() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 2);
        assert!(p.alloc_align(&mut ctx, &mut proc, 4096, 0xDEAD000).is_err());
    }

    #[test]
    fn exhaustion_reports_helpfully() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 1);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let err = p
            .alloc(&mut ctx, &mut proc, 10_000 * row)
            .unwrap_err()
            .to_string();
        assert!(err.contains("pim_preallocate"), "{err}");
    }

    #[test]
    fn free_recycles_regions() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 2);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let before = p.free_regions();
        let va = p.alloc(&mut ctx, &mut proc, 10 * row).unwrap();
        assert_eq!(p.free_regions(), before - 10);
        p.free(&mut ctx, &mut proc, va).unwrap();
        assert_eq!(p.free_regions(), before);
        assert!(p.free(&mut ctx, &mut proc, va).is_err());
    }

    #[test]
    fn colocated_allocations_pass_pud_legality() {
        // the whole point: A, B, C from pim_alloc/pim_alloc_align must
        // produce 100% PUD-legal row plans
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 8);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let len = 16 * row;
        let a = p.alloc(&mut ctx, &mut proc, len).unwrap();
        let b = p.alloc_align(&mut ctx, &mut proc, len, a).unwrap();
        let c = p.alloc_align(&mut ctx, &mut proc, len, a).unwrap();
        let ea = proc.phys_extents(a, len).unwrap();
        let eb = proc.phys_extents(b, len).unwrap();
        let ec = proc.phys_extents(c, len).unwrap();
        let plan =
            crate::pud::legality::check_rowwise(&ctx.scheme, &[&ec, &ea, &eb], len);
        let frac = crate::pud::legality::pud_fraction(&plan);
        assert!(
            frac > 0.95,
            "PUMA operands should be nearly fully PUD-legal, got {frac}"
        );
    }
}
