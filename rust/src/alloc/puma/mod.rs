//! PUMA — the paper's allocator, plus the allocation lifecycle the
//! paper leaves to future work (reclamation + compaction, DESIGN.md
//! §8).
//!
//! Three user-facing APIs (paper §2):
//!
//! * [`PumaAlloc::pim_preallocate`] — move huge pages from the boot
//!   pool into PUMA's region store (the user decides how many, since
//!   huge pages are scarce).
//! * `pim_alloc` (via [`Allocator::alloc`]) — first-operand
//!   allocation: worst-fit over the subarray-indexed ordered array,
//!   maximizing leftover space per subarray so future operands can
//!   co-locate.
//! * `pim_alloc_align` (via [`Allocator::alloc_align`]) — subsequent
//!   operands: look the hint up in the allocation hashmap, then place
//!   each region in the *same subarray* as the corresponding hint
//!   region, falling back to worst-fit only when that subarray is
//!   full. Scattered regions are re-mmapped into contiguous VA.
//!
//! Lifecycle APIs added on top (this reproduction):
//!
//! * [`PumaAlloc::reclaim`] — the free-path coalescer's second half:
//!   freed rows are tracked against the huge page they were carved
//!   from, and pages whose rows have *all* been freed are reassembled
//!   and returned to the boot pool.
//! * [`PumaAlloc::compact`](crate::alloc::puma::compact) — RowClone-
//!   driven migration that repairs lost subarray co-location and
//!   evacuates nearly-empty pages so [`PumaAlloc::reclaim`] can return
//!   them (see [`compact`]).
//!
//! Regions are row-granular (see [`region`]): allocations are rounded
//! up to whole DRAM rows, which is what makes every PUMA operand
//! row-aligned by construction.

pub mod compact;
pub mod ordered;
pub mod region;

pub use compact::CompactReport;

use anyhow::{bail, Context, Result};
use rustc_hash::FxHashMap;

use crate::os::hugepage::HugePage;
use crate::os::process::{Pid, Process};
use crate::os::vma::VmaKind;
use crate::os::PAGE_SIZE;

use super::traits::{AllocStats, Allocator, OsCtx};
use ordered::OrderedArray;
use region::{split_huge_page, Region};

/// Region placement policy. The paper uses worst-fit (take from the
/// *fullest* subarray, maximizing the leftover room co-located
/// operands will need); best-fit and first-fit exist for the E3
/// ablation.
///
/// ```
/// use puma::alloc::puma::FitPolicy;
/// assert_ne!(FitPolicy::WorstFit, FitPolicy::BestFit);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitPolicy {
    /// Paper default: draw from the subarray with the most free regions.
    WorstFit,
    /// Ablation: draw from the least-populated non-empty subarray.
    BestFit,
    /// Ablation: draw from the lowest-numbered non-empty subarray.
    FirstFit,
}

/// A live PUMA allocation: the ordered list of regions backing a
/// contiguous VA range.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub va: u64,
    pub len: u64,
    pub regions: Vec<Region>,
}

/// Per-huge-page bookkeeping for the free-path coalescer.
#[derive(Debug, Clone, Copy)]
struct PageMeta {
    page: HugePage,
    /// Regions carved from this page at `pim_preallocate` time
    /// (reserved Ambit rows are skipped, so this can be < rows/page).
    carved: usize,
    /// Carved regions currently sitting in the free store. When
    /// `free == carved` the page is fully reassembled and
    /// [`PumaAlloc::reclaim`] can hand it back to the boot pool.
    free: usize,
}

/// The PUMA allocator state (kernel-module equivalent).
pub struct PumaAlloc {
    free: OrderedArray,
    /// The allocation hashmap, "indexed by the allocation's virtual
    /// address" (paper §2) — and by owning process, since distinct
    /// address spaces reuse the same VA range.
    allocations: FxHashMap<(Pid, u64), Allocation>,
    /// Huge-page directory: page base -> carved/free region counts.
    /// This is the coalescer: rows are fixed-size, so "merging
    /// adjacent freed rows" means counting a page's rows back together
    /// until the whole 2 MiB page has reassembled.
    pages: FxHashMap<u64, PageMeta>,
    /// `pim_alloc_align` lineage: (pid, aligned va) -> hint va. The
    /// compactor uses this to know *what* an allocation was supposed
    /// to co-locate with.
    align_groups: FxHashMap<(Pid, u64), u64>,
    pub policy: FitPolicy,
    row_bytes: u64,
    preallocated_pages: usize,
    stats: AllocStats,
}

impl PumaAlloc {
    pub fn new(row_bytes: u64, policy: FitPolicy) -> Self {
        assert!(row_bytes % PAGE_SIZE == 0 || PAGE_SIZE % row_bytes == 0,
            "row size and page size must nest");
        Self {
            free: OrderedArray::new(),
            allocations: FxHashMap::default(),
            pages: FxHashMap::default(),
            align_groups: FxHashMap::default(),
            policy,
            row_bytes,
            preallocated_pages: 0,
            stats: AllocStats::default(),
        }
    }

    /// `pim_preallocate`: dedicate `n` huge pages from the boot pool
    /// to PUD allocations, splitting them into subarray-indexed
    /// regions.
    pub fn pim_preallocate(&mut self, ctx: &mut OsCtx, n: usize) -> Result<()> {
        for i in 0..n {
            let page = ctx
                .pool
                .alloc()
                .with_context(|| format!("pim_preallocate page {i}/{n}"))?;
            let regions = split_huge_page(&ctx.scheme, &page);
            self.pages.insert(
                page.phys_addr(),
                PageMeta {
                    page,
                    carved: regions.len(),
                    free: regions.len(),
                },
            );
            for r in regions {
                self.free.insert(r);
            }
            self.preallocated_pages += 1;
            self.stats.alloc_ns += ctx.timing.huge_fault_ns;
        }
        self.refresh_gauges();
        Ok(())
    }

    /// Free regions currently available.
    pub fn free_regions(&self) -> usize {
        self.free.total_free()
    }

    /// Huge pages currently held by the allocator (shrinks when
    /// [`PumaAlloc::reclaim`] returns pages to the boot pool).
    pub fn preallocated(&self) -> usize {
        self.pages.len()
    }

    /// Total regions carved from the currently-held pages.
    pub fn carved_regions(&self) -> usize {
        self.pages.values().map(|m| m.carved).sum()
    }

    /// Regions backing live allocations (accounting identity:
    /// `carved_regions == free_regions + live_regions` at all times).
    pub fn live_regions(&self) -> usize {
        self.allocations.values().map(|a| a.regions.len()).sum()
    }

    /// Look up a live allocation of process `pid` (used by tests and
    /// the compactor to reach region metadata without a page-table
    /// walk).
    pub fn lookup(&self, pid: Pid, va: u64) -> Option<&Allocation> {
        self.allocations.get(&(pid, va))
    }

    /// The hint `va` was aligned to, if it was placed via
    /// `pim_alloc_align`.
    pub fn hint_of(&self, pid: Pid, va: u64) -> Option<u64> {
        self.align_groups.get(&(pid, va)).copied()
    }

    /// Per-page usage, sorted by page base: `(base, carved, free)`.
    pub fn page_usage(&self) -> Vec<(u64, usize, usize)> {
        let mut v: Vec<(u64, usize, usize)> = self
            .pages
            .iter()
            .map(|(base, m)| (*base, m.carved, m.free))
            .collect();
        v.sort_unstable();
        v
    }

    /// Allocated fraction of the carved pool (gauge; 0 with no pages).
    pub fn occupancy(&self) -> f64 {
        let carved = self.carved_regions();
        if carved == 0 {
            return 0.0;
        }
        (carved - self.free.total_free()) as f64 / carved as f64
    }

    /// Fraction of held pages that are partially free — holding freed
    /// rows, yet pinned by still-live rows so they cannot be
    /// reclaimed (gauge; 0 with no pages).
    pub fn fragmentation(&self) -> f64 {
        if self.pages.is_empty() {
            return 0.0;
        }
        let partial = self
            .pages
            .values()
            .filter(|m| m.free > 0 && m.free < m.carved)
            .count();
        partial as f64 / self.pages.len() as f64
    }

    /// The free-path coalescer's give-back step: return every fully
    /// reassembled huge page (all carved rows back in the free store)
    /// to the boot pool. Returns the number of pages released.
    ///
    /// This is an explicit call rather than an automatic side effect
    /// of `free` so a workload can keep its pool warm across phases; a
    /// kernel would drive it from a memory-pressure watermark.
    pub fn reclaim(&mut self, ctx: &mut OsCtx) -> Result<usize> {
        let mut full: Vec<u64> = self
            .pages
            .iter()
            .filter(|(_, m)| m.carved > 0 && m.free == m.carved)
            .map(|(base, _)| *base)
            .collect();
        full.sort_unstable();
        for base in &full {
            let meta = self.pages.remove(base).expect("page listed above");
            for r in split_huge_page(&ctx.scheme, &meta.page) {
                if !self.free.remove(&r) {
                    bail!(
                        "reclaim invariant broken: region {:#x} of page {:#x} \
                         not in the free store",
                        r.paddr,
                        base
                    );
                }
            }
            ctx.pool.release(meta.page);
            self.preallocated_pages -= 1;
            self.stats.pages_reclaimed += 1;
            self.stats.alloc_ns += ctx.timing.reclaim_page_ns;
        }
        self.refresh_gauges();
        Ok(full.len())
    }

    fn regions_needed(&self, len: u64) -> usize {
        (len.div_ceil(self.row_bytes)) as usize
    }

    /// Page-directory bookkeeping when a region leaves the free store.
    fn note_taken(&mut self, r: &Region) {
        if let Some(m) = self.pages.get_mut(&r.page_base()) {
            debug_assert!(m.free > 0, "page free-count underflow");
            m.free -= 1;
        }
    }

    /// Return a region to the free store, keeping the page directory
    /// in step (the coalescer's count-back-together step).
    fn insert_free(&mut self, r: Region) {
        if let Some(m) = self.pages.get_mut(&r.page_base()) {
            debug_assert!(m.free < m.carved, "page free-count overflow");
            m.free += 1;
        }
        self.free.insert(r);
    }

    fn refresh_gauges(&mut self) {
        self.stats.pool_free_regions = self.free.total_free() as u64;
        self.stats.pool_occupancy = self.occupancy();
        self.stats.fragmentation = self.fragmentation();
    }

    fn take_policy(&mut self) -> Option<Region> {
        let r = match self.policy {
            FitPolicy::WorstFit => self.free.take_worst_fit(),
            FitPolicy::BestFit => self.free.take_best_fit(),
            FitPolicy::FirstFit => self.free.take_first_fit(),
        }?;
        self.note_taken(&r);
        Some(r)
    }

    /// Map `regions` into fresh contiguous VA in `proc` and record the
    /// allocation. This is the re-mmap step: regions may come from
    /// different huge pages, yet the user sees one contiguous object.
    fn map_regions(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        regions: Vec<Region>,
        len: u64,
    ) -> Result<u64> {
        let total = regions.len() as u64 * self.row_bytes;
        let va = proc.mmap(total, self.row_bytes.max(PAGE_SIZE), VmaKind::Pud)?;
        self.stats.alloc_ns += ctx.timing.syscall_ns;
        let pages_per_region = self.row_bytes / PAGE_SIZE;
        for (i, r) in regions.iter().enumerate() {
            let base_va = va + i as u64 * self.row_bytes;
            for p in 0..pages_per_region {
                proc.page_table.map(
                    base_va + p * PAGE_SIZE,
                    r.paddr + p * PAGE_SIZE,
                    crate::os::page_table::PageKind::Base,
                )?;
            }
            self.stats.alloc_ns += ctx.timing.remap_region_ns;
            self.stats.pages_mapped += pages_per_region;
        }
        self.allocations.insert(
            (proc.pid, va),
            Allocation {
                va,
                len,
                regions,
            },
        );
        self.refresh_gauges();
        Ok(va)
    }
}

impl Allocator for PumaAlloc {
    fn name(&self) -> &'static str {
        "puma"
    }

    /// `pim_alloc`: worst-fit first allocation.
    fn alloc(&mut self, ctx: &mut OsCtx, proc: &mut Process, len: u64) -> Result<u64> {
        if len == 0 {
            bail!("pim_alloc(0)");
        }
        self.stats.allocs += 1;
        self.stats.bytes_requested += len;
        let need = self.regions_needed(len);
        if need > self.free.total_free() {
            bail!(
                "PUD region pool exhausted: need {need}, have {} \
                 (pim_preallocate more huge pages)",
                self.free.total_free()
            );
        }
        let mut regions = Vec::with_capacity(need);
        for _ in 0..need {
            let r = self.take_policy().expect("checked total above");
            self.stats.alloc_ns += ctx.timing.puma_region_ns;
            regions.push(r);
        }
        self.map_regions(ctx, proc, regions, len)
    }

    /// Placement-spread allocation (the sharded-layout anchor path):
    /// draw from bank `spread % total_banks`, preferring the richest
    /// subarray of that bank and *sticking* to the first subarray
    /// chosen so the allocation — and everything later hinted to it —
    /// stays single-subarray. Falls back to the plain fit policy only
    /// when the target bank has no free regions left. Cycling `spread`
    /// across shards therefore lands sibling shards on disjoint banks
    /// even though each shard is individually fully co-located.
    fn alloc_spread(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        len: u64,
        spread: u32,
    ) -> Result<u64> {
        if len == 0 {
            bail!("pim_alloc_spread(0)");
        }
        self.stats.allocs += 1;
        self.stats.bytes_requested += len;
        let need = self.regions_needed(len);
        if need > self.free.total_free() {
            bail!(
                "PUD region pool exhausted: need {need}, have {} \
                 (pim_preallocate more huge pages)",
                self.free.total_free()
            );
        }
        let spb = ctx.scheme.geometry.subarrays_per_bank;
        let banks = ctx.scheme.geometry.total_banks().max(1);
        let bank = spread % banks;
        let lo = crate::dram::geometry::SubarrayId(bank * spb);
        let hi = crate::dram::geometry::SubarrayId((bank + 1) * spb);
        let mut sticky: Option<crate::dram::geometry::SubarrayId> = None;
        let mut regions = Vec::with_capacity(need);
        for _ in 0..need {
            let mut r = match sticky {
                Some(sid) => self.free.take_from(sid),
                None => None,
            };
            if r.is_none() {
                r = self.free.take_worst_fit_in(lo, hi);
            }
            let r = match r {
                Some(r) => {
                    self.note_taken(&r);
                    sticky = Some(r.sid);
                    r
                }
                // target bank exhausted: cross-bank policy fallback
                None => self.take_policy().expect("checked total above"),
            };
            self.stats.alloc_ns += ctx.timing.puma_region_ns;
            regions.push(r);
        }
        self.map_regions(ctx, proc, regions, len)
    }

    /// `pim_alloc_align`: co-locate with the hint allocation.
    fn alloc_align(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        len: u64,
        hint: u64,
    ) -> Result<u64> {
        if len == 0 {
            bail!("pim_alloc_align(0)");
        }
        // 1. hashmap lookup; a miss is an error (paper §2 step 1)
        let hint_regions: Vec<Region> = match self.allocations.get(&(proc.pid, hint)) {
            Some(a) => a.regions.clone(),
            None => bail!("pim_alloc_align: hint {hint:#x} is not a PUMA allocation"),
        };
        self.stats.allocs += 1;
        self.stats.bytes_requested += len;
        let need = self.regions_needed(len);
        if need > self.free.total_free() {
            bail!(
                "PUD region pool exhausted: need {need}, have {}",
                self.free.total_free()
            );
        }
        // 2-4. walk the hint's regions; try same-subarray first, then
        // policy fallback
        let mut regions = Vec::with_capacity(need);
        for i in 0..need {
            let preferred = hint_regions.get(i % hint_regions.len().max(1));
            let r = match preferred.and_then(|p| self.free.take_from(p.sid)) {
                Some(r) => {
                    self.note_taken(&r);
                    self.stats.hint_colocated += 1;
                    r
                }
                None => {
                    self.stats.hint_missed += 1;
                    self.take_policy().expect("checked total above")
                }
            };
            self.stats.alloc_ns += ctx.timing.puma_region_ns;
            regions.push(r);
        }
        // 5. re-mmap into contiguous VA
        let va = self.map_regions(ctx, proc, regions, len)?;
        self.align_groups.insert((proc.pid, va), hint);
        Ok(va)
    }

    fn free(&mut self, ctx: &mut OsCtx, proc: &mut Process, va: u64) -> Result<()> {
        let alloc = match self.allocations.remove(&(proc.pid, va)) {
            Some(a) => a,
            None => bail!("pim_free of unknown pointer {va:#x}"),
        };
        self.stats.frees += 1;
        self.stats.bytes_freed += alloc.len;
        let pages_per_region = self.row_bytes / PAGE_SIZE;
        for (i, r) in alloc.regions.iter().enumerate() {
            let base_va = va + i as u64 * self.row_bytes;
            for p in 0..pages_per_region {
                proc.unmap_page(base_va + p * PAGE_SIZE)?;
            }
            self.stats.pages_unmapped += pages_per_region;
            self.insert_free(*r);
        }
        proc.unmap_vma(va)?;
        self.stats.alloc_ns += ctx.timing.syscall_ns;
        // drop co-location lineage involving this VA, in either role
        let pid = proc.pid;
        self.align_groups
            .retain(|(p, aligned), hint| !(*p == pid && (*aligned == va || *hint == va)));
        self.refresh_gauges();
        Ok(())
    }

    /// Co-location key: the subarray of the allocation's first region
    /// (hint-aligned and sticky-spread allocations are single-subarray,
    /// so the first region identifies the whole placement).
    fn locus(&self, pid: Pid, va: u64) -> Option<u64> {
        self.lookup(pid, va)
            .and_then(|a| a.regions.first())
            .map(|r| r.sid.0 as u64)
    }

    fn stats(&self) -> AllocStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::os::process::Pid;

    fn ctx() -> OsCtx {
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        OsCtx::boot(scheme, 32, 1_000, 3).unwrap()
    }

    fn puma(ctx: &mut OsCtx, pages: usize) -> PumaAlloc {
        let mut p = PumaAlloc::new(
            ctx.scheme.geometry.row_bytes as u64,
            FitPolicy::WorstFit,
        );
        p.pim_preallocate(ctx, pages).unwrap();
        p
    }

    #[test]
    fn preallocate_splits_pages_into_regions() {
        let mut ctx = ctx();
        let p = puma(&mut ctx, 4);
        // 4 pages x 256 rows, minus reserved overlaps
        assert!(p.free_regions() > 900 && p.free_regions() <= 1024);
        assert_eq!(p.preallocated(), 4);
        assert_eq!(p.carved_regions(), p.free_regions());
        assert_eq!(p.stats().pool_occupancy, 0.0);
        assert_eq!(p.stats().fragmentation, 0.0);
    }

    #[test]
    fn alloc_returns_row_aligned_contiguous_va() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 4);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let va = p.alloc(&mut ctx, &mut proc, 5 * row + 10).unwrap();
        assert_eq!(va % row, 0);
        // 6 regions mapped contiguously in VA
        let ext = proc.phys_extents(va, 6 * row).unwrap();
        let total: u64 = ext.iter().map(|e| e.len).sum();
        assert_eq!(total, 6 * row);
        // every region row-aligned physically
        let alloc = p.lookup(Pid(1), va).unwrap();
        assert_eq!(alloc.regions.len(), 6);
        for r in &alloc.regions {
            assert_eq!(r.paddr % row, 0);
        }
    }

    #[test]
    fn worst_fit_draws_from_fullest_subarrays() {
        // pim_alloc takes each region from the currently-fullest
        // subarray (paper §2). With a fresh pool all subarrays are
        // equally full, so an 8-region allocation spreads over the 8
        // lowest sids — and crucially leaves every touched subarray
        // with maximal remaining space for the hint-aligned operands.
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 8);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let max_before = p.free.occupancy()[0].1;
        let va = p.alloc(&mut ctx, &mut proc, 8 * row).unwrap();
        let alloc = p.lookup(Pid(1), va).unwrap();
        for r in &alloc.regions {
            // every drawn subarray still has plenty of room for the
            // aligned second/third operands
            assert!(p.free.free_in(r.sid) >= max_before - 2);
        }
    }

    #[test]
    fn alloc_align_colocates_with_hint() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 8);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let a = p.alloc(&mut ctx, &mut proc, 4 * row).unwrap();
        let b = p.alloc_align(&mut ctx, &mut proc, 4 * row, a).unwrap();
        let c = p.alloc_align(&mut ctx, &mut proc, 4 * row, a).unwrap();
        let ra = p.lookup(Pid(1), a).unwrap().regions.clone();
        let rb = p.lookup(Pid(1), b).unwrap().regions.clone();
        let rc = p.lookup(Pid(1), c).unwrap().regions.clone();
        let colocated = ra
            .iter()
            .zip(&rb)
            .zip(&rc)
            .filter(|((x, y), z)| x.sid == y.sid && y.sid == z.sid)
            .count();
        assert_eq!(colocated, 4, "all rows of A/B/C share subarrays");
        assert!(p.stats().hint_colocated >= 8);
        assert_eq!(p.hint_of(Pid(1), b), Some(a));
        assert_eq!(p.hint_of(Pid(1), a), None);
    }

    #[test]
    fn alloc_spread_cycles_banks_and_stays_single_subarray() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 8);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let spb = ctx.scheme.geometry.subarrays_per_bank;
        let banks = ctx.scheme.geometry.total_banks();
        let mut seen = Vec::new();
        for k in 0..banks.min(4) {
            let va = p.alloc_spread(&mut ctx, &mut proc, 4 * row, k).unwrap();
            let regions = &p.lookup(Pid(1), va).unwrap().regions;
            assert_eq!(regions.len(), 4);
            let sid0 = regions[0].sid;
            assert!(
                regions.iter().all(|r| r.sid == sid0),
                "spread allocation sticks to one subarray"
            );
            assert_eq!(sid0.0 / spb, k, "shard {k} lands on bank {k}");
            seen.push(sid0.0 / spb);
            // hint-chained follow-ups co-locate with the anchor
            let b = p.alloc_align(&mut ctx, &mut proc, 4 * row, va).unwrap();
            let rb = &p.lookup(Pid(1), b).unwrap().regions;
            assert!(rb.iter().all(|r| r.sid == sid0));
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), banks.min(4) as usize, "banks are disjoint");
        // spread indices past the bank count wrap deterministically
        let va = p
            .alloc_spread(&mut ctx, &mut proc, row, banks + 1)
            .unwrap();
        let sid = p.lookup(Pid(1), va).unwrap().regions[0].sid;
        assert_eq!(sid.0 / spb, 1);
    }

    #[test]
    fn alloc_spread_falls_back_when_the_bank_is_exhausted() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 2);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let spb = ctx.scheme.geometry.subarrays_per_bank;
        // drain bank 0 completely
        let mut drained = 0usize;
        loop {
            let free_in_bank: usize = (0..spb)
                .map(|s| p.free.free_in(crate::dram::geometry::SubarrayId(s)))
                .sum();
            if free_in_bank == 0 {
                break;
            }
            p.alloc_spread(&mut ctx, &mut proc, row, 0).unwrap();
            drained += 1;
        }
        assert!(drained > 0);
        // the next spread-0 allocation still succeeds, elsewhere
        let va = p.alloc_spread(&mut ctx, &mut proc, row, 0).unwrap();
        let sid = p.lookup(Pid(1), va).unwrap().regions[0].sid;
        assert_ne!(sid.0 / spb, 0, "fallback leaves the exhausted bank");
    }

    #[test]
    fn alloc_align_requires_valid_hint() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 2);
        assert!(p.alloc_align(&mut ctx, &mut proc, 4096, 0xDEAD000).is_err());
    }

    #[test]
    fn exhaustion_reports_helpfully() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 1);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let err = p
            .alloc(&mut ctx, &mut proc, 10_000 * row)
            .unwrap_err()
            .to_string();
        assert!(err.contains("pim_preallocate"), "{err}");
    }

    #[test]
    fn free_recycles_regions() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 2);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let before = p.free_regions();
        let va = p.alloc(&mut ctx, &mut proc, 10 * row).unwrap();
        assert_eq!(p.free_regions(), before - 10);
        assert!(p.stats().pool_occupancy > 0.0);
        p.free(&mut ctx, &mut proc, va).unwrap();
        assert_eq!(p.free_regions(), before);
        assert_eq!(p.stats().pool_occupancy, 0.0);
        assert!(p.free(&mut ctx, &mut proc, va).is_err());
    }

    #[test]
    fn allocations_keyed_per_process() {
        // two processes get identical VAs from their own address
        // spaces; the shared kernel allocator must keep them apart
        let mut ctx = ctx();
        let mut p1 = Process::new(Pid(1));
        let mut p2 = Process::new(Pid(2));
        let mut p = puma(&mut ctx, 4);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let va1 = p.alloc(&mut ctx, &mut p1, 2 * row).unwrap();
        let va2 = p.alloc(&mut ctx, &mut p2, 2 * row).unwrap();
        assert_eq!(va1, va2, "fresh address spaces hand out the same VA");
        let r1 = p.lookup(Pid(1), va1).unwrap().regions.clone();
        let r2 = p.lookup(Pid(2), va2).unwrap().regions.clone();
        assert_ne!(r1[0].paddr, r2[0].paddr, "distinct physical backing");
        p.free(&mut ctx, &mut p1, va1).unwrap();
        assert!(p.lookup(Pid(1), va1).is_none());
        assert!(p.lookup(Pid(2), va2).is_some(), "pid 2 untouched");
        p.free(&mut ctx, &mut p2, va2).unwrap();
    }

    #[test]
    fn reclaim_returns_fully_free_pages() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 3);
        let pool_before = ctx.pool.available();
        let row = ctx.scheme.geometry.row_bytes as u64;
        // nothing allocated: every page is fully free -> all reclaimed
        let va = p.alloc(&mut ctx, &mut proc, 4 * row).unwrap();
        let reclaimed = p.reclaim(&mut ctx).unwrap();
        assert_eq!(reclaimed, 2, "two untouched pages go back");
        assert_eq!(ctx.pool.available(), pool_before + 2);
        assert_eq!(p.preallocated(), 1);
        // the pinned page stays usable
        assert!(p.lookup(Pid(1), va).is_some());
        assert_eq!(
            p.carved_regions(),
            p.free_regions() + p.live_regions(),
            "accounting identity"
        );
        // free the allocation: now the last page reassembles too
        p.free(&mut ctx, &mut proc, va).unwrap();
        assert_eq!(p.reclaim(&mut ctx).unwrap(), 1);
        assert_eq!(ctx.pool.available(), pool_before + 3);
        assert_eq!(p.free_regions(), 0);
        assert_eq!(p.stats().pages_reclaimed, 3);
        // and the pool can be re-primed
        p.pim_preallocate(&mut ctx, 2).unwrap();
        assert!(p.alloc(&mut ctx, &mut proc, row).is_ok());
    }

    #[test]
    fn partial_pages_are_not_reclaimed() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 1);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let va = p.alloc(&mut ctx, &mut proc, row).unwrap();
        assert_eq!(p.reclaim(&mut ctx).unwrap(), 0, "page pinned by one row");
        assert!(p.stats().fragmentation > 0.0);
        p.free(&mut ctx, &mut proc, va).unwrap();
        assert_eq!(p.stats().fragmentation, 0.0);
        assert_eq!(p.reclaim(&mut ctx).unwrap(), 1);
    }

    #[test]
    fn colocated_allocations_pass_pud_legality() {
        // the whole point: A, B, C from pim_alloc/pim_alloc_align must
        // produce 100% PUD-legal row plans
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut p = puma(&mut ctx, 8);
        let row = ctx.scheme.geometry.row_bytes as u64;
        let len = 16 * row;
        let a = p.alloc(&mut ctx, &mut proc, len).unwrap();
        let b = p.alloc_align(&mut ctx, &mut proc, len, a).unwrap();
        let c = p.alloc_align(&mut ctx, &mut proc, len, a).unwrap();
        let ea = proc.phys_extents(a, len).unwrap();
        let eb = proc.phys_extents(b, len).unwrap();
        let ec = proc.phys_extents(c, len).unwrap();
        let plan =
            crate::pud::legality::check_rowwise(&ctx.scheme, &[&ec, &ea, &eb], len);
        let frac = crate::pud::legality::pud_fraction(&plan);
        assert!(
            frac > 0.95,
            "PUMA operands should be nearly fully PUD-legal, got {frac}"
        );
    }
}
