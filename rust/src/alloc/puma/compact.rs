//! RowClone-driven compaction: the allocator using the PUD substrate
//! it serves (DESIGN.md §8).
//!
//! Two kinds of migration, planned together and executed as one
//! coordinator batch:
//!
//! * **Co-location repair** — an allocation placed by
//!   `pim_alloc_align` under pool pressure may hold regions outside
//!   its hint's subarrays (`hint_missed` in
//!   [`AllocStats`](crate::alloc::traits::AllocStats)); every bulk op
//!   over such a row pays the CPU-fallback price forever. When the
//!   preferred subarray has free rows again, the row is migrated
//!   there. The migration copy itself crosses subarrays, so it is
//!   priced as a fallback row — paid once, against PUD pricing on
//!   every subsequent op.
//! * **Evacuation** — a huge page pinned by a few live rows cannot be
//!   reclaimed. Those rows are migrated to free rows of the *same*
//!   subarray on other pages (an intra-subarray RowClone FPM copy:
//!   PUD-priced, and co-location preserving by construction), after
//!   which [`PumaAlloc::reclaim`] returns the page to the boot pool.
//!
//! Every migration is executed as a `PudOp::Copy` through
//! [`Coordinator::submit_batch`], so the batch scheduler coalesces the
//! copies, prices them on the per-bank timelines, and the functional
//! DRAM image moves with them. VAs are then re-pointed at the new
//! regions through [`Process::unmap_page`] — which bumps the
//! translation epoch, keeping the coordinator's extent cache honest
//! (DESIGN.md §5).

use anyhow::Result;
use rustc_hash::FxHashSet;

use crate::alloc::traits::OsCtx;
use crate::coordinator::dispatch::Coordinator;
use crate::dram::geometry::SubarrayId;
use crate::os::page_table::PageKind;
use crate::os::process::{Pid, Process};
use crate::os::vma::VmaKind;
use crate::os::PAGE_SIZE;
use crate::pud::isa::{BulkRequest, PudOp};

use super::region::Region;
use super::PumaAlloc;

/// Outcome of one [`PumaAlloc::compact`] pass.
#[derive(Debug, Clone, Default)]
pub struct CompactReport {
    /// Regions migrated to restore hint co-location.
    pub repairs: u64,
    /// Regions migrated off nearly-empty pages.
    pub evacuations: u64,
    /// Huge pages returned to the boot pool by the trailing reclaim.
    pub pages_reclaimed: usize,
    /// Simulated ns of the migration copies (serial-equivalent).
    pub copy_ns: f64,
    /// Migration rows that executed in-DRAM (intra-subarray RowClone).
    pub pud_copy_rows: u64,
    /// Migration rows that crossed subarrays (CPU fallback copy).
    pub fallback_copy_rows: u64,
}

impl CompactReport {
    /// Total regions moved.
    pub fn migrated(&self) -> u64 {
        self.repairs + self.evacuations
    }
}

/// One planned region move.
struct Migration {
    key: (Pid, u64),
    idx: usize,
    old: Region,
    new: Region,
    scratch_va: u64,
    evacuation: bool,
}

/// A page qualifies for evacuation when at most `carved / EVAC_DIVISOR`
/// of its rows are still live. Quarter-full is the knee: evacuating
/// fuller pages moves more rows than it frees, and the migrated rows
/// churn placement for little reclaim gain.
const EVAC_DIVISOR: usize = 4;

/// Map each migration's target region at a fresh scratch VA. On error,
/// the partially-mapped migration is torn down here, so `prepared`
/// tells the caller exactly how many *fully mapped* migrations need
/// unwinding.
fn map_scratch(
    proc: &mut Process,
    migs: &mut [Migration],
    row: u64,
    pages_per_region: u64,
    prepared: &mut usize,
) -> Result<()> {
    for m in migs.iter_mut() {
        let scratch = proc.mmap(row, row.max(PAGE_SIZE), VmaKind::Pud)?;
        for p in 0..pages_per_region {
            if let Err(e) = proc.page_table.map(
                scratch + p * PAGE_SIZE,
                m.new.paddr + p * PAGE_SIZE,
                PageKind::Base,
            ) {
                for q in 0..p {
                    let _ = proc.unmap_page(scratch + q * PAGE_SIZE);
                }
                let _ = proc.unmap_vma(scratch);
                return Err(e);
            }
        }
        m.scratch_va = scratch;
        *prepared += 1;
    }
    Ok(())
}

impl PumaAlloc {
    /// Take a free region from `sid` suitable as a migration target:
    /// never from a `forbidden` page (evacuation sources, or pages
    /// about to reclaim), and from an `avoid` page (fully-free pages
    /// worth keeping clean) only when nothing else is available.
    /// Unsuitable candidates are returned to the free store.
    fn take_target(
        &mut self,
        sid: SubarrayId,
        forbidden: &FxHashSet<u64>,
        avoid: &FxHashSet<u64>,
    ) -> Option<Region> {
        let mut rejects: Vec<Region> = Vec::new();
        let mut fallback: Option<Region> = None;
        let mut found: Option<Region> = None;
        while let Some(r) = self.free.take_from(sid) {
            let base = r.page_base();
            if forbidden.contains(&base) {
                rejects.push(r);
            } else if avoid.contains(&base) {
                if fallback.is_none() {
                    fallback = Some(r);
                } else {
                    rejects.push(r);
                }
            } else {
                found = Some(r);
                break;
            }
        }
        if found.is_none() {
            found = fallback.take();
        }
        if let Some(f) = fallback {
            rejects.push(f);
        }
        for r in rejects {
            self.free.insert(r);
        }
        if let Some(r) = &found {
            self.note_taken(r);
        }
        found
    }

    /// Page bases currently holding no live rows (reclaim candidates —
    /// migrations should not dirty them).
    fn fully_free_pages(&self) -> FxHashSet<u64> {
        self.pages
            .iter()
            .filter(|(_, m)| m.free == m.carved)
            .map(|(base, _)| *base)
            .collect()
    }

    /// One compaction pass over `proc`'s allocations: repair lost
    /// co-location, evacuate nearly-empty pages, execute the
    /// migrations as one batched RowClone copy submission, re-point
    /// the VAs, and reclaim every page that reassembled.
    ///
    /// Memory contents are preserved byte-for-byte (the copies run
    /// through the functional DRAM store), and the translation epoch
    /// is bumped by the remap so cached extent translations die with
    /// the old placement. Queued-but-unflushed requests of `proc`
    /// should be flushed first (see
    /// [`System::compact`](crate::coordinator::system::System::compact)).
    ///
    /// ```
    /// use puma::alloc::puma::{FitPolicy, PumaAlloc};
    /// use puma::alloc::traits::{Allocator, OsCtx};
    /// use puma::coordinator::{Coordinator, FallbackMode};
    /// use puma::dram::address::InterleaveScheme;
    /// use puma::dram::device::DramDevice;
    /// use puma::dram::geometry::DramGeometry;
    /// use puma::dram::timing::TimingParams;
    /// use puma::os::process::{Pid, Process};
    /// use puma::pud::exec::PudEngine;
    ///
    /// let scheme = InterleaveScheme::row_major(DramGeometry {
    ///     channels: 1, ranks_per_channel: 1, banks_per_rank: 4,
    ///     subarrays_per_bank: 8, rows_per_subarray: 256, row_bytes: 8192,
    /// });
    /// let mut ctx = OsCtx::boot(scheme.clone(), 4, 0, 0).unwrap();
    /// let mut coord = Coordinator::new(
    ///     PudEngine::new(DramDevice::new(scheme), TimingParams::default()),
    ///     FallbackMode::Scalar,
    /// );
    /// let mut proc = Process::new(Pid(1));
    /// let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
    /// puma.pim_preallocate(&mut ctx, 2).unwrap();
    /// let _a = puma.alloc(&mut ctx, &mut proc, 4 * 8192).unwrap();
    /// let report = puma.compact(&mut ctx, &mut proc, &mut coord).unwrap();
    /// assert_eq!(report.migrated(), 0); // fresh placements need no repair
    /// assert_eq!(report.pages_reclaimed, 1); // the untouched page goes back
    /// ```
    pub fn compact(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        coord: &mut Coordinator,
    ) -> Result<CompactReport> {
        let pid = proc.pid;
        let row = self.row_bytes;
        let pages_per_region = row / PAGE_SIZE;
        let mut report = CompactReport::default();
        let mut migs: Vec<Migration> = Vec::new();
        let mut planned: FxHashSet<(u64, usize)> = FxHashSet::default();

        // Plan the evacuation set first, from the pre-pass usage
        // snapshot (allocated ascending, fullest occupied page kept as
        // the sink), so phase-A repair targets never land on a page
        // phase B is about to empty.
        let mut occupied: Vec<(usize, u64)> = self
            .page_usage()
            .iter()
            .filter(|(_, carved, free)| free < carved)
            .map(|(base, carved, free)| (carved - free, *base))
            .collect();
        occupied.sort_unstable();
        let evac: FxHashSet<u64> = if occupied.len() >= 2 {
            occupied[..occupied.len() - 1]
                .iter()
                .filter(|(allocated, base)| {
                    allocated * EVAC_DIVISOR <= self.pages[base].carved
                })
                .map(|(_, base)| *base)
                .collect()
        } else {
            FxHashSet::default()
        };

        // ---- phase A: co-location repair --------------------------------
        let mut groups: Vec<(u64, u64)> = self
            .align_groups
            .iter()
            .filter(|((p, _), _)| *p == pid)
            .map(|((_, va), hint)| (*va, *hint))
            .collect();
        groups.sort_unstable();
        let avoid = self.fully_free_pages();
        let no_forbidden = FxHashSet::default();
        for (va, hint) in groups {
            let Some(hint_alloc) = self.allocations.get(&(pid, hint)) else {
                continue;
            };
            let prefs: Vec<SubarrayId> =
                hint_alloc.regions.iter().map(|r| r.sid).collect();
            if prefs.is_empty() {
                continue;
            }
            let Some(alloc) = self.allocations.get(&(pid, va)) else {
                continue;
            };
            let regions = alloc.regions.clone();
            for (idx, r) in regions.iter().enumerate() {
                let want = prefs[idx % prefs.len()];
                if r.sid == want {
                    continue;
                }
                let Some(new) = self.take_target(want, &evac, &avoid) else {
                    continue; // preferred subarray still full; retry later
                };
                planned.insert((va, idx));
                migs.push(Migration {
                    key: (pid, va),
                    idx,
                    old: *r,
                    new,
                    scratch_va: 0,
                    evacuation: false,
                });
            }
        }

        // ---- phase B: evacuate nearly-empty pages -----------------------
        if !evac.is_empty() {
            let mut forbidden = self.fully_free_pages();
            forbidden.extend(evac.iter().copied());
            // live rows sitting on evacuating pages, in deterministic order
            let mut victims: Vec<((Pid, u64), usize, Region)> = self
                .allocations
                .iter()
                .filter(|((p, _), _)| *p == pid)
                .flat_map(|(key, a)| {
                    a.regions
                        .iter()
                        .enumerate()
                        .filter(|(idx, r)| {
                            evac.contains(&r.page_base())
                                && !planned.contains(&(key.1, *idx))
                        })
                        .map(|(idx, r)| (*key, idx, *r))
                        .collect::<Vec<_>>()
                })
                .collect();
            victims.sort_unstable_by_key(|(key, idx, _)| (key.1, *idx));
            for (key, idx, old) in victims {
                let Some(new) = self.take_target(old.sid, &forbidden, &no_forbidden)
                else {
                    continue; // no same-subarray room off this page
                };
                planned.insert((key.1, idx));
                migs.push(Migration {
                    key,
                    idx,
                    old,
                    new,
                    scratch_va: 0,
                    evacuation: true,
                });
            }
        }

        if migs.is_empty() {
            report.pages_reclaimed = self.reclaim(ctx)?;
            return Ok(report);
        }

        // ---- execute: scratch-map targets, one batched copy, re-point ---
        let mut prepared = 0usize;
        let prepare =
            map_scratch(proc, &mut migs, row, pages_per_region, &mut prepared);
        let batch = match prepare {
            Ok(()) => {
                let reqs: Vec<BulkRequest> = migs
                    .iter()
                    .map(|m| {
                        BulkRequest::new(
                            PudOp::Copy,
                            m.scratch_va,
                            vec![m.key.1 + m.idx as u64 * row],
                            row,
                        )
                    })
                    .collect();
                let pud_before = coord.stats.pud_rows;
                let fb_before = coord.stats.fallback_rows;
                match coord.submit_batch(proc, &reqs) {
                    Ok(b) => {
                        report.pud_copy_rows = coord.stats.pud_rows - pud_before;
                        report.fallback_copy_rows =
                            coord.stats.fallback_rows - fb_before;
                        Ok(b)
                    }
                    Err(e) => Err(e),
                }
            }
            Err(e) => Err(e),
        };
        let batch = match batch {
            Ok(b) => b,
            Err(e) => {
                // roll back: drop scratch mappings, return the unused
                // target regions; live allocations are untouched
                for (i, m) in migs.into_iter().enumerate() {
                    if i < prepared {
                        for p in 0..pages_per_region {
                            let _ = proc.unmap_page(m.scratch_va + p * PAGE_SIZE);
                        }
                        let _ = proc.unmap_vma(m.scratch_va);
                    }
                    self.insert_free(m.new);
                }
                self.refresh_gauges();
                return Err(e);
            }
        };

        for m in &migs {
            let base_va = m.key.1 + m.idx as u64 * row;
            for p in 0..pages_per_region {
                proc.unmap_page(base_va + p * PAGE_SIZE)?;
                proc.page_table.map(
                    base_va + p * PAGE_SIZE,
                    m.new.paddr + p * PAGE_SIZE,
                    PageKind::Base,
                )?;
            }
            for p in 0..pages_per_region {
                proc.unmap_page(m.scratch_va + p * PAGE_SIZE)?;
            }
            proc.unmap_vma(m.scratch_va)?;
            self.allocations
                .get_mut(&m.key)
                .expect("allocation live while migrating")
                .regions[m.idx] = m.new;
            self.insert_free(m.old);
            self.stats.regions_migrated += 1;
            // re-point + scratch teardown are both PTE rewrites
            self.stats.alloc_ns += ctx.timing.remap_region_ns * 2.0;
            if m.evacuation {
                report.evacuations += 1;
            } else {
                report.repairs += 1;
            }
        }
        self.stats.compactions += 1;
        report.copy_ns = batch.total_ns;
        report.pages_reclaimed = self.reclaim(ctx)?;
        self.refresh_gauges();
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::FitPolicy;
    use crate::alloc::traits::Allocator;
    use crate::coordinator::dispatch::FallbackMode;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::device::DramDevice;
    use crate::dram::geometry::DramGeometry;
    use crate::dram::timing::TimingParams;
    use crate::pud::exec::PudEngine;

    const ROW: u64 = 8192;

    fn machine() -> (OsCtx, Coordinator) {
        let scheme = InterleaveScheme::row_major(DramGeometry::small()); // 64 MiB
        let ctx = OsCtx::boot(scheme.clone(), 8, 0, 0).unwrap();
        let engine = PudEngine::new(DramDevice::new(scheme), TimingParams::default());
        (ctx, Coordinator::new(engine, FallbackMode::Scalar))
    }

    /// Allocate single-row objects until the pool is empty; returns
    /// their VAs.
    fn drain_pool(
        puma: &mut PumaAlloc,
        ctx: &mut OsCtx,
        proc: &mut Process,
    ) -> Vec<u64> {
        let mut vas = Vec::new();
        while puma.free_regions() > 0 {
            vas.push(puma.alloc(ctx, proc, ROW).unwrap());
        }
        vas
    }

    #[test]
    fn repair_restores_colocation_and_contents() {
        let (mut ctx, mut coord) = machine();
        let mut proc = Process::new(Pid(1));
        let mut puma = PumaAlloc::new(ROW, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 2).unwrap();

        let a = puma.alloc(&mut ctx, &mut proc, ROW).unwrap();
        let want_sid = puma.lookup(Pid(1), a).unwrap().regions[0].sid;
        let fillers = drain_pool(&mut puma, &mut ctx, &mut proc);

        // leave exactly one free region, in the WRONG subarray
        let wrong = fillers
            .iter()
            .find(|va| puma.lookup(Pid(1), **va).unwrap().regions[0].sid != want_sid)
            .copied()
            .unwrap();
        puma.free(&mut ctx, &mut proc, wrong).unwrap();
        let b = puma.alloc_align(&mut ctx, &mut proc, ROW, a).unwrap();
        assert_eq!(puma.stats().hint_missed, 1);
        let b_old = puma.lookup(Pid(1), b).unwrap().regions[0];
        assert_ne!(b_old.sid, want_sid, "forced a scattered placement");

        // give b's row recognizable contents
        let pattern: Vec<u8> = (0..ROW).map(|i| (i % 241) as u8).collect();
        coord.engine.device.write(b_old.paddr, &pattern);

        // open a repair target in the preferred subarray
        let target_filler = fillers
            .iter()
            .find(|va| {
                **va != wrong
                    && puma
                        .lookup(Pid(1), **va)
                        .map(|al| al.regions[0].sid == want_sid)
                        .unwrap_or(false)
            })
            .copied()
            .unwrap();
        puma.free(&mut ctx, &mut proc, target_filler).unwrap();

        let epoch_before = proc.translation_epoch;
        let report = puma.compact(&mut ctx, &mut proc, &mut coord).unwrap();
        assert_eq!(report.repairs, 1);
        assert_eq!(report.evacuations, 0);
        // cross-subarray migration copy is priced as fallback
        assert_eq!(report.fallback_copy_rows, 1);
        assert!(report.copy_ns > 0.0);
        assert!(proc.translation_epoch > epoch_before, "cache invalidated");

        let b_new = puma.lookup(Pid(1), b).unwrap().regions[0];
        assert_eq!(b_new.sid, want_sid, "co-location repaired");
        assert_ne!(b_new.paddr, b_old.paddr);
        let mut got = vec![0u8; ROW as usize];
        coord.engine.device.read(b_new.paddr, &mut got);
        assert_eq!(got, pattern, "migration preserved contents");
        // and the row is reachable through the (re-pointed) VA
        let ext = proc.phys_extents(b, ROW).unwrap();
        assert_eq!(ext[0].paddr, b_new.paddr);
        assert_eq!(puma.stats().regions_migrated, 1);
        assert_eq!(puma.stats().compactions, 1);
        assert_eq!(
            puma.carved_regions(),
            puma.free_regions() + puma.live_regions(),
            "accounting identity after compaction"
        );
    }

    #[test]
    fn evacuation_frees_a_page_for_reclaim() {
        let (mut ctx, mut coord) = machine();
        let mut proc = Process::new(Pid(1));
        let mut puma = PumaAlloc::new(ROW, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 2).unwrap();
        let pool_before = ctx.pool.available();

        let fillers = drain_pool(&mut puma, &mut ctx, &mut proc);
        let usage = puma.page_usage();
        assert_eq!(usage.len(), 2);
        let (low_page, high_page) = (usage[0].0, usage[1].0);

        // keep one straggler on the low page, two anchors on the high
        // page; free everything else
        let mut straggler = None;
        let mut anchors = Vec::new();
        for va in &fillers {
            let base = puma.lookup(Pid(1), *va).unwrap().regions[0].page_base();
            if base == low_page && straggler.is_none() {
                straggler = Some(*va);
            } else if base == high_page && anchors.len() < 2 {
                anchors.push(*va);
            }
        }
        let straggler = straggler.unwrap();
        assert_eq!(anchors.len(), 2);
        for va in fillers {
            if va != straggler && !anchors.contains(&va) {
                puma.free(&mut ctx, &mut proc, va).unwrap();
            }
        }

        let s_old = puma.lookup(Pid(1), straggler).unwrap().regions[0];
        let pattern: Vec<u8> = (0..ROW).map(|i| ((i * 7) % 239) as u8).collect();
        coord.engine.device.write(s_old.paddr, &pattern);

        let report = puma.compact(&mut ctx, &mut proc, &mut coord).unwrap();
        assert_eq!(report.evacuations, 1, "straggler moved off the thin page");
        assert_eq!(
            report.pud_copy_rows, 1,
            "same-subarray evacuation is a RowClone FPM copy"
        );
        assert_eq!(report.pages_reclaimed, 1, "emptied page went back");
        assert_eq!(ctx.pool.available(), pool_before + 1);
        assert_eq!(puma.preallocated(), 1);

        let s_new = puma.lookup(Pid(1), straggler).unwrap().regions[0];
        assert_eq!(s_new.sid, s_old.sid, "evacuation preserves the subarray");
        assert_eq!(s_new.page_base(), high_page);
        let mut got = vec![0u8; ROW as usize];
        coord.engine.device.read(s_new.paddr, &mut got);
        assert_eq!(got, pattern);
        assert_eq!(
            puma.carved_regions(),
            puma.free_regions() + puma.live_regions()
        );
    }

    #[test]
    fn compact_with_nothing_to_do_just_reclaims() {
        let (mut ctx, mut coord) = machine();
        let mut proc = Process::new(Pid(1));
        let mut puma = PumaAlloc::new(ROW, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 2).unwrap();
        let va = puma.alloc(&mut ctx, &mut proc, 4 * ROW).unwrap();
        let report = puma.compact(&mut ctx, &mut proc, &mut coord).unwrap();
        assert_eq!(report.migrated(), 0);
        assert_eq!(report.pages_reclaimed, 1, "the untouched page reassembles");
        assert!(puma.lookup(Pid(1), va).is_some());
        // idempotent on a quiet pool
        let again = puma.compact(&mut ctx, &mut proc, &mut coord).unwrap();
        assert_eq!(again.migrated(), 0);
        assert_eq!(again.pages_reclaimed, 0);
    }
}
