//! The per-subarray ordered free structure behind worst-fit selection.
//!
//! Paper §2: "PUMA uses an ordered array data structure similar to the
//! one used in the Linux Kernel buddy allocator algorithm, where each
//! entry represents the number of memory regions in a single
//! subarray." `pim_alloc` scans for the subarray with the *largest*
//! count (worst-fit); `pim_alloc_align` asks for a region of a
//! *specific* subarray.
//!
//! Implementation: per-sid region stacks plus a count-bucketed index
//! (`BTreeMap<count, set<sid>>`) so worst-fit selection is O(log n)
//! instead of a linear scan — the scan showed up hot in the E2 sweep
//! profile (see EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;

use rustc_hash::{FxHashMap, FxHashSet};

use crate::dram::geometry::SubarrayId;

use super::region::Region;

/// Free-region index over subarrays.
#[derive(Debug, Default)]
pub struct OrderedArray {
    per_sid: FxHashMap<SubarrayId, Vec<Region>>,
    /// count -> sids currently holding exactly `count` free regions.
    by_count: BTreeMap<usize, FxHashSet<SubarrayId>>,
    total: usize,
}

impl OrderedArray {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn total_free(&self) -> usize {
        self.total
    }

    pub fn free_in(&self, sid: SubarrayId) -> usize {
        self.per_sid.get(&sid).map_or(0, |v| v.len())
    }

    /// Number of subarrays with at least one free region.
    pub fn populated_subarrays(&self) -> usize {
        self.per_sid.values().filter(|v| !v.is_empty()).count()
    }

    fn reindex(&mut self, sid: SubarrayId, old: usize, new: usize) {
        if old == new {
            return;
        }
        if old > 0 {
            if let Some(set) = self.by_count.get_mut(&old) {
                set.remove(&sid);
                if set.is_empty() {
                    self.by_count.remove(&old);
                }
            }
        }
        if new > 0 {
            self.by_count.entry(new).or_default().insert(sid);
        }
    }

    /// Add a free region.
    pub fn insert(&mut self, region: Region) {
        let list = self.per_sid.entry(region.sid).or_default();
        let old = list.len();
        list.push(region);
        self.total += 1;
        let sid = region.sid;
        self.reindex(sid, old, old + 1);
    }

    /// Remove a *specific* free region (matched by physical address),
    /// returning whether it was present. Used by the huge-page
    /// coalescer when it extracts every region of a fully-freed page
    /// before handing the page back to the boot pool.
    pub fn remove(&mut self, region: &Region) -> bool {
        let Some(list) = self.per_sid.get_mut(&region.sid) else {
            return false;
        };
        let old = list.len();
        let Some(idx) = list.iter().position(|r| r.paddr == region.paddr) else {
            return false;
        };
        list.swap_remove(idx);
        self.total -= 1;
        self.reindex(region.sid, old, old - 1);
        true
    }

    /// Take one region from subarray `sid`, if available.
    pub fn take_from(&mut self, sid: SubarrayId) -> Option<Region> {
        let list = self.per_sid.get_mut(&sid)?;
        let old = list.len();
        let region = list.pop()?;
        self.total -= 1;
        self.reindex(sid, old, old - 1);
        Some(region)
    }

    /// Worst-fit: take one region from the subarray with the most
    /// free regions (ties broken toward the lowest sid, for
    /// reproducibility).
    pub fn take_worst_fit(&mut self) -> Option<Region> {
        let (_, set) = self.by_count.iter().next_back()?;
        let sid = *set.iter().min().expect("non-empty bucket");
        self.take_from(sid)
    }

    /// Worst-fit restricted to the sid range `[lo, hi)` — the dense
    /// sid span of one bank (sids are bank-major, so bank `b` owns
    /// `[b * subarrays_per_bank, (b + 1) * subarrays_per_bank)`).
    /// Backs PUMA's placement-spread path: take from the richest
    /// subarray *of a specific bank*, ties toward the lowest sid.
    pub fn take_worst_fit_in(
        &mut self,
        lo: SubarrayId,
        hi: SubarrayId,
    ) -> Option<Region> {
        let mut best: Option<SubarrayId> = None;
        for set in self.by_count.values().rev() {
            if let Some(sid) =
                set.iter().copied().filter(|s| *s >= lo && *s < hi).min()
            {
                best = Some(sid);
                break;
            }
        }
        self.take_from(best?)
    }

    /// Best-fit (ablation E3): take from the *least*-populated
    /// non-empty subarray (ties toward the lowest sid).
    pub fn take_best_fit(&mut self) -> Option<Region> {
        let (_, set) = self.by_count.iter().next()?;
        let sid = *set.iter().min().expect("non-empty bucket");
        self.take_from(sid)
    }

    /// First-fit (ablation E3): take from the lowest-numbered
    /// non-empty subarray.
    pub fn take_first_fit(&mut self) -> Option<Region> {
        let sid = self
            .per_sid
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .map(|(sid, _)| *sid)
            .min()?;
        self.take_from(sid)
    }

    /// Sids ordered by descending free count (for diagnostics).
    pub fn occupancy(&self) -> Vec<(SubarrayId, usize)> {
        let mut v: Vec<(SubarrayId, usize)> = self
            .per_sid
            .iter()
            .filter(|(_, l)| !l.is_empty())
            .map(|(sid, l)| (*sid, l.len()))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn region(sid: u32, n: u64) -> Region {
        Region {
            paddr: n * 8192,
            sid: SubarrayId(sid),
        }
    }

    #[test]
    fn insert_and_counts() {
        let mut oa = OrderedArray::new();
        oa.insert(region(1, 0));
        oa.insert(region(1, 1));
        oa.insert(region(2, 2));
        assert_eq!(oa.total_free(), 3);
        assert_eq!(oa.free_in(SubarrayId(1)), 2);
        assert_eq!(oa.free_in(SubarrayId(2)), 1);
        assert_eq!(oa.free_in(SubarrayId(9)), 0);
        assert_eq!(oa.populated_subarrays(), 2);
    }

    #[test]
    fn worst_fit_picks_largest() {
        let mut oa = OrderedArray::new();
        for i in 0..5 {
            oa.insert(region(7, i));
        }
        oa.insert(region(3, 100));
        // counts: sid7=5, sid3=1. With min-sid tie breaking the take
        // order is fully deterministic: 7,7,7,7 (5->1), then the tie
        // {3:1, 7:1} resolves to 3, then 7.
        let order: Vec<u32> = (0..6)
            .map(|_| oa.take_worst_fit().unwrap().sid.0)
            .collect();
        assert_eq!(order, vec![7, 7, 7, 7, 3, 7]);
        assert!(oa.take_worst_fit().is_none());
    }

    #[test]
    fn best_and_first_fit_differ() {
        let mut oa = OrderedArray::new();
        for i in 0..5 {
            oa.insert(region(7, i));
        }
        oa.insert(region(3, 100));
        assert_eq!(oa.take_best_fit().unwrap().sid, SubarrayId(3));
        oa.insert(region(9, 200));
        oa.insert(region(9, 201));
        // first-fit = lowest sid with space = 7
        assert_eq!(oa.take_first_fit().unwrap().sid, SubarrayId(7));
    }

    #[test]
    fn take_worst_fit_in_respects_the_range() {
        let mut oa = OrderedArray::new();
        for i in 0..5 {
            oa.insert(region(7, i)); // outside [0, 4)
        }
        oa.insert(region(1, 100));
        oa.insert(region(3, 101));
        oa.insert(region(3, 102));
        // richest sid inside [0, 4) is 3 (count 2)
        let r = oa.take_worst_fit_in(SubarrayId(0), SubarrayId(4)).unwrap();
        assert_eq!(r.sid, SubarrayId(3));
        // tie at count 1 inside the range resolves to the lowest sid
        let r = oa.take_worst_fit_in(SubarrayId(0), SubarrayId(4)).unwrap();
        assert_eq!(r.sid, SubarrayId(1));
        assert_eq!(
            oa.take_worst_fit_in(SubarrayId(0), SubarrayId(4))
                .unwrap()
                .sid,
            SubarrayId(3)
        );
        // range exhausted -> None; sid 7's regions are untouched
        assert!(oa.take_worst_fit_in(SubarrayId(0), SubarrayId(4)).is_none());
        assert_eq!(oa.free_in(SubarrayId(7)), 5);
    }

    #[test]
    fn take_from_specific_sid() {
        let mut oa = OrderedArray::new();
        oa.insert(region(4, 1));
        assert!(oa.take_from(SubarrayId(5)).is_none());
        assert_eq!(oa.take_from(SubarrayId(4)).unwrap().sid, SubarrayId(4));
        assert!(oa.take_from(SubarrayId(4)).is_none());
        assert_eq!(oa.total_free(), 0);
    }

    #[test]
    fn remove_specific_region() {
        let mut oa = OrderedArray::new();
        oa.insert(region(2, 10));
        oa.insert(region(2, 11));
        oa.insert(region(5, 12));
        assert!(oa.remove(&region(2, 10)));
        assert!(!oa.remove(&region(2, 10)), "already gone");
        assert!(!oa.remove(&region(7, 10)), "unknown sid");
        assert_eq!(oa.total_free(), 2);
        assert_eq!(oa.free_in(SubarrayId(2)), 1);
        // index stays consistent: worst-fit still works afterwards
        assert!(oa.remove(&region(5, 12)));
        assert_eq!(oa.take_worst_fit().unwrap().sid, SubarrayId(2));
        assert_eq!(oa.total_free(), 0);
    }

    #[test]
    fn empty_behaviour() {
        let mut oa = OrderedArray::new();
        assert!(oa.take_worst_fit().is_none());
        assert!(oa.take_best_fit().is_none());
        assert!(oa.take_first_fit().is_none());
        assert_eq!(oa.occupancy(), vec![]);
    }

    #[test]
    fn occupancy_sorted_desc() {
        let mut oa = OrderedArray::new();
        oa.insert(region(1, 0));
        oa.insert(region(2, 1));
        oa.insert(region(2, 2));
        let occ = oa.occupancy();
        assert_eq!(occ[0], (SubarrayId(2), 2));
        assert_eq!(occ[1], (SubarrayId(1), 1));
    }

    #[test]
    fn index_consistent_under_mixed_ops() {
        let mut oa = OrderedArray::new();
        for i in 0..20 {
            oa.insert(region(i % 4, i as u64));
        }
        for _ in 0..10 {
            assert!(oa.take_worst_fit().is_some());
        }
        // remaining counts must sum to total
        let sum: usize = oa.occupancy().iter().map(|(_, c)| c).sum();
        assert_eq!(sum, oa.total_free());
        assert_eq!(sum, 10);
    }
}
