//! PUMA memory regions: the row-granular allocation units carved from
//! reserved huge pages.
//!
//! The allocation routine "uses the DRAM address mapping knowledge to
//! split the huge pages into different memory regions. Then, it uses
//! the DRAM interleaving scheme to index each memory region based on
//! their subarray ID" (paper §2). A region is one DRAM row: aligned to
//! the row address and size, and the atom of PUD operand placement.

use crate::dram::address::InterleaveScheme;
use crate::dram::geometry::SubarrayId;
use crate::os::hugepage::HugePage;
use crate::pud::reserved::is_reserved;

/// One memory region: a row-sized, row-aligned slice of a reserved
/// huge page, tagged with its subarray.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Region {
    /// Physical byte address of the region start (row-aligned).
    pub paddr: u64,
    /// The subarray this region's row lives in.
    pub sid: SubarrayId,
}

impl Region {
    /// Base physical address of the huge page this region was carved
    /// from — the key of the allocator's page directory, which the
    /// free-path coalescer uses to detect fully-reassembled pages.
    pub fn page_base(&self) -> u64 {
        crate::os::align_down(self.paddr, crate::os::HUGE_PAGE_SIZE)
    }
}

/// Split a huge page into row-granular regions, skipping any that land
/// on Ambit-reserved rows.
pub fn split_huge_page(scheme: &InterleaveScheme, page: &HugePage) -> Vec<Region> {
    let row_bytes = scheme.geometry.row_bytes as u64;
    let base = page.phys_addr();
    debug_assert_eq!(base % row_bytes, 0, "huge pages are row-aligned");
    let mut regions = Vec::with_capacity((page.len() / row_bytes) as usize);
    let mut off = 0;
    while off < page.len() {
        let paddr = base + off;
        let loc = scheme.decode(paddr);
        debug_assert_eq!(loc.column, 0, "stride preserves row alignment");
        if !is_reserved(&scheme.geometry, loc.row) {
            regions.push(Region {
                paddr,
                sid: scheme.geometry.subarray_id(&loc),
            });
        }
        off += row_bytes;
    }
    regions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::DramGeometry;

    fn scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(DramGeometry::default())
    }

    #[test]
    fn splits_whole_page_into_row_regions() {
        let s = scheme();
        let page = HugePage { pfn: 512 }; // 2 MiB mark
        let regions = split_huge_page(&s, &page);
        let row_bytes = s.geometry.row_bytes as u64;
        // 2 MiB / 8 KiB = 256 candidate rows, minus any reserved ones
        assert!(regions.len() <= 256);
        assert!(regions.len() >= 240);
        for r in &regions {
            assert_eq!(r.paddr % row_bytes, 0);
            assert_eq!(s.subarray_id(r.paddr), r.sid);
        }
        // regions are unique addresses
        let mut addrs: Vec<u64> = regions.iter().map(|r| r.paddr).collect();
        addrs.dedup();
        assert_eq!(addrs.len(), regions.len());
    }

    #[test]
    fn page_base_recovers_parent_page() {
        let s = scheme();
        let page = HugePage { pfn: 1024 }; // second 2 MiB page
        for r in split_huge_page(&s, &page) {
            assert_eq!(r.page_base(), page.phys_addr());
        }
    }

    #[test]
    fn regions_grouped_by_subarray() {
        let s = scheme();
        let page = HugePage { pfn: 0 };
        let regions = split_huge_page(&s, &page);
        // in the default row-major scheme a huge page touches one
        // subarray per bank (bank bits lie inside the page span)
        let mut sids: Vec<u32> = regions.iter().map(|r| r.sid.0).collect();
        sids.sort_unstable();
        sids.dedup();
        assert_eq!(sids.len(), s.geometry.banks_per_rank as usize);
    }

    #[test]
    fn reserved_rows_are_skipped() {
        // a huge page overlapping the reserved top rows of a subarray
        // must skip them; find one by scanning.
        let s = scheme();
        let g = &s.geometry;
        let usable = crate::pud::reserved::usable_rows(g);
        // reserved rows start at `usable`; pick the page containing
        // such a row for subarray 0 / bank 0
        let loc = crate::dram::geometry::Loc {
            channel: 0,
            rank: 0,
            bank: 0,
            subarray: 0,
            row: usable,
            column: 0,
        };
        let addr = s.encode(&loc);
        let page = HugePage {
            pfn: crate::os::align_down(addr, crate::os::HUGE_PAGE_SIZE)
                / crate::os::PAGE_SIZE,
        };
        let regions = split_huge_page(&s, &page);
        assert!(regions.len() < 256, "some rows were reserved");
        for r in &regions {
            let l = s.decode(r.paddr);
            assert!(!is_reserved(g, l.row));
        }
    }
}
