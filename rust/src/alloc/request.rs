//! The unified allocation request: one builder covering the paper's
//! three entry points (`pim_alloc`, `pim_alloc_align`, and the
//! bank-spread anchor draw) so higher layers — in particular
//! `serve::Session` — expose a single allocation shape.
//!
//! A request is `len` bytes plus at most one placement directive:
//!
//! * [`AllocRequest::align_with`] — co-locate with an existing
//!   allocation (PUMA's `pim_alloc_align`; baselines ignore it);
//! * [`AllocRequest::spread`] — place the anchor of shard `k` for
//!   bank-level spreading (`Allocator::alloc_spread`).
//!
//! The two directives are mutually exclusive (an allocation cannot be
//! pinned to a neighbour's subarray *and* drawn on a spread bank);
//! [`AllocRequest::place`] rejects the combination instead of silently
//! preferring one.

use anyhow::{ensure, Result};

use crate::os::process::Process;

use super::traits::{Allocator, OsCtx};

/// A single-shape allocation request (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AllocRequest {
    len: u64,
    hint: Option<u64>,
    spread: Option<u32>,
}

impl AllocRequest {
    /// Request `len` bytes with no placement directive.
    pub fn bytes(len: u64) -> Self {
        Self {
            len,
            hint: None,
            spread: None,
        }
    }

    /// Co-locate with the existing allocation at `hint`.
    pub fn align_with(mut self, hint: u64) -> Self {
        self.hint = Some(hint);
        self
    }

    /// Place for bank-level spreading as shard `spread`'s anchor.
    pub fn spread(mut self, spread: u32) -> Self {
        self.spread = Some(spread);
        self
    }

    /// Requested size in bytes.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// True when the request is zero-sized.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The co-location hint, if any.
    pub fn hint(&self) -> Option<u64> {
        self.hint
    }

    /// The bank-spread directive, if any.
    pub fn spread_hint(&self) -> Option<u32> {
        self.spread
    }

    /// Dispatch the request against `alloc`, routing to the matching
    /// trait entry point. Errors if both placement directives are set.
    pub fn place(
        &self,
        alloc: &mut dyn Allocator,
        ctx: &mut OsCtx,
        proc: &mut Process,
    ) -> Result<u64> {
        ensure!(
            !(self.hint.is_some() && self.spread.is_some()),
            "an allocation cannot be both hint-aligned and bank-spread"
        );
        match (self.hint, self.spread) {
            (Some(hint), None) => alloc.alloc_align(ctx, proc, self.len, hint),
            (None, Some(spread)) => alloc.alloc_spread(ctx, proc, self.len, spread),
            _ => alloc.alloc(ctx, proc, self.len),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::mallocsim::MallocSim;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::os::process::{Pid, Process};

    fn ctx() -> OsCtx {
        OsCtx::boot(
            InterleaveScheme::row_major(DramGeometry::small()),
            2,
            0,
            7,
        )
        .unwrap()
    }

    #[test]
    fn builder_accumulates_fields() {
        let r = AllocRequest::bytes(4096).align_with(0x5000);
        assert_eq!(r.len(), 4096);
        assert_eq!(r.hint(), Some(0x5000));
        assert_eq!(r.spread_hint(), None);
        let s = AllocRequest::bytes(8192).spread(3);
        assert_eq!(s.spread_hint(), Some(3));
        assert!(!s.is_empty());
    }

    #[test]
    fn conflicting_directives_are_rejected() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut alloc = MallocSim::new();
        let bad = AllocRequest::bytes(4096).align_with(0x5000).spread(1);
        assert!(bad.place(&mut alloc, &mut ctx, &mut proc).is_err());
    }

    #[test]
    fn plain_and_hinted_requests_place() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let mut alloc = MallocSim::new();
        let a = AllocRequest::bytes(4096)
            .place(&mut alloc, &mut ctx, &mut proc)
            .unwrap();
        let b = AllocRequest::bytes(4096)
            .align_with(a)
            .place(&mut alloc, &mut ctx, &mut proc)
            .unwrap();
        let c = AllocRequest::bytes(4096)
            .spread(2)
            .place(&mut alloc, &mut ctx, &mut proc)
            .unwrap();
        assert_ne!(a, b);
        assert_ne!(b, c);
    }
}
