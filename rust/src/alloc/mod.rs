//! The allocators under study.
//!
//! * [`mallocsim`] — glibc-style `malloc`: virtually contiguous, but
//!   demand-paged 4 KiB frames from a churned buddy allocator, i.e.
//!   physically scattered (paper §1: 0% PUD-executable).
//! * [`memalign`] — `posix_memalign`: virtual alignment only; the
//!   physical story is identical to malloc.
//! * [`hugealloc`] — huge-page-backed allocation: physically
//!   contiguous 2 MiB chunks, but operand placement within/across huge
//!   pages is not subarray-aware (paper §1: up to ~60% at large sizes).
//! * [`puma`] — the paper's contribution: subarray-aware region
//!   allocation from a reserved huge-page pool with worst-fit
//!   placement and hint-aligned co-location.
//!
//! All allocators implement [`Allocator`] against the shared
//! [`OsCtx`], so the benchmarks sweep them interchangeably.
//! [`scratch`] adds the allocator-agnostic scratch-region lease pool
//! the expression compiler draws its temporaries from, and
//! [`request`] the unified [`AllocRequest`] builder that collapses
//! `alloc`/`alloc_align`/`alloc_spread` into one request shape.

pub mod hugealloc;
pub mod mallocsim;
pub mod memalign;
pub mod puma;
pub mod request;
pub mod scratch;
pub mod traits;

pub use request::AllocRequest;
pub use scratch::ScratchPool;
pub use traits::{AllocStats, Allocator, OsCtx, OsTiming};
