//! Scratch-region leases: the reusable temp-buffer pool behind the
//! expression compiler (and any other subsystem that needs transient
//! PUD-placed buffers).
//!
//! The historical pattern — allocate a fresh temp per operation and
//! hope someone frees it — both leaks under repeated use and scatters
//! temporaries across subarrays (a fresh worst-fit draw rarely lands
//! next to the operands, so every op touching the temp falls back to
//! the CPU). A [`ScratchPool`] fixes both: buffers are leased once,
//! co-located with a hint VA via the allocator's `alloc_align` path,
//! and reused across calls; `release_all` hands everything back when
//! the owner retires.
//!
//! The pool is *size-classed* (DESIGN.md §12): a buffer belongs to the
//! power-of-two class covering its requested length, and a demand
//! change — a wider kernel, a different column length — *parks* the
//! previous class's buffers on its per-class free list instead of
//! returning them to the allocator. The next demand for that class
//! draws them straight back, so an oscillating working set (the
//! analytics sweep alternating 8- and 16-bit cells) does zero net
//! allocator traffic after warmup. (The single-`slot_len` predecessor
//! released every resident buffer whenever the length grew, and
//! re-leased them all when it shrank back — pure churn, double-counted
//! in `leases`/`releases`.)
//!
//! Reuse is placement-aware: parked buffers are tagged with the
//! allocator's [`Allocator::locus`] (PUMA: subarray id) and a hinted
//! draw only reuses buffers whose locus matches the hint's, so a
//! recycled temp never drags a kernel out of its operands' subarray.
//! Baselines report no locus and reuse freely — they never had
//! placement to lose.

use anyhow::Result;

use crate::os::process::{Pid, Process};

use super::traits::{Allocator, OsCtx};

/// A buffer parked on a class free list, tagged with the placement
/// locus it had when parked (see [`Allocator::locus`]).
#[derive(Debug, Clone, Copy)]
struct Parked {
    va: u64,
    locus: Option<u64>,
}

/// One power-of-two size class: its free list and lifetime counters.
#[derive(Debug)]
struct SizeClass {
    /// Bytes per buffer in this class (power of two).
    class: u64,
    /// Free-listed buffers, LIFO.
    parked: Vec<Parked>,
    leases: u64,
    reuses: u64,
    high_water: usize,
}

/// Per-class counters surfaced by [`ScratchPool::class_stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassStats {
    /// Bytes per buffer (the power-of-two class size).
    pub class: u64,
    /// Buffers leased from the allocator for this class.
    pub leases: u64,
    /// Buffers served from the class free list instead of the
    /// allocator.
    pub reuses: u64,
    /// Peak resident buffers (active + parked) of this class.
    pub high_water: usize,
    /// Buffers currently parked on the class free list.
    pub parked: usize,
}

/// A pool of scratch buffers leased from an [`Allocator`], organized
/// as size-classed free lists.
#[derive(Debug, Default)]
pub struct ScratchPool {
    /// Class of the buffers in `active` (0 until the first lease).
    active_class: u64,
    /// The placement locus the active set was assembled for (the
    /// hint's locus at the last non-fast-path `ensure`); `None` when
    /// assembled without placement (no hint, or a baseline allocator).
    active_locus: Option<u64>,
    /// VAs of the buffers currently handed out via
    /// [`ScratchPool::slots`], in slot order.
    active: Vec<u64>,
    /// Size classes in first-use order.
    classes: Vec<SizeClass>,
    /// Buffers leased from the allocator over the pool's lifetime.
    pub leases: u64,
    /// Buffers returned to the allocator (via `trim`/`release_all`).
    pub releases: u64,
    /// `ensure` calls served without leasing from the allocator
    /// (active reuse or free-list draws only).
    pub reuses: u64,
    /// Peak resident buffer count (active + parked).
    pub high_water: usize,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// The size class covering a `len`-byte demand.
    fn class_of(len: u64) -> u64 {
        len.max(1).next_power_of_two()
    }

    /// Index of `class`'s entry, created on first use.
    fn class_index(&mut self, class: u64) -> usize {
        match self.classes.iter().position(|c| c.class == class) {
            Some(i) => i,
            None => {
                self.classes.push(SizeClass {
                    class,
                    parked: Vec::new(),
                    leases: 0,
                    reuses: 0,
                    high_water: 0,
                });
                self.classes.len() - 1
            }
        }
    }

    /// Active buffer VAs, in slot order — the buffers the last
    /// [`ScratchPool::ensure`] made available.
    pub fn slots(&self) -> &[u64] {
        &self.active
    }

    /// Total resident buffers: active plus parked on free lists.
    pub fn len(&self) -> usize {
        self.active.len()
            + self.classes.iter().map(|c| c.parked.len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes per *active* buffer (the active size class; 0 until the
    /// first lease).
    pub fn slot_len(&self) -> u64 {
        self.active_class
    }

    /// Buffers currently parked across all class free lists.
    pub fn parked(&self) -> usize {
        self.classes.iter().map(|c| c.parked.len()).sum()
    }

    /// Resident count a subsequent `ensure(n, len, …)` would leave
    /// behind, assuming buffers of the matching class are reused and
    /// the shortfall leases fresh — the admission-control bound the
    /// serving tier checks a tenant's scratch quota against *before*
    /// any lease happens. A locus-constrained `ensure` can reuse less
    /// than this estimate assumes (and then leases more), so the bound
    /// is a steady-state heuristic, not a hard ceiling; admission
    /// control wants "will this tenant's scratch footprint stay inside
    /// its quota under normal reuse", which is exactly this number.
    pub fn projected_len(&self, n: usize, len: u64) -> usize {
        let class = Self::class_of(len);
        let reusable = if class == self.active_class {
            self.active.len()
        } else {
            self.classes
                .iter()
                .find(|c| c.class == class)
                .map_or(0, |c| c.parked.len())
        };
        self.len() + n.saturating_sub(reusable)
    }

    /// Per-class lifetime counters, in class order.
    pub fn class_stats(&self) -> Vec<ClassStats> {
        let mut out: Vec<ClassStats> = self
            .classes
            .iter()
            .map(|c| ClassStats {
                class: c.class,
                leases: c.leases,
                reuses: c.reuses,
                high_water: c.high_water,
                parked: c.parked.len(),
            })
            .collect();
        out.sort_by_key(|c| c.class);
        out
    }

    /// Move every active buffer onto its class free list, tagged with
    /// its current placement locus.
    fn park_active(&mut self, pid: Pid, alloc: &dyn Allocator) {
        if self.active.is_empty() {
            return;
        }
        let idx = self.class_index(self.active_class);
        for va in std::mem::take(&mut self.active) {
            let locus = alloc.locus(pid, va);
            self.classes[idx].parked.push(Parked { va, locus });
        }
    }

    /// Refresh the global and per-class peak-resident counters.
    fn note_high_water(&mut self, class_idx: usize) {
        self.high_water = self.high_water.max(self.len());
        let resident = self.classes[class_idx].parked.len()
            + if self.classes[class_idx].class == self.active_class {
                self.active.len()
            } else {
                0
            };
        let c = &mut self.classes[class_idx];
        c.high_water = c.high_water.max(resident);
    }

    /// Make at least `n` buffers of at least `len` bytes active,
    /// drawing from the matching class free list first and leasing
    /// from `alloc` only for the shortfall. New leases are placed with
    /// `alloc_align(hint)` when a hint is given (falling back to a
    /// plain allocation if the hint is not one of `alloc`'s live
    /// allocations); free-list draws under a hint only reuse buffers
    /// whose parked locus matches the hint's, so reuse preserves
    /// co-location. A class change parks the previous active set
    /// instead of releasing it — switching back later is free.
    pub fn ensure(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        alloc: &mut dyn Allocator,
        n: usize,
        len: u64,
        hint: Option<u64>,
    ) -> Result<()> {
        let class = Self::class_of(len);
        let want_locus = hint.and_then(|h| alloc.locus(proc.pid, h));
        // fast path: the active set already satisfies the demand AND
        // the placement (an unplaced demand takes any active set; a
        // placed one only an identically-placed set)
        if class == self.active_class
            && self.active.len() >= n
            && (want_locus.is_none() || want_locus == self.active_locus)
        {
            self.reuses += 1;
            let idx = self.class_index(class);
            self.classes[idx].reuses += 1;
            return Ok(());
        }
        if class != self.active_class
            || (want_locus.is_some() && want_locus != self.active_locus)
        {
            self.park_active(proc.pid, &*alloc);
            self.active_class = class;
        }
        // the set is now assembled for `want_locus` (an unplaced
        // top-up onto a kept set downgrades it to "no single locus")
        self.active_locus = want_locus;
        let idx = self.class_index(class);
        let mut leased = false;
        while self.active.len() < n {
            let drawn = {
                let parked = &mut self.classes[idx].parked;
                match want_locus {
                    // placement-tracking allocator: only a same-locus
                    // buffer keeps the kernel co-located
                    Some(l) => parked
                        .iter()
                        .rposition(|p| p.locus == Some(l))
                        .map(|i| parked.remove(i).va),
                    None => parked.pop().map(|p| p.va),
                }
            };
            match drawn {
                Some(va) => {
                    self.active.push(va);
                    self.classes[idx].reuses += 1;
                }
                None => {
                    let va = match hint {
                        Some(h) => {
                            match alloc.alloc_align(ctx, proc, class, h) {
                                Ok(va) => va,
                                Err(_) => alloc.alloc(ctx, proc, class)?,
                            }
                        }
                        None => alloc.alloc(ctx, proc, class)?,
                    };
                    self.active.push(va);
                    self.leases += 1;
                    self.classes[idx].leases += 1;
                    leased = true;
                }
            }
        }
        if !leased {
            self.reuses += 1;
        }
        self.note_high_water(idx);
        Ok(())
    }

    /// Return every resident buffer — active and parked — to `alloc`.
    /// The pool stays usable: the next `ensure` simply leases afresh.
    /// If a `free` fails (e.g. the caller passed a different allocator
    /// than the one that leased), the failing and untraversed buffers
    /// stay tracked in the pool so nothing leaks from the allocator's
    /// accounting.
    pub fn release_all(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        alloc: &mut dyn Allocator,
    ) -> Result<()> {
        self.trim(ctx, proc, alloc, 0)
    }

    /// Release resident buffers down to at most `keep` total,
    /// returning the surplus to `alloc`. Class-respecting order:
    /// parked buffers go first (non-active classes before the active
    /// one, newest first within a class), active buffers only after
    /// every free list is empty — so trimming between kernels sheds
    /// the stale classes a sweep has moved past while the hot working
    /// set stays leased. Error handling matches
    /// [`ScratchPool::release_all`]: on a failed `free` the buffer
    /// stays tracked and the error returns.
    pub fn trim(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        alloc: &mut dyn Allocator,
        keep: usize,
    ) -> Result<()> {
        while self.len() > keep {
            let parked_src = self
                .classes
                .iter()
                .position(|c| {
                    !c.parked.is_empty() && c.class != self.active_class
                })
                .or_else(|| {
                    self.classes.iter().position(|c| !c.parked.is_empty())
                });
            if let Some(i) = parked_src {
                let p = self.classes[i].parked.pop().expect("non-empty");
                if let Err(e) = alloc.free(ctx, proc, p.va) {
                    self.classes[i].parked.push(p);
                    return Err(e);
                }
            } else {
                let va = self.active.pop().expect("len > keep >= 0");
                if let Err(e) = alloc.free(ctx, proc, va) {
                    self.active.push(va);
                    return Err(e);
                }
            }
            self.releases += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::mallocsim::MallocSim;
    use crate::alloc::puma::{FitPolicy, PumaAlloc};
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::os::process::Pid;

    fn ctx() -> OsCtx {
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        OsCtx::boot(scheme, 16, 500, 5).unwrap()
    }

    #[test]
    fn leases_are_reused_not_reallocated() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let row = ctx.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 4).unwrap();
        let mut pool = ScratchPool::new();
        pool.ensure(&mut ctx, &mut proc, &mut puma, 2, row, None).unwrap();
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.leases, 2);
        let allocs_after_first = puma.stats().allocs;
        for _ in 0..100 {
            pool.ensure(&mut ctx, &mut proc, &mut puma, 2, row, None).unwrap();
        }
        assert_eq!(pool.leases, 2, "no re-leasing on stable demand");
        assert_eq!(pool.reuses, 100);
        assert_eq!(
            puma.stats().allocs,
            allocs_after_first,
            "no net allocation growth across repeated ensure calls"
        );
    }

    #[test]
    fn projected_len_models_reuse_and_class_changes() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let row = ctx.scheme.geometry.row_bytes as u64;
        let mut malloc = MallocSim::new();
        let mut pool = ScratchPool::new();
        // empty pool: everything is a fresh lease
        assert_eq!(pool.projected_len(3, row), 3);
        pool.ensure(&mut ctx, &mut proc, &mut malloc, 3, row, None).unwrap();
        // same class: steady-state demand projects no growth
        assert_eq!(pool.projected_len(3, row), 3);
        assert_eq!(pool.projected_len(5, row), 5);
        // class change parks the 3 and leases 2 fresh
        assert_eq!(pool.projected_len(2, 4 * row), 5);
        pool.ensure(&mut ctx, &mut proc, &mut malloc, 2, 4 * row, None)
            .unwrap();
        assert_eq!(pool.len(), 5);
        // switching back draws the parked trio instead of leasing
        assert_eq!(pool.projected_len(3, row), 5);
        assert_eq!(pool.projected_len(4, row), 6);
    }

    #[test]
    fn hinted_leases_colocate_with_the_hint() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let row = ctx.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 8).unwrap();
        let a = puma.alloc(&mut ctx, &mut proc, row).unwrap();
        let hint_sid = puma.lookup(Pid(1), a).unwrap().regions[0].sid;
        let mut pool = ScratchPool::new();
        pool.ensure(&mut ctx, &mut proc, &mut puma, 1, row, Some(a)).unwrap();
        let sid = puma.lookup(Pid(1), pool.slots()[0]).unwrap().regions[0].sid;
        assert_eq!(sid, hint_sid, "scratch co-locates with the hint");
        // a bogus hint degrades to a plain allocation, not an error
        let mut pool2 = ScratchPool::new();
        pool2
            .ensure(&mut ctx, &mut proc, &mut puma, 1, row, Some(0xDEAD000))
            .unwrap();
        assert_eq!(pool2.len(), 1);
    }

    #[test]
    fn locus_mismatched_parked_buffers_are_not_reused_under_a_hint() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let row = ctx.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 8).unwrap();
        // two anchors in different subarrays
        let a = puma.alloc(&mut ctx, &mut proc, row).unwrap();
        let b = puma.alloc(&mut ctx, &mut proc, row).unwrap();
        let sid_a = puma.locus(Pid(1), a).unwrap();
        let sid_b = puma.locus(Pid(1), b).unwrap();
        assert_ne!(sid_a, sid_b, "worst-fit spreads the anchors");
        let mut pool = ScratchPool::new();
        pool.ensure(&mut ctx, &mut proc, &mut puma, 1, row, Some(a)).unwrap();
        // park the a-co-located buffer by switching classes
        pool.ensure(&mut ctx, &mut proc, &mut puma, 1, 4 * row, None).unwrap();
        // a draw hinted at b must NOT recycle the a-located buffer
        pool.ensure(&mut ctx, &mut proc, &mut puma, 1, row, Some(b)).unwrap();
        assert_eq!(
            puma.locus(Pid(1), pool.slots()[0]),
            Some(sid_b),
            "hinted reuse preserves co-location"
        );
        // ...but a same-locus draw does come from the free list
        let leases = pool.leases;
        pool.ensure(&mut ctx, &mut proc, &mut puma, 1, 4 * row, None).unwrap();
        pool.ensure(&mut ctx, &mut proc, &mut puma, 1, row, Some(a)).unwrap();
        assert_eq!(pool.leases, leases, "same-locus buffer is recycled");
        assert_eq!(puma.locus(Pid(1), pool.slots()[0]), Some(sid_a));
    }

    #[test]
    fn trim_releases_surplus_and_keeps_residents() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(3));
        let mut m = MallocSim::new();
        let mut pool = ScratchPool::new();
        // a wide kernel leases 16 rows; trim back to the preferred 4
        pool.ensure(&mut ctx, &mut proc, &mut m, 16, 4096, None).unwrap();
        assert_eq!(pool.len(), 16);
        pool.trim(&mut ctx, &mut proc, &mut m, 4).unwrap();
        assert_eq!(pool.len(), 4);
        assert_eq!(pool.releases, 12);
        assert_eq!(pool.high_water, 16);
        // trimming below is a no-op when already within bounds
        pool.trim(&mut ctx, &mut proc, &mut m, 8).unwrap();
        assert_eq!(pool.len(), 4);
        // the residents stay usable without re-leasing
        let leases = pool.leases;
        pool.ensure(&mut ctx, &mut proc, &mut m, 4, 4096, None).unwrap();
        assert_eq!(pool.leases, leases);
        pool.release_all(&mut ctx, &mut proc, &mut m).unwrap();
        assert_eq!(m.stats().allocs, m.stats().frees);
    }

    #[test]
    fn trim_sheds_parked_classes_before_the_active_set() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(3));
        let mut m = MallocSim::new();
        let mut pool = ScratchPool::new();
        pool.ensure(&mut ctx, &mut proc, &mut m, 4, 4096, None).unwrap();
        pool.ensure(&mut ctx, &mut proc, &mut m, 4, 16384, None).unwrap();
        assert_eq!(pool.len(), 8, "the 4096-class set parked, not released");
        assert_eq!(pool.parked(), 4);
        // trimming to the active count drops exactly the parked class
        pool.trim(&mut ctx, &mut proc, &mut m, 4).unwrap();
        assert_eq!(pool.parked(), 0);
        assert_eq!(pool.slots().len(), 4, "active buffers survive the trim");
        assert_eq!(pool.slot_len(), 16384);
        pool.release_all(&mut ctx, &mut proc, &mut m).unwrap();
        assert!(pool.is_empty());
        assert_eq!(m.stats().allocs, m.stats().frees);
    }

    #[test]
    fn width_oscillation_does_zero_net_allocator_traffic_after_warmup() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(2));
        let mut m = MallocSim::new();
        let mut pool = ScratchPool::new();
        // warmup: one cell of each width's demand shape (the analytics
        // sweep's 8-bit and 16-bit cells lease different counts and
        // lengths)
        pool.ensure(&mut ctx, &mut proc, &mut m, 8, 4096, None).unwrap();
        pool.ensure(&mut ctx, &mut proc, &mut m, 16, 16384, None).unwrap();
        let warm_leases = pool.leases;
        let warm_allocs = m.stats().allocs;
        // 8 -> 16 -> 8 oscillation, many rounds
        for _ in 0..50 {
            pool.ensure(&mut ctx, &mut proc, &mut m, 8, 4096, None).unwrap();
            pool.ensure(&mut ctx, &mut proc, &mut m, 16, 16384, None).unwrap();
        }
        assert_eq!(
            pool.leases, warm_leases,
            "oscillation is served entirely from the class free lists"
        );
        assert_eq!(pool.releases, 0, "nothing went back to the allocator");
        assert_eq!(
            m.stats().allocs,
            warm_allocs,
            "zero net allocator traffic after warmup"
        );
        // per-class books agree
        let stats = pool.class_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].class, 4096);
        assert_eq!(stats[0].leases, 8);
        assert_eq!(stats[1].class, 16384);
        assert_eq!(stats[1].leases, 16);
        assert!(stats[0].reuses >= 50 * 8, "draws come from the free list");
        pool.release_all(&mut ctx, &mut proc, &mut m).unwrap();
        assert_eq!(m.stats().allocs, m.stats().frees);
    }
}
