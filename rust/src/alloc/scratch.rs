//! Scratch-region leases: the reusable temp-buffer pool behind the
//! expression compiler (and any other subsystem that needs transient
//! PUD-placed buffers).
//!
//! The historical pattern — allocate a fresh temp per operation and
//! hope someone frees it — both leaks under repeated use and scatters
//! temporaries across subarrays (a fresh worst-fit draw rarely lands
//! next to the operands, so every op touching the temp falls back to
//! the CPU). A [`ScratchPool`] fixes both: buffers are leased once,
//! co-located with a hint VA via the allocator's `alloc_align` path,
//! and reused across calls; `release_all` hands everything back when
//! the owner retires.
//!
//! The pool is allocator-agnostic (baselines simply ignore the hint —
//! exactly their deficiency) and sized on demand: the compiler's
//! register allocator asks for its `slots_needed`, which exceeds the
//! preferred bound only when an expression spills.

use anyhow::Result;

use crate::os::process::Process;

use super::traits::{Allocator, OsCtx};

/// A pool of same-length scratch buffers leased from an [`Allocator`].
#[derive(Debug, Default)]
pub struct ScratchPool {
    /// Bytes per leased buffer (0 until the first lease).
    slot_len: u64,
    /// VAs of the leased buffers, in slot order.
    slots: Vec<u64>,
    /// Buffers leased from the allocator over the pool's lifetime.
    pub leases: u64,
    /// Buffers returned via [`ScratchPool::release_all`].
    pub releases: u64,
    /// `ensure` calls fully served by already-leased buffers.
    pub reuses: u64,
    /// Peak resident buffer count.
    pub high_water: usize,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Leased buffer VAs, in slot order.
    pub fn slots(&self) -> &[u64] {
        &self.slots
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Bytes per buffer.
    pub fn slot_len(&self) -> u64 {
        self.slot_len
    }

    /// Make at least `n` buffers of at least `len` bytes resident,
    /// leasing from `alloc` as needed. New leases are placed with
    /// `alloc_align(hint)` when a hint is given (falling back to a
    /// plain allocation if the hint is not one of `alloc`'s live
    /// allocations), so compiler temporaries co-locate with the
    /// expression's operands. Growing `len` releases the old,
    /// too-short buffers first; shrinking reuses the larger ones.
    pub fn ensure(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        alloc: &mut dyn Allocator,
        n: usize,
        len: u64,
        hint: Option<u64>,
    ) -> Result<()> {
        if len > self.slot_len {
            self.release_all(ctx, proc, alloc)?;
            self.slot_len = len;
        }
        if self.slots.len() >= n {
            self.reuses += 1;
            return Ok(());
        }
        while self.slots.len() < n {
            let va = match hint {
                Some(h) => match alloc.alloc_align(ctx, proc, self.slot_len, h) {
                    Ok(va) => va,
                    Err(_) => alloc.alloc(ctx, proc, self.slot_len)?,
                },
                None => alloc.alloc(ctx, proc, self.slot_len)?,
            };
            self.slots.push(va);
            self.leases += 1;
        }
        self.high_water = self.high_water.max(self.slots.len());
        Ok(())
    }

    /// Return every leased buffer to `alloc`. The pool stays usable —
    /// the next `ensure` simply leases afresh. If a `free` fails (e.g.
    /// the caller passed a different allocator than the one that
    /// leased), the failing and untraversed buffers stay tracked in
    /// the pool so nothing leaks from the allocator's accounting.
    pub fn release_all(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        alloc: &mut dyn Allocator,
    ) -> Result<()> {
        self.trim(ctx, proc, alloc, 0)
    }

    /// Release leased buffers down to at most `keep` residents (newest
    /// first), returning the surplus to `alloc`. This is the pool-
    /// sizing valve for W-row intermediates: a 16-bit arithmetic
    /// kernel legitimately leases W+ scratch rows for one batch, but
    /// holding them between kernels pins subarray rows the allocator
    /// could serve to others — trim back to the preferred resident
    /// size (`DEFAULT_SCRATCH_POOL`) once the wide kernel retires.
    /// Error handling matches [`ScratchPool::release_all`]: on a
    /// failed `free` the buffer stays tracked and the error returns.
    pub fn trim(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        alloc: &mut dyn Allocator,
        keep: usize,
    ) -> Result<()> {
        while self.slots.len() > keep {
            let va = self.slots.pop().expect("len > keep >= 0");
            if let Err(e) = alloc.free(ctx, proc, va) {
                self.slots.push(va);
                return Err(e);
            }
            self.releases += 1;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::mallocsim::MallocSim;
    use crate::alloc::puma::{FitPolicy, PumaAlloc};
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::os::process::Pid;

    fn ctx() -> OsCtx {
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        OsCtx::boot(scheme, 16, 500, 5).unwrap()
    }

    #[test]
    fn leases_are_reused_not_reallocated() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let row = ctx.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 4).unwrap();
        let mut pool = ScratchPool::new();
        pool.ensure(&mut ctx, &mut proc, &mut puma, 2, row, None).unwrap();
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.leases, 2);
        let allocs_after_first = puma.stats().allocs;
        for _ in 0..100 {
            pool.ensure(&mut ctx, &mut proc, &mut puma, 2, row, None).unwrap();
        }
        assert_eq!(pool.leases, 2, "no re-leasing on stable demand");
        assert_eq!(pool.reuses, 100);
        assert_eq!(
            puma.stats().allocs,
            allocs_after_first,
            "no net allocation growth across repeated ensure calls"
        );
    }

    #[test]
    fn hinted_leases_colocate_with_the_hint() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(1));
        let row = ctx.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut ctx, 8).unwrap();
        let a = puma.alloc(&mut ctx, &mut proc, row).unwrap();
        let hint_sid = puma.lookup(Pid(1), a).unwrap().regions[0].sid;
        let mut pool = ScratchPool::new();
        pool.ensure(&mut ctx, &mut proc, &mut puma, 1, row, Some(a)).unwrap();
        let sid = puma.lookup(Pid(1), pool.slots()[0]).unwrap().regions[0].sid;
        assert_eq!(sid, hint_sid, "scratch co-locates with the hint");
        // a bogus hint degrades to a plain allocation, not an error
        let mut pool2 = ScratchPool::new();
        pool2
            .ensure(&mut ctx, &mut proc, &mut puma, 1, row, Some(0xDEAD000))
            .unwrap();
        assert_eq!(pool2.len(), 1);
    }

    #[test]
    fn trim_releases_surplus_and_keeps_residents() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(3));
        let mut m = MallocSim::new();
        let mut pool = ScratchPool::new();
        // a wide kernel leases 16 rows; trim back to the preferred 4
        pool.ensure(&mut ctx, &mut proc, &mut m, 16, 4096, None).unwrap();
        assert_eq!(pool.len(), 16);
        pool.trim(&mut ctx, &mut proc, &mut m, 4).unwrap();
        assert_eq!(pool.len(), 4);
        assert_eq!(pool.releases, 12);
        assert_eq!(pool.high_water, 16);
        // trimming below is a no-op when already within bounds
        pool.trim(&mut ctx, &mut proc, &mut m, 8).unwrap();
        assert_eq!(pool.len(), 4);
        // the residents stay usable without re-leasing
        let leases = pool.leases;
        pool.ensure(&mut ctx, &mut proc, &mut m, 4, 4096, None).unwrap();
        assert_eq!(pool.leases, leases);
        pool.release_all(&mut ctx, &mut proc, &mut m).unwrap();
        assert_eq!(m.stats().allocs, m.stats().frees);
    }

    #[test]
    fn growth_releases_short_buffers_and_release_all_balances() {
        let mut ctx = ctx();
        let mut proc = Process::new(Pid(2));
        let mut m = MallocSim::new();
        let mut pool = ScratchPool::new();
        pool.ensure(&mut ctx, &mut proc, &mut m, 2, 4096, None).unwrap();
        assert_eq!(pool.slot_len(), 4096);
        // longer demand: old buffers go back, new ones come out
        pool.ensure(&mut ctx, &mut proc, &mut m, 2, 16384, None).unwrap();
        assert_eq!(pool.slot_len(), 16384);
        assert_eq!(pool.leases, 4);
        assert_eq!(pool.releases, 2);
        // shorter demand reuses the bigger buffers
        pool.ensure(&mut ctx, &mut proc, &mut m, 2, 1024, None).unwrap();
        assert_eq!(pool.leases, 4);
        pool.release_all(&mut ctx, &mut proc, &mut m).unwrap();
        assert!(pool.is_empty());
        assert_eq!(pool.releases, 4);
        assert_eq!(m.stats().allocs, m.stats().frees);
    }
}
