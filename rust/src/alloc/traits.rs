//! Shared allocator interface and the OS context they operate on.

use anyhow::Result;

use crate::dram::address::InterleaveScheme;
use crate::os::buddy::BuddyAllocator;
use crate::os::hugepage::HugePagePool;
use crate::os::process::{Pid, Process};

/// OS-side cost model for allocation paths (simulated ns). These make
/// the small-allocation end of Figure 2 honest: fixed costs dominate
/// there, so speedups shrink — exactly the paper's observed trend.
#[derive(Debug, Clone, PartialEq)]
pub struct OsTiming {
    /// One mmap/brk syscall.
    pub syscall_ns: f64,
    /// One minor fault: allocate + map one 4 KiB frame.
    pub minor_fault_ns: f64,
    /// One huge-page fault: allocate + map one 2 MiB page.
    pub huge_fault_ns: f64,
    /// PUMA: selecting + mapping one memory region (hashmap + ordered
    /// array bookkeeping + PTE writes).
    pub puma_region_ns: f64,
    /// PUMA: re-mmap of one region when stitching VA (PTE rewrite +
    /// TLB shootdown).
    pub remap_region_ns: f64,
    /// PUMA: returning one fully-reassembled huge page to the boot
    /// pool (region-store scrub + hugetlb bookkeeping).
    pub reclaim_page_ns: f64,
}

impl Default for OsTiming {
    fn default() -> Self {
        Self {
            syscall_ns: 700.0,
            minor_fault_ns: 600.0,
            huge_fault_ns: 1_800.0,
            puma_region_ns: 350.0,
            remap_region_ns: 450.0,
            reclaim_page_ns: 1_200.0,
        }
    }
}

/// Cumulative allocator-side statistics.
///
/// Counter fields accumulate over the allocator's lifetime; the
/// `pool_*`/`fragmentation` fields are *gauges* PUMA refreshes after
/// every mutating call (they stay 0 for the baseline allocators, which
/// have no region pool). All four allocators keep the alloc-side and
/// free-side counters symmetric: every mapped page is eventually
/// counted in `pages_unmapped` when its allocation is released to the
/// OS, and `bytes_freed` mirrors `bytes_requested` (arena-recycled
/// chunks, which never go back to the OS, are counted on free too).
///
/// ```
/// use puma::alloc::traits::AllocStats;
/// let s = AllocStats { allocs: 3, frees: 3, ..Default::default() };
/// assert_eq!(s.allocs - s.frees, 0);
/// assert_eq!(s.pages_reclaimed, 0); // baselines never reclaim
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AllocStats {
    pub allocs: u64,
    pub frees: u64,
    pub bytes_requested: u64,
    /// Bytes handed back via `free` (counted per allocation, like
    /// `bytes_requested`, regardless of whether the backing memory
    /// returned to the OS or stayed in an arena).
    pub bytes_freed: u64,
    /// Simulated ns spent in allocation paths.
    pub alloc_ns: f64,
    /// 4 KiB pages mapped (either directly or within huge pages).
    pub pages_mapped: u64,
    /// 4 KiB pages whose translations were torn down on `free`.
    pub pages_unmapped: u64,
    /// PUMA: regions placed via the co-location (hint) path.
    pub hint_colocated: u64,
    /// PUMA: regions that had to fall back to worst-fit despite a hint.
    pub hint_missed: u64,
    /// PUMA: fully-reassembled huge pages returned to the boot pool.
    pub pages_reclaimed: u64,
    /// PUMA: regions moved by `compact()` (RowClone migration).
    pub regions_migrated: u64,
    /// PUMA: `compact()` passes executed.
    pub compactions: u64,
    /// Gauge — regions currently free in the PUD pool.
    pub pool_free_regions: u64,
    /// Gauge — allocated fraction of the carved PUD pool (0 when no
    /// pages are preallocated).
    pub pool_occupancy: f64,
    /// Gauge — fraction of preallocated huge pages that are *partially*
    /// free: they hold freed rows yet cannot be reclaimed because other
    /// rows are still live. This is exactly the capacity `compact()`
    /// exists to win back.
    pub fragmentation: f64,
}

/// Shared machine state the allocators draw from.
pub struct OsCtx {
    pub buddy: BuddyAllocator,
    pub pool: HugePagePool,
    pub scheme: InterleaveScheme,
    pub timing: OsTiming,
}

impl OsCtx {
    /// Build the standard evaluation machine: geometry from `scheme`,
    /// buddy covering the whole capacity, `huge_pages` reserved at
    /// boot, and the buddy churned with `churn_rounds` to model a
    /// long-running system.
    pub fn boot(
        scheme: InterleaveScheme,
        huge_pages: usize,
        churn_rounds: usize,
        seed: u64,
    ) -> Result<Self> {
        let mut buddy =
            BuddyAllocator::with_capacity_bytes(scheme.geometry.capacity_bytes())?;
        // reserve the boot-time pool *before* fragmentation, like Linux
        let pool = HugePagePool::reserve(&mut buddy, huge_pages)?;
        if churn_rounds > 0 {
            let mut rng = crate::util::rng::Pcg64::new(seed);
            buddy.churn(&mut rng, churn_rounds);
        }
        Ok(Self {
            buddy,
            pool,
            scheme,
            timing: OsTiming::default(),
        })
    }
}

/// Common allocator interface.
///
/// `alloc_align` is PUMA's `pim_alloc_align`: allocate `len` bytes
/// placed for PUD co-location with the allocation at `hint` (a VA
/// previously returned by `alloc`). Baseline allocators ignore the
/// hint — that is precisely their deficiency.
pub trait Allocator {
    fn name(&self) -> &'static str;

    /// Allocate `len` bytes in `proc`; returns the virtual address.
    fn alloc(&mut self, ctx: &mut OsCtx, proc: &mut Process, len: u64) -> Result<u64>;

    /// Allocate `len` bytes co-located with `hint` where supported.
    fn alloc_align(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        len: u64,
        hint: u64,
    ) -> Result<u64> {
        let _ = hint;
        self.alloc(ctx, proc, len)
    }

    /// Allocate `len` bytes placed for *bank-level spreading*: the
    /// anchor of shard `spread` of a sharded layout. PUMA targets the
    /// richest subarray of bank `spread % total_banks` (and sticks to
    /// one subarray across the allocation's regions), so sibling
    /// shards land on disjoint bank command timelines and the batch
    /// scheduler can overlap them — MIMDRAM-style SIMD. Baseline
    /// allocators ignore the spread exactly as they ignore hints.
    fn alloc_spread(
        &mut self,
        ctx: &mut OsCtx,
        proc: &mut Process,
        len: u64,
        spread: u32,
    ) -> Result<u64> {
        let _ = spread;
        self.alloc(ctx, proc, len)
    }

    /// Release the allocation at `va`.
    fn free(&mut self, ctx: &mut OsCtx, proc: &mut Process, va: u64) -> Result<()>;

    /// Placement locus of the live allocation at `va` — an opaque
    /// co-location key (PUMA reports the subarray id of the
    /// allocation's first region). Two allocations sharing a `Some`
    /// locus are PUD-co-located; `None` means the allocator doesn't
    /// track placement (every baseline). The size-classed scratch
    /// pool uses this to reuse a parked buffer only where reuse
    /// preserves co-location with the requested hint.
    fn locus(&self, pid: Pid, va: u64) -> Option<u64> {
        let _ = (pid, va);
        None
    }

    fn stats(&self) -> AllocStats;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::DramGeometry;

    #[test]
    fn boot_builds_machine() {
        let scheme = InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 2,
            rows_per_subarray: 1024,
            row_bytes: 4096,
        }); // 16 MiB
        let ctx = OsCtx::boot(scheme, 2, 500, 7).unwrap();
        assert_eq!(ctx.pool.available(), 2);
        assert!(ctx.buddy.free_frames() > 0);
    }

    #[test]
    fn boot_fails_if_pool_exceeds_memory() {
        let scheme = InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 1,
            subarrays_per_bank: 1,
            rows_per_subarray: 1024,
            row_bytes: 4096,
        }); // 4 MiB total
        assert!(OsCtx::boot(scheme, 3, 0, 0).is_err());
    }
}
