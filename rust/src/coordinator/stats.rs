//! Cumulative coordinator statistics.
//!
//! [`CoordStats`] counts the *work* (rows, bytes, simulated time);
//! those totals are invariant under batching: a batch of N ops and N
//! serial submits produce identical values. The exceptions are the
//! dispatch-shape counters `xla_dispatches`/`xla_wall_ns`, which count
//! what the loaded XLA runtime actually executed and therefore drop
//! when coalescing merges runs. [`PipelineStats`] counts the shape of
//! the request path (waves, coalesced dispatch units, cache hits,
//! batch makespans) in every mode and is where batching's gains are
//! measured.

use crate::pud::exec::ExecStats;
use crate::pud::legality::CauseCounts;
use crate::util::stats::HitRate;

/// Counters accumulated across every dispatched bulk operation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CoordStats {
    /// Bulk operations submitted.
    pub ops: u64,
    /// Operations whose *entire* plan ran in-DRAM (the paper's
    /// "executed in the PUD substrate" criterion).
    pub ops_fully_pud: HitRate,
    /// Row-granular split.
    pub pud_rows: u64,
    pub fallback_rows: u64,
    /// Per-cause breakdown of `fallback_rows` (always sums to it).
    pub fallback_causes: CauseCounts,
    pub pud_bytes: u64,
    pub fallback_bytes: u64,
    /// Simulated time, by path.
    pub pud_ns: f64,
    pub fallback_ns: f64,
    /// Allocation-side simulated time attributed to the workload.
    pub alloc_ns: f64,
    /// XLA dispatches issued by the fallback path.
    pub xla_dispatches: u64,
    /// Wall-clock nanoseconds spent inside XLA execution (real time,
    /// not simulated — used by §Perf only).
    pub xla_wall_ns: u64,
}

impl CoordStats {
    /// Total simulated time including allocation costs.
    pub fn total_sim_ns(&self) -> f64 {
        self.pud_ns + self.fallback_ns + self.alloc_ns
    }

    /// Fraction of rows executed in-DRAM.
    pub fn pud_row_fraction(&self) -> f64 {
        let total = self.pud_rows + self.fallback_rows;
        if total == 0 {
            0.0
        } else {
            self.pud_rows as f64 / total as f64
        }
    }

    pub fn absorb_exec(&mut self, e: &ExecStats) {
        self.pud_rows += e.pud_rows;
        self.fallback_rows += e.fallback_rows;
        self.fallback_causes.merge(&e.fallback_causes);
        self.pud_bytes += e.pud_bytes;
        self.fallback_bytes += e.fallback_bytes;
        self.pud_ns += e.pud_ns;
        self.fallback_ns += e.fallback_ns;
    }

    pub fn merge(&mut self, o: &CoordStats) {
        self.ops += o.ops;
        self.ops_fully_pud.merge(o.ops_fully_pud);
        self.pud_rows += o.pud_rows;
        self.fallback_rows += o.fallback_rows;
        self.fallback_causes.merge(&o.fallback_causes);
        self.pud_bytes += o.pud_bytes;
        self.fallback_bytes += o.fallback_bytes;
        self.pud_ns += o.pud_ns;
        self.fallback_ns += o.fallback_ns;
        self.alloc_ns += o.alloc_ns;
        self.xla_dispatches += o.xla_dispatches;
        self.xla_wall_ns += o.xla_wall_ns;
    }
}

/// Per-stage statistics of the plan/schedule/execute pipeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PipelineStats {
    /// Batches submitted (a plain `submit` is a one-element batch).
    pub batches: u64,
    /// Hazard waves executed across all batches.
    pub waves: u64,
    /// Operations lowered to [`super::plan::OpPlan`]s.
    pub planned_ops: u64,
    /// Extent-translation cache hit rate (copied from the planner).
    pub extent_cache: HitRate,
    /// Fallback dispatch units issued: one per coalesced dispatch
    /// group. Counted in Scalar mode too (where it measures what the
    /// XLA runtime *would* be asked to do); with the runtime loaded it
    /// equals the number of `run_op` calls.
    pub fallback_dispatches: u64,
    /// Fallback rows covered by those dispatches.
    pub coalesced_fallback_rows: u64,
    /// Simulated bank-parallel completion time summed over batches.
    /// Always <= the serial-equivalent `CoordStats` time sums.
    pub elapsed_ns: f64,
    /// Host wall-clock spent in each stage (§Perf only).
    pub plan_wall_ns: u64,
    pub schedule_wall_ns: u64,
    pub execute_wall_ns: u64,
}

impl PipelineStats {
    /// Mean ops per wave — >1 means the scheduler is extracting
    /// cross-op parallelism.
    pub fn ops_per_wave(&self) -> f64 {
        if self.waves == 0 {
            0.0
        } else {
            self.planned_ops as f64 / self.waves as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_ops_per_wave() {
        let mut p = PipelineStats::default();
        assert_eq!(p.ops_per_wave(), 0.0);
        p.planned_ops = 6;
        p.waves = 2;
        assert_eq!(p.ops_per_wave(), 3.0);
    }

    #[test]
    fn fractions_and_totals() {
        let mut s = CoordStats::default();
        assert_eq!(s.pud_row_fraction(), 0.0);
        s.absorb_exec(&ExecStats {
            pud_rows: 3,
            fallback_rows: 1,
            pud_bytes: 300,
            fallback_bytes: 100,
            pud_ns: 10.0,
            fallback_ns: 90.0,
            ..Default::default()
        });
        s.alloc_ns = 5.0;
        assert!((s.pud_row_fraction() - 0.75).abs() < 1e-12);
        assert_eq!(s.total_sim_ns(), 105.0);
    }

    #[test]
    fn merge_adds_everything() {
        let mut a = CoordStats {
            ops: 1,
            pud_rows: 2,
            ..Default::default()
        };
        let b = CoordStats {
            ops: 3,
            pud_rows: 5,
            xla_dispatches: 7,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.ops, 4);
        assert_eq!(a.pud_rows, 7);
        assert_eq!(a.xla_dispatches, 7);
    }
}
