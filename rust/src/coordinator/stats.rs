//! Cumulative coordinator statistics.

use crate::pud::exec::ExecStats;
use crate::util::stats::HitRate;

/// Counters accumulated across every dispatched bulk operation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CoordStats {
    /// Bulk operations submitted.
    pub ops: u64,
    /// Operations whose *entire* plan ran in-DRAM (the paper's
    /// "executed in the PUD substrate" criterion).
    pub ops_fully_pud: HitRate,
    /// Row-granular split.
    pub pud_rows: u64,
    pub fallback_rows: u64,
    pub pud_bytes: u64,
    pub fallback_bytes: u64,
    /// Simulated time, by path.
    pub pud_ns: f64,
    pub fallback_ns: f64,
    /// Allocation-side simulated time attributed to the workload.
    pub alloc_ns: f64,
    /// XLA dispatches issued by the fallback path.
    pub xla_dispatches: u64,
    /// Wall-clock nanoseconds spent inside XLA execution (real time,
    /// not simulated — used by §Perf only).
    pub xla_wall_ns: u64,
}

impl CoordStats {
    /// Total simulated time including allocation costs.
    pub fn total_sim_ns(&self) -> f64 {
        self.pud_ns + self.fallback_ns + self.alloc_ns
    }

    /// Fraction of rows executed in-DRAM.
    pub fn pud_row_fraction(&self) -> f64 {
        let total = self.pud_rows + self.fallback_rows;
        if total == 0 {
            0.0
        } else {
            self.pud_rows as f64 / total as f64
        }
    }

    pub fn absorb_exec(&mut self, e: &ExecStats) {
        self.pud_rows += e.pud_rows;
        self.fallback_rows += e.fallback_rows;
        self.pud_bytes += e.pud_bytes;
        self.fallback_bytes += e.fallback_bytes;
        self.pud_ns += e.pud_ns;
        self.fallback_ns += e.fallback_ns;
    }

    pub fn merge(&mut self, o: &CoordStats) {
        self.ops += o.ops;
        self.ops_fully_pud.merge(o.ops_fully_pud);
        self.pud_rows += o.pud_rows;
        self.fallback_rows += o.fallback_rows;
        self.pud_bytes += o.pud_bytes;
        self.fallback_bytes += o.fallback_bytes;
        self.pud_ns += o.pud_ns;
        self.fallback_ns += o.fallback_ns;
        self.alloc_ns += o.alloc_ns;
        self.xla_dispatches += o.xla_dispatches;
        self.xla_wall_ns += o.xla_wall_ns;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_and_totals() {
        let mut s = CoordStats::default();
        assert_eq!(s.pud_row_fraction(), 0.0);
        s.absorb_exec(&ExecStats {
            pud_rows: 3,
            fallback_rows: 1,
            pud_bytes: 300,
            fallback_bytes: 100,
            pud_ns: 10.0,
            fallback_ns: 90.0,
        });
        s.alloc_ns = 5.0;
        assert!((s.pud_row_fraction() - 0.75).abs() < 1e-12);
        assert_eq!(s.total_sim_ns(), 105.0);
    }

    #[test]
    fn merge_adds_everything() {
        let mut a = CoordStats {
            ops: 1,
            pud_rows: 2,
            ..Default::default()
        };
        let b = CoordStats {
            ops: 3,
            pud_rows: 5,
            xla_dispatches: 7,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.ops, 4);
        assert_eq!(a.pud_rows, 7);
        assert_eq!(a.xla_dispatches, 7);
    }
}
