//! Planning layer: lowers [`BulkRequest`]s into the shared [`OpPlan`]
//! IR the scheduler and executor consume.
//!
//! Planning is translate + legality only — nothing executes here. The
//! expensive part, walking the page table to derive physical extents,
//! is fronted by a per-process [`ExtentCache`] keyed on the process's
//! translation epoch: any unmap bumps the epoch
//! ([`Process::unmap_page`]) and implicitly invalidates every cached
//! extent list for that process (DESIGN.md §5). Long-running workloads
//! that re-submit over stable mappings — the common case under heavy
//! traffic — skip the page-table walk entirely.

use std::rc::Rc;

use anyhow::{bail, Result};
use rustc_hash::FxHashMap;

use crate::dram::address::InterleaveScheme;
use crate::os::process::{PhysExtent, Process};
use crate::pud::isa::{BulkRequest, PudOp};
use crate::pud::legality::{check_rowwise, RowPlan};
use crate::util::stats::HitRate;

/// The planned form of one bulk operation: per-row legality verdicts
/// plus the physical footprint used for hazard detection.
#[derive(Debug, Clone)]
pub struct OpPlan {
    pub op: PudOp,
    /// Operation length in bytes (common to all operands).
    pub len: u64,
    /// Row-by-row execution plan from [`check_rowwise`].
    pub rows: Vec<RowPlan>,
    /// Physical `[start, end)` intervals covered by the destination.
    pub dst_ranges: Vec<(u64, u64)>,
    /// Physical intervals covered by all source operands.
    pub src_ranges: Vec<(u64, u64)>,
}

fn ranges_overlap(a: &[(u64, u64)], b: &[(u64, u64)]) -> bool {
    // Extent lists are short (merged during translation), so the
    // quadratic scan beats building interval trees per op.
    a.iter()
        .any(|&(s1, e1)| b.iter().any(|&(s2, e2)| s1 < e2 && s2 < e1))
}

impl OpPlan {
    pub fn pud_rows(&self) -> u64 {
        self.rows.iter().filter(|r| r.is_pud()).count() as u64
    }

    pub fn fallback_rows(&self) -> u64 {
        self.rows.len() as u64 - self.pud_rows()
    }

    /// Whether the destination physically overlaps this op's own
    /// sources (memmove-style). Such ops keep their serial per-run
    /// dispatch order instead of being coalesced.
    pub fn self_aliased(&self) -> bool {
        ranges_overlap(&self.dst_ranges, &self.src_ranges)
    }

    /// Data hazard between two planned ops: any write-write or
    /// read-write overlap of their physical footprints. Hazardous ops
    /// must execute in submission order (separate scheduler waves).
    pub fn conflicts_with(&self, other: &OpPlan) -> bool {
        ranges_overlap(&self.dst_ranges, &other.dst_ranges)
            || ranges_overlap(&self.dst_ranges, &other.src_ranges)
            || ranges_overlap(&self.src_ranges, &other.dst_ranges)
    }
}

struct CacheEntry {
    epoch: u64,
    extents: Rc<Vec<PhysExtent>>,
}

/// Per-process extent-translation cache.
///
/// Keyed by `(pid, va, len)`; an entry is valid only while the owning
/// process's `translation_epoch` matches the one it was filled under.
/// The cache is flushed wholesale when it grows past `cap` — stale
/// epochs dominate by then and the entries are cheap to rebuild.
pub struct ExtentCache {
    entries: FxHashMap<(u32, u64, u64), CacheEntry>,
    /// Hit/miss counters (reported through the pipeline stats).
    pub lookups: HitRate,
    cap: usize,
}

impl Default for ExtentCache {
    fn default() -> Self {
        Self {
            entries: FxHashMap::default(),
            lookups: HitRate::default(),
            cap: 8192,
        }
    }
}

impl ExtentCache {
    /// Translate `va..va+len` of `proc`, serving from cache when the
    /// process's translation epoch still matches.
    pub fn get(
        &mut self,
        proc: &Process,
        va: u64,
        len: u64,
    ) -> Result<Rc<Vec<PhysExtent>>> {
        let key = (proc.pid.0, va, len);
        if let Some(e) = self.entries.get(&key) {
            if e.epoch == proc.translation_epoch {
                self.lookups.record(true);
                return Ok(Rc::clone(&e.extents));
            }
        }
        self.lookups.record(false);
        let extents = Rc::new(proc.phys_extents(va, len)?);
        if self.entries.len() >= self.cap {
            self.entries.clear();
        }
        self.entries.insert(
            key,
            CacheEntry {
                epoch: proc.translation_epoch,
                extents: Rc::clone(&extents),
            },
        );
        Ok(extents)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The planner: owns the translation cache and reusable operand
/// scratch so the hot path allocates nothing on cache hits beyond the
/// plan itself.
#[derive(Default)]
pub struct Planner {
    pub cache: ExtentCache,
    scratch: Vec<Rc<Vec<PhysExtent>>>,
}

impl Planner {
    /// Lower one request into an [`OpPlan`].
    pub fn plan(
        &mut self,
        scheme: &InterleaveScheme,
        proc: &Process,
        req: &BulkRequest,
    ) -> Result<OpPlan> {
        if req.len == 0 {
            bail!("zero-length bulk op");
        }
        // `BulkRequest::new` asserts this, but the fields are public;
        // catch hand-built requests at plan time (all-or-nothing)
        // rather than mid-batch in the executor.
        if req.srcs.len() != req.op.arity() {
            bail!(
                "arity mismatch for {}: {} srcs, want {}",
                req.op,
                req.srcs.len(),
                req.op.arity()
            );
        }
        self.scratch.clear();
        let dst = self.cache.get(proc, req.dst, req.len)?;
        self.scratch.push(dst);
        for s in &req.srcs {
            let e = self.cache.get(proc, *s, req.len)?;
            self.scratch.push(e);
        }
        let operands: Vec<&[PhysExtent]> =
            self.scratch.iter().map(|e| e.as_slice()).collect();
        let rows = check_rowwise(scheme, &operands, req.len);
        let dst_ranges = intervals(&self.scratch[0]);
        let mut src_ranges = Vec::new();
        for e in &self.scratch[1..] {
            src_ranges.extend(intervals(e));
        }
        self.scratch.clear();
        Ok(OpPlan {
            op: req.op,
            len: req.len,
            rows,
            dst_ranges,
            src_ranges,
        })
    }
}

fn intervals(extents: &[PhysExtent]) -> Vec<(u64, u64)> {
    extents
        .iter()
        .map(|e| (e.paddr, e.paddr + e.len))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::{DramGeometry, SubarrayId};
    use crate::os::process::Pid;
    use crate::os::vma::VmaKind;
    use crate::os::PAGE_SIZE;

    fn scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 4,
            rows_per_subarray: 64,
            row_bytes: 8192,
        })
    }

    /// Map `rows.len()` rows of subarray `sid` contiguously in VA.
    fn map_rows(proc: &mut Process, s: &InterleaveScheme, sid: u32, rows: &[u32]) -> u64 {
        let row_bytes = s.geometry.row_bytes as u64;
        let pages = row_bytes / PAGE_SIZE;
        let va = proc
            .mmap(rows.len() as u64 * row_bytes, row_bytes, VmaKind::Pud)
            .unwrap();
        for (i, r) in rows.iter().enumerate() {
            let pa = s.row_start_addr(SubarrayId(sid), *r);
            for p in 0..pages {
                proc.page_table
                    .map(
                        va + i as u64 * row_bytes + p * PAGE_SIZE,
                        pa + p * PAGE_SIZE,
                        crate::os::page_table::PageKind::Base,
                    )
                    .unwrap();
            }
        }
        va
    }

    #[test]
    fn cache_hits_on_stable_mappings() {
        let s = scheme();
        let mut proc = Process::new(Pid(1));
        let row = s.geometry.row_bytes as u64;
        let dst = map_rows(&mut proc, &s, 0, &[1]);
        let src = map_rows(&mut proc, &s, 0, &[2]);
        let mut planner = Planner::default();
        let req = BulkRequest::new(PudOp::Copy, dst, vec![src], row);
        let p1 = planner.plan(&s, &proc, &req).unwrap();
        assert_eq!(planner.cache.lookups.hits, 0);
        assert_eq!(planner.cache.lookups.total, 2);
        let p2 = planner.plan(&s, &proc, &req).unwrap();
        assert_eq!(planner.cache.lookups.hits, 2);
        assert_eq!(p1.rows, p2.rows);
        assert_eq!(p1.pud_rows(), 1);
    }

    #[test]
    fn unmap_invalidates_cached_extents() {
        let s = scheme();
        let mut proc = Process::new(Pid(1));
        let row = s.geometry.row_bytes as u64;
        let dst = map_rows(&mut proc, &s, 1, &[1]);
        let src = map_rows(&mut proc, &s, 1, &[2]);
        let mut planner = Planner::default();
        let req = BulkRequest::new(PudOp::Copy, dst, vec![src], row);
        planner.plan(&s, &proc, &req).unwrap();
        // tear the source down: the next plan must fail, not serve a
        // stale translation
        let pages = row / PAGE_SIZE;
        for p in 0..pages {
            proc.unmap_page(src + p * PAGE_SIZE).unwrap();
        }
        assert!(planner.plan(&s, &proc, &req).is_err());
    }

    #[test]
    fn footprints_and_hazards() {
        let s = scheme();
        let mut proc = Process::new(Pid(1));
        let row = s.geometry.row_bytes as u64;
        let a = map_rows(&mut proc, &s, 2, &[1]);
        let b = map_rows(&mut proc, &s, 2, &[2]);
        let c = map_rows(&mut proc, &s, 2, &[3]);
        let mut planner = Planner::default();
        // op1: b = copy(a); op2: c = copy(b)  -> RAW hazard
        let p1 = planner
            .plan(&s, &proc, &BulkRequest::new(PudOp::Copy, b, vec![a], row))
            .unwrap();
        let p2 = planner
            .plan(&s, &proc, &BulkRequest::new(PudOp::Copy, c, vec![b], row))
            .unwrap();
        assert!(p1.conflicts_with(&p2));
        assert!(p2.conflicts_with(&p1));
        assert!(!p1.self_aliased());
        // op3: c = copy(a) is independent of op1
        let p3 = planner
            .plan(&s, &proc, &BulkRequest::new(PudOp::Copy, c, vec![a], row))
            .unwrap();
        assert!(!p1.conflicts_with(&p3));
        // in-place op aliases itself
        let p4 = planner
            .plan(&s, &proc, &BulkRequest::new(PudOp::Copy, a, vec![a], row))
            .unwrap();
        assert!(p4.self_aliased());
    }

    #[test]
    fn zero_length_rejected() {
        let s = scheme();
        let proc = Process::new(Pid(1));
        let mut planner = Planner::default();
        let req = BulkRequest::new(PudOp::Zero, 0x4000, vec![], 0);
        assert!(planner.plan(&s, &proc, &req).is_err());
    }
}
