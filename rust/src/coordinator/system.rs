//! The fully assembled machine.
//!
//! [`System`] bundles the OS context (buddy + huge-page pool), the
//! DRAM/PUD engine, the coordinator, and a process table — everything
//! a workload needs. It is the single entry point the CLI, examples,
//! and benchmarks construct; allocators plug in per workload run.

use std::sync::Arc;

use anyhow::{bail, ensure, Result};
use rustc_hash::FxHashMap;

use crate::alloc::puma::{CompactReport, PumaAlloc};
use crate::alloc::request::AllocRequest;
use crate::analysis::lint::{self, Diagnostic};
use crate::analysis::{verify, VerifyLevel};
use crate::alloc::scratch::ScratchPool;
use crate::alloc::traits::{AllocStats, Allocator, OsCtx};
use crate::dram::address::InterleaveScheme;
use crate::obs::metrics::{CounterId, HistId, Snapshot};
use crate::dram::device::DramDevice;
use crate::dram::timing::TimingParams;
use crate::os::process::{Pid, Process};
use crate::pud::arith::{
    self, colcache::Lookup, ArithOp, Column, ColumnCache, ColumnCacheStats,
    ColumnKey, LayoutSpec, ProgramCache, ProgramCacheStats, ProgramKey,
    ResidentColumn, ShardedLayout, ShardedScratch, VerticalLayout,
};
use crate::pud::compiler::{self, Compiled, CompiledMulti, CompileStats, Expr};
use crate::pud::exec::PudEngine;
use crate::pud::isa::BulkRequest;
use crate::pud::legality::CauseCounts;
use crate::pud::reserved;
use crate::runtime::XlaRuntime;

use super::dispatch::{BatchReport, Coordinator, FallbackMode};

/// System construction options.
pub struct SystemConfig {
    pub scheme: InterleaveScheme,
    pub timing: TimingParams,
    /// Huge pages reserved at boot for the PUD pool.
    pub huge_pages: usize,
    /// Buddy churn rounds before workloads start (fragmentation).
    pub churn_rounds: usize,
    pub seed: u64,
    /// Artifacts directory to load the XLA runtime from; None =
    /// scalar fallback (simulation-only).
    pub artifacts: Option<std::path::PathBuf>,
    /// Static-analysis level on the request path (the placement linter
    /// and the program verifier; DESIGN.md §16). Defaults to whatever
    /// `PUMA_VERIFY` selects, so CI can run the entire suite under
    /// `PUMA_VERIFY=full` without touching any call site.
    pub verify: VerifyLevel,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            scheme: InterleaveScheme::row_major(Default::default()),
            timing: TimingParams::default(),
            huge_pages: 256, // 512 MiB PUD pool out of 8 GiB
            churn_rounds: 20_000,
            seed: 0xDEC0DE,
            artifacts: None,
            verify: VerifyLevel::from_env(),
        }
    }
}

/// Outcome of one compiled-expression run ([`System::run_expr`]):
/// the batch report plus the compiler's own statistics and the
/// execution-side PUD/fallback row split for exactly this expression.
#[derive(Debug, Clone)]
pub struct ExprReport {
    pub batch: BatchReport,
    pub stats: CompileStats,
    /// Rows of this expression's batch executed in-DRAM.
    pub pud_rows: u64,
    /// Rows that fell back to the CPU path.
    pub fallback_rows: u64,
    /// The fallback rows attributed to the PUMA placement requirement
    /// each violated (sums to `fallback_rows`).
    pub fallback_causes: CauseCounts,
}

impl ExprReport {
    /// In-DRAM fraction of this expression's rows.
    pub fn pud_row_fraction(&self) -> f64 {
        let total = self.pud_rows + self.fallback_rows;
        if total == 0 {
            0.0
        } else {
            self.pud_rows as f64 / total as f64
        }
    }
}

/// The machine: OS + DRAM/PUD + coordinator + processes.
pub struct System {
    pub os: OsCtx,
    pub coord: Coordinator,
    processes: FxHashMap<Pid, Process>,
    next_pid: u32,
    /// Per-process request queues drained by [`System::flush`].
    queued: FxHashMap<Pid, Vec<BulkRequest>>,
    /// The `(ArithOp, width)` compiled-program cache: every arithmetic
    /// entry point compiles each kernel exactly once per key and binds
    /// it per column (and per shard) thereafter.
    programs: ProgramCache,
    /// The resident-column cache: vertical columns persist in
    /// transposed form across kernels and sweep cells (transpose once,
    /// query many; see `pud::arith::colcache`).
    columns: ColumnCache,
    /// Pre-registered handles into the coordinator's metrics registry
    /// for the system-level metrics (allocation latency, hint
    /// outcomes, cache and scratch traffic; DESIGN.md §14).
    metric_ids: SysMetricIds,
}

/// Metric handles registered at boot for the System-owned paths.
#[derive(Debug, Clone, Copy)]
struct SysMetricIds {
    alloc_sim_ns: HistId,
    hint_missed: CounterId,
    hint_colocated: CounterId,
    program_hits: CounterId,
    program_misses: CounterId,
    scratch_leases: CounterId,
    scratch_reuses: CounterId,
}

impl SysMetricIds {
    fn register(reg: &mut crate::obs::metrics::Registry) -> Self {
        SysMetricIds {
            alloc_sim_ns: reg.hist("alloc/sim_ns"),
            hint_missed: reg.counter("alloc/hint_missed"),
            hint_colocated: reg.counter("alloc/hint_colocated"),
            program_hits: reg.counter("cache/program_hits"),
            program_misses: reg.counter("cache/program_misses"),
            scratch_leases: reg.counter("scratch/leases"),
            scratch_reuses: reg.counter("scratch/reuses"),
        }
    }
}

fn hit_ratio(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Does any physical row backing `va..va+len` of `proc` land on a
/// reserved Ambit control/temp row? The verifier's reserved-row probe:
/// translation failures answer `false` (the planner will surface the
/// unmapped operand as its own error).
fn va_on_reserved_row(
    proc: &Process,
    scheme: &InterleaveScheme,
    va: u64,
    len: u64,
) -> bool {
    let row_bytes = scheme.geometry.row_bytes as u64;
    let Ok(extents) = proc.phys_extents(va, len) else {
        return false;
    };
    for e in &extents {
        let mut pa = e.paddr;
        let end = e.paddr + e.len;
        while pa < end {
            let loc = scheme.decode(pa);
            if reserved::is_reserved(&scheme.geometry, loc.row) {
                return true;
            }
            pa += row_bytes - pa % row_bytes;
        }
    }
    false
}

impl System {
    pub fn boot(cfg: SystemConfig) -> Result<Self> {
        let os = OsCtx::boot(
            cfg.scheme.clone(),
            cfg.huge_pages,
            cfg.churn_rounds,
            cfg.seed,
        )?;
        let engine = PudEngine::new(DramDevice::new(cfg.scheme), cfg.timing);
        let fallback = match cfg.artifacts {
            Some(dir) => FallbackMode::Xla(XlaRuntime::load(dir)?),
            None => FallbackMode::Scalar,
        };
        let mut coord = Coordinator::new(engine, fallback);
        coord.verify = cfg.verify;
        let metric_ids = SysMetricIds::register(&mut coord.obs.registry);
        Ok(Self {
            os,
            coord,
            processes: FxHashMap::default(),
            next_pid: 1,
            queued: FxHashMap::default(),
            programs: ProgramCache::new(),
            columns: ColumnCache::new(),
            metric_ids,
        })
    }

    /// Snapshot the metrics registry with the cache-hit-rate gauges
    /// refreshed from the program and column caches. This is what
    /// `puma stats` and the Prometheus export render.
    pub fn metrics_snapshot(&mut self) -> Snapshot {
        let p = self.programs.stats;
        let c = self.columns.stats;
        let reg = &mut self.coord.obs.registry;
        let g = reg.gauge("cache/program_hit_rate");
        reg.set_gauge(g, hit_ratio(p.hits, p.misses));
        let g = reg.gauge("cache/column_host_hit_rate");
        reg.set_gauge(g, hit_ratio(c.host_hits, c.host_misses));
        let g = reg.gauge("cache/column_resident_hit_rate");
        reg.set_gauge(g, hit_ratio(c.resident_hits, c.resident_misses));
        reg.snapshot()
    }

    /// Fold one allocation call's stat deltas into the registry.
    fn record_alloc_metrics(&mut self, before: &AllocStats, after: &AllocStats) {
        let ids = self.metric_ids;
        let reg = &mut self.coord.obs.registry;
        reg.observe_ns(ids.alloc_sim_ns, after.alloc_ns - before.alloc_ns);
        reg.inc(ids.hint_missed, after.hint_missed - before.hint_missed);
        reg.inc(
            ids.hint_colocated,
            after.hint_colocated - before.hint_colocated,
        );
    }

    /// Hit/miss counters of the compiled-program cache.
    pub fn program_cache_stats(&self) -> ProgramCacheStats {
        self.programs.stats
    }

    /// Select how much static analysis runs on the request path: the
    /// placement linter on every batch at `Lint`, plus the program
    /// verifier + translation validator on every compiled emission at
    /// `Full` (see [`crate::analysis`]).
    pub fn set_verify(&mut self, level: VerifyLevel) {
        self.coord.verify = level;
    }

    /// The active static-analysis level.
    pub fn verify_level(&self) -> VerifyLevel {
        self.coord.verify
    }

    /// Drain the diagnostics accumulated by the linter and verifier
    /// (see [`Coordinator::take_diagnostics`]).
    pub fn take_diagnostics(&mut self) -> Vec<Diagnostic> {
        self.coord.take_diagnostics()
    }

    /// Run the program verifier over an emitted single-output stream
    /// when the level is `Full`; failures become `Error` diagnostics
    /// (and a `debug_assert!` in debug builds — "PudSan").
    #[allow(clippy::too_many_arguments)]
    fn verify_emitted(
        &mut self,
        pid: Pid,
        compiled: &Compiled,
        operands: &[u64],
        dst: u64,
        len: u64,
        scratch: &[u64],
        reqs: &[BulkRequest],
        site: &str,
    ) {
        if self.coord.verify < VerifyLevel::Full {
            return;
        }
        let failure = {
            let proc = &self.processes[&pid];
            let scheme = &self.coord.engine.device.scheme;
            let probe =
                |va: u64| va_on_reserved_row(proc, scheme, va, len);
            verify::verify_compiled(
                compiled,
                operands,
                dst,
                len,
                scratch,
                reqs,
                Some(&probe),
            )
            .err()
        };
        if let Some(e) = failure {
            self.coord
                .record_diagnostics(vec![lint::verify_failed(&e, site)]);
        }
    }

    /// Multi-output twin of [`System::verify_emitted`].
    #[allow(clippy::too_many_arguments)]
    fn verify_emitted_multi(
        &mut self,
        pid: Pid,
        compiled: &CompiledMulti,
        operands: &[u64],
        dsts: &[u64],
        len: u64,
        scratch: &[u64],
        reqs: &[BulkRequest],
        site: &str,
    ) {
        if self.coord.verify < VerifyLevel::Full {
            return;
        }
        let failure = {
            let proc = &self.processes[&pid];
            let scheme = &self.coord.engine.device.scheme;
            let probe =
                |va: u64| va_on_reserved_row(proc, scheme, va, len);
            verify::verify_compiled_multi(
                compiled,
                operands,
                dsts,
                len,
                scratch,
                reqs,
                Some(&probe),
            )
            .err()
        };
        if let Some(e) = failure {
            self.coord
                .record_diagnostics(vec![lint::verify_failed(&e, site)]);
        }
    }

    /// Fetch (or compile and cache) the program for `key`. Returns the
    /// compiled program and whether it was a cache hit — callers that
    /// report `CompileStats` should zero `compiles` on a hit, exactly
    /// as the `run_arith*` entry points do. This is the hook the
    /// `pud::query` engine uses to batch many per-constant programs
    /// into one submission without going through `run_arith_const`
    /// once per mask.
    pub fn program(&mut self, key: ProgramKey) -> (Arc<CompiledMulti>, bool) {
        let (program, hit) = self.programs.get_or_compile(key);
        let id = if hit {
            self.metric_ids.program_hits
        } else {
            self.metric_ids.program_misses
        };
        self.coord.obs.registry.inc(id, 1);
        (program, hit)
    }

    /// Drop every cached compiled program (see `ProgramCache::clear`)
    /// — the release valve after sweeping many distinct constant
    /// thresholds.
    pub fn clear_program_cache(&mut self) {
        self.programs.clear();
    }

    /// Spawn a fresh process address space.
    pub fn spawn(&mut self) -> Pid {
        let pid = Pid(self.next_pid);
        self.next_pid += 1;
        self.processes.insert(pid, Process::new(pid));
        pid
    }

    pub fn process(&self, pid: Pid) -> &Process {
        &self.processes[&pid]
    }

    pub fn process_mut(&mut self, pid: Pid) -> &mut Process {
        self.processes.get_mut(&pid).expect("live pid")
    }

    /// Place one [`AllocRequest`] in `pid` with `alloc` — the single
    /// allocation entry point the `alloc`/`alloc_align`/`alloc_spread`
    /// trio delegates to (PR 9 unification).
    pub fn alloc_with(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        req: AllocRequest,
    ) -> Result<u64> {
        let proc = self.processes.get_mut(&pid).expect("live pid");
        let before = alloc.stats();
        let va = req.place(alloc, &mut self.os, proc)?;
        let after = alloc.stats();
        self.record_alloc_metrics(&before, &after);
        if self.coord.verify >= VerifyLevel::Lint {
            let diags = lint::lint_alloc_hint(&before, &after, "system/alloc");
            self.coord.record_diagnostics(diags);
        }
        Ok(va)
    }

    /// Allocate `len` bytes in `pid` with `alloc`.
    pub fn alloc(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        len: u64,
    ) -> Result<u64> {
        self.alloc_with(alloc, pid, AllocRequest::bytes(len))
    }

    /// Allocate co-located with `hint` (PUMA's pim_alloc_align; the
    /// baselines ignore the hint).
    pub fn alloc_align(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        len: u64,
        hint: u64,
    ) -> Result<u64> {
        self.alloc_with(alloc, pid, AllocRequest::bytes(len).align_with(hint))
    }

    /// Allocate placed for bank-level spreading (shard `spread` of a
    /// sharded layout; see `Allocator::alloc_spread` — the baselines
    /// ignore the spread as they ignore hints).
    pub fn alloc_spread(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        len: u64,
        spread: u32,
    ) -> Result<u64> {
        self.alloc_with(alloc, pid, AllocRequest::bytes(len).spread(spread))
    }

    /// Free an allocation.
    pub fn free(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        va: u64,
    ) -> Result<()> {
        let proc = self.processes.get_mut(&pid).expect("live pid");
        alloc.free(&mut self.os, proc, va)
    }

    /// Submit a bulk operation for `pid`; returns simulated ns.
    pub fn submit(&mut self, pid: Pid, req: &BulkRequest) -> Result<f64> {
        let proc = self.processes.get(&pid).expect("live pid");
        self.coord.submit(proc, req)
    }

    /// Submit a batch of bulk operations for `pid` through the
    /// plan/schedule/execute pipeline. Results and stats totals are
    /// identical to submitting the requests serially; control
    /// overheads are amortized (see [`Coordinator::submit_batch`]).
    pub fn submit_batch(
        &mut self,
        pid: Pid,
        reqs: &[BulkRequest],
    ) -> Result<BatchReport> {
        let proc = self.processes.get(&pid).expect("live pid");
        self.coord.submit_batch(proc, reqs)
    }

    /// Submit one batch whose requests belong to *different*
    /// processes: request `i` resolves through `reqs[i].0`'s address
    /// space. This is the serving tier's merge point — a DRR round
    /// interleaves many tenants' queued requests into one batch so
    /// the hazard-wave scheduler overlaps their disjoint banks (see
    /// [`Coordinator::submit_batch_multi`] and `serve::Gateway`).
    pub fn submit_batch_tagged(
        &mut self,
        reqs: &[(Pid, BulkRequest)],
    ) -> Result<BatchReport> {
        let items: Vec<(&Process, &BulkRequest)> = reqs
            .iter()
            .map(|(pid, r)| (self.processes.get(pid).expect("live pid"), r))
            .collect();
        self.coord.submit_batch_multi(&items)
    }

    /// Queue a request for `pid` without executing it. Queued requests
    /// run as one batch at the next [`System::flush`].
    pub fn enqueue(&mut self, pid: Pid, req: BulkRequest) {
        self.queued.entry(pid).or_default().push(req);
    }

    /// Requests currently queued for `pid`.
    pub fn queued_len(&self, pid: Pid) -> usize {
        self.queued.get(&pid).map_or(0, Vec::len)
    }

    /// Drain `pid`'s queue through [`System::submit_batch`]. An empty
    /// queue yields an empty report.
    ///
    /// Error handling: planning errors are all-or-nothing (nothing
    /// has executed), so the batch is put back on the queue for
    /// inspection or retry. If the failure happened during execution
    /// a prefix of the batch has already run; the batch is then
    /// dropped — requeueing would double-execute that prefix on
    /// retry.
    pub fn flush(&mut self, pid: Pid) -> Result<BatchReport> {
        let reqs = self.queued.remove(&pid).unwrap_or_default();
        // Short-circuit the empty queue before touching the process
        // table: a pid that was spawned (or even already retired) but
        // never enqueued anything has nothing to run, and must not
        // trip the live-pid lookup below.
        if reqs.is_empty() {
            return Ok(BatchReport::default());
        }
        let ops_before = self.coord.stats.ops;
        let proc = self.processes.get(&pid).expect("live pid");
        match self.coord.submit_batch(proc, &reqs) {
            Ok(report) => Ok(report),
            Err(e) => {
                if self.coord.stats.ops == ops_before {
                    self.queued.insert(pid, reqs);
                }
                Err(e)
            }
        }
    }

    /// Make at least `n` scratch buffers of `len` bytes resident in
    /// `pool`, leased from `alloc` for `pid` and co-located with
    /// `hint` when given (see [`ScratchPool::ensure`]).
    pub fn lease_scratch(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        pool: &mut ScratchPool,
        n: usize,
        len: u64,
        hint: Option<u64>,
    ) -> Result<()> {
        let proc = self.processes.get_mut(&pid).expect("live pid");
        let (leases0, reuses0) = (pool.leases, pool.reuses);
        pool.ensure(&mut self.os, proc, alloc, n, len, hint)?;
        let ids = self.metric_ids;
        let reg = &mut self.coord.obs.registry;
        reg.inc(ids.scratch_leases, pool.leases - leases0);
        reg.inc(ids.scratch_reuses, pool.reuses - reuses0);
        Ok(())
    }

    /// Return every buffer of `pool` to `alloc` (see
    /// [`ScratchPool::release_all`]).
    pub fn release_scratch(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        pool: &mut ScratchPool,
    ) -> Result<()> {
        let proc = self.processes.get_mut(&pid).expect("live pid");
        pool.release_all(&mut self.os, proc, alloc)
    }

    /// Hit/miss counters of the resident-column cache.
    pub fn column_cache_stats(&self) -> ColumnCacheStats {
        self.columns.stats
    }

    /// Cap the resident-column cache at `columns` layouts (see
    /// `pud::arith::colcache::DEFAULT_COLUMN_BUDGET`).
    pub fn set_column_budget(&mut self, columns: usize) {
        self.columns.set_budget(columns);
    }

    /// Mark column `id` dirty after an in-place store to its planes:
    /// the next `cached_column`/`cached_column_sharded` for `id`
    /// rebuilds instead of serving the stale image.
    pub fn invalidate_column(&mut self, id: u64) {
        self.columns.invalidate(id);
    }

    /// Free every resident column leased through `alloc` for `pid` —
    /// the teardown path before the allocator retires (cached planes
    /// belong to the allocator that placed them).
    pub fn flush_columns(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
    ) -> Result<()> {
        for col in self.columns.drain_owned(alloc.name(), pid) {
            self.free_resident(alloc, pid, col)?;
        }
        Ok(())
    }

    /// Return a cache-dropped layout's planes to its allocator.
    fn free_resident(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        col: ResidentColumn,
    ) -> Result<()> {
        match col {
            ResidentColumn::Flat(l) => l.free(self, alloc, pid),
            ResidentColumn::Sharded(s) => s.free(self, alloc, pid),
        }
    }

    /// The cached host image of `(id, version)`, transposing `values`
    /// only on a miss.
    fn host_image(
        &mut self,
        id: u64,
        version: u64,
        width: u32,
        values: &[u64],
    ) -> Arc<Vec<Vec<u8>>> {
        if let Some(p) = self.columns.image(id, version, width, values.len()) {
            return p;
        }
        let p = Arc::new(arith::transpose(values, width));
        self.columns
            .insert_image(id, version, width, values.len(), p.clone());
        p
    }

    /// The resident [`Column`] of `id` under placement `spec` for
    /// `alloc`/`pid` — allocated, transposed, and stored on first use;
    /// served straight from the cache thereafter (transpose once,
    /// query many). The caller contract is that `(id, version)`
    /// identifies the content: pass a bumped `version` when `values`
    /// change (or call [`System::invalidate_column`] after an in-place
    /// store) and the stale layout is freed and rebuilt. A hit ignores
    /// `values` entirely — zero transpose, zero allocator traffic,
    /// zero store. Distinct specs of the same `id` are distinct cache
    /// entries sharing one host image.
    #[allow(clippy::too_many_arguments)]
    pub fn column(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        id: u64,
        version: u64,
        width: u32,
        values: &[u64],
        spec: LayoutSpec,
    ) -> Result<Column> {
        match spec {
            LayoutSpec::Flat => self
                .cached_column_impl(alloc, pid, id, version, width, values)
                .map(Column::Flat),
            LayoutSpec::Sharded(n) => self
                .cached_column_sharded_impl(
                    alloc, pid, id, version, width, values, n,
                )
                .map(Column::Sharded),
        }
    }

    /// Deprecated flat twin of [`System::column`].
    #[deprecated(note = "use System::column with LayoutSpec::Flat")]
    pub fn cached_column(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        id: u64,
        version: u64,
        width: u32,
        values: &[u64],
    ) -> Result<VerticalLayout> {
        self.cached_column_impl(alloc, pid, id, version, width, values)
    }

    pub(crate) fn cached_column_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        id: u64,
        version: u64,
        width: u32,
        values: &[u64],
    ) -> Result<VerticalLayout> {
        let epoch = self.process(pid).translation_epoch;
        let key = ColumnKey {
            id,
            owner: alloc.name(),
            pid,
            shards: 0,
        };
        match self.columns.lookup(key, version, epoch, width, values.len()) {
            Lookup::Hit(ResidentColumn::Flat(l)) => return Ok(l),
            Lookup::Hit(ResidentColumn::Sharded(_)) => {
                unreachable!("a shards=0 key only ever holds a flat layout")
            }
            Lookup::Stale(col) => self.free_resident(alloc, pid, col)?,
            Lookup::Miss => {}
        }
        let planes = self.host_image(id, version, width, values);
        let layout =
            VerticalLayout::alloc(self, alloc, pid, width, values.len())?;
        layout.store_planes(self, pid, &planes)?;
        for victim in self.columns.evict_for_insert(alloc.name(), pid) {
            self.free_resident(alloc, pid, victim)?;
        }
        self.columns.insert(
            key,
            version,
            epoch,
            width,
            values.len(),
            ResidentColumn::Flat(layout.clone()),
        );
        Ok(layout)
    }

    /// Deprecated sharded twin of [`System::column`].
    #[deprecated(note = "use System::column with LayoutSpec::Sharded")]
    #[allow(clippy::too_many_arguments)]
    pub fn cached_column_sharded(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        id: u64,
        version: u64,
        width: u32,
        values: &[u64],
        shards: usize,
    ) -> Result<ShardedLayout> {
        self.cached_column_sharded_impl(
            alloc, pid, id, version, width, values, shards,
        )
    }

    /// Sharded arm of [`System::column`]: the sharded layout shares
    /// the flat arm's host image — sweeping S=1..16 over one column
    /// transposes it exactly once, and each shard count's layout
    /// slices the image (byte-aligned shard boundaries) or
    /// re-transposes only its own ragged slice.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn cached_column_sharded_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        id: u64,
        version: u64,
        width: u32,
        values: &[u64],
        shards: usize,
    ) -> Result<ShardedLayout> {
        let epoch = self.process(pid).translation_epoch;
        let key = ColumnKey {
            id,
            owner: alloc.name(),
            pid,
            shards: shards.max(1) as u32,
        };
        match self.columns.lookup(key, version, epoch, width, values.len()) {
            Lookup::Hit(ResidentColumn::Sharded(l)) => return Ok(l),
            Lookup::Hit(ResidentColumn::Flat(_)) => {
                unreachable!("a shards>0 key only ever holds a sharded layout")
            }
            Lookup::Stale(col) => self.free_resident(alloc, pid, col)?,
            Lookup::Miss => {}
        }
        let planes = self.host_image(id, version, width, values);
        let layout = ShardedLayout::alloc(
            self,
            alloc,
            pid,
            width,
            values.len(),
            shards,
        )?;
        let mut off = 0usize;
        for part in layout.shards() {
            let n = part.elems();
            if off % 8 == 0 {
                // byte-aligned shard: slice the shared host image
                let b0 = off / 8;
                let blen = arith::plane_bytes(n) as usize;
                let slice: Vec<Vec<u8>> = planes
                    .iter()
                    .map(|p| p[b0..b0 + blen].to_vec())
                    .collect();
                part.store_planes(self, pid, &slice)?;
            } else {
                // unaligned boundary (chunk % 8 != 0): transpose just
                // this shard's slice
                part.store(self, pid, &values[off..off + n])?;
            }
            off += n;
        }
        for victim in self.columns.evict_for_insert(alloc.name(), pid) {
            self.free_resident(alloc, pid, victim)?;
        }
        self.columns.insert(
            key,
            version,
            epoch,
            width,
            values.len(),
            ResidentColumn::Sharded(layout.clone()),
        );
        Ok(layout)
    }

    /// Compile and execute a Boolean expression over `pid`'s operand
    /// buffers: `operands[i]` backs `Leaf(i)`, the result lands in
    /// `dst`, all buffers are `len` bytes. Scratch rows for the
    /// intermediates are leased from `alloc` into `pool` (co-located
    /// with the first operand) and reused across calls. The whole
    /// program runs as ONE [`System::submit_batch`], so independent
    /// subtrees overlap across banks in the hazard-wave scheduler.
    pub fn run_expr(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        expr: &Expr,
        operands: &[u64],
        dst: u64,
        len: u64,
        pool: &mut ScratchPool,
    ) -> Result<ExprReport> {
        let compiled = compiler::compile(expr);
        self.run_compiled(alloc, pid, &compiled, operands, dst, len, pool)
    }

    /// As [`System::run_expr`] for an already-compiled program
    /// (compile once, bind and execute many times).
    pub fn run_compiled(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        compiled: &Compiled,
        operands: &[u64],
        dst: u64,
        len: u64,
        pool: &mut ScratchPool,
    ) -> Result<ExprReport> {
        let hint = operands.first().copied();
        self.lease_scratch(alloc, pid, pool, compiled.scratch_needed(), len, hint)?;
        let reqs = compiled.emit(operands, dst, len, pool.slots())?;
        self.verify_emitted(
            pid,
            compiled,
            operands,
            dst,
            len,
            pool.slots(),
            &reqs,
            "system/run_compiled",
        );
        let (pud0, fb0) = (self.coord.stats.pud_rows, self.coord.stats.fallback_rows);
        let causes0 = self.coord.stats.fallback_causes;
        let batch = self.submit_batch(pid, &reqs)?;
        Ok(ExprReport {
            batch,
            stats: compiled.stats.clone(),
            pud_rows: self.coord.stats.pud_rows - pud0,
            fallback_rows: self.coord.stats.fallback_rows - fb0,
            fallback_causes: self.coord.stats.fallback_causes.delta(&causes0),
        })
    }

    /// As [`System::run_compiled`] for a multi-output program: output
    /// `k` lands in `dsts[k]`. The whole program — shared
    /// intermediates, every output plane, duplicate-output copies —
    /// runs as ONE [`System::submit_batch`].
    pub fn run_multi(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        compiled: &CompiledMulti,
        operands: &[u64],
        dsts: &[u64],
        len: u64,
        pool: &mut ScratchPool,
    ) -> Result<ExprReport> {
        let hint = operands.first().copied().or_else(|| dsts.first().copied());
        self.lease_scratch(alloc, pid, pool, compiled.scratch_needed(), len, hint)?;
        let reqs = compiled.emit(operands, dsts, len, pool.slots())?;
        self.verify_emitted_multi(
            pid,
            compiled,
            operands,
            dsts,
            len,
            pool.slots(),
            &reqs,
            "system/run_multi",
        );
        let (pud0, fb0) = (self.coord.stats.pud_rows, self.coord.stats.fallback_rows);
        let causes0 = self.coord.stats.fallback_causes;
        let batch = self.submit_batch(pid, &reqs)?;
        Ok(ExprReport {
            batch,
            stats: compiled.stats.clone(),
            pud_rows: self.coord.stats.pud_rows - pud0,
            fallback_rows: self.coord.stats.fallback_rows - fb0,
            fallback_causes: self.coord.stats.fallback_causes.delta(&causes0),
        })
    }

    /// Compile and run a bit-serial vertical-arithmetic kernel over
    /// transposed columns (`pud::arith`, DESIGN.md §10): `dst`'s
    /// planes receive `op(a, b)` element-wise, whatever placement the
    /// columns were allocated under. Unary kernels (popcount) take
    /// `b = None`; `dst` must have exactly `op.out_width(a.width())`
    /// planes; every operand must share one [`LayoutSpec`]. Flat
    /// columns lease scratch from `pools.pool(0)`; sharded columns
    /// lease shard `k`'s from `pools.pool(k)`. One `submit_batch`
    /// executes the whole W-bit kernel either way.
    #[allow(clippy::too_many_arguments)]
    pub fn arith(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        a: &Column,
        b: Option<&Column>,
        dst: &Column,
        pools: &mut ShardedScratch,
    ) -> Result<ExprReport> {
        match (a, dst) {
            (Column::Flat(a), Column::Flat(dst)) => {
                let b = match b {
                    None => None,
                    Some(Column::Flat(l)) => Some(l),
                    Some(Column::Sharded(_)) => {
                        bail!("operand layouts differ: flat `a`, sharded `b`")
                    }
                };
                self.run_arith_impl(alloc, pid, op, a, b, dst, pools.pool(0))
            }
            (Column::Sharded(a), Column::Sharded(dst)) => {
                let b = match b {
                    None => None,
                    Some(Column::Sharded(l)) => Some(l),
                    Some(Column::Flat(_)) => {
                        bail!("operand layouts differ: sharded `a`, flat `b`")
                    }
                };
                self.run_arith_sharded_impl(alloc, pid, op, a, b, dst, pools)
            }
            _ => bail!("operand and destination column layouts differ"),
        }
    }

    /// Deprecated flat twin of [`System::arith`].
    #[deprecated(note = "use System::arith over Column handles")]
    #[allow(clippy::too_many_arguments)]
    pub fn run_arith(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        a: &VerticalLayout,
        b: Option<&VerticalLayout>,
        dst: &VerticalLayout,
        pool: &mut ScratchPool,
    ) -> Result<ExprReport> {
        self.run_arith_impl(alloc, pid, op, a, b, dst, pool)
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_arith_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        a: &VerticalLayout,
        b: Option<&VerticalLayout>,
        dst: &VerticalLayout,
        pool: &mut ScratchPool,
    ) -> Result<ExprReport> {
        ensure!(
            op.is_binary() == b.is_some(),
            "{} is {}",
            op.name(),
            if op.is_binary() { "binary" } else { "unary" }
        );
        // VerticalLayout allows up to 64-bit columns (pure transpose
        // storage), but the kernels' reference arithmetic caps at
        // MAX_WIDTH — return Err, don't let kernel() assert
        ensure!(
            a.width() <= arith::MAX_WIDTH,
            "{}-bit operands exceed the {}-bit kernel limit",
            a.width(),
            arith::MAX_WIDTH
        );
        let mut operands: Vec<u64> = a.planes().to_vec();
        if let Some(b) = b {
            ensure!(
                b.width() == a.width() && b.elems() == a.elems(),
                "operand shapes differ: {}x{} vs {}x{}",
                a.elems(),
                a.width(),
                b.elems(),
                b.width()
            );
            operands.extend_from_slice(b.planes());
        }
        ensure!(
            dst.elems() == a.elems(),
            "dst holds {} element(s), operands {}",
            dst.elems(),
            a.elems()
        );
        ensure!(
            dst.width() == op.out_width(a.width()),
            "{} over {}-bit operands writes {} plane(s), dst has {}",
            op.name(),
            a.width(),
            op.out_width(a.width()),
            dst.width()
        );
        let (compiled, hit) = self.program(ProgramKey::Kernel(op, a.width()));
        let mut rep = self.run_multi(
            alloc,
            pid,
            &compiled,
            &operands,
            dst.planes(),
            a.plane_len(),
            pool,
        )?;
        if hit {
            rep.stats.compiles = 0;
        }
        Ok(rep)
    }

    /// As [`System::arith`] with operand `b` folded to the constant
    /// `rhs` at compile time (`arith::kernel_const`): the optimizer
    /// collapses the chain against the literal bits before a single
    /// request is emitted, and the compiled program is cached per
    /// `(op, width, rhs)`.
    #[allow(clippy::too_many_arguments)]
    pub fn arith_const(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        rhs: u64,
        a: &Column,
        dst: &Column,
        pools: &mut ShardedScratch,
    ) -> Result<ExprReport> {
        match (a, dst) {
            (Column::Flat(a), Column::Flat(dst)) => self
                .run_arith_const_impl(alloc, pid, op, rhs, a, dst, pools.pool(0)),
            (Column::Sharded(a), Column::Sharded(dst)) => self
                .run_arith_const_sharded_impl(alloc, pid, op, rhs, a, dst, pools),
            _ => bail!("operand and destination column layouts differ"),
        }
    }

    /// Deprecated flat twin of [`System::arith_const`].
    #[deprecated(note = "use System::arith_const over Column handles")]
    #[allow(clippy::too_many_arguments)]
    pub fn run_arith_const(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        rhs: u64,
        a: &VerticalLayout,
        dst: &VerticalLayout,
        pool: &mut ScratchPool,
    ) -> Result<ExprReport> {
        self.run_arith_const_impl(alloc, pid, op, rhs, a, dst, pool)
    }

    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_arith_const_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        rhs: u64,
        a: &VerticalLayout,
        dst: &VerticalLayout,
        pool: &mut ScratchPool,
    ) -> Result<ExprReport> {
        ensure!(op.is_binary(), "{} takes no second operand", op.name());
        ensure!(
            a.width() <= arith::MAX_WIDTH,
            "{}-bit operands exceed the {}-bit kernel limit",
            a.width(),
            arith::MAX_WIDTH
        );
        ensure!(
            dst.elems() == a.elems(),
            "dst holds {} element(s), operand {}",
            dst.elems(),
            a.elems()
        );
        ensure!(
            dst.width() == op.out_width(a.width()),
            "{} over {}-bit operands writes {} plane(s), dst has {}",
            op.name(),
            a.width(),
            op.out_width(a.width()),
            dst.width()
        );
        let rhs = rhs & arith::width_mask(a.width());
        let (compiled, hit) = self.program(ProgramKey::KernelConst(op, a.width(), rhs));
        let mut rep = self.run_multi(
            alloc,
            pid,
            &compiled,
            a.planes(),
            dst.planes(),
            a.plane_len(),
            pool,
        )?;
        if hit {
            rep.stats.compiles = 0;
        }
        Ok(rep)
    }

    /// Filter-then-sum reduction over a column: with a 1-bit predicate
    /// `mask` column, every value plane is AND-masked in-DRAM (one
    /// multi-output batch into pool-leased planes), then the masked
    /// planes are read back and tree-reduced on the host as
    /// `Σ_w 2^w · popcount(plane_w)` — the MIMDRAM-style hybrid
    /// reduction where the data-parallel masking stays in memory and
    /// only W row reads cross to the CPU. Without a mask the planes
    /// are read directly (no PUD work, `report` is `None`). `values`
    /// and `mask` must share one [`LayoutSpec`].
    pub fn column_sum(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        values: &Column,
        mask: Option<&Column>,
        pools: &mut ShardedScratch,
    ) -> Result<(u128, Option<ExprReport>)> {
        match values {
            Column::Flat(v) => {
                let mask = match mask {
                    None => None,
                    Some(Column::Flat(m)) => {
                        ensure!(
                            m.width() == 1,
                            "predicate mask must be a 1-bit column"
                        );
                        Some(m.planes()[0])
                    }
                    Some(Column::Sharded(_)) => {
                        bail!("mask layout differs: flat values, sharded mask")
                    }
                };
                self.arith_sum_impl(alloc, pid, v, mask, pools.pool(0))
            }
            Column::Sharded(v) => {
                let mask = match mask {
                    None => None,
                    Some(Column::Sharded(m)) => Some(m),
                    Some(Column::Flat(_)) => {
                        bail!("mask layout differs: sharded values, flat mask")
                    }
                };
                self.arith_sum_sharded_impl(alloc, pid, v, mask, pools)
            }
        }
    }

    /// Deprecated flat twin of [`System::column_sum`] (the mask is the
    /// raw VA of a 1-bit predicate plane).
    #[deprecated(note = "use System::column_sum over Column handles")]
    pub fn arith_sum(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        values: &VerticalLayout,
        mask: Option<u64>,
        pool: &mut ScratchPool,
    ) -> Result<(u128, Option<ExprReport>)> {
        self.arith_sum_impl(alloc, pid, values, mask, pool)
    }

    pub(crate) fn arith_sum_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        values: &VerticalLayout,
        mask: Option<u64>,
        pool: &mut ScratchPool,
    ) -> Result<(u128, Option<ExprReport>)> {
        let w = values.width() as usize;
        let len = values.plane_len();
        let Some(mask_va) = mask else {
            let mut sum: u128 = 0;
            for (i, &va) in values.planes().iter().enumerate() {
                // len == ceil(elems / 8) by construction, so padding is
                // < 8 bits here — popcount_live tolerates more anyway
                let bits = self.read_virt(pid, va, len)?;
                sum += (arith::popcount_live(&bits, values.elems()) as u128) << i;
            }
            return Ok((sum, None));
        };
        let (compiled, hit) = self.program(ProgramKey::MaskPlanes(values.width()));
        // lease the masked output planes and the program's scratch
        // from the same pool: slots [0, w) are dsts, the rest scratch
        let need = w + compiled.scratch_needed();
        self.lease_scratch(alloc, pid, pool, need, len, Some(values.hint()))?;
        let mut operands: Vec<u64> = values.planes().to_vec();
        operands.push(mask_va);
        let dsts: Vec<u64> = pool.slots()[..w].to_vec();
        let scratch: Vec<u64> = pool.slots()[w..need].to_vec();
        let reqs = compiled.emit(&operands, &dsts, len, &scratch)?;
        self.verify_emitted_multi(
            pid,
            &compiled,
            &operands,
            &dsts,
            len,
            &scratch,
            &reqs,
            "system/column_sum",
        );
        let (pud0, fb0) = (self.coord.stats.pud_rows, self.coord.stats.fallback_rows);
        let causes0 = self.coord.stats.fallback_causes;
        let batch = self.submit_batch(pid, &reqs)?;
        let mut stats = compiled.stats.clone();
        if hit {
            stats.compiles = 0;
        }
        let report = ExprReport {
            batch,
            stats,
            pud_rows: self.coord.stats.pud_rows - pud0,
            fallback_rows: self.coord.stats.fallback_rows - fb0,
            fallback_causes: self.coord.stats.fallback_causes.delta(&causes0),
        };
        let mut sum: u128 = 0;
        for (i, &va) in dsts.iter().enumerate() {
            // len == ceil(elems / 8): the leased slot may be longer,
            // but only the live prefix is read back and counted
            let bits = self.read_virt(pid, va, len)?;
            sum += (arith::popcount_live(&bits, values.elems()) as u128) << i;
        }
        Ok((sum, Some(report)))
    }

    /// Run a compiled multi-output program once per shard as ONE
    /// batch: shard `k` leases its scratch from `pools.pool(k)`
    /// (hinted to its own anchor), the per-shard request streams are
    /// interleaved round-robin so wave `w` carries every shard's
    /// `w`-th request, and the hazard-wave scheduler overlaps the
    /// shards across their disjoint banks while each shard's own
    /// dependency chain still serializes — the MIMDRAM SIMD execution
    /// model (DESIGN.md §11).
    fn submit_multi_sharded(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        compiled: &CompiledMulti,
        bindings: &[ShardBinding],
        pools: &mut ShardedScratch,
    ) -> Result<ExprReport> {
        ensure!(!bindings.is_empty(), "sharded run over zero shards");
        let need = compiled.scratch_needed();
        let mut per_shard: Vec<Vec<BulkRequest>> =
            Vec::with_capacity(bindings.len());
        for (k, b) in bindings.iter().enumerate() {
            self.lease_scratch(alloc, pid, pools.pool(k), need, b.len, Some(b.hint))?;
            let reqs = compiled.emit(
                &b.operands,
                &b.dsts,
                b.len,
                pools.pool(k).slots(),
            )?;
            self.verify_emitted_multi(
                pid,
                compiled,
                &b.operands,
                &b.dsts,
                b.len,
                pools.pool(k).slots(),
                &reqs,
                &format!("system/arith_sharded/shard{k}"),
            );
            per_shard.push(reqs);
        }
        let reqs = interleave_rounds(per_shard);
        let (pud0, fb0) =
            (self.coord.stats.pud_rows, self.coord.stats.fallback_rows);
        let causes0 = self.coord.stats.fallback_causes;
        let batch = self.submit_batch(pid, &reqs)?;
        Ok(ExprReport {
            batch,
            stats: compiled.stats.clone(),
            pud_rows: self.coord.stats.pud_rows - pud0,
            fallback_rows: self.coord.stats.fallback_rows - fb0,
            fallback_causes: self.coord.stats.fallback_causes.delta(&causes0),
        })
    }

    /// Deprecated sharded twin of [`System::arith`].
    #[deprecated(note = "use System::arith over Column handles")]
    #[allow(clippy::too_many_arguments)]
    pub fn run_arith_sharded(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        a: &ShardedLayout,
        b: Option<&ShardedLayout>,
        dst: &ShardedLayout,
        pools: &mut ShardedScratch,
    ) -> Result<ExprReport> {
        self.run_arith_sharded_impl(alloc, pid, op, a, b, dst, pools)
    }

    /// Sharded arm of [`System::arith`]: the `(op, width)` kernel is
    /// compiled ONCE (program cache), emitted once per shard, and
    /// submitted as ONE batch whose waves overlap the shards across
    /// banks — the batch makespan drops toward `1/min(S, banks)` of
    /// the single-subarray layout's.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_arith_sharded_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        a: &ShardedLayout,
        b: Option<&ShardedLayout>,
        dst: &ShardedLayout,
        pools: &mut ShardedScratch,
    ) -> Result<ExprReport> {
        ensure!(
            op.is_binary() == b.is_some(),
            "{} is {}",
            op.name(),
            if op.is_binary() { "binary" } else { "unary" }
        );
        ensure!(
            a.width() <= arith::MAX_WIDTH,
            "{}-bit operands exceed the {}-bit kernel limit",
            a.width(),
            arith::MAX_WIDTH
        );
        if let Some(b) = b {
            ensure!(
                b.width() == a.width()
                    && b.elems() == a.elems()
                    && b.n_shards() == a.n_shards(),
                "operand shapes differ: {}x{}x{} vs {}x{}x{} shard(s)",
                a.elems(),
                a.width(),
                a.n_shards(),
                b.elems(),
                b.width(),
                b.n_shards()
            );
        }
        ensure!(
            dst.elems() == a.elems() && dst.n_shards() == a.n_shards(),
            "dst holds {}x{} shard(s), operands {}x{}",
            dst.elems(),
            dst.n_shards(),
            a.elems(),
            a.n_shards()
        );
        ensure!(
            dst.width() == op.out_width(a.width()),
            "{} over {}-bit operands writes {} plane(s), dst has {}",
            op.name(),
            a.width(),
            op.out_width(a.width()),
            dst.width()
        );
        let (compiled, hit) = self.program(ProgramKey::Kernel(op, a.width()));
        let mut bindings = Vec::with_capacity(a.n_shards());
        for k in 0..a.n_shards() {
            let pa = a.shard(k);
            ensure!(
                dst.shard(k).elems() == pa.elems(),
                "shard {k}: dst holds {} element(s), operand {}",
                dst.shard(k).elems(),
                pa.elems()
            );
            let mut operands: Vec<u64> = pa.planes().to_vec();
            if let Some(b) = b {
                ensure!(
                    b.shard(k).elems() == pa.elems(),
                    "shard {k}: operand shard sizes differ"
                );
                operands.extend_from_slice(b.shard(k).planes());
            }
            bindings.push(ShardBinding {
                operands,
                dsts: dst.shard(k).planes().to_vec(),
                len: pa.plane_len(),
                hint: pa.hint(),
            });
        }
        let mut rep =
            self.submit_multi_sharded(alloc, pid, &compiled, &bindings, pools)?;
        if hit {
            rep.stats.compiles = 0;
        }
        Ok(rep)
    }

    /// Deprecated sharded twin of [`System::arith_const`].
    #[deprecated(note = "use System::arith_const over Column handles")]
    #[allow(clippy::too_many_arguments)]
    pub fn run_arith_const_sharded(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        rhs: u64,
        a: &ShardedLayout,
        dst: &ShardedLayout,
        pools: &mut ShardedScratch,
    ) -> Result<ExprReport> {
        self.run_arith_const_sharded_impl(alloc, pid, op, rhs, a, dst, pools)
    }

    /// Sharded arm of [`System::arith_const`]: one cached
    /// constant-folded program, one batch, waves overlapped across
    /// the shards' banks.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_arith_const_sharded_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        op: ArithOp,
        rhs: u64,
        a: &ShardedLayout,
        dst: &ShardedLayout,
        pools: &mut ShardedScratch,
    ) -> Result<ExprReport> {
        ensure!(op.is_binary(), "{} takes no second operand", op.name());
        ensure!(
            a.width() <= arith::MAX_WIDTH,
            "{}-bit operands exceed the {}-bit kernel limit",
            a.width(),
            arith::MAX_WIDTH
        );
        ensure!(
            dst.elems() == a.elems() && dst.n_shards() == a.n_shards(),
            "dst holds {}x{} shard(s), operand {}x{}",
            dst.elems(),
            dst.n_shards(),
            a.elems(),
            a.n_shards()
        );
        ensure!(
            dst.width() == op.out_width(a.width()),
            "{} over {}-bit operands writes {} plane(s), dst has {}",
            op.name(),
            a.width(),
            op.out_width(a.width()),
            dst.width()
        );
        let rhs = rhs & arith::width_mask(a.width());
        let (compiled, hit) = self.program(ProgramKey::KernelConst(op, a.width(), rhs));
        let mut bindings = Vec::with_capacity(a.n_shards());
        for k in 0..a.n_shards() {
            let pa = a.shard(k);
            ensure!(
                dst.shard(k).elems() == pa.elems(),
                "shard {k}: dst holds {} element(s), operand {}",
                dst.shard(k).elems(),
                pa.elems()
            );
            bindings.push(ShardBinding {
                operands: pa.planes().to_vec(),
                dsts: dst.shard(k).planes().to_vec(),
                len: pa.plane_len(),
                hint: pa.hint(),
            });
        }
        let mut rep =
            self.submit_multi_sharded(alloc, pid, &compiled, &bindings, pools)?;
        if hit {
            rep.stats.compiles = 0;
        }
        Ok(rep)
    }

    /// Deprecated sharded twin of [`System::column_sum`].
    #[deprecated(note = "use System::column_sum over Column handles")]
    pub fn arith_sum_sharded(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        values: &ShardedLayout,
        mask: Option<&ShardedLayout>,
        pools: &mut ShardedScratch,
    ) -> Result<(u128, Option<ExprReport>)> {
        self.arith_sum_sharded_impl(alloc, pid, values, mask, pools)
    }

    /// Sharded arm of [`System::column_sum`]: every shard's plane-AND
    /// masking lands in the same single batch (waves overlapped across
    /// banks), then the host reads each shard's W masked planes and
    /// tree-reduces — `popcount_live` is applied per shard with that
    /// shard's element count, so the ragged last shard's padding never
    /// miscounts.
    pub(crate) fn arith_sum_sharded_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        values: &ShardedLayout,
        mask: Option<&ShardedLayout>,
        pools: &mut ShardedScratch,
    ) -> Result<(u128, Option<ExprReport>)> {
        let w = values.width() as usize;
        let Some(mask) = mask else {
            let mut sum: u128 = 0;
            for part in values.shards() {
                for (i, &va) in part.planes().iter().enumerate() {
                    let bits = self.read_virt(pid, va, part.plane_len())?;
                    sum +=
                        (arith::popcount_live(&bits, part.elems()) as u128) << i;
                }
            }
            return Ok((sum, None));
        };
        ensure!(mask.width() == 1, "predicate mask must be a 1-bit column");
        ensure!(
            mask.elems() == values.elems() && mask.n_shards() == values.n_shards(),
            "mask holds {}x{} shard(s), values {}x{}",
            mask.elems(),
            mask.n_shards(),
            values.elems(),
            values.n_shards()
        );
        let (compiled, hit) = self.program(ProgramKey::MaskPlanes(values.width()));
        let need = w + compiled.scratch_needed();
        let mut per_shard: Vec<Vec<BulkRequest>> =
            Vec::with_capacity(values.n_shards());
        let mut dsts_per_shard: Vec<Vec<u64>> =
            Vec::with_capacity(values.n_shards());
        for (k, part) in values.shards().iter().enumerate() {
            ensure!(
                mask.shard(k).elems() == part.elems(),
                "shard {k}: mask shard sizes differ"
            );
            let len = part.plane_len();
            self.lease_scratch(
                alloc,
                pid,
                pools.pool(k),
                need,
                len,
                Some(part.hint()),
            )?;
            let pool = pools.pool(k);
            let dsts: Vec<u64> = pool.slots()[..w].to_vec();
            let scratch: Vec<u64> = pool.slots()[w..need].to_vec();
            let mut operands: Vec<u64> = part.planes().to_vec();
            operands.push(mask.shard(k).planes()[0]);
            let reqs = compiled.emit(&operands, &dsts, len, &scratch)?;
            self.verify_emitted_multi(
                pid,
                &compiled,
                &operands,
                &dsts,
                len,
                &scratch,
                &reqs,
                &format!("system/column_sum_sharded/shard{k}"),
            );
            per_shard.push(reqs);
            dsts_per_shard.push(dsts);
        }
        let reqs = interleave_rounds(per_shard);
        let (pud0, fb0) =
            (self.coord.stats.pud_rows, self.coord.stats.fallback_rows);
        let causes0 = self.coord.stats.fallback_causes;
        let batch = self.submit_batch(pid, &reqs)?;
        let mut stats = compiled.stats.clone();
        if hit {
            stats.compiles = 0;
        }
        let report = ExprReport {
            batch,
            stats,
            pud_rows: self.coord.stats.pud_rows - pud0,
            fallback_rows: self.coord.stats.fallback_rows - fb0,
            fallback_causes: self.coord.stats.fallback_causes.delta(&causes0),
        };
        let mut sum: u128 = 0;
        for (k, part) in values.shards().iter().enumerate() {
            for (i, &va) in dsts_per_shard[k].iter().enumerate() {
                let bits = self.read_virt(pid, va, part.plane_len())?;
                sum += (arith::popcount_live(&bits, part.elems()) as u128) << i;
            }
        }
        Ok((sum, Some(report)))
    }

    /// Trim every per-shard pool of `pools` to at most `keep` resident
    /// buffers each (see [`ScratchPool::trim`]) — the release valve
    /// after a wide arithmetic kernel leased W-row intermediates.
    /// Covers flat columns too (their scratch lives in `pools.pool(0)`).
    pub fn trim_pools(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        pools: &mut ShardedScratch,
        keep: usize,
    ) -> Result<()> {
        for k in 0..pools.n_pools() {
            self.trim_scratch_impl(alloc, pid, pools.pool(k), keep)?;
        }
        Ok(())
    }

    /// Deprecated sharded twin of [`System::trim_pools`].
    #[deprecated(note = "use System::trim_pools")]
    pub fn trim_scratch_sharded(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        pools: &mut ShardedScratch,
        keep: usize,
    ) -> Result<()> {
        self.trim_pools(alloc, pid, pools, keep)
    }

    /// Deprecated single-pool twin of [`System::trim_pools`].
    #[deprecated(note = "use System::trim_pools")]
    pub fn trim_scratch(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        pool: &mut ScratchPool,
        keep: usize,
    ) -> Result<()> {
        self.trim_scratch_impl(alloc, pid, pool, keep)
    }

    pub(crate) fn trim_scratch_impl(
        &mut self,
        alloc: &mut dyn Allocator,
        pid: Pid,
        pool: &mut ScratchPool,
        keep: usize,
    ) -> Result<()> {
        let proc = self.processes.get_mut(&pid).expect("live pid");
        pool.trim(&mut self.os, proc, alloc, keep)
    }

    /// Run one PUMA compaction pass for `pid`: flush its queued
    /// requests (so nothing executes against stale placements), then
    /// repair co-location and evacuate thin pages via batched RowClone
    /// copies, and reclaim every huge page that reassembled (see
    /// [`PumaAlloc::compact`] and DESIGN.md §8).
    pub fn compact(
        &mut self,
        alloc: &mut PumaAlloc,
        pid: Pid,
    ) -> Result<CompactReport> {
        self.flush(pid)?;
        let proc = self.processes.get_mut(&pid).expect("live pid");
        alloc.compact(&mut self.os, proc, &mut self.coord)
    }

    /// Write bytes through a process's virtual mapping (test/workload
    /// seeding).
    pub fn write_virt(&mut self, pid: Pid, va: u64, data: &[u8]) -> Result<()> {
        let proc = self.processes.get(&pid).expect("live pid");
        for (off, ext) in extents_with_offsets(proc, va, data.len() as u64)? {
            self.coord
                .engine
                .device
                .write(ext.paddr, &data[off as usize..(off + ext.len) as usize]);
        }
        Ok(())
    }

    /// Read bytes through a process's virtual mapping.
    pub fn read_virt(&mut self, pid: Pid, va: u64, len: u64) -> Result<Vec<u8>> {
        let proc = self.processes.get(&pid).expect("live pid");
        let mut out = vec![0u8; len as usize];
        for (off, ext) in extents_with_offsets(proc, va, len)? {
            let mut buf = vec![0u8; ext.len as usize];
            self.coord.engine.device.read(ext.paddr, &mut buf);
            out[off as usize..(off + ext.len) as usize].copy_from_slice(&buf);
        }
        Ok(out)
    }
}

/// One shard's address binding of a compiled multi-output program.
struct ShardBinding {
    operands: Vec<u64>,
    dsts: Vec<u64>,
    len: u64,
    /// Scratch co-location hint (the shard's anchor plane).
    hint: u64,
}

/// Round-robin merge of per-stream request sequences: position `i` of
/// every stream lands adjacent in the batch, so the wave builder
/// (which scans in submission order) groups the streams' independent
/// step-`i` requests into one wave and overlaps them across banks,
/// while each stream's own step `i+1` — which depends on its step `i`
/// — starts the next wave. Shared by the sharded kernels (streams =
/// shards) and the serving tier's DRR rounds (streams = tenants,
/// hence the generic item: tenants carry `(Pid, BulkRequest)` pairs).
pub(crate) fn interleave_rounds<T>(per_shard: Vec<Vec<T>>) -> Vec<T> {
    let total = per_shard.iter().map(Vec::len).sum();
    let mut streams: Vec<std::vec::IntoIter<T>> =
        per_shard.into_iter().map(Vec::into_iter).collect();
    let mut out = Vec::with_capacity(total);
    loop {
        let before = out.len();
        for stream in &mut streams {
            if let Some(r) = stream.next() {
                out.push(r);
            }
        }
        if out.len() == before {
            break;
        }
    }
    out
}

fn extents_with_offsets(
    proc: &Process,
    va: u64,
    len: u64,
) -> Result<Vec<(u64, crate::os::process::PhysExtent)>> {
    let exts = proc.phys_extents(va, len)?;
    let mut out = Vec::with_capacity(exts.len());
    let mut off = 0u64;
    for e in exts {
        out.push((off, e));
        off += e.len;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    // Several tests drive the deprecated flat/sharded shims on purpose
    // — they are one-line delegations to the `_impl` bodies, so this
    // keeps the legacy surface covered until it is removed.
    #![allow(deprecated)]

    use super::*;
    use crate::alloc::puma::{FitPolicy, PumaAlloc};
    use crate::alloc::mallocsim::MallocSim;
    use crate::pud::isa::PudOp;

    fn small_system() -> System {
        let scheme = InterleaveScheme::row_major(
            crate::dram::geometry::DramGeometry::small(), // 64 MiB
        );
        System::boot(SystemConfig {
            scheme,
            huge_pages: 8,
            churn_rounds: 3_000,
            seed: 9,
            timing: TimingParams::default(),
            artifacts: None,
        })
        .unwrap()
    }

    #[test]
    fn virt_io_roundtrip() {
        let mut sys = small_system();
        let pid = sys.spawn();
        let mut m = MallocSim::new();
        let va = sys.alloc(&mut m, pid, 50_000).unwrap();
        let data: Vec<u8> = (0..50_000u32).map(|i| (i % 251) as u8).collect();
        sys.write_virt(pid, va, &data).unwrap();
        assert_eq!(sys.read_virt(pid, va, 50_000).unwrap(), data);
    }

    #[test]
    fn puma_flow_end_to_end() {
        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 6).unwrap();
        let len = 8 * row;
        let a = sys.alloc(&mut puma, pid, len).unwrap();
        let b = sys.alloc_align(&mut puma, pid, len, a).unwrap();
        let c = sys.alloc_align(&mut puma, pid, len, a).unwrap();
        let va: Vec<u8> = (0..len).map(|i| (i % 256) as u8).collect();
        let vb: Vec<u8> = (0..len).map(|i| ((i / 3) % 256) as u8).collect();
        sys.write_virt(pid, a, &va).unwrap();
        sys.write_virt(pid, b, &vb).unwrap();
        let req = BulkRequest::new(PudOp::And, c, vec![a, b], len);
        sys.submit(pid, &req).unwrap();
        assert!(
            sys.coord.stats.pud_row_fraction() > 0.99,
            "PUMA placement should be fully PUD-executable"
        );
        let want: Vec<u8> = va.iter().zip(&vb).map(|(x, y)| x & y).collect();
        assert_eq!(sys.read_virt(pid, c, len).unwrap(), want);
    }

    #[test]
    fn registry_sees_alloc_latency_hint_outcomes_and_export_replays() {
        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 6).unwrap();
        let len = 4 * row;
        let a = sys.alloc(&mut puma, pid, len).unwrap();
        let b = sys.alloc_align(&mut puma, pid, len, a).unwrap();
        let c = sys.alloc_align(&mut puma, pid, len, a).unwrap();
        sys.submit(pid, &BulkRequest::new(PudOp::And, c, vec![a, b], len))
            .unwrap();

        let snap = sys.metrics_snapshot();
        let alloc_hist = snap.hist("alloc/sim_ns").unwrap();
        assert_eq!(alloc_hist.count, 3, "three instrumented allocations");
        assert!(alloc_hist.sum > 0, "allocation burned simulated time");
        let st = puma.stats();
        assert_eq!(
            snap.counter("alloc/hint_colocated"),
            Some(st.hint_colocated)
        );
        assert_eq!(snap.counter("alloc/hint_missed"), Some(st.hint_missed));
        assert_eq!(
            snap.hist("coord/op_sim_ns").unwrap().count,
            sys.coord.stats.ops
        );

        // full-capture export replays byte-identically
        let stream = crate::obs::export::ddr_stream(sys.coord.obs.tracer.events());
        assert_eq!(sys.coord.obs.tracer.dropped, 0);
        crate::obs::export::verify_replay(&stream, &sys.coord.stats).unwrap();
    }

    #[test]
    fn malloc_flow_falls_back_but_stays_correct() {
        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut m = MallocSim::new();
        let len = 4 * row;
        let a = sys.alloc(&mut m, pid, len).unwrap();
        let b = sys.alloc(&mut m, pid, len).unwrap();
        let c = sys.alloc(&mut m, pid, len).unwrap();
        let va = vec![0xAAu8; len as usize];
        let vb = vec![0x0Fu8; len as usize];
        sys.write_virt(pid, a, &va).unwrap();
        sys.write_virt(pid, b, &vb).unwrap();
        let req = BulkRequest::new(PudOp::Or, c, vec![a, b], len);
        sys.submit(pid, &req).unwrap();
        assert!(
            sys.coord.stats.pud_row_fraction() < 0.01,
            "malloc placement should be ~0% PUD (got {})",
            sys.coord.stats.pud_row_fraction()
        );
        assert_eq!(
            sys.read_virt(pid, c, len).unwrap(),
            vec![0xAFu8; len as usize]
        );
    }

    #[test]
    fn queue_flush_equals_direct_batch() {
        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut m = MallocSim::new();
        let len = 2 * row;
        let a = sys.alloc(&mut m, pid, len).unwrap();
        let b = sys.alloc(&mut m, pid, len).unwrap();
        let c = sys.alloc(&mut m, pid, len).unwrap();
        sys.write_virt(pid, a, &vec![0x33u8; len as usize]).unwrap();
        sys.write_virt(pid, b, &vec![0x55u8; len as usize]).unwrap();
        assert_eq!(sys.flush(pid).unwrap().per_op_ns.len(), 0, "empty queue");
        sys.enqueue(pid, BulkRequest::new(PudOp::Or, c, vec![a, b], len));
        sys.enqueue(pid, BulkRequest::new(PudOp::Not, b, vec![a], len));
        assert_eq!(sys.queued_len(pid), 2);
        assert_eq!(sys.coord.stats.ops, 0, "enqueue does not execute");
        let report = sys.flush(pid).unwrap();
        assert_eq!(sys.queued_len(pid), 0);
        assert_eq!(report.per_op_ns.len(), 2);
        assert_eq!(sys.coord.stats.ops, 2);
        assert_eq!(
            sys.read_virt(pid, c, len).unwrap(),
            vec![0x33 | 0x55u8; len as usize]
        );
        assert_eq!(
            sys.read_virt(pid, b, len).unwrap(),
            vec![!0x33u8; len as usize]
        );
    }

    #[test]
    fn flush_of_a_fresh_pid_is_an_empty_noop() {
        let mut sys = small_system();
        // spawned but never allocated: nothing queued, nothing mapped
        let pid = sys.spawn();
        assert_eq!(sys.queued_len(pid), 0);
        let report = sys.flush(pid).unwrap();
        assert_eq!(report.per_op_ns.len(), 0);
        assert_eq!(report.elapsed_ns, 0.0);
        assert_eq!(sys.coord.stats.ops, 0, "nothing executed");
        // and flushing twice stays a no-op (the queue entry is gone)
        assert_eq!(sys.flush(pid).unwrap().waves, 0);
    }

    #[test]
    fn unified_column_api_matches_the_deprecated_pairs() {
        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 6).unwrap();
        let elems = (row * 8) as usize;
        let vals: Vec<u64> = (0..elems as u64).map(|i| i % 251).collect();
        let thr = 97u64;
        for spec in [LayoutSpec::Flat, LayoutSpec::Sharded(2)] {
            let mut pools = ShardedScratch::new();
            let col = sys
                .column(&mut puma, pid, 1, 0, 8, &vals, spec)
                .unwrap();
            let mask = match &col {
                Column::Flat(l) => Column::Flat(
                    VerticalLayout::alloc_with_hint(
                        &mut sys, &mut puma, pid, 1, elems, l.hint(),
                    )
                    .unwrap(),
                ),
                Column::Sharded(s) => Column::Sharded(
                    ShardedLayout::alloc_like(&mut sys, &mut puma, pid, 1, s)
                        .unwrap(),
                ),
            };
            sys.arith_const(
                &mut puma,
                pid,
                ArithOp::CmpLt,
                thr,
                &col,
                &mask,
                &mut pools,
            )
            .unwrap();
            let (sum, rep) = sys
                .column_sum(&mut puma, pid, &col, Some(&mask), &mut pools)
                .unwrap();
            assert!(rep.is_some(), "masked sum runs PUD work");
            let want: u128 = vals
                .iter()
                .filter(|&&v| v < thr)
                .map(|&v| v as u128)
                .sum();
            assert_eq!(sum, want, "{spec:?}");
            sys.trim_pools(&mut puma, pid, &mut pools, 0).unwrap();
            assert_eq!(pools.resident(), 0);
        }
    }

    #[test]
    fn compact_through_system_preserves_contents_and_restores_pud() {
        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 2).unwrap();
        // exhaust the pool, then force a scattered aligned allocation
        let a = sys.alloc(&mut puma, pid, row).unwrap();
        let want = puma.lookup(pid, a).unwrap().regions[0].sid;
        let mut fillers = Vec::new();
        while puma.free_regions() > 0 {
            fillers.push(sys.alloc(&mut puma, pid, row).unwrap());
        }
        let wrong = fillers
            .iter()
            .find(|va| puma.lookup(pid, **va).unwrap().regions[0].sid != want)
            .copied()
            .unwrap();
        sys.free(&mut puma, pid, wrong).unwrap();
        let b = sys.alloc_align(&mut puma, pid, row, a).unwrap();
        assert_ne!(puma.lookup(pid, b).unwrap().regions[0].sid, want);
        let data: Vec<u8> = (0..row).map(|i| (i % 199) as u8).collect();
        sys.write_virt(pid, b, &data).unwrap();
        // open a repair target in the preferred subarray, compact
        let target = fillers
            .iter()
            .find(|va| {
                **va != wrong
                    && puma
                        .lookup(pid, **va)
                        .map(|al| al.regions[0].sid == want)
                        .unwrap_or(false)
            })
            .copied()
            .unwrap();
        sys.free(&mut puma, pid, target).unwrap();
        let rep = sys.compact(&mut puma, pid).unwrap();
        assert_eq!(rep.repairs, 1);
        assert_eq!(
            sys.read_virt(pid, b, row).unwrap(),
            data,
            "contents survive migration, via the re-pointed VA"
        );
        // the repaired pair now runs fully in-DRAM
        sys.write_virt(pid, a, &data).unwrap();
        let fb_before = sys.coord.stats.fallback_rows;
        let pud_before = sys.coord.stats.pud_rows;
        sys.submit(pid, &BulkRequest::new(PudOp::And, b, vec![a, b], row))
            .unwrap();
        assert_eq!(
            sys.coord.stats.fallback_rows, fb_before,
            "repaired operands run in-DRAM"
        );
        assert!(sys.coord.stats.pud_rows > pud_before);
    }

    #[test]
    fn run_expr_matches_reference_and_runs_in_dram() {
        use crate::alloc::scratch::ScratchPool;
        use crate::pud::compiler::ExprBuilder;
        use crate::util::rng::Pcg64;

        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 6).unwrap();
        let len = 2 * row;
        let first = sys.alloc(&mut puma, pid, len).unwrap();
        let mut cols = vec![first];
        for _ in 1..5 {
            cols.push(sys.alloc_align(&mut puma, pid, len, first).unwrap());
        }
        let dst = sys.alloc_align(&mut puma, pid, len, first).unwrap();
        let mut rng = Pcg64::new(21);
        let mut data: Vec<Vec<u8>> = Vec::new();
        for &va in &cols {
            let mut v = vec![0u8; len as usize];
            rng.fill_bytes(&mut v);
            sys.write_virt(pid, va, &v).unwrap();
            data.push(v);
        }
        // (c0 & c1 & !c2) | (c3 ^ c4)
        let mut b = ExprBuilder::new();
        let l: Vec<_> = (0..5).map(|i| b.leaf(i)).collect();
        let n2 = b.not(l[2]);
        let conj = b.and(l[0], l[1]);
        let left = b.and(conj, n2);
        let x = b.xor(l[3], l[4]);
        let r = b.or(left, x);
        let expr = b.build(r);
        let mut pool = ScratchPool::new();
        let rep = sys
            .run_expr(&mut puma, pid, &expr, &cols, dst, len, &mut pool)
            .unwrap();
        assert_eq!(rep.stats.leaves, 5);
        assert!(rep.batch.waves >= 1);
        assert!(
            rep.pud_row_fraction() > 0.95,
            "PUMA-placed expression should run in-DRAM, got {}",
            rep.pud_row_fraction()
        );
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let want = expr.eval_bytes(&refs, len as usize).unwrap();
        assert_eq!(sys.read_virt(pid, dst, len).unwrap(), want);
        // a second run reuses the leased scratch — no new allocations
        let leases = pool.leases;
        let allocs = puma.stats().allocs;
        sys.run_expr(&mut puma, pid, &expr, &cols, dst, len, &mut pool)
            .unwrap();
        assert_eq!(pool.leases, leases);
        assert_eq!(puma.stats().allocs, allocs);
        assert_eq!(sys.read_virt(pid, dst, len).unwrap(), want);
    }

    #[test]
    fn run_expr_on_malloc_falls_back_but_matches() {
        use crate::alloc::scratch::ScratchPool;
        use crate::pud::compiler::ExprBuilder;

        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut m = MallocSim::new();
        // full rows: demand-paged 4 KiB frames never assemble into
        // row-aligned contiguous 8 KiB chunks, so every row falls back
        let len = 2 * row;
        let a = sys.alloc(&mut m, pid, len).unwrap();
        let b_va = sys.alloc(&mut m, pid, len).unwrap();
        let dst = sys.alloc(&mut m, pid, len).unwrap();
        sys.write_virt(pid, a, &vec![0xA5u8; len as usize]).unwrap();
        sys.write_virt(pid, b_va, &vec![0x0Fu8; len as usize]).unwrap();
        let mut bld = ExprBuilder::new();
        let l0 = bld.leaf(0);
        let l1 = bld.leaf(1);
        let d = bld.and_not(l0, l1);
        let expr = bld.build(d);
        let mut pool = ScratchPool::new();
        let rep = sys
            .run_expr(&mut m, pid, &expr, &[a, b_va], dst, len, &mut pool)
            .unwrap();
        assert!(
            rep.pud_row_fraction() < 0.01,
            "malloc placement stays on the fallback path"
        );
        assert_eq!(
            sys.read_virt(pid, dst, len).unwrap(),
            vec![0xA5u8 & !0x0F; len as usize]
        );
    }

    #[test]
    fn run_arith_add_matches_reference_in_dram() {
        use crate::alloc::scratch::ScratchPool;
        use crate::pud::arith::{self, ArithOp, VerticalLayout};
        use crate::util::rng::Pcg64;

        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 4).unwrap();
        let width = 8u32;
        let elems = (row * 8) as usize; // one full row per plane
        let a = VerticalLayout::alloc(&mut sys, &mut puma, pid, width, elems)
            .unwrap();
        let b = VerticalLayout::alloc_with_hint(
            &mut sys, &mut puma, pid, width, elems, a.hint(),
        )
        .unwrap();
        let dst = VerticalLayout::alloc_with_hint(
            &mut sys, &mut puma, pid, width, elems, a.hint(),
        )
        .unwrap();
        let mut rng = Pcg64::new(0xADD);
        let m = arith::width_mask(width);
        let va: Vec<u64> = (0..elems).map(|_| rng.next_u64() & m).collect();
        let vb: Vec<u64> = (0..elems).map(|_| rng.next_u64() & m).collect();
        a.store(&mut sys, pid, &va).unwrap();
        b.store(&mut sys, pid, &vb).unwrap();
        let mut pool = ScratchPool::new();
        let rep = sys
            .run_arith(&mut puma, pid, ArithOp::Add, &a, Some(&b), &dst, &mut pool)
            .unwrap();
        assert!(
            rep.pud_row_fraction() > 0.99,
            "co-located planes must run in-DRAM, got {}",
            rep.pud_row_fraction()
        );
        assert!(rep.batch.waves >= 1);
        let got = dst.load(&mut sys, pid).unwrap();
        for i in 0..elems {
            assert_eq!(
                got[i],
                arith::reference(ArithOp::Add, width, va[i], vb[i]),
                "element {i}"
            );
        }
        // masked sum: mask = (a < b), sum of a where a < b
        let mask = VerticalLayout::alloc_with_hint(
            &mut sys, &mut puma, pid, 1, elems, a.hint(),
        )
        .unwrap();
        sys.run_arith(&mut puma, pid, ArithOp::CmpLt, &a, Some(&b), &mask, &mut pool)
            .unwrap();
        let (sum, rep2) = sys
            .arith_sum(&mut puma, pid, &a, Some(mask.planes()[0]), &mut pool)
            .unwrap();
        let want: u128 = va
            .iter()
            .zip(&vb)
            .filter(|(x, y)| x < y)
            .map(|(x, _)| *x as u128)
            .sum();
        assert_eq!(sum, want);
        let rep2 = rep2.expect("masked sum runs a batch");
        assert!(rep2.pud_row_fraction() > 0.99);
        // unmasked sum reads the planes directly
        let (total, none_rep) =
            sys.arith_sum(&mut puma, pid, &a, None, &mut pool).unwrap();
        assert_eq!(total, va.iter().map(|x| *x as u128).sum::<u128>());
        assert!(none_rep.is_none());
        // the wide lease trims back down
        assert!(pool.len() >= width as usize);
        sys.trim_scratch(&mut puma, pid, &mut pool, 4).unwrap();
        assert_eq!(pool.len(), 4);
    }

    #[test]
    fn run_arith_validates_shapes() {
        use crate::alloc::scratch::ScratchPool;
        use crate::pud::arith::{ArithOp, VerticalLayout};

        let mut sys = small_system();
        let pid = sys.spawn();
        let mut m = MallocSim::new();
        let a = VerticalLayout::alloc(&mut sys, &mut m, pid, 4, 64).unwrap();
        let b = VerticalLayout::alloc(&mut sys, &mut m, pid, 4, 64).unwrap();
        let narrow = VerticalLayout::alloc(&mut sys, &mut m, pid, 2, 64).unwrap();
        let mut pool = ScratchPool::new();
        assert!(
            sys.run_arith(&mut m, pid, ArithOp::Add, &a, None, &b, &mut pool)
                .is_err(),
            "binary op without b"
        );
        assert!(
            sys.run_arith(
                &mut m, pid, ArithOp::Popcount, &a, Some(&b), &narrow, &mut pool
            )
            .is_err(),
            "unary op with b"
        );
        assert!(
            sys.run_arith(&mut m, pid, ArithOp::Add, &a, Some(&b), &narrow, &mut pool)
                .is_err(),
            "dst width mismatch"
        );
        // popcount(4) needs 3 planes
        let pc = VerticalLayout::alloc(&mut sys, &mut m, pid, 3, 64).unwrap();
        assert!(sys
            .run_arith(&mut m, pid, ArithOp::Popcount, &a, None, &pc, &mut pool)
            .is_ok());
    }

    #[test]
    fn program_cache_makes_repeat_kernels_compile_free() {
        use crate::alloc::scratch::ScratchPool;
        use crate::pud::arith::{ArithOp, VerticalLayout};

        let mut sys = small_system();
        let pid = sys.spawn();
        let mut m = MallocSim::new();
        let a = VerticalLayout::alloc(&mut sys, &mut m, pid, 4, 64).unwrap();
        let b = VerticalLayout::alloc(&mut sys, &mut m, pid, 4, 64).unwrap();
        let dst = VerticalLayout::alloc(&mut sys, &mut m, pid, 4, 64).unwrap();
        let vals: Vec<u64> = (0..64).map(|i| (i as u64) % 16).collect();
        a.store(&mut sys, pid, &vals).unwrap();
        b.store(&mut sys, pid, &vals).unwrap();
        let mut pool = ScratchPool::new();
        let rep1 = sys
            .run_arith(&mut m, pid, ArithOp::Add, &a, Some(&b), &dst, &mut pool)
            .unwrap();
        assert_eq!(rep1.stats.compiles, 1, "first call compiles");
        let s1 = sys.program_cache_stats();
        assert_eq!((s1.misses, s1.hits), (1, 0));
        let rep2 = sys
            .run_arith(&mut m, pid, ArithOp::Add, &a, Some(&b), &dst, &mut pool)
            .unwrap();
        assert_eq!(rep2.stats.compiles, 0, "second call does zero compile work");
        let s2 = sys.program_cache_stats();
        assert_eq!((s2.misses, s2.hits), (1, 1));
        assert_eq!(dst.load(&mut sys, pid).unwrap()[3], (3 + 3) % 16);
        // the masked-sum plane program is cached too
        let mask = VerticalLayout::alloc(&mut sys, &mut m, pid, 1, 64).unwrap();
        sys.run_arith(&mut m, pid, ArithOp::CmpLt, &a, Some(&b), &mask, &mut pool)
            .unwrap();
        let (sum1, _) = sys
            .arith_sum(&mut m, pid, &a, Some(mask.planes()[0]), &mut pool)
            .unwrap();
        let misses = sys.program_cache_stats().misses; // Add, CmpLt, MaskPlanes
        assert_eq!(misses, 3);
        let (sum2, rep) = sys
            .arith_sum(&mut m, pid, &a, Some(mask.planes()[0]), &mut pool)
            .unwrap();
        assert_eq!(sum1, sum2);
        assert_eq!(rep.unwrap().stats.compiles, 0);
        assert_eq!(sys.program_cache_stats().misses, misses);
    }

    #[test]
    fn sharded_arith_matches_unsharded_and_overlaps_banks() {
        use crate::alloc::scratch::ScratchPool;
        use crate::pud::arith::{
            self, ArithOp, ShardedLayout, ShardedScratch, VerticalLayout,
        };
        use crate::util::rng::Pcg64;

        let mut sys = small_system();
        let pid = sys.spawn();
        let row = sys.os.scheme.geometry.row_bytes as u64;
        let spb = sys.os.scheme.geometry.subarrays_per_bank;
        let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 6).unwrap();
        let width = 4u32;
        let elems = (row * 8 * 4) as usize; // 4 rows per unsharded plane
        let wmask = arith::width_mask(width);
        let mut rng = Pcg64::new(0x5AAD);
        let values: Vec<u64> = (0..elems).map(|_| rng.next_u64() & wmask).collect();
        let thr = 8u64;

        // unsharded reference result
        let col =
            VerticalLayout::alloc(&mut sys, &mut puma, pid, width, elems).unwrap();
        col.store(&mut sys, pid, &values).unwrap();
        let mask = VerticalLayout::alloc_with_hint(
            &mut sys, &mut puma, pid, 1, elems, col.hint(),
        )
        .unwrap();
        let mut pool = ScratchPool::new();
        sys.run_arith_const(&mut puma, pid, ArithOp::CmpLt, thr, &col, &mask, &mut pool)
            .unwrap();
        let (want_sum, _) = sys
            .arith_sum(&mut puma, pid, &col, Some(mask.planes()[0]), &mut pool)
            .unwrap();
        sys.trim_scratch(&mut puma, pid, &mut pool, 0).unwrap();
        mask.free(&mut sys, &mut puma, pid).unwrap();
        col.free(&mut sys, &mut puma, pid).unwrap();

        let mut elapsed = Vec::new();
        for shards in [1usize, 2] {
            let col = ShardedLayout::alloc(
                &mut sys, &mut puma, pid, width, elems, shards,
            )
            .unwrap();
            col.store(&mut sys, pid, &values).unwrap();
            // shard anchors land on disjoint banks
            let mut banks: Vec<u32> = col
                .shards()
                .iter()
                .map(|p| {
                    puma.lookup(pid, p.hint()).unwrap().regions[0].sid.0 / spb
                })
                .collect();
            banks.sort_unstable();
            banks.dedup();
            assert_eq!(banks.len(), shards, "S={shards}: banks disjoint");
            let mask =
                ShardedLayout::alloc_like(&mut sys, &mut puma, pid, 1, &col)
                    .unwrap();
            let mut pools = ShardedScratch::new();
            let rep = sys
                .run_arith_const_sharded(
                    &mut puma, pid, ArithOp::CmpLt, thr, &col, &mask, &mut pools,
                )
                .unwrap();
            assert!(
                rep.pud_row_fraction() > 0.99,
                "S={shards}: spread shards stay in-DRAM, got {}",
                rep.pud_row_fraction()
            );
            // the sharded mask is bit-identical to the scalar predicate
            let got = mask.load(&mut sys, pid).unwrap();
            for (i, (&g, &v)) in got.iter().zip(&values).enumerate() {
                assert_eq!(g == 1, v < thr, "mask bit {i} (S={shards})");
            }
            let (sum, srep) = sys
                .arith_sum_sharded(&mut puma, pid, &col, Some(&mask), &mut pools)
                .unwrap();
            assert_eq!(sum, want_sum, "S={shards}: sum identical to unsharded");
            let srep = srep.expect("masked sum batches");
            assert!(srep.pud_row_fraction() > 0.99);
            elapsed.push(rep.batch.elapsed_ns + srep.batch.elapsed_ns);
            sys.trim_scratch_sharded(&mut puma, pid, &mut pools, 0).unwrap();
            mask.free(&mut sys, &mut puma, pid).unwrap();
            col.free(&mut sys, &mut puma, pid).unwrap();
        }
        assert!(
            elapsed[1] < elapsed[0],
            "bank-sharded batch must finish sooner: S=2 {} vs S=1 {}",
            elapsed[1],
            elapsed[0]
        );
    }

    #[test]
    fn sharded_arith_validates_shapes() {
        use crate::pud::arith::{ArithOp, ShardedLayout, ShardedScratch};

        let mut sys = small_system();
        let pid = sys.spawn();
        let mut m = MallocSim::new();
        let a = ShardedLayout::alloc(&mut sys, &mut m, pid, 4, 100, 3).unwrap();
        assert_eq!(a.n_shards(), 3);
        let b = ShardedLayout::alloc_like(&mut sys, &mut m, pid, 4, &a).unwrap();
        let other = ShardedLayout::alloc(&mut sys, &mut m, pid, 4, 100, 2).unwrap();
        let narrow = ShardedLayout::alloc_like(&mut sys, &mut m, pid, 2, &a).unwrap();
        let mut pools = ShardedScratch::new();
        assert!(
            sys.run_arith_sharded(&mut m, pid, ArithOp::Add, &a, None, &b, &mut pools)
                .is_err(),
            "binary op without b"
        );
        assert!(
            sys.run_arith_sharded(
                &mut m, pid, ArithOp::Add, &a, Some(&other), &b, &mut pools
            )
            .is_err(),
            "shard-count mismatch"
        );
        assert!(
            sys.run_arith_sharded(
                &mut m, pid, ArithOp::Add, &a, Some(&b), &narrow, &mut pools
            )
            .is_err(),
            "dst width mismatch"
        );
        assert!(
            sys.arith_sum_sharded(&mut m, pid, &a, Some(&narrow), &mut pools)
                .is_err(),
            "mask must be 1-bit"
        );
        assert!(sys
            .run_arith_sharded(&mut m, pid, ArithOp::Add, &a, Some(&b), &b, &mut pools)
            .is_err(),
            "dst aliasing an operand is rejected by emit");
    }

    #[test]
    fn multiple_processes_isolated() {
        let mut sys = small_system();
        let p1 = sys.spawn();
        let p2 = sys.spawn();
        let mut m1 = MallocSim::new();
        let mut m2 = MallocSim::new();
        let a1 = sys.alloc(&mut m1, p1, 4096).unwrap();
        let a2 = sys.alloc(&mut m2, p2, 4096).unwrap();
        sys.write_virt(p1, a1, &[1u8; 4096]).unwrap();
        sys.write_virt(p2, a2, &[2u8; 4096]).unwrap();
        assert_eq!(sys.read_virt(p1, a1, 4096).unwrap(), [1u8; 4096]);
        assert_eq!(sys.read_virt(p2, a2, 4096).unwrap(), [2u8; 4096]);
    }
}
