//! Execution layer: consumes a [`Schedule`] and drives the two
//! substrates.
//!
//! Per-op work (PUD rows, DRAM-side accounting, scalar fallback when
//! no runtime is loaded) still flows through [`PudEngine::execute`]
//! one op at a time, in submission order — that is what keeps batched
//! stats and memory images byte-identical to serial submission. What
//! changes under batching is the *fallback dispatch* shape: instead of
//! one XLA call per fallback run, the executor issues one call per
//! coalesced [`DispatchGroup`], gathering every segment's operand
//! bytes into reusable scratch buffers (no per-dispatch allocation on
//! the hot path) and scattering the single result back segment by
//! segment.

use anyhow::{bail, Result};

use crate::pud::exec::{ExecStats, PudEngine};
use crate::pud::legality::RowPlan;
use crate::runtime::{XlaRuntime, ROW_BYTES};

use super::dispatch::FallbackMode;
use super::plan::OpPlan;
use super::schedule::{DispatchGroup, Schedule};
use super::stats::{CoordStats, PipelineStats};

/// The executor: owns the reusable gather/scatter scratch.
#[derive(Default)]
pub struct Executor {
    /// Per-operand packed buffers, grown on demand and reused across
    /// dispatches (§Perf: the old per-run `vec![vec![0; padded]]`
    /// allocation was the fallback path's biggest heap churn).
    bufs: Vec<Vec<u8>>,
}

impl Executor {
    /// Run `schedule` over `plans`. Returns per-op [`ExecStats`], in
    /// batch order (the dispatcher derives per-op simulated ns and
    /// feeds the tracer's op slots from them).
    pub fn run(
        &mut self,
        engine: &mut PudEngine,
        fallback: &mut FallbackMode,
        plans: &[OpPlan],
        schedule: &Schedule,
        stats: &mut CoordStats,
        pipeline: &mut PipelineStats,
    ) -> Result<Vec<ExecStats>> {
        let scalar = matches!(fallback, FallbackMode::Scalar);
        let mut per_op = vec![ExecStats::default(); plans.len()];
        for wave in &schedule.waves {
            // per-op functional execution + accounting, in submission
            // order (identical to N serial submits)
            for &i in &wave.op_indices {
                let plan = &plans[i];
                let exec = engine.execute(plan.op, &plan.rows, scalar)?;
                stats.ops += 1;
                stats
                    .ops_fully_pud
                    .record(exec.fallback_rows == 0 && exec.pud_rows > 0);
                stats.absorb_exec(&exec);
                per_op[i] = exec;
            }
            // coalesced fallback dispatches. Counted in both modes so
            // coalescing is measurable without compiled artifacts; in
            // XLA mode each group is exactly one `run_op` call.
            pipeline.fallback_dispatches += wave.groups.len() as u64;
            pipeline.coalesced_fallback_rows += wave
                .groups
                .iter()
                .map(|g| g.rows() as u64)
                .sum::<u64>();
            if let FallbackMode::Xla(rt) = fallback {
                for group in &wave.groups {
                    run_group(&mut self.bufs, engine, rt, plans, group, stats)?;
                }
            }
        }
        Ok(per_op)
    }
}

/// Execute one coalesced dispatch group through the XLA runtime:
/// gather every segment's operand bytes (packed back-to-back, padded
/// to whole kernel rows), run the kernel once, scatter the result.
fn run_group(
    bufs: &mut Vec<Vec<u8>>,
    engine: &mut PudEngine,
    rt: &mut XlaRuntime,
    plans: &[OpPlan],
    group: &DispatchGroup,
    stats: &mut CoordStats,
) -> Result<()> {
    let rows_kernel = group.bytes.div_ceil(ROW_BYTES as u64) as u32;
    let padded = rows_kernel as usize * ROW_BYTES;
    let arity = group.op.arity();
    while bufs.len() < arity {
        bufs.push(Vec::new());
    }
    for b in &mut bufs[..arity] {
        b.clear();
        b.resize(padded, 0);
    }
    // gather
    let mut off = 0usize;
    for seg in &group.segments {
        let rows = &plans[seg.op_idx].rows;
        for entry in &rows[seg.first_row_idx..seg.first_row_idx + seg.rows] {
            let RowPlan::Fallback { srcs, bytes, .. } = entry else {
                bail!("dispatch group covers a non-fallback row");
            };
            let b = *bytes as usize;
            for (k, ext) in srcs.iter().enumerate() {
                engine.gather_into(ext, &mut bufs[k][off..off + b]);
            }
            off += b;
        }
    }
    debug_assert_eq!(off as u64, group.bytes);
    // execute
    let refs: Vec<&[u8]> = bufs[..arity].iter().map(|v| v.as_slice()).collect();
    let t0 = std::time::Instant::now();
    let out = rt.run_op(group.op.kernel_name(), rows_kernel, &refs)?;
    stats.xla_wall_ns += t0.elapsed().as_nanos() as u64;
    stats.xla_dispatches += 1;
    // scatter
    let mut off = 0usize;
    for seg in &group.segments {
        let rows = &plans[seg.op_idx].rows;
        for entry in &rows[seg.first_row_idx..seg.first_row_idx + seg.rows] {
            let RowPlan::Fallback { dst, bytes, .. } = entry else {
                unreachable!("validated during gather");
            };
            let b = *bytes as usize;
            engine.scatter(dst, &out[off..off + b]);
            off += b;
        }
    }
    Ok(())
}
