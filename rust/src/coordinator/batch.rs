//! Fallback-row batching (per-op runs).
//!
//! The legality plan marks individual rows as fallback; issuing one
//! XLA dispatch per 8 KiB row would drown in dispatch overhead. The
//! batcher groups *consecutive* fallback rows of one operation into
//! runs. (Grouping only consecutive rows keeps gather/scatter on the
//! DRAM side trivial: each run is one virtually-contiguous span per
//! operand.) Runs are the unit the scheduler then coalesces *across*
//! operations into [`super::schedule::DispatchGroup`]s, which the
//! runtime covers with its largest shape buckets.

use crate::pud::legality::RowPlan;

/// A run of consecutive fallback rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FallbackRun {
    /// Index of the first plan entry in the run.
    pub first_row_idx: usize,
    /// Number of rows in the run.
    pub rows: usize,
    /// Total bytes (sum of per-row bytes; the final row may be short).
    pub bytes: u64,
}

/// Group the fallback entries of `plan` into maximal consecutive runs.
pub fn fallback_runs(plan: &[RowPlan]) -> Vec<FallbackRun> {
    let mut runs = Vec::new();
    let mut cur: Option<FallbackRun> = None;
    for (i, entry) in plan.iter().enumerate() {
        match entry {
            RowPlan::Fallback { bytes, .. } => {
                match &mut cur {
                    Some(run) if run.first_row_idx + run.rows == i => {
                        run.rows += 1;
                        run.bytes += *bytes as u64;
                    }
                    _ => {
                        if let Some(run) = cur.take() {
                            runs.push(run);
                        }
                        cur = Some(FallbackRun {
                            first_row_idx: i,
                            rows: 1,
                            bytes: *bytes as u64,
                        });
                    }
                }
            }
            RowPlan::Pud { .. } => {
                if let Some(run) = cur.take() {
                    runs.push(run);
                }
            }
        }
    }
    if let Some(run) = cur.take() {
        runs.push(run);
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pud() -> RowPlan {
        RowPlan::Pud {
            sid: crate::dram::geometry::SubarrayId(0),
            dst: crate::dram::geometry::Loc {
                channel: 0,
                rank: 0,
                bank: 0,
                subarray: 0,
                row: 0,
                column: 0,
            },
            srcs: vec![],
            bytes: 8192,
        }
    }

    fn fb(bytes: u32) -> RowPlan {
        RowPlan::Fallback {
            dst: vec![crate::os::process::PhysExtent {
                paddr: 0,
                len: bytes as u64,
            }],
            srcs: vec![],
            bytes,
            cause: crate::pud::legality::FallbackCause::Misaligned,
        }
    }

    #[test]
    fn empty_plan_no_runs() {
        assert!(fallback_runs(&[]).is_empty());
        assert!(fallback_runs(&[pud(), pud()]).is_empty());
    }

    #[test]
    fn single_run_of_all_fallback() {
        let plan = vec![fb(8192), fb(8192), fb(100)];
        let runs = fallback_runs(&plan);
        assert_eq!(
            runs,
            vec![FallbackRun {
                first_row_idx: 0,
                rows: 3,
                bytes: 8192 * 2 + 100
            }]
        );
    }

    #[test]
    fn pud_rows_split_runs() {
        let plan = vec![fb(1), pud(), fb(2), fb(3), pud(), pud(), fb(4)];
        let runs = fallback_runs(&plan);
        assert_eq!(runs.len(), 3);
        assert_eq!(runs[0], FallbackRun { first_row_idx: 0, rows: 1, bytes: 1 });
        assert_eq!(runs[1], FallbackRun { first_row_idx: 2, rows: 2, bytes: 5 });
        assert_eq!(runs[2], FallbackRun { first_row_idx: 6, rows: 1, bytes: 4 });
    }
}
