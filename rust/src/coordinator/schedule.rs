//! Scheduling layer: turns a batch of [`OpPlan`]s into an executable
//! [`Schedule`].
//!
//! Two jobs happen here (DESIGN.md §4):
//!
//! 1. **Hazard-aware waves.** Ops are split, in submission order, into
//!    waves of pairwise-independent operations (no physical overlap
//!    between any op's destination and another's operands). Within a
//!    wave execution order is immaterial, so fallback work can be
//!    coalesced across ops and PUD rows can overlap across banks;
//!    waves themselves serialize, which is exactly what preserves
//!    serial semantics for dependent chains.
//! 2. **Cross-op fallback coalescing.** The per-op [`fallback_runs`]
//!    of every op in a wave are regrouped by op kind into
//!    [`DispatchGroup`]s — one CPU/XLA dispatch each — instead of one
//!    dispatch per run. Self-aliased ops (dst overlapping own srcs)
//!    keep their serial per-run dispatch order.
//!
//! The scheduler also prices the batch: PUD rows land on per-bank
//! command timelines (banks run concurrently — the bank-level
//! parallelism MIMDRAM exploits), fallback rows on the serial CPU
//! timeline. The resulting makespan is reported as the batch's
//! *elapsed* simulated time alongside the serial-equivalent per-op
//! sums, which stay byte-for-byte compatible with one-at-a-time
//! submission.

use rustc_hash::FxHashMap;

use crate::dram::address::InterleaveScheme;
use crate::dram::timing::TimingParams;
use crate::obs::trace::BankLane;
use crate::pud::isa::PudOp;

use super::batch::fallback_runs;
use super::plan::OpPlan;

/// A contiguous span of fallback rows of one op, as placed inside a
/// dispatch group's packed operand buffers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    /// Index of the op in the batch.
    pub op_idx: usize,
    /// First row index of the span within the op's plan.
    pub first_row_idx: usize,
    /// Rows in the span.
    pub rows: usize,
    /// Payload bytes of the span.
    pub bytes: u64,
}

/// One fallback dispatch: segments (possibly from several ops of the
/// same kind) packed back-to-back into a single kernel invocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DispatchGroup {
    pub op: PudOp,
    pub segments: Vec<Segment>,
    /// Total payload bytes across segments.
    pub bytes: u64,
}

impl DispatchGroup {
    pub fn rows(&self) -> usize {
        self.segments.iter().map(|s| s.rows).sum()
    }
}

/// A wave of pairwise-independent ops plus its coalesced fallback
/// dispatches and simulated timing.
#[derive(Debug, Clone)]
pub struct Wave {
    /// Batch indices of the ops in this wave (submission order).
    pub op_indices: Vec<usize>,
    /// Coalesced fallback dispatches for the wave.
    pub groups: Vec<DispatchGroup>,
    /// Bank-parallel makespan of the wave's PUD rows (incl. per-op
    /// dispatch overheads).
    pub pud_ns: f64,
    /// Serial CPU time of the wave's fallback rows (incl. per-op
    /// dispatch overheads).
    pub fallback_ns: f64,
    /// Per-bank PUD load of the wave (sorted by dense bank id) — the
    /// same timelines `pud_ns` is the max of, kept for the tracer's
    /// Perfetto lanes and utilization-spread metrics.
    pub lanes: Vec<BankLane>,
}

impl Wave {
    pub fn elapsed_ns(&self) -> f64 {
        self.pud_ns + self.fallback_ns
    }
}

/// The full schedule for one batch.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    pub waves: Vec<Wave>,
}

impl Schedule {
    /// Simulated completion time of the batch: waves serialize, banks
    /// within a wave run concurrently.
    pub fn elapsed_ns(&self) -> f64 {
        self.waves.iter().map(Wave::elapsed_ns).sum()
    }

    /// Total fallback dispatches the executor will issue.
    pub fn dispatch_groups(&self) -> u64 {
        self.waves.iter().map(|w| w.groups.len() as u64).sum()
    }

    /// Per-wave bank-parallel times, in wave order (sums to
    /// [`Schedule::elapsed_ns`]).
    pub fn wave_elapsed(&self) -> Vec<f64> {
        self.waves.iter().map(Wave::elapsed_ns).collect()
    }

    /// Wave index of each of the batch's `n_ops` ops (every op is in
    /// exactly one wave).
    pub fn op_waves(&self, n_ops: usize) -> Vec<usize> {
        let mut out = vec![0usize; n_ops];
        for (w, wave) in self.waves.iter().enumerate() {
            for &i in &wave.op_indices {
                out[i] = w;
            }
        }
        out
    }
}

/// Build the schedule for `plans` (in submission order).
pub fn build(
    scheme: &InterleaveScheme,
    timing: &TimingParams,
    plans: &[OpPlan],
) -> Schedule {
    let mut schedule = Schedule::default();
    let mut wave_start = 0usize;
    while wave_start < plans.len() {
        let mut end = wave_start + 1;
        while end < plans.len() {
            let candidate = &plans[end];
            if plans[wave_start..end]
                .iter()
                .any(|p| p.conflicts_with(candidate))
            {
                break;
            }
            end += 1;
        }
        schedule
            .waves
            .push(build_wave(scheme, timing, plans, wave_start..end));
        wave_start = end;
    }
    schedule
}

fn build_wave(
    scheme: &InterleaveScheme,
    timing: &TimingParams,
    plans: &[OpPlan],
    range: std::ops::Range<usize>,
) -> Wave {
    let geometry = &scheme.geometry;
    let mut groups: Vec<DispatchGroup> = Vec::new();
    // op kind -> open coalescing group index
    let mut open: FxHashMap<PudOp, usize> = FxHashMap::default();
    let mut bank_busy: FxHashMap<u32, (f64, u64)> = FxHashMap::default();
    let mut pud_overhead = 0.0f64;
    let mut fallback_ns = 0.0f64;

    for op_idx in range.clone() {
        let plan = &plans[op_idx];
        // --- timing: PUD rows onto their banks, fallback rows onto
        // the serial CPU timeline (mirrors PudEngine's per-op sums)
        let row_cost = plan.op.pud_row_ns(timing);
        let mut has_pud = false;
        let mut has_fallback = false;
        for row in &plan.rows {
            if let Some(loc) = row.pud_dst() {
                let lane = bank_busy.entry(geometry.bank_id(loc)).or_insert((0.0, 0));
                lane.0 += row_cost;
                lane.1 += 1;
                has_pud = true;
            } else {
                let arity = row.fallback_arity().unwrap_or(0);
                fallback_ns += timing.fallback_row_ns(row.bytes() as u64, arity);
                has_fallback = true;
            }
        }
        if has_pud {
            pud_overhead += timing.pud_dispatch_overhead;
        }
        if has_fallback {
            fallback_ns += timing.cpu_dispatch_overhead;
        }

        // --- fallback coalescing
        let runs = fallback_runs(&plan.rows);
        if runs.is_empty() {
            continue;
        }
        if plan.self_aliased() {
            // keep the serial per-run dispatch order for memmove-style
            // ops: coalescing would reorder their gathers/scatters
            for run in runs {
                groups.push(DispatchGroup {
                    op: plan.op,
                    segments: vec![Segment {
                        op_idx,
                        first_row_idx: run.first_row_idx,
                        rows: run.rows,
                        bytes: run.bytes,
                    }],
                    bytes: run.bytes,
                });
            }
            continue;
        }
        let gidx = *open.entry(plan.op).or_insert_with(|| {
            groups.push(DispatchGroup {
                op: plan.op,
                segments: Vec::new(),
                bytes: 0,
            });
            groups.len() - 1
        });
        for run in runs {
            groups[gidx].segments.push(Segment {
                op_idx,
                first_row_idx: run.first_row_idx,
                rows: run.rows,
                bytes: run.bytes,
            });
            groups[gidx].bytes += run.bytes;
        }
    }

    let mut lanes: Vec<BankLane> = bank_busy
        .into_iter()
        .map(|(bank, (busy_ns, rows))| BankLane { bank, rows, busy_ns })
        .collect();
    lanes.sort_by_key(|l| l.bank);

    Wave {
        op_indices: range.collect(),
        groups,
        pud_ns: timing.bank_parallel_ns(lanes.iter().map(|l| l.busy_ns)) + pud_overhead,
        fallback_ns,
        lanes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::Loc;
    use crate::os::process::PhysExtent;
    use crate::pud::legality::RowPlan;

    fn scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(crate::dram::geometry::DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 4,
            subarrays_per_bank: 2,
            rows_per_subarray: 32,
            row_bytes: 8192,
        })
    }

    fn pud_row(bank: u32, bytes: u32) -> RowPlan {
        let loc = Loc {
            channel: 0,
            rank: 0,
            bank,
            subarray: 0,
            row: 1,
            column: 0,
        };
        RowPlan::Pud {
            sid: crate::dram::geometry::SubarrayId(bank * 2),
            dst: loc,
            srcs: vec![loc],
            bytes,
        }
    }

    fn fb_row(paddr: u64, bytes: u32) -> RowPlan {
        RowPlan::Fallback {
            dst: vec![PhysExtent {
                paddr,
                len: bytes as u64,
            }],
            srcs: vec![vec![PhysExtent {
                paddr: paddr + (1 << 20),
                len: bytes as u64,
            }]],
            bytes,
            cause: crate::pud::legality::FallbackCause::Misaligned,
        }
    }

    fn plan_of(op: PudOp, rows: Vec<RowPlan>, dst: (u64, u64), src: (u64, u64)) -> OpPlan {
        let len = rows.iter().map(|r| r.bytes() as u64).sum();
        OpPlan {
            op,
            len,
            rows,
            dst_ranges: vec![dst],
            src_ranges: vec![src],
        }
    }

    #[test]
    fn independent_ops_share_a_wave_and_a_group() {
        let s = scheme();
        let t = TimingParams::default();
        let p1 = plan_of(
            PudOp::Copy,
            vec![fb_row(0x1000, 8192)],
            (0x1000, 0x3000),
            (0x101000, 0x103000),
        );
        let p2 = plan_of(
            PudOp::Copy,
            vec![fb_row(0x200000, 8192)],
            (0x200000, 0x202000),
            (0x301000, 0x303000),
        );
        let sched = build(&s, &t, &[p1, p2]);
        assert_eq!(sched.waves.len(), 1);
        assert_eq!(sched.waves[0].groups.len(), 1, "same-kind runs coalesce");
        assert_eq!(sched.waves[0].groups[0].rows(), 2);
        assert_eq!(sched.dispatch_groups(), 1);
    }

    #[test]
    fn dependent_ops_split_waves() {
        let s = scheme();
        let t = TimingParams::default();
        // p2 reads what p1 writes
        let p1 = plan_of(
            PudOp::Copy,
            vec![fb_row(0x1000, 8192)],
            (0x1000, 0x3000),
            (0x101000, 0x103000),
        );
        let p2 = plan_of(
            PudOp::Copy,
            vec![fb_row(0x400000, 8192)],
            (0x400000, 0x402000),
            (0x1000, 0x3000),
        );
        let sched = build(&s, &t, &[p1, p2]);
        assert_eq!(sched.waves.len(), 2);
        assert_eq!(sched.dispatch_groups(), 2);
    }

    #[test]
    fn different_kinds_get_separate_groups() {
        let s = scheme();
        let t = TimingParams::default();
        let p1 = plan_of(
            PudOp::Copy,
            vec![fb_row(0x1000, 8192)],
            (0x1000, 0x3000),
            (0x101000, 0x103000),
        );
        let p2 = plan_of(
            PudOp::Xor,
            vec![fb_row(0x200000, 8192)],
            (0x200000, 0x202000),
            (0x301000, 0x303000),
        );
        let sched = build(&s, &t, &[p1, p2]);
        assert_eq!(sched.waves.len(), 1);
        assert_eq!(sched.waves[0].groups.len(), 2);
    }

    #[test]
    fn self_aliased_ops_are_not_coalesced() {
        let s = scheme();
        let t = TimingParams::default();
        let aliased = plan_of(
            PudOp::Copy,
            vec![fb_row(0x1000, 8192), pud_row(0, 8192), fb_row(0x9000, 8192)],
            (0x1000, 0x3000),
            (0x2000, 0x4000), // overlaps dst
        );
        let other = plan_of(
            PudOp::Copy,
            vec![fb_row(0x800000, 8192)],
            (0x800000, 0x802000),
            (0x901000, 0x903000),
        );
        let sched = build(&s, &t, &[aliased, other]);
        assert_eq!(sched.waves.len(), 1);
        // aliased op: one group per run (2 runs); other op: its own
        // group (opened separately since the aliased op never opens a
        // shared one)
        assert_eq!(sched.waves[0].groups.len(), 3);
    }

    #[test]
    fn bank_parallel_rows_overlap_in_time() {
        let s = scheme();
        let t = TimingParams::default();
        // 4 PUD copy rows on 4 distinct banks, one op
        let rows: Vec<RowPlan> = (0..4).map(|b| pud_row(b, 8192)).collect();
        let p = plan_of(PudOp::Copy, rows, (0x1000, 0x3000), (0x101000, 0x103000));
        let serial_sum = 4.0 * t.rowclone_fpm_ns(1) + t.pud_dispatch_overhead;
        let sched = build(&s, &t, &[p]);
        let elapsed = sched.elapsed_ns();
        assert!(
            elapsed < serial_sum,
            "banks should overlap: {elapsed} vs serial {serial_sum}"
        );
        assert!(
            (elapsed - (t.rowclone_fpm_ns(1) + t.pud_dispatch_overhead)).abs() < 1e-9
        );
    }

    #[test]
    fn waves_carry_sorted_bank_lanes() {
        let s = scheme();
        let t = TimingParams::default();
        // 2 rows on bank 3, 1 row on bank 0, 1 fallback row
        let rows = vec![
            pud_row(3, 8192),
            pud_row(0, 8192),
            pud_row(3, 8192),
            fb_row(0x9000, 8192),
        ];
        let p = plan_of(PudOp::Copy, rows, (0x1000, 0x3000), (0x101000, 0x103000));
        let sched = build(&s, &t, &[p]);
        let lanes = &sched.waves[0].lanes;
        assert_eq!(lanes.len(), 2, "fallback rows get no bank lane");
        assert_eq!((lanes[0].bank, lanes[0].rows), (0, 1));
        assert_eq!((lanes[1].bank, lanes[1].rows), (3, 2));
        assert!((lanes[1].busy_ns - 2.0 * t.rowclone_fpm_ns(1)).abs() < 1e-9);
        // pud_ns is the max lane plus the per-op dispatch overhead
        assert!(
            (sched.waves[0].pud_ns - (lanes[1].busy_ns + t.pud_dispatch_overhead)).abs() < 1e-9
        );
    }

    #[test]
    fn op_waves_and_wave_elapsed_cover_the_batch() {
        let s = scheme();
        let t = TimingParams::default();
        // p2 reads what p1 writes (wave split); p3 is independent of
        // p2 and lands in its wave
        let p1 = plan_of(
            PudOp::Copy,
            vec![fb_row(0x1000, 8192)],
            (0x1000, 0x3000),
            (0x101000, 0x103000),
        );
        let p2 = plan_of(
            PudOp::Copy,
            vec![fb_row(0x400000, 8192)],
            (0x400000, 0x402000),
            (0x1000, 0x3000),
        );
        let p3 = plan_of(
            PudOp::Copy,
            vec![fb_row(0x600000, 8192)],
            (0x600000, 0x602000),
            (0x701000, 0x703000),
        );
        let sched = build(&s, &t, &[p1, p2, p3]);
        assert_eq!(sched.op_waves(3), vec![0, 1, 1]);
        let per_wave = sched.wave_elapsed();
        assert_eq!(per_wave.len(), sched.waves.len());
        assert!(
            (per_wave.iter().sum::<f64>() - sched.elapsed_ns()).abs() < 1e-9
        );
    }

    #[test]
    fn single_bank_elapsed_matches_serial_sum() {
        let s = scheme();
        let t = TimingParams::default();
        let rows: Vec<RowPlan> = (0..3).map(|_| pud_row(1, 8192)).collect();
        let p = plan_of(PudOp::And, rows, (0x1000, 0x3000), (0x101000, 0x103000));
        let sched = build(&s, &t, &[p]);
        let want = 3.0 * t.ambit_and_or_ns(1) + t.pud_dispatch_overhead;
        assert!((sched.elapsed_ns() - want).abs() < 1e-9);
    }
}
