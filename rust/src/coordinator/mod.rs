//! The coordinator: the dispatch layer between workloads and the two
//! execution substrates.
//!
//! For every submitted bulk operation it (1) translates virtual
//! operands to physical extents through the owning process's page
//! table, (2) runs the PUD legality check, (3) executes the eligible
//! rows in-DRAM via [`crate::pud::PudEngine`], and (4) routes the rest
//! to the CPU fallback — the XLA/PJRT runtime when loaded, else the
//! scalar reference. It owns all cross-cutting statistics.
//!
//! * [`dispatch`] — per-operation planning + execution.
//! * [`batch`] — fallback-row batching into bucket-sized XLA calls.
//! * [`stats`] — cumulative counters for reports.
//! * [`system`] — [`system::System`]: the fully-assembled machine
//!   (OS context + PUD engine + allocators + processes + runtime),
//!   the top-level object examples and benches drive.

pub mod batch;
pub mod dispatch;
pub mod stats;
pub mod system;

pub use dispatch::{Coordinator, FallbackMode};
pub use stats::CoordStats;
pub use system::System;
