//! The coordinator: the dispatch layer between workloads and the two
//! execution substrates, structured as an explicit three-stage
//! plan/schedule/execute pipeline (DESIGN.md §§2-4).
//!
//! For every submitted batch it (1) **plans**: lowers each
//! [`pud::isa::BulkRequest`](crate::pud::isa::BulkRequest) to an
//! [`plan::OpPlan`] — virtual operands translated through a cached
//! page-table walk plus the per-row PUD legality verdicts; (2)
//! **schedules**: splits the batch into hazard waves, coalesces
//! fallback rows *across* operations into shared dispatch groups, and
//! prices PUD rows onto per-bank command timelines; (3) **executes**:
//! PUD rows in-DRAM via [`crate::pud::PudEngine`], fallback rows on
//! the CPU — the XLA/PJRT runtime when loaded, else the scalar
//! reference. It owns all cross-cutting statistics.
//!
//! * [`plan`] — the `OpPlan` IR, planner, and extent-translation cache.
//! * [`schedule`] — hazard waves, dispatch groups, bank-parallel timing.
//! * [`execute`] — the executor and its reusable dispatch scratch.
//! * [`dispatch`] — [`dispatch::Coordinator`]: `submit` / `submit_batch`.
//! * [`batch`] — per-op grouping of fallback rows into runs.
//! * [`stats`] — cumulative counters for reports.
//!   The coordinator also owns the [`crate::obs::Obs`] bundle
//!   (metrics registry + wave tracer): `submit_batch` records per-op
//!   latency/wave-width histograms and, while the tracer is enabled,
//!   one wave event per hazard wave (DESIGN.md §14).
//! * [`system`] — [`system::System`]: the fully-assembled machine
//!   (OS context + PUD engine + allocators + processes + runtime +
//!   request queues), the top-level object examples and benches drive.

pub mod batch;
pub mod dispatch;
pub mod execute;
pub mod plan;
pub mod schedule;
pub mod stats;
pub mod system;

pub use dispatch::{BatchReport, Coordinator, FallbackMode};
pub use plan::OpPlan;
pub use stats::{CoordStats, PipelineStats};
pub use system::{ExprReport, System};
