//! The coordinator front-end of the plan/schedule/execute pipeline.
//!
//! [`Coordinator::submit_batch`] is the request path: every request is
//! lowered to an [`super::plan::OpPlan`] (translate + legality, served
//! by the extent cache), the batch is scheduled into hazard waves with
//! coalesced fallback dispatches and bank-parallel timing, and the
//! executor drives both substrates. [`Coordinator::submit`] is the
//! compatibility wrapper: a one-element batch with identical semantics
//! to the historical serial path. Python is never involved; the XLA
//! executables were compiled AOT at build time.

use anyhow::Result;

use crate::analysis::lint::{self, Diagnostic};
use crate::analysis::VerifyLevel;
use crate::obs::trace::{OpSlot, WaveEvent};
use crate::obs::Obs;
use crate::os::process::Process;
use crate::pud::exec::{ExecStats, PudEngine};
use crate::pud::isa::BulkRequest;
use crate::runtime::XlaRuntime;

use super::execute::Executor;
use super::plan::Planner;
use super::schedule;
use super::stats::{CoordStats, PipelineStats};

/// How fallback rows are executed.
pub enum FallbackMode {
    /// Through the AOT-compiled XLA executables (the real stack).
    Xla(XlaRuntime),
    /// Scalar reference (simulation-only runs and tests).
    Scalar,
}

/// Outcome of one batch submission.
#[derive(Debug, Clone, Default)]
pub struct BatchReport {
    /// Simulated ns of each op, in batch order — identical to what N
    /// serial submits would have returned.
    pub per_op_ns: Vec<f64>,
    /// Serial-equivalent total (sum of `per_op_ns`).
    pub total_ns: f64,
    /// Bank-parallel completion time of the batch: waves serialize,
    /// independent banks within a wave overlap. `<= total_ns`.
    pub elapsed_ns: f64,
    /// Hazard waves the batch was split into.
    pub waves: usize,
    /// Per-wave bank-parallel time, in wave order (sums to
    /// `elapsed_ns`). Lets a caller that merged several independent
    /// streams into one batch recover each stream's completion time:
    /// a stream finishes at the cumulative end of the wave carrying
    /// its last op.
    pub wave_ns: Vec<f64>,
    /// Wave index each op ran in, indexed like `per_op_ns`.
    pub op_wave: Vec<usize>,
}

impl BatchReport {
    /// Simulated completion time of op `i`: the cumulative end of the
    /// wave it ran in (waves serialize; within a wave the op's finish
    /// time is the wave end).
    pub fn op_completion_ns(&self, i: usize) -> f64 {
        self.wave_ns[..=self.op_wave[i]].iter().sum()
    }
}

/// Retained-diagnostic ceiling: an analytics sweep submits thousands
/// of batches, so the buffer is bounded and overflow is counted
/// instead of stored.
const DIAG_CAP: usize = 10_000;

/// The coordinator: owns the PUD engine, the fallback runtime, and the
/// three pipeline stages.
pub struct Coordinator {
    pub engine: PudEngine,
    pub fallback: FallbackMode,
    pub stats: CoordStats,
    pub pipeline: PipelineStats,
    /// Observability bundle: metrics registry + wave tracer. Metrics
    /// are always on; the tracer can be disabled
    /// (`obs.tracer.set_enabled(false)`) for overhead measurements.
    pub obs: Obs,
    /// How much static analysis runs on the request path: `Lint` runs
    /// the placement linter over every batch's plans; `Full` also has
    /// the `System` compile paths verify every emitted stream.
    pub verify: VerifyLevel,
    /// Diagnostics accumulated since the last
    /// [`Coordinator::take_diagnostics`], capped at `DIAG_CAP`.
    pub diagnostics: Vec<Diagnostic>,
    /// Diagnostics dropped after the cap was hit.
    pub diagnostics_dropped: u64,
    planner: Planner,
    executor: Executor,
}

impl Coordinator {
    pub fn new(engine: PudEngine, fallback: FallbackMode) -> Self {
        Self {
            engine,
            fallback,
            stats: CoordStats::default(),
            pipeline: PipelineStats::default(),
            obs: Obs::new(),
            verify: VerifyLevel::Off,
            diagnostics: Vec::new(),
            diagnostics_dropped: 0,
            planner: Planner::default(),
            executor: Executor::default(),
        }
    }

    /// Record diagnostics, bounded by `DIAG_CAP`. An `Error` severity
    /// fires a `debug_assert!` — the "PudSan" mode: debug builds stop
    /// at the first wrong stream, release builds keep going and report.
    pub fn record_diagnostics(&mut self, diags: Vec<Diagnostic>) {
        for d in diags {
            debug_assert!(
                d.severity < lint::Severity::Error,
                "verifier rejected a compiled stream: {d}"
            );
            if self.diagnostics.len() < DIAG_CAP {
                self.diagnostics.push(d);
            } else {
                self.diagnostics_dropped += 1;
            }
        }
    }

    /// Drain the accumulated diagnostics (the dropped counter resets
    /// with them).
    pub fn take_diagnostics(&mut self) -> Vec<Diagnostic> {
        self.diagnostics_dropped = 0;
        std::mem::take(&mut self.diagnostics)
    }

    /// Dispatch one bulk operation for `proc`. Returns the simulated
    /// nanoseconds this operation took. Equivalent to a one-element
    /// [`Coordinator::submit_batch`].
    pub fn submit(&mut self, proc: &Process, req: &BulkRequest) -> Result<f64> {
        let report = self.submit_batch(proc, std::slice::from_ref(req))?;
        Ok(report.per_op_ns[0])
    }

    /// Dispatch a batch of bulk operations for `proc`.
    ///
    /// Functionally equivalent to submitting the requests one by one:
    /// same DRAM image, same [`CoordStats`] work totals (ops, rows,
    /// bytes, simulated ns). The pipeline amortizes control overheads:
    /// operand translations come from the extent cache, fallback rows
    /// of independent same-kind ops share one XLA dispatch, and the
    /// reported `elapsed_ns` lets PUD rows on independent banks
    /// overlap in simulated time. The dispatch-shape counters
    /// (`CoordStats::xla_dispatches`, `xla_wall_ns`,
    /// [`PipelineStats::fallback_dispatches`]) intentionally reflect
    /// the coalescing and therefore shrink relative to one-at-a-time
    /// submission when the XLA runtime is loaded.
    ///
    /// Errors are pre-execution: if any request fails to plan (e.g. an
    /// unmapped operand), no op of the batch has executed.
    pub fn submit_batch(
        &mut self,
        proc: &Process,
        reqs: &[BulkRequest],
    ) -> Result<BatchReport> {
        let items: Vec<(&Process, &BulkRequest)> =
            reqs.iter().map(|r| (proc, r)).collect();
        self.submit_batch_multi(&items)
    }

    /// Dispatch a batch whose requests may belong to *different*
    /// processes — the multi-tenant path: each request is planned
    /// against its own process's mappings (the extent cache is keyed
    /// by pid, so tenants never alias), then the whole batch shares
    /// one hazard-wave schedule so independent tenants' PUD rows
    /// overlap across banks. Semantics otherwise match
    /// [`Coordinator::submit_batch`].
    pub fn submit_batch_multi(
        &mut self,
        items: &[(&Process, &BulkRequest)],
    ) -> Result<BatchReport> {
        if items.is_empty() {
            return Ok(BatchReport::default());
        }
        // 1. plan
        let t0 = std::time::Instant::now();
        let mut plans = Vec::with_capacity(items.len());
        for (proc, req) in items {
            plans.push(self.planner.plan(&self.engine.device.scheme, proc, req)?);
        }
        self.pipeline.plan_wall_ns += t0.elapsed().as_nanos() as u64;
        if self.verify >= VerifyLevel::Lint {
            let site = format!("coordinator/batch{}", self.pipeline.batches);
            let diags = lint::lint_plans(&plans, &site);
            self.record_diagnostics(diags);
        }
        // 2. schedule
        let t1 = std::time::Instant::now();
        let sched =
            schedule::build(&self.engine.device.scheme, &self.engine.timing, &plans);
        self.pipeline.schedule_wall_ns += t1.elapsed().as_nanos() as u64;
        // 3. execute
        let t2 = std::time::Instant::now();
        let per_op: Vec<ExecStats> = self.executor.run(
            &mut self.engine,
            &mut self.fallback,
            &plans,
            &sched,
            &mut self.stats,
            &mut self.pipeline,
        )?;
        self.pipeline.execute_wall_ns += t2.elapsed().as_nanos() as u64;

        // observability: per-op/per-wave histograms are always on; the
        // tracer assembles wave events (lanes + op slots) only while
        // enabled, so the disabled path stays allocation-free.
        let batch_idx = self.pipeline.batches;
        for e in &per_op {
            self.obs
                .registry
                .observe_ns(self.obs.coord.op_sim_ns, e.total_ns());
        }
        for wave in &sched.waves {
            self.obs
                .registry
                .observe(self.obs.coord.wave_ops, wave.op_indices.len() as u64);
            self.obs
                .registry
                .observe_ns(self.obs.coord.wave_elapsed_ns, wave.elapsed_ns());
        }
        if self.obs.tracer.enabled() {
            for wave in &sched.waves {
                let ops = wave
                    .op_indices
                    .iter()
                    .map(|&i| {
                        let e = &per_op[i];
                        OpSlot {
                            op: plans[i].op,
                            pud_rows: e.pud_rows,
                            fallback_rows: e.fallback_rows,
                            pud_bytes: e.pud_bytes,
                            fallback_bytes: e.fallback_bytes,
                            pud_ns: e.pud_ns,
                            fallback_ns: e.fallback_ns,
                        }
                    })
                    .collect();
                self.obs.tracer.record(WaveEvent {
                    batch: batch_idx,
                    wave: 0,     // assigned by the tracer
                    start_ns: 0.0, // assigned by the tracer's cursor
                    pud_ns: wave.pud_ns,
                    fallback_ns: wave.fallback_ns,
                    lanes: wave.lanes.clone(),
                    ops,
                });
            }
        }

        let per_op_ns: Vec<f64> = per_op.iter().map(ExecStats::total_ns).collect();
        let elapsed_ns = sched.elapsed_ns();
        self.pipeline.batches += 1;
        self.pipeline.waves += sched.waves.len() as u64;
        self.pipeline.planned_ops += items.len() as u64;
        self.pipeline.elapsed_ns += elapsed_ns;
        self.pipeline.extent_cache = self.planner.cache.lookups;
        Ok(BatchReport {
            total_ns: per_op_ns.iter().sum(),
            elapsed_ns,
            waves: sched.waves.len(),
            wave_ns: sched.wave_elapsed(),
            op_wave: sched.op_waves(items.len()),
            per_op_ns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::device::DramDevice;
    use crate::dram::geometry::{DramGeometry, SubarrayId};
    use crate::dram::timing::TimingParams;
    use crate::os::process::{Pid, Process};
    use crate::os::vma::VmaKind;
    use crate::os::PAGE_SIZE;
    use crate::pud::isa::PudOp;

    /// Build a process whose VA range maps 1:1 onto given physical rows.
    fn map_rows(
        proc: &mut Process,
        scheme: &InterleaveScheme,
        sid: u32,
        rows: &[u32],
    ) -> u64 {
        let row_bytes = scheme.geometry.row_bytes as u64;
        let pages = row_bytes / PAGE_SIZE;
        let va = proc
            .mmap(rows.len() as u64 * row_bytes, row_bytes, VmaKind::Pud)
            .unwrap();
        for (i, r) in rows.iter().enumerate() {
            let pa = scheme.row_start_addr(SubarrayId(sid), *r);
            for p in 0..pages {
                proc.page_table
                    .map(
                        va + i as u64 * row_bytes + p * PAGE_SIZE,
                        pa + p * PAGE_SIZE,
                        crate::os::page_table::PageKind::Base,
                    )
                    .unwrap();
            }
        }
        va
    }

    fn coordinator() -> Coordinator {
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        let engine = PudEngine::new(DramDevice::new(scheme), TimingParams::default());
        Coordinator::new(engine, FallbackMode::Scalar)
    }

    #[test]
    fn colocated_and_runs_fully_in_pud() {
        let mut c = coordinator();
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        let dst = map_rows(&mut proc, &scheme, 3, &[10, 11]);
        let a = map_rows(&mut proc, &scheme, 3, &[20, 21]);
        let b = map_rows(&mut proc, &scheme, 3, &[30, 31]);
        // seed operands
        c.engine.device.write(
            scheme.row_start_addr(SubarrayId(3), 20),
            &vec![0xF0u8; row_bytes as usize],
        );
        c.engine.device.write(
            scheme.row_start_addr(SubarrayId(3), 30),
            &vec![0x3Cu8; row_bytes as usize],
        );
        let req = BulkRequest::new(PudOp::And, dst, vec![a, b], 2 * row_bytes);
        let ns = c.submit(&proc, &req).unwrap();
        assert!(ns > 0.0);
        assert_eq!(c.stats.pud_rows, 2);
        assert_eq!(c.stats.fallback_rows, 0);
        assert!((c.stats.pud_row_fraction() - 1.0).abs() < 1e-12);
        let mut got = vec![0u8; row_bytes as usize];
        c.engine
            .device
            .read(scheme.row_start_addr(SubarrayId(3), 10), &mut got);
        assert_eq!(got, vec![0xF0 & 0x3C; row_bytes as usize]);
    }

    #[test]
    fn cross_subarray_operands_fall_back() {
        let mut c = coordinator();
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        let dst = map_rows(&mut proc, &scheme, 1, &[5]);
        let a = map_rows(&mut proc, &scheme, 2, &[6]); // different sid
        let req = BulkRequest::new(PudOp::Copy, dst, vec![a], row_bytes);
        c.submit(&proc, &req).unwrap();
        assert_eq!(c.stats.pud_rows, 0);
        assert_eq!(c.stats.fallback_rows, 1);
        assert_eq!(c.stats.ops_fully_pud.hits, 0);
    }

    #[test]
    fn unmapped_operand_is_an_error() {
        let mut c = coordinator();
        let proc = Process::new(Pid(1));
        let req = BulkRequest::new(PudOp::Zero, 0x5000, vec![], 4096);
        assert!(c.submit(&proc, &req).is_err());
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut c = coordinator();
        let proc = Process::new(Pid(1));
        let report = c.submit_batch(&proc, &[]).unwrap();
        assert!(report.per_op_ns.is_empty());
        assert_eq!(report.waves, 0);
        assert_eq!(c.stats.ops, 0);
        assert_eq!(c.pipeline.batches, 0);
    }

    #[test]
    fn batch_of_independent_ops_runs_in_one_wave() {
        let mut c = coordinator();
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        let mut reqs = Vec::new();
        for i in 0..3u32 {
            let dst = map_rows(&mut proc, &scheme, 3, &[10 + i]);
            let src = map_rows(&mut proc, &scheme, 3, &[20 + i]);
            reqs.push(BulkRequest::new(PudOp::Copy, dst, vec![src], row_bytes));
        }
        let report = c.submit_batch(&proc, &reqs).unwrap();
        assert_eq!(report.waves, 1);
        assert_eq!(report.per_op_ns.len(), 3);
        assert_eq!(c.stats.ops, 3);
        assert!((report.total_ns - report.per_op_ns.iter().sum::<f64>()).abs() < 1e-9);
        // same subarray => same bank: no overlap, but overheads still
        // bound elapsed by the serial total
        assert!(report.elapsed_ns <= report.total_ns + 1e-9);
    }

    #[test]
    fn tracer_records_one_event_per_wave_with_op_slots() {
        let mut c = coordinator();
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        let a = map_rows(&mut proc, &scheme, 2, &[1]);
        let cc = map_rows(&mut proc, &scheme, 2, &[3]);
        let d = map_rows(&mut proc, &scheme, 2, &[4]);
        let reqs = vec![
            BulkRequest::new(PudOp::Copy, cc, vec![a], row_bytes),
            BulkRequest::new(PudOp::Not, d, vec![cc], row_bytes),
        ];
        c.submit_batch(&proc, &reqs).unwrap();
        let t = &c.obs.tracer;
        assert_eq!(t.len() as u64 + t.dropped, c.pipeline.waves);
        assert_eq!(t.total_waves, c.pipeline.waves);
        let slot_ops: u64 = t.events().iter().map(|e| e.ops.len() as u64).sum();
        assert_eq!(slot_ops, c.stats.ops);
        // wave ids are the global sequence, batches stamped
        for (i, e) in t.events().iter().enumerate() {
            assert_eq!(e.wave, i as u64);
            assert_eq!(e.batch, 0);
        }
        // histograms saw every op and wave
        let reg = &c.obs.registry;
        assert_eq!(reg.hist_by_name("coord/op_sim_ns").unwrap().count, c.stats.ops);
        assert_eq!(
            reg.hist_by_name("coord/wave_ops").unwrap().count,
            c.pipeline.waves
        );
    }

    #[test]
    fn disabled_tracer_records_nothing_but_metrics_stay_on() {
        let mut c = coordinator();
        c.obs.tracer.set_enabled(false);
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        let dst = map_rows(&mut proc, &scheme, 3, &[10]);
        let src = map_rows(&mut proc, &scheme, 3, &[20]);
        c.submit(&proc, &BulkRequest::new(PudOp::Copy, dst, vec![src], row_bytes))
            .unwrap();
        assert!(c.obs.tracer.is_empty());
        assert_eq!(c.obs.tracer.dropped, 0);
        assert_eq!(c.obs.registry.hist_by_name("coord/op_sim_ns").unwrap().count, 1);
    }

    #[test]
    fn dependent_batch_matches_serial_results() {
        // c = copy(a); d = and(c, b): RAW chain through c
        let run = |batched: bool| -> (Vec<u8>, CoordStats) {
            let mut c = coordinator();
            let scheme = c.engine.device.scheme.clone();
            let mut proc = Process::new(Pid(1));
            let row_bytes = scheme.geometry.row_bytes as u64;
            let a = map_rows(&mut proc, &scheme, 2, &[1]);
            let b = map_rows(&mut proc, &scheme, 2, &[2]);
            let cc = map_rows(&mut proc, &scheme, 2, &[3]);
            let d = map_rows(&mut proc, &scheme, 2, &[4]);
            c.engine.device.write(
                scheme.row_start_addr(SubarrayId(2), 1),
                &vec![0xA5u8; row_bytes as usize],
            );
            c.engine.device.write(
                scheme.row_start_addr(SubarrayId(2), 2),
                &vec![0x0Fu8; row_bytes as usize],
            );
            let reqs = vec![
                BulkRequest::new(PudOp::Copy, cc, vec![a], row_bytes),
                BulkRequest::new(PudOp::And, d, vec![cc, b], row_bytes),
            ];
            if batched {
                let report = c.submit_batch(&proc, &reqs).unwrap();
                assert_eq!(report.waves, 2, "RAW hazard must split waves");
            } else {
                for r in &reqs {
                    c.submit(&proc, r).unwrap();
                }
            }
            let mut got = vec![0u8; row_bytes as usize];
            c.engine
                .device
                .read(scheme.row_start_addr(SubarrayId(2), 4), &mut got);
            (got, c.stats.clone())
        };
        let (serial, serial_stats) = run(false);
        let (batched, batched_stats) = run(true);
        assert_eq!(serial, batched);
        assert_eq!(serial, vec![0xA5 & 0x0F; serial.len()]);
        assert_eq!(serial_stats, batched_stats);
    }

    #[test]
    fn fallback_is_slower_than_pud_in_sim_time() {
        let mut c = coordinator();
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        // PUD-placed copy
        let dst1 = map_rows(&mut proc, &scheme, 4, &[1]);
        let src1 = map_rows(&mut proc, &scheme, 4, &[2]);
        let pud_ns = c
            .submit(&proc, &BulkRequest::new(PudOp::Copy, dst1, vec![src1], row_bytes))
            .unwrap();
        // cross-subarray copy (fallback)
        let dst2 = map_rows(&mut proc, &scheme, 5, &[1]);
        let src2 = map_rows(&mut proc, &scheme, 6, &[2]);
        let fb_ns = c
            .submit(&proc, &BulkRequest::new(PudOp::Copy, dst2, vec![src2], row_bytes))
            .unwrap();
        assert!(
            fb_ns > 3.0 * pud_ns,
            "fallback {fb_ns} ns should dwarf PUD {pud_ns} ns"
        );
    }

    #[test]
    fn lint_level_records_fallback_diagnostics() {
        use crate::analysis::{Lint, VerifyLevel};
        use crate::pud::legality::FallbackCause;
        let mut c = coordinator();
        c.verify = VerifyLevel::Lint;
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        // clean PUD batch: no diagnostics
        let dst = map_rows(&mut proc, &scheme, 3, &[10]);
        let src = map_rows(&mut proc, &scheme, 3, &[20]);
        c.submit(&proc, &BulkRequest::new(PudOp::Copy, dst, vec![src], row_bytes))
            .unwrap();
        assert!(c.diagnostics.is_empty());
        // cross-subarray batch: attributed fallback + avoidable note
        let dst2 = map_rows(&mut proc, &scheme, 1, &[5]);
        let src2 = map_rows(&mut proc, &scheme, 2, &[6]);
        c.submit(&proc, &BulkRequest::new(PudOp::Copy, dst2, vec![src2], row_bytes))
            .unwrap();
        let diags = c.take_diagnostics();
        assert!(diags
            .iter()
            .any(|d| d.lint == Lint::FallbackRow(FallbackCause::CrossSubarray)));
        assert!(diags.iter().any(|d| d.lint == Lint::AvoidableFallback));
        assert!(diags[0].site.contains("coordinator/batch"));
        assert!(c.diagnostics.is_empty(), "take drains the buffer");
        // off by default: the same traffic records nothing
        c.verify = VerifyLevel::Off;
        c.submit(&proc, &BulkRequest::new(PudOp::Copy, dst2, vec![src2], row_bytes))
            .unwrap();
        assert!(c.diagnostics.is_empty());
    }

    #[test]
    fn xla_fallback_matches_scalar() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.tsv").exists() {
            return;
        }
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        let row_bytes = scheme.geometry.row_bytes as u64;
        let mk = |mode: FallbackMode| {
            let engine = PudEngine::new(
                DramDevice::new(scheme.clone()),
                TimingParams::default(),
            );
            Coordinator::new(engine, mode)
        };
        let rt = XlaRuntime::load(&dir).unwrap();
        let mut rng = crate::util::rng::Pcg64::new(77);
        let mut va_bytes = vec![0u8; 2 * row_bytes as usize];
        let mut vb_bytes = vec![0u8; 2 * row_bytes as usize];
        rng.fill_bytes(&mut va_bytes);
        rng.fill_bytes(&mut vb_bytes);

        let mut run = |mut c: Coordinator| -> Vec<u8> {
            let mut proc = Process::new(Pid(1));
            // misaligned dst forces fallback on both rows
            let dst = map_rows(&mut proc, &scheme, 7, &[40, 41, 42]);
            let dst_off = dst + 128; // break row alignment
            let a = map_rows(&mut proc, &scheme, 7, &[50, 51, 52]);
            let b = map_rows(&mut proc, &scheme, 7, &[60, 61, 62]);
            c.engine
                .device
                .write(scheme.row_start_addr(SubarrayId(7), 50), &va_bytes[..row_bytes as usize]);
            c.engine
                .device
                .write(scheme.row_start_addr(SubarrayId(7), 51), &va_bytes[row_bytes as usize..]);
            c.engine
                .device
                .write(scheme.row_start_addr(SubarrayId(7), 60), &vb_bytes[..row_bytes as usize]);
            c.engine
                .device
                .write(scheme.row_start_addr(SubarrayId(7), 61), &vb_bytes[row_bytes as usize..]);
            let req =
                BulkRequest::new(PudOp::Xor, dst_off, vec![a, b], 2 * row_bytes);
            c.submit(&proc, &req).unwrap();
            assert_eq!(c.stats.fallback_rows, 2);
            // read result through the process mapping
            let ext = proc.phys_extents(dst_off, 2 * row_bytes).unwrap();
            let mut out = Vec::new();
            for e in ext {
                let mut buf = vec![0u8; e.len as usize];
                c.engine.device.read(e.paddr, &mut buf);
                out.extend(buf);
            }
            out
        };

        let scalar_out = run(mk(FallbackMode::Scalar));
        let xla_out = run(mk(FallbackMode::Xla(rt)));
        assert_eq!(scalar_out, xla_out, "XLA and scalar fallback agree");
        let want: Vec<u8> = va_bytes
            .iter()
            .zip(&vb_bytes)
            .map(|(x, y)| x ^ y)
            .collect();
        assert_eq!(xla_out, want);
    }
}
