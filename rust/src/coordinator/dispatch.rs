//! Per-operation planning and execution.
//!
//! [`Coordinator::submit`] is the request path: translate -> legality
//! plan -> PUD execute -> fallback execute (XLA or scalar). Python is
//! never involved; the XLA executables were compiled AOT at build
//! time.

use anyhow::{bail, Result};

use crate::os::process::Process;
use crate::pud::exec::PudEngine;
use crate::pud::isa::{BulkRequest, PudOp};
use crate::pud::legality::{check_rowwise, RowPlan};
use crate::runtime::{XlaRuntime, ROW_BYTES};

use super::batch::fallback_runs;
use super::stats::CoordStats;

/// How fallback rows are executed.
pub enum FallbackMode {
    /// Through the AOT-compiled XLA executables (the real stack).
    Xla(XlaRuntime),
    /// Scalar reference (simulation-only runs and tests).
    Scalar,
}

/// The coordinator: owns the PUD engine and the fallback runtime.
pub struct Coordinator {
    pub engine: PudEngine,
    pub fallback: FallbackMode,
    pub stats: CoordStats,
}

impl Coordinator {
    pub fn new(engine: PudEngine, fallback: FallbackMode) -> Self {
        Self {
            engine,
            fallback,
            stats: CoordStats::default(),
        }
    }

    /// Dispatch one bulk operation for `proc`. Returns the simulated
    /// nanoseconds this operation took.
    pub fn submit(&mut self, proc: &Process, req: &BulkRequest) -> Result<f64> {
        if req.len == 0 {
            bail!("zero-length bulk op");
        }
        // 1. virtual -> physical extents
        let dst_ext = proc.phys_extents(req.dst, req.len)?;
        let mut src_exts = Vec::with_capacity(req.srcs.len());
        for s in &req.srcs {
            src_exts.push(proc.phys_extents(*s, req.len)?);
        }
        let mut operands: Vec<&[crate::os::process::PhysExtent]> =
            Vec::with_capacity(1 + src_exts.len());
        operands.push(&dst_ext);
        for e in &src_exts {
            operands.push(e);
        }
        // 2. legality plan
        let plan = check_rowwise(&self.engine.device.scheme, &operands, req.len);
        // 3. PUD rows (functional + simulated timing); fallback rows
        //    get DRAM-side accounting here, functional execution below
        let exec = self
            .engine
            .execute(req.op, &plan, matches!(self.fallback, FallbackMode::Scalar))?;
        // 4. fallback runs through XLA
        if let FallbackMode::Xla(_) = self.fallback {
            self.run_fallback_xla(req.op, &plan)?;
        }
        self.stats.ops += 1;
        self.stats
            .ops_fully_pud
            .record(exec.fallback_rows == 0 && exec.pud_rows > 0);
        self.stats.absorb_exec(&exec);
        Ok(exec.total_ns())
    }

    /// Execute the fallback rows of `plan` via the XLA runtime:
    /// gather operand bytes from the device, run the kernel, scatter
    /// the result back.
    fn run_fallback_xla(&mut self, op: PudOp, plan: &[RowPlan]) -> Result<()> {
        let runs = fallback_runs(plan);
        if runs.is_empty() {
            return Ok(());
        }
        debug_assert!(matches!(self.fallback, FallbackMode::Xla(_)));
        for run in runs {
            // whole rows for the kernel; the tail is zero-padded and
            // the scatter truncates back to `run.bytes`
            let rows = run.bytes.div_ceil(ROW_BYTES as u64) as u32;
            let padded = rows as usize * ROW_BYTES;
            let arity = op.arity();
            // gather each operand's (scattered) bytes row-by-row
            let mut srcs: Vec<Vec<u8>> = vec![vec![0u8; padded]; arity];
            let mut off = 0usize;
            for entry in &plan[run.first_row_idx..run.first_row_idx + run.rows] {
                let RowPlan::Fallback { srcs: s_exts, bytes, .. } = entry else {
                    bail!("run covers a non-fallback row");
                };
                let b = *bytes as usize;
                for (k, ext) in s_exts.iter().enumerate() {
                    let chunk = self.engine.gather(ext, b as u64);
                    srcs[k][off..off + b].copy_from_slice(&chunk);
                }
                off += b;
            }
            let FallbackMode::Xla(rt) = &mut self.fallback else {
                unreachable!("caller checked");
            };
            let src_refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
            let t0 = std::time::Instant::now();
            let out = rt.run_op(op.kernel_name(), rows, &src_refs)?;
            self.stats.xla_wall_ns += t0.elapsed().as_nanos() as u64;
            self.stats.xla_dispatches += 1;
            // scatter the result back to the destination extents
            let mut off = 0usize;
            for entry in &plan[run.first_row_idx..run.first_row_idx + run.rows] {
                let RowPlan::Fallback { dst, bytes, .. } = entry else {
                    unreachable!()
                };
                let b = *bytes as usize;
                self.engine.scatter(dst, &out[off..off + b]);
                off += b;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::device::DramDevice;
    use crate::dram::geometry::{DramGeometry, SubarrayId};
    use crate::dram::timing::TimingParams;
    use crate::os::process::{Pid, Process};
    use crate::os::vma::VmaKind;
    use crate::os::PAGE_SIZE;

    /// Build a process whose VA range maps 1:1 onto given physical rows.
    fn map_rows(
        proc: &mut Process,
        scheme: &InterleaveScheme,
        sid: u32,
        rows: &[u32],
    ) -> u64 {
        let row_bytes = scheme.geometry.row_bytes as u64;
        let pages = row_bytes / PAGE_SIZE;
        let va = proc
            .mmap(rows.len() as u64 * row_bytes, row_bytes, VmaKind::Pud)
            .unwrap();
        for (i, r) in rows.iter().enumerate() {
            let pa = scheme.row_start_addr(SubarrayId(sid), *r);
            for p in 0..pages {
                proc.page_table
                    .map(
                        va + i as u64 * row_bytes + p * PAGE_SIZE,
                        pa + p * PAGE_SIZE,
                        crate::os::page_table::PageKind::Base,
                    )
                    .unwrap();
            }
        }
        va
    }

    fn coordinator() -> Coordinator {
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        let engine = PudEngine::new(DramDevice::new(scheme), TimingParams::default());
        Coordinator::new(engine, FallbackMode::Scalar)
    }

    #[test]
    fn colocated_and_runs_fully_in_pud() {
        let mut c = coordinator();
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        let dst = map_rows(&mut proc, &scheme, 3, &[10, 11]);
        let a = map_rows(&mut proc, &scheme, 3, &[20, 21]);
        let b = map_rows(&mut proc, &scheme, 3, &[30, 31]);
        // seed operands
        c.engine.device.write(
            scheme.row_start_addr(SubarrayId(3), 20),
            &vec![0xF0u8; row_bytes as usize],
        );
        c.engine.device.write(
            scheme.row_start_addr(SubarrayId(3), 30),
            &vec![0x3Cu8; row_bytes as usize],
        );
        let req = BulkRequest::new(PudOp::And, dst, vec![a, b], 2 * row_bytes);
        let ns = c.submit(&proc, &req).unwrap();
        assert!(ns > 0.0);
        assert_eq!(c.stats.pud_rows, 2);
        assert_eq!(c.stats.fallback_rows, 0);
        assert!((c.stats.pud_row_fraction() - 1.0).abs() < 1e-12);
        let mut got = vec![0u8; row_bytes as usize];
        c.engine
            .device
            .read(scheme.row_start_addr(SubarrayId(3), 10), &mut got);
        assert_eq!(got, vec![0xF0 & 0x3C; row_bytes as usize]);
    }

    #[test]
    fn cross_subarray_operands_fall_back() {
        let mut c = coordinator();
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        let dst = map_rows(&mut proc, &scheme, 1, &[5]);
        let a = map_rows(&mut proc, &scheme, 2, &[6]); // different sid
        let req = BulkRequest::new(PudOp::Copy, dst, vec![a], row_bytes);
        c.submit(&proc, &req).unwrap();
        assert_eq!(c.stats.pud_rows, 0);
        assert_eq!(c.stats.fallback_rows, 1);
        assert_eq!(c.stats.ops_fully_pud.hits, 0);
    }

    #[test]
    fn unmapped_operand_is_an_error() {
        let mut c = coordinator();
        let proc = Process::new(Pid(1));
        let req = BulkRequest::new(PudOp::Zero, 0x5000, vec![], 4096);
        assert!(c.submit(&proc, &req).is_err());
    }

    #[test]
    fn fallback_is_slower_than_pud_in_sim_time() {
        let mut c = coordinator();
        let scheme = c.engine.device.scheme.clone();
        let mut proc = Process::new(Pid(1));
        let row_bytes = scheme.geometry.row_bytes as u64;
        // PUD-placed copy
        let dst1 = map_rows(&mut proc, &scheme, 4, &[1]);
        let src1 = map_rows(&mut proc, &scheme, 4, &[2]);
        let pud_ns = c
            .submit(&proc, &BulkRequest::new(PudOp::Copy, dst1, vec![src1], row_bytes))
            .unwrap();
        // cross-subarray copy (fallback)
        let dst2 = map_rows(&mut proc, &scheme, 5, &[1]);
        let src2 = map_rows(&mut proc, &scheme, 6, &[2]);
        let fb_ns = c
            .submit(&proc, &BulkRequest::new(PudOp::Copy, dst2, vec![src2], row_bytes))
            .unwrap();
        assert!(
            fb_ns > 3.0 * pud_ns,
            "fallback {fb_ns} ns should dwarf PUD {pud_ns} ns"
        );
    }

    #[test]
    fn xla_fallback_matches_scalar() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.tsv").exists() {
            return;
        }
        let scheme = InterleaveScheme::row_major(DramGeometry::default());
        let row_bytes = scheme.geometry.row_bytes as u64;
        let mk = |mode: FallbackMode| {
            let engine = PudEngine::new(
                DramDevice::new(scheme.clone()),
                TimingParams::default(),
            );
            Coordinator::new(engine, mode)
        };
        let rt = XlaRuntime::load(&dir).unwrap();
        let mut rng = crate::util::rng::Pcg64::new(77);
        let mut va_bytes = vec![0u8; 2 * row_bytes as usize];
        let mut vb_bytes = vec![0u8; 2 * row_bytes as usize];
        rng.fill_bytes(&mut va_bytes);
        rng.fill_bytes(&mut vb_bytes);

        let mut run = |mut c: Coordinator| -> Vec<u8> {
            let mut proc = Process::new(Pid(1));
            // misaligned dst forces fallback on both rows
            let dst = map_rows(&mut proc, &scheme, 7, &[40, 41, 42]);
            let dst_off = dst + 128; // break row alignment
            let a = map_rows(&mut proc, &scheme, 7, &[50, 51, 52]);
            let b = map_rows(&mut proc, &scheme, 7, &[60, 61, 62]);
            c.engine
                .device
                .write(scheme.row_start_addr(SubarrayId(7), 50), &va_bytes[..row_bytes as usize]);
            c.engine
                .device
                .write(scheme.row_start_addr(SubarrayId(7), 51), &va_bytes[row_bytes as usize..]);
            c.engine
                .device
                .write(scheme.row_start_addr(SubarrayId(7), 60), &vb_bytes[..row_bytes as usize]);
            c.engine
                .device
                .write(scheme.row_start_addr(SubarrayId(7), 61), &vb_bytes[row_bytes as usize..]);
            let req =
                BulkRequest::new(PudOp::Xor, dst_off, vec![a, b], 2 * row_bytes);
            c.submit(&proc, &req).unwrap();
            assert_eq!(c.stats.fallback_rows, 2);
            // read result through the process mapping
            let ext = proc.phys_extents(dst_off, 2 * row_bytes).unwrap();
            let mut out = Vec::new();
            for e in ext {
                let mut buf = vec![0u8; e.len as usize];
                c.engine.device.read(e.paddr, &mut buf);
                out.extend(buf);
            }
            out
        };

        let scalar_out = run(mk(FallbackMode::Scalar));
        let xla_out = run(mk(FallbackMode::Xla(rt)));
        assert_eq!(scalar_out, xla_out, "XLA and scalar fallback agree");
        let want: Vec<u8> = va_bytes
            .iter()
            .zip(&vb_bytes)
            .map(|(x, y)| x ^ y)
            .collect();
        assert_eq!(xla_out, want);
    }
}
