//! `puma` — leader entrypoint + CLI.
//!
//! See `puma help` for commands; the heavy lifting lives in
//! [`puma::cli`]. The binary is fully self-contained after
//! `make artifacts`: python never runs on this path.

fn main() {
    puma::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match puma::cli::run(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
