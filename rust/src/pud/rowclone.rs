//! RowClone: in-DRAM bulk copy and initialization.
//!
//! FPM (Fast Parallel Mode): two back-to-back activations (an "AAP")
//! copy a source row into a destination row of the *same subarray*
//! through the shared sense amplifiers. PSM (Pipelined Serial Mode)
//! moves a row between subarrays/banks through the internal bus —
//! slower, but still avoids the memory channel.
//!
//! Functional semantics execute on the [`DramDevice`] backing store;
//! command counters and analytic latency follow the sequence costs in
//! [`TimingParams`].

use anyhow::{ensure, Result};

use crate::dram::device::DramDevice;
use crate::dram::geometry::Loc;
use crate::dram::timing::TimingParams;

/// Copy `src` row into `dst` row via FPM. Both must be row-aligned
/// locations in the same subarray. Returns latency (ns).
pub fn fpm_copy(
    dev: &mut DramDevice,
    timing: &TimingParams,
    src: &Loc,
    dst: &Loc,
) -> Result<f64> {
    ensure!(src.column == 0 && dst.column == 0, "FPM needs row-aligned operands");
    let g = dev.geometry().clone();
    ensure!(
        g.subarray_id(src) == g.subarray_id(dst),
        "FPM requires same-subarray src/dst"
    );
    if src.row == dst.row {
        // copy-to-self: an identity — charge the AAP, move nothing
        dev.counters.aaps += 1;
        return Ok(timing.rowclone_fpm_ns(1));
    }
    let row = dev.read_row(src);
    dev.write_row(dst, &row);
    dev.counters.aaps += 1;
    Ok(timing.rowclone_fpm_ns(1))
}

/// Zero-initialize `dst` row (AAP from the control all-zeros row).
pub fn zero_row(
    dev: &mut DramDevice,
    timing: &TimingParams,
    dst: &Loc,
) -> Result<f64> {
    ensure!(dst.column == 0, "zero-init needs a row-aligned destination");
    let zeros = vec![0u8; dev.geometry().row_bytes as usize];
    dev.write_row(dst, &zeros);
    dev.counters.aaps += 1;
    Ok(timing.rowclone_zero_ns(1))
}

/// Copy a row between *different* subarrays via PSM.
pub fn psm_copy(
    dev: &mut DramDevice,
    timing: &TimingParams,
    src: &Loc,
    dst: &Loc,
) -> Result<f64> {
    ensure!(src.column == 0 && dst.column == 0, "PSM needs row-aligned operands");
    let g = dev.geometry().clone();
    ensure!(
        g.subarray_id(src) != g.subarray_id(dst),
        "PSM is for inter-subarray moves (use FPM within one)"
    );
    let row = dev.read_row(src);
    dev.write_row(dst, &row);
    dev.counters.psm_rows += 1;
    Ok(timing.rowclone_psm_ns(1, g.row_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::{DramGeometry, SubarrayId};

    fn dev() -> DramDevice {
        DramDevice::new(InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 2,
            rows_per_subarray: 16,
            row_bytes: 128,
        }))
    }

    fn loc_of(d: &DramDevice, sid: u32, row: u32) -> Loc {
        let addr = d.scheme.row_start_addr(SubarrayId(sid), row);
        d.scheme.decode(addr)
    }

    #[test]
    fn fpm_copies_contents() {
        let mut d = dev();
        let t = TimingParams::default();
        let src = loc_of(&d, 0, 3);
        let dst = loc_of(&d, 0, 7);
        let data: Vec<u8> = (0..128).collect();
        d.write_row(&src, &data);
        let ns = fpm_copy(&mut d, &t, &src, &dst).unwrap();
        assert_eq!(d.read_row(&dst), data);
        assert_eq!(ns, t.t_aap);
        assert_eq!(d.counters.aaps, 1);
    }

    #[test]
    fn fpm_rejects_cross_subarray_and_misalignment() {
        let mut d = dev();
        let t = TimingParams::default();
        let a = loc_of(&d, 0, 1);
        let b = loc_of(&d, 1, 1);
        assert!(fpm_copy(&mut d, &t, &a, &b).is_err());
        let mid = Loc { column: 4, ..a };
        assert!(fpm_copy(&mut d, &t, &mid, &a).is_err());
    }

    #[test]
    fn fpm_copy_to_self_is_identity() {
        let mut d = dev();
        let t = TimingParams::default();
        let a = loc_of(&d, 0, 1);
        let data: Vec<u8> = (0..128).collect();
        d.write_row(&a, &data);
        let ns = fpm_copy(&mut d, &t, &a, &a).unwrap();
        assert_eq!(d.read_row(&a), data);
        assert_eq!(ns, t.t_aap);
    }

    #[test]
    fn zero_row_clears() {
        let mut d = dev();
        let t = TimingParams::default();
        let dst = loc_of(&d, 1, 2);
        d.write_row(&dst, &vec![0xFF; 128]);
        zero_row(&mut d, &t, &dst).unwrap();
        assert_eq!(d.read_row(&dst), vec![0u8; 128]);
    }

    #[test]
    fn psm_crosses_subarrays_and_costs_more() {
        let mut d = dev();
        let t = TimingParams::default();
        let src = loc_of(&d, 0, 3);
        let dst = loc_of(&d, 3, 9);
        let data: Vec<u8> = (0..128).rev().collect();
        d.write_row(&src, &data);
        let psm_ns = psm_copy(&mut d, &t, &src, &dst).unwrap();
        assert_eq!(d.read_row(&dst), data);
        // at realistic row sizes (8 KiB) PSM costs well above one AAP;
        // the 128 B test row is too small for that comparison, so
        // check the model directly at the default row size
        assert!(t.rowclone_psm_ns(1, 8192) > t.rowclone_fpm_ns(1));
        assert!(psm_ns > 0.0);
        assert_eq!(d.counters.psm_rows, 1);
        // PSM within one subarray is rejected
        let near = loc_of(&d, 0, 5);
        assert!(psm_copy(&mut d, &t, &src, &near).is_err());
    }
}
