//! Bit-serial arithmetic kernels as multi-output expression programs.
//!
//! Every kernel expands into the PR-3 compiler's
//! [`Node`](crate::pud::compiler::Node) DAG over per-bit leaves — a
//! W-bit ripple-carry add is W chained full adders of XOR/AND/OR
//! gates — and freezes as a [`MultiExpr`] whose roots
//! are the result bit-planes. Compilation then gives CSE (one shared
//! carry/borrow chain feeds every output), scratch register
//! allocation, and single-`submit_batch` emission for free.
//!
//! Leaf layout: leaves `0..W` are operand `a`'s bit-planes (LSB
//! first); binary kernels put operand `b` at leaves `W..2W`.
//! [`kernel_const`] replaces `b` with constant bits so comparisons
//! against a literal threshold fold through the optimizer before a
//! single request is emitted.

use super::super::compiler::{ExprBuilder, ExprId, MultiExpr};

/// Which arithmetic kernel. `Hash`/`Eq` so `(ArithOp, width)` can key
/// the system's compiled-program cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArithOp {
    /// Wrapping W-bit add.
    Add,
    /// Wrapping W-bit subtract.
    Sub,
    /// Unsigned `a < b` (one predicate bit-plane, usable as a filter
    /// mask).
    CmpLt,
    /// `a == b` (one predicate bit-plane).
    CmpEq,
    /// Element-wise unsigned minimum (select via the `a < b` borrow).
    Min,
    /// Element-wise unsigned maximum.
    Max,
    /// Per-element popcount of `a`'s W bits via a widening adder tree.
    Popcount,
}

impl ArithOp {
    pub const ALL: [ArithOp; 7] = [
        ArithOp::Add,
        ArithOp::Sub,
        ArithOp::CmpLt,
        ArithOp::CmpEq,
        ArithOp::Min,
        ArithOp::Max,
        ArithOp::Popcount,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ArithOp::Add => "add",
            ArithOp::Sub => "sub",
            ArithOp::CmpLt => "cmp_lt",
            ArithOp::CmpEq => "cmp_eq",
            ArithOp::Min => "min",
            ArithOp::Max => "max",
            ArithOp::Popcount => "popcount",
        }
    }

    /// Does the kernel read a second operand?
    pub fn is_binary(&self) -> bool {
        !matches!(self, ArithOp::Popcount)
    }

    /// Result bit-planes for a `width`-bit input.
    pub fn out_width(&self, width: u32) -> u32 {
        match self {
            ArithOp::Add | ArithOp::Sub | ArithOp::Min | ArithOp::Max => width,
            ArithOp::CmpLt | ArithOp::CmpEq => 1,
            ArithOp::Popcount => popcount_width(width),
        }
    }
}

/// Maximum kernel operand width (u64-backed reference arithmetic).
pub const MAX_WIDTH: u32 = 32;

/// Bit-planes the popcount adder tree emits for a `width`-bit input.
/// Mirrors the pairing in [`popcount_tree`]; for power-of-two widths
/// this is exactly `log2(width) + 1`, for ragged widths the leftover
/// operand carried across levels can add a provably-zero top bit.
pub fn popcount_width(width: u32) -> u32 {
    assert!(width >= 1);
    let mut widths: Vec<u32> = vec![1; width as usize];
    while widths.len() > 1 {
        let mut next = Vec::with_capacity(widths.len().div_ceil(2));
        for pair in widths.chunks(2) {
            if let [x, y] = pair {
                next.push(x.max(y) + 1);
            } else {
                next.push(pair[0]);
            }
        }
        widths = next;
    }
    widths[0]
}

/// The all-ones mask of a `width`-bit lane.
pub fn width_mask(width: u32) -> u64 {
    assert!(width >= 1 && width <= 64);
    if width == 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// Scalar reference semantics of one element — the numeric oracle the
/// property tests and workloads check compiled execution against.
pub fn reference(op: ArithOp, width: u32, a: u64, b: u64) -> u64 {
    let m = width_mask(width);
    let (a, b) = (a & m, b & m);
    match op {
        ArithOp::Add => a.wrapping_add(b) & m,
        ArithOp::Sub => a.wrapping_sub(b) & m,
        ArithOp::CmpLt => (a < b) as u64,
        ArithOp::CmpEq => (a == b) as u64,
        ArithOp::Min => a.min(b),
        ArithOp::Max => a.max(b),
        ArithOp::Popcount => a.count_ones() as u64,
    }
}

/// Build the `op` kernel over `width`-bit operands: leaves `0..width`
/// are `a`, leaves `width..2*width` are `b` (binary kernels only).
pub fn kernel(op: ArithOp, width: u32) -> MultiExpr {
    assert!(width >= 1 && width <= MAX_WIDTH, "width {width} out of range");
    let mut b = ExprBuilder::new();
    let a_bits: Vec<ExprId> = (0..width).map(|i| b.leaf(i as usize)).collect();
    if !op.is_binary() {
        let outs = popcount_tree(&mut b, &a_bits);
        return b.build_multi(outs);
    }
    let b_bits: Vec<ExprId> =
        (0..width).map(|i| b.leaf((width + i) as usize)).collect();
    let outs = binary_outputs(&mut b, op, &a_bits, &b_bits);
    b.build_multi(outs)
}

/// Build the `op` kernel with operand `b` fixed to the constant `rhs`:
/// its bits become `Const` nodes and the optimizer folds the chain
/// down before lowering (a threshold compare against `2^(W-1)` is a
/// handful of ops, not a full borrow chain).
pub fn kernel_const(op: ArithOp, width: u32, rhs: u64) -> MultiExpr {
    assert!(width >= 1 && width <= MAX_WIDTH, "width {width} out of range");
    assert!(op.is_binary(), "{} takes no second operand", op.name());
    let mut b = ExprBuilder::new();
    let a_bits: Vec<ExprId> = (0..width).map(|i| b.leaf(i as usize)).collect();
    let b_bits: Vec<ExprId> = (0..width)
        .map(|i| b.constant((rhs >> i) & 1 == 1))
        .collect();
    let outs = binary_outputs(&mut b, op, &a_bits, &b_bits);
    b.build_multi(outs)
}

/// The masking program behind the filter-then-sum reduction: leaves
/// `0..width` are value bit-planes, leaf `width` is the predicate
/// mask; output `w` is `plane_w & mask`. One batch masks the whole
/// column.
pub fn mask_planes(width: u32) -> MultiExpr {
    assert!(width >= 1 && width <= MAX_WIDTH, "width {width} out of range");
    let mut b = ExprBuilder::new();
    let planes: Vec<ExprId> = (0..width).map(|i| b.leaf(i as usize)).collect();
    let m = b.leaf(width as usize);
    let outs: Vec<ExprId> = planes.iter().map(|&p| b.and(p, m)).collect();
    b.build_multi(outs)
}

fn binary_outputs(
    b: &mut ExprBuilder,
    op: ArithOp,
    a: &[ExprId],
    c: &[ExprId],
) -> Vec<ExprId> {
    match op {
        ArithOp::Add => ripple_add(b, a, c).0,
        ArithOp::Sub => ripple_sub(b, a, c).0,
        ArithOp::CmpLt => vec![ripple_sub(b, a, c).1],
        ArithOp::CmpEq => vec![equal(b, a, c)],
        ArithOp::Min => {
            let lt = ripple_sub(b, a, c).1; // a < c
            select(b, lt, a, c)
        }
        ArithOp::Max => {
            let lt = ripple_sub(b, a, c).1;
            select(b, lt, c, a)
        }
        ArithOp::Popcount => unreachable!("popcount is unary"),
    }
}

/// One full adder: `x + y + carry` → (sum, carry-out). The first
/// stage (no carry-in) is a half adder.
fn full_add(
    b: &mut ExprBuilder,
    x: ExprId,
    y: ExprId,
    carry: Option<ExprId>,
) -> (ExprId, ExprId) {
    let t = b.xor(x, y);
    match carry {
        None => (t, b.and(x, y)),
        Some(cin) => {
            let s = b.xor(t, cin);
            let g = b.and(x, y);
            let p = b.and(t, cin);
            (s, b.or(g, p))
        }
    }
}

/// W-bit ripple-carry addition, LSB first: (sum bits, carry-out).
pub fn ripple_add(
    b: &mut ExprBuilder,
    a: &[ExprId],
    c: &[ExprId],
) -> (Vec<ExprId>, ExprId) {
    assert!(!a.is_empty() && a.len() == c.len(), "operand width mismatch");
    let mut carry: Option<ExprId> = None;
    let mut sum = Vec::with_capacity(a.len());
    for (&x, &y) in a.iter().zip(c) {
        let (s, co) = full_add(b, x, y, carry);
        sum.push(s);
        carry = Some(co);
    }
    (sum, carry.expect("non-empty operands"))
}

/// One full subtractor: `x - y - borrow` → (diff, borrow-out).
/// Borrow-out is `(!x & y) | (!(x^y) & borrow)`, built with `AndNot`
/// so the optimizer's canonicalization keeps the NOT count minimal.
fn full_sub(
    b: &mut ExprBuilder,
    x: ExprId,
    y: ExprId,
    borrow: Option<ExprId>,
) -> (ExprId, ExprId) {
    let t = b.xor(x, y);
    match borrow {
        None => (t, b.and_not(y, x)),
        Some(br) => {
            let d = b.xor(t, br);
            let g = b.and_not(y, x); // y & !x
            let p = b.and_not(br, t); // br & !(x^y)
            (d, b.or(g, p))
        }
    }
}

/// W-bit borrow-chain subtraction, LSB first: (difference bits,
/// borrow-out). The borrow-out IS the unsigned `a < c` predicate.
pub fn ripple_sub(
    b: &mut ExprBuilder,
    a: &[ExprId],
    c: &[ExprId],
) -> (Vec<ExprId>, ExprId) {
    assert!(!a.is_empty() && a.len() == c.len(), "operand width mismatch");
    let mut borrow: Option<ExprId> = None;
    let mut diff = Vec::with_capacity(a.len());
    for (&x, &y) in a.iter().zip(c) {
        let (d, bo) = full_sub(b, x, y, borrow);
        diff.push(d);
        borrow = Some(bo);
    }
    (diff, borrow.expect("non-empty operands"))
}

/// `a == c`: AND over per-bit XNORs.
pub fn equal(b: &mut ExprBuilder, a: &[ExprId], c: &[ExprId]) -> ExprId {
    assert!(!a.is_empty() && a.len() == c.len(), "operand width mismatch");
    let xn: Vec<ExprId> = a
        .iter()
        .zip(c)
        .map(|(&x, &y)| {
            let t = b.xor(x, y);
            b.not(t)
        })
        .collect();
    b.all_and(&xn)
}

/// Bit-wise select: `m ? t : f` per lane — `(t & m) | (f & !m)`. The
/// `!m` is shared across every output bit by CSE.
pub fn select(
    b: &mut ExprBuilder,
    m: ExprId,
    t: &[ExprId],
    f: &[ExprId],
) -> Vec<ExprId> {
    assert_eq!(t.len(), f.len(), "select arm width mismatch");
    t.iter()
        .zip(f)
        .map(|(&x, &y)| {
            let p = b.and(x, m);
            let q = b.and_not(y, m);
            b.or(p, q)
        })
        .collect()
}

/// Widening addition of two little-endian bit numbers of possibly
/// different widths; the result carries one extra bit.
pub fn add_widen(b: &mut ExprBuilder, x: &[ExprId], y: &[ExprId]) -> Vec<ExprId> {
    let n = x.len().max(y.len());
    assert!(n >= 1, "empty addends");
    let mut out = Vec::with_capacity(n + 1);
    let mut carry: Option<ExprId> = None;
    for i in 0..n {
        let (s, co) = match (x.get(i).copied(), y.get(i).copied(), carry) {
            (Some(p), Some(q), c) => {
                let (s, co) = full_add(b, p, q, c);
                (s, Some(co))
            }
            (Some(p), None, Some(c)) | (None, Some(p), Some(c)) => {
                let s = b.xor(p, c);
                (s, Some(b.and(p, c)))
            }
            (Some(p), None, None) | (None, Some(p), None) => (p, None),
            (None, None, _) => unreachable!("i < max(len) has a bit"),
        };
        out.push(s);
        carry = co;
    }
    if let Some(c) = carry {
        out.push(c);
    }
    out
}

/// Per-element popcount: a balanced tree of widening adds over the W
/// input bits — the "tree reduction" lowered entirely onto the
/// substrate. Output width is [`popcount_width`].
pub fn popcount_tree(b: &mut ExprBuilder, bits: &[ExprId]) -> Vec<ExprId> {
    assert!(!bits.is_empty(), "popcount of nothing");
    let mut nums: Vec<Vec<ExprId>> = bits.iter().map(|&x| vec![x]).collect();
    while nums.len() > 1 {
        let mut next = Vec::with_capacity(nums.len().div_ceil(2));
        for pair in nums.chunks(2) {
            if let [x, y] = pair {
                next.push(add_widen(b, x, y));
            } else {
                next.push(pair[0].clone());
            }
        }
        nums = next;
    }
    nums.pop().expect("one number remains")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Evaluate `m` on one element packed into single-byte planes:
    /// plane `w`'s byte is 0xFF when bit `w` of the operand is set.
    /// Returns the outputs re-packed into a u64.
    fn eval_elem(m: &MultiExpr, inputs: &[u64], width: u32) -> u64 {
        let mut leaves: Vec<Vec<u8>> = Vec::new();
        for &v in inputs {
            for w in 0..width {
                leaves.push(vec![if (v >> w) & 1 == 1 { 0xFF } else { 0x00 }]);
            }
        }
        // pad to the leaf count the program expects (mask programs
        // append the predicate plane)
        while leaves.len() < m.n_leaves() {
            leaves.push(vec![0xFF]);
        }
        let refs: Vec<&[u8]> = leaves.iter().map(|v| v.as_slice()).collect();
        let outs = m.eval_bytes(&refs, 1).unwrap();
        let mut packed = 0u64;
        for (w, o) in outs.iter().enumerate() {
            assert!(o[0] == 0x00 || o[0] == 0xFF, "plane {w} not saturated");
            if o[0] == 0xFF {
                packed |= 1 << w;
            }
        }
        packed
    }

    #[test]
    fn popcount_width_matches_tree_shape() {
        assert_eq!(popcount_width(1), 1);
        assert_eq!(popcount_width(2), 2);
        assert_eq!(popcount_width(4), 3);
        assert_eq!(popcount_width(8), 4);
        assert_eq!(popcount_width(16), 5);
        // ragged widths may carry a provably-zero top bit
        assert!(popcount_width(3) >= 2);
        assert!(popcount_width(5) >= 3);
    }

    #[test]
    fn out_widths_are_consistent() {
        for op in ArithOp::ALL {
            for w in [1u32, 4, 8, 16] {
                let m = kernel(op, w);
                assert_eq!(
                    m.n_outputs() as u32,
                    op.out_width(w),
                    "{} width {w}",
                    op.name()
                );
            }
        }
    }

    #[test]
    fn kernels_match_numeric_reference_exhaustively_at_width_4() {
        for op in ArithOp::ALL {
            let m = kernel(op, 4);
            for a in 0u64..16 {
                if !op.is_binary() {
                    let got = eval_elem(&m, &[a], 4);
                    assert_eq!(
                        got,
                        reference(op, 4, a, 0),
                        "{}({a})",
                        op.name()
                    );
                    continue;
                }
                for c in 0u64..16 {
                    let got = eval_elem(&m, &[a, c], 4);
                    assert_eq!(
                        got,
                        reference(op, 4, a, c),
                        "{}({a}, {c})",
                        op.name()
                    );
                }
            }
        }
    }

    #[test]
    fn const_kernels_match_and_fold() {
        use crate::pud::compiler::compile_multi;
        for op in [ArithOp::CmpLt, ArithOp::CmpEq, ArithOp::Add] {
            for rhs in [0u64, 1, 7, 8, 15] {
                let m = kernel_const(op, 4, rhs);
                for a in 0u64..16 {
                    assert_eq!(
                        eval_elem(&m, &[a], 4),
                        reference(op, 4, a, rhs),
                        "{}({a}, const {rhs})",
                        op.name()
                    );
                }
            }
        }
        // constant folding must shrink the program vs the leaf kernel
        let free = compile_multi(&kernel(ArithOp::CmpLt, 8));
        let fixed = compile_multi(&kernel_const(ArithOp::CmpLt, 8, 128));
        assert!(
            fixed.stats.ops < free.stats.ops,
            "const threshold must fold ({} vs {})",
            fixed.stats.ops,
            free.stats.ops
        );
    }

    #[test]
    fn wider_kernels_match_on_random_operands() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(0xA217);
        for w in [8u32, 16] {
            for op in ArithOp::ALL {
                let m = kernel(op, w);
                for _ in 0..16 {
                    let a = rng.next_u64() & width_mask(w);
                    let c = rng.next_u64() & width_mask(w);
                    let got = if op.is_binary() {
                        eval_elem(&m, &[a, c], w)
                    } else {
                        eval_elem(&m, &[a], w)
                    };
                    assert_eq!(
                        got,
                        reference(op, w, a, c),
                        "{}({a}, {c}) at width {w}",
                        op.name()
                    );
                }
            }
        }
    }

    #[test]
    fn mask_planes_ands_every_plane() {
        let m = mask_planes(4);
        assert_eq!(m.n_outputs(), 4);
        assert_eq!(m.n_leaves(), 5);
        let planes: Vec<Vec<u8>> =
            vec![vec![0b1010], vec![0b1100], vec![0b1111], vec![0b0001]];
        let mask = vec![0b0110u8];
        let mut refs: Vec<&[u8]> = planes.iter().map(|v| v.as_slice()).collect();
        refs.push(&mask);
        let outs = m.eval_bytes(&refs, 1).unwrap();
        for (w, o) in outs.iter().enumerate() {
            assert_eq!(o[0], planes[w][0] & mask[0], "plane {w}");
        }
    }

    #[test]
    fn add_kernel_shares_one_carry_chain() {
        use crate::pud::compiler::compile_multi;
        let c = compile_multi(&kernel(ArithOp::Add, 8));
        // a naive per-output lowering would recompute the carry chain
        // per bit (O(W^2) gates); the shared DAG stays linear in W:
        // 5 gates per full adder, 2 for the half adder
        assert!(
            c.stats.ops <= 8 * 6,
            "add(8) must reuse the carry chain, got {} ops",
            c.stats.ops
        );
        assert_eq!(c.n_outputs(), 8);
    }
}
