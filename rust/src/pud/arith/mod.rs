//! `pud::arith` — bit-serial vertical arithmetic on the Ambit
//! substrate (DESIGN.md §10).
//!
//! The Boolean compiler (PR 3) lifted the substrate from single bulk
//! ops to whole predicate expressions; this layer lifts it from
//! single-bit predicates to multi-bit integers, the composition
//! MIMDRAM and Proteus build their analytics kernels on:
//!
//! * [`layout`] — [`VerticalLayout`]: W-bit integers transposed into W
//!   bit-plane rows, allocated through `pim_alloc_align` hints so all
//!   operand planes co-locate in one subarray.
//! * [`kernels`] — ripple-carry [`ArithOp::Add`]/[`ArithOp::Sub`],
//!   predicate [`ArithOp::CmpLt`]/[`ArithOp::CmpEq`] (mask outputs
//!   usable by `workloads::filter`), select-based
//!   [`ArithOp::Min`]/[`ArithOp::Max`], and the widening adder-tree
//!   [`ArithOp::Popcount`] — each expanded into the compiler's `Expr`
//!   DAG (a full adder is XOR/AND/OR over per-bit leaves) and frozen
//!   as a multi-output [`MultiExpr`](crate::pud::compiler::MultiExpr),
//!   so CSE (one shared carry chain), scratch register allocation, and
//!   single-`submit_batch` emission come for free.
//! * [`colcache`] — [`ColumnCache`]: columns stay resident in
//!   transposed form across kernels and sweep cells (transpose once,
//!   query many), with version/epoch invalidation and an LRU budget.
//! * [`column`] — [`Column`]: the layout-polymorphic handle (flat or
//!   sharded) the PR-9 unified `System` surface operates on, placed
//!   once via [`LayoutSpec`].
//!
//! Execution goes through the unified
//! [`System::arith`](crate::coordinator::system::System::arith)
//! (and `arith_const`/`column_sum`), which accept a [`Column`] of
//! either layout; `workloads::analytics` runs the filter-then-sum
//! aggregate on top and `puma analytics` reports it.

pub mod colcache;
pub mod column;
pub mod kernels;
pub mod layout;
pub mod shard;

pub use column::{Column, LayoutSpec};

pub use colcache::{
    ColumnCache, ColumnCacheStats, ColumnKey, ResidentColumn,
    DEFAULT_COLUMN_BUDGET,
};
pub use kernels::{
    kernel, kernel_const, mask_planes, popcount_width, reference, width_mask,
    ArithOp, MAX_WIDTH,
};
pub use layout::{
    plane_bytes, popcount_live, transpose, transpose_naive, untranspose,
    untranspose_naive, VerticalLayout,
};
pub use shard::{shard_sizes, ShardedLayout, ShardedScratch};

use std::sync::Arc;

use rustc_hash::FxHashMap;

use crate::dram::energy::EnergyParams;
use crate::dram::timing::TimingParams;
use crate::pud::compiler::{compile_multi, CompiledMulti};
use crate::pud::isa::{batch_cost, BatchCost};

/// Compile the `op` kernel for `width`-bit operands (compile once,
/// bind and execute per column).
pub fn compile_kernel(op: ArithOp, width: u32) -> CompiledMulti {
    compile_multi(&kernel(op, width))
}

/// Key of one cached compiled program (see [`ProgramCache`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProgramKey {
    /// The two-operand/unary `(op, width)` kernel.
    Kernel(ArithOp, u32),
    /// `(op, width)` with operand `b` folded to a constant (the rhs is
    /// stored pre-masked to `width` bits so equivalent thresholds share
    /// one entry).
    KernelConst(ArithOp, u32, u64),
    /// The filter-then-sum plane-masking program for `width` planes.
    MaskPlanes(u32),
}

/// Cumulative [`ProgramCache`] counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProgramCacheStats {
    /// Lookups served from the cache (zero compile work).
    pub hits: u64,
    /// Lookups that compiled and inserted a fresh program.
    pub misses: u64,
}

/// The `(ArithOp, width)` compiled-program cache. `System` owns one so
/// every arithmetic entry point — sharded or not — compiles each
/// kernel exactly once and binds it per column/shard thereafter
/// (`run_arith`/`arith_sum` used to rebuild and re-optimize the full
/// adder DAG on every invocation).
#[derive(Default)]
pub struct ProgramCache {
    programs: FxHashMap<ProgramKey, Arc<CompiledMulti>>,
    pub stats: ProgramCacheStats,
}

impl ProgramCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch `key`'s program, compiling on first use. The second
    /// element is `true` when the program came from the cache —
    /// callers zero `CompileStats::compiles` in their reports with it.
    pub fn get_or_compile(&mut self, key: ProgramKey) -> (Arc<CompiledMulti>, bool) {
        if let Some(p) = self.programs.get(&key) {
            self.stats.hits += 1;
            return (p.clone(), true);
        }
        self.stats.misses += 1;
        let program = match key {
            ProgramKey::Kernel(op, w) => kernel(op, w),
            ProgramKey::KernelConst(op, w, rhs) => kernel_const(op, w, rhs),
            ProgramKey::MaskPlanes(w) => mask_planes(w),
        };
        let compiled = Arc::new(compile_multi(&program));
        self.programs.insert(key, compiled.clone());
        (compiled, false)
    }

    /// Distinct programs cached.
    pub fn len(&self) -> usize {
        self.programs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.programs.is_empty()
    }

    /// Drop every cached program (counters are kept). The release
    /// valve for long-lived systems sweeping many *distinct*
    /// `KernelConst` thresholds — each distinct `(op, width, rhs)`
    /// retains a compiled DAG until cleared.
    pub fn clear(&mut self) {
        self.programs.clear();
    }
}

/// Analytic in-DRAM cost of one fully-PUD execution of the `op`
/// kernel over planes of `plane_len` bytes — the W-bit op-cost
/// accounting (`pud::isa::batch_cost`) the reports print next to
/// throughput. Binds the compiled program to synthetic addresses;
/// costs depend only on ops and lengths, not placement.
pub fn kernel_cost(
    op: ArithOp,
    width: u32,
    plane_len: u64,
    row_bytes: u64,
    t: &TimingParams,
    e: &EnergyParams,
) -> BatchCost {
    let c = compile_kernel(op, width);
    let step = plane_len.max(1);
    let base = 0x1000_0000u64;
    let operands: Vec<u64> =
        (0..c.n_leaves() as u64).map(|i| base + i * step).collect();
    let dsts: Vec<u64> = (0..c.n_outputs() as u64)
        .map(|i| base + (0x1000 + i) * step)
        .collect();
    let scratch: Vec<u64> = (0..c.scratch_needed() as u64)
        .map(|i| base + (0x2000 + i) * step)
        .collect();
    let reqs = c
        .emit(&operands, &dsts, plane_len, &scratch)
        .expect("synthetic binding is well-formed");
    batch_cost(&reqs, row_bytes, t, e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_cost_scales_with_width() {
        let t = TimingParams::default();
        let e = EnergyParams::default();
        let row = 8192u64;
        let c8 = kernel_cost(ArithOp::Add, 8, row, row, &t, &e);
        let c16 = kernel_cost(ArithOp::Add, 16, row, row, &t, &e);
        assert!(c8.aaps > 0 && c8.tras > 0);
        // ripple-carry adds are linear in W: twice the width costs
        // roughly (not exactly: one half adder amortizes) twice the AAPs
        assert!(c16.aaps > c8.aaps && c16.aaps < 3 * c8.aaps);
        assert!(c16.pud_ns > c8.pud_ns);
        // partial-row planes still price the full row
        let tail = kernel_cost(ArithOp::Add, 8, row + 1, row, &t, &e);
        assert_eq!(tail.rows, 2 * c8.rows);
    }

    #[test]
    fn compile_kernel_matches_kernel_shape() {
        for op in ArithOp::ALL {
            let c = compile_kernel(op, 8);
            assert_eq!(c.n_outputs() as u32, op.out_width(8), "{}", op.name());
        }
    }

    #[test]
    fn program_cache_compiles_once_per_key() {
        let mut cache = ProgramCache::new();
        let (a, hit) = cache.get_or_compile(ProgramKey::Kernel(ArithOp::Add, 8));
        assert!(!hit);
        assert_eq!(a.stats.compiles, 1, "fresh compile reports work");
        let (b, hit) = cache.get_or_compile(ProgramKey::Kernel(ArithOp::Add, 8));
        assert!(hit, "second lookup is a hit");
        assert!(Arc::ptr_eq(&a, &b), "the very same program is served");
        assert_eq!(cache.stats.hits, 1);
        assert_eq!(cache.stats.misses, 1);
        // distinct widths, ops, and const folds are distinct programs
        cache.get_or_compile(ProgramKey::Kernel(ArithOp::Add, 16));
        cache.get_or_compile(ProgramKey::Kernel(ArithOp::Sub, 8));
        cache.get_or_compile(ProgramKey::KernelConst(ArithOp::CmpLt, 8, 128));
        cache.get_or_compile(ProgramKey::MaskPlanes(8));
        assert_eq!(cache.len(), 5);
        assert_eq!(cache.stats.misses, 5);
    }
}
