//! Resident-column cache: transpose once, query many (DESIGN.md §12).
//!
//! The host/PIM boundary of every vertical workload is the same three
//! steps — transpose the column into bit-planes, allocate W plane
//! rows, store them — and before this cache every kernel invocation
//! and every sweep cell paid all three from scratch. A [`ColumnCache`]
//! makes columns *resident* at two levels:
//!
//! * **Host images**: the transposed byte planes of a column id,
//!   shared across layouts. The sharded sweep's S=1..16 cells all
//!   slice one image (shard boundaries are byte-aligned whenever the
//!   chunk size is a multiple of 8) instead of re-transposing the
//!   million-element column per shard count.
//! * **Resident layouts**: the allocated-and-stored
//!   [`VerticalLayout`]/[`ShardedLayout`] itself, keyed by
//!   `(id, allocator, pid, shard count)`. A repeat query against the
//!   same column — the second kernel of a filter-then-sum cell, a
//!   warm sweep pass — reuses the planes already sitting in DRAM:
//!   zero transpose, zero allocation, zero store traffic.
//!
//! Invalidation rules: an entry is served only while its caller-
//! declared content `version` and its process's `translation_epoch`
//! both still match (a bumped version means new data; a bumped epoch
//! means mappings changed under the layout). [`ColumnCache::invalidate`]
//! force-dirties an id after an in-place store. Residency is bounded
//! by a column budget; insertion evicts least-recently-used entries
//! of the same allocator/process (only their owner can free their
//! planes).
//!
//! The cache itself is pure bookkeeping — `System::column` (the
//! unified, layout-polymorphic entry point) orchestrates allocation,
//! stores, and the freeing of stale or evicted layouts.

use std::sync::Arc;

use rustc_hash::FxHashMap;

use crate::os::process::Pid;

use super::layout::VerticalLayout;
use super::shard::ShardedLayout;

/// Default [`ColumnCache`] residency budget (columns, flat or
/// sharded). Sized for a sweep's per-width working set (one flat
/// column plus a handful of shard variants) with headroom.
pub const DEFAULT_COLUMN_BUDGET: usize = 8;

/// Key of one resident column layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ColumnKey {
    /// Caller-chosen stable column id.
    pub id: u64,
    /// Owning allocator ([`crate::alloc::traits::Allocator::name`]):
    /// placement belongs to the allocator that produced it, and only
    /// that allocator can free the planes.
    pub owner: &'static str,
    /// Owning process.
    pub pid: Pid,
    /// Shard count of the layout (0 = unsharded flat layout).
    pub shards: u32,
}

/// A resident layout handle (clones are cheap: plane VAs only).
#[derive(Debug, Clone)]
pub enum ResidentColumn {
    Flat(VerticalLayout),
    Sharded(ShardedLayout),
}

#[derive(Debug)]
struct Resident {
    version: u64,
    epoch: u64,
    width: u32,
    elems: usize,
    dirty: bool,
    lru: u64,
    layout: ResidentColumn,
}

/// One cached host image: the transposed planes of a column id.
#[derive(Debug)]
struct HostImage {
    version: u64,
    width: u32,
    elems: usize,
    planes: Arc<Vec<Vec<u8>>>,
}

/// Cumulative [`ColumnCache`] counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ColumnCacheStats {
    /// Host images served from the cache (transposes avoided).
    pub host_hits: u64,
    /// Host images built fresh (a transpose ran).
    pub host_misses: u64,
    /// Resident layouts served from the cache (alloc + store avoided).
    pub resident_hits: u64,
    /// Lookups that had to build a layout.
    pub resident_misses: u64,
    /// Entries dropped for a version/epoch/shape change or an explicit
    /// [`ColumnCache::invalidate`].
    pub invalidations: u64,
    /// Entries dropped to stay within the residency budget.
    pub evictions: u64,
}

/// Outcome of a resident-layout lookup.
pub enum Lookup {
    /// Valid entry — use the handle as-is.
    Hit(ResidentColumn),
    /// The entry existed but its version/epoch/shape no longer match;
    /// it has been removed and the caller must free its planes.
    Stale(ResidentColumn),
    Miss,
}

/// The two-level column cache. Owned by
/// [`System`](crate::coordinator::system::System); see the module docs.
#[derive(Default)]
pub struct ColumnCache {
    images: FxHashMap<u64, HostImage>,
    resident: FxHashMap<ColumnKey, Resident>,
    tick: u64,
    budget: Option<usize>,
    pub stats: ColumnCacheStats,
}

impl ColumnCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resident-column budget (defaults to [`DEFAULT_COLUMN_BUDGET`]).
    pub fn budget(&self) -> usize {
        self.budget.unwrap_or(DEFAULT_COLUMN_BUDGET)
    }

    pub fn set_budget(&mut self, columns: usize) {
        self.budget = Some(columns.max(1));
    }

    /// Resident layouts currently cached.
    pub fn len(&self) -> usize {
        self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    /// Host images currently cached.
    pub fn n_images(&self) -> usize {
        self.images.len()
    }

    /// The host image for `(id, version)` with the given shape, if
    /// cached. A hit avoids a full column transpose.
    pub fn image(
        &mut self,
        id: u64,
        version: u64,
        width: u32,
        elems: usize,
    ) -> Option<Arc<Vec<Vec<u8>>>> {
        match self.images.get(&id) {
            Some(img)
                if img.version == version
                    && img.width == width
                    && img.elems == elems =>
            {
                self.stats.host_hits += 1;
                Some(img.planes.clone())
            }
            _ => None,
        }
    }

    /// Insert (or replace) the host image of `id`.
    pub fn insert_image(
        &mut self,
        id: u64,
        version: u64,
        width: u32,
        elems: usize,
        planes: Arc<Vec<Vec<u8>>>,
    ) {
        self.stats.host_misses += 1;
        self.images.insert(
            id,
            HostImage {
                version,
                width,
                elems,
                planes,
            },
        );
    }

    /// Look up the resident layout for `key`, validating against the
    /// caller's current content version, translation epoch, and shape.
    /// A stale entry is removed and handed back so the caller can free
    /// its planes.
    pub fn lookup(
        &mut self,
        key: ColumnKey,
        version: u64,
        epoch: u64,
        width: u32,
        elems: usize,
    ) -> Lookup {
        let valid = match self.resident.get(&key) {
            None => {
                self.stats.resident_misses += 1;
                return Lookup::Miss;
            }
            Some(r) => {
                !r.dirty
                    && r.version == version
                    && r.epoch == epoch
                    && r.width == width
                    && r.elems == elems
            }
        };
        if valid {
            self.stats.resident_hits += 1;
            self.tick += 1;
            let r = self.resident.get_mut(&key).expect("checked above");
            r.lru = self.tick;
            Lookup::Hit(r.layout.clone())
        } else {
            self.stats.resident_misses += 1;
            self.stats.invalidations += 1;
            let r = self.resident.remove(&key).expect("checked above");
            Lookup::Stale(r.layout)
        }
    }

    /// Insert a freshly built layout for `key`.
    pub fn insert(
        &mut self,
        key: ColumnKey,
        version: u64,
        epoch: u64,
        width: u32,
        elems: usize,
        layout: ResidentColumn,
    ) {
        self.tick += 1;
        self.resident.insert(
            key,
            Resident {
                version,
                epoch,
                width,
                elems,
                dirty: false,
                lru: self.tick,
                layout,
            },
        );
    }

    /// Pop least-recently-used entries owned by `(owner, pid)` until
    /// the resident count has room for one more insertion within the
    /// budget. Returned layouts must be freed by the caller (through
    /// `owner`'s allocator). Entries of other owners are never touched
    /// — only their allocator can free them — so the cache can
    /// transiently exceed its budget in multi-allocator use.
    pub fn evict_for_insert(
        &mut self,
        owner: &'static str,
        pid: Pid,
    ) -> Vec<ResidentColumn> {
        let mut out = Vec::new();
        while self.resident.len() >= self.budget() {
            let victim = self
                .resident
                .iter()
                .filter(|(k, _)| k.owner == owner && k.pid == pid)
                .min_by_key(|(_, r)| r.lru)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    let r = self.resident.remove(&k).expect("chosen above");
                    self.stats.evictions += 1;
                    out.push(r.layout);
                }
                None => break,
            }
        }
        out
    }

    /// Remove every resident layout owned by `(owner, pid)` — the
    /// teardown path before an allocator retires. The caller frees
    /// the returned layouts.
    pub fn drain_owned(
        &mut self,
        owner: &'static str,
        pid: Pid,
    ) -> Vec<ResidentColumn> {
        let keys: Vec<ColumnKey> = self
            .resident
            .keys()
            .filter(|k| k.owner == owner && k.pid == pid)
            .copied()
            .collect();
        keys.iter()
            .map(|k| self.resident.remove(k).expect("listed above").layout)
            .collect()
    }

    /// Force-dirty every entry of `id` and drop its host image: the
    /// hook for an in-place store to a cached column. The next lookup
    /// reports the entries stale (never serving the old planes) and
    /// rebuilds.
    pub fn invalidate(&mut self, id: u64) {
        self.images.remove(&id);
        for (k, r) in self.resident.iter_mut() {
            if k.id == id {
                r.dirty = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(id: u64, shards: u32) -> ColumnKey {
        ColumnKey {
            id,
            owner: "puma",
            pid: Pid(1),
            shards,
        }
    }

    fn layout() -> ResidentColumn {
        // a synthetic handle is enough for bookkeeping tests
        ResidentColumn::Flat(VerticalLayout::synthetic(4, 16, &[1, 2, 3, 4]))
    }

    #[test]
    fn lookup_validates_version_epoch_and_shape() {
        let mut c = ColumnCache::new();
        assert!(matches!(c.lookup(key(1, 0), 0, 0, 4, 16), Lookup::Miss));
        c.insert(key(1, 0), 0, 0, 4, 16, layout());
        assert!(matches!(c.lookup(key(1, 0), 0, 0, 4, 16), Lookup::Hit(_)));
        assert_eq!(c.stats.resident_hits, 1);
        // a bumped version must not serve the stale entry
        assert!(matches!(c.lookup(key(1, 0), 1, 0, 4, 16), Lookup::Stale(_)));
        assert_eq!(c.stats.invalidations, 1);
        assert!(c.is_empty(), "the stale entry is gone");
        // epoch and shape changes likewise
        c.insert(key(1, 0), 1, 0, 4, 16, layout());
        assert!(matches!(c.lookup(key(1, 0), 1, 7, 4, 16), Lookup::Stale(_)));
        c.insert(key(1, 0), 1, 0, 4, 16, layout());
        assert!(matches!(c.lookup(key(1, 0), 1, 0, 8, 16), Lookup::Stale(_)));
    }

    #[test]
    fn invalidate_dirties_entries_and_drops_the_image() {
        let mut c = ColumnCache::new();
        c.insert_image(7, 0, 4, 16, Arc::new(vec![vec![0u8; 2]; 4]));
        assert!(c.image(7, 0, 4, 16).is_some());
        assert_eq!(c.stats.host_hits, 1);
        c.insert(key(7, 0), 0, 0, 4, 16, layout());
        c.invalidate(7);
        assert!(c.image(7, 0, 4, 16).is_none(), "image dropped");
        assert!(
            matches!(c.lookup(key(7, 0), 0, 0, 4, 16), Lookup::Stale(_)),
            "a dirtied entry must never serve"
        );
    }

    #[test]
    fn eviction_is_lru_and_owner_scoped() {
        let mut c = ColumnCache::new();
        c.set_budget(2);
        c.insert(key(1, 0), 0, 0, 4, 16, layout());
        c.insert(key(2, 0), 0, 0, 4, 16, layout());
        // touch 1 so 2 is the LRU
        assert!(matches!(c.lookup(key(1, 0), 0, 0, 4, 16), Lookup::Hit(_)));
        let evicted = c.evict_for_insert("puma", Pid(1));
        assert_eq!(evicted.len(), 1);
        assert_eq!(c.stats.evictions, 1);
        assert!(matches!(c.lookup(key(1, 0), 0, 0, 4, 16), Lookup::Hit(_)));
        assert!(
            matches!(c.lookup(key(2, 0), 0, 0, 4, 16), Lookup::Miss),
            "the LRU entry was the one evicted"
        );
        // another owner's entries are not evictable from this path
        let mut c = ColumnCache::new();
        c.set_budget(1);
        c.insert(
            ColumnKey {
                id: 1,
                owner: "malloc",
                pid: Pid(1),
                shards: 0,
            },
            0,
            0,
            4,
            16,
            layout(),
        );
        assert!(c.evict_for_insert("puma", Pid(1)).is_empty());
        assert_eq!(c.len(), 1, "over budget rather than cross-owner free");
    }

    #[test]
    fn drain_owned_scopes_to_owner_and_pid() {
        let mut c = ColumnCache::new();
        c.insert(key(1, 0), 0, 0, 4, 16, layout());
        c.insert(key(1, 4), 0, 0, 4, 16, layout());
        c.insert(
            ColumnKey {
                id: 1,
                owner: "malloc",
                pid: Pid(1),
                shards: 0,
            },
            0,
            0,
            4,
            16,
            layout(),
        );
        assert_eq!(c.drain_owned("puma", Pid(1)).len(), 2);
        assert_eq!(c.len(), 1);
    }
}
