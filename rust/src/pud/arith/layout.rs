//! Vertical (bit-transposed) data layout: W-bit integers stored as W
//! bit-plane rows.
//!
//! Bit-serial PUD arithmetic operates on *bit-planes*: plane `w` holds
//! bit `w` of every element, so one bulk AND over two planes processes
//! the whole column's bit `w` in a single command sequence. A
//! [`VerticalLayout`] owns the W plane buffers of one column,
//! allocated through the normal allocator interface with
//! `pim_alloc_align` hints so all planes of all operands co-locate in
//! one subarray — exactly the placement the PUMA allocator exists to
//! produce and the baselines cannot.
//!
//! The bit convention matches `workloads::filter`'s bitmaps: element
//! `i` lives at byte `i / 8`, bit `i % 8` (LSB first) of each plane.
//! [`transpose`] / [`untranspose`] are pure functions so property
//! tests can round-trip them without booting a system.
//!
//! Both directions run a *blocked* bit-matrix transpose: eight
//! consecutive elements × eight consecutive bit positions form an
//! 8×8 bit tile packed into one `u64` (byte `j` = element `j` of the
//! octet), flipped branch-free by [`transpose8x8`] — the classic
//! three-stage masked-swap network (Hacker's Delight §7-3) — and
//! scattered to one byte per destination plane. The bit-at-a-time
//! originals survive as [`transpose_naive`] / [`untranspose_naive`],
//! the oracles the property tests and the host-boundary bench compare
//! against.

use anyhow::{ensure, Result};

use crate::alloc::traits::Allocator;
use crate::coordinator::system::System;
use crate::os::process::Pid;

use super::kernels::width_mask;

/// Transpose the 8×8 bit matrix packed in `x` (row `r` = byte `r`
/// LSB-first, column `c` = bit `c` of that byte): output bit
/// `8r + c` = input bit `8c + r`. Three masked swap stages exchange
/// 1×1 sub-blocks within 2×2, 2×2 within 4×4, then 4×4 within 8×8 —
/// an involution, so the same kernel serves both directions.
#[inline]
fn transpose8x8(mut x: u64) -> u64 {
    let mut t = (x ^ (x >> 7)) & 0x00AA_00AA_00AA_00AA;
    x ^= t ^ (t << 7);
    t = (x ^ (x >> 14)) & 0x0000_CCCC_0000_CCCC;
    x ^= t ^ (t << 14);
    t = (x ^ (x >> 28)) & 0x0000_0000_F0F0_F0F0;
    x ^= t ^ (t << 28);
    x
}

/// Transpose `values` (each at most `width` bits) into `width`
/// bit-plane byte buffers, LSB plane first.
///
/// Blocked fast path: per octet of elements and per group of eight
/// planes, pack byte `j` = bits `[w0, w0+8)` of element `j`, flip the
/// tile with [`transpose8x8`], and byte `r` of the result is plane
/// `w0 + r`'s byte for this octet. Tail octets are zero-padded and
/// plane groups past `width` are dropped, so the output is
/// byte-identical to [`transpose_naive`].
pub fn transpose(values: &[u64], width: u32) -> Vec<Vec<u8>> {
    let width = width as usize;
    let plane_len = plane_bytes(values.len()) as usize;
    let mut planes = vec![vec![0u8; plane_len]; width];
    for o in 0..plane_len {
        let base = o * 8;
        let n = (values.len() - base).min(8);
        let octet = &values[base..base + n];
        let mut w0 = 0;
        while w0 < width {
            let mut x = 0u64;
            for (j, &v) in octet.iter().enumerate() {
                x |= ((v >> w0) & 0xFF) << (8 * j);
            }
            let x = transpose8x8(x);
            let take = (width - w0).min(8);
            for (r, plane) in planes[w0..w0 + take].iter_mut().enumerate() {
                plane[o] = (x >> (8 * r)) as u8;
            }
            w0 += 8;
        }
    }
    planes
}

/// Inverse of [`transpose`]: rebuild `elems` values from bit-planes
/// (`planes[w]` is bit `w`). Plane bytes — and final-byte bits — past
/// `elems` are ignored.
///
/// Errors (instead of indexing out of bounds, as the bit-at-a-time
/// version did) when any plane is shorter than the `ceil(elems / 8)`
/// bytes the element count requires, or when more than 64 planes are
/// given (bit positions past 63 don't fit a `u64`).
pub fn untranspose(planes: &[Vec<u8>], elems: usize) -> Result<Vec<u64>> {
    ensure!(
        planes.len() <= 64,
        "{} bit-planes exceed a u64's 64 bit positions",
        planes.len()
    );
    let need = plane_bytes(elems) as usize;
    for (w, plane) in planes.iter().enumerate() {
        ensure!(
            plane.len() >= need,
            "plane {w} holds {} byte(s) but {elems} element(s) need {need}",
            plane.len()
        );
    }
    let mut values = vec![0u64; elems];
    let mut w0 = 0;
    while w0 < planes.len() {
        let group = &planes[w0..(w0 + 8).min(planes.len())];
        for o in 0..need {
            let mut x = 0u64;
            for (r, plane) in group.iter().enumerate() {
                x |= (plane[o] as u64) << (8 * r);
            }
            let x = transpose8x8(x);
            let base = o * 8;
            for (j, v) in values[base..elems.min(base + 8)]
                .iter_mut()
                .enumerate()
            {
                *v |= ((x >> (8 * j)) & 0xFF) << w0;
            }
        }
        w0 += 8;
    }
    Ok(values)
}

/// Bit-at-a-time reference transpose — the pre-blocking
/// implementation, kept as the oracle the property tests and the
/// host-boundary bench measure [`transpose`] against.
pub fn transpose_naive(values: &[u64], width: u32) -> Vec<Vec<u8>> {
    let len = plane_bytes(values.len()) as usize;
    let mut planes = vec![vec![0u8; len]; width as usize];
    for (i, &v) in values.iter().enumerate() {
        for (w, plane) in planes.iter_mut().enumerate() {
            if (v >> w) & 1 == 1 {
                plane[i / 8] |= 1 << (i % 8);
            }
        }
    }
    planes
}

/// Bit-at-a-time reference untranspose, the oracle for
/// [`untranspose`]. Assumes in-bounds planes (the blocked path is the
/// one that validates).
pub fn untranspose_naive(planes: &[Vec<u8>], elems: usize) -> Vec<u64> {
    let mut values = vec![0u64; elems];
    for (w, plane) in planes.iter().enumerate() {
        for (i, v) in values.iter_mut().enumerate() {
            if (plane[i / 8] >> (i % 8)) & 1 == 1 {
                *v |= 1 << w;
            }
        }
    }
    values
}

/// Bytes one bit-plane of an `elems`-element column occupies:
/// `ceil(elems / 8)`. Every plane readback, bitmap allocation, and
/// mask-row length in the tree must use this helper instead of
/// re-deriving the expression inline — the PR-5 popcount bug came from
/// one call site rounding differently from the rest.
pub fn plane_bytes(elems: usize) -> u64 {
    elems.div_ceil(8) as u64
}

/// Set bits among the first `elems` bit positions of `bits` — a
/// padding-safe popcount (padding-lane bits can be set by kernels
/// whose padding-lane inputs are all-zero, e.g. `0 < T`).
///
/// The buffer may be arbitrarily longer than `ceil(elems / 8)`: a
/// plane read back at full row length (or a ragged shard bound to a
/// uniform-length scratch slot) carries whole trailing pad *bytes* on
/// top of the final byte's pad bits, and every one of them is ignored.
/// (A previous version only masked the final byte and underflowed the
/// shift for `pad >= 8`, miscounting — or debug-panicking on — any
/// row-padded buffer.)
pub fn popcount_live(bits: &[u8], elems: usize) -> u64 {
    // whole live bytes, clamped to the buffer
    let full = (elems / 8).min(bits.len());
    let mut total: u64 = bits[..full].iter().map(|b| b.count_ones() as u64).sum();
    // partial live byte: keep only the low `elems % 8` bits
    if elems % 8 != 0 && full < bits.len() {
        let keep = (1u8 << (elems % 8)) - 1;
        total += (bits[full] & keep).count_ones() as u64;
    }
    total
}

/// A column of `elems` `width`-bit integers stored as `width` bit-plane
/// buffers of `plane_len` bytes each.
///
/// `Clone` is cheap (plane VAs only, no data) so the `ColumnCache`
/// can hand out handles to resident columns without borrowing the
/// [`System`] that owns the cache.
#[derive(Debug, Clone)]
pub struct VerticalLayout {
    width: u32,
    elems: usize,
    plane_len: u64,
    planes: Vec<u64>,
}

impl VerticalLayout {
    /// Bytes per plane of an `elems`-element column, after validating
    /// the shape — the shared prologue of the constructors.
    fn checked_plane_len(width: u32, elems: usize) -> Result<u64> {
        ensure!((1..=64).contains(&width), "width {width} out of range");
        ensure!(elems > 0, "empty column");
        Ok(plane_bytes(elems))
    }

    /// Chain `width - 1` further planes hint-aligned to the
    /// already-placed anchor plane `first` and assemble the layout —
    /// the shared body of [`VerticalLayout::alloc`] and
    /// [`VerticalLayout::alloc_spread`].
    fn chain_to_anchor(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
        plane_len: u64,
        first: u64,
    ) -> Result<Self> {
        let mut planes = vec![first];
        for _ in 1..width {
            planes.push(sys.alloc_align(alloc, pid, plane_len, first)?);
        }
        Ok(Self {
            width,
            elems,
            plane_len,
            planes,
        })
    }

    /// Allocate the planes with `alloc`: the first through the plain
    /// path, the rest hint-aligned to it (the paper's `pim_alloc` /
    /// `pim_alloc_align` protocol; baselines ignore the hint).
    pub fn alloc(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
    ) -> Result<Self> {
        let plane_len = Self::checked_plane_len(width, elems)?;
        let first = sys.alloc(alloc, pid, plane_len)?;
        Self::chain_to_anchor(sys, alloc, pid, width, elems, plane_len, first)
    }

    /// Allocate with the first plane placed through the allocator's
    /// placement-spread path (`Allocator::alloc_spread`, PUMA's
    /// bank-targeted draw) and the rest hint-aligned to it: shard
    /// `spread` of a sharded column lands on bank `spread % banks`
    /// under PUMA, so sibling shards execute on disjoint bank command
    /// timelines (baselines ignore the spread exactly as they ignore
    /// hints).
    pub fn alloc_spread(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
        spread: u32,
    ) -> Result<Self> {
        let plane_len = Self::checked_plane_len(width, elems)?;
        let first = sys.alloc_spread(alloc, pid, plane_len, spread)?;
        Self::chain_to_anchor(sys, alloc, pid, width, elems, plane_len, first)
    }

    /// Allocate with every plane hint-aligned to `hint` — used for the
    /// second operand and the destination so the whole kernel lands in
    /// the first operand's subarray.
    pub fn alloc_with_hint(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
        hint: u64,
    ) -> Result<Self> {
        let plane_len = Self::checked_plane_len(width, elems)?;
        let mut planes = Vec::with_capacity(width as usize);
        for _ in 0..width {
            planes.push(sys.alloc_align(alloc, pid, plane_len, hint)?);
        }
        Ok(Self {
            width,
            elems,
            plane_len,
            planes,
        })
    }

    /// Test-only handle with caller-chosen plane VAs (no allocation) —
    /// for exercising cache bookkeeping without booting a system.
    #[cfg(test)]
    pub(crate) fn synthetic(width: u32, elems: usize, planes: &[u64]) -> Self {
        Self {
            width,
            elems,
            plane_len: plane_bytes(elems),
            planes: planes.to_vec(),
        }
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    pub fn elems(&self) -> usize {
        self.elems
    }

    /// Bytes per plane buffer.
    pub fn plane_len(&self) -> u64 {
        self.plane_len
    }

    /// Plane VAs, LSB plane first.
    pub fn planes(&self) -> &[u64] {
        &self.planes
    }

    /// The co-location hint for further allocations (the first plane).
    pub fn hint(&self) -> u64 {
        self.planes[0]
    }

    /// Transpose `values` into the planes through the process's
    /// virtual mappings. Every value must fit in `width` bits.
    pub fn store(&self, sys: &mut System, pid: Pid, values: &[u64]) -> Result<()> {
        ensure!(
            values.len() == self.elems,
            "store of {} value(s) into a {}-element column",
            values.len(),
            self.elems
        );
        let mask = width_mask(self.width);
        for (i, v) in values.iter().enumerate() {
            ensure!(
                (v & !mask) == 0,
                "value {v:#x} at index {i} exceeds {} bits",
                self.width
            );
        }
        for (plane, bytes) in
            self.planes.iter().zip(transpose(values, self.width))
        {
            sys.write_virt(pid, *plane, &bytes)?;
        }
        Ok(())
    }

    /// Write already-transposed plane bytes directly (the column
    /// cache's fast path: transpose once on the host, store the same
    /// image into any number of resident layouts without re-running
    /// the transpose). `bytes[w]` must be exactly `plane_len` bytes.
    pub fn store_planes(
        &self,
        sys: &mut System,
        pid: Pid,
        bytes: &[Vec<u8>],
    ) -> Result<()> {
        ensure!(
            bytes.len() == self.width as usize,
            "{} plane buffer(s) for a {}-bit column",
            bytes.len(),
            self.width
        );
        for (w, b) in bytes.iter().enumerate() {
            ensure!(
                b.len() as u64 == self.plane_len,
                "plane {w} is {} byte(s), layout wants {}",
                b.len(),
                self.plane_len
            );
        }
        for (plane, b) in self.planes.iter().zip(bytes) {
            sys.write_virt(pid, *plane, b)?;
        }
        Ok(())
    }

    /// Read the planes back and untranspose into values.
    pub fn load(&self, sys: &mut System, pid: Pid) -> Result<Vec<u64>> {
        let mut planes = Vec::with_capacity(self.planes.len());
        for &va in &self.planes {
            planes.push(sys.read_virt(pid, va, self.plane_len)?);
        }
        untranspose(&planes, self.elems)
    }

    /// Return every plane to `alloc`.
    pub fn free(
        &self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
    ) -> Result<()> {
        for &va in &self.planes {
            sys.free(alloc, pid, va)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrips() {
        let values: Vec<u64> = (0..100).map(|i| (i * 37) % 256).collect();
        let planes = transpose(&values, 8);
        assert_eq!(planes.len(), 8);
        assert_eq!(planes[0].len(), 13); // ceil(100 / 8)
        assert_eq!(untranspose(&planes, 100).unwrap(), values);
    }

    #[test]
    fn blocked_matches_naive_oracles() {
        // ragged length (101 % 64 != 0, tail octet of 5), width that
        // splits a plane group (19 = 8 + 8 + 3)
        let values: Vec<u64> =
            (0..101u64).map(|i| i.wrapping_mul(0x9E37_79B9) & 0x7FFFF).collect();
        let planes = transpose(&values, 19);
        assert_eq!(planes, transpose_naive(&values, 19));
        assert_eq!(
            untranspose(&planes, 101).unwrap(),
            untranspose_naive(&planes, 101)
        );
        // shorter than one octet
        let tiny = [0b101u64, 0b011, 0b110];
        assert_eq!(transpose(&tiny, 3), transpose_naive(&tiny, 3));
    }

    #[test]
    fn untranspose_rejects_short_planes() {
        // Regression: a plane shorter than ceil(elems / 8) used to
        // index out of bounds (`plane[i / 8]`); it must be a clean
        // error now.
        let planes = vec![vec![0xFFu8; 2]]; // 16 bits of storage
        assert!(untranspose(&planes, 17).is_err());
        assert!(untranspose(&planes, 16).is_ok());
        // the error names the offending plane, not a panic site
        let ragged = vec![vec![0u8; 3], vec![0u8; 1]];
        let err = untranspose(&ragged, 20).unwrap_err().to_string();
        assert!(err.contains("plane 1"), "unexpected error: {err}");
        // > 64 planes cannot map onto u64 bit positions
        let wide = vec![vec![0u8; 1]; 65];
        assert!(untranspose(&wide, 4).is_err());
    }

    #[test]
    fn transpose_bit_convention_is_lsb_first() {
        // element 0 → byte 0 bit 0; element 9 → byte 1 bit 1
        let mut values = vec![0u64; 10];
        values[0] = 0b01; // bit 0 set
        values[9] = 0b10; // bit 1 set
        let planes = transpose(&values, 2);
        assert_eq!(planes[0][0], 0b0000_0001);
        assert_eq!(planes[0][1], 0);
        assert_eq!(planes[1][1], 0b0000_0010);
    }

    #[test]
    fn popcount_live_excludes_padding() {
        assert_eq!(popcount_live(&[0xFF, 0xFF], 16), 16);
        assert_eq!(popcount_live(&[0xFF, 0xFF], 13), 13);
        assert_eq!(popcount_live(&[0x00, 0xE0], 13), 0);
        assert_eq!(popcount_live(&[0x00, 0x1F], 13), 5);
    }

    #[test]
    fn popcount_live_excludes_row_padding_bytes() {
        // Regression: a plane buffer longer than ceil(elems / 8) — a
        // full-row read-back, or a ragged shard in a uniform-length
        // slot — carries >= 8 bits of padding. The pre-fix mask
        // `0xFF << (8 - pad)` underflowed for pad >= 8 and only ever
        // touched the final byte, so this case panicked (debug) or
        // miscounted (release).
        assert_eq!(popcount_live(&[0xFF; 4], 5), 5); // pad = 27 bits
        assert_eq!(popcount_live(&[0xFF, 0xFF, 0xFF], 8), 8); // pad = 16
        assert_eq!(popcount_live(&[0b0000_0101, 0xFF], 3), 1); // pad = 13
        // whole-byte padding with a byte-aligned live region
        assert_eq!(popcount_live(&[0xF0, 0x0F, 0xFF, 0xFF], 16), 8);
        // degenerate buffers stay well-defined
        assert_eq!(popcount_live(&[], 0), 0);
        assert_eq!(popcount_live(&[0xFF], 8), 8);
    }

    #[test]
    fn untranspose_ignores_padding_bits() {
        let mut planes = transpose(&[1u64, 1, 1], 1);
        planes[0][0] |= 0xF8; // junk in the padding lanes
        assert_eq!(untranspose(&planes, 3).unwrap(), vec![1, 1, 1]);
        // whole trailing pad bytes (e.g. a full-row read-back) are
        // ignored too, junk and all
        let mut padded = transpose(&[7u64, 7], 3);
        for p in &mut padded {
            p.extend_from_slice(&[0xFF; 4]);
        }
        assert_eq!(untranspose(&padded, 2).unwrap(), vec![7, 7]);
    }
}
