//! Vertical (bit-transposed) data layout: W-bit integers stored as W
//! bit-plane rows.
//!
//! Bit-serial PUD arithmetic operates on *bit-planes*: plane `w` holds
//! bit `w` of every element, so one bulk AND over two planes processes
//! the whole column's bit `w` in a single command sequence. A
//! [`VerticalLayout`] owns the W plane buffers of one column,
//! allocated through the normal allocator interface with
//! `pim_alloc_align` hints so all planes of all operands co-locate in
//! one subarray — exactly the placement the PUMA allocator exists to
//! produce and the baselines cannot.
//!
//! The bit convention matches `workloads::filter`'s bitmaps: element
//! `i` lives at byte `i / 8`, bit `i % 8` (LSB first) of each plane.
//! [`transpose`] / [`untranspose`] are pure functions so property
//! tests can round-trip them without booting a system.

use anyhow::{ensure, Result};

use crate::alloc::traits::Allocator;
use crate::coordinator::system::System;
use crate::os::process::Pid;

use super::kernels::width_mask;

/// Transpose `values` (each at most `width` bits) into `width`
/// bit-plane byte buffers, LSB plane first.
pub fn transpose(values: &[u64], width: u32) -> Vec<Vec<u8>> {
    let len = values.len().div_ceil(8);
    let mut planes = vec![vec![0u8; len]; width as usize];
    for (i, &v) in values.iter().enumerate() {
        for (w, plane) in planes.iter_mut().enumerate() {
            if (v >> w) & 1 == 1 {
                plane[i / 8] |= 1 << (i % 8);
            }
        }
    }
    planes
}

/// Inverse of [`transpose`]: rebuild `elems` values from bit-planes
/// (`planes[w]` is bit `w`). Plane bytes past `elems` bits are
/// ignored.
pub fn untranspose(planes: &[Vec<u8>], elems: usize) -> Vec<u64> {
    let mut values = vec![0u64; elems];
    for (w, plane) in planes.iter().enumerate() {
        for (i, v) in values.iter_mut().enumerate() {
            if (plane[i / 8] >> (i % 8)) & 1 == 1 {
                *v |= 1 << w;
            }
        }
    }
    values
}

/// Set bits among the first `elems` bit positions of `bits` — a
/// padding-safe popcount (padding-lane bits can be set by kernels
/// whose padding-lane inputs are all-zero, e.g. `0 < T`).
///
/// The buffer may be arbitrarily longer than `ceil(elems / 8)`: a
/// plane read back at full row length (or a ragged shard bound to a
/// uniform-length scratch slot) carries whole trailing pad *bytes* on
/// top of the final byte's pad bits, and every one of them is ignored.
/// (A previous version only masked the final byte and underflowed the
/// shift for `pad >= 8`, miscounting — or debug-panicking on — any
/// row-padded buffer.)
pub fn popcount_live(bits: &[u8], elems: usize) -> u64 {
    // whole live bytes, clamped to the buffer
    let full = (elems / 8).min(bits.len());
    let mut total: u64 = bits[..full].iter().map(|b| b.count_ones() as u64).sum();
    // partial live byte: keep only the low `elems % 8` bits
    if elems % 8 != 0 && full < bits.len() {
        let keep = (1u8 << (elems % 8)) - 1;
        total += (bits[full] & keep).count_ones() as u64;
    }
    total
}

/// A column of `elems` `width`-bit integers stored as `width` bit-plane
/// buffers of `plane_len` bytes each.
#[derive(Debug)]
pub struct VerticalLayout {
    width: u32,
    elems: usize,
    plane_len: u64,
    planes: Vec<u64>,
}

impl VerticalLayout {
    /// Bytes per plane of an `elems`-element column, after validating
    /// the shape — the shared prologue of the constructors.
    fn checked_plane_len(width: u32, elems: usize) -> Result<u64> {
        ensure!((1..=64).contains(&width), "width {width} out of range");
        ensure!(elems > 0, "empty column");
        Ok(elems.div_ceil(8) as u64)
    }

    /// Chain `width - 1` further planes hint-aligned to the
    /// already-placed anchor plane `first` and assemble the layout —
    /// the shared body of [`VerticalLayout::alloc`] and
    /// [`VerticalLayout::alloc_spread`].
    fn chain_to_anchor(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
        plane_len: u64,
        first: u64,
    ) -> Result<Self> {
        let mut planes = vec![first];
        for _ in 1..width {
            planes.push(sys.alloc_align(alloc, pid, plane_len, first)?);
        }
        Ok(Self {
            width,
            elems,
            plane_len,
            planes,
        })
    }

    /// Allocate the planes with `alloc`: the first through the plain
    /// path, the rest hint-aligned to it (the paper's `pim_alloc` /
    /// `pim_alloc_align` protocol; baselines ignore the hint).
    pub fn alloc(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
    ) -> Result<Self> {
        let plane_len = Self::checked_plane_len(width, elems)?;
        let first = sys.alloc(alloc, pid, plane_len)?;
        Self::chain_to_anchor(sys, alloc, pid, width, elems, plane_len, first)
    }

    /// Allocate with the first plane placed through the allocator's
    /// placement-spread path (`Allocator::alloc_spread`, PUMA's
    /// bank-targeted draw) and the rest hint-aligned to it: shard
    /// `spread` of a sharded column lands on bank `spread % banks`
    /// under PUMA, so sibling shards execute on disjoint bank command
    /// timelines (baselines ignore the spread exactly as they ignore
    /// hints).
    pub fn alloc_spread(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
        spread: u32,
    ) -> Result<Self> {
        let plane_len = Self::checked_plane_len(width, elems)?;
        let first = sys.alloc_spread(alloc, pid, plane_len, spread)?;
        Self::chain_to_anchor(sys, alloc, pid, width, elems, plane_len, first)
    }

    /// Allocate with every plane hint-aligned to `hint` — used for the
    /// second operand and the destination so the whole kernel lands in
    /// the first operand's subarray.
    pub fn alloc_with_hint(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
        hint: u64,
    ) -> Result<Self> {
        let plane_len = Self::checked_plane_len(width, elems)?;
        let mut planes = Vec::with_capacity(width as usize);
        for _ in 0..width {
            planes.push(sys.alloc_align(alloc, pid, plane_len, hint)?);
        }
        Ok(Self {
            width,
            elems,
            plane_len,
            planes,
        })
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    pub fn elems(&self) -> usize {
        self.elems
    }

    /// Bytes per plane buffer.
    pub fn plane_len(&self) -> u64 {
        self.plane_len
    }

    /// Plane VAs, LSB plane first.
    pub fn planes(&self) -> &[u64] {
        &self.planes
    }

    /// The co-location hint for further allocations (the first plane).
    pub fn hint(&self) -> u64 {
        self.planes[0]
    }

    /// Transpose `values` into the planes through the process's
    /// virtual mappings. Every value must fit in `width` bits.
    pub fn store(&self, sys: &mut System, pid: Pid, values: &[u64]) -> Result<()> {
        ensure!(
            values.len() == self.elems,
            "store of {} value(s) into a {}-element column",
            values.len(),
            self.elems
        );
        let mask = width_mask(self.width);
        for (i, v) in values.iter().enumerate() {
            ensure!(
                (v & !mask) == 0,
                "value {v:#x} at index {i} exceeds {} bits",
                self.width
            );
        }
        for (plane, bytes) in
            self.planes.iter().zip(transpose(values, self.width))
        {
            sys.write_virt(pid, *plane, &bytes)?;
        }
        Ok(())
    }

    /// Read the planes back and untranspose into values.
    pub fn load(&self, sys: &mut System, pid: Pid) -> Result<Vec<u64>> {
        let mut planes = Vec::with_capacity(self.planes.len());
        for &va in &self.planes {
            planes.push(sys.read_virt(pid, va, self.plane_len)?);
        }
        Ok(untranspose(&planes, self.elems))
    }

    /// Return every plane to `alloc`.
    pub fn free(
        &self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
    ) -> Result<()> {
        for &va in &self.planes {
            sys.free(alloc, pid, va)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrips() {
        let values: Vec<u64> = (0..100).map(|i| (i * 37) % 256).collect();
        let planes = transpose(&values, 8);
        assert_eq!(planes.len(), 8);
        assert_eq!(planes[0].len(), 13); // ceil(100 / 8)
        assert_eq!(untranspose(&planes, 100), values);
    }

    #[test]
    fn transpose_bit_convention_is_lsb_first() {
        // element 0 → byte 0 bit 0; element 9 → byte 1 bit 1
        let mut values = vec![0u64; 10];
        values[0] = 0b01; // bit 0 set
        values[9] = 0b10; // bit 1 set
        let planes = transpose(&values, 2);
        assert_eq!(planes[0][0], 0b0000_0001);
        assert_eq!(planes[0][1], 0);
        assert_eq!(planes[1][1], 0b0000_0010);
    }

    #[test]
    fn popcount_live_excludes_padding() {
        assert_eq!(popcount_live(&[0xFF, 0xFF], 16), 16);
        assert_eq!(popcount_live(&[0xFF, 0xFF], 13), 13);
        assert_eq!(popcount_live(&[0x00, 0xE0], 13), 0);
        assert_eq!(popcount_live(&[0x00, 0x1F], 13), 5);
    }

    #[test]
    fn popcount_live_excludes_row_padding_bytes() {
        // Regression: a plane buffer longer than ceil(elems / 8) — a
        // full-row read-back, or a ragged shard in a uniform-length
        // slot — carries >= 8 bits of padding. The pre-fix mask
        // `0xFF << (8 - pad)` underflowed for pad >= 8 and only ever
        // touched the final byte, so this case panicked (debug) or
        // miscounted (release).
        assert_eq!(popcount_live(&[0xFF; 4], 5), 5); // pad = 27 bits
        assert_eq!(popcount_live(&[0xFF, 0xFF, 0xFF], 8), 8); // pad = 16
        assert_eq!(popcount_live(&[0b0000_0101, 0xFF], 3), 1); // pad = 13
        // whole-byte padding with a byte-aligned live region
        assert_eq!(popcount_live(&[0xF0, 0x0F, 0xFF, 0xFF], 16), 8);
        // degenerate buffers stay well-defined
        assert_eq!(popcount_live(&[], 0), 0);
        assert_eq!(popcount_live(&[0xFF], 8), 8);
    }

    #[test]
    fn untranspose_ignores_padding_bits() {
        let mut planes = transpose(&[1u64, 1, 1], 1);
        planes[0][0] |= 0xF8; // junk in the padding lanes
        assert_eq!(untranspose(&planes, 3), vec![1, 1, 1]);
    }
}
