//! Bank-sharded vertical layouts: MIMDRAM-style SIMD over the PUMA
//! substrate (DESIGN.md §11).
//!
//! A [`super::VerticalLayout`] hint-co-locates all W bit-planes of a
//! column into one subarray — the placement PUD legality wants, but
//! also the placement that serializes every kernel on a single bank's
//! command timeline. MIMDRAM's answer is to spread the *data* instead
//! of the kernel: partition the column into S shards, give each shard
//! its own fully co-located plane set on a *distinct bank*, and let
//! the hazard-wave scheduler run the S copies of each kernel step in
//! lockstep across banks. A [`ShardedLayout`] is that partition:
//!
//! * shard `k`'s first plane is placed through the allocator's
//!   placement-spread path (`Allocator::alloc_spread`, PUMA cycles
//!   `k` across bank ids and sticks to one subarray within the bank);
//! * every other plane of shard `k` — and its scratch, via
//!   [`ShardedScratch`]'s per-shard pools — is `pim_alloc_align`-hinted
//!   to that anchor, so each shard is individually single-subarray;
//! * only the *last* shard is ragged (`ceil` partition), and
//!   [`super::popcount_live`] tolerates its padding.
//!
//! Execution goes through the unified `System::{arith, arith_const,
//! column_sum}` over a sharded [`Column`](super::column::Column): one
//! compiled program
//! per `(ArithOp, width)` (served from the system's program cache),
//! emitted once per shard, submitted as ONE batch with the per-shard
//! streams interleaved round-robin so wave `w` carries every shard's
//! `w`-th request.

use anyhow::{ensure, Result};

use crate::alloc::scratch::ScratchPool;
use crate::alloc::traits::Allocator;
use crate::coordinator::system::System;
use crate::os::process::Pid;

use super::layout::VerticalLayout;

/// Ceil-partition `elems` into at most `shards` non-empty chunk sizes
/// (only the last chunk is ragged; `shards > elems` degrades to one
/// element per shard).
pub fn shard_sizes(elems: usize, shards: usize) -> Vec<usize> {
    let s = shards.max(1).min(elems.max(1));
    let chunk = elems.div_ceil(s).max(1);
    let mut out = Vec::with_capacity(s);
    let mut rem = elems;
    while rem > 0 {
        let take = chunk.min(rem);
        out.push(take);
        rem -= take;
    }
    out
}

/// A column of `elems` `width`-bit integers partitioned into
/// bank-disjoint [`VerticalLayout`] shards.
///
/// `Clone` is cheap (plane VAs only) — the `ColumnCache` hands out
/// handles to resident sharded columns the same way it does for
/// unsharded ones.
#[derive(Debug, Clone)]
pub struct ShardedLayout {
    width: u32,
    elems: usize,
    shards: Vec<VerticalLayout>,
}

impl ShardedLayout {
    /// Allocate `shards` shards, anchor plane of shard `k` through the
    /// allocator's placement-spread path (`spread = k`), remaining
    /// planes hinted to the anchor. The actual shard count can be
    /// lower than requested for tiny columns (see [`shard_sizes`]).
    pub fn alloc(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        elems: usize,
        shards: usize,
    ) -> Result<Self> {
        ensure!((1..=64).contains(&width), "width {width} out of range");
        ensure!(elems > 0, "empty column");
        let sizes = shard_sizes(elems, shards);
        let mut parts = Vec::with_capacity(sizes.len());
        for (k, &n) in sizes.iter().enumerate() {
            parts.push(VerticalLayout::alloc_spread(
                sys, alloc, pid, width, n, k as u32,
            )?);
        }
        Ok(Self {
            width,
            elems,
            shards: parts,
        })
    }

    /// Allocate shard-for-shard co-located with `like`: shard `k`'s
    /// planes are hinted to `like`'s shard `k` anchor. Used for the
    /// second operand, the destination, and the predicate mask of a
    /// sharded kernel, so every shard's whole working set shares one
    /// subarray.
    pub fn alloc_like(
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
        width: u32,
        like: &ShardedLayout,
    ) -> Result<Self> {
        ensure!((1..=64).contains(&width), "width {width} out of range");
        let mut parts = Vec::with_capacity(like.shards.len());
        for part in &like.shards {
            parts.push(VerticalLayout::alloc_with_hint(
                sys,
                alloc,
                pid,
                width,
                part.elems(),
                part.hint(),
            )?);
        }
        Ok(Self {
            width,
            elems: like.elems,
            shards: parts,
        })
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    /// Total elements across shards.
    pub fn elems(&self) -> usize {
        self.elems
    }

    /// Actual shard count (can be lower than requested).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard layouts, in element order.
    pub fn shards(&self) -> &[VerticalLayout] {
        &self.shards
    }

    /// Shard `k`'s layout.
    pub fn shard(&self, k: usize) -> &VerticalLayout {
        &self.shards[k]
    }

    /// Transpose `values` into the shards (element order is preserved:
    /// shard 0 holds the first chunk, the last shard the ragged tail).
    pub fn store(&self, sys: &mut System, pid: Pid, values: &[u64]) -> Result<()> {
        ensure!(
            values.len() == self.elems,
            "store of {} value(s) into a {}-element sharded column",
            values.len(),
            self.elems
        );
        let mut off = 0usize;
        for part in &self.shards {
            part.store(sys, pid, &values[off..off + part.elems()])?;
            off += part.elems();
        }
        Ok(())
    }

    /// Read every shard back and reassemble the column in element
    /// order.
    pub fn load(&self, sys: &mut System, pid: Pid) -> Result<Vec<u64>> {
        let mut out = Vec::with_capacity(self.elems);
        for part in &self.shards {
            out.extend(part.load(sys, pid)?);
        }
        Ok(out)
    }

    /// Return every shard's planes to `alloc`.
    pub fn free(
        &self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
    ) -> Result<()> {
        for part in &self.shards {
            part.free(sys, alloc, pid)?;
        }
        Ok(())
    }
}

/// Per-shard scratch pools: shard `k`'s kernel intermediates lease
/// from pool `k`, hinted to shard `k`'s anchor, so scratch co-locates
/// with its shard instead of dragging every shard's temporaries into
/// one subarray. `trim` between kernels works exactly as for a single
/// [`ScratchPool`], per pool.
#[derive(Debug, Default)]
pub struct ShardedScratch {
    pools: Vec<ScratchPool>,
}

impl ShardedScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pool backing shard `k` (created on first use).
    pub fn pool(&mut self, k: usize) -> &mut ScratchPool {
        while self.pools.len() <= k {
            self.pools.push(ScratchPool::new());
        }
        &mut self.pools[k]
    }

    /// Pools currently materialized.
    pub fn n_pools(&self) -> usize {
        self.pools.len()
    }

    /// Total buffers leased across pools over the lifetime.
    pub fn leases(&self) -> u64 {
        self.pools.iter().map(|p| p.leases).sum()
    }

    /// Sum of the per-pool peak resident counts.
    pub fn high_water(&self) -> usize {
        self.pools.iter().map(|p| p.high_water).sum()
    }

    /// Total buffers currently resident across pools.
    pub fn resident(&self) -> usize {
        self.pools.iter().map(ScratchPool::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_sizes_partition_exactly() {
        assert_eq!(shard_sizes(10, 4), vec![3, 3, 3, 1]);
        assert_eq!(shard_sizes(8, 3), vec![3, 3, 2]);
        assert_eq!(shard_sizes(8, 1), vec![8]);
        assert_eq!(shard_sizes(8, 8), vec![1; 8]);
        // S > elems degrades to one element per shard
        assert_eq!(shard_sizes(3, 9), vec![1, 1, 1]);
        // ceil partition may need fewer shards than requested
        assert_eq!(shard_sizes(9, 4), vec![3, 3, 3]);
        assert_eq!(shard_sizes(1, 1), vec![1]);
        for (elems, shards) in [(1usize, 1usize), (100, 7), (64, 16), (5, 8)] {
            let sizes = shard_sizes(elems, shards);
            assert_eq!(sizes.iter().sum::<usize>(), elems);
            assert!(sizes.len() <= shards.max(1));
            assert!(sizes.iter().all(|&n| n > 0));
        }
    }

    #[test]
    fn sharded_scratch_pools_materialize_on_demand() {
        let mut s = ShardedScratch::new();
        assert_eq!(s.n_pools(), 0);
        assert_eq!(s.resident(), 0);
        s.pool(2);
        assert_eq!(s.n_pools(), 3);
        assert_eq!(s.leases(), 0);
        assert_eq!(s.high_water(), 0);
    }
}
