//! The layout-polymorphic column handle.
//!
//! PR 9 collapses the flat/sharded method pairs on `System`
//! (`run_arith`/`run_arith_sharded`, `arith_sum`/`arith_sum_sharded`,
//! …) behind single entry points that accept a [`Column`]: one handle
//! that is either a [`VerticalLayout`] (all planes co-located in one
//! subarray via `pim_alloc_align`) or a [`ShardedLayout`] (anchors
//! spread across banks for MIMDRAM-style bank parallelism). Callers
//! pick the placement once, at allocation time, via [`LayoutSpec`];
//! every kernel thereafter is layout-agnostic.

use crate::pud::arith::layout::VerticalLayout;
use crate::pud::arith::shard::ShardedLayout;

/// Placement policy for a new column (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LayoutSpec {
    /// All planes co-located in one subarray (single bank timeline).
    #[default]
    Flat,
    /// Shard anchors spread across `n` banks (disjoint timelines).
    Sharded(usize),
}

impl LayoutSpec {
    /// Shard count this spec materializes (`1` for [`LayoutSpec::Flat`]).
    pub fn shards(&self) -> usize {
        match self {
            LayoutSpec::Flat => 1,
            LayoutSpec::Sharded(n) => (*n).max(1),
        }
    }
}

/// A transposed bit-serial column under either placement (see module
/// docs). Cheap to clone — both layouts hold only plane VAs.
#[derive(Debug, Clone)]
pub enum Column {
    /// Co-located single-subarray placement.
    Flat(VerticalLayout),
    /// Bank-spread placement with per-shard timelines.
    Sharded(ShardedLayout),
}

impl Column {
    /// Operand width in bits.
    pub fn width(&self) -> u32 {
        match self {
            Column::Flat(l) => l.width(),
            Column::Sharded(l) => l.width(),
        }
    }

    /// Total elements.
    pub fn elems(&self) -> usize {
        match self {
            Column::Flat(l) => l.elems(),
            Column::Sharded(l) => l.elems(),
        }
    }

    /// The [`LayoutSpec`] this column was placed under.
    pub fn spec(&self) -> LayoutSpec {
        match self {
            Column::Flat(_) => LayoutSpec::Flat,
            Column::Sharded(l) => LayoutSpec::Sharded(l.n_shards()),
        }
    }

    /// The co-location hint for further allocations (first plane of
    /// the first shard).
    pub fn hint(&self) -> u64 {
        match self {
            Column::Flat(l) => l.hint(),
            Column::Sharded(l) => l.shard(0).hint(),
        }
    }

    /// The flat layout, if this column is [`Column::Flat`].
    pub fn as_flat(&self) -> Option<&VerticalLayout> {
        match self {
            Column::Flat(l) => Some(l),
            Column::Sharded(_) => None,
        }
    }

    /// The sharded layout, if this column is [`Column::Sharded`].
    pub fn as_sharded(&self) -> Option<&ShardedLayout> {
        match self {
            Column::Flat(_) => None,
            Column::Sharded(l) => Some(l),
        }
    }
}

impl From<VerticalLayout> for Column {
    fn from(l: VerticalLayout) -> Self {
        Column::Flat(l)
    }
}

impl From<ShardedLayout> for Column {
    fn from(l: ShardedLayout) -> Self {
        Column::Sharded(l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_spec_shard_counts() {
        assert_eq!(LayoutSpec::Flat.shards(), 1);
        assert_eq!(LayoutSpec::Sharded(4).shards(), 4);
        assert_eq!(LayoutSpec::Sharded(0).shards(), 1, "degenerate spread");
        assert_eq!(LayoutSpec::default(), LayoutSpec::Flat);
    }
}
