//! The PUD execution engine the coordinator drives.
//!
//! Consumes the per-row plan from [`legality::check_rowwise`] and
//! executes the PUD-eligible rows in-DRAM (functional + counters +
//! analytic latency). Fallback rows are *not* executed here — the
//! coordinator routes them to the CPU runtime — but the engine
//! accounts their DRAM-side traffic so end-to-end latency and energy
//! include both paths.

use anyhow::{bail, Result};

use crate::dram::device::DramDevice;
use crate::dram::timing::TimingParams;

use super::isa::PudOp;
use super::legality::{CauseCounts, RowPlan};
use super::{ambit, rowclone};

/// Outcome of running one bulk op's plan through the engine.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecStats {
    pub pud_rows: u64,
    pub fallback_rows: u64,
    /// Per-cause breakdown of `fallback_rows` (always sums to it).
    pub fallback_causes: CauseCounts,
    pub pud_bytes: u64,
    pub fallback_bytes: u64,
    /// Simulated nanoseconds spent on the PUD path.
    pub pud_ns: f64,
    /// Simulated nanoseconds the fallback path owes (CPU streaming +
    /// dispatch), accounted by the engine for the DRAM side.
    pub fallback_ns: f64,
}

impl ExecStats {
    pub fn total_ns(&self) -> f64 {
        self.pud_ns + self.fallback_ns
    }

    pub fn merge(&mut self, o: &ExecStats) {
        self.pud_rows += o.pud_rows;
        self.fallback_rows += o.fallback_rows;
        self.fallback_causes.merge(&o.fallback_causes);
        self.pud_bytes += o.pud_bytes;
        self.fallback_bytes += o.fallback_bytes;
        self.pud_ns += o.pud_ns;
        self.fallback_ns += o.fallback_ns;
    }
}

/// The engine: owns the device and timing parameters.
pub struct PudEngine {
    pub device: DramDevice,
    pub timing: TimingParams,
}

impl PudEngine {
    pub fn new(device: DramDevice, timing: TimingParams) -> Self {
        Self { device, timing }
    }

    /// Execute the PUD rows of `plan` for `op`. Returns stats; the
    /// fallback rows' latency is *estimated* here (dispatch + stream)
    /// and their functional execution is the coordinator's job.
    ///
    /// `fallback_executed` tells the engine whether to also apply the
    /// fallback rows functionally with the scalar reference (used by
    /// tests and by runs without the XLA runtime).
    pub fn execute(
        &mut self,
        op: PudOp,
        plan: &[RowPlan],
        fallback_executed: bool,
    ) -> Result<ExecStats> {
        let mut stats = ExecStats::default();
        let mut pud_rows_by_kind = 0u64;
        for entry in plan {
            match entry {
                RowPlan::Pud {
                    dst, srcs, bytes, ..
                } => {
                    let ns = match op {
                        PudOp::Zero => {
                            rowclone::zero_row(&mut self.device, &self.timing, dst)?
                        }
                        PudOp::Copy => rowclone::fpm_copy(
                            &mut self.device,
                            &self.timing,
                            &srcs[0],
                            dst,
                        )?,
                        PudOp::Not => ambit::dcc_not(
                            &mut self.device,
                            &self.timing,
                            &srcs[0],
                            dst,
                        )?,
                        PudOp::And | PudOp::Or => ambit::tra_and_or(
                            &mut self.device,
                            &self.timing,
                            op,
                            &srcs[0],
                            &srcs[1],
                            dst,
                        )?,
                        PudOp::Xor => ambit::tra_xor(
                            &mut self.device,
                            &self.timing,
                            &srcs[0],
                            &srcs[1],
                            dst,
                        )?,
                    };
                    stats.pud_ns += ns;
                    stats.pud_rows += 1;
                    stats.pud_bytes += *bytes as u64;
                    pud_rows_by_kind += 1;
                }
                RowPlan::Fallback {
                    dst,
                    srcs,
                    bytes,
                    cause,
                } => {
                    let b = *bytes as u64;
                    stats.fallback_causes.add(*cause, 1);
                    // DRAM-side accounting: operands stream to the CPU
                    // and the result streams back, extent by extent.
                    for src in srcs {
                        for e in src {
                            self.device.account_cpu_read(e.paddr, e.len);
                        }
                    }
                    for e in dst {
                        self.device.account_cpu_write(e.paddr, e.len);
                    }
                    stats.fallback_ns +=
                        self.timing.fallback_row_ns(b, srcs.len());
                    stats.fallback_rows += 1;
                    stats.fallback_bytes += b;
                    if fallback_executed {
                        self.apply_fallback_functional(op, dst, srcs, b)?;
                    }
                }
            }
        }
        // one dispatch overhead per bulk op per path actually used
        if stats.fallback_rows > 0 {
            stats.fallback_ns += self.timing.cpu_dispatch_overhead;
        }
        if pud_rows_by_kind > 0 {
            stats.pud_ns += self.timing.pud_dispatch_overhead;
        }
        Ok(stats)
    }

    fn apply_fallback_functional(
        &mut self,
        op: PudOp,
        dst: &[crate::os::process::PhysExtent],
        srcs: &[Vec<crate::os::process::PhysExtent>],
        bytes: u64,
    ) -> Result<()> {
        if srcs.len() != op.arity() {
            bail!("fallback arity mismatch for {op}");
        }
        let mut bufs: Vec<Vec<u8>> = Vec::with_capacity(srcs.len());
        for src in srcs {
            bufs.push(self.gather(src, bytes));
        }
        let refs: Vec<&[u8]> = bufs.iter().map(|b| b.as_slice()).collect();
        let mut out = vec![0u8; bytes as usize];
        op.apply_bytes(&refs, &mut out);
        self.scatter(dst, &out);
        Ok(())
    }

    /// Read a scattered extent list into one contiguous buffer.
    pub fn gather(
        &mut self,
        extents: &[crate::os::process::PhysExtent],
        bytes: u64,
    ) -> Vec<u8> {
        let mut buf = vec![0u8; bytes as usize];
        self.gather_into(extents, &mut buf);
        buf
    }

    /// As [`PudEngine::gather`], but into a caller-owned buffer — the
    /// batch executor reuses its scratch across dispatches instead of
    /// allocating per run.
    pub fn gather_into(
        &mut self,
        extents: &[crate::os::process::PhysExtent],
        buf: &mut [u8],
    ) {
        let mut off = 0usize;
        for e in extents {
            let n = (e.len as usize).min(buf.len() - off);
            self.device.read(e.paddr, &mut buf[off..off + n]);
            off += n;
            if off == buf.len() {
                break;
            }
        }
    }

    /// Write a contiguous buffer back to a scattered extent list.
    pub fn scatter(
        &mut self,
        extents: &[crate::os::process::PhysExtent],
        data: &[u8],
    ) {
        let mut off = 0usize;
        for e in extents {
            let n = (e.len as usize).min(data.len() - off);
            self.device.write(e.paddr, &data[off..off + n]);
            off += n;
            if off == data.len() {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::{DramGeometry, SubarrayId};
    use crate::os::process::PhysExtent;
    use crate::pud::legality::check_rowwise;
    use crate::util::rng::Pcg64;

    fn engine() -> PudEngine {
        let scheme = InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 4,
            rows_per_subarray: 32,
            row_bytes: 128,
        });
        PudEngine::new(DramDevice::new(scheme), TimingParams::default())
    }

    fn row_ext(e: &PudEngine, sid: u32, row: u32, len: u64) -> Vec<PhysExtent> {
        let addr = e.device.scheme.row_start_addr(SubarrayId(sid), row);
        vec![PhysExtent { paddr: addr, len }]
    }

    #[test]
    fn pud_and_fallback_agree_functionally() {
        // run AND once via PUD placement and once via fallback; the
        // memory images must match.
        let mut rng = Pcg64::new(3);
        let mut va = vec![0u8; 128];
        let mut vb = vec![0u8; 128];
        rng.fill_bytes(&mut va);
        rng.fill_bytes(&mut vb);

        // PUD-placed
        let mut e1 = engine();
        let (a, b, d) = (
            row_ext(&e1, 0, 1, 128),
            row_ext(&e1, 0, 2, 128),
            row_ext(&e1, 0, 3, 128),
        );
        e1.device.write(a[0].paddr, &va);
        e1.device.write(b[0].paddr, &vb);
        let plan = check_rowwise(&e1.device.scheme, &[&d, &a, &b], 128);
        assert!(plan[0].is_pud());
        let st = e1.execute(PudOp::And, &plan, true).unwrap();
        assert_eq!(st.pud_rows, 1);
        let mut got1 = vec![0u8; 128];
        e1.device.read(d[0].paddr, &mut got1);

        // fallback-placed (misaligned dst)
        let mut e2 = engine();
        let d2 = vec![PhysExtent {
            paddr: e2.device.scheme.row_start_addr(SubarrayId(0), 3) + 16,
            len: 128,
        }];
        let (a2, b2) = (row_ext(&e2, 0, 1, 128), row_ext(&e2, 0, 2, 128));
        e2.device.write(a2[0].paddr, &va);
        e2.device.write(b2[0].paddr, &vb);
        let plan2 = check_rowwise(&e2.device.scheme, &[&d2, &a2, &b2], 128);
        assert!(!plan2[0].is_pud());
        let st2 = e2.execute(PudOp::And, &plan2, true).unwrap();
        assert_eq!(st2.fallback_rows, 1);
        let mut got2 = vec![0u8; 128];
        e2.device.read(d2[0].paddr, &mut got2);

        let want: Vec<u8> = va.iter().zip(&vb).map(|(x, y)| x & y).collect();
        assert_eq!(got1, want);
        assert_eq!(got2, want);
        // and the PUD path is far faster in simulated time
        assert!(st.total_ns() < st2.total_ns());
    }

    #[test]
    fn multi_row_mixed_plan_accumulates() {
        let mut e = engine();
        let sid = 1;
        // 2 rows: first aligned, second misaligned
        let dst = vec![
            PhysExtent {
                paddr: e.device.scheme.row_start_addr(SubarrayId(sid), 4),
                len: 128,
            },
            PhysExtent {
                paddr: e.device.scheme.row_start_addr(SubarrayId(sid), 5) + 8,
                len: 128,
            },
        ];
        let src = vec![
            PhysExtent {
                paddr: e.device.scheme.row_start_addr(SubarrayId(sid), 8),
                len: 128,
            },
            PhysExtent {
                paddr: e.device.scheme.row_start_addr(SubarrayId(sid), 9),
                len: 128,
            },
        ];
        e.device.write(src[0].paddr, &vec![0xAB; 128]);
        e.device.write(src[1].paddr, &vec![0xCD; 128]);
        let plan = check_rowwise(&e.device.scheme, &[&dst, &src], 256);
        let st = e.execute(PudOp::Copy, &plan, true).unwrap();
        assert_eq!(st.pud_rows, 1);
        assert_eq!(st.fallback_rows, 1);
        assert_eq!(st.pud_bytes + st.fallback_bytes, 256);
        let mut got = vec![0u8; 128];
        e.device.read(dst[0].paddr, &mut got);
        assert_eq!(got, vec![0xAB; 128]);
        e.device.read(dst[1].paddr, &mut got);
        assert_eq!(got, vec![0xCD; 128]);
    }

    #[test]
    fn zero_plan_zeroes_rows() {
        let mut e = engine();
        let dst = row_ext(&e, 2, 7, 128);
        e.device.write(dst[0].paddr, &vec![0xFF; 128]);
        let plan = check_rowwise(&e.device.scheme, &[&dst], 128);
        let st = e.execute(PudOp::Zero, &plan, true).unwrap();
        assert_eq!(st.pud_rows, 1);
        let mut got = vec![0u8; 128];
        e.device.read(dst[0].paddr, &mut got);
        assert_eq!(got, vec![0u8; 128]);
    }

    #[test]
    fn counters_reflect_command_sequences() {
        let mut e = engine();
        let (a, b, d) = (
            row_ext(&e, 0, 1, 128),
            row_ext(&e, 0, 2, 128),
            row_ext(&e, 0, 3, 128),
        );
        let plan = check_rowwise(&e.device.scheme, &[&d, &a, &b], 128);
        e.execute(PudOp::And, &plan, false).unwrap();
        assert_eq!(e.device.counters.aaps, 4);
        assert_eq!(e.device.counters.tras, 1);
        // fallback traffic counts lines
        let d2 = vec![PhysExtent {
            paddr: e.device.scheme.row_start_addr(SubarrayId(0), 3) + 16,
            len: 128,
        }];
        let plan2 = check_rowwise(&e.device.scheme, &[&d2, &a, &b], 128);
        e.execute(PudOp::And, &plan2, false).unwrap();
        assert_eq!(e.device.counters.line_reads, 4); // 2 srcs x 128B
        assert_eq!(e.device.counters.line_writes, 2);
    }
}
