//! Ambit: in-DRAM bulk Boolean operations via triple-row activation.
//!
//! Activating three rows simultaneously drives each bitline to the
//! *majority* of the three cells; with one operand pre-set to all-0s
//! or all-1s this computes AND or OR of the other two. NOT uses a
//! dual-contact cell whose complementary port inverts on sense. The
//! operands are first staged into the reserved temp rows with AAPs
//! (computation is destructive), so one Boolean op costs a short AAP
//! sequence (see [`TimingParams::ambit_and_or_ns`]).
//!
//! Functional semantics run on the backing store; counters record the
//! real command sequence (AAP staging + TRA).

use anyhow::{ensure, Result};

use crate::dram::device::DramDevice;
use crate::dram::geometry::Loc;
use crate::dram::timing::TimingParams;

use super::isa::PudOp;

/// Bitwise majority of three byte slices (the TRA primitive).
pub fn maj3_bytes(a: &[u8], b: &[u8], c: &[u8], out: &mut [u8]) {
    for i in 0..out.len() {
        out[i] = (a[i] & b[i]) | (b[i] & c[i]) | (c[i] & a[i]);
    }
}

fn ensure_colocated(dev: &DramDevice, locs: &[&Loc]) -> Result<()> {
    let g = dev.geometry();
    let sid0 = g.subarray_id(locs[0]);
    for l in locs {
        ensure!(l.column == 0, "Ambit operands must be row-aligned");
        ensure!(
            g.subarray_id(l) == sid0,
            "Ambit operands must share one subarray"
        );
    }
    Ok(())
}

/// dst = a AND b / a OR b via TRA (C=0 / C=1). All rows in one
/// subarray. Returns latency (ns).
pub fn tra_and_or(
    dev: &mut DramDevice,
    timing: &TimingParams,
    op: PudOp,
    a: &Loc,
    b: &Loc,
    dst: &Loc,
) -> Result<f64> {
    ensure!(
        matches!(op, PudOp::And | PudOp::Or),
        "tra_and_or only handles And/Or"
    );
    // aliasing allowed: operands are staged into temp rows before the
    // TRA on the real substrate (we read both sources before writing)
    ensure_colocated(dev, &[a, b, dst])?;
    let ra = dev.read_row(a);
    let rb = dev.read_row(b);
    let control = match op {
        PudOp::And => vec![0x00u8; ra.len()],
        _ => vec![0xFFu8; ra.len()],
    };
    let mut out = vec![0u8; ra.len()];
    maj3_bytes(&ra, &rb, &control, &mut out);
    dev.write_row(dst, &out);
    // sequence: AAP(a->T0), AAP(b->T1), AAP(ctl->T2), TRA+copy-out —
    // counts come from the shared PudOp cost table
    dev.counters.aaps += op.aaps_per_row();
    dev.counters.tras += op.tras_per_row();
    Ok(timing.ambit_and_or_ns(1))
}

/// dst = NOT src via the dual-contact row.
pub fn dcc_not(
    dev: &mut DramDevice,
    timing: &TimingParams,
    src: &Loc,
    dst: &Loc,
) -> Result<f64> {
    ensure_colocated(dev, &[src, dst])?;
    let row = dev.read_row(src);
    let inv: Vec<u8> = row.iter().map(|b| !b).collect();
    dev.write_row(dst, &inv);
    dev.counters.aaps += PudOp::Not.aaps_per_row();
    Ok(timing.ambit_not_ns(1))
}

/// dst = a XOR b, composed from AND/OR/NOT sequences.
pub fn tra_xor(
    dev: &mut DramDevice,
    timing: &TimingParams,
    a: &Loc,
    b: &Loc,
    dst: &Loc,
) -> Result<f64> {
    ensure_colocated(dev, &[a, b, dst])?;
    let ra = dev.read_row(a);
    let rb = dev.read_row(b);
    let out: Vec<u8> = ra.iter().zip(&rb).map(|(x, y)| x ^ y).collect();
    dev.write_row(dst, &out);
    // (a AND !b) OR (!a AND b): 2 NOTs + 2 ANDs + 1 OR worth of AAPs,
    // folded into the 7-AAP/3-TRA sequence the shared cost table (and
    // therefore the timing and energy models) charges.
    dev.counters.aaps += PudOp::Xor.aaps_per_row();
    dev.counters.tras += PudOp::Xor.tras_per_row();
    Ok(timing.ambit_xor_ns(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::{DramGeometry, SubarrayId};
    use crate::util::rng::Pcg64;

    fn dev() -> DramDevice {
        DramDevice::new(InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 2,
            rows_per_subarray: 16,
            row_bytes: 128,
        }))
    }

    fn loc_of(d: &DramDevice, sid: u32, row: u32) -> Loc {
        let addr = d.scheme.row_start_addr(SubarrayId(sid), row);
        d.scheme.decode(addr)
    }

    fn rand_row(rng: &mut Pcg64, n: usize) -> Vec<u8> {
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    #[test]
    fn maj3_identities() {
        let a = [0b1100u8];
        let b = [0b1010u8];
        let mut out = [0u8];
        maj3_bytes(&a, &b, &[0x00], &mut out);
        assert_eq!(out[0], a[0] & b[0]);
        maj3_bytes(&a, &b, &[0xFF], &mut out);
        assert_eq!(out[0], a[0] | b[0]);
        // commutativity
        let mut o2 = [0u8];
        maj3_bytes(&b, &[0x00], &a, &mut o2);
        assert_eq!(out[0] & (a[0] & b[0]), a[0] & b[0] & out[0]);
    }

    #[test]
    fn and_or_functional() {
        let mut d = dev();
        let t = TimingParams::default();
        let mut rng = Pcg64::new(5);
        let (la, lb, ld) = (loc_of(&d, 0, 1), loc_of(&d, 0, 2), loc_of(&d, 0, 3));
        let va = rand_row(&mut rng, 128);
        let vb = rand_row(&mut rng, 128);
        d.write_row(&la, &va);
        d.write_row(&lb, &vb);
        tra_and_or(&mut d, &t, PudOp::And, &la, &lb, &ld).unwrap();
        let want: Vec<u8> = va.iter().zip(&vb).map(|(x, y)| x & y).collect();
        assert_eq!(d.read_row(&ld), want);
        tra_and_or(&mut d, &t, PudOp::Or, &la, &lb, &ld).unwrap();
        let want: Vec<u8> = va.iter().zip(&vb).map(|(x, y)| x | y).collect();
        assert_eq!(d.read_row(&ld), want);
        assert_eq!(d.counters.tras, 2);
        assert_eq!(d.counters.aaps, 8);
    }

    #[test]
    fn not_and_xor_functional() {
        let mut d = dev();
        let t = TimingParams::default();
        let mut rng = Pcg64::new(6);
        let (la, lb, ld) = (loc_of(&d, 1, 1), loc_of(&d, 1, 2), loc_of(&d, 1, 3));
        let va = rand_row(&mut rng, 128);
        let vb = rand_row(&mut rng, 128);
        d.write_row(&la, &va);
        d.write_row(&lb, &vb);
        dcc_not(&mut d, &t, &la, &ld).unwrap();
        let want: Vec<u8> = va.iter().map(|x| !x).collect();
        assert_eq!(d.read_row(&ld), want);
        tra_xor(&mut d, &t, &la, &lb, &ld).unwrap();
        let want: Vec<u8> = va.iter().zip(&vb).map(|(x, y)| x ^ y).collect();
        assert_eq!(d.read_row(&ld), want);
    }

    #[test]
    fn sources_survive_the_operation() {
        // Ambit stages operands into temp rows precisely so the
        // sources are not destroyed; our functional model must match.
        let mut d = dev();
        let t = TimingParams::default();
        let (la, lb, ld) = (loc_of(&d, 0, 4), loc_of(&d, 0, 5), loc_of(&d, 0, 6));
        let va = vec![0xA5u8; 128];
        let vb = vec![0x0Fu8; 128];
        d.write_row(&la, &va);
        d.write_row(&lb, &vb);
        tra_and_or(&mut d, &t, PudOp::And, &la, &lb, &ld).unwrap();
        assert_eq!(d.read_row(&la), va);
        assert_eq!(d.read_row(&lb), vb);
    }

    #[test]
    fn rejects_cross_subarray_but_allows_aliasing() {
        let mut d = dev();
        let t = TimingParams::default();
        let (la, lb) = (loc_of(&d, 0, 1), loc_of(&d, 1, 2));
        let ld = loc_of(&d, 0, 3);
        assert!(tra_and_or(&mut d, &t, PudOp::And, &la, &lb, &ld).is_err());
        // in-place ops are fine: a &= a, a = !a
        let v = vec![0x5Au8; 128];
        d.write_row(&la, &v);
        tra_and_or(&mut d, &t, PudOp::And, &la, &la, &la).unwrap();
        assert_eq!(d.read_row(&la), v, "a & a == a");
        dcc_not(&mut d, &t, &la, &la).unwrap();
        let inv: Vec<u8> = v.iter().map(|x| !x).collect();
        assert_eq!(d.read_row(&la), inv);
    }

    #[test]
    fn latencies_ordered_not_lt_and_lt_xor() {
        let t = TimingParams::default();
        assert!(t.ambit_not_ns(1) < t.ambit_and_or_ns(1));
        assert!(t.ambit_and_or_ns(1) < t.ambit_xor_ns(1));
    }
}
