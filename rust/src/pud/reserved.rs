//! Per-subarray reserved rows for the Ambit substrate.
//!
//! Ambit dedicates a small group of rows in every subarray to
//! computation: temporary rows for triple-row activation (the row
//! triplet that is simultaneously activated), control rows holding
//! all-zeros / all-ones (to specialize `maj` into AND / OR), and
//! dual-contact rows whose complementary sense amplifies into NOT.
//! These rows are invisible to the OS allocator: the usable capacity
//! of each subarray shrinks accordingly, which PUMA's region split
//! must respect.

use crate::dram::geometry::{DramGeometry, Loc, SubarrayId};

/// Rows reserved at the *top* of each subarray.
pub const RESERVED_ROWS: u32 = 8;

/// Roles of the reserved rows, offset from the top of the subarray.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReservedRow {
    /// TRA temporaries T0..T2 (offsets 0..=2).
    Temp(u8),
    /// Control all-zeros row.
    Zero,
    /// Control all-ones row.
    One,
    /// Dual-contact row (and its complement) for NOT.
    Dcc(u8),
}

impl ReservedRow {
    fn offset(&self) -> u32 {
        match self {
            ReservedRow::Temp(i) => {
                debug_assert!(*i < 3);
                *i as u32
            }
            ReservedRow::Zero => 3,
            ReservedRow::One => 4,
            ReservedRow::Dcc(i) => {
                debug_assert!(*i < 2);
                5 + *i as u32
            } // 5, 6 (7 spare)
        }
    }
}

/// Number of rows in each subarray usable for data.
pub fn usable_rows(geom: &DramGeometry) -> u32 {
    geom.rows_per_subarray - RESERVED_ROWS
}

/// Usable data bytes per subarray.
pub fn usable_bytes(geom: &DramGeometry) -> u64 {
    usable_rows(geom) as u64 * geom.row_bytes as u64
}

/// Is `row` a reserved row?
pub fn is_reserved(geom: &DramGeometry, row: u32) -> bool {
    row >= usable_rows(geom)
}

/// Location of a reserved row within subarray `sid`.
pub fn reserved_loc(geom: &DramGeometry, sid: SubarrayId, which: ReservedRow) -> Loc {
    let mut rest = sid.0;
    let subarray = rest % geom.subarrays_per_bank;
    rest /= geom.subarrays_per_bank;
    let bank = rest % geom.banks_per_rank;
    rest /= geom.banks_per_rank;
    let rank = rest % geom.ranks_per_channel;
    let channel = rest / geom.ranks_per_channel;
    Loc {
        channel,
        rank,
        bank,
        subarray,
        row: usable_rows(geom) + which.offset(),
        column: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usable_rows_excludes_reserved() {
        let g = DramGeometry::default();
        assert_eq!(usable_rows(&g), 1024 - RESERVED_ROWS);
        assert_eq!(usable_bytes(&g), (1024 - RESERVED_ROWS) as u64 * 8192);
    }

    #[test]
    fn reserved_rows_detected() {
        let g = DramGeometry::default();
        assert!(!is_reserved(&g, 0));
        assert!(!is_reserved(&g, usable_rows(&g) - 1));
        assert!(is_reserved(&g, usable_rows(&g)));
        assert!(is_reserved(&g, 1023));
    }

    #[test]
    fn reserved_locs_distinct_and_in_subarray() {
        let g = DramGeometry::default();
        let sid = SubarrayId(37);
        let rows = [
            ReservedRow::Temp(0),
            ReservedRow::Temp(1),
            ReservedRow::Temp(2),
            ReservedRow::Zero,
            ReservedRow::One,
            ReservedRow::Dcc(0),
            ReservedRow::Dcc(1),
        ];
        let mut seen = std::collections::HashSet::new();
        for r in rows {
            let loc = reserved_loc(&g, sid, r);
            assert!(g.contains(&loc), "{loc:?}");
            assert_eq!(g.subarray_id(&loc), sid);
            assert!(is_reserved(&g, loc.row));
            assert!(seen.insert(loc.row), "reserved rows collide: {r:?}");
        }
    }
}
