//! Processing-using-DRAM substrate (Ambit + RowClone).
//!
//! The PUD device the paper targets: bulk row-granular operations
//! executed *inside* DRAM by exploiting analog row interactions —
//! RowClone for copy/initialize, Ambit triple-row activation for
//! AND/OR (and NOT via dual-contact cells).
//!
//! * [`isa`] — the bulk-op instruction set the coordinator dispatches.
//! * [`reserved`] — per-subarray reserved row groups (temporary TRA
//!   rows, control all-0/all-1 rows, dual-contact rows).
//! * [`legality`] — the operand-placement rules: all operands of one
//!   PUD instruction must be row-aligned and co-located in one
//!   subarray (paper §1) — the rules PUMA exists to satisfy.
//! * [`rowclone`] — functional + counted RowClone FPM/PSM execution.
//! * [`ambit`] — functional + counted Ambit Boolean execution.
//! * [`exec`] — [`exec::PudEngine`]: the device-level executor that
//!   the coordinator drives; returns analytic latencies.
//! * [`compiler`] — the Boolean-expression compiler that lowers
//!   multi-operand expression DAGs onto this substrate (IR, optimizer,
//!   scratch-row register allocator, batched lowering — single- and
//!   multi-output programs).
//! * [`arith`] — bit-serial vertical arithmetic over the compiler:
//!   transposed bit-plane layouts and ripple-carry/compare/select/
//!   popcount kernels expanded into expression DAGs.
//! * [`query`] — analytics query shapes (bitmap semi-join, batched
//!   group-by, top-k threshold bisection) composed from the arith
//!   kernels as mask-plane algebra, with scalar host oracles for
//!   differential testing.

pub mod ambit;
pub mod arith;
pub mod compiler;
pub mod exec;
pub mod isa;
pub mod legality;
pub mod query;
pub mod reserved;
pub mod rowclone;

pub use compiler::{Expr, ExprBuilder};
pub use exec::PudEngine;
pub use isa::PudOp;
pub use legality::{check_rowwise, RowPlan};
