//! The bulk-operation instruction set.
//!
//! These are the operations the modeled substrate supports in-DRAM
//! (RowClone: `Zero`/`Copy`; Ambit: `And`/`Or`/`Not`/`Xor`) and that
//! the CPU fallback must therefore also implement (the L1 Pallas
//! kernel set mirrors this enum — see python/compile/kernels).

use std::fmt;

/// A bulk operation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PudOp {
    /// dst = 0 (RowClone zero-init from the control zero row).
    Zero,
    /// dst = src (RowClone copy).
    Copy,
    /// dst = a & b (Ambit TRA with C=0).
    And,
    /// dst = a | b (Ambit TRA with C=1).
    Or,
    /// dst = !a (Ambit dual-contact row).
    Not,
    /// dst = a ^ b (Ambit composite sequence).
    Xor,
}

impl PudOp {
    /// Number of *source* operands (dst excluded).
    pub fn arity(&self) -> usize {
        match self {
            PudOp::Zero => 0,
            PudOp::Copy | PudOp::Not => 1,
            PudOp::And | PudOp::Or | PudOp::Xor => 2,
        }
    }

    /// All ops, for sweeps.
    pub const ALL: [PudOp; 6] = [
        PudOp::Zero,
        PudOp::Copy,
        PudOp::And,
        PudOp::Or,
        PudOp::Not,
        PudOp::Xor,
    ];

    /// AAPs one PUD-executed row of this op issues — THE cost table.
    /// Everything that prices an op (the timing sequences, the
    /// device counters bumped by `pud::{rowclone, ambit}`, the energy
    /// model, the `report::op_costs` table) derives from this and
    /// [`PudOp::tras_per_row`], so composite XOR is consistently a
    /// 7-AAP/3-TRA sequence everywhere — never a single TRA.
    pub fn aaps_per_row(&self) -> u64 {
        match self {
            PudOp::Zero | PudOp::Copy => 1,
            PudOp::Not => 2,
            PudOp::And | PudOp::Or => 4,
            PudOp::Xor => 7,
        }
    }

    /// Triple-row activations one PUD-executed row of this op issues.
    /// XOR is composed of two ANDs and one OR worth of majority
    /// operations, so it counts 3 — pricing it as one TRA would make
    /// the energy/report tables disagree with what the engine executes.
    pub fn tras_per_row(&self) -> u64 {
        match self {
            PudOp::Zero | PudOp::Copy | PudOp::Not => 0,
            PudOp::And | PudOp::Or => 1,
            PudOp::Xor => 3,
        }
    }

    /// Analytic cost of one PUD-executed row of this op (matches the
    /// command sequences charged by [`crate::pud::exec::PudEngine`]:
    /// RowClone AAPs for `Zero`/`Copy`, Ambit sequences for the rest).
    /// The scheduler uses this to lay rows onto per-bank timelines
    /// without re-running the engine. Always equals
    /// `aaps_per_row() * t_aap` (asserted by `costs_agree_with_timing`).
    pub fn pud_row_ns(&self, t: &crate::dram::timing::TimingParams) -> f64 {
        match self {
            PudOp::Zero => t.rowclone_zero_ns(1),
            PudOp::Copy => t.rowclone_fpm_ns(1),
            PudOp::Not => t.ambit_not_ns(1),
            PudOp::And | PudOp::Or => t.ambit_and_or_ns(1),
            PudOp::Xor => t.ambit_xor_ns(1),
        }
    }

    /// Energy of one PUD-executed row: the same AAP/TRA counts the
    /// engine's counters record, priced with `e`'s constants.
    pub fn pud_row_nj(&self, e: &crate::dram::energy::EnergyParams) -> f64 {
        self.aaps_per_row() as f64 * e.aap_nj + self.tras_per_row() as f64 * e.tra_nj
    }

    /// Artifact base name of the matching L1 kernel.
    pub fn kernel_name(&self) -> &'static str {
        match self {
            PudOp::Zero => "zero",
            PudOp::Copy => "copy",
            PudOp::And => "and",
            PudOp::Or => "or",
            PudOp::Not => "not",
            PudOp::Xor => "xor",
        }
    }

    /// Apply the op to byte slices (the scalar reference used by the
    /// simulator's own unit tests; the production fallback path runs
    /// the XLA artifacts instead).
    pub fn apply_bytes(&self, srcs: &[&[u8]], dst: &mut [u8]) {
        match self {
            PudOp::Zero => dst.fill(0),
            PudOp::Copy => dst.copy_from_slice(srcs[0]),
            PudOp::Not => {
                for (d, s) in dst.iter_mut().zip(srcs[0]) {
                    *d = !s;
                }
            }
            PudOp::And => {
                for ((d, a), b) in dst.iter_mut().zip(srcs[0]).zip(srcs[1]) {
                    *d = a & b;
                }
            }
            PudOp::Or => {
                for ((d, a), b) in dst.iter_mut().zip(srcs[0]).zip(srcs[1]) {
                    *d = a | b;
                }
            }
            PudOp::Xor => {
                for ((d, a), b) in dst.iter_mut().zip(srcs[0]).zip(srcs[1]) {
                    *d = a ^ b;
                }
            }
        }
    }
}

impl fmt::Display for PudOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.kernel_name())
    }
}

/// A bulk operation over *virtual* ranges of one process — what the
/// workloads submit to the coordinator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BulkRequest {
    pub op: PudOp,
    /// Destination virtual address.
    pub dst: u64,
    /// Source virtual addresses (`op.arity()` of them).
    pub srcs: Vec<u64>,
    /// Length in bytes (common to all operands).
    pub len: u64,
}

impl BulkRequest {
    pub fn new(op: PudOp, dst: u64, srcs: Vec<u64>, len: u64) -> Self {
        assert_eq!(srcs.len(), op.arity(), "arity mismatch for {op}");
        Self { op, dst, srcs, len }
    }

    /// DRAM rows this request covers (the final partial row counts).
    pub fn rows(&self, row_bytes: u64) -> u64 {
        self.len.div_ceil(row_bytes)
    }
}

/// Aggregate analytic cost of a request batch, all derived from the
/// single per-op cost table ([`PudOp::aaps_per_row`] /
/// [`PudOp::tras_per_row`]). This is the op-cost accounting for the
/// compiled W-bit `pud::arith` kernels: a 16-bit ripple-carry add is
/// ~80 bulk requests, and this rolls their AAP/TRA/ns/nJ charges into
/// one number the reports can put next to per-element throughput —
/// assuming full PUD execution (the fallback path prices itself).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchCost {
    /// Requests in the batch.
    pub reqs: usize,
    /// DRAM rows covered across all requests.
    pub rows: u64,
    /// Activate-Activate-Precharge sequences issued.
    pub aaps: u64,
    /// Triple-row activations among them.
    pub tras: u64,
    /// Analytic in-DRAM time, serial-equivalent.
    pub pud_ns: f64,
    /// Analytic in-DRAM energy.
    pub pud_nj: f64,
}

/// Roll up the per-row cost table over `reqs` (see [`BatchCost`]).
pub fn batch_cost(
    reqs: &[BulkRequest],
    row_bytes: u64,
    t: &crate::dram::timing::TimingParams,
    e: &crate::dram::energy::EnergyParams,
) -> BatchCost {
    let mut c = BatchCost {
        reqs: reqs.len(),
        ..Default::default()
    };
    for r in reqs {
        let rows = r.rows(row_bytes);
        c.rows += rows;
        c.aaps += rows * r.op.aaps_per_row();
        c.tras += rows * r.op.tras_per_row();
        c.pud_ns += rows as f64 * r.op.pud_row_ns(t);
        c.pud_nj += rows as f64 * r.op.pud_row_nj(e);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_matches_semantics() {
        assert_eq!(PudOp::Zero.arity(), 0);
        assert_eq!(PudOp::Copy.arity(), 1);
        assert_eq!(PudOp::Not.arity(), 1);
        assert_eq!(PudOp::And.arity(), 2);
        assert_eq!(PudOp::Or.arity(), 2);
        assert_eq!(PudOp::Xor.arity(), 2);
    }

    #[test]
    fn apply_bytes_semantics() {
        let a = [0b1100u8, 0xFF];
        let b = [0b1010u8, 0x0F];
        let mut d = [0u8; 2];
        PudOp::And.apply_bytes(&[&a, &b], &mut d);
        assert_eq!(d, [0b1000, 0x0F]);
        PudOp::Or.apply_bytes(&[&a, &b], &mut d);
        assert_eq!(d, [0b1110, 0xFF]);
        PudOp::Xor.apply_bytes(&[&a, &b], &mut d);
        assert_eq!(d, [0b0110, 0xF0]);
        PudOp::Not.apply_bytes(&[&a], &mut d);
        assert_eq!(d, [0xF3, 0x00]);
        PudOp::Copy.apply_bytes(&[&a], &mut d);
        assert_eq!(d, a);
        PudOp::Zero.apply_bytes(&[], &mut d);
        assert_eq!(d, [0, 0]);
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn request_arity_checked() {
        BulkRequest::new(PudOp::And, 0, vec![0], 64);
    }

    #[test]
    fn costs_agree_with_timing() {
        // one cost table: the analytic per-row ns of every op is its
        // AAP count times the AAP latency — XOR included (7 AAPs, not
        // a single TRA's worth)
        let t = crate::dram::timing::TimingParams::default();
        for op in PudOp::ALL {
            assert!(
                (op.pud_row_ns(&t) - op.aaps_per_row() as f64 * t.t_aap).abs()
                    < 1e-9,
                "{op}: timing and AAP table disagree"
            );
        }
        assert_eq!(PudOp::Xor.aaps_per_row(), 7);
        assert_eq!(PudOp::Xor.tras_per_row(), 3);
        assert!(PudOp::Xor.pud_row_ns(&t) > PudOp::And.pud_row_ns(&t));
    }

    #[test]
    fn costs_agree_with_energy() {
        let e = crate::dram::energy::EnergyParams::default();
        // XOR must be priced as the composite sequence
        assert!(
            PudOp::Xor.pud_row_nj(&e)
                > 2.0 * PudOp::And.pud_row_nj(&e) - e.aap_nj,
            "composite XOR cannot be cheaper than its constituent ops"
        );
        assert_eq!(
            PudOp::And.pud_row_nj(&e),
            4.0 * e.aap_nj + e.tra_nj,
            "AND: 4 AAPs + 1 TRA"
        );
        assert_eq!(
            PudOp::Xor.pud_row_nj(&e),
            7.0 * e.aap_nj + 3.0 * e.tra_nj,
            "XOR: 7 AAPs + 3 TRAs, never a single TRA"
        );
    }

    #[test]
    fn batch_cost_rolls_up_the_op_table() {
        let t = crate::dram::timing::TimingParams::default();
        let e = crate::dram::energy::EnergyParams::default();
        let row = 8192u64;
        let reqs = vec![
            BulkRequest::new(PudOp::And, 0x0, vec![0x1, 0x2], 2 * row),
            BulkRequest::new(PudOp::Xor, 0x3, vec![0x4, 0x5], row + 1), // 2 rows
            BulkRequest::new(PudOp::Zero, 0x6, vec![], row),
        ];
        let c = batch_cost(&reqs, row, &t, &e);
        assert_eq!(c.reqs, 3);
        assert_eq!(c.rows, 5);
        assert_eq!(c.aaps, 2 * 4 + 2 * 7 + 1);
        assert_eq!(c.tras, 2 + 2 * 3);
        let want_ns = 2.0 * PudOp::And.pud_row_ns(&t)
            + 2.0 * PudOp::Xor.pud_row_ns(&t)
            + PudOp::Zero.pud_row_ns(&t);
        assert!((c.pud_ns - want_ns).abs() < 1e-9);
        assert!(c.pud_nj > 0.0);
        assert_eq!(reqs[1].rows(row), 2);
    }

    #[test]
    fn kernel_names_match_artifacts() {
        for op in PudOp::ALL {
            assert!(!op.kernel_name().is_empty());
        }
        assert_eq!(PudOp::And.to_string(), "and");
    }
}
