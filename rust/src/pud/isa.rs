//! The bulk-operation instruction set.
//!
//! These are the operations the modeled substrate supports in-DRAM
//! (RowClone: `Zero`/`Copy`; Ambit: `And`/`Or`/`Not`/`Xor`) and that
//! the CPU fallback must therefore also implement (the L1 Pallas
//! kernel set mirrors this enum — see python/compile/kernels).

use std::fmt;

/// A bulk operation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PudOp {
    /// dst = 0 (RowClone zero-init from the control zero row).
    Zero,
    /// dst = src (RowClone copy).
    Copy,
    /// dst = a & b (Ambit TRA with C=0).
    And,
    /// dst = a | b (Ambit TRA with C=1).
    Or,
    /// dst = !a (Ambit dual-contact row).
    Not,
    /// dst = a ^ b (Ambit composite sequence).
    Xor,
}

impl PudOp {
    /// Number of *source* operands (dst excluded).
    pub fn arity(&self) -> usize {
        match self {
            PudOp::Zero => 0,
            PudOp::Copy | PudOp::Not => 1,
            PudOp::And | PudOp::Or | PudOp::Xor => 2,
        }
    }

    /// All ops, for sweeps.
    pub const ALL: [PudOp; 6] = [
        PudOp::Zero,
        PudOp::Copy,
        PudOp::And,
        PudOp::Or,
        PudOp::Not,
        PudOp::Xor,
    ];

    /// Analytic cost of one PUD-executed row of this op (matches the
    /// command sequences charged by [`crate::pud::exec::PudEngine`]:
    /// RowClone AAPs for `Zero`/`Copy`, Ambit sequences for the rest).
    /// The scheduler uses this to lay rows onto per-bank timelines
    /// without re-running the engine.
    pub fn pud_row_ns(&self, t: &crate::dram::timing::TimingParams) -> f64 {
        match self {
            PudOp::Zero => t.rowclone_zero_ns(1),
            PudOp::Copy => t.rowclone_fpm_ns(1),
            PudOp::Not => t.ambit_not_ns(1),
            PudOp::And | PudOp::Or => t.ambit_and_or_ns(1),
            PudOp::Xor => t.ambit_xor_ns(1),
        }
    }

    /// Artifact base name of the matching L1 kernel.
    pub fn kernel_name(&self) -> &'static str {
        match self {
            PudOp::Zero => "zero",
            PudOp::Copy => "copy",
            PudOp::And => "and",
            PudOp::Or => "or",
            PudOp::Not => "not",
            PudOp::Xor => "xor",
        }
    }

    /// Apply the op to byte slices (the scalar reference used by the
    /// simulator's own unit tests; the production fallback path runs
    /// the XLA artifacts instead).
    pub fn apply_bytes(&self, srcs: &[&[u8]], dst: &mut [u8]) {
        match self {
            PudOp::Zero => dst.fill(0),
            PudOp::Copy => dst.copy_from_slice(srcs[0]),
            PudOp::Not => {
                for (d, s) in dst.iter_mut().zip(srcs[0]) {
                    *d = !s;
                }
            }
            PudOp::And => {
                for ((d, a), b) in dst.iter_mut().zip(srcs[0]).zip(srcs[1]) {
                    *d = a & b;
                }
            }
            PudOp::Or => {
                for ((d, a), b) in dst.iter_mut().zip(srcs[0]).zip(srcs[1]) {
                    *d = a | b;
                }
            }
            PudOp::Xor => {
                for ((d, a), b) in dst.iter_mut().zip(srcs[0]).zip(srcs[1]) {
                    *d = a ^ b;
                }
            }
        }
    }
}

impl fmt::Display for PudOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.kernel_name())
    }
}

/// A bulk operation over *virtual* ranges of one process — what the
/// workloads submit to the coordinator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BulkRequest {
    pub op: PudOp,
    /// Destination virtual address.
    pub dst: u64,
    /// Source virtual addresses (`op.arity()` of them).
    pub srcs: Vec<u64>,
    /// Length in bytes (common to all operands).
    pub len: u64,
}

impl BulkRequest {
    pub fn new(op: PudOp, dst: u64, srcs: Vec<u64>, len: u64) -> Self {
        assert_eq!(srcs.len(), op.arity(), "arity mismatch for {op}");
        Self { op, dst, srcs, len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arity_matches_semantics() {
        assert_eq!(PudOp::Zero.arity(), 0);
        assert_eq!(PudOp::Copy.arity(), 1);
        assert_eq!(PudOp::Not.arity(), 1);
        assert_eq!(PudOp::And.arity(), 2);
        assert_eq!(PudOp::Or.arity(), 2);
        assert_eq!(PudOp::Xor.arity(), 2);
    }

    #[test]
    fn apply_bytes_semantics() {
        let a = [0b1100u8, 0xFF];
        let b = [0b1010u8, 0x0F];
        let mut d = [0u8; 2];
        PudOp::And.apply_bytes(&[&a, &b], &mut d);
        assert_eq!(d, [0b1000, 0x0F]);
        PudOp::Or.apply_bytes(&[&a, &b], &mut d);
        assert_eq!(d, [0b1110, 0xFF]);
        PudOp::Xor.apply_bytes(&[&a, &b], &mut d);
        assert_eq!(d, [0b0110, 0xF0]);
        PudOp::Not.apply_bytes(&[&a], &mut d);
        assert_eq!(d, [0xF3, 0x00]);
        PudOp::Copy.apply_bytes(&[&a], &mut d);
        assert_eq!(d, a);
        PudOp::Zero.apply_bytes(&[], &mut d);
        assert_eq!(d, [0, 0]);
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn request_arity_checked() {
        BulkRequest::new(PudOp::And, 0, vec![0], 64);
    }

    #[test]
    fn kernel_names_match_artifacts() {
        for op in PudOp::ALL {
            assert!(!op.kernel_name().is_empty());
        }
        assert_eq!(PudOp::And.to_string(), "and");
    }
}
