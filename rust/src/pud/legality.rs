//! PUD operand-placement legality — the rules PUMA exists to satisfy.
//!
//! A PUD instruction over N-row operands executes row-by-row; row `i`
//! of the operation is in-DRAM executable iff (paper §1):
//!
//! 1. every operand's row `i` starts at a DRAM row boundary
//!    (column == 0) and spans the full row (or is the common tail), and
//! 2. all operands' row `i` live in the **same subarray**, and
//! 3. none of them touch reserved (Ambit control/temp) rows.
//!
//! Operands arrive as physically-scattered extent lists (from
//! [`Process::phys_extents`](crate::os::process::Process::phys_extents));
//! [`check_rowwise`] aligns them row-by-row and emits a per-row plan
//! the executor and the fallback path both consume.

use crate::dram::address::InterleaveScheme;
use crate::dram::geometry::{Loc, SubarrayId};
use crate::os::process::PhysExtent;

use super::reserved::is_reserved;

/// Which PUMA placement requirement a fallback row violated — the
/// first failure found, in the order the legality walk checks them
/// (contiguity, then alignment, then reserved rows, then subarray
/// co-location). The linter and reports use this to answer "why not
/// PUD" per row instead of the old undifferentiated fallback count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FallbackCause {
    /// An operand's chunk is not physically contiguous (stitched from
    /// multiple extents), so it cannot be a single DRAM row.
    Fragmented,
    /// An operand's chunk is contiguous but does not start at a DRAM
    /// row boundary (column != 0).
    Misaligned,
    /// An operand's chunk lands in a reserved (Ambit control/temp) row.
    Reserved,
    /// Operand rows are individually legal but live in different
    /// subarrays, so no TRA can reach them together.
    CrossSubarray,
}

impl FallbackCause {
    pub const ALL: [FallbackCause; 4] = [
        FallbackCause::Fragmented,
        FallbackCause::Misaligned,
        FallbackCause::Reserved,
        FallbackCause::CrossSubarray,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FallbackCause::Fragmented => "fragmented",
            FallbackCause::Misaligned => "misaligned",
            FallbackCause::Reserved => "reserved",
            FallbackCause::CrossSubarray => "cross_subarray",
        }
    }
}

impl std::fmt::Display for FallbackCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-cause fallback-row counters, accumulated wherever fallback rows
/// are counted ([`ExecStats`](crate::pud::exec::ExecStats),
/// [`CoordStats`](crate::coordinator::stats::CoordStats), workload
/// reports). `total()` always equals the matching `fallback_rows`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CauseCounts {
    pub fragmented: u64,
    pub misaligned: u64,
    pub reserved: u64,
    pub cross_subarray: u64,
}

impl CauseCounts {
    pub fn add(&mut self, cause: FallbackCause, rows: u64) {
        match cause {
            FallbackCause::Fragmented => self.fragmented += rows,
            FallbackCause::Misaligned => self.misaligned += rows,
            FallbackCause::Reserved => self.reserved += rows,
            FallbackCause::CrossSubarray => self.cross_subarray += rows,
        }
    }

    pub fn get(&self, cause: FallbackCause) -> u64 {
        match cause {
            FallbackCause::Fragmented => self.fragmented,
            FallbackCause::Misaligned => self.misaligned,
            FallbackCause::Reserved => self.reserved,
            FallbackCause::CrossSubarray => self.cross_subarray,
        }
    }

    pub fn merge(&mut self, o: &CauseCounts) {
        self.fragmented += o.fragmented;
        self.misaligned += o.misaligned;
        self.reserved += o.reserved;
        self.cross_subarray += o.cross_subarray;
    }

    /// Per-cause deltas `self - earlier` (both from one monotonic
    /// counter stream, so the subtraction cannot underflow).
    pub fn delta(&self, earlier: &CauseCounts) -> CauseCounts {
        CauseCounts {
            fragmented: self.fragmented - earlier.fragmented,
            misaligned: self.misaligned - earlier.misaligned,
            reserved: self.reserved - earlier.reserved,
            cross_subarray: self.cross_subarray - earlier.cross_subarray,
        }
    }

    pub fn total(&self) -> u64 {
        self.fragmented + self.misaligned + self.reserved + self.cross_subarray
    }
}

/// Plan entry for one operation row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RowPlan {
    /// Executable in-DRAM: all operand rows co-located in `sid`.
    Pud {
        sid: SubarrayId,
        dst: Loc,
        srcs: Vec<Loc>,
        /// Bytes covered (== row_bytes except for the final partial row).
        bytes: u32,
    },
    /// Must fall back to the CPU: the physically-scattered extents of
    /// the destination and each source for this chunk (a chunk that
    /// *is* physically contiguous simply has one extent per operand).
    Fallback {
        dst: Vec<PhysExtent>,
        srcs: Vec<Vec<PhysExtent>>,
        bytes: u32,
        /// The first placement requirement this row violated.
        cause: FallbackCause,
    },
}

impl RowPlan {
    pub fn is_pud(&self) -> bool {
        matches!(self, RowPlan::Pud { .. })
    }

    pub fn bytes(&self) -> u32 {
        match self {
            RowPlan::Pud { bytes, .. } | RowPlan::Fallback { bytes, .. } => *bytes,
        }
    }

    /// Destination location of a PUD row (`None` for fallback rows).
    /// The batch scheduler uses this to place the row on its bank's
    /// command timeline.
    pub fn pud_dst(&self) -> Option<&Loc> {
        match self {
            RowPlan::Pud { dst, .. } => Some(dst),
            RowPlan::Fallback { .. } => None,
        }
    }

    /// Source-operand count of a fallback row (`None` for PUD rows).
    pub fn fallback_arity(&self) -> Option<usize> {
        match self {
            RowPlan::Fallback { srcs, .. } => Some(srcs.len()),
            RowPlan::Pud { .. } => None,
        }
    }

    /// Why this row fell back (`None` for PUD rows).
    pub fn fallback_cause(&self) -> Option<FallbackCause> {
        match self {
            RowPlan::Fallback { cause, .. } => Some(*cause),
            RowPlan::Pud { .. } => None,
        }
    }
}

/// Iterator-style cursor over an extent list.
struct ExtentCursor<'a> {
    extents: &'a [PhysExtent],
    idx: usize,
    off: u64,
}

impl<'a> ExtentCursor<'a> {
    fn new(extents: &'a [PhysExtent]) -> Self {
        Self {
            extents,
            idx: 0,
            off: 0,
        }
    }

    /// Physical address of the next `n` bytes if they are physically
    /// contiguous within the current extent; advances either way is
    /// deferred to `advance`.
    fn peek_contiguous(&self, n: u64) -> Option<u64> {
        let e = self.extents.get(self.idx)?;
        if self.off + n <= e.len {
            Some(e.paddr + self.off)
        } else {
            None
        }
    }

    /// The (possibly scattered) extents covering the next `n` bytes,
    /// without advancing.
    fn peek_extents(&self, mut n: u64) -> Vec<PhysExtent> {
        let mut out = Vec::new();
        let mut idx = self.idx;
        let mut off = self.off;
        while n > 0 {
            let e = &self.extents[idx];
            let take = (e.len - off).min(n);
            out.push(PhysExtent {
                paddr: e.paddr + off,
                len: take,
            });
            n -= take;
            off += take;
            if off == e.len {
                idx += 1;
                off = 0;
            }
        }
        out
    }

    fn advance(&mut self, mut n: u64) {
        while n > 0 {
            let e = &self.extents[self.idx];
            let left = e.len - self.off;
            if n < left {
                self.off += n;
                return;
            }
            n -= left;
            self.idx += 1;
            self.off = 0;
        }
    }
}

/// Build the row-by-row execution plan for an operation of `len`
/// bytes whose destination and sources have the given extents.
///
/// `extents[0]` is the destination; the rest are sources.
pub fn check_rowwise(
    scheme: &InterleaveScheme,
    operands: &[&[PhysExtent]],
    len: u64,
) -> Vec<RowPlan> {
    assert!(!operands.is_empty(), "need at least the destination");
    let row_bytes = scheme.geometry.row_bytes as u64;
    let mut cursors: Vec<ExtentCursor> =
        operands.iter().map(|e| ExtentCursor::new(e)).collect();
    let mut plan = Vec::with_capacity((len / row_bytes + 1) as usize);
    let mut remaining = len;
    while remaining > 0 {
        let chunk = remaining.min(row_bytes);
        // try the PUD condition for this row across all operands,
        // recording the first requirement that fails
        let mut locs: Vec<Loc> = Vec::with_capacity(cursors.len());
        let mut fail: Option<FallbackCause> = None;
        for cur in &cursors {
            match cur.peek_contiguous(chunk) {
                Some(pa) => {
                    let loc = scheme.decode(pa);
                    // row-aligned, full row (or common tail starting at 0)
                    if loc.column != 0 {
                        fail = Some(FallbackCause::Misaligned);
                        break;
                    }
                    if is_reserved(&scheme.geometry, loc.row) {
                        fail = Some(FallbackCause::Reserved);
                        break;
                    }
                    locs.push(loc);
                }
                None => {
                    fail = Some(FallbackCause::Fragmented);
                    break;
                }
            }
        }
        if fail.is_none() {
            // same-subarray across every operand
            let sid0 = scheme.geometry.subarray_id(&locs[0]);
            let co_located = locs
                .iter()
                .all(|l| scheme.geometry.subarray_id(l) == sid0);
            // NOTE: operand aliasing (dst row == src row) is fine on
            // the real substrate: Ambit stages operands into the
            // reserved temp rows before the TRA, so in-place ops like
            // `scratch &= b` are legal; RowClone copy-to-self is an
            // identity. No distinctness requirement here.
            if co_located {
                plan.push(RowPlan::Pud {
                    sid: sid0,
                    dst: locs[0],
                    srcs: locs[1..].to_vec(),
                    bytes: chunk as u32,
                });
                for cur in &mut cursors {
                    cur.advance(chunk);
                }
                remaining -= chunk;
                continue;
            }
            fail = Some(FallbackCause::CrossSubarray);
        }
        // fallback for this row: capture the scatter lists
        let dst = cursors[0].peek_extents(chunk);
        let srcs: Vec<Vec<PhysExtent>> = cursors[1..]
            .iter()
            .map(|c| c.peek_extents(chunk))
            .collect();
        plan.push(RowPlan::Fallback {
            dst,
            srcs,
            bytes: chunk as u32,
            cause: fail.expect("fallback row always has a cause"),
        });
        for cur in &mut cursors {
            cur.advance(chunk);
        }
        remaining -= chunk;
    }
    plan
}

/// Fraction of the operation's rows that are PUD-executable.
pub fn pud_fraction(plan: &[RowPlan]) -> f64 {
    if plan.is_empty() {
        return 0.0;
    }
    plan.iter().filter(|p| p.is_pud()).count() as f64 / plan.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::DramGeometry;

    fn scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 4,
            rows_per_subarray: 64,
            row_bytes: 256,
        })
    }

    fn ext(paddr: u64, len: u64) -> Vec<PhysExtent> {
        vec![PhysExtent { paddr, len }]
    }

    #[test]
    fn perfectly_aligned_operands_all_pud() {
        let s = scheme();
        // rows 0,1 vs rows 2,3 vs rows 4,5 of subarray 0 (row stride =
        // row_bytes * banks = 512 in this scheme)
        let stride = 512u64;
        // NOTE: extents are contiguous in *physical address*, but rows
        // of one subarray are strided. A 512-byte contiguous extent at
        // 0 covers row 0 of subarray 0 AND row 0 of bank 1's subarray.
        // For full-row ops we feed row-sized operands:
        let dst = ext(0, 256);
        let a = ext(2 * stride, 256);
        let b = ext(4 * stride, 256);
        let plan = check_rowwise(&s, &[&dst, &a, &b], 256);
        assert_eq!(plan.len(), 1);
        assert!(plan[0].is_pud());
        assert_eq!(pud_fraction(&plan), 1.0);
    }

    #[test]
    fn misaligned_operand_forces_fallback() {
        let s = scheme();
        let dst = ext(0, 256);
        let a = ext(100, 256); // not row-aligned
        let plan = check_rowwise(&s, &[&dst, &a], 256);
        assert_eq!(plan.len(), 1);
        assert!(!plan[0].is_pud());
    }

    #[test]
    fn cross_subarray_operands_fall_back() {
        let s = scheme();
        let g = &s.geometry;
        let dst = ext(0, 256); // subarray id 0
        // an address in a different subarray, row-aligned
        let sid1_addr = s.row_start_addr(crate::dram::geometry::SubarrayId(1), 0);
        let a = ext(sid1_addr, 256);
        assert_ne!(s.subarray_id(0), s.subarray_id(sid1_addr));
        let plan = check_rowwise(&s, &[&dst, &a], 256);
        assert!(!plan[0].is_pud());
        let _ = g;
    }

    #[test]
    fn reserved_rows_force_fallback() {
        let s = scheme();
        let sid = crate::dram::geometry::SubarrayId(0);
        // row 60 is reserved (64 - 8 = 56 usable)
        let reserved_addr = s.row_start_addr(sid, 60);
        let ok_addr = s.row_start_addr(sid, 0);
        let plan = check_rowwise(&s, &[&ext(reserved_addr, 256), &ext(ok_addr, 256)], 256);
        assert!(!plan[0].is_pud());
    }

    #[test]
    fn aliased_operands_are_still_pud() {
        // in-place ops (dst == src) stay on the PUD path: Ambit stages
        // operands into temp rows before the TRA
        let s = scheme();
        let dst = ext(0, 256);
        let a = ext(0, 256); // same row as dst
        let plan = check_rowwise(&s, &[&dst, &a], 256);
        assert!(plan[0].is_pud());
    }

    #[test]
    fn partial_tail_row_still_pud() {
        // final chunk < row_bytes with all operands row-aligned
        let s = scheme();
        let sid = crate::dram::geometry::SubarrayId(0);
        let dst = ext(s.row_start_addr(sid, 0), 100);
        let a = ext(s.row_start_addr(sid, 1), 100);
        let plan = check_rowwise(&s, &[&dst, &a], 100);
        assert_eq!(plan.len(), 1);
        assert!(plan[0].is_pud());
        assert_eq!(plan[0].bytes(), 100);
    }

    #[test]
    fn mixed_plan_counts_fraction() {
        let s = scheme();
        let sid = crate::dram::geometry::SubarrayId(0);
        let r0 = s.row_start_addr(sid, 0);
        let r1 = s.row_start_addr(sid, 1);
        let r2 = s.row_start_addr(sid, 2);
        let r3 = s.row_start_addr(sid, 3);
        // dst: row 0 then a misaligned piece; src: rows 2, 3
        let dst = vec![
            PhysExtent { paddr: r0, len: 256 },
            PhysExtent {
                paddr: r1 + 64,
                len: 256,
            },
        ];
        let s_ext = vec![
            PhysExtent { paddr: r2, len: 256 },
            PhysExtent { paddr: r3, len: 256 },
        ];
        let plan = check_rowwise(&s, &[&dst, &s_ext], 512);
        assert_eq!(plan.len(), 2);
        assert!(plan[0].is_pud());
        assert!(!plan[1].is_pud());
        assert!((pud_fraction(&plan) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fragmented_extent_breaks_contiguity() {
        let s = scheme();
        let sid = crate::dram::geometry::SubarrayId(0);
        let r0 = s.row_start_addr(sid, 0);
        // destination's "row" is stitched from two 128-byte pieces
        let dst = vec![
            PhysExtent {
                paddr: r0,
                len: 128,
            },
            PhysExtent {
                paddr: r0 + 4096,
                len: 128,
            },
        ];
        let src = ext(s.row_start_addr(sid, 1), 256);
        let plan = check_rowwise(&s, &[&dst, &src], 256);
        assert!(!plan[0].is_pud());
    }

    #[test]
    fn fallback_causes_are_attributed() {
        let s = scheme();
        let sid = crate::dram::geometry::SubarrayId(0);
        // misaligned: contiguous but column != 0
        let plan =
            check_rowwise(&s, &[&ext(0, 256), &ext(100, 256)], 256);
        assert_eq!(
            plan[0].fallback_cause(),
            Some(FallbackCause::Misaligned)
        );
        // fragmented: chunk stitched from two extents
        let frag = vec![
            PhysExtent {
                paddr: s.row_start_addr(sid, 0),
                len: 128,
            },
            PhysExtent {
                paddr: s.row_start_addr(sid, 0) + 4096,
                len: 128,
            },
        ];
        let src = ext(s.row_start_addr(sid, 1), 256);
        let plan = check_rowwise(&s, &[&frag, &src], 256);
        assert_eq!(
            plan[0].fallback_cause(),
            Some(FallbackCause::Fragmented)
        );
        // reserved: row 60 >= 56 usable rows
        let plan = check_rowwise(
            &s,
            &[&ext(s.row_start_addr(sid, 60), 256), &src],
            256,
        );
        assert_eq!(plan[0].fallback_cause(), Some(FallbackCause::Reserved));
        // cross-subarray: both legal alone, different subarrays
        let other = ext(
            s.row_start_addr(crate::dram::geometry::SubarrayId(1), 0),
            256,
        );
        let plan =
            check_rowwise(&s, &[&ext(s.row_start_addr(sid, 0), 256), &other], 256);
        assert_eq!(
            plan[0].fallback_cause(),
            Some(FallbackCause::CrossSubarray)
        );
        // PUD rows carry no cause
        let plan = check_rowwise(
            &s,
            &[&ext(s.row_start_addr(sid, 0), 256), &src],
            256,
        );
        assert_eq!(plan[0].fallback_cause(), None);
    }

    #[test]
    fn cause_counts_accumulate_and_delta() {
        let mut c = CauseCounts::default();
        c.add(FallbackCause::Misaligned, 3);
        c.add(FallbackCause::Reserved, 1);
        let mut d = CauseCounts::default();
        d.add(FallbackCause::Misaligned, 2);
        d.add(FallbackCause::CrossSubarray, 4);
        c.merge(&d);
        assert_eq!(c.misaligned, 5);
        assert_eq!(c.reserved, 1);
        assert_eq!(c.cross_subarray, 4);
        assert_eq!(c.total(), 10);
        let delta = c.delta(&d);
        assert_eq!(delta.misaligned, 3);
        assert_eq!(delta.cross_subarray, 0);
        assert_eq!(delta.total(), 4);
        for cause in FallbackCause::ALL {
            assert_eq!(c.get(cause) - delta.get(cause), d.get(cause));
        }
    }

    #[test]
    fn zero_arity_ops_need_only_dst_placement() {
        let s = scheme();
        let sid = crate::dram::geometry::SubarrayId(2);
        let dst = ext(s.row_start_addr(sid, 5), 256);
        let plan = check_rowwise(&s, &[&dst], 256);
        assert!(plan[0].is_pud());
    }
}
