//! Lowering: turn an optimized expression into the topologically
//! ordered [`BulkRequest`] batch the coordinator executes as ONE
//! `submit_batch`.
//!
//! [`compile`] runs the whole pipeline — optimize → emission order →
//! scratch register allocation — and freezes the result as a
//! [`Compiled`] program plus its [`CompileStats`]. [`Compiled::emit`]
//! then binds the program to concrete addresses: operand VAs for the
//! leaves, the destination VA for the root, and leased scratch VAs for
//! the intermediates. Because requests are emitted in topological
//! order, the PR-1 hazard-wave scheduler recovers exactly the DAG's
//! dependence structure: independent subtrees land in one wave and
//! overlap across banks, dependent chains serialize.

use anyhow::{ensure, Result};
use rustc_hash::FxHashMap;

use crate::pud::isa::{BulkRequest, PudOp};

use super::expr::{Expr, ExprId, MultiExpr, Node};
use super::opt::{optimize, optimize_multi};
use super::regalloc::{
    allocate, allocate_multi, emission_order, emission_order_multi, Assignment,
};

/// Preferred resident size of the compiler's scratch pool; expressions
/// needing more lease extra rows (counted as spills).
pub const DEFAULT_SCRATCH_POOL: usize = 4;

/// Per-expression compilation report (the execution-side PUD/fallback
/// row split is reported by
/// [`ExprReport`](crate::coordinator::system::ExprReport), which
/// carries these stats alongside it).
#[derive(Debug, Clone, Default)]
pub struct CompileStats {
    /// Distinct operand buffers the expression reads.
    pub leaves: usize,
    /// Reachable DAG nodes before / after optimization.
    pub nodes_in: usize,
    pub nodes_opt: usize,
    /// Bulk requests the program emits.
    pub ops: usize,
    /// NOT requests among them (each burns a dual-contact-row pass).
    pub not_ops: usize,
    /// Scratch slots the program needs simultaneously.
    pub scratch_slots: usize,
    /// Slots past the preferred pool bound.
    pub spills: usize,
    /// Optimizer counters.
    pub cse_hits: usize,
    pub folds: usize,
    pub demorgans: usize,
    /// Fresh compilation passes behind this report: 1 when the program
    /// was compiled for this call, 0 when it was served from the
    /// system's `(ArithOp, width)` program cache — the counter tests
    /// assert to prove a repeat invocation does zero compile work.
    pub compiles: usize,
}

/// A compiled expression: optimized DAG + emission order + slot
/// assignment, ready to bind to addresses any number of times.
pub struct Compiled {
    expr: Expr,
    order: Vec<ExprId>,
    assignment: Assignment,
    pub stats: CompileStats,
}

/// Compile with the default scratch-pool bound.
pub fn compile(expr: &Expr) -> Compiled {
    compile_with_pool(expr, DEFAULT_SCRATCH_POOL)
}

/// Requests (total, NOTs) one emitted non-leaf node expands to.
fn node_ops(n: Node) -> (usize, usize) {
    match n {
        Node::Leaf(_) => unreachable!("leaves are not emitted"),
        Node::Const(true) => (2, 1), // Zero + in-place NOT
        Node::Const(false) => (1, 0),
        Node::Not(_) => (1, 1),
        Node::AndNot(..) => (2, 1),
        Node::And(..) | Node::Or(..) | Node::Xor(..) => (1, 0),
    }
}

/// Append the request(s) computing `node` into `p`, with operand
/// placement `place`. Shared by the single- and multi-output emitters
/// so the two lowerings cannot drift apart.
fn push_node_reqs<F: Fn(ExprId) -> u64>(
    reqs: &mut Vec<BulkRequest>,
    node: Node,
    p: u64,
    place: &F,
    len: u64,
) {
    match node {
        Node::Leaf(_) => unreachable!("leaves are not emitted"),
        Node::Const(v) => {
            reqs.push(BulkRequest::new(PudOp::Zero, p, vec![], len));
            if v {
                reqs.push(BulkRequest::new(PudOp::Not, p, vec![p], len));
            }
        }
        Node::Not(a) => {
            reqs.push(BulkRequest::new(PudOp::Not, p, vec![place(a)], len));
        }
        Node::And(a, b) => {
            reqs.push(BulkRequest::new(
                PudOp::And,
                p,
                vec![place(a), place(b)],
                len,
            ));
        }
        Node::Or(a, b) => {
            reqs.push(BulkRequest::new(
                PudOp::Or,
                p,
                vec![place(a), place(b)],
                len,
            ));
        }
        Node::Xor(a, b) => {
            reqs.push(BulkRequest::new(
                PudOp::Xor,
                p,
                vec![place(a), place(b)],
                len,
            ));
        }
        Node::AndNot(a, b) => {
            // p = !b; p = a & p. Defensive: `compile()` always
            // optimizes, and the optimizer canonicalizes AndNot to
            // And(a, Not(b)), so this arm only runs if compilation
            // ever grows a no-opt path. The register allocator's
            // matching carve-out guarantees p aliases neither live
            // operand.
            reqs.push(BulkRequest::new(PudOp::Not, p, vec![place(b)], len));
            reqs.push(BulkRequest::new(PudOp::And, p, vec![place(a), p], len));
        }
    }
}

/// Compile with an explicit preferred scratch-pool bound.
pub fn compile_with_pool(expr: &Expr, pool_limit: usize) -> Compiled {
    let (opt, rep) = optimize(expr);
    let order = emission_order(&opt);
    let assignment = allocate(&opt, &order, pool_limit.max(1));
    let (mut ops, mut not_ops) = (0usize, 0usize);
    for &id in &order {
        let (o, n) = node_ops(opt.node(id));
        ops += o;
        not_ops += n;
    }
    if order.is_empty() {
        ops = 1; // leaf root: one RowClone copy
    }
    let stats = CompileStats {
        leaves: opt.n_leaves(),
        nodes_in: rep.nodes_before,
        nodes_opt: rep.nodes_after,
        ops,
        not_ops,
        scratch_slots: assignment.slots_needed,
        spills: assignment.spills,
        cse_hits: rep.cse_hits,
        folds: rep.folds,
        demorgans: rep.demorgans,
        compiles: 1,
    };
    Compiled {
        expr: opt,
        order,
        assignment,
        stats,
    }
}

impl Compiled {
    /// The optimized expression this program computes.
    pub fn expr(&self) -> &Expr {
        &self.expr
    }

    /// Scratch buffers `emit` needs (lease this many before binding).
    pub fn scratch_needed(&self) -> usize {
        self.assignment.slots_needed
    }

    /// Operand buffers the program reads.
    pub fn n_leaves(&self) -> usize {
        self.stats.leaves
    }

    /// Bind the program to addresses: `operands[i]` backs `Leaf(i)`,
    /// the root writes `dst`, intermediates use `scratch` slots. All
    /// buffers are `len` bytes. The returned batch is in topological
    /// order and is meant to be submitted as one
    /// `Coordinator::submit_batch`.
    pub fn emit(
        &self,
        operands: &[u64],
        dst: u64,
        len: u64,
        scratch: &[u64],
    ) -> Result<Vec<BulkRequest>> {
        ensure!(len > 0, "zero-length expression operands");
        ensure!(
            self.n_leaves() <= operands.len(),
            "expression reads {} operand(s), {} supplied",
            self.n_leaves(),
            operands.len()
        );
        ensure!(
            scratch.len() >= self.assignment.slots_needed,
            "need {} scratch buffer(s), {} leased",
            self.assignment.slots_needed,
            scratch.len()
        );
        let root = self.expr.root();
        let place = |id: ExprId| -> u64 {
            if id == root {
                dst
            } else {
                match self.expr.node(id) {
                    Node::Leaf(i) => operands[i],
                    _ => scratch[self.assignment.slot[&id]],
                }
            }
        };
        let mut reqs = Vec::with_capacity(self.stats.ops);
        if self.order.is_empty() {
            // root is a leaf: dst = copy(operand)
            let Node::Leaf(i) = self.expr.node(root) else {
                unreachable!("empty order implies a leaf root");
            };
            reqs.push(BulkRequest::new(PudOp::Copy, dst, vec![operands[i]], len));
            return Ok(reqs);
        }
        for &id in &self.order {
            push_node_reqs(&mut reqs, self.expr.node(id), place(id), &place, len);
        }
        debug_assert_eq!(reqs.len(), self.stats.ops);
        Ok(reqs)
    }
}

/// A compiled multi-output program: optimized DAG + emission order +
/// slot assignment + output ownership, ready to bind any number of
/// times. This is the program form behind `pud::arith` — a W-bit
/// kernel's sum/carry chain is one arena, its W result bit-planes are
/// the roots, and the whole thing is emitted as ONE
/// `Coordinator::submit_batch`.
pub struct CompiledMulti {
    expr: MultiExpr,
    order: Vec<ExprId>,
    assignment: Assignment,
    /// First root index owning each non-leaf root node: that root's
    /// dst receives the compute; later duplicate roots copy from it.
    owner: FxHashMap<ExprId, usize>,
    pub stats: CompileStats,
}

/// Compile a multi-output program with the default scratch-pool bound.
pub fn compile_multi(m: &MultiExpr) -> CompiledMulti {
    compile_multi_with_pool(m, DEFAULT_SCRATCH_POOL)
}

/// Compile a multi-output program with an explicit preferred
/// scratch-pool bound.
pub fn compile_multi_with_pool(m: &MultiExpr, pool_limit: usize) -> CompiledMulti {
    let (opt, rep) = optimize_multi(m);
    let order = emission_order_multi(&opt);
    let assignment = allocate_multi(&opt, &order, pool_limit.max(1));
    let (mut ops, mut not_ops) = (0usize, 0usize);
    for &id in &order {
        let (o, n) = node_ops(opt.node(id));
        ops += o;
        not_ops += n;
    }
    // outputs that are leaves, or that CSE'd onto an earlier output's
    // node, cost one RowClone copy each
    let mut owner: FxHashMap<ExprId, usize> = FxHashMap::default();
    for (ri, &r) in opt.roots().iter().enumerate() {
        if matches!(opt.node(r), Node::Leaf(_)) {
            ops += 1;
        } else if owner.contains_key(&r) {
            ops += 1;
        } else {
            owner.insert(r, ri);
        }
    }
    let stats = CompileStats {
        leaves: opt.n_leaves(),
        nodes_in: rep.nodes_before,
        nodes_opt: rep.nodes_after,
        ops,
        not_ops,
        scratch_slots: assignment.slots_needed,
        spills: assignment.spills,
        cse_hits: rep.cse_hits,
        folds: rep.folds,
        demorgans: rep.demorgans,
        compiles: 1,
    };
    CompiledMulti {
        expr: opt,
        order,
        assignment,
        owner,
        stats,
    }
}

impl CompiledMulti {
    /// The optimized program.
    pub fn expr(&self) -> &MultiExpr {
        &self.expr
    }

    /// Scratch buffers `emit` needs (lease this many before binding).
    pub fn scratch_needed(&self) -> usize {
        self.assignment.slots_needed
    }

    /// Operand buffers the program reads.
    pub fn n_leaves(&self) -> usize {
        self.stats.leaves
    }

    /// Output buffers the program writes.
    pub fn n_outputs(&self) -> usize {
        self.expr.n_outputs()
    }

    /// Bind the program to addresses: `operands[i]` backs `Leaf(i)`,
    /// output `k` writes `dsts[k]`, intermediates use `scratch` slots.
    /// All buffers are `len` bytes. The returned batch is in
    /// topological order and is meant to run as one
    /// `Coordinator::submit_batch`.
    ///
    /// `dsts` must be pairwise distinct and disjoint from both
    /// `scratch` and `operands`: a root's dst is written at its
    /// topological position, mid-batch, so a dst aliasing an operand
    /// would clobber it for every later request that still reads it
    /// (unlike the single-output `Compiled::emit`, where the root
    /// write is always the final request).
    pub fn emit(
        &self,
        operands: &[u64],
        dsts: &[u64],
        len: u64,
        scratch: &[u64],
    ) -> Result<Vec<BulkRequest>> {
        ensure!(len > 0, "zero-length program operands");
        ensure!(
            self.n_leaves() <= operands.len(),
            "program reads {} operand(s), {} supplied",
            self.n_leaves(),
            operands.len()
        );
        ensure!(
            dsts.len() == self.expr.n_outputs(),
            "program writes {} output(s), {} dst buffer(s) supplied",
            self.expr.n_outputs(),
            dsts.len()
        );
        ensure!(
            scratch.len() >= self.assignment.slots_needed,
            "need {} scratch buffer(s), {} leased",
            self.assignment.slots_needed,
            scratch.len()
        );
        for (i, d) in dsts.iter().enumerate() {
            for d2 in &dsts[i + 1..] {
                ensure!(d != d2, "dst buffer {d:#x} is bound to two outputs");
            }
        }
        for s in &scratch[..self.assignment.slots_needed] {
            ensure!(
                !dsts.contains(s),
                "scratch buffer {s:#x} aliases a dst buffer"
            );
        }
        for d in dsts {
            ensure!(
                !operands.contains(d),
                "dst buffer {d:#x} aliases an operand buffer (dsts are \
                 written mid-batch)"
            );
        }
        let place = |id: ExprId| -> u64 {
            if let Some(&ri) = self.owner.get(&id) {
                return dsts[ri];
            }
            match self.expr.node(id) {
                Node::Leaf(i) => operands[i],
                _ => scratch[self.assignment.slot[&id]],
            }
        };
        let mut reqs = Vec::with_capacity(self.stats.ops);
        for &id in &self.order {
            push_node_reqs(&mut reqs, self.expr.node(id), place(id), &place, len);
        }
        // output copies: leaf outputs read their operand, duplicate
        // outputs read the owning dst — both stay valid to the end of
        // the batch (operands are never written, dsts never recycled)
        for (ri, &r) in self.expr.roots().iter().enumerate() {
            match self.expr.node(r) {
                Node::Leaf(i) => reqs.push(BulkRequest::new(
                    PudOp::Copy,
                    dsts[ri],
                    vec![operands[i]],
                    len,
                )),
                _ => {
                    let own = self.owner[&r];
                    if own != ri {
                        reqs.push(BulkRequest::new(
                            PudOp::Copy,
                            dsts[ri],
                            vec![dsts[own]],
                            len,
                        ));
                    }
                }
            }
        }
        debug_assert_eq!(reqs.len(), self.stats.ops);
        Ok(reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::compiler::expr::ExprBuilder;
    use rustc_hash::FxHashMap;

    /// Interpret an emitted batch over plain byte buffers — a
    /// System-free check that lowering matches the IR's reference
    /// evaluator.
    fn interpret(
        reqs: &[BulkRequest],
        bufs: &mut FxHashMap<u64, Vec<u8>>,
        len: usize,
    ) {
        for r in reqs {
            let srcs: Vec<Vec<u8>> = r
                .srcs
                .iter()
                .map(|va| bufs.get(va).cloned().unwrap_or_else(|| vec![0u8; len]))
                .collect();
            let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
            let mut out = vec![0u8; len];
            r.op.apply_bytes(&refs, &mut out);
            bufs.insert(r.dst, out);
        }
    }

    fn check_against_reference(e: &crate::pud::compiler::Expr, seed: u64) {
        let len = 8usize;
        let n = e.n_leaves();
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let mut bufs: FxHashMap<u64, Vec<u8>> = FxHashMap::default();
        let mut operands = Vec::new();
        for i in 0..n {
            let mut v = vec![0u8; len];
            rng.fill_bytes(&mut v);
            let va = 0x1000 + i as u64 * 0x100;
            bufs.insert(va, v);
            operands.push(va);
        }
        let c = compile(e);
        let scratch: Vec<u64> =
            (0..c.scratch_needed()).map(|i| 0x9000 + i as u64 * 0x100).collect();
        let dst = 0x8000u64;
        let reqs = c.emit(&operands, dst, len as u64, &scratch).unwrap();
        assert_eq!(reqs.len(), c.stats.ops);
        let leaves: Vec<Vec<u8>> =
            operands.iter().map(|va| bufs[va].clone()).collect();
        interpret(&reqs, &mut bufs, len);
        let refs: Vec<&[u8]> = leaves.iter().map(|v| v.as_slice()).collect();
        let want = e.eval_bytes(&refs, len).unwrap();
        assert_eq!(bufs[&dst], want, "lowering diverged for {e}");
        // sources must survive (the substrate stages operands)
        for (va, orig) in operands.iter().zip(&leaves) {
            assert_eq!(&bufs[va], orig, "operand clobbered");
        }
    }

    #[test]
    fn three_clause_predicate_lowers_and_matches() {
        let mut b = ExprBuilder::new();
        let c: Vec<_> = (0..5).map(|i| b.leaf(i)).collect();
        let n2 = b.not(c[2]);
        let conj = b.and(c[0], c[1]);
        let left = b.and(conj, n2);
        let x = b.xor(c[3], c[4]);
        let r = b.or(left, x);
        let e = b.build(r);
        check_against_reference(&e, 11);
        let comp = compile(&e);
        assert_eq!(comp.n_leaves(), 5);
        assert!(comp.scratch_needed() >= 1);
        assert!(comp.stats.not_ops >= 1);
    }

    #[test]
    fn leaf_root_lowers_to_copy() {
        let mut b = ExprBuilder::new();
        let l = b.leaf(0);
        let e = b.build(l);
        let c = compile(&e);
        assert_eq!(c.scratch_needed(), 0);
        let reqs = c.emit(&[0x4000], 0x5000, 64, &[]).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].op, PudOp::Copy);
        assert_eq!(reqs[0].dst, 0x5000);
        assert_eq!(reqs[0].srcs, vec![0x4000]);
        check_against_reference(&e, 12);
    }

    #[test]
    fn const_roots_lower_via_control_rows() {
        for v in [false, true] {
            let mut b = ExprBuilder::new();
            let k = b.constant(v);
            let e = b.build(k);
            let c = compile(&e);
            let reqs = c.emit(&[], 0x5000, 64, &[]).unwrap();
            assert_eq!(reqs[0].op, PudOp::Zero);
            assert_eq!(reqs.len(), if v { 2 } else { 1 });
            check_against_reference(&e, 13);
        }
    }

    #[test]
    fn andnot_and_dedup_lower_correctly() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let d = b.and_not(l0, l1);
        let n1 = b.not(l1); // shared with the canonicalized AndNot
        let r = b.xor(d, n1);
        let e = b.build(r);
        check_against_reference(&e, 14);
        let c = compile(&e);
        assert!(c.stats.cse_hits >= 1);
    }

    #[test]
    fn emit_validates_bindings() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let a = b.and(l0, l1);
        let r = b.not(a);
        let e = b.build(r);
        let c = compile(&e);
        assert!(c.emit(&[0x1000], 0x5000, 64, &[0x9000]).is_err(), "missing operand");
        assert!(
            c.emit(&[0x1000, 0x2000], 0x5000, 64, &[]).is_err(),
            "missing scratch"
        );
        assert!(
            c.emit(&[0x1000, 0x2000], 0x5000, 0, &[0x9000]).is_err(),
            "zero length"
        );
        assert!(c.emit(&[0x1000, 0x2000], 0x5000, 64, &[0x9000]).is_ok());
    }

    fn check_multi_against_reference(
        m: &crate::pud::compiler::MultiExpr,
        seed: u64,
    ) {
        let len = 8usize;
        let n = m.n_leaves();
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let mut bufs: FxHashMap<u64, Vec<u8>> = FxHashMap::default();
        let mut operands = Vec::new();
        for i in 0..n {
            let mut v = vec![0u8; len];
            rng.fill_bytes(&mut v);
            let va = 0x1000 + i as u64 * 0x100;
            bufs.insert(va, v);
            operands.push(va);
        }
        let c = compile_multi(m);
        let scratch: Vec<u64> = (0..c.scratch_needed())
            .map(|i| 0x9000 + i as u64 * 0x100)
            .collect();
        let dsts: Vec<u64> = (0..c.n_outputs())
            .map(|i| 0x8000_0000 + i as u64 * 0x100)
            .collect();
        let reqs = c.emit(&operands, &dsts, len as u64, &scratch).unwrap();
        assert_eq!(reqs.len(), c.stats.ops);
        let leaves: Vec<Vec<u8>> =
            operands.iter().map(|va| bufs[va].clone()).collect();
        interpret(&reqs, &mut bufs, len);
        let refs: Vec<&[u8]> = leaves.iter().map(|v| v.as_slice()).collect();
        let want = m.eval_bytes(&refs, len).unwrap();
        for (k, d) in dsts.iter().enumerate() {
            assert_eq!(bufs[d], want[k], "output {k} diverged");
        }
        for (va, orig) in operands.iter().zip(&leaves) {
            assert_eq!(&bufs[va], orig, "operand clobbered");
        }
    }

    #[test]
    fn multi_full_adder_lowers_and_matches() {
        // one shared carry chain, two outputs (sum, carry)
        let mut b = ExprBuilder::new();
        let x = b.leaf(0);
        let y = b.leaf(1);
        let cin = b.leaf(2);
        let t = b.xor(x, y);
        let s = b.xor(t, cin);
        let g = b.and(x, y);
        let p = b.and(t, cin);
        let co = b.or(g, p);
        let m = b.build_multi(vec![s, co]);
        check_multi_against_reference(&m, 21);
        let c = compile_multi(&m);
        assert_eq!(c.n_outputs(), 2);
        assert_eq!(c.n_leaves(), 3);
        // the shared t = x^y needs scratch; the outputs write dsts
        assert!(c.scratch_needed() >= 1);
    }

    #[test]
    fn multi_leaf_and_duplicate_outputs_lower_to_copies() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let a = b.and(l0, l1);
        let m = b.build_multi(vec![a, l0, a]);
        check_multi_against_reference(&m, 22);
        let c = compile_multi(&m);
        // one AND + two copies (leaf output, duplicate output)
        assert_eq!(c.stats.ops, 3);
        let reqs = c
            .emit(&[0x1000, 0x1100], &[0x8000, 0x8100, 0x8200], 64, &[])
            .unwrap();
        assert_eq!(reqs[0].op, PudOp::And);
        assert_eq!(reqs[0].dst, 0x8000);
        assert_eq!(reqs[1].op, PudOp::Copy);
        assert_eq!(reqs[1].srcs, vec![0x1000]);
        assert_eq!(reqs[2].op, PudOp::Copy);
        assert_eq!(reqs[2].srcs, vec![0x8000]);
    }

    #[test]
    fn multi_consumed_output_stays_readable() {
        // c1 = x & y is an output AND feeds s1 = z ^ c1
        let mut b = ExprBuilder::new();
        let x = b.leaf(0);
        let y = b.leaf(1);
        let z = b.leaf(2);
        let c1 = b.and(x, y);
        let s1 = b.xor(z, c1);
        let m = b.build_multi(vec![c1, s1]);
        check_multi_against_reference(&m, 23);
        let c = compile_multi(&m);
        assert_eq!(c.scratch_needed(), 0, "both values live in dsts");
    }

    #[test]
    fn multi_emit_validates_bindings() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let a = b.and(l0, l1);
        let o = b.or(l0, a);
        let m = b.build_multi(vec![a, o]);
        let c = compile_multi(&m);
        let ops = [0x1000u64, 0x1100];
        assert!(c.emit(&ops, &[0x8000], 64, &[]).is_err(), "dst count");
        assert!(
            c.emit(&ops, &[0x8000, 0x8000], 64, &[]).is_err(),
            "duplicate dst"
        );
        assert!(
            c.emit(&ops, &[0x1000, 0x8100], 64, &[]).is_err(),
            "dst aliasing an operand (written mid-batch)"
        );
        assert!(c.emit(&ops, &[0x8000, 0x8100], 64, &[]).is_ok());
        check_multi_against_reference(&m, 24);
    }

    #[test]
    fn requests_are_topologically_ordered() {
        // every request's scratch sources were written earlier
        let mut b = ExprBuilder::new();
        let c: Vec<_> = (0..4).map(|i| b.leaf(i)).collect();
        let a1 = b.and(c[0], c[1]);
        let a2 = b.or(c[2], c[3]);
        let m = b.xor(a1, a2);
        let n = b.not(m);
        let e = b.build(n);
        let comp = compile(&e);
        let scratch: Vec<u64> =
            (0..comp.scratch_needed()).map(|i| 0x9000 + i as u64).collect();
        let reqs = comp
            .emit(&[0x1, 0x2, 0x3, 0x4], 0x8000, 64, &scratch)
            .unwrap();
        let mut written: Vec<u64> = vec![0x1, 0x2, 0x3, 0x4];
        for r in &reqs {
            for s in &r.srcs {
                assert!(
                    written.contains(s) || *s == r.dst,
                    "source {s:#x} read before any write"
                );
            }
            written.push(r.dst);
        }
        assert_eq!(reqs.last().unwrap().dst, 0x8000, "root writes dst last");
    }
}
