//! Lowering: turn an optimized expression into the topologically
//! ordered [`BulkRequest`] batch the coordinator executes as ONE
//! `submit_batch`.
//!
//! [`compile`] runs the whole pipeline — optimize → emission order →
//! scratch register allocation — and freezes the result as a
//! [`Compiled`] program plus its [`CompileStats`]. [`Compiled::emit`]
//! then binds the program to concrete addresses: operand VAs for the
//! leaves, the destination VA for the root, and leased scratch VAs for
//! the intermediates. Because requests are emitted in topological
//! order, the PR-1 hazard-wave scheduler recovers exactly the DAG's
//! dependence structure: independent subtrees land in one wave and
//! overlap across banks, dependent chains serialize.

use anyhow::{ensure, Result};

use crate::pud::isa::{BulkRequest, PudOp};

use super::expr::{Expr, ExprId, Node};
use super::opt::optimize;
use super::regalloc::{allocate, emission_order, Assignment};

/// Preferred resident size of the compiler's scratch pool; expressions
/// needing more lease extra rows (counted as spills).
pub const DEFAULT_SCRATCH_POOL: usize = 4;

/// Per-expression compilation report (the execution-side PUD/fallback
/// row split is reported by
/// [`ExprReport`](crate::coordinator::system::ExprReport), which
/// carries these stats alongside it).
#[derive(Debug, Clone, Default)]
pub struct CompileStats {
    /// Distinct operand buffers the expression reads.
    pub leaves: usize,
    /// Reachable DAG nodes before / after optimization.
    pub nodes_in: usize,
    pub nodes_opt: usize,
    /// Bulk requests the program emits.
    pub ops: usize,
    /// NOT requests among them (each burns a dual-contact-row pass).
    pub not_ops: usize,
    /// Scratch slots the program needs simultaneously.
    pub scratch_slots: usize,
    /// Slots past the preferred pool bound.
    pub spills: usize,
    /// Optimizer counters.
    pub cse_hits: usize,
    pub folds: usize,
    pub demorgans: usize,
}

/// A compiled expression: optimized DAG + emission order + slot
/// assignment, ready to bind to addresses any number of times.
pub struct Compiled {
    expr: Expr,
    order: Vec<ExprId>,
    assignment: Assignment,
    pub stats: CompileStats,
}

/// Compile with the default scratch-pool bound.
pub fn compile(expr: &Expr) -> Compiled {
    compile_with_pool(expr, DEFAULT_SCRATCH_POOL)
}

/// Compile with an explicit preferred scratch-pool bound.
pub fn compile_with_pool(expr: &Expr, pool_limit: usize) -> Compiled {
    let (opt, rep) = optimize(expr);
    let order = emission_order(&opt);
    let assignment = allocate(&opt, &order, pool_limit.max(1));
    let (mut ops, mut not_ops) = (0usize, 0usize);
    for &id in &order {
        match opt.node(id) {
            Node::Leaf(_) => unreachable!("leaves are not emitted"),
            Node::Const(true) => {
                ops += 2; // Zero + in-place NOT
                not_ops += 1;
            }
            Node::Const(false) => ops += 1,
            Node::Not(_) => {
                ops += 1;
                not_ops += 1;
            }
            Node::AndNot(..) => {
                ops += 2;
                not_ops += 1;
            }
            Node::And(..) | Node::Or(..) | Node::Xor(..) => ops += 1,
        }
    }
    if order.is_empty() {
        ops = 1; // leaf root: one RowClone copy
    }
    let stats = CompileStats {
        leaves: opt.n_leaves(),
        nodes_in: rep.nodes_before,
        nodes_opt: rep.nodes_after,
        ops,
        not_ops,
        scratch_slots: assignment.slots_needed,
        spills: assignment.spills,
        cse_hits: rep.cse_hits,
        folds: rep.folds,
        demorgans: rep.demorgans,
    };
    Compiled {
        expr: opt,
        order,
        assignment,
        stats,
    }
}

impl Compiled {
    /// The optimized expression this program computes.
    pub fn expr(&self) -> &Expr {
        &self.expr
    }

    /// Scratch buffers `emit` needs (lease this many before binding).
    pub fn scratch_needed(&self) -> usize {
        self.assignment.slots_needed
    }

    /// Operand buffers the program reads.
    pub fn n_leaves(&self) -> usize {
        self.stats.leaves
    }

    /// Bind the program to addresses: `operands[i]` backs `Leaf(i)`,
    /// the root writes `dst`, intermediates use `scratch` slots. All
    /// buffers are `len` bytes. The returned batch is in topological
    /// order and is meant to be submitted as one
    /// `Coordinator::submit_batch`.
    pub fn emit(
        &self,
        operands: &[u64],
        dst: u64,
        len: u64,
        scratch: &[u64],
    ) -> Result<Vec<BulkRequest>> {
        ensure!(len > 0, "zero-length expression operands");
        ensure!(
            self.n_leaves() <= operands.len(),
            "expression reads {} operand(s), {} supplied",
            self.n_leaves(),
            operands.len()
        );
        ensure!(
            scratch.len() >= self.assignment.slots_needed,
            "need {} scratch buffer(s), {} leased",
            self.assignment.slots_needed,
            scratch.len()
        );
        let root = self.expr.root();
        let place = |id: ExprId| -> u64 {
            if id == root {
                dst
            } else {
                match self.expr.node(id) {
                    Node::Leaf(i) => operands[i],
                    _ => scratch[self.assignment.slot[&id]],
                }
            }
        };
        let mut reqs = Vec::with_capacity(self.stats.ops);
        if self.order.is_empty() {
            // root is a leaf: dst = copy(operand)
            let Node::Leaf(i) = self.expr.node(root) else {
                unreachable!("empty order implies a leaf root");
            };
            reqs.push(BulkRequest::new(PudOp::Copy, dst, vec![operands[i]], len));
            return Ok(reqs);
        }
        for &id in &self.order {
            let p = place(id);
            match self.expr.node(id) {
                Node::Leaf(_) => unreachable!("leaves are not emitted"),
                Node::Const(v) => {
                    reqs.push(BulkRequest::new(PudOp::Zero, p, vec![], len));
                    if v {
                        reqs.push(BulkRequest::new(PudOp::Not, p, vec![p], len));
                    }
                }
                Node::Not(a) => {
                    reqs.push(BulkRequest::new(PudOp::Not, p, vec![place(a)], len));
                }
                Node::And(a, b) => {
                    reqs.push(BulkRequest::new(
                        PudOp::And,
                        p,
                        vec![place(a), place(b)],
                        len,
                    ));
                }
                Node::Or(a, b) => {
                    reqs.push(BulkRequest::new(
                        PudOp::Or,
                        p,
                        vec![place(a), place(b)],
                        len,
                    ));
                }
                Node::Xor(a, b) => {
                    reqs.push(BulkRequest::new(
                        PudOp::Xor,
                        p,
                        vec![place(a), place(b)],
                        len,
                    ));
                }
                Node::AndNot(a, b) => {
                    // p = !b; p = a & p. Defensive: `compile()` always
                    // optimizes, and the optimizer canonicalizes
                    // AndNot to And(a, Not(b)), so this arm only runs
                    // if compilation ever grows a no-opt path. The
                    // register allocator's matching carve-out
                    // guarantees p aliases neither live operand.
                    reqs.push(BulkRequest::new(PudOp::Not, p, vec![place(b)], len));
                    reqs.push(BulkRequest::new(
                        PudOp::And,
                        p,
                        vec![place(a), p],
                        len,
                    ));
                }
            }
        }
        debug_assert_eq!(reqs.len(), self.stats.ops);
        Ok(reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::compiler::expr::ExprBuilder;
    use rustc_hash::FxHashMap;

    /// Interpret an emitted batch over plain byte buffers — a
    /// System-free check that lowering matches the IR's reference
    /// evaluator.
    fn interpret(
        reqs: &[BulkRequest],
        bufs: &mut FxHashMap<u64, Vec<u8>>,
        len: usize,
    ) {
        for r in reqs {
            let srcs: Vec<Vec<u8>> = r
                .srcs
                .iter()
                .map(|va| bufs.get(va).cloned().unwrap_or_else(|| vec![0u8; len]))
                .collect();
            let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
            let mut out = vec![0u8; len];
            r.op.apply_bytes(&refs, &mut out);
            bufs.insert(r.dst, out);
        }
    }

    fn check_against_reference(e: &crate::pud::compiler::Expr, seed: u64) {
        let len = 8usize;
        let n = e.n_leaves();
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let mut bufs: FxHashMap<u64, Vec<u8>> = FxHashMap::default();
        let mut operands = Vec::new();
        for i in 0..n {
            let mut v = vec![0u8; len];
            rng.fill_bytes(&mut v);
            let va = 0x1000 + i as u64 * 0x100;
            bufs.insert(va, v);
            operands.push(va);
        }
        let c = compile(e);
        let scratch: Vec<u64> =
            (0..c.scratch_needed()).map(|i| 0x9000 + i as u64 * 0x100).collect();
        let dst = 0x8000u64;
        let reqs = c.emit(&operands, dst, len as u64, &scratch).unwrap();
        assert_eq!(reqs.len(), c.stats.ops);
        let leaves: Vec<Vec<u8>> =
            operands.iter().map(|va| bufs[va].clone()).collect();
        interpret(&reqs, &mut bufs, len);
        let refs: Vec<&[u8]> = leaves.iter().map(|v| v.as_slice()).collect();
        let want = e.eval_bytes(&refs, len).unwrap();
        assert_eq!(bufs[&dst], want, "lowering diverged for {e}");
        // sources must survive (the substrate stages operands)
        for (va, orig) in operands.iter().zip(&leaves) {
            assert_eq!(&bufs[va], orig, "operand clobbered");
        }
    }

    #[test]
    fn three_clause_predicate_lowers_and_matches() {
        let mut b = ExprBuilder::new();
        let c: Vec<_> = (0..5).map(|i| b.leaf(i)).collect();
        let n2 = b.not(c[2]);
        let conj = b.and(c[0], c[1]);
        let left = b.and(conj, n2);
        let x = b.xor(c[3], c[4]);
        let r = b.or(left, x);
        let e = b.build(r);
        check_against_reference(&e, 11);
        let comp = compile(&e);
        assert_eq!(comp.n_leaves(), 5);
        assert!(comp.scratch_needed() >= 1);
        assert!(comp.stats.not_ops >= 1);
    }

    #[test]
    fn leaf_root_lowers_to_copy() {
        let mut b = ExprBuilder::new();
        let l = b.leaf(0);
        let e = b.build(l);
        let c = compile(&e);
        assert_eq!(c.scratch_needed(), 0);
        let reqs = c.emit(&[0x4000], 0x5000, 64, &[]).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].op, PudOp::Copy);
        assert_eq!(reqs[0].dst, 0x5000);
        assert_eq!(reqs[0].srcs, vec![0x4000]);
        check_against_reference(&e, 12);
    }

    #[test]
    fn const_roots_lower_via_control_rows() {
        for v in [false, true] {
            let mut b = ExprBuilder::new();
            let k = b.constant(v);
            let e = b.build(k);
            let c = compile(&e);
            let reqs = c.emit(&[], 0x5000, 64, &[]).unwrap();
            assert_eq!(reqs[0].op, PudOp::Zero);
            assert_eq!(reqs.len(), if v { 2 } else { 1 });
            check_against_reference(&e, 13);
        }
    }

    #[test]
    fn andnot_and_dedup_lower_correctly() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let d = b.and_not(l0, l1);
        let n1 = b.not(l1); // shared with the canonicalized AndNot
        let r = b.xor(d, n1);
        let e = b.build(r);
        check_against_reference(&e, 14);
        let c = compile(&e);
        assert!(c.stats.cse_hits >= 1);
    }

    #[test]
    fn emit_validates_bindings() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let a = b.and(l0, l1);
        let r = b.not(a);
        let e = b.build(r);
        let c = compile(&e);
        assert!(c.emit(&[0x1000], 0x5000, 64, &[0x9000]).is_err(), "missing operand");
        assert!(
            c.emit(&[0x1000, 0x2000], 0x5000, 64, &[]).is_err(),
            "missing scratch"
        );
        assert!(
            c.emit(&[0x1000, 0x2000], 0x5000, 0, &[0x9000]).is_err(),
            "zero length"
        );
        assert!(c.emit(&[0x1000, 0x2000], 0x5000, 64, &[0x9000]).is_ok());
    }

    #[test]
    fn requests_are_topologically_ordered() {
        // every request's scratch sources were written earlier
        let mut b = ExprBuilder::new();
        let c: Vec<_> = (0..4).map(|i| b.leaf(i)).collect();
        let a1 = b.and(c[0], c[1]);
        let a2 = b.or(c[2], c[3]);
        let m = b.xor(a1, a2);
        let n = b.not(m);
        let e = b.build(n);
        let comp = compile(&e);
        let scratch: Vec<u64> =
            (0..comp.scratch_needed()).map(|i| 0x9000 + i as u64).collect();
        let reqs = comp
            .emit(&[0x1, 0x2, 0x3, 0x4], 0x8000, 64, &scratch)
            .unwrap();
        let mut written: Vec<u64> = vec![0x1, 0x2, 0x3, 0x4];
        for r in &reqs {
            for s in &r.srcs {
                assert!(
                    written.contains(s) || *s == r.dst,
                    "source {s:#x} read before any write"
                );
            }
            written.push(r.dst);
        }
        assert_eq!(reqs.last().unwrap().dst, 0x8000, "root writes dst last");
    }
}
