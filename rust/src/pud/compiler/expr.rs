//! The Boolean-expression IR: a DAG of bitwise operators over named
//! N-row operand leaves.
//!
//! Expressions are built with [`ExprBuilder`] (an arena: children are
//! always created before their parents, so node ids double as a
//! topological order) and frozen into an [`Expr`] — or, for programs
//! with several outputs (the bit-planes of a vertical-arithmetic
//! kernel), a [`MultiExpr`]. Both carry a scalar reference evaluator
//! ([`Expr::eval_bytes`] / [`MultiExpr::eval_bytes`]) — the oracle the
//! property tests and the workloads verify compiled PUD execution
//! against, byte for byte.
//!
//! Leaves are *indices* into a caller-supplied operand list, not
//! addresses: the same expression compiles against any operand
//! placement (PUMA-co-located or scattered), which is what lets the
//! workloads sweep allocator choices with one program.

use std::fmt;

use anyhow::{ensure, Result};

/// Index of a node in its expression's arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExprId(pub u32);

impl ExprId {
    pub fn idx(&self) -> usize {
        self.0 as usize
    }
}

/// One DAG node. Binary operators reference earlier nodes only
/// (enforced by the builder), so a plain ascending walk of the arena
/// is a valid evaluation order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Node {
    /// The `i`-th caller-supplied operand buffer.
    Leaf(usize),
    /// All-zeros (`false`) / all-ones (`true`) — materialized from the
    /// reserved Zero control row (plus a NOT for all-ones), though the
    /// optimizer folds almost every constant away before lowering.
    Const(bool),
    Not(ExprId),
    And(ExprId, ExprId),
    Or(ExprId, ExprId),
    Xor(ExprId, ExprId),
    /// `a & !b` — set difference. Canonicalized to `And(a, Not(b))` by
    /// the optimizer so the inner NOT participates in CSE.
    AndNot(ExprId, ExprId),
}

impl Node {
    /// Child ids, in operand order.
    pub fn children(&self) -> Vec<ExprId> {
        match self {
            Node::Leaf(_) | Node::Const(_) => Vec::new(),
            Node::Not(a) => vec![*a],
            Node::And(a, b)
            | Node::Or(a, b)
            | Node::Xor(a, b)
            | Node::AndNot(a, b) => vec![*a, *b],
        }
    }
}

/// A frozen expression DAG with a designated root.
#[derive(Debug, Clone)]
pub struct Expr {
    nodes: Vec<Node>,
    root: ExprId,
}

/// Arena builder. Every factory method returns the id of a node whose
/// children already exist, so ids are a topological order by
/// construction.
#[derive(Default)]
pub struct ExprBuilder {
    nodes: Vec<Node>,
}

impl ExprBuilder {
    pub fn new() -> Self {
        Self { nodes: Vec::new() }
    }

    fn push(&mut self, n: Node) -> ExprId {
        for c in n.children() {
            assert!(
                c.idx() < self.nodes.len(),
                "child {c:?} does not exist in this builder"
            );
        }
        self.nodes.push(n);
        ExprId(self.nodes.len() as u32 - 1)
    }

    /// The `i`-th operand buffer.
    pub fn leaf(&mut self, i: usize) -> ExprId {
        self.push(Node::Leaf(i))
    }

    /// All-zeros (`false`) or all-ones (`true`).
    pub fn constant(&mut self, v: bool) -> ExprId {
        self.push(Node::Const(v))
    }

    pub fn not(&mut self, a: ExprId) -> ExprId {
        self.push(Node::Not(a))
    }

    pub fn and(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.push(Node::And(a, b))
    }

    pub fn or(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.push(Node::Or(a, b))
    }

    pub fn xor(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.push(Node::Xor(a, b))
    }

    /// `a & !b`.
    pub fn and_not(&mut self, a: ExprId, b: ExprId) -> ExprId {
        self.push(Node::AndNot(a, b))
    }

    /// Left fold of `xs` under AND (`xs` must be non-empty).
    pub fn all_and(&mut self, xs: &[ExprId]) -> ExprId {
        assert!(!xs.is_empty(), "all_and of nothing");
        xs[1..].iter().fold(xs[0], |acc, &x| self.and(acc, x))
    }

    /// Left fold of `xs` under OR (`xs` must be non-empty).
    pub fn all_or(&mut self, xs: &[ExprId]) -> ExprId {
        assert!(!xs.is_empty(), "all_or of nothing");
        xs[1..].iter().fold(xs[0], |acc, &x| self.or(acc, x))
    }

    /// Freeze the arena with `root` as the expression's output.
    pub fn build(self, root: ExprId) -> Expr {
        assert!(root.idx() < self.nodes.len(), "root {root:?} out of range");
        Expr {
            nodes: self.nodes,
            root,
        }
    }

    /// Freeze the arena as a multi-output program: `roots[k]` is the
    /// `k`-th output (e.g. the `k`-th result bit-plane of an arithmetic
    /// kernel). Roots may repeat and may be leaves; `roots` must be
    /// non-empty.
    pub fn build_multi(self, roots: Vec<ExprId>) -> MultiExpr {
        assert!(!roots.is_empty(), "a program needs at least one output");
        for r in &roots {
            assert!(r.idx() < self.nodes.len(), "root {r:?} out of range");
        }
        MultiExpr {
            nodes: self.nodes,
            roots,
        }
    }
}

/// Reachability mask over an arena from a set of roots (shared by
/// [`Expr`], [`MultiExpr`], the optimizer, and the register allocator).
pub(crate) fn reachable_from(nodes: &[Node], roots: &[ExprId]) -> Vec<bool> {
    let mut mark = vec![false; nodes.len()];
    let mut stack: Vec<ExprId> = roots.to_vec();
    while let Some(id) = stack.pop() {
        if std::mem::replace(&mut mark[id.idx()], true) {
            continue;
        }
        stack.extend(nodes[id.idx()].children());
    }
    mark
}

/// One past the highest reachable leaf index (0 if no leaves).
fn n_leaves_from(nodes: &[Node], mark: &[bool]) -> usize {
    nodes
        .iter()
        .zip(mark)
        .filter_map(|(n, m)| match (n, m) {
            (Node::Leaf(i), true) => Some(i + 1),
            _ => None,
        })
        .max()
        .unwrap_or(0)
}

fn check_operands(n_leaves: usize, leaves: &[&[u8]], len: usize) -> Result<()> {
    ensure!(
        n_leaves <= leaves.len(),
        "expression reads {} operand(s), {} supplied",
        n_leaves,
        leaves.len()
    );
    for (i, l) in leaves.iter().enumerate() {
        ensure!(l.len() == len, "operand {i} is {} bytes, want {len}", l.len());
    }
    Ok(())
}

/// Scalar evaluation of every reachable node over byte buffers; the
/// value table is indexed by arena id (unreachable entries stay
/// `None`).
fn eval_nodes(
    nodes: &[Node],
    mark: &[bool],
    leaves: &[&[u8]],
    len: usize,
) -> Vec<Option<Vec<u8>>> {
    let mut vals: Vec<Option<Vec<u8>>> = vec![None; nodes.len()];
    for (idx, node) in nodes.iter().enumerate() {
        if !mark[idx] {
            continue;
        }
        let get = |id: &ExprId, vals: &[Option<Vec<u8>>]| -> Vec<u8> {
            vals[id.idx()].clone().expect("children precede parents")
        };
        let v = match node {
            Node::Leaf(i) => leaves[*i].to_vec(),
            Node::Const(false) => vec![0u8; len],
            Node::Const(true) => vec![0xFFu8; len],
            Node::Not(a) => get(a, &vals).iter().map(|x| !x).collect(),
            Node::And(a, b) => zip_bytes(&get(a, &vals), &get(b, &vals), |x, y| x & y),
            Node::Or(a, b) => zip_bytes(&get(a, &vals), &get(b, &vals), |x, y| x | y),
            Node::Xor(a, b) => zip_bytes(&get(a, &vals), &get(b, &vals), |x, y| x ^ y),
            Node::AndNot(a, b) => {
                zip_bytes(&get(a, &vals), &get(b, &vals), |x, y| x & !y)
            }
        };
        vals[idx] = Some(v);
    }
    vals
}

/// A frozen DAG with several designated outputs. This is the program
/// form the vertical-arithmetic layer compiles: one shared carry/borrow
/// chain, W output bit-planes, all emitted as one batch. Shares the
/// arena, [`Node`] type, and builder with [`Expr`].
#[derive(Debug, Clone)]
pub struct MultiExpr {
    nodes: Vec<Node>,
    roots: Vec<ExprId>,
}

impl MultiExpr {
    /// Rebuild from raw parts (used by the optimizer).
    pub(crate) fn from_parts(nodes: Vec<Node>, roots: Vec<ExprId>) -> Self {
        debug_assert!(roots.iter().all(|r| r.idx() < nodes.len()));
        debug_assert!(!roots.is_empty());
        Self { nodes, roots }
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, id: ExprId) -> Node {
        self.nodes[id.idx()]
    }

    /// The outputs, in program order.
    pub fn roots(&self) -> &[ExprId] {
        &self.roots
    }

    pub fn n_outputs(&self) -> usize {
        self.roots.len()
    }

    /// Reachability mask from all roots.
    pub fn reachable(&self) -> Vec<bool> {
        reachable_from(&self.nodes, &self.roots)
    }

    /// Number of distinct operand buffers the program reads.
    pub fn n_leaves(&self) -> usize {
        n_leaves_from(&self.nodes, &self.reachable())
    }

    /// Reachable node count.
    pub fn live_nodes(&self) -> usize {
        self.reachable().iter().filter(|m| **m).count()
    }

    /// Scalar reference evaluation: one byte buffer per output, in
    /// root order — the oracle for compiled multi-output execution.
    pub fn eval_bytes(&self, leaves: &[&[u8]], len: usize) -> Result<Vec<Vec<u8>>> {
        check_operands(self.n_leaves(), leaves, len)?;
        let mark = self.reachable();
        let vals = eval_nodes(&self.nodes, &mark, leaves, len);
        Ok(self
            .roots
            .iter()
            .map(|r| vals[r.idx()].clone().expect("roots are reachable"))
            .collect())
    }
}

impl Expr {
    /// Rebuild an expression from raw parts (used by the optimizer).
    pub(crate) fn from_parts(nodes: Vec<Node>, root: ExprId) -> Self {
        debug_assert!(root.idx() < nodes.len());
        Self { nodes, root }
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, id: ExprId) -> Node {
        self.nodes[id.idx()]
    }

    pub fn root(&self) -> ExprId {
        self.root
    }

    /// Reachability mask from the root (dead arena nodes are skipped
    /// by every consumer).
    pub fn reachable(&self) -> Vec<bool> {
        reachable_from(&self.nodes, &[self.root])
    }

    /// Number of distinct operand buffers the expression needs: one
    /// past the highest reachable leaf index (0 for constant-only
    /// expressions).
    pub fn n_leaves(&self) -> usize {
        n_leaves_from(&self.nodes, &self.reachable())
    }

    /// Reachable node count (the DAG's size; dead arena entries are
    /// not counted).
    pub fn live_nodes(&self) -> usize {
        self.reachable().iter().filter(|m| **m).count()
    }

    /// Reachable NOT count — the metric the De Morgan rewrites shrink,
    /// since every NOT burns a dual-contact-row sequence.
    pub fn live_nots(&self) -> usize {
        let mark = self.reachable();
        self.nodes
            .iter()
            .zip(&mark)
            .filter(|(n, m)| **m && matches!(n, Node::Not(_)))
            .count()
    }

    /// Scalar reference evaluation over byte buffers: the oracle for
    /// compiled PUD execution. `leaves[i]` backs `Leaf(i)`; all
    /// buffers (and the result) are `len` bytes.
    pub fn eval_bytes(&self, leaves: &[&[u8]], len: usize) -> Result<Vec<u8>> {
        check_operands(self.n_leaves(), leaves, len)?;
        let mark = self.reachable();
        let mut vals = eval_nodes(&self.nodes, &mark, leaves, len);
        Ok(vals[self.root.idx()].take().expect("root is reachable"))
    }

    fn render(&self, id: ExprId, out: &mut String) {
        match self.node(id) {
            Node::Leaf(i) => out.push_str(&format!("c{i}")),
            Node::Const(v) => out.push_str(if v { "1" } else { "0" }),
            Node::Not(a) => {
                out.push('!');
                self.render_atom(a, out);
            }
            Node::And(a, b) => self.render_bin(a, " & ", b, out),
            Node::Or(a, b) => self.render_bin(a, " | ", b, out),
            Node::Xor(a, b) => self.render_bin(a, " ^ ", b, out),
            Node::AndNot(a, b) => {
                self.render_atom(a, out);
                out.push_str(" & !");
                self.render_atom(b, out);
            }
        }
    }

    fn render_bin(&self, a: ExprId, op: &str, b: ExprId, out: &mut String) {
        self.render_atom(a, out);
        out.push_str(op);
        self.render_atom(b, out);
    }

    fn render_atom(&self, id: ExprId, out: &mut String) {
        let atomic = matches!(
            self.node(id),
            Node::Leaf(_) | Node::Const(_) | Node::Not(_)
        );
        if atomic {
            self.render(id, out);
        } else {
            out.push('(');
            self.render(id, out);
            out.push(')');
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.render(self.root, &mut s);
        f.write_str(&s)
    }
}

fn zip_bytes(a: &[u8], b: &[u8], f: impl Fn(u8, u8) -> u8) -> Vec<u8> {
    a.iter().zip(b).map(|(x, y)| f(*x, *y)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_ids_are_topological() {
        let mut b = ExprBuilder::new();
        let a = b.leaf(0);
        let c = b.leaf(1);
        let n = b.not(c);
        let r = b.and(a, n);
        assert!(a < n && n < r);
        let e = b.build(r);
        assert_eq!(e.n_leaves(), 2);
        assert_eq!(e.live_nodes(), 4);
        assert_eq!(e.live_nots(), 1);
    }

    #[test]
    fn eval_matches_hand_computation() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let l2 = b.leaf(2);
        let n2 = b.not(l2);
        let conj = b.and(l0, l1);
        let left = b.and(conj, n2);
        let x = b.xor(l0, l2);
        let r = b.or(left, x);
        let e = b.build(r);
        let va = [0b1100u8, 0xFF];
        let vb = [0b1010u8, 0x0F];
        let vc = [0b0110u8, 0x33];
        let got = e.eval_bytes(&[&va, &vb, &vc], 2).unwrap();
        let want: Vec<u8> = (0..2)
            .map(|i| (va[i] & vb[i] & !vc[i]) | (va[i] ^ vc[i]))
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn and_not_and_consts_evaluate() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let d = b.and_not(l0, l1);
        let one = b.constant(true);
        let r = b.xor(d, one);
        let e = b.build(r);
        let got = e.eval_bytes(&[&[0xF0u8], &[0x30u8]], 1).unwrap();
        assert_eq!(got, vec![!(0xF0u8 & !0x30u8)]);
    }

    #[test]
    fn dead_nodes_are_ignored() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let _dead = b.leaf(7); // unreachable: must not inflate n_leaves
        let r = b.not(l0);
        let e = b.build(r);
        assert_eq!(e.n_leaves(), 1);
        assert_eq!(e.live_nodes(), 2);
        assert!(e.eval_bytes(&[&[0x0Fu8]], 1).unwrap() == vec![0xF0]);
    }

    #[test]
    fn eval_rejects_bad_operands() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let r = b.and(l0, l1);
        let e = b.build(r);
        assert!(e.eval_bytes(&[&[0u8]], 1).is_err(), "missing operand");
        assert!(
            e.eval_bytes(&[&[0u8], &[0u8, 0u8]], 1).is_err(),
            "length mismatch"
        );
    }

    #[test]
    fn multi_expr_evaluates_every_root() {
        // full adder over three 1-bit planes: sum + carry, one arena
        let mut b = ExprBuilder::new();
        let x = b.leaf(0);
        let y = b.leaf(1);
        let c = b.leaf(2);
        let t = b.xor(x, y);
        let s = b.xor(t, c);
        let g = b.and(x, y);
        let p = b.and(t, c);
        let co = b.or(g, p);
        let m = b.build_multi(vec![s, co]);
        assert_eq!(m.n_outputs(), 2);
        assert_eq!(m.n_leaves(), 3);
        let vx = [0b1100u8];
        let vy = [0b1010u8];
        let vc = [0b1000u8];
        let outs = m.eval_bytes(&[&vx, &vy, &vc], 1).unwrap();
        assert_eq!(outs[0], vec![vx[0] ^ vy[0] ^ vc[0]]);
        assert_eq!(
            outs[1],
            vec![(vx[0] & vy[0]) | ((vx[0] ^ vy[0]) & vc[0])]
        );
    }

    #[test]
    fn multi_expr_allows_leaf_and_repeated_roots() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let a = b.and(l0, l1);
        let m = b.build_multi(vec![a, l0, a]);
        let v0 = [0xF0u8];
        let v1 = [0x3Cu8];
        let outs = m.eval_bytes(&[&v0, &v1], 1).unwrap();
        assert_eq!(outs[0], vec![0xF0 & 0x3C]);
        assert_eq!(outs[1], v0.to_vec());
        assert_eq!(outs[2], outs[0]);
        assert_eq!(m.live_nodes(), 3);
    }

    #[test]
    fn display_renders_infix() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let l2 = b.leaf(2);
        let n = b.not(l2);
        let conj = b.and(l0, l1);
        let left = b.and(conj, n);
        let x = b.xor(l0, l1);
        let r = b.or(left, x);
        let e = b.build(r);
        let s = e.to_string();
        assert!(s.contains("c0"), "{s}");
        assert!(s.contains("!c2"), "{s}");
        assert!(s.contains('^'), "{s}");
    }
}
