//! Expression optimizer: CSE, constant folding, double-negation
//! elimination, and De Morgan rewrites.
//!
//! Every pass rebuilds the reachable DAG bottom-up through a
//! hash-consing arena (structural sharing *is* common-subexpression
//! elimination) while smart constructors apply local rewrites:
//!
//! * constants fold through every operator (`x & 0 → 0`, `x | 1 → 1`,
//!   `x ^ 1 → !x`, …) — the residue lowers onto the reserved Zero/One
//!   control rows, but almost nothing survives to that point;
//! * `!!x → x`, `x & x → x`, `x ^ x → 0`, `x & !x → 0`, `x | !x → 1`;
//! * De Morgan in the NOT-reducing direction only: `!a & !b → !(a|b)`
//!   and `!a | !b → !(a&b)` turn two dual-contact-row sequences into
//!   one (NOT is the op the substrate pays a DCC row for). The rewrite
//!   fires only when neither NOT has another use — a shared NOT stays
//!   live through its other parent, and rewriting would *add* nodes.
//!   Use counts are exact only on a deduplicated DAG, so the first
//!   pass runs CSE/folding alone and De Morgan joins from the second
//!   pass on;
//! * `AndNot(a, b)` canonicalizes to `And(a, Not(b))` so the inner
//!   NOT participates in CSE with any other use of `!b`.
//!
//! Passes repeat until a fixpoint (bounded); rewrites only ever
//! *shrink* the op count or leave it unchanged, and the property tests
//! assert optimized and unoptimized expressions evaluate identically.

use rustc_hash::FxHashMap;

use super::expr::{reachable_from, Expr, ExprId, MultiExpr, Node};

/// What the optimizer did (absorbed into
/// [`CompileStats`](super::lower::CompileStats)).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptReport {
    pub nodes_before: usize,
    pub nodes_after: usize,
    pub nots_before: usize,
    pub nots_after: usize,
    /// Structurally duplicate nodes merged by hash-consing.
    pub cse_hits: usize,
    /// Constant folds + identity/annihilator/double-negation rewrites.
    pub folds: usize,
    /// NOT-reducing De Morgan rewrites applied.
    pub demorgans: usize,
}

const MAX_PASSES: usize = 8;

/// Optimize `expr`. The result evaluates identically on every input.
pub fn optimize(expr: &Expr) -> (Expr, OptReport) {
    let (nodes, roots, report) =
        optimize_nodes(expr.nodes(), &[expr.root()]);
    (Expr::from_parts(nodes, roots[0]), report)
}

/// Optimize a multi-output program. Every rewrite the single-root
/// optimizer applies is live-range-safe here too: reachability, CSE,
/// and the De Morgan use counts are all computed over the union of the
/// roots, so an output shared between two result bit-planes (a CSE'd
/// sum bit, a folded constant) collapses to one node and the lowering
/// emits one compute plus copies.
pub fn optimize_multi(m: &MultiExpr) -> (MultiExpr, OptReport) {
    let (nodes, roots, report) = optimize_nodes(m.nodes(), m.roots());
    (MultiExpr::from_parts(nodes, roots), report)
}

/// The shared fixpoint driver over raw arena parts.
fn optimize_nodes(
    nodes: &[Node],
    roots: &[ExprId],
) -> (Vec<Node>, Vec<ExprId>, OptReport) {
    let (n0, nn0) = live_counts(nodes, roots);
    let mut report = OptReport {
        nodes_before: n0,
        nots_before: nn0,
        ..Default::default()
    };
    let mut cur_nodes = nodes.to_vec();
    let mut cur_roots = roots.to_vec();
    for i in 0..MAX_PASSES {
        // pass 0: CSE + folds only (duplicates not yet merged would
        // make NOT use counts lie); De Morgan needs one clean pass
        let (next_nodes, next_roots, changed) =
            pass(&cur_nodes, &cur_roots, &mut report, i > 0);
        cur_nodes = next_nodes;
        cur_roots = next_roots;
        if !changed && i > 0 {
            break;
        }
    }
    let (n1, nn1) = live_counts(&cur_nodes, &cur_roots);
    report.nodes_after = n1;
    report.nots_after = nn1;
    (cur_nodes, cur_roots, report)
}

/// (reachable nodes, reachable NOTs) from `roots`.
fn live_counts(nodes: &[Node], roots: &[ExprId]) -> (usize, usize) {
    let mark = reachable_from(nodes, roots);
    let live = mark.iter().filter(|m| **m).count();
    let nots = nodes
        .iter()
        .zip(&mark)
        .filter(|(n, m)| **m && matches!(n, Node::Not(_)))
        .count();
    (live, nots)
}

/// One bottom-up rebuild of the reachable DAG. `demorgan` enables the
/// NOT-reducing De Morgan rewrites (legal to decide here: use counts
/// over the arena are exact once the DAG has been through one CSE
/// pass).
fn pass(
    nodes: &[Node],
    roots: &[ExprId],
    rep: &mut OptReport,
    demorgan: bool,
) -> (Vec<Node>, Vec<ExprId>, bool) {
    let mark = reachable_from(nodes, roots);
    // reachable-parent count per node, for the De Morgan sharing gate
    let mut uses = vec![0usize; nodes.len()];
    for (idx, node) in nodes.iter().enumerate() {
        if mark[idx] {
            for c in node.children() {
                uses[c.idx()] += 1;
            }
        }
    }
    let unshared_not = |id: ExprId| {
        matches!(nodes[id.idx()], Node::Not(_)) && uses[id.idx()] == 1
    };
    let mut rb = Rebuild::default();
    let mut memo: Vec<Option<ExprId>> = vec![None; nodes.len()];
    for (idx, node) in nodes.iter().enumerate() {
        if !mark[idx] {
            continue;
        }
        let remap = |id: ExprId| memo[id.idx()].expect("children precede parents");
        // this node may De Morgan only if both its NOT operands die
        // with it (for AndNot, the synthesized !b is single-use by
        // construction, so only the first operand gates)
        let dm_ok = demorgan
            && match *node {
                Node::And(a, b) | Node::Or(a, b) => {
                    unshared_not(a) && unshared_not(b)
                }
                Node::AndNot(a, _) => unshared_not(a),
                _ => false,
            };
        let n = match *node {
            Node::Leaf(i) => Node::Leaf(i),
            Node::Const(v) => Node::Const(v),
            Node::Not(a) => Node::Not(remap(a)),
            Node::And(a, b) => Node::And(remap(a), remap(b)),
            Node::Or(a, b) => Node::Or(remap(a), remap(b)),
            Node::Xor(a, b) => Node::Xor(remap(a), remap(b)),
            Node::AndNot(a, b) => Node::AndNot(remap(a), remap(b)),
        };
        memo[idx] = Some(rb.mk(n, dm_ok, rep));
    }
    let new_roots: Vec<ExprId> = roots
        .iter()
        .map(|r| memo[r.idx()].expect("roots are reachable"))
        .collect();
    let changed = rb.nodes.as_slice() != nodes || new_roots != roots;
    (rb.nodes, new_roots, changed)
}

/// Hash-consing arena with rewriting smart constructors.
#[derive(Default)]
struct Rebuild {
    nodes: Vec<Node>,
    cons: FxHashMap<Node, ExprId>,
}

impl Rebuild {
    fn node(&self, id: ExprId) -> Node {
        self.nodes[id.idx()]
    }

    /// Insert after canonicalizing commutative operand order; a hit is
    /// a CSE merge.
    fn intern(&mut self, n: Node, rep: &mut OptReport) -> ExprId {
        let n = match n {
            Node::And(a, b) if b < a => Node::And(b, a),
            Node::Or(a, b) if b < a => Node::Or(b, a),
            Node::Xor(a, b) if b < a => Node::Xor(b, a),
            other => other,
        };
        if let Some(&id) = self.cons.get(&n) {
            rep.cse_hits += 1;
            return id;
        }
        self.nodes.push(n);
        let id = ExprId(self.nodes.len() as u32 - 1);
        self.cons.insert(n, id);
        id
    }

    /// `x` and `!y` with either orientation: is one the complement of
    /// the other?
    fn complementary(&self, a: ExprId, b: ExprId) -> bool {
        matches!(self.node(a), Node::Not(x) if x == b)
            || matches!(self.node(b), Node::Not(y) if y == a)
    }

    /// Smart constructor: children of `n` are already in this arena.
    /// `dm_ok` allows the De Morgan rewrite for THIS node (the caller
    /// proved its NOT operands have no other uses); recursively
    /// synthesized nodes stay conservative.
    fn mk(&mut self, n: Node, dm_ok: bool, rep: &mut OptReport) -> ExprId {
        match n {
            Node::Leaf(_) | Node::Const(_) => self.intern(n, rep),
            Node::AndNot(a, b) => {
                // canonicalize so !b is CSE-visible
                let nb = self.mk(Node::Not(b), false, rep);
                self.mk(Node::And(a, nb), dm_ok, rep)
            }
            Node::Not(a) => match self.node(a) {
                Node::Not(x) => {
                    rep.folds += 1;
                    x
                }
                Node::Const(v) => {
                    rep.folds += 1;
                    self.intern(Node::Const(!v), rep)
                }
                _ => self.intern(Node::Not(a), rep),
            },
            Node::And(a, b) => {
                if a == b {
                    rep.folds += 1;
                    return a;
                }
                if self.complementary(a, b) {
                    rep.folds += 1;
                    return self.intern(Node::Const(false), rep);
                }
                match (self.node(a), self.node(b)) {
                    (Node::Const(false), _) | (_, Node::Const(false)) => {
                        rep.folds += 1;
                        self.intern(Node::Const(false), rep)
                    }
                    (Node::Const(true), _) => {
                        rep.folds += 1;
                        b
                    }
                    (_, Node::Const(true)) => {
                        rep.folds += 1;
                        a
                    }
                    (Node::Not(x), Node::Not(y)) if dm_ok => {
                        rep.demorgans += 1;
                        let or = self.mk(Node::Or(x, y), false, rep);
                        self.mk(Node::Not(or), false, rep)
                    }
                    _ => self.intern(Node::And(a, b), rep),
                }
            }
            Node::Or(a, b) => {
                if a == b {
                    rep.folds += 1;
                    return a;
                }
                if self.complementary(a, b) {
                    rep.folds += 1;
                    return self.intern(Node::Const(true), rep);
                }
                match (self.node(a), self.node(b)) {
                    (Node::Const(true), _) | (_, Node::Const(true)) => {
                        rep.folds += 1;
                        self.intern(Node::Const(true), rep)
                    }
                    (Node::Const(false), _) => {
                        rep.folds += 1;
                        b
                    }
                    (_, Node::Const(false)) => {
                        rep.folds += 1;
                        a
                    }
                    (Node::Not(x), Node::Not(y)) if dm_ok => {
                        rep.demorgans += 1;
                        let and = self.mk(Node::And(x, y), false, rep);
                        self.mk(Node::Not(and), false, rep)
                    }
                    _ => self.intern(Node::Or(a, b), rep),
                }
            }
            Node::Xor(a, b) => {
                if a == b {
                    rep.folds += 1;
                    return self.intern(Node::Const(false), rep);
                }
                match (self.node(a), self.node(b)) {
                    (Node::Const(false), _) => {
                        rep.folds += 1;
                        b
                    }
                    (_, Node::Const(false)) => {
                        rep.folds += 1;
                        a
                    }
                    (Node::Const(true), _) => {
                        rep.folds += 1;
                        self.mk(Node::Not(b), false, rep)
                    }
                    (_, Node::Const(true)) => {
                        rep.folds += 1;
                        self.mk(Node::Not(a), false, rep)
                    }
                    _ => self.intern(Node::Xor(a, b), rep),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::compiler::expr::ExprBuilder;
    use crate::util::rng::Pcg64;

    fn eval_pair(e1: &Expr, e2: &Expr, seed: u64) {
        let n = e1.n_leaves().max(e2.n_leaves()).max(1);
        let mut rng = Pcg64::new(seed);
        let leaves: Vec<Vec<u8>> = (0..n)
            .map(|_| {
                let mut v = vec![0u8; 16];
                rng.fill_bytes(&mut v);
                v
            })
            .collect();
        let refs: Vec<&[u8]> = leaves.iter().map(|v| v.as_slice()).collect();
        assert_eq!(
            e1.eval_bytes(&refs, 16).unwrap(),
            e2.eval_bytes(&refs, 16).unwrap(),
            "optimizer changed semantics of {e1}"
        );
    }

    #[test]
    fn cse_merges_duplicate_subtrees() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let x1 = b.and(l0, l1);
        let x2 = b.and(l0, l1); // structural duplicate
        let r = b.xor(x1, x2); // == Const(false), via CSE then x^x
        let e = b.build(r);
        let (opt, rep) = optimize(&e);
        assert!(rep.cse_hits >= 1);
        assert_eq!(opt.node(opt.root()), Node::Const(false));
        eval_pair(&e, &opt, 1);
    }

    #[test]
    fn commutative_duplicates_merge_too() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let x1 = b.and(l0, l1);
        let x2 = b.and(l1, l0); // same op, swapped operands
        let r = b.or(x1, x2);
        let e = b.build(r);
        let (opt, rep) = optimize(&e);
        assert!(rep.cse_hits >= 1);
        // or(x, x) then folds to the single AND
        assert_eq!(opt.live_nodes(), 3);
        eval_pair(&e, &opt, 2);
    }

    #[test]
    fn double_negation_and_constants_fold() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let n1 = b.not(l0);
        let n2 = b.not(n1); // !!a == a
        let zero = b.constant(false);
        let r1 = b.or(n2, zero); // a | 0 == a
        let one = b.constant(true);
        let r = b.and(r1, one); // a & 1 == a
        let e = b.build(r);
        let (opt, rep) = optimize(&e);
        assert!(rep.folds >= 3);
        assert_eq!(opt.live_nodes(), 1, "whole thing folds to the leaf");
        assert_eq!(opt.node(opt.root()), Node::Leaf(0));
        eval_pair(&e, &opt, 3);
    }

    #[test]
    fn xor_with_one_becomes_not() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let one = b.constant(true);
        let r = b.xor(l0, one);
        let e = b.build(r);
        let (opt, _) = optimize(&e);
        assert_eq!(opt.node(opt.root()), Node::Not(ExprId(0)));
        eval_pair(&e, &opt, 4);
    }

    #[test]
    fn demorgan_reduces_not_count() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let n0 = b.not(l0);
        let n1 = b.not(l1);
        let r = b.and(n0, n1); // !a & !b -> !(a | b)
        let e = b.build(r);
        assert_eq!(e.live_nots(), 2);
        let (opt, rep) = optimize(&e);
        assert_eq!(rep.demorgans, 1);
        assert_eq!(opt.live_nots(), 1);
        eval_pair(&e, &opt, 5);
    }

    #[test]
    fn demorgan_skipped_when_nots_are_shared() {
        // (!a & !b) ^ !a — rewriting the AND would leave !a alive
        // through the XOR and *grow* the program
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let n0 = b.not(l0);
        let n1 = b.not(l1);
        let conj = b.and(n0, n1);
        let r = b.xor(conj, n0);
        let e = b.build(r);
        let (opt, rep) = optimize(&e);
        assert_eq!(rep.demorgans, 0, "shared NOT must block De Morgan");
        assert_eq!(opt.live_nots(), 2);
        assert!(opt.live_nodes() <= e.live_nodes(), "optimizer may not grow");
        eval_pair(&e, &opt, 9);
    }

    #[test]
    fn andnot_canonicalizes_and_shares_the_not() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let d = b.and_not(l0, l1); // a & !b
        let n1 = b.not(l1); // !b again, elsewhere
        let r = b.xor(d, n1);
        let e = b.build(r);
        let (opt, rep) = optimize(&e);
        assert!(rep.cse_hits >= 1, "!b must be shared after canonicalization");
        assert!(!opt
            .nodes()
            .iter()
            .any(|n| matches!(n, Node::AndNot(..))));
        eval_pair(&e, &opt, 6);
    }

    #[test]
    fn complements_annihilate() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let n0 = b.not(l0);
        let r1 = b.and(l0, n0); // == 0
        let l1 = b.leaf(1);
        let n1 = b.not(l1);
        let r2 = b.or(l1, n1); // == 1
        let r = b.and(r1, r2); // 0 & 1 == 0
        let e = b.build(r);
        let (opt, _) = optimize(&e);
        assert_eq!(opt.node(opt.root()), Node::Const(false));
        eval_pair(&e, &opt, 7);
    }

    #[test]
    fn multi_root_optimize_preserves_every_output() {
        // two outputs sharing a subterm; one output folds to a leaf
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let t = b.xor(l0, l1);
        let one = b.constant(true);
        let s = b.and(t, one); // folds to t
        let n = b.not(t);
        let nn = b.not(n); // folds to t as well
        let g = b.and(l0, l1);
        let m = b.build_multi(vec![s, nn, g]);
        let (opt, rep) = optimize_multi(&m);
        assert_eq!(opt.n_outputs(), 3);
        assert!(rep.folds >= 2);
        // both folded outputs collapse onto the same node
        assert_eq!(opt.roots()[0], opt.roots()[1]);
        let v0 = [0xC3u8, 0x55];
        let v1 = [0x0Fu8, 0xF0];
        let outs = opt.eval_bytes(&[&v0, &v1], 2).unwrap();
        let want = m.eval_bytes(&[&v0, &v1], 2).unwrap();
        assert_eq!(outs, want, "multi-root optimizer changed semantics");
        assert_eq!(outs[0], vec![0xC3 ^ 0x0F, 0x55 ^ 0xF0]);
        assert_eq!(outs[2], vec![0xC3 & 0x0F, 0x55 & 0xF0]);
    }

    #[test]
    fn optimize_is_idempotent() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let l2 = b.leaf(2);
        let n2 = b.not(l2);
        let conj = b.and(l0, l1);
        let left = b.and(conj, n2);
        let x = b.xor(l0, l1);
        let r = b.or(left, x);
        let e = b.build(r);
        let (o1, _) = optimize(&e);
        let (o2, rep2) = optimize(&o1);
        assert_eq!(o1.nodes(), o2.nodes());
        assert_eq!(o1.root(), o2.root());
        assert_eq!(rep2.folds + rep2.demorgans, 0, "fixpoint reached");
        eval_pair(&e, &o1, 8);
    }
}
