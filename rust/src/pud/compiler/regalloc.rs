//! Temp-row register allocation: map expression intermediates onto a
//! bounded pool of scratch regions.
//!
//! Emission order is the arena order of the reachable non-leaf nodes
//! (a topological order by construction), so live ranges are plain
//! `[def, last_use]` index intervals and a linear scan suffices. Slots
//! are recycled through a FIFO free list — the *least recently freed*
//! slot is reused first, which maximizes the distance between a WAR
//! hazard's read and write and so keeps independent subtrees in
//! distinct slots (= distinct rows = schedulable in one hazard wave)
//! whenever the pool allows.
//!
//! When pressure exceeds the pool bound the allocator keeps going —
//! slots past the bound are *spills*, extra scratch rows the caller
//! leases on demand (`Assignment::spills` counts them; the scratch
//! pool they come from is the same [`crate::alloc::scratch::ScratchPool`],
//! just beyond its preferred resident size).

use std::collections::VecDeque;

use rustc_hash::FxHashMap;

use super::expr::{Expr, ExprId, Node};

/// Slot assignment for every emitted non-root interior node.
#[derive(Debug, Clone, Default)]
pub struct Assignment {
    /// Scratch slot index per node (the root writes `dst` instead and
    /// has no entry; leaves read operand buffers directly).
    pub slot: FxHashMap<ExprId, usize>,
    /// Distinct slots the emission needs simultaneously.
    pub slots_needed: usize,
    /// Slots allocated beyond the preferred pool bound.
    pub spills: usize,
}

/// The emission order: reachable non-leaf nodes in arena (topological)
/// order. Empty exactly when the root is a leaf.
pub fn emission_order(expr: &Expr) -> Vec<ExprId> {
    let mark = expr.reachable();
    (0..expr.nodes().len())
        .filter(|&i| mark[i] && !matches!(expr.nodes()[i], Node::Leaf(_)))
        .map(|i| ExprId(i as u32))
        .collect()
}

/// Linear-scan allocation over `order` with a preferred pool of
/// `pool_limit` slots.
pub fn allocate(expr: &Expr, order: &[ExprId], pool_limit: usize) -> Assignment {
    // last emission position reading each interior node's value
    let mut last_use: FxHashMap<ExprId, usize> = FxHashMap::default();
    for (pos, &id) in order.iter().enumerate() {
        for c in expr.node(id).children() {
            if !matches!(expr.node(c), Node::Leaf(_)) {
                last_use.insert(c, pos);
            }
        }
    }
    let root = expr.root();
    let mut asg = Assignment::default();
    let mut free: VecDeque<usize> = VecDeque::new();
    for (pos, &id) in order.iter().enumerate() {
        let mut freed: Vec<usize> = expr
            .node(id)
            .children()
            .iter()
            .filter(|c| last_use.get(c) == Some(&pos))
            .filter_map(|c| asg.slot.get(c).copied())
            .collect();
        freed.sort_unstable();
        freed.dedup();
        // In-place destination reuse (dst slot == a dying operand's
        // slot) is legal for single-request lowerings: the engine
        // reads every source before writing. `AndNot` lowers to TWO
        // requests (NOT then AND) whose first write must not clobber
        // the still-needed first operand, so it allocates its slot
        // *before* the operands' slots recycle. (Defensive, like the
        // AndNot arm in `Compiled::emit`: `compile()`'s optimizer
        // canonicalizes AndNot away, but `allocate` accepts raw
        // expressions too.)
        let inplace_ok = !matches!(expr.node(id), Node::AndNot(..));
        if inplace_ok {
            free.extend(freed.iter().copied());
        }
        if id != root {
            let s = match free.pop_front() {
                Some(s) => s,
                None => {
                    let s = asg.slots_needed;
                    asg.slots_needed += 1;
                    if asg.slots_needed > pool_limit {
                        asg.spills += 1;
                    }
                    s
                }
            };
            asg.slot.insert(id, s);
        }
        if !inplace_ok {
            free.extend(freed);
        }
    }
    asg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::compiler::expr::ExprBuilder;

    #[test]
    fn chain_reuses_one_slot() {
        // !!!!a — each NOT's operand dies at its single use
        let mut b = ExprBuilder::new();
        let mut x = b.leaf(0);
        for _ in 0..4 {
            x = b.not(x);
        }
        let e = b.build(x);
        let order = emission_order(&e);
        assert_eq!(order.len(), 4);
        let asg = allocate(&e, &order, 4);
        assert_eq!(asg.slots_needed, 1, "a linear chain needs one slot");
        assert_eq!(asg.spills, 0);
        assert!(!asg.slot.contains_key(&e.root()), "root writes dst");
    }

    #[test]
    fn balanced_tree_needs_logarithmic_slots() {
        // ((a&b) | (c&d)) ^ ((e&f) | (g&h))
        let mut b = ExprBuilder::new();
        let leaves: Vec<_> = (0..8).map(|i| b.leaf(i)).collect();
        let ands: Vec<_> = leaves
            .chunks(2)
            .map(|p| b.and(p[0], p[1]))
            .collect();
        let o1 = b.or(ands[0], ands[1]);
        let o2 = b.or(ands[2], ands[3]);
        let r = b.xor(o1, o2);
        let e = b.build(r);
        let order = emission_order(&e);
        let asg = allocate(&e, &order, 8);
        assert!(asg.slots_needed <= 4, "got {}", asg.slots_needed);
        assert_eq!(asg.spills, 0);
        // every non-root interior node has a slot within bounds
        for &s in asg.slot.values() {
            assert!(s < asg.slots_needed);
        }
    }

    #[test]
    fn pressure_beyond_pool_counts_spills() {
        // 6 independent ANDs all live until the final fold
        let mut b = ExprBuilder::new();
        let ands: Vec<_> = (0..6)
            .map(|i| {
                let x = b.leaf(2 * i);
                let y = b.leaf(2 * i + 1);
                b.and(x, y)
            })
            .collect();
        // fold pairwise at the end so all 6 stay live
        let p1 = b.or(ands[0], ands[1]);
        let p2 = b.or(ands[2], ands[3]);
        let p3 = b.or(ands[4], ands[5]);
        let q = b.or(p1, p2);
        let r = b.or(q, p3);
        let e = b.build(r);
        let order = emission_order(&e);
        let tight = allocate(&e, &order, 2);
        let roomy = allocate(&e, &order, 16);
        assert_eq!(tight.slots_needed, roomy.slots_needed);
        assert!(tight.spills > 0, "pool of 2 must spill");
        assert_eq!(roomy.spills, 0);
    }

    #[test]
    fn no_live_operand_shares_its_consumer_dst_slot_for_andnot() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let inner = b.and(l0, l1); // dies at the AndNot
        let l2 = b.leaf(2);
        let d = b.and_not(inner, l2);
        let r = b.not(d);
        let e = b.build(r);
        let order = emission_order(&e);
        let asg = allocate(&e, &order, 4);
        // the AndNot's slot must differ from its dying operand's slot
        let inner_id = order[0];
        let andnot_id = order[1];
        assert_ne!(asg.slot[&inner_id], asg.slot[&andnot_id]);
    }
}
