//! Temp-row register allocation: map expression intermediates onto a
//! bounded pool of scratch regions.
//!
//! Emission order is the arena order of the reachable non-leaf nodes
//! (a topological order by construction), so live ranges are plain
//! `[def, last_use]` index intervals and a linear scan suffices. Slots
//! are recycled through a FIFO free list — the *least recently freed*
//! slot is reused first, which maximizes the distance between a WAR
//! hazard's read and write and so keeps independent subtrees in
//! distinct slots (= distinct rows = schedulable in one hazard wave)
//! whenever the pool allows.
//!
//! When pressure exceeds the pool bound the allocator keeps going —
//! slots past the bound are *spills*, extra scratch rows the caller
//! leases on demand (`Assignment::spills` counts them; the scratch
//! pool they come from is the same [`crate::alloc::scratch::ScratchPool`],
//! just beyond its preferred resident size).

use std::collections::VecDeque;

use rustc_hash::FxHashMap;

use super::expr::{Expr, ExprId, MultiExpr, Node};

/// Slot assignment for every emitted non-root interior node.
#[derive(Debug, Clone, Default)]
pub struct Assignment {
    /// Scratch slot index per node (the root writes `dst` instead and
    /// has no entry; leaves read operand buffers directly).
    pub slot: FxHashMap<ExprId, usize>,
    /// Distinct slots the emission needs simultaneously.
    pub slots_needed: usize,
    /// Slots allocated beyond the preferred pool bound.
    pub spills: usize,
}

/// The emission order: reachable non-leaf nodes in arena (topological)
/// order. Empty exactly when the root is a leaf.
pub fn emission_order(expr: &Expr) -> Vec<ExprId> {
    order_impl(expr.nodes(), &expr.reachable())
}

/// Multi-output emission order: reachable (from any root) non-leaf
/// nodes in arena order. Empty exactly when every output is a leaf.
pub fn emission_order_multi(m: &MultiExpr) -> Vec<ExprId> {
    order_impl(m.nodes(), &m.reachable())
}

fn order_impl(nodes: &[Node], mark: &[bool]) -> Vec<ExprId> {
    (0..nodes.len())
        .filter(|&i| mark[i] && !matches!(nodes[i], Node::Leaf(_)))
        .map(|i| ExprId(i as u32))
        .collect()
}

/// Linear-scan allocation over `order` with a preferred pool of
/// `pool_limit` slots.
pub fn allocate(expr: &Expr, order: &[ExprId], pool_limit: usize) -> Assignment {
    allocate_impl(expr.nodes(), &[expr.root()], order, pool_limit)
}

/// Multi-output linear scan: every root writes a caller-provided dst
/// buffer instead of a scratch slot (dst VAs are never recycled, so a
/// root consumed by a later node stays readable for the whole batch).
pub fn allocate_multi(
    m: &MultiExpr,
    order: &[ExprId],
    pool_limit: usize,
) -> Assignment {
    allocate_impl(m.nodes(), m.roots(), order, pool_limit)
}

fn allocate_impl(
    nodes: &[Node],
    roots: &[ExprId],
    order: &[ExprId],
    pool_limit: usize,
) -> Assignment {
    let node = |id: ExprId| nodes[id.idx()];
    // last emission position reading each interior node's value
    let mut last_use: FxHashMap<ExprId, usize> = FxHashMap::default();
    for (pos, &id) in order.iter().enumerate() {
        for c in node(id).children() {
            if !matches!(node(c), Node::Leaf(_)) {
                last_use.insert(c, pos);
            }
        }
    }
    let mut asg = Assignment::default();
    let mut free: VecDeque<usize> = VecDeque::new();
    for (pos, &id) in order.iter().enumerate() {
        let mut freed: Vec<usize> = node(id)
            .children()
            .iter()
            .filter(|c| last_use.get(c) == Some(&pos))
            .filter_map(|c| asg.slot.get(c).copied())
            .collect();
        freed.sort_unstable();
        freed.dedup();
        // In-place destination reuse (dst slot == a dying operand's
        // slot) is legal for single-request lowerings: the engine
        // reads every source before writing. `AndNot` lowers to TWO
        // requests (NOT then AND) whose first write must not clobber
        // the still-needed first operand, so it allocates its slot
        // *before* the operands' slots recycle. (Defensive, like the
        // AndNot arm in `Compiled::emit`: `compile()`'s optimizer
        // canonicalizes AndNot away, but `allocate` accepts raw
        // expressions too.)
        let inplace_ok = !matches!(node(id), Node::AndNot(..));
        if inplace_ok {
            free.extend(freed.iter().copied());
        }
        if !roots.contains(&id) {
            let s = match free.pop_front() {
                Some(s) => s,
                None => {
                    let s = asg.slots_needed;
                    asg.slots_needed += 1;
                    if asg.slots_needed > pool_limit {
                        asg.spills += 1;
                    }
                    s
                }
            };
            asg.slot.insert(id, s);
        }
        if !inplace_ok {
            free.extend(freed);
        }
    }
    asg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::compiler::expr::ExprBuilder;

    #[test]
    fn chain_reuses_one_slot() {
        // !!!!a — each NOT's operand dies at its single use
        let mut b = ExprBuilder::new();
        let mut x = b.leaf(0);
        for _ in 0..4 {
            x = b.not(x);
        }
        let e = b.build(x);
        let order = emission_order(&e);
        assert_eq!(order.len(), 4);
        let asg = allocate(&e, &order, 4);
        assert_eq!(asg.slots_needed, 1, "a linear chain needs one slot");
        assert_eq!(asg.spills, 0);
        assert!(!asg.slot.contains_key(&e.root()), "root writes dst");
    }

    #[test]
    fn balanced_tree_needs_logarithmic_slots() {
        // ((a&b) | (c&d)) ^ ((e&f) | (g&h))
        let mut b = ExprBuilder::new();
        let leaves: Vec<_> = (0..8).map(|i| b.leaf(i)).collect();
        let ands: Vec<_> = leaves
            .chunks(2)
            .map(|p| b.and(p[0], p[1]))
            .collect();
        let o1 = b.or(ands[0], ands[1]);
        let o2 = b.or(ands[2], ands[3]);
        let r = b.xor(o1, o2);
        let e = b.build(r);
        let order = emission_order(&e);
        let asg = allocate(&e, &order, 8);
        assert!(asg.slots_needed <= 4, "got {}", asg.slots_needed);
        assert_eq!(asg.spills, 0);
        // every non-root interior node has a slot within bounds
        for &s in asg.slot.values() {
            assert!(s < asg.slots_needed);
        }
    }

    #[test]
    fn pressure_beyond_pool_counts_spills() {
        // 6 independent ANDs all live until the final fold
        let mut b = ExprBuilder::new();
        let ands: Vec<_> = (0..6)
            .map(|i| {
                let x = b.leaf(2 * i);
                let y = b.leaf(2 * i + 1);
                b.and(x, y)
            })
            .collect();
        // fold pairwise at the end so all 6 stay live
        let p1 = b.or(ands[0], ands[1]);
        let p2 = b.or(ands[2], ands[3]);
        let p3 = b.or(ands[4], ands[5]);
        let q = b.or(p1, p2);
        let r = b.or(q, p3);
        let e = b.build(r);
        let order = emission_order(&e);
        let tight = allocate(&e, &order, 2);
        let roomy = allocate(&e, &order, 16);
        assert_eq!(tight.slots_needed, roomy.slots_needed);
        assert!(tight.spills > 0, "pool of 2 must spill");
        assert_eq!(roomy.spills, 0);
    }

    #[test]
    fn multi_root_allocation_gives_roots_no_slot() {
        // carry chain: c1 = a&b is BOTH an output and an input of s1
        let mut b = ExprBuilder::new();
        let x = b.leaf(0);
        let y = b.leaf(1);
        let z = b.leaf(2);
        let s0 = b.xor(x, y);
        let c1 = b.and(x, y);
        let s1 = b.xor(z, c1);
        let e = b.build_multi(vec![s0, s1, c1]);
        let order = emission_order_multi(&e);
        assert_eq!(order.len(), 3);
        let asg = allocate_multi(&e, &order, 4);
        // every root writes its own dst: no scratch slots at all here
        assert_eq!(asg.slots_needed, 0);
        assert!(asg.slot.is_empty());
    }

    #[test]
    fn multi_root_interior_nodes_still_get_slots() {
        let mut b = ExprBuilder::new();
        let x = b.leaf(0);
        let y = b.leaf(1);
        let t = b.xor(x, y); // interior only
        let r0 = b.not(t);
        let r1 = b.and(t, x);
        let e = b.build_multi(vec![r0, r1]);
        let order = emission_order_multi(&e);
        let asg = allocate_multi(&e, &order, 4);
        assert_eq!(asg.slots_needed, 1, "only the shared xor needs scratch");
        assert!(asg.slot.contains_key(&t));
        assert!(!asg.slot.contains_key(&r0));
        assert!(!asg.slot.contains_key(&r1));
    }

    #[test]
    fn no_live_operand_shares_its_consumer_dst_slot_for_andnot() {
        let mut b = ExprBuilder::new();
        let l0 = b.leaf(0);
        let l1 = b.leaf(1);
        let inner = b.and(l0, l1); // dies at the AndNot
        let l2 = b.leaf(2);
        let d = b.and_not(inner, l2);
        let r = b.not(d);
        let e = b.build(r);
        let order = emission_order(&e);
        let asg = allocate(&e, &order, 4);
        // the AndNot's slot must differ from its dying operand's slot
        let inner_id = order[0];
        let andnot_id = order[1];
        assert_ne!(asg.slot[&inner_id], asg.slot[&andnot_id]);
    }
}
