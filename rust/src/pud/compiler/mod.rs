//! `pud::compiler` — a Boolean-expression compiler for the Ambit
//! substrate.
//!
//! The substrate executes one bulk op at a time (RowClone copy/zero,
//! Ambit AND/OR/NOT and composite XOR), but real PUD workloads —
//! predicate filters, bitmap joins, set algebra — are multi-operand
//! Boolean *expressions*. This subsystem is the layer between the
//! allocator and those applications (the role MIMDRAM's and Proteus's
//! compiler support plays):
//!
//! * [`expr`] — the expression IR: a DAG of `And/Or/Not/Xor/AndNot`
//!   over indexed operand leaves, with a builder API and the scalar
//!   reference evaluator every test verifies against.
//! * [`opt`] — CSE via hash-consing, constant folding onto the
//!   reserved Zero/One control rows, double-negation elimination, and
//!   NOT-reducing De Morgan rewrites (NOT burns a dual-contact row).
//! * [`regalloc`] — linear-scan mapping of intermediates onto a
//!   bounded pool of scratch rows leased from the allocator
//!   ([`crate::alloc::scratch::ScratchPool`]), spilling to extra rows
//!   under pressure.
//! * [`lower`] — emission of the topologically ordered
//!   [`crate::pud::isa::BulkRequest`] batch, submitted as ONE
//!   `Coordinator::submit_batch` so the hazard-wave scheduler overlaps
//!   independent subtrees across banks.
//!
//! Programs come in two shapes: a single-output [`Expr`] (predicates,
//! set algebra) and a multi-output [`MultiExpr`] (the W result
//! bit-planes of a `pud::arith` vertical-arithmetic kernel, sharing
//! one carry chain through CSE). Both run through the same optimizer,
//! register allocator, and single-batch emission.
//!
//! The user-facing entry points are
//! [`System::run_expr`](crate::coordinator::system::System::run_expr)
//! and [`System::run_multi`](crate::coordinator::system::System::run_multi);
//! `workloads::{setops, filter, analytics}` and `pud::arith` sit on
//! top of them.

pub mod expr;
pub mod lower;
pub mod opt;
pub mod regalloc;

pub use expr::{Expr, ExprBuilder, ExprId, MultiExpr, Node};
pub use lower::{
    compile, compile_multi, compile_multi_with_pool, compile_with_pool,
    Compiled, CompiledMulti, CompileStats, DEFAULT_SCRATCH_POOL,
};
pub use opt::{optimize, optimize_multi, OptReport};
