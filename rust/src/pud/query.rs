//! In-DRAM analytics query shapes composed from the vertical-arithmetic
//! primitives: bitmap **semi-join**, batched **group-by** aggregation,
//! and **top-k** by threshold bisection (DESIGN.md §13).
//!
//! All three shapes reduce to *mask-plane algebra*: every intermediate
//! is a 1-bit-per-element mask row, combined with bulk AND/OR/NOT —
//! exactly the operations the Ambit substrate executes in-DRAM when
//! PUMA placement makes the operands row-aligned and co-located.
//!
//! - **Semi-join** `probe ⋉ build`: the build side's keys become a
//!   key-presence bitmap over the key *domain* ([`present_keys`]); each
//!   present key `k` compiles to a cached `CmpEq`-const kernel whose
//!   output mask is OR-folded into the join mask, optionally ANDed with
//!   a residual predicate mask — all submitted as ONE batch.
//! - **Group-by** ([`group_masks`] / [`group_by_sum`]): one
//!   `CmpEq`-const program per group key, every emission concatenated
//!   into ONE `submit_batch` (a single host→memory boundary crossing),
//!   then a masked [`System::column_sum`] per group.
//! - **Top-k** ([`top_k`]): no sort. Bisect the value domain on the
//!   popcount of cached `CmpLt`-const masks — at most `W = log2(domain)`
//!   kernel rounds — to find the largest threshold `T` with
//!   `count(v ≥ T) ≥ k`, then materialize the selection mask `v ≥ T`
//!   as `NOT (v < T)`.
//!
//! Every shape has a `_sharded` twin that emits the same request
//! stream once per bank-disjoint shard and round-robin-interleaves the
//! streams into one batch so the hazard-wave scheduler overlaps shards
//! across banks (DESIGN.md §11).
//!
//! Padding caveat: comparison masks can set bits in padding lanes
//! (e.g. `0 < T` holds in all-zero lanes) and `NOT` flips them either
//! way. Counts here go through [`popcount_live`] and masked sums only
//! read value planes (whose padding is zero), so padded lanes never
//! leak into results.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::alloc::scratch::ScratchPool;
use crate::alloc::traits::Allocator;
use crate::coordinator::dispatch::BatchReport;
use crate::coordinator::system::{interleave_rounds, ExprReport, System};
use crate::os::process::Pid;
use crate::pud::compiler::CompiledMulti;
use crate::pud::isa::{BulkRequest, PudOp};
use crate::pud::legality::CauseCounts;

use super::arith::{
    plane_bytes, popcount_live, ArithOp, ProgramKey, ShardedLayout,
    ShardedScratch, VerticalLayout, MAX_WIDTH,
};

/// Aggregate execution report of one query shape: batch/wave counts,
/// simulated PUD time, the in-DRAM vs fallback row split, compiler
/// work, bisection rounds, and the wall-clock host-boundary cost of
/// the mask readbacks the shape performs.
#[derive(Debug, Clone, Default)]
pub struct QueryReport {
    /// `submit_batch` round trips the shape issued.
    pub batches: usize,
    /// Hazard waves across those batches.
    pub waves: usize,
    /// Serial-equivalent simulated ns (sum of per-op costs).
    pub total_ns: f64,
    /// Bank-parallel simulated completion ns.
    pub elapsed_ns: f64,
    /// Rows executed in-DRAM.
    pub pud_rows: u64,
    /// Rows that fell back to the CPU path.
    pub fallback_rows: u64,
    /// Per-cause attribution of `fallback_rows` (which PUMA placement
    /// requirement each fallback row violated).
    pub fallback_causes: CauseCounts,
    /// Fresh kernel compiles (0 once the program cache is warm).
    pub compiles: usize,
    /// Bisection rounds (top-k only; 0 for the other shapes).
    pub rounds: usize,
    /// Wall-clock ns spent reading mask planes back and popcounting.
    pub host_ns: u64,
}

impl QueryReport {
    /// In-DRAM fraction of the shape's rows (0 when nothing ran).
    pub fn pud_row_fraction(&self) -> f64 {
        let total = self.pud_rows + self.fallback_rows;
        if total == 0 {
            0.0
        } else {
            self.pud_rows as f64 / total as f64
        }
    }

    /// Fold one expression run (e.g. a masked sum) into this report.
    pub fn absorb(&mut self, rep: &ExprReport) {
        self.absorb_batch(&rep.batch);
        self.pud_rows += rep.pud_rows;
        self.fallback_rows += rep.fallback_rows;
        self.fallback_causes.merge(&rep.fallback_causes);
        self.compiles += rep.stats.compiles;
    }

    /// Fold another query report into this one (sum semantics).
    pub fn merge(&mut self, other: &QueryReport) {
        self.batches += other.batches;
        self.waves += other.waves;
        self.total_ns += other.total_ns;
        self.elapsed_ns += other.elapsed_ns;
        self.pud_rows += other.pud_rows;
        self.fallback_rows += other.fallback_rows;
        self.fallback_causes.merge(&other.fallback_causes);
        self.compiles += other.compiles;
        self.rounds += other.rounds;
        self.host_ns += other.host_ns;
    }

    fn absorb_batch(&mut self, b: &BatchReport) {
        self.batches += 1;
        self.waves += b.waves;
        self.total_ns += b.total_ns;
        self.elapsed_ns += b.elapsed_ns;
    }
}

/// One group's aggregates from [`group_by_sum`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupAggregate {
    pub group: u64,
    pub count: u64,
    pub sum: u128,
}

/// Outcome of a [`top_k`] query: the selection threshold (the k-th
/// largest value; `2^width` when `k == 0` so nothing satisfies
/// `v ≥ T`), how many elements the final `v ≥ T` mask selects (`≥ k`
/// when ties straddle the threshold), and the bisection rounds taken.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TopK {
    pub threshold: u64,
    pub selected: u64,
    pub rounds: usize,
}

/// The build side's key-presence bitmap, materialized back to the key
/// list the mask compiler needs: deduplicated, sorted, and restricted
/// to the `width`-bit key domain (out-of-domain build keys can never
/// equal a `width`-bit probe value, so they are dropped, NOT masked —
/// masking would alias them onto unrelated keys).
///
/// For domains up to 2^16 the bitmap is literal — one bit per domain
/// value, sized with [`plane_bytes`] like every other bitmap in the
/// tree; wider domains fall back to sort+dedup rather than allocate
/// gigabit bitmaps for a handful of keys.
pub fn present_keys(build_keys: &[u64], width: u32) -> Vec<u64> {
    debug_assert!(width <= MAX_WIDTH);
    let domain = 1u64 << width;
    if width <= 16 {
        let mut bitmap = vec![0u8; plane_bytes(domain as usize) as usize];
        for &k in build_keys {
            if k < domain {
                bitmap[(k / 8) as usize] |= 1 << (k % 8);
            }
        }
        let mut keys = Vec::new();
        for (byte, &b) in bitmap.iter().enumerate() {
            if b == 0 {
                continue;
            }
            for bit in 0..8 {
                if (b >> bit) & 1 == 1 {
                    keys.push((byte * 8 + bit) as u64);
                }
            }
        }
        keys
    } else {
        let mut keys: Vec<u64> =
            build_keys.iter().copied().filter(|&k| k < domain).collect();
        keys.sort_unstable();
        keys.dedup();
        keys
    }
}

/// Submit one request batch, folding the batch report and the PUD vs
/// fallback row delta into `rep`.
fn submit(
    sys: &mut System,
    pid: Pid,
    reqs: &[BulkRequest],
    rep: &mut QueryReport,
) -> Result<()> {
    let (p0, f0) = (sys.coord.stats.pud_rows, sys.coord.stats.fallback_rows);
    let causes0 = sys.coord.stats.fallback_causes;
    let batch = sys.submit_batch(pid, reqs)?;
    rep.absorb_batch(&batch);
    rep.pud_rows += sys.coord.stats.pud_rows - p0;
    rep.fallback_rows += sys.coord.stats.fallback_rows - f0;
    rep.fallback_causes
        .merge(&sys.coord.stats.fallback_causes.delta(&causes0));
    Ok(())
}

/// Fetch a cached program, counting fresh compiles into `rep`.
fn fetch(
    sys: &mut System,
    key: ProgramKey,
    rep: &mut QueryReport,
) -> Arc<CompiledMulti> {
    let (prog, hit) = sys.program(key);
    if !hit {
        rep.compiles += prog.stats.compiles;
    }
    prog
}

/// Read one mask plane back and count its live bits, charging the
/// wall-clock cost to `rep.host_ns`.
fn popcount_mask(
    sys: &mut System,
    pid: Pid,
    mask: &VerticalLayout,
    rep: &mut QueryReport,
) -> Result<u64> {
    let t0 = Instant::now();
    let bits = sys.read_virt(pid, mask.planes()[0], mask.plane_len())?;
    let n = popcount_live(&bits, mask.elems());
    rep.host_ns += t0.elapsed().as_nanos() as u64;
    Ok(n)
}

/// Sharded twin of [`popcount_mask`]: sum the live bits of every
/// shard's mask plane.
fn popcount_mask_sharded(
    sys: &mut System,
    pid: Pid,
    mask: &ShardedLayout,
    rep: &mut QueryReport,
) -> Result<u64> {
    let t0 = Instant::now();
    let mut total = 0;
    for part in mask.shards() {
        let bits = sys.read_virt(pid, part.planes()[0], part.plane_len())?;
        total += popcount_live(&bits, part.elems());
    }
    rep.host_ns += t0.elapsed().as_nanos() as u64;
    Ok(total)
}

/// Shared request-stream builder for one (shard of a) semi-join: the
/// per-key `CmpEq` masks land in pool slots `[0, K)`, are OR-folded
/// into `dst_plane`, then ANDed with the optional predicate mask.
/// `K == 0` degenerates to a bulk `Zero`; `K == 1` writes the single
/// compare straight into `dst_plane`.
#[allow(clippy::too_many_arguments)]
fn emit_semi_join(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    progs: &[Arc<CompiledMulti>],
    operands: &[u64],
    dst_plane: u64,
    pred_plane: Option<u64>,
    len: u64,
    hint: u64,
    pool: &mut ScratchPool,
) -> Result<Vec<BulkRequest>> {
    let kcount = progs.len();
    if kcount == 0 {
        return Ok(vec![BulkRequest::new(PudOp::Zero, dst_plane, vec![], len)]);
    }
    let scratch_max =
        progs.iter().map(|p| p.scratch_needed()).max().unwrap_or(0);
    let need = kcount + scratch_max;
    sys.lease_scratch(alloc, pid, pool, need, len, Some(hint))?;
    let slots = pool.slots().to_vec();
    let scratch = &slots[kcount..need];
    let mut reqs = Vec::new();
    for (i, prog) in progs.iter().enumerate() {
        let d = if kcount == 1 { dst_plane } else { slots[i] };
        reqs.extend(prog.emit(operands, &[d], len, scratch)?);
    }
    if kcount > 1 {
        reqs.push(BulkRequest::new(
            PudOp::Or,
            dst_plane,
            vec![slots[0], slots[1]],
            len,
        ));
        for &slot in &slots[2..kcount] {
            reqs.push(BulkRequest::new(
                PudOp::Or,
                dst_plane,
                vec![dst_plane, slot],
                len,
            ));
        }
    }
    if let Some(p) = pred_plane {
        reqs.push(BulkRequest::new(
            PudOp::And,
            dst_plane,
            vec![dst_plane, p],
            len,
        ));
    }
    Ok(reqs)
}

/// Bitmap semi-join `probe ⋉ build_keys`: write a 1-bit mask into
/// `dst` selecting every probe element whose key appears on the build
/// side, optionally ANDed with a pre-computed residual predicate mask
/// plane (`pred`). The whole shape — every per-key compare, the OR
/// fold, and the predicate AND — is ONE `submit_batch`.
#[allow(clippy::too_many_arguments)]
pub fn semi_join_mask(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    probe: &VerticalLayout,
    build_keys: &[u64],
    pred: Option<u64>,
    dst: &VerticalLayout,
    pool: &mut ScratchPool,
) -> Result<QueryReport> {
    ensure!(dst.width() == 1, "semi-join mask dst must be 1 bit wide");
    ensure!(
        dst.elems() == probe.elems(),
        "dst holds {} element(s), probe {}",
        dst.elems(),
        probe.elems()
    );
    ensure!(
        probe.width() <= MAX_WIDTH,
        "probe width {} exceeds MAX_WIDTH {MAX_WIDTH}",
        probe.width()
    );
    let mut rep = QueryReport::default();
    let keys = present_keys(build_keys, probe.width());
    let mut progs = Vec::with_capacity(keys.len());
    for &k in &keys {
        progs.push(fetch(
            sys,
            ProgramKey::KernelConst(ArithOp::CmpEq, probe.width(), k),
            &mut rep,
        ));
    }
    let reqs = emit_semi_join(
        sys,
        alloc,
        pid,
        &progs,
        probe.planes(),
        dst.planes()[0],
        pred,
        probe.plane_len(),
        probe.hint(),
        pool,
    )?;
    submit(sys, pid, &reqs, &mut rep)?;
    Ok(rep)
}

/// Sharded [`semi_join_mask`]: the same per-key compare + OR-fold
/// stream is emitted once per bank-disjoint shard (each leasing
/// scratch from its own per-shard pool, hinted to its own anchor) and
/// the streams are round-robin-interleaved into ONE batch so the
/// hazard-wave scheduler overlaps shards across banks.
#[allow(clippy::too_many_arguments)]
pub fn semi_join_mask_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    probe: &ShardedLayout,
    build_keys: &[u64],
    pred: Option<&ShardedLayout>,
    dst: &ShardedLayout,
    pools: &mut ShardedScratch,
) -> Result<QueryReport> {
    ensure!(dst.width() == 1, "semi-join mask dst must be 1 bit wide");
    ensure!(
        dst.n_shards() == probe.n_shards() && dst.elems() == probe.elems(),
        "dst shape mismatch"
    );
    if let Some(p) = pred {
        ensure!(
            p.n_shards() == probe.n_shards() && p.elems() == probe.elems(),
            "pred shape mismatch"
        );
    }
    let mut rep = QueryReport::default();
    let keys = present_keys(build_keys, probe.width());
    let mut progs = Vec::with_capacity(keys.len());
    for &k in &keys {
        progs.push(fetch(
            sys,
            ProgramKey::KernelConst(ArithOp::CmpEq, probe.width(), k),
            &mut rep,
        ));
    }
    let mut per_shard = Vec::with_capacity(probe.n_shards());
    for k in 0..probe.n_shards() {
        let part = probe.shard(k);
        per_shard.push(emit_semi_join(
            sys,
            alloc,
            pid,
            &progs,
            part.planes(),
            dst.shard(k).planes()[0],
            pred.map(|p| p.shard(k).planes()[0]),
            part.plane_len(),
            part.hint(),
            pools.pool(k),
        )?);
    }
    let reqs = interleave_rounds(per_shard);
    submit(sys, pid, &reqs, &mut rep)?;
    Ok(rep)
}

/// Per-group equality masks, batched: one cached `CmpEq`-const program
/// per group key, every emission concatenated into ONE `submit_batch`
/// (the single host→memory crossing is the point — the groups share
/// the scratch slots, whose WAW hazards serialize waves, but a
/// co-located flat layout has no bank parallelism to lose anyway; the
/// sharded twin keeps per-shard pools so shards still overlap).
pub fn group_masks(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    col: &VerticalLayout,
    groups: &[u64],
    dsts: &[VerticalLayout],
    pool: &mut ScratchPool,
) -> Result<QueryReport> {
    ensure!(
        groups.len() == dsts.len(),
        "{} group(s) but {} mask dst(s)",
        groups.len(),
        dsts.len()
    );
    let mut rep = QueryReport::default();
    if groups.is_empty() {
        return Ok(rep);
    }
    let domain = 1u64 << col.width();
    for (g, dst) in groups.iter().zip(dsts) {
        ensure!(*g < domain, "group key {g} outside {}-bit domain", col.width());
        ensure!(dst.width() == 1, "group mask dst must be 1 bit wide");
        ensure!(
            dst.elems() == col.elems(),
            "dst holds {} element(s), column {}",
            dst.elems(),
            col.elems()
        );
    }
    let mut progs = Vec::with_capacity(groups.len());
    for &g in groups {
        progs.push(fetch(
            sys,
            ProgramKey::KernelConst(ArithOp::CmpEq, col.width(), g),
            &mut rep,
        ));
    }
    let scratch_max =
        progs.iter().map(|p| p.scratch_needed()).max().unwrap_or(0);
    let len = col.plane_len();
    sys.lease_scratch(alloc, pid, pool, scratch_max, len, Some(col.hint()))?;
    let scratch = pool.slots()[..scratch_max].to_vec();
    let mut reqs = Vec::new();
    for (prog, dst) in progs.iter().zip(dsts) {
        reqs.extend(prog.emit(col.planes(), &[dst.planes()[0]], len, &scratch)?);
    }
    submit(sys, pid, &reqs, &mut rep)?;
    Ok(rep)
}

/// Sharded [`group_masks`]: per shard, every group's emission is
/// concatenated (sharing that shard's pool); the per-shard streams are
/// interleaved into ONE batch.
pub fn group_masks_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    col: &ShardedLayout,
    groups: &[u64],
    dsts: &[ShardedLayout],
    pools: &mut ShardedScratch,
) -> Result<QueryReport> {
    ensure!(
        groups.len() == dsts.len(),
        "{} group(s) but {} mask dst(s)",
        groups.len(),
        dsts.len()
    );
    let mut rep = QueryReport::default();
    if groups.is_empty() {
        return Ok(rep);
    }
    let domain = 1u64 << col.width();
    for (g, dst) in groups.iter().zip(dsts) {
        ensure!(*g < domain, "group key {g} outside {}-bit domain", col.width());
        ensure!(dst.width() == 1, "group mask dst must be 1 bit wide");
        ensure!(
            dst.n_shards() == col.n_shards() && dst.elems() == col.elems(),
            "dst shape mismatch"
        );
    }
    let mut progs = Vec::with_capacity(groups.len());
    for &g in groups {
        progs.push(fetch(
            sys,
            ProgramKey::KernelConst(ArithOp::CmpEq, col.width(), g),
            &mut rep,
        ));
    }
    let scratch_max =
        progs.iter().map(|p| p.scratch_needed()).max().unwrap_or(0);
    let mut per_shard = Vec::with_capacity(col.n_shards());
    for k in 0..col.n_shards() {
        let part = col.shard(k);
        let len = part.plane_len();
        sys.lease_scratch(
            alloc,
            pid,
            pools.pool(k),
            scratch_max,
            len,
            Some(part.hint()),
        )?;
        let scratch = pools.pool(k).slots()[..scratch_max].to_vec();
        let mut reqs = Vec::new();
        for (prog, dst) in progs.iter().zip(dsts) {
            reqs.extend(prog.emit(
                part.planes(),
                &[dst.shard(k).planes()[0]],
                len,
                &scratch,
            )?);
        }
        per_shard.push(reqs);
    }
    let reqs = interleave_rounds(per_shard);
    submit(sys, pid, &reqs, &mut rep)?;
    Ok(rep)
}

/// Group-by aggregation: batched per-group masks ([`group_masks`]),
/// then per group a live-bit count and a masked in-DRAM sum over
/// `values`. Mask planes are transient — allocated hinted to the key
/// column and freed before returning.
pub fn group_by_sum(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    keys: &VerticalLayout,
    values: &VerticalLayout,
    groups: &[u64],
    pool: &mut ScratchPool,
) -> Result<(Vec<GroupAggregate>, QueryReport)> {
    ensure!(
        values.elems() == keys.elems(),
        "values hold {} element(s), keys {}",
        values.elems(),
        keys.elems()
    );
    let mut rep = QueryReport::default();
    if groups.is_empty() {
        return Ok((Vec::new(), rep));
    }
    let mut masks = Vec::with_capacity(groups.len());
    for _ in groups {
        masks.push(VerticalLayout::alloc_with_hint(
            sys,
            alloc,
            pid,
            1,
            keys.elems(),
            keys.hint(),
        )?);
    }
    rep.merge(&group_masks(sys, alloc, pid, keys, groups, &masks, pool)?);
    let mut out = Vec::with_capacity(groups.len());
    for (&g, mask) in groups.iter().zip(&masks) {
        let count = popcount_mask(sys, pid, mask, &mut rep)?;
        let (sum, erep) =
            sys.arith_sum_impl(alloc, pid, values, Some(mask.planes()[0]), pool)?;
        if let Some(er) = erep {
            rep.absorb(&er);
        }
        out.push(GroupAggregate { group: g, count, sum });
    }
    for mask in &masks {
        mask.free(sys, alloc, pid)?;
    }
    Ok((out, rep))
}

/// Sharded [`group_by_sum`].
pub fn group_by_sum_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    keys: &ShardedLayout,
    values: &ShardedLayout,
    groups: &[u64],
    pools: &mut ShardedScratch,
) -> Result<(Vec<GroupAggregate>, QueryReport)> {
    ensure!(
        values.elems() == keys.elems() && values.n_shards() == keys.n_shards(),
        "values/keys shape mismatch"
    );
    let mut rep = QueryReport::default();
    if groups.is_empty() {
        return Ok((Vec::new(), rep));
    }
    let mut masks = Vec::with_capacity(groups.len());
    for _ in groups {
        masks.push(ShardedLayout::alloc_like(sys, alloc, pid, 1, keys)?);
    }
    rep.merge(&group_masks_sharded(
        sys, alloc, pid, keys, groups, &masks, pools,
    )?);
    let mut out = Vec::with_capacity(groups.len());
    for (&g, mask) in groups.iter().zip(&masks) {
        let count = popcount_mask_sharded(sys, pid, mask, &mut rep)?;
        let (sum, erep) =
            sys.arith_sum_sharded_impl(alloc, pid, values, Some(mask), pools)?;
        if let Some(er) = erep {
            rep.absorb(&er);
        }
        out.push(GroupAggregate { group: g, count, sum });
    }
    for mask in &masks {
        mask.free(sys, alloc, pid)?;
    }
    Ok((out, rep))
}

/// Materialize the mask `v ≥ rhs` into `dst` as `NOT (v < rhs)`: the
/// cached `CmpLt`-const kernel writes into a leased slot and a single
/// bulk `NOT` flips it into `dst`, all in one batch. `rhs == 0` yields
/// the all-ones mask through the same path (`v < 0` is vacuously
/// false). `rhs` must be inside the `width`-bit domain — the compiler
/// truncates constants to the operand width, so a wrapped `2^w` would
/// silently become `v ≥ 0`.
pub fn cmp_ge_mask(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    col: &VerticalLayout,
    rhs: u64,
    dst: &VerticalLayout,
    pool: &mut ScratchPool,
) -> Result<QueryReport> {
    ensure!(dst.width() == 1, "cmp-ge mask dst must be 1 bit wide");
    ensure!(
        dst.elems() == col.elems(),
        "dst holds {} element(s), column {}",
        dst.elems(),
        col.elems()
    );
    ensure!(
        rhs < 1u64 << col.width(),
        "rhs {rhs} outside {}-bit domain",
        col.width()
    );
    let mut rep = QueryReport::default();
    let prog = fetch(
        sys,
        ProgramKey::KernelConst(ArithOp::CmpLt, col.width(), rhs),
        &mut rep,
    );
    let need = 1 + prog.scratch_needed();
    let len = col.plane_len();
    sys.lease_scratch(alloc, pid, pool, need, len, Some(col.hint()))?;
    let slots = pool.slots().to_vec();
    let mut reqs = prog.emit(col.planes(), &[slots[0]], len, &slots[1..need])?;
    reqs.push(BulkRequest::new(
        PudOp::Not,
        dst.planes()[0],
        vec![slots[0]],
        len,
    ));
    submit(sys, pid, &reqs, &mut rep)?;
    Ok(rep)
}

/// Sharded [`cmp_ge_mask`].
pub fn cmp_ge_mask_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    col: &ShardedLayout,
    rhs: u64,
    dst: &ShardedLayout,
    pools: &mut ShardedScratch,
) -> Result<QueryReport> {
    ensure!(dst.width() == 1, "cmp-ge mask dst must be 1 bit wide");
    ensure!(
        dst.n_shards() == col.n_shards() && dst.elems() == col.elems(),
        "dst shape mismatch"
    );
    ensure!(
        rhs < 1u64 << col.width(),
        "rhs {rhs} outside {}-bit domain",
        col.width()
    );
    let mut rep = QueryReport::default();
    let prog = fetch(
        sys,
        ProgramKey::KernelConst(ArithOp::CmpLt, col.width(), rhs),
        &mut rep,
    );
    let need = 1 + prog.scratch_needed();
    let mut per_shard = Vec::with_capacity(col.n_shards());
    for k in 0..col.n_shards() {
        let part = col.shard(k);
        let len = part.plane_len();
        sys.lease_scratch(
            alloc,
            pid,
            pools.pool(k),
            need,
            len,
            Some(part.hint()),
        )?;
        let slots = pools.pool(k).slots().to_vec();
        let mut reqs =
            prog.emit(part.planes(), &[slots[0]], len, &slots[1..need])?;
        reqs.push(BulkRequest::new(
            PudOp::Not,
            dst.shard(k).planes()[0],
            vec![slots[0]],
            len,
        ));
        per_shard.push(reqs);
    }
    let reqs = interleave_rounds(per_shard);
    submit(sys, pid, &reqs, &mut rep)?;
    Ok(rep)
}

/// Top-k selection by threshold bisection — no sort, at most
/// `W = log2(domain)` kernel rounds.
///
/// Invariant: `lo` always satisfies `count(v ≥ lo) ≥ k` and `hi`
/// always satisfies `count(v ≥ hi) < k` (`lo = 0` counts all `n`
/// elements, `hi = 2^w` counts none — both hold without running a
/// kernel). Each round halves `[lo, hi)` on the popcount of the
/// cached `CmpLt(mid)` mask, so on exit `lo` is the LARGEST threshold
/// selecting at least `k` elements — exactly the k-th largest value.
/// The final `v ≥ lo` mask lands in `dst` via [`cmp_ge_mask`]; ties at
/// the threshold make it select ≥ k elements, matching the scalar
/// reference.
///
/// Edge cases from the invariant, not special-cased math: `k == 0`
/// yields threshold `2^w` and an all-zero mask (one bulk `Zero`, since
/// `2^w` is not representable as a kernel constant); `k ≥ n` yields
/// threshold 0 and the all-ones mask.
pub fn top_k(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    col: &VerticalLayout,
    k: u64,
    dst: &VerticalLayout,
    pool: &mut ScratchPool,
) -> Result<(TopK, QueryReport)> {
    ensure!(dst.width() == 1, "top-k mask dst must be 1 bit wide");
    ensure!(
        dst.elems() == col.elems(),
        "dst holds {} element(s), column {}",
        dst.elems(),
        col.elems()
    );
    ensure!(
        col.width() <= MAX_WIDTH,
        "column width {} exceeds MAX_WIDTH {MAX_WIDTH}",
        col.width()
    );
    let n = col.elems() as u64;
    let w = col.width();
    let mut rep = QueryReport::default();
    if k == 0 {
        let reqs = [BulkRequest::new(
            PudOp::Zero,
            dst.planes()[0],
            vec![],
            dst.plane_len(),
        )];
        submit(sys, pid, &reqs, &mut rep)?;
        let out = TopK { threshold: 1u64 << w, selected: 0, rounds: 0 };
        return Ok((out, rep));
    }
    let (mut lo, mut hi) = (0u64, 1u64 << w);
    let mut rounds = 0;
    if k < n {
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            let er =
                sys.run_arith_const_impl(alloc, pid, ArithOp::CmpLt, mid, col, dst, pool)?;
            rep.absorb(&er);
            rounds += 1;
            let count_lt = popcount_mask(sys, pid, dst, &mut rep)?;
            if n - count_lt >= k {
                lo = mid;
            } else {
                hi = mid;
            }
        }
    }
    rep.rounds = rounds;
    rep.merge(&cmp_ge_mask(sys, alloc, pid, col, lo, dst, pool)?);
    let selected = popcount_mask(sys, pid, dst, &mut rep)?;
    Ok((TopK { threshold: lo, selected, rounds }, rep))
}

/// Sharded [`top_k`]: bisection rounds run through
/// [`System::run_arith_const_sharded_impl`] (one interleaved batch per
/// round) and counts sum the live bits across shards.
pub fn top_k_sharded(
    sys: &mut System,
    alloc: &mut dyn Allocator,
    pid: Pid,
    col: &ShardedLayout,
    k: u64,
    dst: &ShardedLayout,
    pools: &mut ShardedScratch,
) -> Result<(TopK, QueryReport)> {
    ensure!(dst.width() == 1, "top-k mask dst must be 1 bit wide");
    ensure!(
        dst.n_shards() == col.n_shards() && dst.elems() == col.elems(),
        "dst shape mismatch"
    );
    let n = col.elems() as u64;
    let w = col.width();
    let mut rep = QueryReport::default();
    if k == 0 {
        let mut per_shard = Vec::with_capacity(dst.n_shards());
        for part in dst.shards() {
            per_shard.push(vec![BulkRequest::new(
                PudOp::Zero,
                part.planes()[0],
                vec![],
                part.plane_len(),
            )]);
        }
        let reqs = interleave_rounds(per_shard);
        submit(sys, pid, &reqs, &mut rep)?;
        let out = TopK { threshold: 1u64 << w, selected: 0, rounds: 0 };
        return Ok((out, rep));
    }
    let (mut lo, mut hi) = (0u64, 1u64 << w);
    let mut rounds = 0;
    if k < n {
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            let er = sys.run_arith_const_sharded_impl(
                alloc,
                pid,
                ArithOp::CmpLt,
                mid,
                col,
                dst,
                pools,
            )?;
            rep.absorb(&er);
            rounds += 1;
            let count_lt = popcount_mask_sharded(sys, pid, dst, &mut rep)?;
            if n - count_lt >= k {
                lo = mid;
            } else {
                hi = mid;
            }
        }
    }
    rep.rounds = rounds;
    rep.merge(&cmp_ge_mask_sharded(sys, alloc, pid, col, lo, dst, pools)?);
    let selected = popcount_mask_sharded(sys, pid, dst, &mut rep)?;
    Ok((TopK { threshold: lo, selected, rounds }, rep))
}

/// Scalar host oracles for the three query shapes — the ground truth
/// the differential fuzzing harness (`tests/prop_queries.rs`) and the
/// workload's inline verification compare every PUD result against.
pub mod reference {
    use std::collections::HashSet;

    /// `probe[i]` survives iff its key appears in `build_keys` AND the
    /// optional residual predicate holds.
    pub fn semi_join(
        probe: &[u64],
        build_keys: &[u64],
        pred: Option<&[bool]>,
    ) -> Vec<bool> {
        let set: HashSet<u64> = build_keys.iter().copied().collect();
        probe
            .iter()
            .enumerate()
            .map(|(i, v)| set.contains(v) && pred.map_or(true, |p| p[i]))
            .collect()
    }

    /// Per requested group key: `(count, sum of values)` over the rows
    /// whose key equals it.
    pub fn group_by(
        keys: &[u64],
        values: &[u64],
        groups: &[u64],
    ) -> Vec<(u64, u128)> {
        groups
            .iter()
            .map(|&g| {
                let mut count = 0u64;
                let mut sum = 0u128;
                for (k, v) in keys.iter().zip(values) {
                    if *k == g {
                        count += 1;
                        sum += *v as u128;
                    }
                }
                (count, sum)
            })
            .collect()
    }

    /// `(threshold, selection)` with the same semantics as
    /// [`super::top_k`]: threshold = k-th largest value (`2^width`
    /// when `k == 0`, 0 when `k ≥ n`), selection = `v ≥ threshold`.
    pub fn top_k(values: &[u64], k: u64, width: u32) -> (u64, Vec<bool>) {
        let n = values.len() as u64;
        if k == 0 {
            return (1u64 << width, vec![false; values.len()]);
        }
        if k >= n {
            return (0, vec![true; values.len()]);
        }
        let mut sorted = values.to_vec();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let t = sorted[(k - 1) as usize];
        (t, values.iter().map(|&v| v >= t).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn present_keys_dedups_sorts_and_drops_out_of_domain() {
        let keys = present_keys(&[9, 3, 3, 16, 0, 9, 255], 4);
        assert_eq!(keys, vec![0, 3, 9]); // 16 and 255 exceed the 4-bit domain
        assert!(present_keys(&[], 8).is_empty());
        assert!(present_keys(&[1 << 20], 16).is_empty());
        // wide-domain fallback path behaves identically
        let wide = present_keys(&[5, 1, 5, (1 << 20) - 1, 1 << 20], 20);
        assert_eq!(wide, vec![1, 5, (1 << 20) - 1]);
    }

    #[test]
    fn reference_top_k_edges() {
        let vals = [7u64, 3, 7, 1];
        let (t, sel) = reference::top_k(&vals, 0, 4);
        assert_eq!(t, 16);
        assert!(sel.iter().all(|&s| !s));
        let (t, sel) = reference::top_k(&vals, 9, 4);
        assert_eq!(t, 0);
        assert!(sel.iter().all(|&s| s));
        // ties straddling the threshold select >= k
        let (t, sel) = reference::top_k(&vals, 1, 4);
        assert_eq!(t, 7);
        assert_eq!(sel, vec![true, false, true, false]);
        let (t, _) = reference::top_k(&vals, 3, 4);
        assert_eq!(t, 3);
    }

    #[test]
    fn reference_semi_join_and_group_by() {
        let probe = [1u64, 2, 3, 2];
        let m = reference::semi_join(&probe, &[2, 9], None);
        assert_eq!(m, vec![false, true, false, true]);
        let pred = [true, false, true, true];
        let m = reference::semi_join(&probe, &[2, 9], Some(&pred));
        assert_eq!(m, vec![false, false, false, true]);
        let agg = reference::group_by(&[1, 2, 1], &[10, 20, 30], &[1, 2, 7]);
        assert_eq!(agg, vec![(2, 40), (1, 20), (0, 0)]);
    }
}
