//! # PUMA — full-system reproduction
//!
//! Library root for the reproduction of *PUMA: Efficient and Low-Cost
//! Memory Allocation and Alignment Support for Processing-Using-Memory
//! Architectures* (Oliveira et al., ETH Zürich, 2024).
//!
//! The crate contains the complete simulated stack the paper's
//! evaluation needs (see DESIGN.md for the inventory):
//!
//! * [`dram`] — DRAM device model: geometry, configurable address
//!   interleaving (device-tree style), DDR command timing, energy, and
//!   a functional backing store.
//! * [`os`] — OS memory substrate: buddy frame allocator, Sv39-like
//!   page tables, VMA manager, boot-time huge-page pool, processes.
//! * [`alloc`] — the allocators under study: `malloc`/`posix_memalign`
//!   simulations, huge-page-backed allocation, and **PUMA** itself —
//!   including the allocation lifecycle (free-path coalescing,
//!   huge-page reclamation, RowClone-driven compaction; DESIGN.md §8).
//! * [`pud`] — the processing-using-DRAM substrate (Ambit + RowClone):
//!   legality checks, functional execution, command timing.
//! * [`analysis`] — static analysis over compiled PUD programs: the
//!   dataflow verifier + translation validator that proves emitted
//!   request streams byte-equivalent to their source expression DAGs,
//!   and the placement linter that attributes every fallback row to
//!   the PUMA requirement it violated (DESIGN.md §16).
//! * [`coordinator`] — the plan/schedule/execute request pipeline:
//!   batches of bulk operations are planned into the `OpPlan` IR
//!   (cached extent translation + legality), scheduled into hazard
//!   waves with cross-op fallback coalescing and bank-parallel
//!   timing, and executed on PUD or the CPU fallback (DESIGN.md §§2-4).
//! * [`obs`] — observability: the metrics registry (counters, gauges,
//!   log2 latency histograms), the wave-granularity sim-time tracer,
//!   and the exporters (Perfetto JSON, replayable DDR command stream,
//!   Prometheus text; DESIGN.md §14).
//! * [`serve`] — the multi-tenant serving front-end: per-tenant
//!   [`Session`](serve::Session) handles (pids stay private), a
//!   deficit-round-robin fairness scheduler merging tenants' requests
//!   into multi-pid hazard-wave batches, and typed admission control
//!   with backpressure (DESIGN.md §15).
//! * [`runtime`] — XLA/PJRT CPU runtime executing the AOT-compiled
//!   JAX + Pallas kernels (`artifacts/*.hlo.txt`) for the fallback;
//!   built against an inert stub unless the `xla-runtime` feature
//!   supplies real bindings (DESIGN.md §7).
//! * [`workloads`] — the paper's micro-benchmarks and app workloads.
//! * [`report`] — regenerates every figure/table of the paper.
//! * [`util`], [`proptest`] — support code that is ordinarily a crates
//!   dependency (offline build; see DESIGN.md §7).

// Lint policy lives in Cargo.toml's [lints] table so tests, benches,
// and examples share it; CI enforces `clippy --all-targets -D warnings`.

pub mod alloc;
pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod obs;
pub mod os;
pub mod proptest;
pub mod pud;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
