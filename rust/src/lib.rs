//! # PUMA — full-system reproduction
//!
//! Library root for the reproduction of *PUMA: Efficient and Low-Cost
//! Memory Allocation and Alignment Support for Processing-Using-Memory
//! Architectures* (Oliveira et al., ETH Zürich, 2024).
//!
//! The crate contains the complete simulated stack the paper's
//! evaluation needs (see DESIGN.md for the inventory):
//!
//! * [`dram`] — DRAM device model: geometry, configurable address
//!   interleaving (device-tree style), DDR command timing, energy, and
//!   a functional backing store.
//! * [`os`] — OS memory substrate: buddy frame allocator, Sv39-like
//!   page tables, VMA manager, boot-time huge-page pool, processes.
//! * [`alloc`] — the allocators under study: `malloc`/`posix_memalign`
//!   simulations, huge-page-backed allocation, and **PUMA** itself.
//! * [`pud`] — the processing-using-DRAM substrate (Ambit + RowClone):
//!   legality checks, functional execution, command timing.
//! * [`coordinator`] — the dispatch layer: routes each bulk operation
//!   to PUD when operand placement allows, else to the CPU fallback.
//! * [`runtime`] — XLA/PJRT CPU runtime executing the AOT-compiled
//!   JAX + Pallas kernels (`artifacts/*.hlo.txt`) for the fallback.
//! * [`workloads`] — the paper's micro-benchmarks and app workloads.
//! * [`report`] — regenerates every figure/table of the paper.
//! * [`util`], [`proptest`] — support code that is ordinarily a crates
//!   dependency (offline build; see DESIGN.md §7).

pub mod alloc;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod os;
pub mod proptest;
pub mod pud;
pub mod report;
pub mod runtime;
pub mod util;
pub mod workloads;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
