//! Per-bank row-buffer state machine.
//!
//! Tracks which row is open in each bank so CPU access streams get
//! row-hit/row-miss timing; PUD command sequences (AAP/TRA) leave the
//! bank precharged.

use rustc_hash::FxHashMap;

use super::geometry::{DramGeometry, Loc};
use super::timing::TimingParams;

/// Bank state: open row (per bank, identified by the dense bank id).
#[derive(Debug, Default)]
pub struct BankState {
    /// bank id -> open (subarray, row), None when precharged
    open: FxHashMap<u32, (u32, u32)>,
    pub hits: u64,
    pub misses: u64,
}

impl BankState {
    pub fn new() -> Self {
        Self::default()
    }

    fn bank_id(geom: &DramGeometry, loc: &Loc) -> u32 {
        (loc.channel * geom.ranks_per_channel + loc.rank) * geom.banks_per_rank
            + loc.bank
    }

    /// Account a column access at `loc`; returns its latency and
    /// updates hit/miss counters and the open row.
    pub fn access(
        &mut self,
        geom: &DramGeometry,
        timing: &TimingParams,
        loc: &Loc,
    ) -> f64 {
        let bid = Self::bank_id(geom, loc);
        let target = (loc.subarray, loc.row);
        match self.open.get(&bid) {
            Some(&open) if open == target => {
                self.hits += 1;
                timing.row_hit_ns()
            }
            _ => {
                self.misses += 1;
                self.open.insert(bid, target);
                timing.row_miss_ns()
            }
        }
    }

    /// PUD sequences close the rows they touch (AAP ends precharged).
    pub fn precharge(&mut self, geom: &DramGeometry, loc: &Loc) {
        self.open.remove(&Self::bank_id(geom, loc));
    }

    /// Precharge-all (e.g. refresh boundary).
    pub fn precharge_all(&mut self) {
        self.open.clear();
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loc(bank: u32, subarray: u32, row: u32, column: u32) -> Loc {
        Loc {
            channel: 0,
            rank: 0,
            bank,
            subarray,
            row,
            column,
        }
    }

    #[test]
    fn first_access_misses_then_hits() {
        let g = DramGeometry::default();
        let t = TimingParams::default();
        let mut b = BankState::new();
        let l = loc(0, 0, 5, 0);
        let first = b.access(&g, &t, &l);
        let second = b.access(&g, &t, &loc(0, 0, 5, 64));
        assert_eq!(first, t.row_miss_ns());
        assert_eq!(second, t.row_hit_ns());
        assert_eq!((b.hits, b.misses), (1, 1));
    }

    #[test]
    fn row_conflict_misses() {
        let g = DramGeometry::default();
        let t = TimingParams::default();
        let mut b = BankState::new();
        b.access(&g, &t, &loc(0, 0, 5, 0));
        let conflict = b.access(&g, &t, &loc(0, 0, 6, 0));
        assert_eq!(conflict, t.row_miss_ns());
    }

    #[test]
    fn different_banks_independent() {
        let g = DramGeometry::default();
        let t = TimingParams::default();
        let mut b = BankState::new();
        b.access(&g, &t, &loc(0, 0, 5, 0));
        b.access(&g, &t, &loc(1, 0, 9, 0));
        // bank 0 row 5 still open
        assert_eq!(b.access(&g, &t, &loc(0, 0, 5, 64)), t.row_hit_ns());
    }

    #[test]
    fn same_bank_different_subarray_is_conflict() {
        // two subarrays of one bank share the bank-level open-row slot
        // in our model (one row buffer active per bank at a time)
        let g = DramGeometry::default();
        let t = TimingParams::default();
        let mut b = BankState::new();
        b.access(&g, &t, &loc(0, 0, 5, 0));
        assert_eq!(b.access(&g, &t, &loc(0, 1, 5, 0)), t.row_miss_ns());
    }

    #[test]
    fn precharge_forces_miss() {
        let g = DramGeometry::default();
        let t = TimingParams::default();
        let mut b = BankState::new();
        let l = loc(0, 0, 5, 0);
        b.access(&g, &t, &l);
        b.precharge(&g, &l);
        assert_eq!(b.access(&g, &t, &l), t.row_miss_ns());
    }

    #[test]
    fn hit_rate_math() {
        let g = DramGeometry::default();
        let t = TimingParams::default();
        let mut b = BankState::new();
        assert_eq!(b.hit_rate(), 0.0);
        let l = loc(0, 0, 1, 0);
        b.access(&g, &t, &l);
        b.access(&g, &t, &l);
        b.access(&g, &t, &l);
        assert!((b.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
