//! Analytic DRAM command timing, including the PUD command sequences.
//!
//! All results in the reproduction are reported in *simulated
//! nanoseconds* derived from these parameters (DESIGN.md §3). Defaults
//! model a DDR4-2400-class part; the PUD sequence costs follow the
//! RowClone and Ambit papers' command counts:
//!
//! * `AAP` (ACTIVATE-ACTIVATE-PRECHARGE) — RowClone-FPM's back-to-back
//!   activation; one AAP copies a full row inside a subarray (~90 ns,
//!   vs ~1000 ns to move the same row over the channel).
//! * Ambit `bbop_and/or` — 4 AAPs (copy A,B and a control row into the
//!   designated TRA rows, triple-activate, copy out).
//! * Ambit `bbop_not` — 2 AAPs through the dual-contact row.
//! * RowClone-PSM — inter-subarray copy: the row transits the bank I/O
//!   as column reads+writes (no channel transfer, but serialized).
//!
//! The CPU fallback streams both operands over the channel and writes
//! the result back; its cost is `bytes / effective_bandwidth` plus a
//! fixed per-operation dispatch overhead. This reproduces the paper's
//! observation that the penalty of a failed PUD op grows linearly with
//! allocation size.

/// Timing parameters (nanoseconds unless noted).
#[derive(Debug, Clone, PartialEq)]
pub struct TimingParams {
    /// ACTIVATE to column command (tRCD).
    pub t_rcd: f64,
    /// Row-active minimum (tRAS).
    pub t_ras: f64,
    /// PRECHARGE latency (tRP).
    pub t_rp: f64,
    /// Column access strobe latency (tCL).
    pub t_cl: f64,
    /// Data-burst time for one 64-byte transfer (BL8 @ DDR4-2400).
    pub t_burst: f64,
    /// One AAP (RowClone-FPM intra-subarray row copy).
    pub t_aap: f64,
    /// Effective CPU streaming bandwidth, bytes/ns (= GB/s).
    pub cpu_stream_bw: f64,
    /// Fixed per-bulk-op dispatch overhead on the CPU path (syscall +
    /// driver, ns).
    pub cpu_dispatch_overhead: f64,
    /// Fixed per-bulk-op overhead on the PUD path (command injection
    /// via the memory controller, ns).
    pub pud_dispatch_overhead: f64,
}

impl Default for TimingParams {
    fn default() -> Self {
        Self {
            t_rcd: 13.32,
            t_ras: 32.0,
            t_rp: 13.32,
            t_cl: 13.32,
            t_burst: 3.33,
            t_aap: 90.0,
            cpu_stream_bw: 12.0, // ~12 GB/s effective single-core stream
            cpu_dispatch_overhead: 1_000.0,
            pud_dispatch_overhead: 200.0,
        }
    }
}

/// Cache-line size used for channel transfers.
pub const LINE_BYTES: u64 = 64;

impl TimingParams {
    /// Time to read (or write) one full row over the channel after the
    /// row is open: column bursts back-to-back.
    pub fn row_stream_ns(&self, row_bytes: u32) -> f64 {
        (row_bytes as u64).div_ceil(LINE_BYTES) as f64 * self.t_burst
    }

    /// Row-miss access: PRE + ACT + CAS + one burst.
    pub fn row_miss_ns(&self) -> f64 {
        self.t_rp + self.t_rcd + self.t_cl + self.t_burst
    }

    /// Row-hit access: CAS + one burst.
    pub fn row_hit_ns(&self) -> f64 {
        self.t_cl + self.t_burst
    }

    // ------------------------------------------------ PUD sequences

    /// RowClone-FPM: one AAP per row (both operands in one subarray).
    pub fn rowclone_fpm_ns(&self, rows: u64) -> f64 {
        rows as f64 * self.t_aap
    }

    /// RowClone zero-init: one AAP from the reserved zero row.
    pub fn rowclone_zero_ns(&self, rows: u64) -> f64 {
        rows as f64 * self.t_aap
    }

    /// RowClone-PSM: inter-subarray (same bank) copy — the row moves
    /// through the bank's global sense amps as serialized column
    /// reads and writes, with an ACT/PRE pair on each side.
    pub fn rowclone_psm_ns(&self, rows: u64, row_bytes: u32) -> f64 {
        let per_row = 2.0 * (self.t_rcd + self.t_rp)
            + 2.0 * self.row_stream_ns(row_bytes);
        rows as f64 * per_row
    }

    /// Ambit AND/OR: 4 AAPs per row (stage A, stage B, stage control,
    /// TRA + copy-out).
    pub fn ambit_and_or_ns(&self, rows: u64) -> f64 {
        rows as f64 * 4.0 * self.t_aap
    }

    /// Ambit NOT: 2 AAPs per row (through the dual-contact row).
    pub fn ambit_not_ns(&self, rows: u64) -> f64 {
        rows as f64 * 2.0 * self.t_aap
    }

    /// Ambit XOR: composed of AND/NOT sequences — 7 AAPs per row.
    pub fn ambit_xor_ns(&self, rows: u64) -> f64 {
        rows as f64 * 7.0 * self.t_aap
    }

    // ------------------------------------------------ CPU fallback

    /// CPU bulk path: stream `read_bytes` in and `write_bytes` out at
    /// the effective bandwidth, plus dispatch overhead.
    pub fn cpu_bulk_ns(&self, read_bytes: u64, write_bytes: u64) -> f64 {
        self.cpu_dispatch_overhead
            + (read_bytes + write_bytes) as f64 / self.cpu_stream_bw
    }

    /// Inter-subarray data relocation cost used when PUMA must migrate
    /// a region (re-mmap keeps VA stable; the physical copy is PSM).
    pub fn migrate_ns(&self, rows: u64, row_bytes: u32) -> f64 {
        self.rowclone_psm_ns(rows, row_bytes)
    }

    // --------------------------------------- bank-level parallelism

    /// Makespan of a set of per-bank command timelines.
    ///
    /// PUD commands on different banks (and on independent subarrays
    /// behind them) proceed concurrently — MIMDRAM/PiDRAM's source of
    /// end-to-end throughput — so a batch of row operations scheduled
    /// onto disjoint banks completes in the time of the *busiest*
    /// bank, not the sum. The scheduler feeds the summed busy time of
    /// each bank; an empty set completes instantly.
    pub fn bank_parallel_ns<I: IntoIterator<Item = f64>>(&self, timelines: I) -> f64 {
        timelines.into_iter().fold(0.0, f64::max)
    }

    /// One fallback row's DRAM+CPU streaming cost, excluding the
    /// per-operation dispatch overhead (charged once per op). Must
    /// match the per-row accounting in `PudEngine::execute`.
    pub fn fallback_row_ns(&self, bytes: u64, arity: usize) -> f64 {
        self.cpu_bulk_ns(bytes * arity as u64, bytes) - self.cpu_dispatch_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpm_beats_cpu_by_an_order_of_magnitude() {
        let t = TimingParams::default();
        let rows = 16u64;
        let row_bytes = 8192u32;
        let bytes = rows * row_bytes as u64;
        let fpm = t.rowclone_fpm_ns(rows);
        let cpu = t.cpu_bulk_ns(bytes, bytes);
        assert!(
            cpu / fpm > 10.0,
            "FPM {fpm} ns vs CPU {cpu} ns — expected >10x gap"
        );
    }

    #[test]
    fn psm_between_fpm_and_cpu() {
        let t = TimingParams::default();
        let rows = 8;
        let rb = 8192;
        let fpm = t.rowclone_fpm_ns(rows);
        let psm = t.rowclone_psm_ns(rows, rb);
        let cpu = t.cpu_bulk_ns(rows * rb as u64, rows * rb as u64);
        assert!(fpm < psm, "fpm {fpm} < psm {psm}");
        assert!(psm < cpu, "psm {psm} < cpu {cpu}");
    }

    #[test]
    fn ambit_sequences_scale_with_rows() {
        let t = TimingParams::default();
        assert_eq!(t.ambit_and_or_ns(2), 2.0 * 4.0 * t.t_aap);
        assert_eq!(t.ambit_not_ns(3), 3.0 * 2.0 * t.t_aap);
        assert!(t.ambit_xor_ns(1) > t.ambit_and_or_ns(1));
    }

    #[test]
    fn row_stream_counts_lines() {
        let t = TimingParams::default();
        assert_eq!(t.row_stream_ns(8192), 128.0 * t.t_burst);
        // partial line rounds up
        assert_eq!(t.row_stream_ns(65), 2.0 * t.t_burst);
    }

    #[test]
    fn cpu_cost_linear_in_bytes() {
        let t = TimingParams::default();
        let small = t.cpu_bulk_ns(1 << 10, 1 << 10);
        let big = t.cpu_bulk_ns(1 << 20, 1 << 20);
        // subtracting the fixed overhead, big/small == 1024
        let ratio = (big - t.cpu_dispatch_overhead)
            / (small - t.cpu_dispatch_overhead);
        assert!((ratio - 1024.0).abs() < 1e-6);
    }

    #[test]
    fn hit_cheaper_than_miss() {
        let t = TimingParams::default();
        assert!(t.row_hit_ns() < t.row_miss_ns());
    }
}
