//! Per-command energy accounting.
//!
//! Rough DDR4-class constants (nanojoules); the absolute values are
//! estimates, but the *ratios* follow the RowClone/Ambit results the
//! paper builds on: in-DRAM copy avoids the channel I/O energy that
//! dominates CPU-path bulk transfers, so FPM copy is an order of
//! magnitude cheaper per byte than moving the data out and back.

use super::device::DramCounters;

/// Energy constants in nanojoules per event.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyParams {
    /// One ACTIVATE+PRECHARGE pair.
    pub act_pre_nj: f64,
    /// One 64-byte line transferred over the channel (incl. I/O).
    pub line_io_nj: f64,
    /// One AAP sequence (two activations, no channel I/O).
    pub aap_nj: f64,
    /// One triple-row activation.
    pub tra_nj: f64,
    /// One row moved by PSM (internal column reads/writes).
    pub psm_row_nj: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            act_pre_nj: 2.5,
            line_io_nj: 1.3,
            aap_nj: 5.5,     // ~2 activations + margin
            tra_nj: 8.0,     // three simultaneous activations
            psm_row_nj: 95.0, // 128 internal line moves per 8 KiB row
        }
    }
}

impl EnergyParams {
    /// Total energy (nJ) implied by a counter snapshot.
    pub fn total_nj(&self, c: &DramCounters) -> f64 {
        c.activates as f64 * self.act_pre_nj
            + (c.line_reads + c.line_writes) as f64 * self.line_io_nj
            + c.aaps as f64 * self.aap_nj
            + c.tras as f64 * self.tra_nj
            + c.psm_rows as f64 * self.psm_row_nj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_counters_zero_energy() {
        let e = EnergyParams::default();
        assert_eq!(e.total_nj(&DramCounters::default()), 0.0);
    }

    #[test]
    fn fpm_copy_cheaper_than_channel_copy() {
        let e = EnergyParams::default();
        // copy one 8 KiB row in-DRAM: 1 AAP
        let fpm = DramCounters {
            aaps: 1,
            ..Default::default()
        };
        // copy the same row over the channel: 128 line reads + 128
        // line writes + 2 activations
        let cpu = DramCounters {
            activates: 2,
            line_reads: 128,
            line_writes: 128,
            ..Default::default()
        };
        let ratio = e.total_nj(&cpu) / e.total_nj(&fpm);
        assert!(ratio > 10.0, "expected >10x energy gap, got {ratio}");
    }

    #[test]
    fn linear_in_counters() {
        let e = EnergyParams::default();
        let one = DramCounters {
            aaps: 1,
            tras: 1,
            ..Default::default()
        };
        let two = DramCounters {
            aaps: 2,
            tras: 2,
            ..Default::default()
        };
        assert!((e.total_nj(&two) - 2.0 * e.total_nj(&one)).abs() < 1e-9);
    }
}
