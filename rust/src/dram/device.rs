//! Functional DRAM backing store with access accounting.
//!
//! The device stores real bytes (lazily materialized per row, so an
//! 8 GiB device costs only what the workload touches) and counts
//! every access class. PUD ops and the CPU fallback both mutate this
//! store, which lets integration tests assert that the two execution
//! paths produce identical memory images.

use rustc_hash::FxHashMap;

use super::address::InterleaveScheme;
use super::geometry::{DramGeometry, Loc};

/// Access counters (command-level, for reports and energy).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DramCounters {
    /// Row activations attributable to CPU-path accesses.
    pub activates: u64,
    /// 64-byte line reads over the channel.
    pub line_reads: u64,
    /// 64-byte line writes over the channel.
    pub line_writes: u64,
    /// AAP sequences issued (RowClone FPM / Ambit staging).
    pub aaps: u64,
    /// Triple-row activations issued (Ambit).
    pub tras: u64,
    /// Rows moved via PSM (inter-subarray).
    pub psm_rows: u64,
}

/// The simulated DRAM device.
pub struct DramDevice {
    pub scheme: InterleaveScheme,
    /// global row index -> row contents (lazily materialized, zeroed).
    rows: FxHashMap<u64, Box<[u8]>>,
    pub counters: DramCounters,
}

impl DramDevice {
    pub fn new(scheme: InterleaveScheme) -> Self {
        Self {
            scheme,
            rows: FxHashMap::default(),
            counters: DramCounters::default(),
        }
    }

    pub fn geometry(&self) -> &DramGeometry {
        &self.scheme.geometry
    }

    fn row_bytes(&self) -> usize {
        self.scheme.geometry.row_bytes as usize
    }

    /// Number of rows actually materialized (for memory accounting).
    pub fn resident_rows(&self) -> usize {
        self.rows.len()
    }

    fn row_mut(&mut self, global_row: u64) -> &mut Box<[u8]> {
        let rb = self.row_bytes();
        self.rows
            .entry(global_row)
            .or_insert_with(|| vec![0u8; rb].into_boxed_slice())
    }

    /// Read `buf.len()` bytes starting at physical address `addr`,
    /// crossing row boundaries as needed. Pure-functional (no counter
    /// updates) — timing/counters belong to the caller, which knows
    /// whether this models a CPU stream or a PUD staging access.
    pub fn read(&mut self, addr: u64, buf: &mut [u8]) {
        let rb = self.row_bytes() as u64;
        let mut off = 0usize;
        let mut cur = addr;
        while off < buf.len() {
            let loc = self.scheme.decode(cur);
            let grow = self.scheme.geometry.global_row(&loc);
            let start = loc.column as usize;
            let n = ((rb - loc.column as u64) as usize).min(buf.len() - off);
            match self.rows.get(&grow) {
                Some(row) => buf[off..off + n].copy_from_slice(&row[start..start + n]),
                None => buf[off..off + n].fill(0),
            }
            off += n;
            cur += n as u64;
        }
    }

    /// Write bytes starting at physical address `addr`.
    pub fn write(&mut self, addr: u64, data: &[u8]) {
        let rb = self.row_bytes() as u64;
        let mut off = 0usize;
        let mut cur = addr;
        while off < data.len() {
            let loc = self.scheme.decode(cur);
            let grow = self.scheme.geometry.global_row(&loc);
            let start = loc.column as usize;
            let n = ((rb - loc.column as u64) as usize).min(data.len() - off);
            let row = self.row_mut(grow);
            row[start..start + n].copy_from_slice(&data[off..off + n]);
            off += n;
            cur += n as u64;
        }
    }

    /// Whole-row read by location (must be row-aligned usage; PUD path).
    pub fn read_row(&mut self, loc: &Loc) -> Vec<u8> {
        debug_assert_eq!(loc.column, 0);
        let grow = self.scheme.geometry.global_row(loc);
        match self.rows.get(&grow) {
            Some(row) => row.to_vec(),
            None => vec![0u8; self.row_bytes()],
        }
    }

    /// Whole-row write by location (PUD path).
    pub fn write_row(&mut self, loc: &Loc, data: &[u8]) {
        debug_assert_eq!(loc.column, 0);
        debug_assert_eq!(data.len(), self.row_bytes());
        let grow = self.scheme.geometry.global_row(loc);
        self.row_mut(grow).copy_from_slice(data);
    }

    /// Account a CPU stream of `bytes` starting at `addr` (reads).
    pub fn account_cpu_read(&mut self, addr: u64, bytes: u64) {
        let lines = bytes.div_ceil(super::timing::LINE_BYTES);
        self.counters.line_reads += lines;
        // one activation per distinct row touched
        let rb = self.row_bytes() as u64;
        let first = addr / rb;
        let last = (addr + bytes.max(1) - 1) / rb;
        self.counters.activates += last - first + 1;
    }

    /// Account a CPU stream of `bytes` starting at `addr` (writes).
    pub fn account_cpu_write(&mut self, addr: u64, bytes: u64) {
        let lines = bytes.div_ceil(super::timing::LINE_BYTES);
        self.counters.line_writes += lines;
        let rb = self.row_bytes() as u64;
        let first = addr / rb;
        let last = (addr + bytes.max(1) - 1) / rb;
        self.counters.activates += last - first + 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::DramGeometry;

    fn device() -> DramDevice {
        let geom = DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 2,
            rows_per_subarray: 4,
            row_bytes: 64,
        };
        DramDevice::new(InterleaveScheme::row_major(geom))
    }

    #[test]
    fn read_back_what_was_written() {
        let mut d = device();
        let data: Vec<u8> = (0..100).collect();
        d.write(10, &data);
        let mut got = vec![0u8; 100];
        d.read(10, &mut got);
        assert_eq!(got, data);
    }

    #[test]
    fn unwritten_memory_reads_zero() {
        let mut d = device();
        let mut buf = vec![0xAAu8; 32];
        d.read(200, &mut buf);
        assert_eq!(buf, vec![0u8; 32]);
        assert_eq!(d.resident_rows(), 0);
    }

    #[test]
    fn writes_cross_row_boundaries() {
        let mut d = device();
        // row size 64: write 200 bytes spanning 4 rows
        let data: Vec<u8> = (0..200).map(|i| (i % 251) as u8).collect();
        d.write(30, &data);
        assert!(d.resident_rows() >= 3);
        let mut got = vec![0u8; 200];
        d.read(30, &mut got);
        assert_eq!(got, data);
        // bytes before the write are untouched
        let mut head = vec![0u8; 30];
        d.read(0, &mut head);
        assert_eq!(head, vec![0u8; 30]);
    }

    #[test]
    fn row_read_write_roundtrip() {
        let mut d = device();
        let loc = d.scheme.decode(0);
        let row: Vec<u8> = (0..64).collect();
        d.write_row(&loc, &row);
        assert_eq!(d.read_row(&loc), row);
        // and via the byte interface at the row's physical address
        let addr = d.scheme.encode(&loc);
        let mut buf = vec![0u8; 64];
        d.read(addr, &mut buf);
        assert_eq!(buf, row);
    }

    #[test]
    fn cpu_accounting_counts_lines_and_rows() {
        let mut d = device();
        d.account_cpu_read(0, 128); // 2 lines, rows 0..1 (64B rows)
        assert_eq!(d.counters.line_reads, 2);
        assert_eq!(d.counters.activates, 2);
        d.account_cpu_write(0, 1);
        assert_eq!(d.counters.line_writes, 1);
        assert_eq!(d.counters.activates, 3);
    }

    #[test]
    fn lazy_rows_bound_memory() {
        let mut d = DramDevice::new(InterleaveScheme::row_major(
            DramGeometry::default(), // 8 GiB
        ));
        d.write(4096, b"hello");
        assert_eq!(d.resident_rows(), 1);
        let mut buf = [0u8; 5];
        d.read(4096, &mut buf);
        assert_eq!(&buf, b"hello");
    }
}
