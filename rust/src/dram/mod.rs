//! DRAM device model.
//!
//! Everything the paper's evaluation needs from the memory system:
//!
//! * [`geometry`] — organization (channels/ranks/banks/subarrays/rows/
//!   columns) and typed coordinates.
//! * [`address`] — the configurable physical-address interleaving
//!   scheme (bit-field mapping) and the subarray-ID extraction PUMA
//!   keys its ordered array on.
//! * [`devicetree`] — parser for the device-tree-style description the
//!   memory controller exposes (paper §2, component ii).
//! * [`timing`] — DDR4-style command timing, including the PUD command
//!   sequences (AAP, TRA) used for analytic latency accounting.
//! * [`bank`] — per-bank row-buffer state machine (open-row tracking).
//! * [`device`] — the functional backing store: byte-addressable,
//!   lazily materialized rows, access counters.
//! * [`energy`] — per-command energy accounting (RowClone/Ambit data).

pub mod address;
pub mod bank;
pub mod device;
pub mod devicetree;
pub mod energy;
pub mod geometry;
pub mod timing;

pub use address::{Field, InterleaveScheme};
pub use device::DramDevice;
pub use geometry::{DramGeometry, Loc, SubarrayId};
pub use timing::TimingParams;
