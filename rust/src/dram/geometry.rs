//! DRAM organization: sizes and typed coordinates.
//!
//! The paper's reference organization (§1, footnote 1): a subarray has
//! 1024 rows sharing a row buffer; with 8 KiB rows a subarray holds
//! 8 MiB per rank-wide row (1 MiB per chip in the paper's per-chip
//! view — we model rank-wide rows, the granularity PUD operates on).

use anyhow::{bail, Result};

/// Geometry of the simulated DRAM.
///
/// All counts are powers of two so that address interleaving can be a
/// pure bit-field mapping (as real controllers do).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DramGeometry {
    pub channels: u32,
    pub ranks_per_channel: u32,
    pub banks_per_rank: u32,
    pub subarrays_per_bank: u32,
    pub rows_per_subarray: u32,
    /// Bytes per (rank-wide) DRAM row — the PUD operand granularity.
    pub row_bytes: u32,
}

impl Default for DramGeometry {
    /// 8 GiB, matching the paper's evaluated system: 1 channel, 1 rank,
    /// 16 banks, 64 subarrays/bank, 1024 rows/subarray, 8 KiB rows.
    fn default() -> Self {
        Self {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 16,
            subarrays_per_bank: 64,
            rows_per_subarray: 1024,
            row_bytes: 8192,
        }
    }
}

/// Global subarray identifier (dense, 0..total_subarrays).
///
/// The paper indexes PUMA's ordered array "by subarray ID (obtained by
/// ORing subarray, bank, channel, and rank mask bits)": a dense id over
/// every (channel, rank, bank, subarray) tuple. See
/// [`InterleaveScheme::subarray_id`](super::address::InterleaveScheme).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SubarrayId(pub u32);

/// Fully decomposed DRAM coordinate of a physical byte address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Loc {
    pub channel: u32,
    pub rank: u32,
    pub bank: u32,
    pub subarray: u32,
    pub row: u32,
    pub column: u32, // byte offset within the row
}

impl DramGeometry {
    /// The 64 MiB machine used throughout tests, benches, and the
    /// small examples: 1 channel × 1 rank × 4 banks × 8 subarrays ×
    /// 256 rows × 8 KiB rows — big enough to exercise every placement
    /// path, small enough to churn hard in milliseconds.
    pub fn small() -> Self {
        Self {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 4,
            subarrays_per_bank: 8,
            rows_per_subarray: 256,
            row_bytes: 8192,
        }
    }

    /// Validate all fields are nonzero powers of two.
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("channels", self.channels),
            ("ranks_per_channel", self.ranks_per_channel),
            ("banks_per_rank", self.banks_per_rank),
            ("subarrays_per_bank", self.subarrays_per_bank),
            ("rows_per_subarray", self.rows_per_subarray),
            ("row_bytes", self.row_bytes),
        ] {
            if v == 0 || !v.is_power_of_two() {
                bail!("geometry field {name} = {v} must be a nonzero power of two");
            }
        }
        Ok(())
    }

    pub fn total_banks(&self) -> u32 {
        self.channels * self.ranks_per_channel * self.banks_per_rank
    }

    pub fn total_subarrays(&self) -> u32 {
        self.total_banks() * self.subarrays_per_bank
    }

    /// Bytes stored by one subarray (rows x row size).
    pub fn subarray_bytes(&self) -> u64 {
        self.rows_per_subarray as u64 * self.row_bytes as u64
    }

    /// Total device capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.total_subarrays() as u64 * self.subarray_bytes()
    }

    /// Rows in the whole device.
    pub fn total_rows(&self) -> u64 {
        self.total_subarrays() as u64 * self.rows_per_subarray as u64
    }

    /// Dense global subarray id for a location.
    pub fn subarray_id(&self, loc: &Loc) -> SubarrayId {
        let mut id = loc.channel;
        id = id * self.ranks_per_channel + loc.rank;
        id = id * self.banks_per_rank + loc.bank;
        id = id * self.subarrays_per_bank + loc.subarray;
        SubarrayId(id)
    }

    /// Dense global bank id over every (channel, rank, bank) tuple —
    /// the unit of command-timeline parallelism the batch scheduler
    /// exploits (independent banks execute PUD sequences concurrently).
    pub fn bank_id(&self, loc: &Loc) -> u32 {
        (loc.channel * self.ranks_per_channel + loc.rank) * self.banks_per_rank
            + loc.bank
    }

    /// Dense global row index (subarray-major) for a location.
    pub fn global_row(&self, loc: &Loc) -> u64 {
        self.subarray_id(loc).0 as u64 * self.rows_per_subarray as u64
            + loc.row as u64
    }

    /// Validate a location against this geometry.
    pub fn contains(&self, loc: &Loc) -> bool {
        loc.channel < self.channels
            && loc.rank < self.ranks_per_channel
            && loc.bank < self.banks_per_rank
            && loc.subarray < self.subarrays_per_bank
            && loc.row < self.rows_per_subarray
            && loc.column < self.row_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_8gib() {
        let g = DramGeometry::default();
        g.validate().unwrap();
        assert_eq!(g.capacity_bytes(), 8 << 30);
        assert_eq!(g.total_subarrays(), 1024);
        assert_eq!(g.subarray_bytes(), 8 << 20);
    }

    #[test]
    fn small_geometry_is_64mib() {
        let g = DramGeometry::small();
        g.validate().unwrap();
        assert_eq!(g.capacity_bytes(), 64 << 20);
        assert_eq!(g.total_subarrays(), 32);
    }

    #[test]
    fn validate_rejects_non_pow2() {
        let mut g = DramGeometry::default();
        g.banks_per_rank = 12;
        assert!(g.validate().is_err());
        g.banks_per_rank = 0;
        assert!(g.validate().is_err());
    }

    #[test]
    fn subarray_id_is_dense_and_unique() {
        let g = DramGeometry {
            channels: 2,
            ranks_per_channel: 2,
            banks_per_rank: 4,
            subarrays_per_bank: 8,
            rows_per_subarray: 16,
            row_bytes: 64,
        };
        let mut seen = std::collections::HashSet::new();
        for channel in 0..g.channels {
            for rank in 0..g.ranks_per_channel {
                for bank in 0..g.banks_per_rank {
                    for subarray in 0..g.subarrays_per_bank {
                        let loc = Loc {
                            channel,
                            rank,
                            bank,
                            subarray,
                            row: 0,
                            column: 0,
                        };
                        let id = g.subarray_id(&loc);
                        assert!(id.0 < g.total_subarrays());
                        assert!(seen.insert(id), "duplicate id {id:?}");
                    }
                }
            }
        }
        assert_eq!(seen.len(), g.total_subarrays() as usize);
    }

    #[test]
    fn global_row_unique_per_row() {
        let g = DramGeometry {
            channels: 1,
            ranks_per_channel: 1,
            banks_per_rank: 2,
            subarrays_per_bank: 2,
            rows_per_subarray: 4,
            row_bytes: 64,
        };
        let mut seen = std::collections::HashSet::new();
        for bank in 0..2 {
            for subarray in 0..2 {
                for row in 0..4 {
                    let loc = Loc {
                        channel: 0,
                        rank: 0,
                        bank,
                        subarray,
                        row,
                        column: 0,
                    };
                    assert!(seen.insert(g.global_row(&loc)));
                }
            }
        }
        assert_eq!(seen.len() as u64, g.total_rows());
    }

    #[test]
    fn contains_bounds() {
        let g = DramGeometry::default();
        let ok = Loc {
            channel: 0,
            rank: 0,
            bank: 15,
            subarray: 63,
            row: 1023,
            column: 8191,
        };
        assert!(g.contains(&ok));
        let bad = Loc { bank: 16, ..ok };
        assert!(!g.contains(&bad));
        let bad = Loc { column: 8192, ..ok };
        assert!(!g.contains(&bad));
    }
}
