//! Physical-address interleaving: the bit-field mapping between
//! physical byte addresses and DRAM coordinates.
//!
//! Real memory controllers scatter consecutive physical addresses
//! across channels/banks for parallelism; which bits select what is
//! the *interleaving scheme*. PUMA needs this mapping (the paper gets
//! it from an open-firmware device tree, or by reverse engineering) to
//! know which subarray a physical page lands in.

use anyhow::{bail, Result};

use super::geometry::{DramGeometry, Loc, SubarrayId};

/// An address field selected by a set of physical-address bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Field {
    Channel,
    Rank,
    Bank,
    Subarray,
    Row,
    Column,
}

impl Field {
    pub const ALL: [Field; 6] = [
        Field::Channel,
        Field::Rank,
        Field::Bank,
        Field::Subarray,
        Field::Row,
        Field::Column,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Field::Channel => "channel",
            Field::Rank => "rank",
            Field::Bank => "bank",
            Field::Subarray => "subarray",
            Field::Row => "row",
            Field::Column => "column",
        }
    }
}

/// Bit-field interleaving scheme: for each field, the (LSB-first) list
/// of physical address bits that form its value.
///
/// Bits must be disjoint across fields and cover exactly
/// `log2(capacity)` bits. XOR-hashing variants are expressed by
/// `xor_bank_with_row_low`, which folds low row bits into the bank
/// index (common in real controllers to spread row-buffer conflicts).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InterleaveScheme {
    pub geometry: DramGeometry,
    pub bits: Vec<(Field, Vec<u8>)>,
    /// If true, bank index is XORed with the low `log2(banks)` row
    /// bits (bank permutation / "XOR scheme").
    pub xor_bank_with_row_low: bool,
}

fn log2(v: u32) -> u8 {
    debug_assert!(v.is_power_of_two());
    v.trailing_zeros() as u8
}

impl InterleaveScheme {
    /// Width (in bits) each field needs for `geometry`.
    pub fn field_width(geometry: &DramGeometry, f: Field) -> u8 {
        match f {
            Field::Channel => log2(geometry.channels),
            Field::Rank => log2(geometry.ranks_per_channel),
            Field::Bank => log2(geometry.banks_per_rank),
            Field::Subarray => log2(geometry.subarrays_per_bank),
            Field::Row => log2(geometry.rows_per_subarray),
            Field::Column => log2(geometry.row_bytes),
        }
    }

    /// The standard "row : subarray : bank : rank : channel : column"
    /// layout (row bits highest): consecutive addresses sweep a row,
    /// then move to the next bank — the scheme the paper's examples
    /// assume. Called *row-major* here.
    pub fn row_major(geometry: DramGeometry) -> Self {
        Self::from_order(
            geometry,
            // LSB-first field order
            &[
                Field::Column,
                Field::Channel,
                Field::Rank,
                Field::Bank,
                Field::Row,
                Field::Subarray,
            ],
            false,
        )
    }

    /// Subarray bits *below* the row bits: a 2 MiB huge page spans many
    /// subarrays. Used by the interleave-sensitivity ablation (E4).
    pub fn subarray_low(geometry: DramGeometry) -> Self {
        Self::from_order(
            geometry,
            &[
                Field::Column,
                Field::Channel,
                Field::Rank,
                Field::Bank,
                Field::Subarray,
                Field::Row,
            ],
            false,
        )
    }

    /// Row-major with bank-XOR permutation.
    pub fn bank_xor(geometry: DramGeometry) -> Self {
        let mut s = Self::row_major(geometry);
        s.xor_bank_with_row_low = true;
        s
    }

    /// Build from an LSB-first field order, assigning contiguous bit
    /// ranges to each field. The stored `bits` list is normalized to
    /// `Field::ALL` order so schemes compare equal independent of the
    /// construction order (devicetree round-trips rely on this).
    pub fn from_order(
        geometry: DramGeometry,
        order: &[Field],
        xor_bank: bool,
    ) -> Self {
        let mut bits = Vec::new();
        let mut next = 0u8;
        for &f in order {
            let w = Self::field_width(&geometry, f);
            bits.push((f, (next..next + w).collect()));
            next += w;
        }
        bits.sort_by_key(|(f, _)| Field::ALL.iter().position(|g| g == f));
        let s = Self {
            geometry,
            bits,
            xor_bank_with_row_low: xor_bank,
        };
        s.validate().expect("from_order produces valid schemes");
        s
    }

    /// Total mapped address bits.
    pub fn addr_bits(&self) -> u8 {
        self.bits.iter().map(|(_, b)| b.len() as u8).sum()
    }

    /// Check bit-disjointness and coverage.
    pub fn validate(&self) -> Result<()> {
        self.geometry.validate()?;
        let mut seen = std::collections::HashSet::new();
        for (f, fbits) in &self.bits {
            let want = Self::field_width(&self.geometry, *f);
            if fbits.len() as u8 != want {
                bail!(
                    "field {} has {} bits, geometry needs {want}",
                    f.name(),
                    fbits.len()
                );
            }
            for &b in fbits {
                if !seen.insert(b) {
                    bail!("address bit {b} assigned twice");
                }
            }
        }
        let total = self.addr_bits();
        let cap_bits = 64 - (self.geometry.capacity_bytes() - 1).leading_zeros() as u8;
        if total != cap_bits {
            bail!("scheme maps {total} bits, capacity needs {cap_bits}");
        }
        for &b in &seen {
            if b >= total {
                bail!("address bit {b} beyond mapped range {total}");
            }
        }
        Ok(())
    }

    fn extract(addr: u64, fbits: &[u8]) -> u32 {
        let mut v = 0u32;
        for (i, &b) in fbits.iter().enumerate() {
            v |= (((addr >> b) & 1) as u32) << i;
        }
        v
    }

    fn scatter(value: u32, fbits: &[u8]) -> u64 {
        let mut a = 0u64;
        for (i, &b) in fbits.iter().enumerate() {
            a |= (((value >> i) & 1) as u64) << b;
        }
        a
    }

    fn field_bits(&self, f: Field) -> &[u8] {
        self.bits
            .iter()
            .find(|(g, _)| *g == f)
            .map(|(_, b)| b.as_slice())
            .expect("validated scheme has all fields")
    }

    /// Decompose a physical byte address.
    pub fn decode(&self, addr: u64) -> Loc {
        debug_assert!(
            addr < self.geometry.capacity_bytes(),
            "address {addr:#x} beyond capacity"
        );
        let mut loc = Loc {
            channel: Self::extract(addr, self.field_bits(Field::Channel)),
            rank: Self::extract(addr, self.field_bits(Field::Rank)),
            bank: Self::extract(addr, self.field_bits(Field::Bank)),
            subarray: Self::extract(addr, self.field_bits(Field::Subarray)),
            row: Self::extract(addr, self.field_bits(Field::Row)),
            column: Self::extract(addr, self.field_bits(Field::Column)),
        };
        if self.xor_bank_with_row_low {
            let mask = self.geometry.banks_per_rank - 1;
            loc.bank ^= loc.row & mask;
        }
        loc
    }

    /// Recompose a physical byte address (inverse of [`decode`]).
    pub fn encode(&self, loc: &Loc) -> u64 {
        debug_assert!(self.geometry.contains(loc), "loc out of geometry");
        let mut bank = loc.bank;
        if self.xor_bank_with_row_low {
            let mask = self.geometry.banks_per_rank - 1;
            bank ^= loc.row & mask;
        }
        Self::scatter(loc.channel, self.field_bits(Field::Channel))
            | Self::scatter(loc.rank, self.field_bits(Field::Rank))
            | Self::scatter(bank, self.field_bits(Field::Bank))
            | Self::scatter(loc.subarray, self.field_bits(Field::Subarray))
            | Self::scatter(loc.row, self.field_bits(Field::Row))
            | Self::scatter(loc.column, self.field_bits(Field::Column))
    }

    /// Dense subarray id of a physical address — what PUMA's ordered
    /// array is indexed by (paper §2: subarray | bank | channel | rank
    /// mask bits).
    pub fn subarray_id(&self, addr: u64) -> SubarrayId {
        let loc = self.decode(addr);
        self.geometry.subarray_id(&loc)
    }

    /// Is `addr` the first byte of a DRAM row?
    pub fn row_aligned(&self, addr: u64) -> bool {
        self.decode(addr).column == 0
    }

    /// Physical address of the start of row `row` in subarray `sid`.
    pub fn row_start_addr(&self, sid: SubarrayId, row: u32) -> u64 {
        let g = &self.geometry;
        let mut rest = sid.0;
        let subarray = rest % g.subarrays_per_bank;
        rest /= g.subarrays_per_bank;
        let bank = rest % g.banks_per_rank;
        rest /= g.banks_per_rank;
        let rank = rest % g.ranks_per_channel;
        let channel = rest / g.ranks_per_channel;
        self.encode(&Loc {
            channel,
            rank,
            bank,
            subarray,
            row,
            column: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_geom() -> DramGeometry {
        DramGeometry {
            channels: 2,
            ranks_per_channel: 1,
            banks_per_rank: 4,
            subarrays_per_bank: 8,
            rows_per_subarray: 16,
            row_bytes: 256,
        }
    }

    #[test]
    fn row_major_roundtrip() {
        let s = InterleaveScheme::row_major(small_geom());
        s.validate().unwrap();
        for addr in (0..s.geometry.capacity_bytes()).step_by(4093) {
            let loc = s.decode(addr);
            assert!(s.geometry.contains(&loc), "{addr:#x} -> {loc:?}");
            assert_eq!(s.encode(&loc), addr, "roundtrip at {addr:#x}");
        }
    }

    #[test]
    fn bank_xor_roundtrip() {
        let s = InterleaveScheme::bank_xor(small_geom());
        for addr in (0..s.geometry.capacity_bytes()).step_by(977) {
            assert_eq!(s.encode(&s.decode(addr)), addr);
        }
    }

    #[test]
    fn consecutive_addresses_sweep_column_first() {
        let s = InterleaveScheme::row_major(small_geom());
        let a = s.decode(0);
        let b = s.decode(1);
        assert_eq!(a.column + 1, b.column);
        assert_eq!((a.row, a.bank, a.subarray), (b.row, b.bank, b.subarray));
    }

    #[test]
    fn row_major_keeps_subarray_contiguous() {
        // In the row_major scheme, one subarray's rows occupy one
        // contiguous physical range (subarray bits are the top bits
        // within a bank's slice) — the property PUMA exploits when
        // splitting huge pages.
        let s = InterleaveScheme::row_major(small_geom());
        let sid = s.subarray_id(0);
        let span = s.geometry.row_bytes as u64
            * s.geometry.channels as u64
            * s.geometry.ranks_per_channel as u64
            * s.geometry.banks_per_rank as u64;
        // first `row_bytes` bytes are in sid; the address one bank-row
        // stride away is a different bank, same subarray id? No —
        // different bank means different dense id. Just check row 0 and
        // row 1 of the same subarray differ by the expected stride.
        let r0 = s.row_start_addr(sid, 0);
        let r1 = s.row_start_addr(sid, 1);
        assert_eq!(r1 - r0, span);
    }

    #[test]
    fn row_aligned_detects_column_zero() {
        let s = InterleaveScheme::row_major(small_geom());
        assert!(s.row_aligned(0));
        assert!(!s.row_aligned(1));
        assert!(!s.row_aligned(255));
        // next row-aligned address (column wraps at 256, channel bit
        // above columns): addr 256 has column 0 again
        assert!(s.row_aligned(256));
    }

    #[test]
    fn subarray_id_matches_row_start() {
        let s = InterleaveScheme::row_major(small_geom());
        for sid in 0..s.geometry.total_subarrays() {
            let sid = SubarrayId(sid);
            for row in [0u32, 1, 15] {
                let addr = s.row_start_addr(sid, row);
                assert_eq!(s.subarray_id(addr), sid);
                assert_eq!(s.decode(addr).row, row);
                assert!(s.row_aligned(addr));
            }
        }
    }

    #[test]
    fn validate_rejects_overlapping_bits() {
        let g = small_geom();
        let mut s = InterleaveScheme::row_major(g);
        // force an overlap between two fields that both have bits
        let (a, b) = {
            let with_bits: Vec<usize> = s
                .bits
                .iter()
                .enumerate()
                .filter(|(_, (_, bits))| !bits.is_empty())
                .map(|(i, _)| i)
                .collect();
            (with_bits[0], with_bits[1])
        };
        s.bits[a].1[0] = s.bits[b].1[0];
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_wrong_width() {
        let g = small_geom();
        let mut s = InterleaveScheme::row_major(g);
        // drop one bit from a field that actually has bits
        let idx = s
            .bits
            .iter()
            .position(|(_, b)| !b.is_empty())
            .expect("some field has bits");
        s.bits[idx].1.pop();
        assert!(s.validate().is_err());
    }

    #[test]
    fn default_geometry_has_33_addr_bits() {
        let s = InterleaveScheme::row_major(DramGeometry::default());
        assert_eq!(s.addr_bits(), 33); // 8 GiB
        s.validate().unwrap();
    }

    #[test]
    fn subarray_low_scheme_differs() {
        // With subarray bits low, two addresses one row apart land in
        // different subarrays (the pathological case for PUD).
        let g = small_geom();
        let s = InterleaveScheme::subarray_low(g.clone());
        s.validate().unwrap();
        let stride = g.row_bytes as u64 * g.channels as u64 * g.banks_per_rank as u64;
        let a = s.decode(0);
        let b = s.decode(stride);
        assert_ne!(a.subarray, b.subarray);
    }
}
