//! Parser for the device-tree-style DRAM description.
//!
//! The paper (§2, component ii) obtains the DRAM interleaving scheme
//! from an open-firmware device tree provided by the memory
//! controller. We model the same information path: geometry and
//! interleaving come from an external text description rather than
//! being hardcoded, e.g.:
//!
//! ```text
//! dram {
//!     channels = 1;
//!     ranks-per-channel = 1;
//!     banks-per-rank = 16;
//!     subarrays-per-bank = 64;
//!     rows-per-subarray = 1024;
//!     row-bytes = 8192;
//!     interleave {
//!         column   = 0-12;
//!         channel  = ;
//!         rank     = ;
//!         bank     = 13-16;
//!         row      = 17-26;
//!         subarray = 27-32;
//!         xor-bank = 0;
//!     };
//! };
//! ```
//!
//! Bit ranges are `lo-hi` inclusive (LSB-first), comma-separated
//! ranges compose (`0-3,8-9`), and an empty value means zero bits
//! (field width 1 value 0 — e.g. single channel).

use anyhow::{anyhow, bail, Context, Result};
use rustc_hash::FxHashMap;

use super::address::{Field, InterleaveScheme};
use super::geometry::DramGeometry;

/// Parse a device-tree-style description into an interleave scheme.
pub fn parse(text: &str) -> Result<InterleaveScheme> {
    let props = tokenize(text)?;
    let geom = DramGeometry {
        channels: get_num(&props, "dram.channels")? as u32,
        ranks_per_channel: get_num(&props, "dram.ranks-per-channel")? as u32,
        banks_per_rank: get_num(&props, "dram.banks-per-rank")? as u32,
        subarrays_per_bank: get_num(&props, "dram.subarrays-per-bank")? as u32,
        rows_per_subarray: get_num(&props, "dram.rows-per-subarray")? as u32,
        row_bytes: get_num(&props, "dram.row-bytes")? as u32,
    };
    geom.validate()?;

    let mut bits = Vec::new();
    for f in Field::ALL {
        let key = format!("dram.interleave.{}", f.name());
        let raw = props
            .get(key.as_str())
            .ok_or_else(|| anyhow!("missing property {key}"))?;
        bits.push((f, parse_bit_list(raw)?));
    }
    let xor = props
        .get("dram.interleave.xor-bank")
        .map(|v| v.trim() == "1" || v.trim() == "true")
        .unwrap_or(false);

    let scheme = InterleaveScheme {
        geometry: geom,
        bits,
        xor_bank_with_row_low: xor,
    };
    scheme.validate().context("device tree describes an invalid scheme")?;
    Ok(scheme)
}

/// Render a scheme back to device-tree text (round-trips via [`parse`]).
pub fn render(s: &InterleaveScheme) -> String {
    let g = &s.geometry;
    let mut out = String::from("dram {\n");
    for (k, v) in [
        ("channels", g.channels),
        ("ranks-per-channel", g.ranks_per_channel),
        ("banks-per-rank", g.banks_per_rank),
        ("subarrays-per-bank", g.subarrays_per_bank),
        ("rows-per-subarray", g.rows_per_subarray),
        ("row-bytes", g.row_bytes),
    ] {
        out.push_str(&format!("    {k} = {v};\n"));
    }
    out.push_str("    interleave {\n");
    for (f, fbits) in &s.bits {
        out.push_str(&format!(
            "        {} = {};\n",
            f.name(),
            render_bit_list(fbits)
        ));
    }
    out.push_str(&format!(
        "        xor-bank = {};\n",
        s.xor_bank_with_row_low as u8
    ));
    out.push_str("    };\n};\n");
    out
}

fn render_bit_list(bits: &[u8]) -> String {
    // compress consecutive runs into lo-hi
    let mut parts = Vec::new();
    let mut i = 0;
    while i < bits.len() {
        let lo = bits[i];
        let mut hi = lo;
        while i + 1 < bits.len() && bits[i + 1] == hi + 1 {
            i += 1;
            hi += 1;
        }
        if lo == hi {
            parts.push(format!("{lo}"));
        } else {
            parts.push(format!("{lo}-{hi}"));
        }
        i += 1;
    }
    parts.join(",")
}

fn parse_bit_list(raw: &str) -> Result<Vec<u8>> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Ok(Vec::new());
    }
    let mut bits = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if let Some((lo, hi)) = part.split_once('-') {
            let lo: u8 = lo.trim().parse().context("bit range lo")?;
            let hi: u8 = hi.trim().parse().context("bit range hi")?;
            if lo > hi {
                bail!("inverted bit range {part:?}");
            }
            bits.extend(lo..=hi);
        } else {
            bits.push(part.parse().context("bit index")?);
        }
    }
    Ok(bits)
}

/// Flatten `name { key = value; ... }` nesting into dotted keys.
fn tokenize(text: &str) -> Result<FxHashMap<String, String>> {
    let mut props = FxHashMap::default();
    let mut path: Vec<String> = Vec::new();
    // strip comments
    let mut clean = String::new();
    for line in text.lines() {
        let line = match line.find("//") {
            Some(idx) => &line[..idx],
            None => line,
        };
        clean.push_str(line);
        clean.push('\n');
    }
    let mut buf = String::new();
    for ch in clean.chars() {
        match ch {
            '{' => {
                let name = buf.trim().trim_end_matches(';').trim();
                if name.is_empty() {
                    bail!("anonymous block");
                }
                path.push(name.to_string());
                buf.clear();
            }
            '}' => {
                if !buf.trim().is_empty() {
                    record(&mut props, &path, &buf)?;
                    buf.clear();
                }
                path.pop().ok_or_else(|| anyhow!("unbalanced '}}'"))?;
            }
            ';' => {
                if !buf.trim().is_empty() {
                    record(&mut props, &path, &buf)?;
                }
                buf.clear();
            }
            c => buf.push(c),
        }
    }
    if !path.is_empty() {
        bail!("unbalanced '{{' — unclosed block {:?}", path.join("."));
    }
    Ok(props)
}

fn record(
    props: &mut FxHashMap<String, String>,
    path: &[String],
    stmt: &str,
) -> Result<()> {
    let (k, v) = stmt
        .split_once('=')
        .ok_or_else(|| anyhow!("expected key = value, got {stmt:?}"))?;
    let mut key = path.join(".");
    if !key.is_empty() {
        key.push('.');
    }
    key.push_str(k.trim());
    props.insert(key, v.trim().to_string());
    Ok(())
}

fn get_num(props: &FxHashMap<String, String>, key: &str) -> Result<u64> {
    let raw = props
        .get(key)
        .ok_or_else(|| anyhow!("missing property {key}"))?;
    raw.trim()
        .parse()
        .with_context(|| format!("property {key} = {raw:?} is not a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_row_major_default() {
        let s = InterleaveScheme::row_major(DramGeometry::default());
        let text = render(&s);
        let parsed = parse(&text).unwrap();
        assert_eq!(parsed, s);
    }

    #[test]
    fn roundtrip_bank_xor() {
        let s = InterleaveScheme::bank_xor(DramGeometry::default());
        assert_eq!(parse(&render(&s)).unwrap(), s);
    }

    #[test]
    fn parses_handwritten() {
        let text = "
// comment line
dram {
    channels = 1; ranks-per-channel = 1;
    banks-per-rank = 2;
    subarrays-per-bank = 2;
    rows-per-subarray = 4;
    row-bytes = 16;
    interleave {
        column = 0-3;
        channel = ;
        rank = ;
        bank = 4;
        row = 5-6;
        subarray = 7;
        xor-bank = 0;
    };
};";
        let s = parse(text).unwrap();
        assert_eq!(s.geometry.banks_per_rank, 2);
        assert_eq!(s.addr_bits(), 8);
        assert!(s.row_aligned(0));
        assert!(!s.row_aligned(5));
    }

    #[test]
    fn rejects_missing_property() {
        let text = "dram { channels = 1; };";
        let err = parse(text).unwrap_err().to_string();
        assert!(err.contains("missing property"), "{err}");
    }

    #[test]
    fn rejects_unbalanced_braces() {
        assert!(parse("dram { channels = 1;").is_err());
        assert!(parse("dram { } }").is_err());
    }

    #[test]
    fn rejects_invalid_scheme() {
        // bank needs 1 bit but gets none
        let text = "
dram {
    channels = 1; ranks-per-channel = 1; banks-per-rank = 2;
    subarrays-per-bank = 2; rows-per-subarray = 4; row-bytes = 16;
    interleave {
        column = 0-3; channel = ; rank = ; bank = ;
        row = 4-5; subarray = 6; xor-bank = 0;
    };
};";
        assert!(parse(text).is_err());
    }

    #[test]
    fn bit_list_forms() {
        assert_eq!(parse_bit_list("").unwrap(), Vec::<u8>::new());
        assert_eq!(parse_bit_list("3").unwrap(), vec![3]);
        assert_eq!(parse_bit_list("0-2").unwrap(), vec![0, 1, 2]);
        assert_eq!(parse_bit_list("0-1, 5, 7-8").unwrap(), vec![0, 1, 5, 7, 8]);
        assert!(parse_bit_list("5-2").is_err());
        assert!(parse_bit_list("x").is_err());
    }

    #[test]
    fn render_compresses_ranges() {
        assert_eq!(render_bit_list(&[0, 1, 2, 5, 7, 8]), "0-2,5,7-8");
        assert_eq!(render_bit_list(&[]), "");
    }
}
