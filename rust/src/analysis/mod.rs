//! Static analysis over compiled PUD programs (DESIGN.md §16).
//!
//! PUMA's correctness story hinges on placement invariants that the
//! repo historically discovered only dynamically, one row at a time,
//! inside `legality::check_rowwise` during execution. This module adds
//! the verification layer between codegen and the substrate:
//!
//! * [`verify`] — a dataflow **program verifier** over the
//!   `Vec<BulkRequest>` streams that `Compiled`/`CompiledMulti` emit
//!   (def-before-use, aliasing legality, scratch-lease balance,
//!   reserved-row safety, hazard-wave consistency), plus a
//!   **translation-validation** pass that abstractly interprets the
//!   stream over exhaustive truth-table lanes and proves it
//!   byte-equivalent to the source expression DAG — no simulator run
//!   needed.
//! * [`lint`] — a **placement linter** producing typed
//!   [`Diagnostic`]s that attribute every fallback row to the PUMA
//!   requirement it violated (misaligned vs fragmented vs
//!   cross-subarray vs reserved) and flag avoidable fallbacks, missed
//!   allocation hints, shard imbalance, and leaked scratch leases.
//!
//! Wiring: `System::set_verify` (or the `PUMA_VERIFY` environment
//! variable) selects a [`VerifyLevel`]; the coordinator runs the
//! linter on every batch and the `System` compile paths run the
//! verifier on every emission. `puma lint` replays workloads in
//! analyze mode and renders the diagnostics.

pub mod lint;
pub mod verify;

pub use lint::{Diagnostic, Lint, Severity};
pub use verify::{VerifyError, VerifyErrorKind, VerifyOk};

/// How much analysis runs on the request path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum VerifyLevel {
    /// No analysis (the historical behavior).
    Off,
    /// Placement linter on every batch: fallback-cause attribution,
    /// avoidable-fallback and imbalance diagnostics. Cheap — reuses
    /// the plans the pipeline already built.
    Lint,
    /// Lint plus the program verifier (dataflow + translation
    /// validation) on every compiled emission. "PudSan": in debug
    /// builds a verifier error also fires a `debug_assert!`.
    Full,
}

impl VerifyLevel {
    pub fn name(&self) -> &'static str {
        match self {
            VerifyLevel::Off => "off",
            VerifyLevel::Lint => "lint",
            VerifyLevel::Full => "full",
        }
    }

    /// Parse a level name; accepts the `PUMA_VERIFY` spellings.
    pub fn parse(s: &str) -> Option<VerifyLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" | "none" | "" => Some(VerifyLevel::Off),
            "lint" => Some(VerifyLevel::Lint),
            "full" | "1" | "on" => Some(VerifyLevel::Full),
            _ => None,
        }
    }

    /// The level the `PUMA_VERIFY` environment variable selects
    /// (`off` when unset or unparseable) — the default every
    /// `SystemConfig` boots with, so CI can run the whole test suite
    /// under `PUMA_VERIFY=full` without touching a single test.
    pub fn from_env() -> VerifyLevel {
        std::env::var("PUMA_VERIFY")
            .ok()
            .and_then(|s| VerifyLevel::parse(&s))
            .unwrap_or(VerifyLevel::Off)
    }
}

impl std::fmt::Display for VerifyLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(VerifyLevel::parse("off"), Some(VerifyLevel::Off));
        assert_eq!(VerifyLevel::parse("Lint"), Some(VerifyLevel::Lint));
        assert_eq!(VerifyLevel::parse("FULL"), Some(VerifyLevel::Full));
        assert_eq!(VerifyLevel::parse("bogus"), None);
        assert!(VerifyLevel::Full > VerifyLevel::Lint);
        assert!(VerifyLevel::Lint > VerifyLevel::Off);
    }
}
