//! The compiled-program verifier and translation validator.
//!
//! A compiled PUD program is a `Vec<BulkRequest>` bound to concrete
//! virtual addresses: operand (leaf) buffers, output buffers, and
//! scratch rows leased from a [`crate::alloc::scratch::ScratchPool`].
//! [`verify_compiled`]/[`verify_compiled_multi`] prove, without
//! touching the simulator, that such a stream is well-formed and
//! byte-equivalent to the source expression DAG:
//!
//! 1. **Dataflow** — every request has the right arity and length,
//!    reads only defined values (operands or earlier writes), writes
//!    only into the declared binding universe, and never clobbers an
//!    operand buffer that a later request still reads (the aliasing
//!    legality `regalloc`'s in-place-dst rule relies on).
//! 2. **Lease balance** — every scratch slot the program declared it
//!    needs actually appears in the stream; a leased-but-unused slot
//!    is a scratch leak (the pool grew for nothing and the
//!    lease/release ledger no longer balances).
//! 3. **Reserved rows** — with a resolver from the caller (the
//!    `System` supplies page-table translation), no operand may land
//!    on an Ambit control/temp row.
//! 4. **Hazard-wave consistency** — the stream must match the
//!    canonical emission of the compiled program position by position
//!    on `(dst, srcs, len)`; any divergence changes the greedy
//!    hazard-wave partition `coordinator/schedule.rs` builds (the
//!    VA-level partition of both streams is reported in the error).
//! 5. **Translation validation** — the stream is abstractly
//!    interpreted over truth-table lanes: with `n <= 8` leaves the
//!    lanes enumerate all `2^n` assignments exhaustively (one bit per
//!    assignment), so equality with the reference
//!    `Expr::eval_bytes`/`MultiExpr::eval_bytes` *proves* the
//!    optimized + regalloc'd + lowered stream computes the source DAG;
//!    beyond 8 leaves, 256 pseudo-random lanes give a probabilistic
//!    check.
//!
//! The checks run in the order above and report the first failure, so
//! each systematic fault maps to a stable [`VerifyErrorKind`] (see
//! `rust/tests/prop_analysis.rs` for the fault-injection matrix).

use rustc_hash::{FxHashMap, FxHashSet};

use crate::pud::compiler::{Compiled, CompiledMulti, MultiExpr};
use crate::pud::isa::BulkRequest;
use crate::util::rng::Pcg64;

/// Lane seed for the >8-leaf probabilistic fallback — fixed so runs
/// are reproducible.
const LANE_SEED: u64 = 0x7E57_1A9E;

/// Random lane bytes used when exhaustive enumeration is too wide.
const RANDOM_LANE_BYTES: usize = 256;

/// Exhaustive truth-table enumeration bound: `2^8` assignments fit in
/// 32 lane bytes.
pub const EXHAUSTIVE_LEAVES: usize = 8;

/// What went wrong, as a stable kind the fault-injection tests (and
/// the linter's diagnostics) key on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VerifyErrorKind {
    /// A request's source count does not match its op's arity.
    ArityMismatch,
    /// A request's length differs from the program binding length.
    LengthMismatch,
    /// A source address is read before anything defined it.
    UseBeforeDef,
    /// An address outside the operand/dst/scratch binding universe.
    UnknownAddress,
    /// A write clobbers an operand buffer that a later request reads.
    IllegalAlias,
    /// A declared output buffer is never written — the stream ends
    /// early (or lost its defining request).
    TruncatedStream,
    /// A scratch slot the program leased is never used by the stream.
    ScratchLeak,
    /// An operand resolves onto a reserved (Ambit control/temp) row.
    ReservedRow,
    /// The stream diverges from the canonical emission order, which
    /// changes the scheduler's greedy hazard-wave partition.
    HazardWaveMismatch,
    /// Abstract interpretation over truth-table lanes disagrees with
    /// the reference evaluation of the source DAG.
    TranslationMismatch,
}

impl VerifyErrorKind {
    pub fn name(&self) -> &'static str {
        match self {
            VerifyErrorKind::ArityMismatch => "arity_mismatch",
            VerifyErrorKind::LengthMismatch => "length_mismatch",
            VerifyErrorKind::UseBeforeDef => "use_before_def",
            VerifyErrorKind::UnknownAddress => "unknown_address",
            VerifyErrorKind::IllegalAlias => "illegal_alias",
            VerifyErrorKind::TruncatedStream => "truncated_stream",
            VerifyErrorKind::ScratchLeak => "scratch_leak",
            VerifyErrorKind::ReservedRow => "reserved_row",
            VerifyErrorKind::HazardWaveMismatch => "hazard_wave_mismatch",
            VerifyErrorKind::TranslationMismatch => "translation_mismatch",
        }
    }
}

impl std::fmt::Display for VerifyErrorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A verification failure: the kind, a human-readable message, and
/// the offending request index when one exists.
#[derive(Debug, Clone)]
pub struct VerifyError {
    pub kind: VerifyErrorKind,
    pub message: String,
    pub req_idx: Option<usize>,
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.req_idx {
            Some(i) => write!(f, "{}: {} (request {})", self.kind, self.message, i),
            None => write!(f, "{}: {}", self.kind, self.message),
        }
    }
}

impl std::error::Error for VerifyError {}

fn err(
    kind: VerifyErrorKind,
    req_idx: Option<usize>,
    message: String,
) -> VerifyError {
    VerifyError {
        kind,
        message,
        req_idx,
    }
}

/// What a successful verification covered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VerifyOk {
    /// Requests checked.
    pub ops: usize,
    /// Truth-table assignments the translation validation evaluated
    /// (`2^n` exhaustive lanes, or `8 * RANDOM_LANE_BYTES` random
    /// ones).
    pub lanes: usize,
    /// `true` when the lanes enumerate every leaf assignment (a
    /// proof, not a probabilistic check).
    pub exhaustive: bool,
    /// VA-level hazard waves of the stream.
    pub waves: usize,
}

/// The address binding a stream was emitted against.
#[derive(Debug, Clone, Copy)]
pub struct Binding<'a> {
    /// `operands[i]` backs `Leaf(i)`; may be longer than the leaf
    /// count (extra entries are simply unused).
    pub operands: &'a [u64],
    /// Output buffers, one per root.
    pub dsts: &'a [u64],
    /// Scratch slots handed to `emit` (may exceed `scratch_needed`).
    pub scratch: &'a [u64],
    /// How many scratch slots the program actually claims.
    pub scratch_needed: usize,
    /// Buffer length in bytes, common to every operand.
    pub len: u64,
}

/// Optional per-address predicate: does `va`'s backing storage touch
/// a reserved row? The `System` answers via page-table translation;
/// tests inject synthetic placements.
pub type ReservedProbe<'a> = &'a dyn Fn(u64) -> bool;

/// Do two requests conflict at the VA level (write/write or
/// write/read overlap)? Mirrors the physical-range test in
/// `coordinator/plan.rs::OpPlan::conflicts_with`, one level up.
fn conflicts(a: &BulkRequest, b: &BulkRequest) -> bool {
    a.dst == b.dst || a.srcs.contains(&b.dst) || b.srcs.contains(&a.dst)
}

/// Greedy VA-level hazard-wave partition of a request stream — the
/// abstraction of `coordinator/schedule.rs`'s physical-range
/// partitioning that the verifier can compute without translation.
pub fn va_waves(reqs: &[BulkRequest]) -> Vec<Vec<usize>> {
    let mut waves: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    for (i, r) in reqs.iter().enumerate() {
        if cur.iter().any(|&j| conflicts(&reqs[j], r)) {
            waves.push(std::mem::take(&mut cur));
        }
        cur.push(i);
    }
    if !cur.is_empty() {
        waves.push(cur);
    }
    waves
}

/// Dataflow + lease-balance + reserved-row checks (stages 1-3).
fn check_dataflow(
    reqs: &[BulkRequest],
    b: &Binding,
    reserved: Option<ReservedProbe>,
) -> Result<(), VerifyError> {
    let operand_set: FxHashSet<u64> = b.operands.iter().copied().collect();
    let mut universe: FxHashSet<u64> = operand_set.clone();
    universe.extend(b.dsts.iter().copied());
    universe.extend(b.scratch.iter().copied());

    // read positions per VA, for the operand-clobber liveness check
    let mut reads: FxHashMap<u64, Vec<usize>> = FxHashMap::default();
    for (i, r) in reqs.iter().enumerate() {
        for &s in &r.srcs {
            reads.entry(s).or_default().push(i);
        }
    }

    let mut written: FxHashSet<u64> = FxHashSet::default();
    let mut touched: FxHashSet<u64> = FxHashSet::default();
    for (i, r) in reqs.iter().enumerate() {
        if r.srcs.len() != r.op.arity() {
            return Err(err(
                VerifyErrorKind::ArityMismatch,
                Some(i),
                format!(
                    "{} takes {} source(s), stream carries {}",
                    r.op,
                    r.op.arity(),
                    r.srcs.len()
                ),
            ));
        }
        if r.len != b.len {
            return Err(err(
                VerifyErrorKind::LengthMismatch,
                Some(i),
                format!("request length {} != binding length {}", r.len, b.len),
            ));
        }
        for &s in &r.srcs {
            if !universe.contains(&s) {
                return Err(err(
                    VerifyErrorKind::UnknownAddress,
                    Some(i),
                    format!("source {s:#x} is outside the binding universe"),
                ));
            }
            if !written.contains(&s) && !operand_set.contains(&s) {
                return Err(err(
                    VerifyErrorKind::UseBeforeDef,
                    Some(i),
                    format!("source {s:#x} read before any request defined it"),
                ));
            }
            touched.insert(s);
        }
        if !universe.contains(&r.dst) {
            return Err(err(
                VerifyErrorKind::UnknownAddress,
                Some(i),
                format!("destination {:#x} is outside the binding universe", r.dst),
            ));
        }
        if operand_set.contains(&r.dst) {
            // Writing an operand buffer is legal only when nothing
            // reads it afterwards (the single-output root write is
            // always last; mid-stream clobbers corrupt later reads).
            let read_later = reads
                .get(&r.dst)
                .is_some_and(|ps| ps.iter().any(|&p| p > i));
            if read_later {
                return Err(err(
                    VerifyErrorKind::IllegalAlias,
                    Some(i),
                    format!(
                        "destination {:#x} clobbers an operand a later \
                         request still reads",
                        r.dst
                    ),
                ));
            }
        }
        written.insert(r.dst);
        touched.insert(r.dst);
    }

    for (k, &d) in b.dsts.iter().enumerate() {
        if !written.contains(&d) {
            return Err(err(
                VerifyErrorKind::TruncatedStream,
                None,
                format!(
                    "output {k} ({d:#x}) is never written — the stream \
                     ends before its defining request"
                ),
            ));
        }
    }
    for (k, &s) in b.scratch.iter().take(b.scratch_needed).enumerate() {
        if !touched.contains(&s) {
            return Err(err(
                VerifyErrorKind::ScratchLeak,
                None,
                format!(
                    "scratch slot {k} ({s:#x}) was leased but never used \
                     — the lease/release ledger no longer balances"
                ),
            ));
        }
    }

    if let Some(probe) = reserved {
        let mut seen: FxHashSet<u64> = FxHashSet::default();
        for (i, r) in reqs.iter().enumerate() {
            for &va in std::iter::once(&r.dst).chain(r.srcs.iter()) {
                if seen.insert(va) && probe(va) {
                    return Err(err(
                        VerifyErrorKind::ReservedRow,
                        Some(i),
                        format!(
                            "{va:#x} resolves onto a reserved Ambit \
                             control/temp row"
                        ),
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Stage 4: the stream must match the canonical emission position by
/// position on `(dst, srcs, len)` — ops are deliberately ignored so an
/// op swap falls through to translation validation, which names it
/// precisely.
fn check_hazard_order(
    reqs: &[BulkRequest],
    expected: &[BulkRequest],
) -> Result<(), VerifyError> {
    let diverged = reqs.len() != expected.len()
        || reqs.iter().zip(expected).any(|(a, e)| {
            a.dst != e.dst || a.srcs != e.srcs || a.len != e.len
        });
    if diverged {
        return Err(err(
            VerifyErrorKind::HazardWaveMismatch,
            None,
            format!(
                "stream order diverges from the canonical emission \
                 ({} vs {} request(s), {} vs {} VA-level wave(s)) — \
                 the greedy hazard-wave partition no longer matches",
                reqs.len(),
                expected.len(),
                va_waves(reqs).len(),
                va_waves(expected).len(),
            ),
        ));
    }
    Ok(())
}

/// Truth-table lanes for `n` leaves: exhaustive when `n <=
/// EXHAUSTIVE_LEAVES` (bit `g` of the lane buffers encodes assignment
/// `g mod 2^n`, so every assignment appears), pseudo-random otherwise.
fn leaf_lanes(n: usize) -> (Vec<Vec<u8>>, usize, bool) {
    if n <= EXHAUSTIVE_LEAVES {
        let assignments = 1usize << n;
        let len = assignments.div_ceil(8).max(1);
        let mut lanes = vec![vec![0u8; len]; n];
        for g in 0..len * 8 {
            let a = g % assignments;
            for (i, lane) in lanes.iter_mut().enumerate() {
                if (a >> i) & 1 == 1 {
                    lane[g / 8] |= 1 << (g % 8);
                }
            }
        }
        (lanes, len, true)
    } else {
        let mut rng = Pcg64::new(LANE_SEED);
        let mut lanes = vec![vec![0u8; RANDOM_LANE_BYTES]; n];
        for lane in &mut lanes {
            rng.fill_bytes(lane);
        }
        (lanes, RANDOM_LANE_BYTES, false)
    }
}

/// Stage 5: abstract interpretation of the stream over the lanes,
/// compared against the reference evaluation of the (optimized) DAG.
/// `n_leaves` leaves are bound to `binding.operands[..n_leaves]`;
/// `want[k]` is the reference image of output `k`.
fn check_translation(
    reqs: &[BulkRequest],
    b: &Binding,
    n_leaves: usize,
    eval: impl FnOnce(&[&[u8]], usize) -> anyhow::Result<Vec<Vec<u8>>>,
) -> Result<(usize, bool), VerifyError> {
    let (lanes, lane_len, exhaustive) = leaf_lanes(n_leaves);
    // One buffer per VA: duplicate operand bindings collapse exactly
    // as the hardware would alias them, and the reference is fed the
    // collapsed images so the proof covers the actual binding.
    let mut mem: FxHashMap<u64, Vec<u8>> = FxHashMap::default();
    for (i, &va) in b.operands.iter().take(n_leaves).enumerate() {
        mem.entry(va).or_insert_with(|| lanes[i].clone());
    }
    let leaf_imgs: Vec<Vec<u8>> = b
        .operands
        .iter()
        .take(n_leaves)
        .map(|va| mem[va].clone())
        .collect();
    let leaf_refs: Vec<&[u8]> = leaf_imgs.iter().map(|v| v.as_slice()).collect();
    let want = eval(&leaf_refs, lane_len).map_err(|e| {
        err(
            VerifyErrorKind::TranslationMismatch,
            None,
            format!("reference evaluation failed: {e}"),
        )
    })?;

    for r in reqs {
        let srcs: Vec<Vec<u8>> = r
            .srcs
            .iter()
            .map(|s| mem.get(s).cloned().unwrap_or_else(|| vec![0u8; lane_len]))
            .collect();
        let refs: Vec<&[u8]> = srcs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0u8; lane_len];
        r.op.apply_bytes(&refs, &mut out);
        mem.insert(r.dst, out);
    }

    for (k, (&d, want_k)) in b.dsts.iter().zip(&want).enumerate() {
        let got = mem.get(&d);
        if got != Some(want_k) {
            let lane = got.map_or(usize::MAX, |g| {
                g.iter()
                    .zip(want_k)
                    .position(|(a, b)| a != b)
                    .unwrap_or(usize::MAX)
            });
            return Err(err(
                VerifyErrorKind::TranslationMismatch,
                None,
                format!(
                    "output {k} ({d:#x}) disagrees with the reference \
                     evaluation of the source DAG (first bad lane byte \
                     {lane}; {} assignment(s) checked{})",
                    lane_len * 8,
                    if exhaustive { ", exhaustive" } else { "" },
                ),
            ));
        }
    }
    Ok((lane_len * 8, exhaustive))
}

fn verify_stream(
    reqs: &[BulkRequest],
    b: &Binding,
    reserved: Option<ReservedProbe>,
    expected: &[BulkRequest],
    n_leaves: usize,
    eval: impl FnOnce(&[&[u8]], usize) -> anyhow::Result<Vec<Vec<u8>>>,
) -> Result<VerifyOk, VerifyError> {
    check_dataflow(reqs, b, reserved)?;
    check_hazard_order(reqs, expected)?;
    let (lanes, exhaustive) = check_translation(reqs, b, n_leaves, eval)?;
    Ok(VerifyOk {
        ops: reqs.len(),
        lanes,
        exhaustive,
        waves: va_waves(reqs).len(),
    })
}

/// Verify a single-output program's emitted stream against its
/// compiled form and binding. `reserved` is the optional reserved-row
/// probe ([`ReservedProbe`]).
#[allow(clippy::too_many_arguments)]
pub fn verify_compiled(
    c: &Compiled,
    operands: &[u64],
    dst: u64,
    len: u64,
    scratch: &[u64],
    reqs: &[BulkRequest],
    reserved: Option<ReservedProbe>,
) -> Result<VerifyOk, VerifyError> {
    let dsts = [dst];
    let b = Binding {
        operands,
        dsts: &dsts,
        scratch,
        scratch_needed: c.scratch_needed(),
        len,
    };
    let expected = c.emit(operands, dst, len, scratch).map_err(|e| {
        err(
            VerifyErrorKind::HazardWaveMismatch,
            None,
            format!("canonical re-emission failed: {e}"),
        )
    })?;
    let expr = c.expr();
    verify_stream(reqs, &b, reserved, &expected, expr.n_leaves(), |lv, n| {
        expr.eval_bytes(lv, n).map(|one| vec![one])
    })
}

/// Verify a multi-output program's emitted stream against its
/// compiled form and binding.
#[allow(clippy::too_many_arguments)]
pub fn verify_compiled_multi(
    c: &CompiledMulti,
    operands: &[u64],
    dsts: &[u64],
    len: u64,
    scratch: &[u64],
    reqs: &[BulkRequest],
    reserved: Option<ReservedProbe>,
) -> Result<VerifyOk, VerifyError> {
    let b = Binding {
        operands,
        dsts,
        scratch,
        scratch_needed: c.scratch_needed(),
        len,
    };
    let expected = c.emit(operands, dsts, len, scratch).map_err(|e| {
        err(
            VerifyErrorKind::HazardWaveMismatch,
            None,
            format!("canonical re-emission failed: {e}"),
        )
    })?;
    let expr: &MultiExpr = c.expr();
    verify_stream(reqs, &b, reserved, &expected, expr.n_leaves(), |lv, n| {
        expr.eval_bytes(lv, n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::compiler::{compile, compile_multi, ExprBuilder};
    use crate::pud::isa::PudOp;

    fn addrs(n: usize, base: u64) -> Vec<u64> {
        (0..n as u64).map(|i| base + i * 0x1000).collect()
    }

    #[test]
    fn accepts_and_proves_a_simple_program() {
        let mut b = ExprBuilder::new();
        let (x, y, z) = (b.leaf(0), b.leaf(1), b.leaf(2));
        let xy = b.and(x, y);
        let root = b.xor(xy, z);
        let c = compile(&b.build(root));
        let ops = addrs(3, 0x10_0000);
        let scratch = addrs(c.scratch_needed().max(1), 0x20_0000);
        let reqs = c.emit(&ops, 0x30_0000, 4096, &scratch).unwrap();
        let ok =
            verify_compiled(&c, &ops, 0x30_0000, 4096, &scratch, &reqs, None)
                .unwrap();
        assert_eq!(ok.ops, reqs.len());
        assert!(ok.exhaustive);
        assert_eq!(ok.lanes % 8, 0);
        assert!(ok.waves >= 1);
    }

    #[test]
    fn accepts_multi_output_with_duplicate_roots() {
        let mut b = ExprBuilder::new();
        let (x, y) = (b.leaf(0), b.leaf(1));
        let xy = b.or(x, y);
        let m = b.build_multi(vec![xy, xy, x]);
        let c = compile_multi(&m);
        let ops = addrs(2, 0x10_0000);
        let dsts = addrs(3, 0x30_0000);
        let scratch = addrs(c.scratch_needed().max(1), 0x20_0000);
        let reqs = c.emit(&ops, &dsts, 512, &scratch).unwrap();
        verify_compiled_multi(&c, &ops, &dsts, 512, &scratch, &reqs, None)
            .unwrap();
    }

    #[test]
    fn swapped_op_is_a_translation_mismatch() {
        let mut b = ExprBuilder::new();
        let (x, y) = (b.leaf(0), b.leaf(1));
        let root = b.and(x, y);
        let c = compile(&b.build(root));
        let ops = addrs(2, 0x10_0000);
        let scratch = addrs(c.scratch_needed().max(1), 0x20_0000);
        let mut reqs = c.emit(&ops, 0x30_0000, 64, &scratch).unwrap();
        let i = reqs
            .iter()
            .position(|r| matches!(r.op, PudOp::And))
            .unwrap();
        reqs[i].op = PudOp::Or;
        let e = verify_compiled(&c, &ops, 0x30_0000, 64, &scratch, &reqs, None)
            .unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::TranslationMismatch);
    }

    #[test]
    fn truncated_stream_is_flagged() {
        let mut b = ExprBuilder::new();
        let (x, y) = (b.leaf(0), b.leaf(1));
        let root = b.and(x, y);
        let c = compile(&b.build(root));
        let ops = addrs(2, 0x10_0000);
        let scratch = addrs(c.scratch_needed().max(1), 0x20_0000);
        let mut reqs = c.emit(&ops, 0x30_0000, 64, &scratch).unwrap();
        reqs.pop();
        let e = verify_compiled(&c, &ops, 0x30_0000, 64, &scratch, &reqs, None)
            .unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::TruncatedStream);
    }

    #[test]
    fn reserved_probe_fires() {
        let mut b = ExprBuilder::new();
        let (x, y) = (b.leaf(0), b.leaf(1));
        let root = b.or(x, y);
        let c = compile(&b.build(root));
        let ops = addrs(2, 0x10_0000);
        let scratch = addrs(c.scratch_needed().max(1), 0x20_0000);
        let reqs = c.emit(&ops, 0x30_0000, 64, &scratch).unwrap();
        let poisoned = ops[1];
        let probe = move |va: u64| va == poisoned;
        let e = verify_compiled(
            &c,
            &ops,
            0x30_0000,
            64,
            &scratch,
            &reqs,
            Some(&probe),
        )
        .unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::ReservedRow);
    }

    #[test]
    fn va_wave_partition_respects_conflicts() {
        // two independent copies share a wave; a dependent read opens
        // a new one
        let reqs = vec![
            BulkRequest::new(PudOp::Copy, 0x1000, vec![0x2000], 64),
            BulkRequest::new(PudOp::Copy, 0x3000, vec![0x4000], 64),
            BulkRequest::new(PudOp::Not, 0x5000, vec![0x1000], 64),
        ];
        let waves = va_waves(&reqs);
        assert_eq!(waves.len(), 2);
        assert_eq!(waves[0], vec![0, 1]);
        assert_eq!(waves[1], vec![2]);
    }
}
