//! The placement linter: typed diagnostics over planned batches.
//!
//! Where the verifier proves a compiled stream *correct*, the linter
//! explains why it was *slow*: every fallback row in a planned batch
//! is attributed to the PUMA placement requirement it violated
//! (misaligned vs fragmented vs cross-subarray vs reserved), and
//! recurring self-inflicted patterns — fallbacks `AllocRequest`
//! alignment hints could have avoided, missed hints, lopsided shard
//! placement, scratch leases that outlive their workload — get their
//! own diagnostics. `puma lint` renders these as a table and JSON;
//! the coordinator records them on every batch when
//! [`super::VerifyLevel`] is `Lint` or higher.

use rustc_hash::FxHashMap;

use crate::alloc::scratch::ScratchPool;
use crate::alloc::traits::AllocStats;
use crate::coordinator::plan::OpPlan;
use crate::pud::legality::{CauseCounts, FallbackCause, RowPlan};

use super::verify::{VerifyError, VerifyErrorKind};

/// How bad a diagnostic is. `Error` means the program is wrong (only
/// the verifier emits it); `Warning` means measurable performance was
/// left on the table; `Note` is advisory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Note,
    Warning,
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Note => "note",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

impl std::fmt::Display for Severity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a diagnostic is about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lint {
    /// Rows fell back to the CPU path, attributed to the placement
    /// requirement that failed.
    FallbackRow(FallbackCause),
    /// A fallback the allocator could have prevented (e.g.
    /// `AllocRequest::align_with` would have co-located the operands).
    AvoidableFallback,
    /// An allocation hint was requested but the allocator could not
    /// honor it.
    MissedHint,
    /// PUD rows concentrate on a few banks while others idle.
    ShardImbalance,
    /// Scratch leases outlive the workload that took them.
    LeakedScratchLease,
    /// The program verifier rejected a compiled stream.
    VerifyFailed(VerifyErrorKind),
}

impl Lint {
    /// Stable slug, used as the JSON `lint` field and the table key.
    pub fn name(&self) -> &'static str {
        match self {
            Lint::FallbackRow(FallbackCause::Fragmented) => {
                "fallback_row.fragmented"
            }
            Lint::FallbackRow(FallbackCause::Misaligned) => {
                "fallback_row.misaligned"
            }
            Lint::FallbackRow(FallbackCause::Reserved) => {
                "fallback_row.reserved"
            }
            Lint::FallbackRow(FallbackCause::CrossSubarray) => {
                "fallback_row.cross_subarray"
            }
            Lint::AvoidableFallback => "avoidable_fallback",
            Lint::MissedHint => "missed_hint",
            Lint::ShardImbalance => "shard_imbalance",
            Lint::LeakedScratchLease => "leaked_scratch_lease",
            Lint::VerifyFailed(_) => "verify_failed",
        }
    }
}

impl std::fmt::Display for Lint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Lint::VerifyFailed(k) => write!(f, "verify_failed.{k}"),
            other => f.write_str(other.name()),
        }
    }
}

/// One linter finding: what, how bad, why, and where.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub lint: Lint,
    pub severity: Severity,
    pub message: String,
    /// Where the finding was made — a workload/batch label such as
    /// `analytics[puma]/cell(w=8)` or `system/run_compiled`.
    pub site: String,
}

impl Diagnostic {
    pub fn new(
        lint: Lint,
        severity: Severity,
        site: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Self {
            lint,
            severity,
            message: message.into(),
            site: site.into(),
        }
    }

    /// Render as one JSON object (hand-rolled; the repo carries no
    /// serde).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"lint\":\"{}\",\"severity\":\"{}\",\"site\":\"{}\",\"message\":\"{}\"}}",
            json_escape(&self.lint.to_string()),
            self.severity.name(),
            json_escape(&self.site),
            json_escape(&self.message),
        )
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}[{}] {}: {}",
            self.severity, self.lint, self.site, self.message
        )
    }
}

/// Render a diagnostic list as a JSON array (one object per line, so
/// the artifact diffs cleanly).
pub fn diagnostics_to_json(diags: &[Diagnostic]) -> String {
    let mut out = String::from("[\n");
    for (i, d) in diags.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&d.to_json());
        if i + 1 < diags.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push(']');
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// What each fallback cause means, and what would have fixed it.
fn cause_hint(cause: FallbackCause) -> &'static str {
    match cause {
        FallbackCause::Fragmented => {
            "operand rows are not physically contiguous — allocate \
             row-granular PUD memory (PUMA pimalloc) instead of \
             page-scattered base pages"
        }
        FallbackCause::Misaligned => {
            "operand rows do not start at column 0 — the allocation \
             is not row-aligned"
        }
        FallbackCause::Reserved => {
            "operand rows land on reserved Ambit control/temp rows"
        }
        FallbackCause::CrossSubarray => {
            "operands sit in different subarrays — \
             AllocRequest::align_with (or a scratch hint) would have \
             co-located them"
        }
    }
}

/// Shard-imbalance thresholds: only speak up when the batch is big
/// enough to matter and the skew is real.
const IMBALANCE_MIN_ROWS: u64 = 64;
const IMBALANCE_MIN_BANKS: usize = 2;
const IMBALANCE_FACTOR: f64 = 2.0;

/// Lint a planned batch: attribute every fallback row to its cause,
/// flag avoidable ones, and check the PUD-row spread across banks.
pub fn lint_plans(plans: &[OpPlan], site: &str) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut causes = CauseCounts::default();
    let mut per_bank: FxHashMap<(u32, u32, u32), u64> = FxHashMap::default();
    let mut total_rows = 0u64;
    for p in plans {
        total_rows += p.rows.len() as u64;
        for r in &p.rows {
            match r {
                RowPlan::Pud { dst, .. } => {
                    *per_bank
                        .entry((dst.channel, dst.rank, dst.bank))
                        .or_insert(0) += 1;
                }
                RowPlan::Fallback { cause, .. } => causes.add(*cause, 1),
            }
        }
    }

    for cause in FallbackCause::ALL {
        let n = causes.get(cause);
        if n > 0 {
            diags.push(Diagnostic::new(
                Lint::FallbackRow(cause),
                Severity::Warning,
                site,
                format!(
                    "{n} of {total_rows} row(s) fell back to the CPU \
                     path: {}",
                    cause_hint(cause)
                ),
            ));
        }
    }
    if causes.get(FallbackCause::CrossSubarray) > 0 {
        diags.push(Diagnostic::new(
            Lint::AvoidableFallback,
            Severity::Note,
            site,
            format!(
                "{} cross-subarray fallback row(s) are avoidable: \
                 request the operands with AllocRequest::align_with so \
                 the allocator co-locates them",
                causes.get(FallbackCause::CrossSubarray)
            ),
        ));
    }
    if causes.get(FallbackCause::Misaligned) > 0 {
        diags.push(Diagnostic::new(
            Lint::AvoidableFallback,
            Severity::Note,
            site,
            format!(
                "{} misaligned fallback row(s) are avoidable: allocate \
                 the operands from a row-granular PUD pool so every \
                 buffer starts at column 0",
                causes.get(FallbackCause::Misaligned)
            ),
        ));
    }

    let pud_rows: u64 = per_bank.values().sum();
    if per_bank.len() >= IMBALANCE_MIN_BANKS && pud_rows >= IMBALANCE_MIN_ROWS {
        let max = per_bank.values().copied().max().unwrap_or(0);
        let avg = pud_rows as f64 / per_bank.len() as f64;
        if max as f64 > IMBALANCE_FACTOR * avg {
            let (&(ch, rk, bk), _) = per_bank
                .iter()
                .max_by_key(|(_, &n)| n)
                .expect("non-empty per_bank");
            diags.push(Diagnostic::new(
                Lint::ShardImbalance,
                Severity::Warning,
                site,
                format!(
                    "PUD rows are imbalanced across banks: \
                     channel {ch} rank {rk} bank {bk} executes {max} of \
                     {pud_rows} row(s) ({:.0}% above the {:.1}-row \
                     per-bank average) — bank-level parallelism is \
                     being wasted",
                    100.0 * (max as f64 - avg) / avg.max(1e-9),
                    avg,
                ),
            ));
        }
    }
    diags
}

/// Lint a scratch pool at a retirement point: resident leases here
/// mean the workload finished without handing its temporaries back.
pub fn lint_scratch_pool(pool: &ScratchPool, site: &str) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if !pool.is_empty() {
        diags.push(Diagnostic::new(
            Lint::LeakedScratchLease,
            Severity::Warning,
            site,
            format!(
                "{} scratch buffer(s) ({} active, {} parked) still \
                 leased after the workload retired — trim or \
                 release_all the pool so the allocator gets its rows \
                 back",
                pool.len(),
                pool.slots().len(),
                pool.parked(),
            ),
        ));
    }
    diags
}

/// Lint an allocation-stats delta: hints that the allocator could not
/// honor usually foreshadow cross-subarray fallbacks later.
pub fn lint_alloc_hint(
    before: &AllocStats,
    after: &AllocStats,
    site: &str,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let missed = after.hint_missed.saturating_sub(before.hint_missed);
    if missed > 0 {
        diags.push(Diagnostic::new(
            Lint::MissedHint,
            Severity::Note,
            site,
            format!(
                "{missed} alignment hint(s) could not be honored — the \
                 target subarray was full, so these buffers will not \
                 co-locate with their hint"
            ),
        ));
    }
    diags
}

/// Wrap a verifier rejection as an `Error` diagnostic (the only lint
/// that is an error: the stream is wrong, not just slow).
pub fn verify_failed(e: &VerifyError, site: &str) -> Diagnostic {
    Diagnostic::new(
        Lint::VerifyFailed(e.kind),
        Severity::Error,
        site,
        e.to_string(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::{Loc, SubarrayId};
    use crate::os::process::PhysExtent;
    use crate::pud::isa::PudOp;

    fn pud_row(bank: u32) -> RowPlan {
        RowPlan::Pud {
            sid: SubarrayId(0),
            dst: Loc {
                channel: 0,
                rank: 0,
                bank,
                subarray: 0,
                row: 0,
                column: 0,
            },
            srcs: vec![],
            bytes: 8192,
        }
    }

    fn fb_row(cause: FallbackCause) -> RowPlan {
        RowPlan::Fallback {
            dst: vec![PhysExtent { paddr: 0, len: 8192 }],
            srcs: vec![],
            bytes: 8192,
            cause,
        }
    }

    fn plan_of(rows: Vec<RowPlan>) -> OpPlan {
        OpPlan {
            op: PudOp::And,
            len: rows.len() as u64 * 8192,
            rows,
            dst_ranges: vec![],
            src_ranges: vec![],
        }
    }

    #[test]
    fn clean_plans_produce_no_diagnostics() {
        let plans = vec![plan_of(vec![pud_row(0), pud_row(1)])];
        assert!(lint_plans(&plans, "t").is_empty());
    }

    #[test]
    fn fallbacks_are_attributed_per_cause() {
        let plans = vec![plan_of(vec![
            fb_row(FallbackCause::CrossSubarray),
            fb_row(FallbackCause::CrossSubarray),
            fb_row(FallbackCause::Reserved),
            pud_row(0),
        ])];
        let diags = lint_plans(&plans, "t");
        let names: Vec<&str> = diags.iter().map(|d| d.lint.name()).collect();
        assert!(names.contains(&"fallback_row.cross_subarray"));
        assert!(names.contains(&"fallback_row.reserved"));
        assert!(!names.contains(&"fallback_row.misaligned"));
        // cross-subarray fallbacks also get the avoidable note
        assert!(names.contains(&"avoidable_fallback"));
        let xs = diags
            .iter()
            .find(|d| {
                d.lint == Lint::FallbackRow(FallbackCause::CrossSubarray)
            })
            .unwrap();
        assert_eq!(xs.severity, Severity::Warning);
        assert!(xs.message.contains("2 of 4"), "{}", xs.message);
    }

    #[test]
    fn shard_imbalance_requires_scale_and_skew() {
        // balanced: no finding
        let rows: Vec<RowPlan> =
            (0..128).map(|i| pud_row(i % 4)).collect();
        assert!(lint_plans(&[plan_of(rows)], "t").is_empty());
        // skewed but tiny: still quiet
        let rows: Vec<RowPlan> = (0..8)
            .map(|i| pud_row(if i == 0 { 1 } else { 0 }))
            .collect();
        assert!(lint_plans(&[plan_of(rows)], "t").is_empty());
        // skewed at scale: one bank does ~all the work
        let rows: Vec<RowPlan> = (0..128)
            .map(|i| pud_row(if i < 120 { 0 } else { i % 4 }))
            .collect();
        let diags = lint_plans(&[plan_of(rows)], "t");
        assert!(diags
            .iter()
            .any(|d| d.lint == Lint::ShardImbalance), "{diags:?}");
    }

    #[test]
    fn scratch_and_hint_lints() {
        let pool = ScratchPool::new();
        assert!(lint_scratch_pool(&pool, "t").is_empty());

        let before = AllocStats::default();
        let mut after = AllocStats::default();
        assert!(lint_alloc_hint(&before, &after, "t").is_empty());
        after.hint_missed = 3;
        let diags = lint_alloc_hint(&before, &after, "t");
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].lint, Lint::MissedHint);
        assert_eq!(diags[0].severity, Severity::Note);
    }

    #[test]
    fn json_rendering_escapes_and_lists() {
        let d = Diagnostic::new(
            Lint::AvoidableFallback,
            Severity::Note,
            "site\"x\"",
            "line1\nline2",
        );
        let j = d.to_json();
        assert!(j.contains("\\\"x\\\""), "{j}");
        assert!(j.contains("line1\\nline2"), "{j}");
        let arr = diagnostics_to_json(&[d.clone(), d]);
        assert!(arr.starts_with("[\n"), "{arr}");
        assert!(arr.ends_with(']'), "{arr}");
        assert_eq!(arr.matches("avoidable_fallback").count(), 2);
        assert!(diagnostics_to_json(&[]).starts_with('['));
    }

    #[test]
    fn verify_failures_are_errors() {
        let e = VerifyError {
            kind: VerifyErrorKind::UseBeforeDef,
            message: "x".into(),
            req_idx: Some(3),
        };
        let d = verify_failed(&e, "t");
        assert_eq!(d.severity, Severity::Error);
        assert_eq!(d.lint, Lint::VerifyFailed(VerifyErrorKind::UseBeforeDef));
        assert!(d.to_json().contains("verify_failed.use_before_def"));
    }
}
