//! The multi-tenant serving workload.
//!
//! Boots the SAME machine twice (same seed, same allocator, same
//! per-tenant traffic) behind two [`Gateway`]s and drains one with the
//! DRR fairness scheduler ([`Gateway::drain`]) and the other
//! back-to-back, tenant after tenant
//! ([`Gateway::drain_back_to_back`]). Because every tenant touches
//! only its own session's buffers, the two schedules must produce
//! byte-identical memory images — the driver reads every buffer back
//! from both machines and records the comparison in
//! [`ServeResult::identical`] — while their *tenant completion times*
//! differ: under DRR the p99 tenant completion tracks the interleaved
//! makespan (PUMA's bank-disjoint placement lets the hazard-wave
//! scheduler overlap different tenants' rows), whereas back-to-back
//! the p99 tenant waits for every earlier tenant's full queue.
//!
//! Each tenant runs one of four traffic kinds (round-robin by tenant
//! index — [`Traffic`]): independent boolean *filter* planes, a
//! dependent *analytics* chain, progressive *query* mask folds, and
//! RowClone-heavy *churn*. Tenant buffers follow the paper's
//! allocation protocol through the [`AllocRequest`] builder: the
//! anchor is drawn with `spread(tenant)` so tenants land on distinct
//! banks, and the remaining operands chain `align_with(anchor)`.

use anyhow::{ensure, Result};

use crate::alloc::request::AllocRequest;
use crate::coordinator::system::{System, SystemConfig};
use crate::dram::address::InterleaveScheme;
use crate::pud::isa::{BulkRequest, PudOp};
use crate::serve::{
    AdmissionStats, Gateway, GatewayConfig, SessionConfig, SessionId,
};
use crate::util::rng::Pcg64;
use crate::workloads::microbench::AllocatorKind;

/// Serving-workload configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Concurrent tenant sessions (the paper-style study uses >= 8).
    pub tenants: usize,
    /// Requests each tenant submits.
    pub ops_per_tenant: usize,
    /// Bytes per tenant buffer (each tenant owns four).
    pub buf_bytes: u64,
    /// DRR quantum, in rows per unit weight per round.
    pub quantum: u64,
    /// Per-session soft backpressure threshold (see
    /// [`SessionConfig::backpressure`]); set below `ops_per_tenant` to
    /// exercise `SubmitOutcome::Queued`.
    pub backpressure: usize,
    /// Per-session hard queue cap; the driver requires
    /// `queue_cap >= ops_per_tenant` so its own traffic is never
    /// rejected (rejection paths are covered by `tests/prop_serve.rs`).
    pub queue_cap: usize,
    /// Boot-time huge-page pool size.
    pub huge_pages: usize,
    /// Huge pages PUMA pre-allocates.
    pub puma_pages: usize,
    /// Churn rounds for the boot-time pool aging model.
    pub churn_rounds: usize,
    /// Seed for tenant data.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            tenants: 8,
            ops_per_tenant: 12,
            buf_bytes: 64 * 1024,
            quantum: 8,
            backpressure: 8,
            queue_cap: 1024,
            huge_pages: 24,
            puma_pages: 16,
            churn_rounds: 2_000,
            seed: 0xC0FFEE,
        }
    }
}

/// One tenant's traffic kind, assigned round-robin by tenant index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Traffic {
    /// Independent boolean filter planes (AND/OR/XOR over the seeded
    /// operands).
    Filter,
    /// Dependent chain: each op consumes the previous op's output.
    Analytics,
    /// Progressive mask folds (semi-join-style AND/OR narrowing).
    Query,
    /// RowClone-heavy zero/copy traffic.
    Churn,
}

impl Traffic {
    /// The kind tenant `t` runs.
    pub fn of(t: usize) -> Traffic {
        match t % 4 {
            0 => Traffic::Filter,
            1 => Traffic::Analytics,
            2 => Traffic::Query,
            _ => Traffic::Churn,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Traffic::Filter => "filter",
            Traffic::Analytics => "analytics",
            Traffic::Query => "query",
            Traffic::Churn => "churn",
        }
    }

    /// The `j`-th request of this kind over the tenant's four buffers
    /// `[a, b, c, d]` (a and b are seeded; c and d start zeroed).
    fn request(&self, j: usize, bufs: [u64; 4], len: u64) -> BulkRequest {
        let [a, b, c, d] = bufs;
        match self {
            Traffic::Filter => match j % 3 {
                0 => BulkRequest::new(PudOp::And, c, vec![a, b], len),
                1 => BulkRequest::new(PudOp::Or, d, vec![a, b], len),
                _ => BulkRequest::new(PudOp::Xor, c, vec![a, b], len),
            },
            Traffic::Analytics => match j % 4 {
                0 => BulkRequest::new(PudOp::And, c, vec![a, b], len),
                1 => BulkRequest::new(PudOp::Not, d, vec![c], len),
                2 => BulkRequest::new(PudOp::Or, c, vec![d, a], len),
                _ => BulkRequest::new(PudOp::Xor, d, vec![c, b], len),
            },
            Traffic::Query => match j % 4 {
                0 => BulkRequest::new(PudOp::And, c, vec![a, b], len),
                1 => BulkRequest::new(PudOp::Or, d, vec![c, b], len),
                2 => BulkRequest::new(PudOp::And, c, vec![d, a], len),
                _ => BulkRequest::new(PudOp::Xor, d, vec![c, a], len),
            },
            Traffic::Churn => match j % 4 {
                0 => BulkRequest::new(PudOp::Zero, c, vec![], len),
                1 => BulkRequest::new(PudOp::Copy, d, vec![a], len),
                2 => BulkRequest::new(PudOp::Copy, c, vec![b], len),
                _ => BulkRequest::new(PudOp::Zero, d, vec![], len),
            },
        }
    }
}

/// One tenant's completion summary under both schedules.
#[derive(Debug, Clone)]
pub struct TenantSummary {
    /// Session name (`t{i}-{traffic}`).
    pub name: String,
    /// Traffic kind name.
    pub traffic: &'static str,
    /// DRR weight.
    pub weight: u32,
    /// Requests the tenant submitted.
    pub ops: usize,
    /// Completion time under the DRR schedule (gateway clock, ns).
    pub drr_done_ns: f64,
    /// Completion time under the back-to-back schedule.
    pub b2b_done_ns: f64,
}

/// Result of one serving-workload run (both schedules).
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// Allocator under test.
    pub allocator: &'static str,
    /// Per-tenant completions, in tenant order.
    pub tenants: Vec<TenantSummary>,
    /// Requests per tenant.
    pub ops_per_tenant: usize,
    /// DRR rounds the fair gateway executed.
    pub drr_rounds: u64,
    /// Fair gateway's total simulated makespan (ns).
    pub drr_makespan_ns: f64,
    /// Baseline gateway's total simulated makespan (ns).
    pub b2b_makespan_ns: f64,
    /// Exact p50 of per-tenant completion under DRR.
    pub drr_p50_ns: f64,
    /// Exact p99 of per-tenant completion under DRR.
    pub drr_p99_ns: f64,
    /// Exact p50 of per-tenant completion back-to-back.
    pub b2b_p50_ns: f64,
    /// Exact p99 of per-tenant completion back-to-back.
    pub b2b_p99_ns: f64,
    /// True when both schedules produced byte-identical buffers for
    /// every tenant (they must; asserted by callers).
    pub identical: bool,
    /// Admission counters of the fair gateway (the baseline's are
    /// checked equal before it is reported).
    pub admission: AdmissionStats,
    /// Rows executed in DRAM by the fair gateway.
    pub pud_rows: u64,
    /// Rows that fell back to the CPU on the fair gateway.
    pub fallback_rows: u64,
}

impl ServeResult {
    /// Fraction of rows the fair gateway executed in DRAM.
    pub fn pud_row_fraction(&self) -> f64 {
        let total = self.pud_rows + self.fallback_rows;
        if total == 0 {
            return 0.0;
        }
        self.pud_rows as f64 / total as f64
    }

    /// How much the DRR schedule improves the p99 tenant completion:
    /// `b2b_p99 / drr_p99` (> 1 means fairness won).
    pub fn p99_speedup(&self) -> f64 {
        self.b2b_p99_ns / self.drr_p99_ns.max(1e-9)
    }
}

/// Exact nearest-rank percentile (`p` in 0..=100) of `xs`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("completion times are finite"));
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.saturating_sub(1).min(v.len() - 1)]
}

struct Tenant {
    id: SessionId,
    bufs: [u64; 4],
    traffic: Traffic,
}

/// Boot a gateway, open `cfg.tenants` sessions with their buffers
/// seeded, and load every tenant's full traffic into its queue.
fn build_loaded_gateway(
    scheme: InterleaveScheme,
    cfg: &ServeConfig,
    kind: AllocatorKind,
) -> Result<(Gateway, Vec<Tenant>)> {
    let mut sys = System::boot(SystemConfig {
        scheme,
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        artifacts: None,
        ..Default::default()
    })?;
    let alloc = kind.build(&mut sys, cfg.puma_pages)?;
    let mut gw =
        Gateway::new(sys, alloc, GatewayConfig { quantum: cfg.quantum });
    let len = cfg.buf_bytes;
    let mut tenants = Vec::with_capacity(cfg.tenants);
    for t in 0..cfg.tenants {
        let traffic = Traffic::of(t);
        let id = gw.open(SessionConfig {
            weight: if t % 4 == 1 { 2 } else { 1 },
            backpressure: cfg.backpressure,
            queue_cap: cfg.queue_cap,
            ..SessionConfig::named(format!("t{t}-{}", traffic.name()))
        });
        let seed = cfg.seed ^ (t as u64 + 1);
        let bufs = gw.with_session(id, |sess, sys, alloc| {
            let a = sess.alloc(
                sys,
                alloc,
                AllocRequest::bytes(len).spread(t as u32),
            )?;
            let b =
                sess.alloc(sys, alloc, AllocRequest::bytes(len).align_with(a))?;
            let c =
                sess.alloc(sys, alloc, AllocRequest::bytes(len).align_with(a))?;
            let d =
                sess.alloc(sys, alloc, AllocRequest::bytes(len).align_with(a))?;
            let mut rng = Pcg64::new(seed);
            let mut pa = vec![0u8; len as usize];
            let mut pb = vec![0u8; len as usize];
            rng.fill_bytes(&mut pa);
            rng.fill_bytes(&mut pb);
            sess.write(sys, a, &pa)?;
            sess.write(sys, b, &pb)?;
            sess.write(sys, c, &vec![0u8; len as usize])?;
            sess.write(sys, d, &vec![0u8; len as usize])?;
            Ok([a, b, c, d])
        })?;
        tenants.push(Tenant { id, bufs, traffic });
    }
    for t in &tenants {
        for j in 0..cfg.ops_per_tenant {
            let req = t.traffic.request(j, t.bufs, len);
            let outcome = gw.submit(t.id, req)?;
            ensure!(
                outcome.is_admitted(),
                "serve driver overflowed its own queue cap \
                 (queue_cap {} < ops_per_tenant {}?)",
                cfg.queue_cap,
                cfg.ops_per_tenant
            );
        }
    }
    Ok((gw, tenants))
}

/// Run the serving workload on `kind`: twin gateways, DRR vs
/// back-to-back, with byte-identical-results verification (see module
/// docs).
pub fn run(
    scheme: InterleaveScheme,
    cfg: &ServeConfig,
    kind: AllocatorKind,
) -> Result<ServeResult> {
    ensure!(cfg.tenants >= 2, "the serving study needs >= 2 tenants");
    ensure!(cfg.ops_per_tenant >= 1, "tenants must submit something");
    ensure!(
        cfg.queue_cap >= cfg.ops_per_tenant,
        "driver traffic must fit the queue cap"
    );
    let (mut fair, tenants) =
        build_loaded_gateway(scheme.clone(), cfg, kind)?;
    let drr_rounds = fair.drain()?;
    let (mut base, base_tenants) = build_loaded_gateway(scheme, cfg, kind)?;
    ensure!(
        fair.admission_stats() == base.admission_stats(),
        "twin gateways saw different admission outcomes"
    );
    base.drain_back_to_back()?;

    let mut identical = true;
    for (t, u) in tenants.iter().zip(&base_tenants) {
        for (&fva, &bva) in t.bufs.iter().zip(&u.bufs) {
            let got = fair.with_session(t.id, |sess, sys, _| {
                sess.read(sys, fva, cfg.buf_bytes)
            })?;
            let want = base.with_session(u.id, |sess, sys, _| {
                sess.read(sys, bva, cfg.buf_bytes)
            })?;
            identical &= got == want;
        }
    }

    let drr_done: Vec<f64> =
        fair.completions().iter().map(|(_, ns)| *ns).collect();
    let b2b_done: Vec<f64> =
        base.completions().iter().map(|(_, ns)| *ns).collect();
    let mut summaries = Vec::with_capacity(tenants.len());
    for (i, t) in tenants.iter().enumerate() {
        let sess = fair.session(t.id)?;
        summaries.push(TenantSummary {
            name: sess.name().to_string(),
            traffic: t.traffic.name(),
            weight: sess.weight(),
            ops: cfg.ops_per_tenant,
            drr_done_ns: drr_done[i],
            b2b_done_ns: b2b_done[i],
        });
    }
    let stats = &fair.sys.coord.stats;
    Ok(ServeResult {
        allocator: kind.name(),
        tenants: summaries,
        ops_per_tenant: cfg.ops_per_tenant,
        drr_rounds,
        drr_makespan_ns: fair.clock_ns(),
        b2b_makespan_ns: base.clock_ns(),
        drr_p50_ns: percentile(&drr_done, 50.0),
        drr_p99_ns: percentile(&drr_done, 99.0),
        b2b_p50_ns: percentile(&b2b_done, 50.0),
        b2b_p99_ns: percentile(&b2b_done, 99.0),
        identical,
        admission: fair.admission_stats(),
        pud_rows: stats.pud_rows,
        fallback_rows: stats.fallback_rows,
    })
}

/// Sweep allocators, one twin-gateway run per kind.
pub fn sweep(
    scheme: &InterleaveScheme,
    cfg: &ServeConfig,
    kinds: &[AllocatorKind],
) -> Result<Vec<ServeResult>> {
    kinds
        .iter()
        .map(|kind| run(scheme.clone(), cfg, *kind))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::FitPolicy;
    use crate::dram::geometry::DramGeometry;

    fn scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(DramGeometry::small()) // 64 MiB
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            tenants: 8,
            ops_per_tenant: 8,
            buf_bytes: 16 * 1024,
            backpressure: 4,
            churn_rounds: 500,
            ..Default::default()
        }
    }

    #[test]
    fn percentile_is_exact_nearest_rank() {
        let xs = vec![40.0, 10.0, 30.0, 20.0];
        assert_eq!(percentile(&xs, 50.0), 20.0);
        assert_eq!(percentile(&xs, 99.0), 40.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
    }

    #[test]
    fn traffic_kinds_cycle_by_tenant() {
        assert_eq!(Traffic::of(0), Traffic::Filter);
        assert_eq!(Traffic::of(5), Traffic::Analytics);
        assert_eq!(Traffic::of(7), Traffic::Churn);
        assert_eq!(Traffic::of(7).name(), "churn");
    }

    #[test]
    fn drr_matches_back_to_back_byte_for_byte() {
        let c = cfg();
        let r = run(scheme(), &c, AllocatorKind::Puma(FitPolicy::WorstFit))
            .unwrap();
        assert!(r.identical, "schedules diverged");
        assert_eq!(r.tenants.len(), 8);
        assert!(r.drr_rounds >= 1);
        for t in &r.tenants {
            assert!(t.drr_done_ns > 0.0, "{} never completed", t.name);
            assert!(t.b2b_done_ns > 0.0, "{} never completed", t.name);
        }
        // every submission was admitted, and backpressure < ops means
        // some were soft-queued
        let st = r.admission;
        assert_eq!(
            (st.accepted + st.queued) as usize,
            c.tenants * c.ops_per_tenant
        );
        assert_eq!(st.rejected, 0);
        assert!(st.queued > 0, "backpressure threshold never tripped");
    }

    #[test]
    fn puma_fairness_beats_back_to_back_at_the_tail() {
        let r = run(scheme(), &cfg(), AllocatorKind::Puma(FitPolicy::WorstFit))
            .unwrap();
        assert!(r.identical);
        // bank-disjoint tenants overlap under DRR, so the tail tenant
        // finishes strictly earlier than in the serial schedule
        assert!(
            r.drr_p99_ns < r.b2b_p99_ns,
            "drr p99 {} !< b2b p99 {}",
            r.drr_p99_ns,
            r.b2b_p99_ns
        );
        assert!(r.p99_speedup() > 1.0);
        // spread anchors + align chaining keep the traffic in DRAM
        assert!(
            r.pud_row_fraction() > 0.5,
            "got {}",
            r.pud_row_fraction()
        );
    }

    #[test]
    fn malloc_stays_correct_without_pud() {
        let c = ServeConfig {
            tenants: 4,
            ops_per_tenant: 4,
            ..cfg()
        };
        let r = run(scheme(), &c, AllocatorKind::Malloc).unwrap();
        assert!(r.identical);
        assert!(
            r.pud_row_fraction() < 0.5,
            "got {}",
            r.pud_row_fraction()
        );
    }
}
