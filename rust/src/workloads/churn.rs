//! Multi-tenant aging/churn driver — the allocation-lifecycle
//! workload (promoted from `examples/multi_tenant.rs`).
//!
//! Several tenants allocate operand triples through the shared PUMA
//! instance, run bulk ops over every live triple, and free a fraction
//! of the fleet each epoch. The fill phase deliberately drives the
//! region pool to near-exhaustion, which is what makes
//! `pim_alloc_align` miss its preferred subarrays — the co-location
//! decay the paper's alloc-time-only design cannot undo. With
//! `compact: true` the driver runs a [`PumaAlloc::compact`] pass per
//! tenant per epoch (plus a final drain), so the decay is repaired and
//! fully-freed huge pages flow back to the boot pool; with
//! `compact: false` it only runs the bare [`PumaAlloc::reclaim`],
//! which models the paper's baseline lifecycle.
//!
//! Per-epoch curves (PUD-row fraction of the *workload* ops, pool
//! occupancy, fragmentation) are what `puma churn` prints and
//! `bench_runtime` writes to `BENCH_runtime.json`.

use anyhow::Result;

use crate::alloc::puma::{FitPolicy, PumaAlloc};
use crate::alloc::traits::{AllocStats, Allocator};
use crate::coordinator::system::{System, SystemConfig};
use crate::coordinator::CoordStats;
use crate::dram::address::InterleaveScheme;
use crate::dram::timing::TimingParams;
use crate::os::process::Pid;
use crate::pud::isa::{BulkRequest, PudOp};
use crate::util::rng::Pcg64;

/// Churn-driver knobs.
#[derive(Debug, Clone)]
pub struct ChurnConfig {
    /// Concurrent tenant processes sharing the PUMA instance.
    pub tenants: usize,
    /// Alloc/op/free/compact rounds.
    pub epochs: usize,
    /// Upper bound on operand size, in DRAM rows (sizes vary per
    /// group, `4..=2*rows_per_operand`, to stress placement).
    pub rows_per_operand: u64,
    /// Bulk ops per live triple per epoch.
    pub ops_per_group: usize,
    /// Fraction of live triples freed per epoch.
    pub free_fraction: f64,
    /// Run `compact()` per tenant per epoch (else bare `reclaim()`).
    pub compact: bool,
    /// Boot-time hugetlb pool size.
    pub huge_pages: usize,
    /// Pages `pim_preallocate` keeps moving into PUMA (the driver tops
    /// the allocator back up to this as reclaim returns pages).
    pub puma_pages: usize,
    /// Buddy aging before the run.
    pub churn_rounds: usize,
    pub seed: u64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        Self {
            tenants: 3,
            epochs: 10,
            rows_per_operand: 12,
            ops_per_group: 2,
            free_fraction: 0.45,
            compact: false,
            huge_pages: 8,
            puma_pages: 4,
            churn_rounds: 1_000,
            seed: 0xC0FFEE,
        }
    }
}

/// One live operand triple.
#[derive(Debug, Clone, Copy)]
struct Group {
    pid: Pid,
    a: u64,
    b: u64,
    c: u64,
    len: u64,
}

/// Per-epoch measurement point.
#[derive(Debug, Clone)]
pub struct EpochSample {
    pub epoch: usize,
    /// Live triples at sample time (after the epoch's frees).
    pub live_groups: usize,
    /// PUD-row fraction of this epoch's workload ops only (compaction
    /// copies are excluded — they are reported as `compact_ns`).
    pub op_pud_fraction: f64,
    /// Allocated fraction of the carved pool right after the fill
    /// phase (the pressure the epoch's late allocations saw).
    pub peak_occupancy: f64,
    /// Allocated fraction of the carved pool at epoch end (after the
    /// frees and the lifecycle pass).
    pub pool_occupancy: f64,
    /// Fraction of held pages that are partially free (unreclaimable).
    pub fragmentation: f64,
    pub free_regions: usize,
    /// Cumulative regions moved by compaction.
    pub regions_migrated_total: u64,
    /// Cumulative huge pages returned to the boot pool.
    pub pages_reclaimed_total: u64,
    /// Simulated ns of this epoch's workload ops.
    pub op_ns: f64,
    /// Simulated ns of this epoch's migration copies.
    pub compact_ns: f64,
}

/// Per-tenant latency digest, read back from the coordinator's metrics
/// registry (`churn/t{i}/alloc_ns` and `churn/t{i}/op_ns`; DESIGN.md
/// §14). Simulated nanoseconds, so the digest is deterministic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantLatency {
    pub tenant: usize,
    /// Successful allocations this tenant made.
    pub allocs: u64,
    pub alloc_p50_ns: u64,
    pub alloc_p99_ns: u64,
    /// Workload ops flushed for this tenant.
    pub ops: u64,
    pub op_p50_ns: u64,
    pub op_p99_ns: u64,
}

/// Result of one churn run.
#[derive(Debug, Clone)]
pub struct ChurnResult {
    pub samples: Vec<EpochSample>,
    pub alloc: AllocStats,
    pub coord: CoordStats,
    /// Per-tenant alloc/op latency percentiles (one entry per tenant).
    pub tenant_latency: Vec<TenantLatency>,
    /// Mean workload-op PUD-row fraction over the last half of the
    /// epochs — the paper-metric the compaction comparison is about.
    pub steady_state_pud_fraction: f64,
    /// Huge pages returned to the boot pool over the whole run
    /// (including the final drain).
    pub pages_returned: u64,
    /// Pool occupancy after the final drain.
    pub final_occupancy: f64,
    /// Boot-pool pages available again after the final drain.
    pub final_pool_available: usize,
}

/// Run the churn workload on a machine with the given interleaving.
pub fn run(scheme: InterleaveScheme, cfg: &ChurnConfig) -> Result<ChurnResult> {
    let mut sys = System::boot(SystemConfig {
        scheme,
        timing: TimingParams::default(),
        huge_pages: cfg.huge_pages,
        churn_rounds: cfg.churn_rounds,
        seed: cfg.seed,
        artifacts: None,
    })?;
    let row = sys.os.scheme.geometry.row_bytes as u64;
    let mut puma = PumaAlloc::new(row, FitPolicy::WorstFit);
    puma.pim_preallocate(&mut sys.os, cfg.puma_pages)?;
    let pids: Vec<Pid> = (0..cfg.tenants).map(|_| sys.spawn()).collect();
    // per-tenant latency histograms, registered once and recorded by id
    let (alloc_h, op_h): (Vec<_>, Vec<_>) = (0..cfg.tenants)
        .map(|ti| {
            let reg = &mut sys.coord.obs.registry;
            (
                reg.hist(&format!("churn/t{ti}/alloc_ns")),
                reg.hist(&format!("churn/t{ti}/op_ns")),
            )
        })
        .unzip();
    let mut rng = Pcg64::new(cfg.seed ^ 0x5EED_CAFE);
    let ops = [PudOp::And, PudOp::Or, PudOp::Xor];

    let mut live: Vec<Group> = Vec::new();
    let mut samples = Vec::with_capacity(cfg.epochs);
    let mut tenant_rr = 0usize;

    for epoch in 0..cfg.epochs {
        // 0. top the allocator back up with pages reclaim gave back
        while puma.preallocated() < cfg.puma_pages && sys.os.pool.available() > 0 {
            puma.pim_preallocate(&mut sys.os, 1)?;
        }

        // 1. fill to near-exhaustion: randomly-sized triples until not
        //    even the smallest triple fits — the final groups allocate
        //    under real subarray pressure, where hint misses happen
        while puma.free_regions() >= 3 * 4 {
            let max_rows =
                (2 * cfg.rows_per_operand).min(puma.free_regions() as u64 / 3);
            if max_rows < 4 {
                break;
            }
            let rows = rng.range(4, max_rows);
            let len = rows * row;
            let ti = tenant_rr % pids.len();
            let pid = pids[ti];
            tenant_rr += 1;
            let t0 = puma.stats().alloc_ns;
            let Ok(a) = sys.alloc(&mut puma, pid, len) else { break };
            let t1 = puma.stats().alloc_ns;
            sys.coord.obs.registry.observe_ns(alloc_h[ti], t1 - t0);
            let Ok(b) = sys.alloc_align(&mut puma, pid, len, a) else {
                sys.free(&mut puma, pid, a)?;
                break;
            };
            let t2 = puma.stats().alloc_ns;
            sys.coord.obs.registry.observe_ns(alloc_h[ti], t2 - t1);
            let Ok(c) = sys.alloc_align(&mut puma, pid, len, a) else {
                sys.free(&mut puma, pid, b)?;
                sys.free(&mut puma, pid, a)?;
                break;
            };
            let t3 = puma.stats().alloc_ns;
            sys.coord.obs.registry.observe_ns(alloc_h[ti], t3 - t2);
            let mut buf = vec![0u8; len as usize];
            rng.fill_bytes(&mut buf);
            sys.write_virt(pid, a, &buf)?;
            rng.fill_bytes(&mut buf);
            sys.write_virt(pid, b, &buf)?;
            live.push(Group { pid, a, b, c, len });
        }
        let peak_occupancy = puma.occupancy();

        // 2. workload ops over every live triple, batched per tenant
        let pud_before = sys.coord.stats.pud_rows;
        let fb_before = sys.coord.stats.fallback_rows;
        let mut op_ns = 0.0;
        for (ti, pid) in pids.iter().enumerate() {
            for g in live.iter().filter(|g| g.pid == *pid) {
                for k in 0..cfg.ops_per_group {
                    let op = ops[(epoch + k) % ops.len()];
                    sys.enqueue(*pid, BulkRequest::new(op, g.c, vec![g.a, g.b], g.len));
                }
            }
            let report = sys.flush(*pid)?;
            for &ns in &report.per_op_ns {
                sys.coord.obs.registry.observe_ns(op_h[ti], ns);
            }
            op_ns += report.total_ns;
        }
        let dp = sys.coord.stats.pud_rows - pud_before;
        let df = sys.coord.stats.fallback_rows - fb_before;
        let op_pud_fraction = dp as f64 / (dp + df).max(1) as f64;

        // 3. free a fraction of the fleet, uniformly at random
        let nfree = (live.len() as f64 * cfg.free_fraction) as usize;
        for _ in 0..nfree {
            let idx = rng.below(live.len().max(1) as u64) as usize;
            let g = live.swap_remove(idx);
            sys.free(&mut puma, g.pid, g.c)?;
            sys.free(&mut puma, g.pid, g.b)?;
            sys.free(&mut puma, g.pid, g.a)?;
        }

        // 4. lifecycle pass
        let mut compact_ns = 0.0;
        if cfg.compact {
            for pid in &pids {
                compact_ns += sys.compact(&mut puma, *pid)?.copy_ns;
            }
        } else {
            puma.reclaim(&mut sys.os)?;
        }

        samples.push(EpochSample {
            epoch,
            live_groups: live.len(),
            op_pud_fraction,
            peak_occupancy,
            pool_occupancy: puma.occupancy(),
            fragmentation: puma.fragmentation(),
            free_regions: puma.free_regions(),
            regions_migrated_total: puma.stats().regions_migrated,
            pages_reclaimed_total: puma.stats().pages_reclaimed,
            op_ns,
            compact_ns,
        });
    }

    // 5. final drain: the fleet shrinks to a few stragglers; without
    //    evacuation the pool stays pinned, with it the pages flow back
    let keep = (live.len() / 8).max(2).min(live.len());
    while live.len() > keep {
        let idx = rng.below(live.len() as u64) as usize;
        let g = live.swap_remove(idx);
        sys.free(&mut puma, g.pid, g.c)?;
        sys.free(&mut puma, g.pid, g.b)?;
        sys.free(&mut puma, g.pid, g.a)?;
    }
    if cfg.compact {
        for pid in &pids {
            sys.compact(&mut puma, *pid)?;
        }
    } else {
        puma.reclaim(&mut sys.os)?;
    }

    let half = samples.len().div_ceil(2);
    let steady: f64 = samples[samples.len() - half..]
        .iter()
        .map(|s| s.op_pud_fraction)
        .sum::<f64>()
        / half.max(1) as f64;
    let tenant_latency: Vec<TenantLatency> = (0..cfg.tenants)
        .map(|ti| {
            let reg = &sys.coord.obs.registry;
            let a = reg.hist_value(alloc_h[ti]);
            let o = reg.hist_value(op_h[ti]);
            TenantLatency {
                tenant: ti,
                allocs: a.count,
                alloc_p50_ns: a.p50(),
                alloc_p99_ns: a.p99(),
                ops: o.count,
                op_p50_ns: o.p50(),
                op_p99_ns: o.p99(),
            }
        })
        .collect();
    Ok(ChurnResult {
        steady_state_pud_fraction: steady,
        alloc: puma.stats(),
        coord: sys.coord.stats.clone(),
        tenant_latency,
        pages_returned: puma.stats().pages_reclaimed,
        final_occupancy: puma.occupancy(),
        final_pool_available: sys.os.pool.available(),
        samples,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::geometry::DramGeometry;

    fn small_scheme() -> InterleaveScheme {
        InterleaveScheme::row_major(DramGeometry::small()) // 64 MiB
    }

    #[test]
    fn churn_is_deterministic() {
        let cfg = ChurnConfig {
            epochs: 3,
            ..Default::default()
        };
        let x = run(small_scheme(), &cfg).unwrap();
        let y = run(small_scheme(), &cfg).unwrap();
        assert_eq!(x.samples.len(), 3);
        assert_eq!(
            x.steady_state_pud_fraction,
            y.steady_state_pud_fraction
        );
        assert_eq!(x.alloc, y.alloc);
    }

    #[test]
    fn churn_exercises_the_pool_lifecycle() {
        let result = run(small_scheme(), &ChurnConfig::default()).unwrap();
        assert_eq!(result.samples.len(), 10);
        let st = &result.alloc;
        assert!(st.allocs > st.frees, "stragglers stay live");
        assert!(
            st.hint_missed > 0,
            "near-exhaustion fills must produce scattered placements \
             (misses={}, colocated={})",
            st.hint_missed,
            st.hint_colocated
        );
        // the fill phase drives the pool to near-exhaustion
        assert!(result.samples.iter().any(|s| s.peak_occupancy > 0.9));
    }

    #[test]
    fn per_tenant_latency_digests_are_populated_and_deterministic() {
        let cfg = ChurnConfig {
            epochs: 3,
            ..Default::default()
        };
        let x = run(small_scheme(), &cfg).unwrap();
        let y = run(small_scheme(), &cfg).unwrap();
        assert_eq!(x.tenant_latency.len(), cfg.tenants);
        // simulated time, so the digest replays exactly
        assert_eq!(x.tenant_latency, y.tenant_latency);
        let recorded: u64 = x.tenant_latency.iter().map(|t| t.allocs).sum();
        assert!(recorded > 0);
        // AllocStats counts failed fill-phase attempts too, so the
        // per-tenant histograms (successes only) can only undershoot
        assert!(recorded <= x.alloc.allocs, "{recorded} vs {}", x.alloc.allocs);
        for t in &x.tenant_latency {
            assert!(t.ops > 0, "tenant {} ran no ops", t.tenant);
            assert!(t.alloc_p50_ns <= t.alloc_p99_ns);
            assert!(t.op_p50_ns <= t.op_p99_ns);
            assert!(t.op_p99_ns > 0);
        }
    }

    #[test]
    fn compaction_strictly_improves_steady_state_and_reclaims() {
        let off = run(small_scheme(), &ChurnConfig::default()).unwrap();
        let on = run(
            small_scheme(),
            &ChurnConfig {
                compact: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            off.steady_state_pud_fraction < 1.0,
            "without compaction, co-location decay must be visible \
             (steady={})",
            off.steady_state_pud_fraction
        );
        assert!(
            on.steady_state_pud_fraction > off.steady_state_pud_fraction,
            "compaction must strictly improve the steady-state PUD-row \
             fraction: on={} off={}",
            on.steady_state_pud_fraction,
            off.steady_state_pud_fraction
        );
        assert!(on.alloc.regions_migrated > 0, "repairs actually ran");
        assert!(
            on.pages_returned >= 1,
            "evacuation must hand at least one reassembled huge page back"
        );
        assert!(
            on.final_pool_available > off.final_pool_available,
            "the reclaimed pool is visible to the rest of the system"
        );
    }
}
