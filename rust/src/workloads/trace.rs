//! Allocation/operation trace record + replay.
//!
//! Traces stress the allocators the way long-running multi-tenant
//! systems do: interleaved allocs, frees, and bulk ops from several
//! processes, with the PUD pool filling and draining. Used by the
//! fragmentation stress tests and the multi_tenant example.

use anyhow::Result;

use crate::alloc::traits::Allocator;
use crate::coordinator::system::System;
use crate::os::process::Pid;
use crate::pud::isa::{BulkRequest, PudOp};
use crate::util::rng::Pcg64;

/// One trace event.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Allocate `len` bytes; slot is the handle index.
    Alloc { slot: usize, len: u64 },
    /// Allocate aligned to the allocation in `hint_slot`.
    AllocAlign {
        slot: usize,
        len: u64,
        hint_slot: usize,
    },
    /// Free the allocation in `slot`.
    Free { slot: usize },
    /// dst = op(srcs) over the listed slots.
    Op {
        op: PudOp,
        dst_slot: usize,
        src_slots: Vec<usize>,
        len: u64,
    },
}

/// A recorded trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<Event>,
}

impl Trace {
    /// Generate a random-but-deterministic trace: `groups` operand
    /// groups of `group_len` bytes each, with op/free churn.
    pub fn generate(seed: u64, groups: usize, group_len: u64, ops_per_group: usize) -> Trace {
        let mut rng = Pcg64::new(seed);
        let mut events = Vec::new();
        let mut slot = 0usize;
        for _ in 0..groups {
            let (a, b, c) = (slot, slot + 1, slot + 2);
            slot += 3;
            events.push(Event::Alloc { slot: a, len: group_len });
            events.push(Event::AllocAlign {
                slot: b,
                len: group_len,
                hint_slot: a,
            });
            events.push(Event::AllocAlign {
                slot: c,
                len: group_len,
                hint_slot: a,
            });
            for _ in 0..ops_per_group {
                let op = *rng.choose(&[PudOp::And, PudOp::Or, PudOp::Xor, PudOp::Copy]);
                let (dst_slot, src_slots) = match op.arity() {
                    1 => (c, vec![a]),
                    _ => (c, vec![a, b]),
                };
                events.push(Event::Op {
                    op,
                    dst_slot,
                    src_slots,
                    len: group_len,
                });
            }
            // churn: free ~1/3 of groups immediately
            if rng.chance(0.33) {
                events.push(Event::Free { slot: a });
                events.push(Event::Free { slot: b });
                events.push(Event::Free { slot: c });
            }
        }
        Trace { events }
    }

    /// Replay against a system + allocator for one process. Returns
    /// total simulated ns.
    pub fn replay(
        &self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
    ) -> Result<f64> {
        let mut slots: Vec<Option<u64>> = Vec::new();
        let mut total_ns = 0.0;
        let slot_va = |slots: &Vec<Option<u64>>, idx: usize| -> Result<u64> {
            slots
                .get(idx)
                .copied()
                .flatten()
                .ok_or_else(|| anyhow::anyhow!("slot {idx} not live"))
        };
        for ev in &self.events {
            match ev {
                Event::Alloc { slot, len } => {
                    let va = sys.alloc(alloc, pid, *len)?;
                    if slots.len() <= *slot {
                        slots.resize(*slot + 1, None);
                    }
                    slots[*slot] = Some(va);
                }
                Event::AllocAlign {
                    slot,
                    len,
                    hint_slot,
                } => {
                    let hint = slot_va(&slots, *hint_slot)?;
                    let va = sys.alloc_align(alloc, pid, *len, hint)?;
                    if slots.len() <= *slot {
                        slots.resize(*slot + 1, None);
                    }
                    slots[*slot] = Some(va);
                }
                Event::Free { slot } => {
                    let va = slot_va(&slots, *slot)?;
                    sys.free(alloc, pid, va)?;
                    slots[*slot] = None;
                }
                Event::Op {
                    op,
                    dst_slot,
                    src_slots,
                    len,
                } => {
                    let dst = slot_va(&slots, *dst_slot)?;
                    let srcs: Result<Vec<u64>> = src_slots
                        .iter()
                        .map(|s| slot_va(&slots, *s))
                        .collect();
                    let req = BulkRequest::new(*op, dst, srcs?, *len);
                    total_ns += sys.submit(pid, &req)?;
                }
            }
        }
        Ok(total_ns)
    }

    /// As [`Trace::replay`], but consecutive ops are queued on the
    /// system and flushed as one pipeline batch whenever an
    /// allocation-side event (or the end of the trace) intervenes —
    /// the request-queue usage pattern of a batching client. Simulated
    /// time and memory images match the serial replay.
    pub fn replay_batched(
        &self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        pid: Pid,
    ) -> Result<f64> {
        let mut slots: Vec<Option<u64>> = Vec::new();
        let mut total_ns = 0.0;
        let slot_va = |slots: &Vec<Option<u64>>, idx: usize| -> Result<u64> {
            slots
                .get(idx)
                .copied()
                .flatten()
                .ok_or_else(|| anyhow::anyhow!("slot {idx} not live"))
        };
        for ev in &self.events {
            // allocator events change the address space: drain queued
            // ops first so they run against the mappings they saw
            if !matches!(ev, Event::Op { .. }) {
                total_ns += sys.flush(pid)?.total_ns;
            }
            match ev {
                Event::Alloc { slot, len } => {
                    let va = sys.alloc(alloc, pid, *len)?;
                    if slots.len() <= *slot {
                        slots.resize(*slot + 1, None);
                    }
                    slots[*slot] = Some(va);
                }
                Event::AllocAlign {
                    slot,
                    len,
                    hint_slot,
                } => {
                    let hint = slot_va(&slots, *hint_slot)?;
                    let va = sys.alloc_align(alloc, pid, *len, hint)?;
                    if slots.len() <= *slot {
                        slots.resize(*slot + 1, None);
                    }
                    slots[*slot] = Some(va);
                }
                Event::Free { slot } => {
                    let va = slot_va(&slots, *slot)?;
                    sys.free(alloc, pid, va)?;
                    slots[*slot] = None;
                }
                Event::Op {
                    op,
                    dst_slot,
                    src_slots,
                    len,
                } => {
                    let dst = slot_va(&slots, *dst_slot)?;
                    let srcs: Result<Vec<u64>> = src_slots
                        .iter()
                        .map(|s| slot_va(&slots, *s))
                        .collect();
                    sys.enqueue(pid, BulkRequest::new(*op, dst, srcs?, *len));
                }
            }
        }
        total_ns += sys.flush(pid)?.total_ns;
        Ok(total_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::{FitPolicy, PumaAlloc};
    use crate::coordinator::system::SystemConfig;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;
    use crate::workloads::microbench::AllocatorKind;

    fn sys() -> System {
        let scheme = InterleaveScheme::row_major(DramGeometry::small());
        System::boot(SystemConfig {
            scheme,
            huge_pages: 16,
            churn_rounds: 1_000,
            seed: 2,
            artifacts: None,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn generated_trace_is_deterministic() {
        let a = Trace::generate(9, 4, 32 << 10, 2);
        let b = Trace::generate(9, 4, 32 << 10, 2);
        assert_eq!(a.events, b.events);
        assert!(a.events.len() >= 4 * 5);
    }

    #[test]
    fn replay_with_puma_keeps_high_pud_fraction() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 10).unwrap();
        let trace = Trace::generate(31, 6, 64 << 10, 3);
        let ns = trace.replay(&mut sys, &mut puma, pid).unwrap();
        assert!(ns > 0.0);
        assert!(
            sys.coord.stats.pud_row_fraction() > 0.8,
            "PUD fraction under churn: {}",
            sys.coord.stats.pud_row_fraction()
        );
    }

    #[test]
    fn replay_with_malloc_mostly_falls_back() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut m = crate::alloc::mallocsim::MallocSim::new();
        let trace = Trace::generate(31, 4, 64 << 10, 2);
        trace.replay(&mut sys, &mut m, pid).unwrap();
        assert!(sys.coord.stats.pud_row_fraction() < 0.05);
        let _ = AllocatorKind::Malloc;
    }

    #[test]
    fn batched_replay_matches_serial_under_churn() {
        let trace = Trace::generate(77, 8, 48 << 10, 4);
        let mut s1 = sys();
        let p1 = s1.spawn();
        let mut a1 = PumaAlloc::new(8192, FitPolicy::WorstFit);
        a1.pim_preallocate(&mut s1.os, 10).unwrap();
        let serial_ns = trace.replay(&mut s1, &mut a1, p1).unwrap();

        let mut s2 = sys();
        let p2 = s2.spawn();
        let mut a2 = PumaAlloc::new(8192, FitPolicy::WorstFit);
        a2.pim_preallocate(&mut s2.os, 10).unwrap();
        let batched_ns = trace.replay_batched(&mut s2, &mut a2, p2).unwrap();

        assert!((serial_ns - batched_ns).abs() < 1e-6 * serial_ns.max(1.0));
        assert_eq!(s1.coord.stats, s2.coord.stats);
        // the trace frees ~1/3 of its groups, so the batched run must
        // have survived extent-cache invalidation; and batching must
        // actually have batched something
        assert!(s2.coord.pipeline.ops_per_wave() >= 1.0);
        assert!(s2.coord.pipeline.batches < s2.coord.stats.ops);
    }

    #[test]
    fn replay_rejects_dangling_slots() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut m = crate::alloc::mallocsim::MallocSim::new();
        let trace = Trace {
            events: vec![Event::Free { slot: 0 }],
        };
        assert!(trace.replay(&mut sys, &mut m, pid).is_err());
    }
}
