//! Set algebra over bit-vector sets (SISA-style graph/set workload).
//!
//! Sets over a bounded universe are dense bit vectors; union,
//! intersection, difference, and symmetric difference map directly
//! onto the PUD op set (OR / AND / AND+NOT / XOR). This is the second
//! application workload (after bitmap_index) exercising the public
//! API the way the paper's motivating use cases do.

use anyhow::Result;

use crate::alloc::traits::Allocator;
use crate::coordinator::system::System;
use crate::os::process::Pid;
use crate::pud::isa::{BulkRequest, PudOp};

/// A set universe of `universe_bits` elements backed by PUD-placed
/// bit vectors.
pub struct SetUniverse {
    pub pid: Pid,
    pub len: u64,
    first_va: Option<u64>,
}

/// Handle to one set.
#[derive(Debug, Clone, Copy)]
pub struct SetHandle {
    pub va: u64,
}

impl SetUniverse {
    pub fn new(universe_bits: u64, pid: Pid) -> Self {
        Self {
            pid,
            len: universe_bits.div_ceil(8),
            first_va: None,
        }
    }

    /// Allocate an empty set (hint-aligned to the first one).
    pub fn alloc_set(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
    ) -> Result<SetHandle> {
        let va = match self.first_va {
            None => {
                let va = sys.alloc(alloc, self.pid, self.len)?;
                self.first_va = Some(va);
                va
            }
            Some(f) => sys.alloc_align(alloc, self.pid, self.len, f)?,
        };
        Ok(SetHandle { va })
    }

    /// Populate a set from element ids.
    pub fn fill(
        &self,
        sys: &mut System,
        set: SetHandle,
        elements: &[u64],
    ) -> Result<()> {
        let mut bits = vec![0u8; self.len as usize];
        for &e in elements {
            anyhow::ensure!(e / 8 < self.len, "element {e} outside universe");
            bits[(e / 8) as usize] |= 1 << (e % 8);
        }
        sys.write_virt(self.pid, set.va, &bits)
    }

    /// Read a set's members back.
    pub fn members(&self, sys: &mut System, set: SetHandle) -> Result<Vec<u64>> {
        let bits = sys.read_virt(self.pid, set.va, self.len)?;
        let mut out = Vec::new();
        for (byte_idx, byte) in bits.iter().enumerate() {
            let mut b = *byte;
            while b != 0 {
                let bit = b.trailing_zeros() as u64;
                out.push(byte_idx as u64 * 8 + bit);
                b &= b - 1;
            }
        }
        Ok(out)
    }

    /// dst = a INTERSECT b. Returns simulated ns.
    pub fn intersect(
        &self,
        sys: &mut System,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
    ) -> Result<f64> {
        sys.submit(
            self.pid,
            &BulkRequest::new(PudOp::And, dst.va, vec![a.va, b.va], self.len),
        )
    }

    /// dst = a UNION b.
    pub fn union(
        &self,
        sys: &mut System,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
    ) -> Result<f64> {
        sys.submit(
            self.pid,
            &BulkRequest::new(PudOp::Or, dst.va, vec![a.va, b.va], self.len),
        )
    }

    /// dst = a SYMMETRIC-DIFFERENCE b.
    pub fn sym_diff(
        &self,
        sys: &mut System,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
    ) -> Result<f64> {
        sys.submit(
            self.pid,
            &BulkRequest::new(PudOp::Xor, dst.va, vec![a.va, b.va], self.len),
        )
    }

    /// dst = a DIFFERENCE b, composed as a AND (NOT b) with a scratch
    /// set for the complement.
    pub fn difference(
        &self,
        sys: &mut System,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
        scratch: SetHandle,
    ) -> Result<f64> {
        let mut ns = sys.submit(
            self.pid,
            &BulkRequest::new(PudOp::Not, scratch.va, vec![b.va], self.len),
        )?;
        ns += sys.submit(
            self.pid,
            &BulkRequest::new(PudOp::And, dst.va, vec![a.va, scratch.va], self.len),
        )?;
        Ok(ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::{FitPolicy, PumaAlloc};
    use crate::coordinator::system::SystemConfig;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;

    fn sys() -> System {
        let scheme = InterleaveScheme::row_major(DramGeometry::small());
        System::boot(SystemConfig {
            scheme,
            huge_pages: 16,
            churn_rounds: 500,
            seed: 12,
            artifacts: None,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn set_algebra_matches_reference() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 10).unwrap();
        let mut uni = SetUniverse::new(128 * 1024, pid);
        let a = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let b = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let dst = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let scratch = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let xs: Vec<u64> = (0..1000).map(|i| i * 7 % 100_000).collect();
        let ys: Vec<u64> = (0..1000).map(|i| i * 13 % 100_000).collect();
        uni.fill(&mut sys, a, &xs).unwrap();
        uni.fill(&mut sys, b, &ys).unwrap();

        use std::collections::BTreeSet;
        let sa: BTreeSet<u64> = xs.iter().copied().collect();
        let sb: BTreeSet<u64> = ys.iter().copied().collect();

        uni.intersect(&mut sys, dst, a, b).unwrap();
        let got: BTreeSet<u64> = uni.members(&mut sys, dst).unwrap().into_iter().collect();
        assert_eq!(got, &sa & &sb);

        uni.union(&mut sys, dst, a, b).unwrap();
        let got: BTreeSet<u64> = uni.members(&mut sys, dst).unwrap().into_iter().collect();
        assert_eq!(got, &sa | &sb);

        uni.sym_diff(&mut sys, dst, a, b).unwrap();
        let got: BTreeSet<u64> = uni.members(&mut sys, dst).unwrap().into_iter().collect();
        assert_eq!(got, &sa ^ &sb);

        uni.difference(&mut sys, dst, a, b, scratch).unwrap();
        let got: BTreeSet<u64> = uni.members(&mut sys, dst).unwrap().into_iter().collect();
        assert_eq!(got, &sa - &sb);

        // all of it in-DRAM under PUMA placement
        assert!(sys.coord.stats.pud_row_fraction() > 0.9);
    }

    #[test]
    fn fill_rejects_out_of_universe() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 4).unwrap();
        let mut uni = SetUniverse::new(1024, pid);
        let s = uni.alloc_set(&mut sys, &mut puma).unwrap();
        assert!(uni.fill(&mut sys, s, &[5000]).is_err());
    }
}
