//! Set algebra over bit-vector sets (SISA-style graph/set workload).
//!
//! Sets over a bounded universe are dense bit vectors; union,
//! intersection, difference, and symmetric difference map directly
//! onto Boolean expressions over the PUD op set. Since PR 3 the
//! operations are *compiled*: each one builds a
//! [`crate::pud::compiler::Expr`], and [`System::run_expr`] lowers it
//! into a single coordinator batch with temporaries drawn from the
//! universe's reusable [`ScratchPool`]. That fixes the historical
//! temp-buffer pattern (a fresh allocation per `difference` call that
//! was never returned): across any number of calls the pool holds a
//! bounded set of leased rows, co-located with the sets themselves —
//! see `repeated_set_ops_do_not_grow_allocations` below.

use anyhow::Result;

use crate::alloc::scratch::ScratchPool;
use crate::alloc::traits::Allocator;
use crate::coordinator::system::System;
use crate::os::process::Pid;
use crate::pud::compiler::{self, Compiled, Expr, ExprBuilder, ExprId};

/// Indices into [`SetUniverse`]'s precompiled binary programs.
const OP_AND: usize = 0;
const OP_OR: usize = 1;
const OP_XOR: usize = 2;
const OP_ANDNOT: usize = 3;

/// Compile a 2-leaf program once (bound to fresh addresses per call).
fn compile_binary(
    build: impl FnOnce(&mut ExprBuilder, ExprId, ExprId) -> ExprId,
) -> Compiled {
    let mut b = ExprBuilder::new();
    let l0 = b.leaf(0);
    let l1 = b.leaf(1);
    let root = build(&mut b, l0, l1);
    compiler::compile(&b.build(root))
}

/// A set universe of `universe_bits` elements backed by PUD-placed
/// bit vectors.
pub struct SetUniverse {
    pub pid: Pid,
    pub len: u64,
    first_va: Option<u64>,
    /// Reusable compiler scratch, leased on first use and kept across
    /// operations.
    scratch: ScratchPool,
    /// The four binary programs (AND/OR/XOR/ANDNOT), compiled once.
    programs: [Compiled; 4],
}

/// Handle to one set.
#[derive(Debug, Clone, Copy)]
pub struct SetHandle {
    pub va: u64,
}

impl SetUniverse {
    pub fn new(universe_bits: u64, pid: Pid) -> Self {
        Self {
            pid,
            len: crate::pud::arith::plane_bytes(universe_bits as usize),
            first_va: None,
            scratch: ScratchPool::new(),
            programs: [
                compile_binary(|b, x, y| b.and(x, y)),
                compile_binary(|b, x, y| b.or(x, y)),
                compile_binary(|b, x, y| b.xor(x, y)),
                compile_binary(|b, x, y| b.and_not(x, y)),
            ],
        }
    }

    /// Allocate an empty set (hint-aligned to the first one).
    pub fn alloc_set(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
    ) -> Result<SetHandle> {
        let va = match self.first_va {
            None => {
                let va = sys.alloc(alloc, self.pid, self.len)?;
                self.first_va = Some(va);
                va
            }
            Some(f) => sys.alloc_align(alloc, self.pid, self.len, f)?,
        };
        Ok(SetHandle { va })
    }

    /// Populate a set from element ids.
    pub fn fill(
        &self,
        sys: &mut System,
        set: SetHandle,
        elements: &[u64],
    ) -> Result<()> {
        let mut bits = vec![0u8; self.len as usize];
        for &e in elements {
            anyhow::ensure!(e / 8 < self.len, "element {e} outside universe");
            bits[(e / 8) as usize] |= 1 << (e % 8);
        }
        sys.write_virt(self.pid, set.va, &bits)
    }

    /// Read a set's members back.
    pub fn members(&self, sys: &mut System, set: SetHandle) -> Result<Vec<u64>> {
        let bits = sys.read_virt(self.pid, set.va, self.len)?;
        let mut out = Vec::new();
        for (byte_idx, byte) in bits.iter().enumerate() {
            let mut b = *byte;
            while b != 0 {
                let bit = b.trailing_zeros() as u64;
                out.push(byte_idx as u64 * 8 + bit);
                b &= b - 1;
            }
        }
        Ok(out)
    }

    /// Compile and run an arbitrary set expression: `Leaf(i)` in
    /// `expr` reads `operands[i]`, the result lands in `dst`. Scratch
    /// rows come from the universe's reusable pool. Returns simulated
    /// ns.
    pub fn apply(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        dst: SetHandle,
        expr: &Expr,
        operands: &[SetHandle],
    ) -> Result<f64> {
        let vas: Vec<u64> = operands.iter().map(|h| h.va).collect();
        let rep = sys.run_expr(
            alloc,
            self.pid,
            expr,
            &vas,
            dst.va,
            self.len,
            &mut self.scratch,
        )?;
        Ok(rep.batch.total_ns)
    }

    /// Run one precompiled binary program (compile-once/bind-many —
    /// only address binding and execution happen per call).
    fn binary(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
        op: usize,
    ) -> Result<f64> {
        let rep = sys.run_compiled(
            alloc,
            self.pid,
            &self.programs[op],
            &[a.va, b.va],
            dst.va,
            self.len,
            &mut self.scratch,
        )?;
        Ok(rep.batch.total_ns)
    }

    /// dst = a INTERSECT b. Returns simulated ns.
    pub fn intersect(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
    ) -> Result<f64> {
        self.binary(sys, alloc, dst, a, b, OP_AND)
    }

    /// dst = a UNION b.
    pub fn union(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
    ) -> Result<f64> {
        self.binary(sys, alloc, dst, a, b, OP_OR)
    }

    /// dst = a SYMMETRIC-DIFFERENCE b.
    pub fn sym_diff(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
    ) -> Result<f64> {
        self.binary(sys, alloc, dst, a, b, OP_XOR)
    }

    /// dst = a DIFFERENCE b (`a & !b`). The complement's temp row
    /// comes from the reusable scratch pool — callers no longer pass
    /// (or leak) a scratch set.
    pub fn difference(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
        dst: SetHandle,
        a: SetHandle,
        b: SetHandle,
    ) -> Result<f64> {
        self.binary(sys, alloc, dst, a, b, OP_ANDNOT)
    }

    /// Scratch rows leased from the allocator over this universe's
    /// lifetime (stays flat under repeated operations).
    pub fn scratch_leases(&self) -> u64 {
        self.scratch.leases
    }

    /// Return the universe's scratch rows to `alloc`.
    pub fn release_scratch(
        &mut self,
        sys: &mut System,
        alloc: &mut dyn Allocator,
    ) -> Result<()> {
        sys.release_scratch(alloc, self.pid, &mut self.scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::puma::{FitPolicy, PumaAlloc};
    use crate::coordinator::system::SystemConfig;
    use crate::dram::address::InterleaveScheme;
    use crate::dram::geometry::DramGeometry;

    fn sys() -> System {
        let scheme = InterleaveScheme::row_major(DramGeometry::small());
        System::boot(SystemConfig {
            scheme,
            huge_pages: 16,
            churn_rounds: 500,
            seed: 12,
            artifacts: None,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn set_algebra_matches_reference() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 10).unwrap();
        let mut uni = SetUniverse::new(128 * 1024, pid);
        let a = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let b = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let dst = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let xs: Vec<u64> = (0..1000).map(|i| i * 7 % 100_000).collect();
        let ys: Vec<u64> = (0..1000).map(|i| i * 13 % 100_000).collect();
        uni.fill(&mut sys, a, &xs).unwrap();
        uni.fill(&mut sys, b, &ys).unwrap();

        use std::collections::BTreeSet;
        let sa: BTreeSet<u64> = xs.iter().copied().collect();
        let sb: BTreeSet<u64> = ys.iter().copied().collect();

        uni.intersect(&mut sys, &mut puma, dst, a, b).unwrap();
        let got: BTreeSet<u64> = uni.members(&mut sys, dst).unwrap().into_iter().collect();
        assert_eq!(got, &sa & &sb);

        uni.union(&mut sys, &mut puma, dst, a, b).unwrap();
        let got: BTreeSet<u64> = uni.members(&mut sys, dst).unwrap().into_iter().collect();
        assert_eq!(got, &sa | &sb);

        uni.sym_diff(&mut sys, &mut puma, dst, a, b).unwrap();
        let got: BTreeSet<u64> = uni.members(&mut sys, dst).unwrap().into_iter().collect();
        assert_eq!(got, &sa ^ &sb);

        uni.difference(&mut sys, &mut puma, dst, a, b).unwrap();
        let got: BTreeSet<u64> = uni.members(&mut sys, dst).unwrap().into_iter().collect();
        assert_eq!(got, &sa - &sb);

        // all of it in-DRAM under PUMA placement (incl. the compiled
        // difference's scratch row, leased with a co-location hint)
        assert!(sys.coord.stats.pud_row_fraction() > 0.9);
    }

    #[test]
    fn compiled_multi_operand_expression() {
        // (a | b) & !c in ONE batch through SetUniverse::apply
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 10).unwrap();
        let mut uni = SetUniverse::new(64 * 1024, pid);
        let a = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let b = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let c = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let dst = uni.alloc_set(&mut sys, &mut puma).unwrap();
        uni.fill(&mut sys, a, &[1, 5, 9]).unwrap();
        uni.fill(&mut sys, b, &[5, 7]).unwrap();
        uni.fill(&mut sys, c, &[9, 7, 100]).unwrap();
        let mut bld = ExprBuilder::new();
        let l0 = bld.leaf(0);
        let l1 = bld.leaf(1);
        let l2 = bld.leaf(2);
        let u = bld.or(l0, l1);
        let r = bld.and_not(u, l2);
        let expr = bld.build(r);
        let ops_before = sys.coord.stats.ops;
        uni.apply(&mut sys, &mut puma, dst, &expr, &[a, b, c]).unwrap();
        assert!(sys.coord.stats.ops > ops_before);
        assert_eq!(
            sys.coord.pipeline.batches, 1,
            "the whole expression is one submitted batch"
        );
        assert_eq!(uni.members(&mut sys, dst).unwrap(), vec![1, 5]);
    }

    #[test]
    fn repeated_set_ops_do_not_grow_allocations() {
        // the satellite fix: 100 differences / sym_diffs reuse one
        // leased scratch row instead of allocating per call
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 10).unwrap();
        let mut uni = SetUniverse::new(64 * 1024, pid);
        let a = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let b = uni.alloc_set(&mut sys, &mut puma).unwrap();
        let dst = uni.alloc_set(&mut sys, &mut puma).unwrap();
        uni.fill(&mut sys, a, &[2, 4, 6, 8]).unwrap();
        uni.fill(&mut sys, b, &[4, 8, 16]).unwrap();
        uni.difference(&mut sys, &mut puma, dst, a, b).unwrap();
        let allocs_after_first = puma.stats().allocs;
        let live_after_first = puma.live_regions();
        for _ in 0..99 {
            uni.difference(&mut sys, &mut puma, dst, a, b).unwrap();
            uni.sym_diff(&mut sys, &mut puma, dst, a, b).unwrap();
        }
        assert_eq!(
            puma.stats().allocs,
            allocs_after_first,
            "no net allocation growth across 100 iterations"
        );
        assert_eq!(puma.live_regions(), live_after_first);
        assert_eq!(uni.scratch_leases(), 1, "one reusable scratch row");
        uni.difference(&mut sys, &mut puma, dst, a, b).unwrap();
        assert_eq!(uni.members(&mut sys, dst).unwrap(), vec![2, 6]);
        // and the pool hands its rows back on release
        let frees_before = puma.stats().frees;
        uni.release_scratch(&mut sys, &mut puma).unwrap();
        assert_eq!(puma.stats().frees, frees_before + 1);
    }

    #[test]
    fn fill_rejects_out_of_universe() {
        let mut sys = sys();
        let pid = sys.spawn();
        let mut puma = PumaAlloc::new(8192, FitPolicy::WorstFit);
        puma.pim_preallocate(&mut sys.os, 4).unwrap();
        let mut uni = SetUniverse::new(1024, pid);
        let s = uni.alloc_set(&mut sys, &mut puma).unwrap();
        assert!(uni.fill(&mut sys, s, &[5000]).is_err());
    }
}
